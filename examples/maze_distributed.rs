//! End-to-end validation driver (DESIGN.md §6): solve a **million-state**
//! maze MDP on a 4-rank simulated-MPI world with iPI(GMRES), logging the
//! convergence trace and communication volume. This is the run recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! Run: `cargo run --release --example maze_distributed`
//! (defaults to 1024×1024 = 1,048,576 states; pass `--rows R --cols C` to
//! shrink, `--ranks N` to change the world size)

use madupite::comm::World;
use madupite::models::gridworld::GridSpec;
use madupite::models::ModelGenerator;
use madupite::solver::{gather_result, solve_dist, Method, SolveOptions};
use madupite::util::args::Options;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let opts = Options::from_env();
    let rows = opts.get_usize("rows", 1024).unwrap();
    let cols = opts.get_usize("cols", 1024).unwrap();
    let ranks = opts.get_usize("ranks", 4).unwrap();
    // γ = 0.9: the effective horizon (log atol / log γ ≈ 175 outer
    // iterations) bounds the PI wavefront on mazes whose diameter exceeds
    // it — the standard discounted-criterion setup for gigantic mazes.
    let gamma = opts.get_f64("gamma", 0.9).unwrap();

    println!(
        "maze_distributed: {rows}×{cols} = {} states, {ranks} ranks, γ={gamma}",
        rows * cols
    );
    let t0 = Instant::now();
    let spec = Arc::new(GridSpec::maze(rows, cols, 20_240_909));
    println!("maze generated in {:.2}s", t0.elapsed().as_secs_f64());

    let solve_opts = SolveOptions {
        method: Method::ipi_gmres(),
        atol: 1e-8,
        // Eisenstat–Walker adaptive forcing: on wavefront-limited problems
        // the outer count is fixed by the maze geometry, so the adaptation
        // keeps inner solves cheap while the front moves and tightens at
        // the end (ablation E7 — 12× over the fixed default)
        alpha: 1e-4,
        adaptive_forcing: true,
        max_outer: 100_000,
        ..Default::default()
    };

    let t1 = Instant::now();
    let spec2 = Arc::clone(&spec);
    let so = solve_opts.clone();
    let mut results = World::run(ranks, move |comm| {
        let build_start = Instant::now();
        let mdp = spec2.build_dist(&comm, gamma);
        if comm.is_root() {
            println!(
                "rank-local build: {} states/rank, {} local nnz, {:.2}s",
                mdp.local_states(),
                mdp.transitions().nnz_local(),
                build_start.elapsed().as_secs_f64()
            );
        }
        let local = solve_dist(&comm, &mdp, &so);
        gather_result(&comm, local)
    });
    let result = results.swap_remove(0);
    let solve_time = t1.elapsed().as_secs_f64();

    println!("\nconvergence trace (outer iteration, ‖TV−V‖∞, inner iters):");
    for rec in &result.trace {
        println!(
            "  {:3}  {:.6e}  {:4}",
            rec.outer, rec.residual, rec.inner_iterations
        );
    }
    println!(
        "\nconverged={} outer={} total_spmvs={} final_residual={:.3e}",
        result.converged, result.outer_iterations, result.total_spmvs, result.residual
    );
    println!(
        "solve wall time: {:.2}s   communication: {:.1} MiB across {ranks} ranks",
        solve_time,
        result.comm_bytes as f64 / (1024.0 * 1024.0)
    );
    println!(
        "V*[start]={:.4}  (goal value {:.2e})",
        result.value[0],
        result.value[rows * cols - 1]
    );

    // machine-readable record for EXPERIMENTS.md
    let json = result.to_json("maze_distributed_e2e");
    let path = "target/maze_distributed_e2e.json";
    if std::fs::create_dir_all("target").is_ok() {
        let _ = std::fs::write(path, json.to_string_pretty());
        println!("wrote {path}");
    }

    assert!(result.converged, "end-to-end run failed to converge");
}
