//! Traffic-signal control scenario (Xu et al. 2016 motivation): solve the
//! two-approach intersection MDP, print the optimal switching policy as a
//! phase diagram over queue states, and simulate the controlled
//! intersection to estimate average queue length under the optimal policy
//! vs a fixed-cycle baseline.
//!
//! Run: `cargo run --release --example traffic_control`

use madupite::models::traffic::TrafficSpec;
use madupite::models::ModelGenerator;
use madupite::solver::{solve_serial, Method, SolveOptions};
use madupite::util::args::Options;
use madupite::util::prng::Xoshiro256pp;

fn main() {
    let opts = Options::from_env();
    let capacity = opts.get_usize("capacity", 20).unwrap();
    let gamma = opts.get_f64("gamma", 0.99).unwrap();

    let spec = TrafficSpec::standard(capacity);
    println!(
        "traffic intersection: capacity={capacity} → {} states, arrivals ({}, {})",
        spec.n_states(),
        spec.arrival1,
        spec.arrival2
    );
    let mdp = spec.build_serial(gamma);
    let r = solve_serial(
        &mdp,
        &SolveOptions {
            method: Method::ipi_gmres(),
            atol: 1e-9,
            ..Default::default()
        },
    );
    assert!(r.converged);
    println!(
        "solved in {} outer iterations / {} spmvs ({:.3}s)\n",
        r.outer_iterations, r.total_spmvs, r.wall_time_s
    );

    // Phase diagram: when approach 1 is green, for which (q1, q2) do we
    // switch? ('.' = keep, 'S' = switch)
    println!("switch policy while phase-1 green (rows q1=0.., cols q2=0..):");
    let show = capacity.min(14);
    for q1 in 0..=show {
        let mut line = String::new();
        for q2 in 0..=show {
            let s = spec.encode(q1, q2, 0);
            line.push(if r.policy[s] == 1 { 'S' } else { '.' });
        }
        println!("  q1={q1:2} {line}");
    }

    // Closed-loop simulation: optimal policy vs fixed 4-period cycle.
    let horizon = 200_000;
    let avg_opt = simulate(&spec, horizon, 99, |s, t| {
        let _ = t;
        r.policy[s]
    });
    let avg_fixed = simulate(&spec, horizon, 99, |s, t| {
        // switch every 4 periods regardless of queues
        let (_, _, phase) = spec.decode(s);
        let want = (t / 4) % 2;
        usize::from(phase != want)
    });
    println!("\nclosed-loop average total queue over {horizon} periods:");
    println!("  optimal policy   : {avg_opt:.3}");
    println!("  fixed 4-cycle    : {avg_fixed:.3}");
    println!(
        "  improvement      : {:.1}%",
        100.0 * (avg_fixed - avg_opt) / avg_fixed
    );
}

/// Simulate the intersection under a policy; returns average total queue.
fn simulate(
    spec: &TrafficSpec,
    horizon: usize,
    seed: u64,
    policy: impl Fn(usize, usize) -> usize,
) -> f64 {
    let mut rng = Xoshiro256pp::new(seed);
    let mut s = spec.encode(0, 0, 0);
    let mut total_queue = 0.0;
    for t in 0..horizon {
        let a = policy(s, t);
        let row = spec.prob_row(s, a);
        // sample the next state from the transition row
        let u = rng.next_f64();
        let mut acc = 0.0;
        let mut next = row[0].0;
        for &(tgt, p) in &row {
            acc += p;
            if u < acc {
                next = tgt;
                break;
            }
        }
        s = next;
        let (q1, q2, _) = spec.decode(s);
        total_queue += (q1 + q2) as f64;
    }
    total_queue / horizon as f64
}
