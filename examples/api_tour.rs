//! API tour: define an MDP from closures, solve it hybrid-parallel on
//! 4 ranks × 2 threads per rank through the options database, and write
//! the madupite-style output files
//! (`write_policy` / `write_cost` / `write_json_metadata`).
//!
//! The model is a service-queue admission problem defined entirely inline —
//! no generator, no file — in the spirit of madupite's
//! `createTransitionProbabilityTensor` closures: a queue of up to N jobs,
//! arrivals with probability p, and two actions (slow/fast service) trading
//! service cost against holding and overflow cost.
//!
//! Run: `cargo run --release --example api_tour`

use madupite::api::{MdpBuilder, Solver};

fn main() -> Result<(), madupite::api::ApiError> {
    // 1. The model, as closures. States 0..=n_jobs count queued jobs.
    let n_states = 2_000usize;
    let p_arrival = 0.6;
    // service completion probability per action: slow is cheap, fast costs
    let p_serve = [0.5, 0.85];

    let prob = move |s: usize, a: usize| -> Vec<(usize, f64)> {
        let last = n_states - 1;
        let ps = p_serve[a];
        // transitions: arrival (+1 unless full), service (−1 unless empty)
        let up = if s < last { p_arrival * (1.0 - ps) } else { 0.0 };
        let down = if s > 0 { ps * (1.0 - p_arrival) } else { 0.0 };
        let stay = 1.0 - up - down;
        let mut row = Vec::with_capacity(3);
        if down > 0.0 {
            row.push((s - 1, down));
        }
        row.push((s, stay));
        if up > 0.0 {
            row.push((s + 1, up));
        }
        row
    };
    let cost = move |s: usize, a: usize| -> f64 {
        let holding = s as f64 * 0.05;
        let service = if a == 1 { 1.0 } else { 0.2 };
        let overflow = if s == n_states - 1 { 50.0 } else { 0.0 };
        holding + service + overflow
    };

    // 2. Build + configure through the options database, madupite style.
    let builder = MdpBuilder::from_fillers(n_states, 2, prob, cost).gamma(0.995);
    let mut solver = Solver::new(builder);
    solver.set_options_from_str(
        "-method ipi -ksp_type gmres -pc_type jacobi -alpha 1e-4 -atol 1e-9 \
         -ranks 4 -threads 2",
    )?;
    solver.set_options_from_env()?; // MADUPITE_OPTIONS supplies low-priority defaults

    // 3. Solve hybrid-parallel on 4 SPMD ranks × 2 worker threads each
    // (the thread dimension changes wall time only — results are bitwise
    // identical for any -threads, see DESIGN.md §11).
    let outcome = solver.solve()?;
    println!(
        "solved {} states x {} actions on {} ranks x {} threads: method={} converged={} \
         outer={} spmvs={} residual={:.2e} time={:.3}s",
        outcome.n_states,
        outcome.n_actions,
        outcome.ranks,
        outcome.threads,
        outcome.options.method.name(),
        outcome.result.converged,
        outcome.result.outer_iterations,
        outcome.result.total_spmvs,
        outcome.result.residual,
        outcome.result.wall_time_s,
    );

    // 4. Inspect: below some queue length the slow server suffices; past
    // the threshold the optimal policy switches to the fast server.
    let switch = outcome.policy().iter().position(|&a| a == 1);
    match switch {
        Some(s) => println!("policy switches to fast service at queue length {s}"),
        None => println!("slow service is optimal everywhere"),
    }

    // 5. Write the madupite output surface (root-gathered, one writer).
    let dir = std::env::temp_dir().join("madupite_api_tour");
    std::fs::create_dir_all(&dir)
        .map_err(|e| madupite::api::ApiError(format!("creating {}: {e}", dir.display())))?;
    let policy_path = dir.join("policy.txt");
    let cost_path = dir.join("cost.txt");
    let meta_path = dir.join("metadata.json");
    outcome.write_policy(&policy_path)?;
    outcome.write_cost(&cost_path)?;
    outcome.write_json_metadata(&meta_path)?;
    println!(
        "wrote {}, {}, {}",
        policy_path.display(),
        cost_path.display(),
        meta_path.display()
    );
    Ok(())
}
