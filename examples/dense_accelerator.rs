//! Dense-block accelerator: run Bellman backups and policy evaluation on a
//! dense `(A,S,S)` transition block, and validate that the dense path and
//! the sparse solver agree.
//!
//! Three backends meet here (DESIGN.md §4):
//!
//! 1. the native Rust dense kernel (`bellman_dense_native`) — the reference
//!    the AOT artifacts are validated against;
//! 2. the shared KSP stack over `ksp::DenseOp` — dense policy evaluation
//!    through exactly the same Krylov code the sparse solver uses, thanks
//!    to the `Apply` operator trait;
//! 3. the PJRT-executed Pallas/HLO artifacts (L1/L2), when an XLA client is
//!    linked and `make artifacts` has produced `artifacts/*.hlo.txt` —
//!    reported as unavailable in the zero-dependency build.
//!
//! Run: `cargo run --release --example dense_accelerator`

use madupite::ksp::{self, Apply, DenseOp, Precond, Tolerance};
use madupite::linalg::Csr;
use madupite::mdp::Mdp;
use madupite::runtime::{bellman_dense_native, dense_policy_matrix, random_block, Engine};
use madupite::solver::{solve_serial, Method, SolveOptions};
use std::time::Instant;

fn main() {
    let (n, m) = (64usize, 4usize);
    let (p, g, _) = random_block(2024, n, m);
    let gamma = 0.95f32;

    // --- 1. native dense VI to the fixed point ----------------------------
    let t = Instant::now();
    let mut v = vec![0.0f32; n];
    let mut sweeps = 0usize;
    let pi = loop {
        let (tv, tpi) = bellman_dense_native(n, m, &p, &g, &v, gamma);
        let res = tv
            .iter()
            .zip(&v)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        v = tv;
        sweeps += 1;
        if res < 1e-5 || sweeps >= 10_000 {
            break tpi;
        }
    };
    println!("native dense VI : {sweeps} sweeps in {:?}", t.elapsed());

    // --- 2. evaluate the greedy policy through DenseOp + GMRES ------------
    // The dense block flows through the *same* KSP stack as the sparse
    // solver: DenseOp implements the Apply operator trait.
    let policy: Vec<usize> = pi.iter().map(|&a| a as usize).collect();
    let p_pi = dense_policy_matrix(n, m, &p, &policy);
    let g_pi: Vec<f64> = policy
        .iter()
        .enumerate()
        .map(|(s, &a)| g[a * n + s] as f64)
        .collect();
    let t = Instant::now();
    let v_ksp = madupite::comm::World::run(1, move |comm| {
        let op = DenseOp::new(&p_pi, gamma as f64);
        let mut x = vec![0.0f64; n];
        let tol = Tolerance {
            atol: 1e-10,
            rtol: 0.0,
            max_iters: 10_000,
        };
        let stats = ksp::gmres::solve(&comm, &op, &Precond::None, &g_pi, &mut x, &tol, 30);
        assert!(stats.converged, "DenseOp GMRES did not converge");
        let mut buf = op.make_buffer();
        let mut r = vec![0.0f64; n];
        let res = op.residual(&comm, &g_pi, &x, &mut r, &mut buf);
        assert!(res < 1e-8, "DenseOp residual {res}");
        x
    })
    .swap_remove(0);
    println!("DenseOp + GMRES : policy evaluation in {:?}", t.elapsed());
    let max_diff = v_ksp
        .iter()
        .zip(&v)
        .map(|(a, b)| (a - *b as f64).abs())
        .fold(0.0f64, f64::max);
    // V* equals V^π* for the greedy policy at the fixed point (f32 slack)
    assert!(max_diff < 1e-2, "DenseOp vs native VI diverged: {max_diff}");
    println!("                  max|V_ksp − V_vi| = {max_diff:.2e}");

    // --- 3. cross-validate against the sparse L3 solver -------------------
    let mut rows = Vec::with_capacity(n * m);
    let mut costs = Vec::with_capacity(n * m);
    for s in 0..n {
        for a in 0..m {
            // renormalize: f32 rows sum to 1 only within ~1e-6
            let raw: Vec<f64> = (0..n).map(|t2| p[a * n * n + s * n + t2] as f64).collect();
            let sum: f64 = raw.iter().sum();
            let row: Vec<(usize, f64)> = raw
                .into_iter()
                .enumerate()
                .map(|(t2, x)| (t2, x / sum))
                .collect();
            rows.push(row);
            costs.push(g[a * n + s] as f64);
        }
    }
    let mdp = Mdp::new(n, m, Csr::from_row_lists(n, rows), costs, gamma as f64)
        .expect("dense block converts to a valid MDP");
    let r = solve_serial(
        &mdp,
        &SolveOptions {
            method: Method::ipi_gmres(),
            atol: 1e-9,
            ..Default::default()
        },
    );
    let max_diff = v
        .iter()
        .zip(&r.value)
        .map(|(a, b)| (*a as f64 - b).abs())
        .fold(0.0f64, f64::max);
    let pol_match = pi
        .iter()
        .zip(&r.policy)
        .filter(|(a, b)| **a as usize == **b)
        .count();
    println!(
        "cross-validation: max|V_dense − V_sparse| = {max_diff:.2e}, \
         policies agree on {pol_match}/{n} states"
    );
    assert!(max_diff < 1e-3, "backends disagree: {max_diff}");

    // --- 4. PJRT artifacts, when available --------------------------------
    match Engine::load("artifacts") {
        Ok(engine) => println!("PJRT platform: {}", engine.platform()),
        Err(e) => println!("\nPJRT path skipped: {e}"),
    }

    println!("\ndense backends agree ✓");
}
