//! Dense-block accelerator: run the Bellman backup through the full
//! three-layer stack — the Pallas kernel (L1) embedded in the jax graph
//! (L2), AOT-compiled to HLO and executed from Rust via PJRT — and validate
//! it against both the native Rust dense kernel and the sparse solver.
//!
//! Requires `make artifacts` to have produced `artifacts/*.hlo.txt`.
//!
//! Run: `cargo run --release --example dense_accelerator`

use madupite::mdp::Mdp;
use madupite::runtime::{bellman_dense_native, random_block, DenseBellman, Engine};
use madupite::solver::{solve_serial, Method, SolveOptions};
use madupite::linalg::Csr;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let mut engine = Engine::load("artifacts")?;
    println!("PJRT platform: {}", engine.platform());
    println!("artifacts: {:?}\n", engine.available());

    let (n, m) = (64usize, 4usize);
    let db = DenseBellman::new(&engine, n, m)?;
    let (p, g, _) = random_block(2024, n, m);
    let gamma = 0.95f32;

    // --- 1. single backup: PJRT vs native rust ---------------------------
    let v0 = vec![0.0f32; n];
    let t = Instant::now();
    let (tv_pjrt, pi_pjrt) = db.bellman(&mut engine, &p, &g, &v0, gamma)?;
    let pjrt_first = t.elapsed();
    let t = Instant::now();
    let (tv_native, pi_native) = bellman_dense_native(n, m, &p, &g, &v0, gamma);
    let native_time = t.elapsed();
    let max_diff = tv_pjrt
        .iter()
        .zip(&tv_native)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "PJRT vs native diverged: {max_diff}");
    assert_eq!(pi_pjrt, pi_native);
    println!(
        "single backup   : PJRT(first, incl. compile) {:?} | native {:?} | max|Δ| = {:.1e}",
        pjrt_first, native_time, max_diff
    );
    let t = Instant::now();
    let _ = db.bellman(&mut engine, &p, &g, &v0, gamma)?;
    println!("single backup   : PJRT(cached executable) {:?}", t.elapsed());

    // --- 2. fused k-sweep VI: one dispatch per k sweeps -------------------
    let t = Instant::now();
    let (v_star, pi_star, sweeps) = db.solve_vi(&mut engine, &p, &g, gamma, 1e-5, 10_000)?;
    println!(
        "fused VI solve  : {} sweeps in {:?} ({} dispatches)",
        sweeps,
        t.elapsed(),
        sweeps / db.sweeps * 2
    );

    // --- 3. cross-validate against the sparse L3 solver -------------------
    // Convert the dense block to the sparse Mdp representation and solve
    // with iPI(GMRES); values must agree to f32 tolerance.
    let mut rows = Vec::with_capacity(n * m);
    let mut costs = Vec::with_capacity(n * m);
    for s in 0..n {
        for a in 0..m {
            // renormalize: f32 rows sum to 1 only within ~1e-6
            let raw: Vec<f64> = (0..n).map(|t2| p[a * n * n + s * n + t2] as f64).collect();
            let sum: f64 = raw.iter().sum();
            let row: Vec<(usize, f64)> = raw
                .into_iter()
                .enumerate()
                .map(|(t2, x)| (t2, x / sum))
                .collect();
            rows.push(row);
            costs.push(g[a * n + s] as f64);
        }
    }
    let mdp = Mdp::new(n, m, Csr::from_row_lists(n, rows), costs, gamma as f64)
        .expect("dense block converts to a valid MDP");
    let r = solve_serial(
        &mdp,
        &SolveOptions {
            method: Method::ipi_gmres(),
            atol: 1e-9,
            ..Default::default()
        },
    );
    let max_diff = v_star
        .iter()
        .zip(&r.value)
        .map(|(a, b)| (*a as f64 - b).abs())
        .fold(0.0f64, f64::max);
    let pol_match = pi_star
        .iter()
        .zip(&r.policy)
        .filter(|(a, b)| **a as usize == **b)
        .count();
    println!(
        "cross-validation: max|V_pjrt − V_sparse| = {:.2e}, policies agree on {}/{} states",
        max_diff, pol_match, n
    );
    assert!(max_diff < 1e-3, "layers disagree: {max_diff}");
    println!("\nall three layers agree ✓");
    Ok(())
}
