//! Quickstart: build a small maze MDP through the embedded API, solve it
//! with three methods via the options database, and compare their work
//! counts — the 60-second tour of the public API.
//!
//! Run: `cargo run --release --example quickstart`

use madupite::api::{MdpBuilder, Solver};
use madupite::models::gridworld::GridSpec;
use std::sync::Arc;

fn main() -> Result<(), madupite::api::ApiError> {
    // 1. Build a 32×32 maze MDP (1024 states, 4 actions, γ = 0.99).
    let spec = GridSpec::maze(32, 32, 7);
    let builder = MdpBuilder::from_model(Arc::new(spec.clone())).gamma(0.99);
    let mdp = builder.build_serial()?;
    println!(
        "maze MDP: {} states × {} actions, {} transition nonzeros",
        mdp.n_states(),
        mdp.n_actions(),
        mdp.transitions().nnz()
    );

    // 2. Solve with value iteration, modified PI, and iPI(GMRES) — all
    // configured through the same `-key value` options database the CLI
    // uses.
    for method in ["vi", "mpi", "ipi"] {
        let mut solver = Solver::new(builder.clone());
        solver
            .set_option("-method", method)?
            .set_option("-atol", "1e-8")?
            .set_option("-max_iter_pi", "100000")?;
        let outcome = solver.solve()?;
        println!(
            "  {:<14} converged={} outer={:5} spmvs={:6} residual={:.2e} time={:.3}s",
            outcome.options.method.name(),
            outcome.result.converged,
            outcome.result.outer_iterations,
            outcome.result.total_spmvs,
            outcome.result.residual,
            outcome.result.wall_time_s
        );
    }

    // 3. Inspect the solution: V* at the start corner and the first move.
    let mut solver = Solver::new(builder);
    solver.set_options_from_str("-method ipi -ksp_type gmres -atol 1e-10")?;
    let outcome = solver.solve()?;
    let action_names = ["north", "east", "south", "west"];
    println!(
        "\noptimal expected cost from the start corner: {:.4}",
        outcome.value()[0]
    );
    println!(
        "first move from the start corner: {}",
        action_names[outcome.policy()[0]]
    );
    println!(
        "value at the goal (must be 0): {:.2e}",
        outcome.value()[spec.goal.0 * 32 + spec.goal.1]
    );
    Ok(())
}
