//! Quickstart: build a small maze MDP, solve it with three methods, and
//! compare their work counts — the 60-second tour of the public API.
//!
//! Run: `cargo run --release --example quickstart`

use madupite::models::gridworld::GridSpec;
use madupite::models::ModelGenerator;
use madupite::solver::{solve_serial, Method, SolveOptions};

fn main() {
    // 1. Build a 32×32 maze MDP (1024 states, 4 actions, γ = 0.99).
    let spec = GridSpec::maze(32, 32, 7);
    let mdp = spec.build_serial(0.99);
    println!(
        "maze MDP: {} states × {} actions, {} transition nonzeros",
        mdp.n_states(),
        mdp.n_actions(),
        mdp.transitions().nnz()
    );

    // 2. Solve with value iteration, modified PI, and iPI(GMRES).
    for method in [Method::Vi, Method::Mpi { sweeps: 20 }, Method::ipi_gmres()] {
        let opts = SolveOptions {
            method: method.clone(),
            atol: 1e-8,
            max_outer: 100_000,
            ..Default::default()
        };
        let r = solve_serial(&mdp, &opts);
        println!(
            "  {:<14} converged={} outer={:5} spmvs={:6} residual={:.2e} time={:.3}s",
            method.name(),
            r.converged,
            r.outer_iterations,
            r.total_spmvs,
            r.residual,
            r.wall_time_s
        );
    }

    // 3. Inspect the solution: V* at the start corner and the first moves.
    let r = solve_serial(
        &mdp,
        &SolveOptions {
            method: Method::ipi_gmres(),
            atol: 1e-10,
            ..Default::default()
        },
    );
    let action_names = ["north", "east", "south", "west"];
    println!(
        "\noptimal expected cost from the start corner: {:.4}",
        r.value[0]
    );
    println!("first move from the start corner: {}", action_names[r.policy[0]]);
    println!(
        "value at the goal (must be 0): {:.2e}",
        r.value[spec.goal.0 * 32 + spec.goal.1]
    );
}
