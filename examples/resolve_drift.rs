//! Warm-started re-solve of a drifting model: the maintenance-loop story
//! from DESIGN.md §16 end to end.
//!
//! 1. Solve a maze cold and write a `.mdpa` checkpoint.
//! 2. Drift the model: ~2% cost perturbation on a slice of the entries.
//! 3. Re-solve the drifted model cold, then warm-started from the
//!    checkpoint via `-warm_start` — same tolerance, fewer outer
//!    iterations.
//! 4. Re-solve the *unchanged* model warm: one outer iteration, value
//!    bitwise identical to the checkpoint.
//!
//! Run: `cargo run --release --example resolve_drift`

use madupite::api::{MdpBuilder, Solver};
use madupite::models::gridworld::GridSpec;
use madupite::models::ModelGenerator;
use std::sync::Arc;

fn main() -> Result<(), madupite::api::ApiError> {
    let dir = std::env::temp_dir().join(format!("madupite-resolve-drift-{}", std::process::id()));
    std::fs::create_dir_all(&dir).map_err(|e| madupite::api::ApiError(e.to_string()))?;
    let checkpoint_path = dir.join("maze.mdpa");

    // 1. Cold solve + checkpoint. The checkpoint is the same digest-verified
    // artifact format the policy-serving store uses.
    let spec = Arc::new(GridSpec::maze(24, 24, 7));
    let builder = MdpBuilder::from_model(Arc::clone(&spec) as Arc<dyn ModelGenerator + Send + Sync>)
        .gamma(0.99);
    let mut solver = Solver::new(builder.clone());
    solver.set_options_from_str("-method ipi -ksp_type gmres -atol 1e-9")?;
    let cold = solver.solve()?;
    cold.write_checkpoint(&checkpoint_path)?;
    println!(
        "cold solve:   outer={:3}  residual={:.2e}  checkpoint={} ({})",
        cold.result.outer_iterations,
        cold.result.residual,
        checkpoint_path.display(),
        cold.fingerprint()
    );

    // 2. Drift: every 9th state's costs move by up to ±2% (deterministic).
    let (n, m) = (spec.n_states(), spec.n_actions());
    let mut x: u64 = 0x9e3779b97f4a7c15;
    let mut patches = Vec::new();
    for s in (0..n).step_by(9) {
        for a in 0..m {
            x = x
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let u = (x >> 11) as f64 / (1u64 << 53) as f64;
            patches.push((s, a, spec.cost(s, a) * (1.0 + 0.02 * (2.0 * u - 1.0))));
        }
    }
    println!("drift:        {} of {} cost entries perturbed ±2%", patches.len(), n * m);

    // 3. Cold vs warm on the drifted model. Both paths run to the same
    // tolerance; `-warm_start` only changes the starting point. The patch
    // re-validates touched rows only.
    let drifted = builder.clone().patch_costs(patches);
    let mut cold_solver = Solver::new(drifted.clone());
    cold_solver.set_options_from_str("-method ipi -ksp_type gmres -atol 1e-9")?;
    let drift_cold = cold_solver.solve()?;

    let mut warm_solver = Solver::new(drifted);
    warm_solver.set_options_from_str("-method ipi -ksp_type gmres -atol 1e-9")?;
    warm_solver.set_option("-warm_start", checkpoint_path.to_str().unwrap())?;
    let drift_warm = warm_solver.solve()?;

    println!(
        "drift cold:   outer={:3}  residual={:.2e}",
        drift_cold.result.outer_iterations, drift_cold.result.residual
    );
    println!(
        "drift warm:   outer={:3}  residual={:.2e}  (seeded from {})",
        drift_warm.result.outer_iterations,
        drift_warm.result.residual,
        drift_warm.warm_start.as_deref().unwrap_or("-")
    );
    assert!(drift_cold.result.converged && drift_warm.result.converged);
    assert!(
        drift_warm.result.outer_iterations < drift_cold.result.outer_iterations,
        "warm start must save outer iterations under small drift"
    );
    assert!(drift_warm.result.residual < 1e-9, "same tolerance on both paths");

    // 4. Warm re-solve of the *unchanged* model: the convergence check
    // fires before any update, so the value comes back bitwise identical
    // in a single outer iteration.
    let mut unchanged = Solver::new(builder);
    unchanged.set_options_from_str("-method ipi -ksp_type gmres -atol 1e-9")?;
    unchanged.set_option("-warm_start", checkpoint_path.to_str().unwrap())?;
    let warm = unchanged.solve()?;
    assert_eq!(warm.result.outer_iterations, 1);
    assert!(warm
        .value()
        .iter()
        .zip(cold.value())
        .all(|(a, b)| a.to_bits() == b.to_bits()));
    assert_eq!(warm.fingerprint(), cold.fingerprint());
    println!(
        "no-drift warm: outer={:3}  value bitwise == checkpoint, fingerprint unchanged",
        warm.result.outer_iterations
    );

    let saved = drift_cold.result.outer_iterations - drift_warm.result.outer_iterations;
    println!("\nwarm start saved {saved} outer iterations under drift");
    Ok(())
}
