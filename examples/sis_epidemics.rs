//! Epidemic-control scenario (Steimle & Denton 2017 motivation): compute
//! the optimal intervention policy for a stochastic SIS model with 100k+
//! states, and show how the inner-solver choice changes the work required —
//! the paper's "select the method tailored to your application" claim (C2)
//! on a real workload.
//!
//! Run: `cargo run --release --example sis_epidemics`

use madupite::models::sis::SisSpec;
use madupite::models::ModelGenerator;
use madupite::solver::{solve_world, Method, SolveOptions};
use madupite::util::args::Options;
use std::sync::Arc;

fn main() {
    let opts = Options::from_env();
    let population = opts.get_usize("population", 100_000).unwrap();
    let gamma = opts.get_f64("gamma", 0.999).unwrap();
    let ranks = opts.get_usize("ranks", 2).unwrap();

    let spec = SisSpec::standard(population, 5);
    println!(
        "SIS epidemic control: population={population} → {} states × {} interventions, γ={gamma}",
        spec.n_states(),
        spec.n_actions()
    );
    let mdp = Arc::new(spec.build_serial(gamma));

    // γ → 1 is exactly where VI collapses and Krylov-iPI shines.
    let methods = [
        Method::Mpi { sweeps: 50 },
        Method::ipi_gmres(),
        Method::ipi_bicgstab(),
    ];
    for method in methods {
        let r = solve_world(
            Arc::clone(&mdp),
            ranks,
            &SolveOptions {
                method: method.clone(),
                atol: 1e-8,
                max_outer: 200_000,
                ..Default::default()
            },
        );
        println!(
            "  {:<16} converged={} outer={:6} spmvs={:8} time={:.3}s",
            method.name(),
            r.converged,
            r.outer_iterations,
            r.total_spmvs,
            r.wall_time_s
        );
    }

    // Inspect the optimal policy's shape: intervention level vs prevalence.
    let r = solve_world(
        Arc::clone(&mdp),
        ranks,
        &SolveOptions {
            method: Method::ipi_gmres(),
            atol: 1e-9,
            ..Default::default()
        },
    );
    println!("\nprevalence → optimal intervention level (sampled):");
    for pct in [0usize, 1, 2, 5, 10, 20, 40, 60, 80, 100] {
        let i = (population * pct) / 100;
        println!(
            "  {:3}% infected (i={:7}):  level {}   V={:.4}",
            pct, i, r.policy[i], r.value[i]
        );
    }
}
