//! Factored MDPs and the ADD compression backend (DESIGN.md §17).
//!
//! Every flat catalog model enumerates its state space, so combinatorially
//! structured problems (network epidemics, machine lines) hit memory walls
//! long before the solver does. This module fights the curse of
//! dimensionality with *structure* instead of only distribution:
//!
//! - [`spec`] — the factored model description: state = tuple of discrete
//!   variables, transitions as per-variable CPTs over parent scopes,
//!   costs as sums of local scope functions ([`FactoredMdp`]);
//! - [`add`] — a hash-consed algebraic decision diagram store with
//!   `apply` / `restrict` / `marginalize` over shared subgraphs
//!   ([`AddStore`]);
//! - [`svi`] — SPUDD-style structured value iteration: the Bellman backup
//!   runs entirely on ADDs and the greedy policy is extracted as an ADD
//!   ([`solve_svi`]);
//! - [`compile`] — the escape hatch to everything that already exists:
//!   stream the flattened kernel to `.mdpb` in O(chunk) memory
//!   ([`compile_to_mdpb`]) and solve with any method × backend × rank ×
//!   thread configuration.
//!
//! The two consumption paths are pinned against each other by the
//! cross-representation conformance suite (`tests/factored.rs`):
//! structured VI and compile-then-flat-solve must agree to 1e-9 in value
//! and exactly in policy on every enumerable factored model.
//!
//! Front-door integration: `MdpBuilder::from_factored` /
//! `MdpBuilder::factored` take a [`FactoredMdp`] as a model source, and
//! the factored catalog models (`sis_factored`, `factory`) expose their
//! spec through `ModelGenerator::factored`. `-factored_mode svi|compile`
//! selects the path and `-factored_order` the elimination order.

pub mod add;
pub mod compile;
pub mod spec;
pub mod svi;

pub use add::{AddStore, NodeId, Op};
pub use compile::compile_to_mdpb;
pub use spec::{
    CostTerm, Cpt, FactoredError, FactoredMdp, VarSpec, CPT_TOL, MAX_ENUMERABLE_STATES,
};
pub use svi::{solve_svi, FactoredOrder, SviOptions, SviResult};

/// Which consumption path a factored source solves through
/// (`-factored_mode`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum FactoredMode {
    /// Flatten through the existing distributed builders and solve with
    /// the configured flat method (the default).
    #[default]
    Compile,
    /// SPUDD-style structured value iteration on ADDs (serial).
    Svi,
}

impl FactoredMode {
    /// Stable option-value name.
    pub fn name(&self) -> &'static str {
        match self {
            FactoredMode::Compile => "compile",
            FactoredMode::Svi => "svi",
        }
    }
}
