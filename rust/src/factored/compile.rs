//! Streaming compilation of a factored MDP to the flat `.mdpb` format
//! (DESIGN.md §17).
//!
//! The factored description is a pure function `(s, a) → row / cost`
//! ([`FactoredMdp::flat_prob_row`] / [`FactoredMdp::flat_cost`]), so the
//! existing two-pass streaming writer does all the heavy lifting: rows
//! are produced chunk-by-chunk, rank-parallel, in O(chunk) memory — the
//! flat kernel is *never* materialized, even when it has billions of
//! nonzeros. The output is a standard `.mdpb` v3 file, so every method ×
//! backend × rank × thread configuration of the flat solver (and the
//! serving/re-solve layers behind it) consumes compiled factored models
//! with no further changes. Bytes are identical for every world size, a
//! property `tests/par_determinism.rs` pins for the factored path too.

use super::spec::FactoredMdp;
use crate::comm::Comm;
use crate::mdp::{io, Objective};
use std::path::Path;

/// Stream the flattened kernel of `fmdp` to `path` as `.mdpb` v3.
/// Collective over `comm`; returns the written header. Equivalent to
/// `ModelGenerator::write_mdpb` on the spec — exposed under its
/// task-specific name so the compile pipeline is discoverable.
pub fn compile_to_mdpb(
    fmdp: &FactoredMdp,
    comm: &Comm,
    path: &Path,
    gamma: f64,
    objective: Objective,
    chunk_rows: usize,
) -> std::io::Result<io::Header> {
    io::write_streaming(
        comm,
        path,
        fmdp.n_states(),
        fmdp.n_actions(),
        gamma,
        objective,
        chunk_rows,
        |s, a| fmdp.flat_prob_row(s, a),
        |s, a| fmdp.flat_cost(s, a),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;
    use crate::factored::spec::{CostTerm, Cpt, VarSpec};
    use std::sync::Arc;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("madupite-factored-compile");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn chain(n: usize) -> FactoredMdp {
        // n binary variables, each flips toward 0 under action 1
        let cpts = (0..n)
            .map(|i| Cpt {
                var: i,
                scope: vec![i],
                rows: vec![0.9, 0.1, 0.3, 0.7, 0.95, 0.05, 0.6, 0.4],
            })
            .collect();
        let costs = (0..n)
            .map(|i| CostTerm {
                scope: vec![i],
                values: vec![0.0, 1.0 + 0.1 * i as f64, 0.2, 1.2 + 0.1 * i as f64],
            })
            .collect();
        FactoredMdp::new(
            (0..n).map(|i| VarSpec::new(&format!("x{i}"), 2)).collect(),
            2,
            cpts,
            costs,
        )
        .unwrap()
    }

    #[test]
    fn compiled_file_loads_and_matches_the_spec() {
        let f = Arc::new(chain(4));
        let path = tmpfile("chain4.mdpb");
        {
            let f = Arc::clone(&f);
            let path = path.clone();
            World::run(1, move |comm| {
                compile_to_mdpb(&f, &comm, &path, 0.95, Objective::Min, 8).unwrap();
            });
        }
        let mdp = crate::mdp::io::load(&path).unwrap();
        assert_eq!(mdp.n_states(), f.n_states());
        assert_eq!(mdp.n_actions(), f.n_actions());
        for s in 0..f.n_states() {
            for a in 0..f.n_actions() {
                assert!((mdp.cost(s, a) - f.flat_cost(s, a)).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn bytes_identical_across_world_sizes() {
        let f = Arc::new(chain(5));
        let mut blobs = Vec::new();
        for ranks in [1usize, 3] {
            let path = tmpfile(&format!("chain5_r{ranks}.mdpb"));
            {
                let f = Arc::clone(&f);
                let path = path.clone();
                World::run(ranks, move |comm| {
                    compile_to_mdpb(&f, &comm, &path, 0.9, Objective::Min, 4).unwrap();
                });
            }
            blobs.push(std::fs::read(&path).unwrap());
        }
        assert_eq!(blobs[0], blobs[1], "compiled bytes differ across ranks");
    }
}
