//! Factored MDP description: tuple-valued states, per-variable CPTs over
//! parent scopes, and additively decomposed costs (DESIGN.md §17).
//!
//! A [`FactoredMdp`] never materializes its flat state space. The state is
//! a tuple `(x_0, …, x_{n-1})` of discrete variables; the transition
//! kernel factorizes as `P(x' | x, a) = Π_i P_i(x_i' | scope_i(x), a)`
//! (one [`Cpt`] per variable) and the stage cost decomposes as
//! `c(x, a) = Σ_j c_j(scope_j(x), a)` (a list of [`CostTerm`]s). Both
//! consumption paths — the SPUDD-style structured solver
//! ([`crate::factored::solve_svi`]) and the streaming flat compiler
//! ([`crate::factored::compile_to_mdpb`]) — read this one description.
//!
//! Flat-space encoding: variable 0 is the most significant digit of the
//! mixed-radix state index. This makes the cartesian-product enumeration
//! in [`FactoredMdp::flat_prob_row`] emit successor columns in ascending
//! order, which is exactly what the CSR builders and the `.mdpb` writer
//! require.
//!
//! Validation is strict and typed ([`FactoredError`]): malformed scopes,
//! mis-sized tables, and sub-stochastic CPT columns are rejected at
//! construction, and every accepted distribution is then *exactly*
//! normalized (divided by its float sum) so products of `n` per-variable
//! factors stay within a few ulps of row-stochastic — the flat pipeline
//! re-validates rows at its own 1e-8 bar and must never trip over
//! accumulated CPT round-off.

use crate::models::ModelGenerator;

/// Looser-than-float tolerance for *user-provided* CPT columns; accepted
/// columns are re-normalized exactly, so downstream row sums are tight.
pub const CPT_TOL: f64 = 1e-8;

/// Largest flat state count the structured solver will flatten results
/// for (and the conformance suite will enumerate). The factored
/// *description* itself has no such limit — `compile_to_mdpb` streams.
pub const MAX_ENUMERABLE_STATES: usize = 1 << 22;

/// One discrete state variable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VarSpec {
    /// Human-readable name (diagnostics only).
    pub name: String,
    /// Domain size (values are `0..domain`).
    pub domain: usize,
}

impl VarSpec {
    /// Convenience constructor.
    pub fn new(name: &str, domain: usize) -> VarSpec {
        VarSpec {
            name: name.to_string(),
            domain,
        }
    }
}

/// Conditional probability table for one variable: the distribution of
/// `x_var'` given the current values of the `scope` variables and the
/// action.
///
/// `rows` is indexed `((a * scope_card) + u) * domain(var) + x'`, where
/// `u` is the mixed-radix index of the scope assignment (`scope[0]` most
/// significant) and `scope_card = Π domain(scope[j])`. Its length must be
/// exactly `n_actions · scope_card · domain(var)`.
#[derive(Clone, Debug, PartialEq)]
pub struct Cpt {
    /// The variable whose next value this table distributes.
    pub var: usize,
    /// Current-state parent variables (may include `var` itself).
    pub scope: Vec<usize>,
    /// Flattened distributions, one per `(action, scope assignment)`.
    pub rows: Vec<f64>,
}

/// One additive stage-cost term over a (small) scope of variables.
///
/// `values` is indexed `a * scope_card + u` with the same mixed-radix
/// scope index as [`Cpt`]; its length must be `n_actions · scope_card`.
/// An empty scope is allowed (a pure per-action cost).
#[derive(Clone, Debug, PartialEq)]
pub struct CostTerm {
    /// Variables this term reads.
    pub scope: Vec<usize>,
    /// Flattened cost values, one per `(action, scope assignment)`.
    pub values: Vec<f64>,
}

/// Typed validation errors surfaced by [`FactoredMdp::new`] and the
/// structured solver.
#[derive(Clone, Debug, PartialEq)]
pub enum FactoredError {
    /// The model has no state variables.
    NoVariables,
    /// The model has no actions.
    NoActions,
    /// A variable has an empty domain.
    EmptyDomain {
        /// Offending variable index.
        var: usize,
    },
    /// Not exactly one CPT per variable.
    CptCount {
        /// Expected count (= number of variables).
        expected: usize,
        /// Provided count.
        got: usize,
    },
    /// `cpts[index].var != index` — CPTs must be listed in variable order.
    CptVar {
        /// Position in the CPT list.
        index: usize,
        /// The `var` field found there.
        var: usize,
    },
    /// A scope mentions a variable that does not exist.
    ScopeVarOutOfRange {
        /// `"cpt"` or `"cost term"`.
        what: &'static str,
        /// Index of the offending table.
        index: usize,
        /// The out-of-range variable.
        var: usize,
        /// Number of declared variables.
        n_vars: usize,
    },
    /// A scope mentions the same variable twice.
    DuplicateScopeVar {
        /// `"cpt"` or `"cost term"`.
        what: &'static str,
        /// Index of the offending table.
        index: usize,
        /// The duplicated variable.
        var: usize,
    },
    /// A table's flat length disagrees with its scope/action shape.
    TableLen {
        /// `"cpt"` or `"cost term"`.
        what: &'static str,
        /// Index of the offending table.
        index: usize,
        /// Required length.
        expected: usize,
        /// Provided length.
        got: usize,
    },
    /// A CPT entry is negative, above one, or non-finite.
    BadProbability {
        /// Variable whose CPT is malformed.
        var: usize,
        /// Action index of the column.
        action: usize,
        /// Mixed-radix scope assignment index of the column.
        parent: usize,
        /// The offending entry.
        p: f64,
    },
    /// A CPT column does not sum to one within [`CPT_TOL`].
    BadDistributionSum {
        /// Variable whose CPT is malformed.
        var: usize,
        /// Action index of the column.
        action: usize,
        /// Mixed-radix scope assignment index of the column.
        parent: usize,
        /// The actual column sum.
        sum: f64,
    },
    /// A cost entry is non-finite.
    NonFiniteCost {
        /// Index of the offending cost term.
        term: usize,
        /// Action index of the entry.
        action: usize,
        /// Mixed-radix scope assignment index of the entry.
        assignment: usize,
    },
    /// The flat state space does not fit in a `usize`.
    StateSpaceOverflow {
        /// The (truncated) product of domain sizes.
        n_states: u128,
    },
    /// The flat state space exceeds [`MAX_ENUMERABLE_STATES`], so results
    /// cannot be flattened (the streaming compile path still works).
    TooLargeToEnumerate {
        /// The flat state count.
        n_states: usize,
        /// The enumeration cap.
        limit: usize,
    },
    /// The discount factor is outside `[0, 1)`.
    BadGamma {
        /// The offending value.
        gamma: f64,
    },
}

impl std::fmt::Display for FactoredError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FactoredError::NoVariables => write!(f, "factored model has no state variables"),
            FactoredError::NoActions => write!(f, "factored model has no actions"),
            FactoredError::EmptyDomain { var } => {
                write!(f, "variable {var} has an empty domain")
            }
            FactoredError::CptCount { expected, got } => write!(
                f,
                "expected exactly one CPT per variable ({expected}), got {got}"
            ),
            FactoredError::CptVar { index, var } => write!(
                f,
                "CPTs must be listed in variable order: cpts[{index}].var is {var}"
            ),
            FactoredError::ScopeVarOutOfRange {
                what,
                index,
                var,
                n_vars,
            } => write!(
                f,
                "{what} {index}: scope variable {var} is out of range (model has {n_vars} variables)"
            ),
            FactoredError::DuplicateScopeVar { what, index, var } => {
                write!(f, "{what} {index}: scope lists variable {var} twice")
            }
            FactoredError::TableLen {
                what,
                index,
                expected,
                got,
            } => write!(
                f,
                "{what} {index}: table has {got} entries, its action x scope shape requires {expected}"
            ),
            FactoredError::BadProbability {
                var,
                action,
                parent,
                p,
            } => write!(
                f,
                "CPT of variable {var}: probability {p} at (action {action}, scope assignment {parent}) is not in [0, 1]"
            ),
            FactoredError::BadDistributionSum {
                var,
                action,
                parent,
                sum,
            } => write!(
                f,
                "CPT of variable {var}: column (action {action}, scope assignment {parent}) sums to {sum}, not 1 (tolerance {CPT_TOL:e})"
            ),
            FactoredError::NonFiniteCost {
                term,
                action,
                assignment,
            } => write!(
                f,
                "cost term {term}: non-finite value at (action {action}, scope assignment {assignment})"
            ),
            FactoredError::StateSpaceOverflow { n_states } => write!(
                f,
                "flat state space (~{n_states} states) overflows the address space"
            ),
            FactoredError::TooLargeToEnumerate { n_states, limit } => write!(
                f,
                "flat state space has {n_states} states, above the {limit}-state enumeration cap; use the streaming compile path"
            ),
            FactoredError::BadGamma { gamma } => {
                write!(f, "discount factor {gamma} is outside [0, 1)")
            }
        }
    }
}

impl std::error::Error for FactoredError {}

/// A validated factored MDP (see the module docs for the semantics).
#[derive(Clone, Debug)]
pub struct FactoredMdp {
    vars: Vec<VarSpec>,
    n_actions: usize,
    cpts: Vec<Cpt>,
    costs: Vec<CostTerm>,
    /// Mixed-radix strides of the flat encoding (`strides[0]` largest).
    strides: Vec<usize>,
    n_states: usize,
}

impl FactoredMdp {
    /// Validate and build. CPTs must be listed in variable order (one per
    /// variable); every CPT column is checked against [`CPT_TOL`] and then
    /// exactly normalized.
    pub fn new(
        vars: Vec<VarSpec>,
        n_actions: usize,
        mut cpts: Vec<Cpt>,
        costs: Vec<CostTerm>,
    ) -> Result<FactoredMdp, FactoredError> {
        if vars.is_empty() {
            return Err(FactoredError::NoVariables);
        }
        if n_actions == 0 {
            return Err(FactoredError::NoActions);
        }
        for (i, v) in vars.iter().enumerate() {
            if v.domain == 0 {
                return Err(FactoredError::EmptyDomain { var: i });
            }
        }
        let mut product: u128 = 1;
        for v in &vars {
            product = product.saturating_mul(v.domain as u128);
        }
        if product > (usize::MAX / 2) as u128 {
            return Err(FactoredError::StateSpaceOverflow { n_states: product });
        }
        let n_states = product as usize;
        let mut strides = vec![1usize; vars.len()];
        for i in (0..vars.len().saturating_sub(1)).rev() {
            strides[i] = strides[i + 1] * vars[i + 1].domain;
        }

        if cpts.len() != vars.len() {
            return Err(FactoredError::CptCount {
                expected: vars.len(),
                got: cpts.len(),
            });
        }
        let check_scope =
            |what: &'static str, index: usize, scope: &[usize]| -> Result<usize, FactoredError> {
                let mut card = 1usize;
                for (j, &v) in scope.iter().enumerate() {
                    if v >= vars.len() {
                        return Err(FactoredError::ScopeVarOutOfRange {
                            what,
                            index,
                            var: v,
                            n_vars: vars.len(),
                        });
                    }
                    if scope[..j].contains(&v) {
                        return Err(FactoredError::DuplicateScopeVar { what, index, var: v });
                    }
                    card = card.saturating_mul(vars[v].domain);
                }
                Ok(card)
            };

        for (i, cpt) in cpts.iter_mut().enumerate() {
            if cpt.var != i {
                return Err(FactoredError::CptVar {
                    index: i,
                    var: cpt.var,
                });
            }
            let card = check_scope("cpt", i, &cpt.scope)?;
            let dom = vars[i].domain;
            let expected = n_actions * card * dom;
            if cpt.rows.len() != expected {
                return Err(FactoredError::TableLen {
                    what: "cpt",
                    index: i,
                    expected,
                    got: cpt.rows.len(),
                });
            }
            // validate + exactly normalize every (action, parent) column
            for a in 0..n_actions {
                for u in 0..card {
                    let off = (a * card + u) * dom;
                    let col = &mut cpt.rows[off..off + dom];
                    let mut sum = 0.0;
                    for p in col.iter() {
                        if !p.is_finite() || *p < -1e-12 || *p > 1.0 + CPT_TOL {
                            return Err(FactoredError::BadProbability {
                                var: i,
                                action: a,
                                parent: u,
                                p: *p,
                            });
                        }
                        sum += p.max(0.0);
                    }
                    if (sum - 1.0).abs() > CPT_TOL {
                        return Err(FactoredError::BadDistributionSum {
                            var: i,
                            action: a,
                            parent: u,
                            sum,
                        });
                    }
                    for p in col.iter_mut() {
                        *p = p.max(0.0) / sum;
                    }
                }
            }
        }

        for (j, term) in costs.iter().enumerate() {
            let card = check_scope("cost term", j, &term.scope)?;
            let expected = n_actions * card;
            if term.values.len() != expected {
                return Err(FactoredError::TableLen {
                    what: "cost term",
                    index: j,
                    expected,
                    got: term.values.len(),
                });
            }
            for a in 0..n_actions {
                for u in 0..card {
                    if !term.values[a * card + u].is_finite() {
                        return Err(FactoredError::NonFiniteCost {
                            term: j,
                            action: a,
                            assignment: u,
                        });
                    }
                }
            }
        }

        Ok(FactoredMdp {
            vars,
            n_actions,
            cpts,
            costs,
            strides,
            n_states,
        })
    }

    /// Number of state variables.
    pub fn n_vars(&self) -> usize {
        self.vars.len()
    }

    /// Flat state count (product of domains).
    pub fn n_states(&self) -> usize {
        self.n_states
    }

    /// Action count.
    pub fn n_actions(&self) -> usize {
        self.n_actions
    }

    /// The variable declarations.
    pub fn vars(&self) -> &[VarSpec] {
        &self.vars
    }

    /// The per-variable CPTs (normalized).
    pub fn cpts(&self) -> &[Cpt] {
        &self.cpts
    }

    /// The additive cost terms.
    pub fn cost_terms(&self) -> &[CostTerm] {
        &self.costs
    }

    /// Flat index of a full assignment (variable 0 most significant).
    pub fn encode(&self, assignment: &[usize]) -> usize {
        debug_assert_eq!(assignment.len(), self.vars.len());
        assignment
            .iter()
            .zip(&self.strides)
            .map(|(&x, &st)| x * st)
            .sum()
    }

    /// Inverse of [`Self::encode`]: fills `out` with the tuple of `s`.
    pub fn decode(&self, s: usize, out: &mut Vec<usize>) {
        debug_assert!(s < self.n_states);
        out.clear();
        let mut rem = s;
        for &st in &self.strides {
            out.push(rem / st);
            rem %= st;
        }
    }

    /// Cardinality of a scope's joint assignment space.
    fn scope_card(&self, scope: &[usize]) -> usize {
        scope.iter().map(|&v| self.vars[v].domain).product()
    }

    /// Mixed-radix index of `assignment`'s restriction to `scope`
    /// (`scope[0]` most significant).
    pub fn scope_index(&self, scope: &[usize], assignment: &[usize]) -> usize {
        let mut u = 0usize;
        for &v in scope {
            u = u * self.vars[v].domain + assignment[v];
        }
        u
    }

    /// The normalized CPT column of `var` under `(action, parent index)`.
    pub fn dist(&self, var: usize, action: usize, parent: usize) -> &[f64] {
        let cpt = &self.cpts[var];
        let card = self.scope_card(&cpt.scope);
        let dom = self.vars[var].domain;
        let off = (action * card + parent) * dom;
        &cpt.rows[off..off + dom]
    }

    /// The flat sparse successor row of `(s, a)`: the cartesian product of
    /// the per-variable CPT columns, zero-probability branches pruned,
    /// columns emitted in ascending order. O(row nnz · n_vars).
    pub fn flat_prob_row(&self, s: usize, a: usize) -> Vec<(usize, f64)> {
        let mut asg = Vec::with_capacity(self.vars.len());
        self.decode(s, &mut asg);
        let dists: Vec<&[f64]> = (0..self.vars.len())
            .map(|i| self.dist(i, a, self.scope_index(&self.cpts[i].scope, &asg)))
            .collect();
        let mut out = Vec::new();
        self.product_rec(&dists, 0, 0, 1.0, &mut out);
        out
    }

    fn product_rec(
        &self,
        dists: &[&[f64]],
        depth: usize,
        idx: usize,
        p: f64,
        out: &mut Vec<(usize, f64)>,
    ) {
        if depth == dists.len() {
            out.push((idx, p));
            return;
        }
        for (x, &px) in dists[depth].iter().enumerate() {
            if px > 0.0 {
                self.product_rec(dists, depth + 1, idx + x * self.strides[depth], p * px, out);
            }
        }
    }

    /// The flat stage cost of `(s, a)`: sum of the local cost terms.
    pub fn flat_cost(&self, s: usize, a: usize) -> f64 {
        let mut asg = Vec::with_capacity(self.vars.len());
        self.decode(s, &mut asg);
        self.costs
            .iter()
            .map(|t| {
                let card = self.scope_card(&t.scope);
                t.values[a * card + self.scope_index(&t.scope, &asg)]
            })
            .sum()
    }

    /// Total nonzeros of the flat transition kernel (the denominator of
    /// the compression ratio): `Σ_{s,a} Π_i |support_i(s, a)|`, computed
    /// without materializing any row. O(n_states · n_actions · n_vars) —
    /// intended for enumerable instances only.
    pub fn flat_nnz(&self) -> u128 {
        let mut asg = Vec::with_capacity(self.vars.len());
        let mut total: u128 = 0;
        for s in 0..self.n_states {
            self.decode(s, &mut asg);
            for a in 0..self.n_actions {
                let mut row: u128 = 1;
                for i in 0..self.vars.len() {
                    let support = self
                        .dist(i, a, self.scope_index(&self.cpts[i].scope, &asg))
                        .iter()
                        .filter(|&&p| p > 0.0)
                        .count();
                    row *= support as u128;
                }
                total += row;
            }
        }
        total
    }
}

/// A factored MDP *is* a model generator: its flat row/cost closures feed
/// the existing serial/distributed builders and the streaming `.mdpb`
/// writer unchanged — this is the compile path.
impl ModelGenerator for FactoredMdp {
    fn n_states(&self) -> usize {
        self.n_states
    }

    fn n_actions(&self) -> usize {
        self.n_actions
    }

    fn prob_row(&self, s: usize, a: usize) -> Vec<(usize, f64)> {
        self.flat_prob_row(s, a)
    }

    fn cost(&self, s: usize, a: usize) -> f64 {
        self.flat_cost(s, a)
    }

    fn factored(&self) -> Option<&FactoredMdp> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two binary variables: x1' copies x0, x0' flips with prob 0.25.
    fn two_var() -> FactoredMdp {
        FactoredMdp::new(
            vec![VarSpec::new("x0", 2), VarSpec::new("x1", 2)],
            1,
            vec![
                Cpt {
                    var: 0,
                    scope: vec![0],
                    rows: vec![0.75, 0.25, 0.25, 0.75],
                },
                Cpt {
                    var: 1,
                    scope: vec![0],
                    rows: vec![1.0, 0.0, 0.0, 1.0],
                },
            ],
            vec![CostTerm {
                scope: vec![1],
                values: vec![0.0, 2.0],
            }],
        )
        .unwrap()
    }

    #[test]
    fn encode_decode_roundtrip() {
        let m = two_var();
        let mut asg = Vec::new();
        for s in 0..m.n_states() {
            m.decode(s, &mut asg);
            assert_eq!(m.encode(&asg), s);
        }
        // var 0 is most significant
        assert_eq!(m.encode(&[1, 0]), 2);
    }

    #[test]
    fn flat_rows_are_sorted_stochastic_products() {
        let m = two_var();
        for s in 0..4 {
            let row = m.flat_prob_row(s, 0);
            assert!(row.windows(2).all(|w| w[0].0 < w[1].0), "unsorted at {s}");
            let sum: f64 = row.iter().map(|&(_, p)| p).sum();
            assert!((sum - 1.0).abs() < 1e-12);
        }
        // from s=0 (x0=0, x1=0): x1'=x0=0, x0' flips w.p. 0.25
        assert_eq!(m.flat_prob_row(0, 0), vec![(0, 0.75), (2, 0.25)]);
    }

    #[test]
    fn flat_cost_sums_terms() {
        let m = two_var();
        assert_eq!(m.flat_cost(0, 0), 0.0); // x1 = 0
        assert_eq!(m.flat_cost(1, 0), 2.0); // x1 = 1
    }

    #[test]
    fn columns_are_exactly_normalized() {
        // a column off by just under the tolerance is accepted and fixed
        let m = FactoredMdp::new(
            vec![VarSpec::new("x", 2)],
            1,
            vec![Cpt {
                var: 0,
                scope: vec![],
                rows: vec![0.5 + 4e-9, 0.5],
            }],
            vec![],
        )
        .unwrap();
        let d = m.dist(0, 0, 0);
        assert!(((d[0] + d[1]) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn typed_errors() {
        let v = vec![VarSpec::new("x", 2)];
        let ok_cpt = Cpt {
            var: 0,
            scope: vec![],
            rows: vec![0.5, 0.5],
        };
        assert_eq!(
            FactoredMdp::new(vec![], 1, vec![], vec![]).unwrap_err(),
            FactoredError::NoVariables
        );
        assert_eq!(
            FactoredMdp::new(v.clone(), 0, vec![ok_cpt.clone()], vec![]).unwrap_err(),
            FactoredError::NoActions
        );
        assert_eq!(
            FactoredMdp::new(v.clone(), 1, vec![], vec![]).unwrap_err(),
            FactoredError::CptCount {
                expected: 1,
                got: 0
            }
        );
        let bad_scope = Cpt {
            var: 0,
            scope: vec![3],
            rows: vec![0.5, 0.5],
        };
        assert!(matches!(
            FactoredMdp::new(v.clone(), 1, vec![bad_scope], vec![]).unwrap_err(),
            FactoredError::ScopeVarOutOfRange { var: 3, .. }
        ));
        let sub_stochastic = Cpt {
            var: 0,
            scope: vec![],
            rows: vec![0.5, 0.4],
        };
        assert!(matches!(
            FactoredMdp::new(v.clone(), 1, vec![sub_stochastic], vec![]).unwrap_err(),
            FactoredError::BadDistributionSum { .. }
        ));
        let bad_cost = CostTerm {
            scope: vec![],
            values: vec![f64::NAN],
        };
        assert!(matches!(
            FactoredMdp::new(v, 1, vec![ok_cpt], vec![bad_cost]).unwrap_err(),
            FactoredError::NonFiniteCost { .. }
        ));
    }

    #[test]
    fn generator_contract_holds() {
        crate::models::check_generator(&two_var());
    }
}
