//! SPUDD-style structured value iteration over ADDs (DESIGN.md §17).
//!
//! The Bellman backup is computed symbolically, never touching the flat
//! state space: the value function, the per-variable transition CPTs and
//! the additive cost terms all live as ADDs in one hash-consed store, and
//! one backup is a sequence of `apply`/`marginalize` operations:
//!
//! ```text
//! W   := V[x → x']                      (relabel current → primed levels)
//! for each variable i, innermost first:
//!     W := Σ_{x_i'} P_i(x_i' | scope_i, a) · W        (apply-Mul, marginalize)
//! Q_a := C_a + γ · W
//! V'  := min_a Q_a   (or max_a, per objective)
//! ```
//!
//! Level layout: the elimination ordering assigns each variable a
//! position `p`; its current-state level is `2p` and its primed
//! (next-state) level `2p+1`. Interleaving keeps each CPT's parents and
//! its primed child close in the order, which is what lets `apply` stay
//! polynomial in diagram size on structured models.
//!
//! The greedy policy is itself extracted as an ADD, with the exact
//! tie-break of the flat solver (lowest action index wins, strict
//! improvement replaces): action 0 seeds the running best, and action `a`
//! overwrites only where `Q_a` is *strictly* better. The conformance
//! suite (`tests/factored.rs`) pins structured results against
//! compile-then-flat-solve to 1e-9 values and identical policies.

use super::add::{AddStore, NodeId, Op};
use super::spec::{FactoredError, FactoredMdp, MAX_ENUMERABLE_STATES};
use crate::mdp::Objective;

/// Variable elimination order for the structured solver
/// (`-factored_order`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum FactoredOrder {
    /// Declaration order (the default).
    #[default]
    Given,
    /// Reversed declaration order.
    Reverse,
    /// Cheap heuristic: variables sorted by CPT scope size ascending
    /// (ties by index) — small-scope variables eliminate first.
    Auto,
}

impl FactoredOrder {
    /// Stable name (options layer / diagnostics).
    pub fn name(&self) -> &'static str {
        match self {
            FactoredOrder::Given => "given",
            FactoredOrder::Reverse => "reverse",
            FactoredOrder::Auto => "auto",
        }
    }
}

/// Options for [`solve_svi`].
#[derive(Clone, Debug)]
pub struct SviOptions {
    /// Stop when `‖V_{k+1} − V_k‖∞ < atol`.
    pub atol: f64,
    /// Iteration cap.
    pub max_iter: usize,
    /// Variable elimination order.
    pub order: FactoredOrder,
}

impl Default for SviOptions {
    fn default() -> Self {
        SviOptions {
            atol: 1e-8,
            max_iter: 10_000,
            order: FactoredOrder::Given,
        }
    }
}

/// Result of a structured solve, flattened for consumption by the same
/// pipelines as the flat solver (plus ADD size diagnostics).
#[derive(Clone, Debug)]
pub struct SviResult {
    /// Value vector over the enumerated flat state space.
    pub value: Vec<f64>,
    /// Greedy policy over the flat state space (flat-solver tie-break).
    pub policy: Vec<usize>,
    /// Backups executed.
    pub iterations: usize,
    /// Final `‖V_{k+1} − V_k‖∞`.
    pub residual: f64,
    /// Whether the residual dropped below `atol`.
    pub converged: bool,
    /// Per-iteration residuals (`trace[k]` is the residual of backup k+1).
    pub residual_trace: Vec<f64>,
    /// Reachable node count of the final value ADD.
    pub value_nodes: usize,
    /// Reachable node count of the policy ADD.
    pub policy_nodes: usize,
    /// Reachable node count over all per-action per-variable CPT ADDs —
    /// the numerator of the compression ratio vs. the flat kernel nnz.
    pub transition_nodes: usize,
    /// The variable elimination ordering actually used.
    pub ordering: Vec<usize>,
}

/// Compaction threshold: hash-consing never frees, so once the store
/// grows past this many physical nodes the live roots are migrated into a
/// fresh store. Keeps thousand-iteration runs in bounded memory.
const COMPACT_THRESHOLD: usize = 1 << 20;

/// Structured value iteration on a factored MDP. Runs serially (the ADD
/// store is a single shared arena); the compile path covers every
/// distributed configuration. Results are flattened over the enumerable
/// state space, which caps `n_states` at [`MAX_ENUMERABLE_STATES`].
pub fn solve_svi(
    fmdp: &FactoredMdp,
    gamma: f64,
    objective: Objective,
    opts: &SviOptions,
) -> Result<SviResult, FactoredError> {
    if !(0.0..1.0).contains(&gamma) {
        return Err(FactoredError::BadGamma { gamma });
    }
    if fmdp.n_states() > MAX_ENUMERABLE_STATES {
        return Err(FactoredError::TooLargeToEnumerate {
            n_states: fmdp.n_states(),
            limit: MAX_ENUMERABLE_STATES,
        });
    }
    let n = fmdp.n_vars();
    let m = fmdp.n_actions();

    // --- ordering and level layout -------------------------------------
    let ordering: Vec<usize> = match opts.order {
        FactoredOrder::Given => (0..n).collect(),
        FactoredOrder::Reverse => (0..n).rev().collect(),
        FactoredOrder::Auto => {
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by_key(|&i| (fmdp.cpts()[i].scope.len(), i));
            idx
        }
    };
    let mut pos = vec![0usize; n]; // variable -> position in the ordering
    for (p, &i) in ordering.iter().enumerate() {
        pos[i] = p;
    }
    let mut domains = vec![0usize; 2 * n];
    for (p, &i) in ordering.iter().enumerate() {
        domains[2 * p] = fmdp.vars()[i].domain;
        domains[2 * p + 1] = fmdp.vars()[i].domain;
    }
    let mut store = AddStore::new(domains);

    // current → primed relabel map (identity on primed levels, which a
    // value ADD never tests)
    let prime_map: Vec<u32> = (0..2 * n)
        .map(|l| if l % 2 == 0 { l as u32 + 1 } else { l as u32 })
        .collect();

    // --- model ADDs -----------------------------------------------------
    // trans[a][p]: P(x_i' | scope_i, a) for i = ordering[p], over the
    // parents' current levels plus the child's primed level
    let build_model = |store: &mut AddStore| -> (Vec<Vec<NodeId>>, Vec<NodeId>) {
        let mut trans = Vec::with_capacity(m);
        let mut costs = Vec::with_capacity(m);
        for a in 0..m {
            let mut per_var = Vec::with_capacity(n);
            for &i in &ordering {
                let cpt = &fmdp.cpts()[i];
                let primed = 2 * pos[i] + 1;
                let mut levels: Vec<usize> =
                    cpt.scope.iter().map(|&v| 2 * pos[v]).collect();
                levels.push(primed);
                levels.sort_unstable();
                // map each sorted level back to what it encodes
                let root = store.build_over(&levels, &mut |asg| {
                    let mut scope_asg = vec![0usize; cpt.scope.len()];
                    let mut xprime = 0usize;
                    for (k, &l) in levels.iter().enumerate() {
                        if l == primed {
                            xprime = asg[k];
                        } else {
                            let var = ordering[l / 2];
                            let j = cpt.scope.iter().position(|&v| v == var).unwrap();
                            scope_asg[j] = asg[k];
                        }
                    }
                    let mut u = 0usize;
                    for (j, &v) in cpt.scope.iter().enumerate() {
                        u = u * fmdp.vars()[v].domain + scope_asg[j];
                    }
                    fmdp.dist(i, a, u)[xprime]
                });
                per_var.push(root);
            }
            trans.push(per_var);

            let mut c_a = store.terminal(0.0);
            for term in fmdp.cost_terms() {
                let levels: Vec<usize> = {
                    let mut ls: Vec<usize> = term.scope.iter().map(|&v| 2 * pos[v]).collect();
                    ls.sort_unstable();
                    ls
                };
                let t = store.build_over(&levels, &mut |asg| {
                    // recover the scope assignment from the sorted levels
                    let mut u = 0usize;
                    for &v in &term.scope {
                        let l = 2 * pos[v];
                        let k = levels.iter().position(|&x| x == l).unwrap();
                        u = u * fmdp.vars()[v].domain + asg[k];
                    }
                    let card: usize = term
                        .scope
                        .iter()
                        .map(|&v| fmdp.vars()[v].domain)
                        .product();
                    term.values[a * card + u]
                });
                c_a = store.apply(c_a, t, Op::Add);
            }
            costs.push(c_a);
        }
        (trans, costs)
    };
    let (mut trans, mut costs) = build_model(&mut store);
    let trans_roots: Vec<NodeId> = trans.iter().flatten().copied().collect();
    let transition_nodes = store.reachable(&trans_roots);

    // --- value iteration ------------------------------------------------
    let better_op = match objective {
        Objective::Min => Op::Lt,
        Objective::Max => Op::Gt,
    };
    let pick_op = match objective {
        Objective::Min => Op::Min,
        Objective::Max => Op::Max,
    };
    let mut v = store.terminal(0.0);
    let mut pol = store.terminal(0.0);
    let mut residual = f64::INFINITY;
    let mut converged = false;
    let mut iterations = 0usize;
    let mut residual_trace = Vec::new();

    for _ in 0..opts.max_iter {
        let gamma_t = store.terminal(gamma);
        let one = store.terminal(1.0);
        let w_base = store.relabel(v, &prime_map);
        let mut best: Option<NodeId> = None;
        let mut new_pol = store.terminal(0.0);
        for (a, per_var) in trans.iter().enumerate() {
            let mut w = w_base;
            for p in (0..n).rev() {
                w = store.apply(per_var[p], w, Op::Mul);
                w = store.marginalize(w, 2 * p + 1);
            }
            let disc = store.apply(gamma_t, w, Op::Mul);
            let q_a = store.apply(costs[a], disc, Op::Add);
            match best {
                None => best = Some(q_a),
                Some(b) => {
                    // strict improvement only — flat tie-break (lowest a)
                    let strictly = store.apply(q_a, b, better_op);
                    let keep = store.apply(one, strictly, Op::Sub);
                    let a_t = store.terminal(a as f64);
                    let take = store.apply(strictly, a_t, Op::Mul);
                    let hold = store.apply(keep, new_pol, Op::Mul);
                    new_pol = store.apply(take, hold, Op::Add);
                    best = Some(store.apply(b, q_a, pick_op));
                }
            }
        }
        let v_new = best.expect("n_actions >= 1");
        let diff = store.apply(v_new, v, Op::Sub);
        residual = store.sup_abs(diff);
        v = v_new;
        pol = new_pol;
        iterations += 1;
        residual_trace.push(residual);
        if residual < opts.atol {
            converged = true;
            break;
        }
        if store.len() > COMPACT_THRESHOLD {
            // keep only the model ADDs and the live iterate
            let mut roots: Vec<NodeId> = trans.iter().flatten().copied().collect();
            roots.extend(costs.iter().copied());
            roots.push(v);
            roots.push(pol);
            let (fresh, new_roots) = store.compact(&roots);
            store = fresh;
            let mut it = new_roots.into_iter();
            for per_var in trans.iter_mut() {
                for t in per_var.iter_mut() {
                    *t = it.next().unwrap();
                }
            }
            for c in costs.iter_mut() {
                *c = it.next().unwrap();
            }
            v = it.next().unwrap();
            pol = it.next().unwrap();
        }
    }

    // --- flatten over the enumerable state space ------------------------
    let n_states = fmdp.n_states();
    let mut value = Vec::with_capacity(n_states);
    let mut policy = Vec::with_capacity(n_states);
    let mut asg = Vec::with_capacity(n);
    let mut levels = vec![0usize; 2 * n];
    for s in 0..n_states {
        fmdp.decode(s, &mut asg);
        for (i, &x) in asg.iter().enumerate() {
            levels[2 * pos[i]] = x;
        }
        value.push(store.eval(v, &levels));
        let a = store.eval(pol, &levels);
        policy.push((a.round() as usize).min(m - 1));
    }

    Ok(SviResult {
        value,
        policy,
        iterations,
        residual,
        converged,
        residual_trace,
        value_nodes: store.reachable(&[v]),
        policy_nodes: store.reachable(&[pol]),
        transition_nodes,
        ordering,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factored::spec::{CostTerm, Cpt, VarSpec};
    use crate::models::ModelGenerator;
    use crate::solver::{solve_serial, Method, SolveOptions};

    /// 2-variable, 2-action factored MDP with asymmetric costs.
    fn toy() -> FactoredMdp {
        FactoredMdp::new(
            vec![VarSpec::new("x0", 2), VarSpec::new("x1", 2)],
            2,
            vec![
                Cpt {
                    var: 0,
                    scope: vec![0],
                    // a=0: sticky; a=1: pushed toward 0
                    rows: vec![
                        0.9, 0.1, 0.2, 0.8, // a=0: x0=0 -> [.9 .1], x0=1 -> [.2 .8]
                        0.95, 0.05, 0.7, 0.3, // a=1
                    ],
                },
                Cpt {
                    var: 1,
                    scope: vec![0, 1],
                    rows: vec![
                        // a=0, (x0,x1) in lex order
                        0.8, 0.2, 0.6, 0.4, 0.5, 0.5, 0.1, 0.9,
                        // a=1
                        0.85, 0.15, 0.7, 0.3, 0.55, 0.45, 0.2, 0.8,
                    ],
                },
            ],
            vec![
                CostTerm {
                    scope: vec![0],
                    values: vec![0.0, 1.0, 0.3, 1.3],
                },
                CostTerm {
                    scope: vec![1],
                    values: vec![0.0, 0.7, 0.0, 0.7],
                },
            ],
        )
        .unwrap()
    }

    #[test]
    fn svi_matches_flat_vi_on_toy() {
        let f = toy();
        let svi = solve_svi(
            &f,
            0.9,
            Objective::Min,
            &SviOptions {
                atol: 1e-12,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(svi.converged);
        let mdp = f.try_build_serial(0.9).unwrap();
        let flat = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::Vi,
                atol: 1e-12,
                max_outer: 100_000,
                ..Default::default()
            },
        );
        assert!(flat.converged);
        for s in 0..f.n_states() {
            assert!(
                (svi.value[s] - flat.value[s]).abs() < 1e-9,
                "value mismatch at {s}: {} vs {}",
                svi.value[s],
                flat.value[s]
            );
        }
        assert_eq!(svi.policy, flat.policy);
    }

    #[test]
    fn orderings_agree() {
        let f = toy();
        let base = solve_svi(&f, 0.9, Objective::Min, &SviOptions::default()).unwrap();
        for order in [FactoredOrder::Reverse, FactoredOrder::Auto] {
            let r = solve_svi(
                &f,
                0.9,
                Objective::Min,
                &SviOptions {
                    order,
                    ..Default::default()
                },
            )
            .unwrap();
            for s in 0..f.n_states() {
                assert!((r.value[s] - base.value[s]).abs() < 1e-9);
            }
            assert_eq!(r.policy, base.policy);
        }
    }

    #[test]
    fn max_objective_flips_the_sense() {
        let f = toy();
        let min = solve_svi(&f, 0.9, Objective::Min, &SviOptions::default()).unwrap();
        let max = solve_svi(&f, 0.9, Objective::Max, &SviOptions::default()).unwrap();
        assert!(max.value[3] >= min.value[3]);
        let mdp = f
            .try_build_serial(0.9)
            .unwrap()
            .with_objective(Objective::Max);
        let flat = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::Vi,
                atol: 1e-8,
                max_outer: 100_000,
                ..Default::default()
            },
        );
        for s in 0..f.n_states() {
            assert!((max.value[s] - flat.value[s]).abs() < 1e-6);
        }
        assert_eq!(max.policy, flat.policy);
    }

    #[test]
    fn bad_gamma_is_typed() {
        let f = toy();
        assert_eq!(
            solve_svi(&f, 1.0, Objective::Min, &SviOptions::default()).unwrap_err(),
            FactoredError::BadGamma { gamma: 1.0 }
        );
    }
}
