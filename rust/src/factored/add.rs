//! Hash-consed algebraic decision diagrams (ADDs) — DESIGN.md §17.
//!
//! An ADD is an ordered, reduced decision diagram whose terminals carry
//! `f64` values instead of booleans (a *multi-terminal* BDD, generalized
//! here to multi-valued variables: a node at level `l` has one child per
//! element of `domains[l]`). Two invariants make every function's
//! representation canonical:
//!
//! - **ordering**: on every root-to-terminal path, node levels strictly
//!   increase — a variable is tested at most once and always in the same
//!   global position;
//! - **reduction**: a node whose children are all identical is never
//!   materialized (the shared child stands in for it), and structurally
//!   equal nodes are *hash-consed* into one physical node.
//!
//! Canonicity is what turns structural sharing into compression: the CPTs
//! of a factored MDP and every Bellman iterate live in one [`AddStore`]
//! and automatically share equal subfunctions. It is also what the
//! property tests pin: building the same function along two different
//! construction orders must yield the *same* [`NodeId`].
//!
//! All operations ([`AddStore::apply`], [`AddStore::restrict`],
//! [`AddStore::marginalize`], [`AddStore::relabel`]) are memoized per
//! call, so their cost is O(product of operand diagram sizes), never the
//! size of the exponential flat table they represent.

use std::collections::HashMap;

/// Sentinel level for terminal nodes: deeper than every variable level,
/// so `min(level(f), level(g))` in `apply` naturally picks the variable
/// node when one operand is a terminal.
const TERMINAL_LEVEL: u32 = u32::MAX;

/// Handle to a node inside an [`AddStore`]. Because nodes are
/// hash-consed, `NodeId` equality *is* function equality (for nodes of
/// the same store): structurally equal diagrams get pointer-equal ids.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(u32);

/// Pointwise binary operator for [`AddStore::apply`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// `f + g`
    Add,
    /// `f - g`
    Sub,
    /// `f * g`
    Mul,
    /// `min(f, g)`
    Min,
    /// `max(f, g)`
    Max,
    /// Strict comparison indicator: `1.0` where `f < g`, else `0.0`.
    Lt,
    /// Strict comparison indicator: `1.0` where `f > g`, else `0.0`.
    Gt,
}

impl Op {
    fn eval(self, a: f64, b: f64) -> f64 {
        match self {
            Op::Add => a + b,
            Op::Sub => a - b,
            Op::Mul => a * b,
            Op::Min => a.min(b),
            Op::Max => a.max(b),
            Op::Lt => {
                if a < b {
                    1.0
                } else {
                    0.0
                }
            }
            Op::Gt => {
                if a > b {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }
}

#[derive(Clone, Debug)]
enum NodeData {
    Terminal(f64),
    Internal { level: u32, children: Vec<NodeId> },
}

/// Arena of hash-consed ADD nodes over a fixed level layout.
///
/// `domains[l]` is the arity (number of children) of nodes at level `l`.
/// Nodes are append-only; long-running iterations bound their footprint
/// with [`AddStore::compact`], which rebuilds a fresh store containing
/// only the nodes reachable from a chosen set of roots.
#[derive(Clone, Debug)]
pub struct AddStore {
    domains: Vec<usize>,
    nodes: Vec<NodeData>,
    terminals: HashMap<u64, NodeId>,
    internals: HashMap<(u32, Vec<NodeId>), NodeId>,
}

impl AddStore {
    /// New empty store with the given per-level arities.
    pub fn new(domains: Vec<usize>) -> AddStore {
        assert!(
            domains.iter().all(|&d| d >= 1),
            "every ADD level needs arity >= 1"
        );
        assert!(
            domains.len() < TERMINAL_LEVEL as usize,
            "too many ADD levels"
        );
        AddStore {
            domains,
            nodes: Vec::new(),
            terminals: HashMap::new(),
            internals: HashMap::new(),
        }
    }

    /// Number of variable levels.
    pub fn n_levels(&self) -> usize {
        self.domains.len()
    }

    /// Arity of level `l`.
    pub fn domain(&self, level: usize) -> usize {
        self.domains[level]
    }

    /// Total physical nodes ever interned (terminals included).
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// Whether no node has been interned yet.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    fn push(&mut self, data: NodeData) -> NodeId {
        let id = self.nodes.len();
        assert!(id < TERMINAL_LEVEL as usize, "ADD store overflow");
        self.nodes.push(data);
        NodeId(id as u32)
    }

    /// Intern the constant function `v`. `-0.0` is canonicalized to `0.0`
    /// so the bit-keyed consing cannot split the two zeros.
    pub fn terminal(&mut self, v: f64) -> NodeId {
        assert!(v.is_finite(), "ADD terminals must be finite, got {v}");
        let v = if v == 0.0 { 0.0 } else { v };
        if let Some(&id) = self.terminals.get(&v.to_bits()) {
            return id;
        }
        let id = self.push(NodeData::Terminal(v));
        self.terminals.insert(v.to_bits(), id);
        id
    }

    /// Intern an internal node at `level` with the given children (one per
    /// domain element, in value order). Applies the reduction rule: if all
    /// children are the same node, that child is returned instead.
    pub fn node(&mut self, level: usize, children: &[NodeId]) -> NodeId {
        assert_eq!(
            children.len(),
            self.domains[level],
            "level {level} has arity {}",
            self.domains[level]
        );
        debug_assert!(
            children.iter().all(|&c| self.level_of(c) > level as u32),
            "ADD ordering violated at level {level}"
        );
        if children.iter().all(|&c| c == children[0]) {
            return children[0];
        }
        let key = (level as u32, children.to_vec());
        if let Some(&id) = self.internals.get(&key) {
            return id;
        }
        let id = self.push(NodeData::Internal {
            level: level as u32,
            children: children.to_vec(),
        });
        self.internals.insert(key, id);
        id
    }

    fn level_of(&self, id: NodeId) -> u32 {
        match &self.nodes[id.0 as usize] {
            NodeData::Terminal(_) => TERMINAL_LEVEL,
            NodeData::Internal { level, .. } => *level,
        }
    }

    /// The constant value of a terminal node, `None` for internal nodes.
    pub fn terminal_value(&self, id: NodeId) -> Option<f64> {
        match &self.nodes[id.0 as usize] {
            NodeData::Terminal(v) => Some(*v),
            NodeData::Internal { .. } => None,
        }
    }

    /// The cofactor of `id` with respect to `level = v`: the child when
    /// `id` tests exactly that level, `id` itself otherwise (ordering
    /// guarantees the level then does not occur anywhere below).
    fn cofactor(&self, id: NodeId, level: u32, v: usize) -> NodeId {
        match &self.nodes[id.0 as usize] {
            NodeData::Internal { level: l, children } if *l == level => children[v],
            _ => id,
        }
    }

    /// Pointwise combination `op(f, g)`, memoized over operand pairs.
    pub fn apply(&mut self, f: NodeId, g: NodeId, op: Op) -> NodeId {
        let mut memo = HashMap::new();
        self.apply_rec(f, g, op, &mut memo)
    }

    fn apply_rec(
        &mut self,
        f: NodeId,
        g: NodeId,
        op: Op,
        memo: &mut HashMap<(NodeId, NodeId), NodeId>,
    ) -> NodeId {
        if let (Some(a), Some(b)) = (self.terminal_value(f), self.terminal_value(g)) {
            return self.terminal(op.eval(a, b));
        }
        if let Some(&r) = memo.get(&(f, g)) {
            return r;
        }
        let top = self.level_of(f).min(self.level_of(g));
        let k = self.domains[top as usize];
        let mut children = Vec::with_capacity(k);
        for v in 0..k {
            let fv = self.cofactor(f, top, v);
            let gv = self.cofactor(g, top, v);
            children.push(self.apply_rec(fv, gv, op, memo));
        }
        let r = self.node(top as usize, &children);
        memo.insert((f, g), r);
        r
    }

    /// Fix `level := val` in `f` (the resulting diagram no longer tests
    /// that level).
    pub fn restrict(&mut self, f: NodeId, level: usize, val: usize) -> NodeId {
        assert!(val < self.domains[level]);
        let mut memo = HashMap::new();
        self.restrict_rec(f, level as u32, val, &mut memo)
    }

    fn restrict_rec(
        &mut self,
        f: NodeId,
        level: u32,
        val: usize,
        memo: &mut HashMap<NodeId, NodeId>,
    ) -> NodeId {
        let lf = self.level_of(f);
        if lf > level {
            return f; // ordered: the level cannot occur below here
        }
        if let Some(&r) = memo.get(&f) {
            return r;
        }
        let r = if lf == level {
            self.cofactor(f, level, val)
        } else {
            let k = self.domains[lf as usize];
            let mut children = Vec::with_capacity(k);
            for v in 0..k {
                let c = self.cofactor(f, lf, v);
                children.push(self.restrict_rec(c, level, val, memo));
            }
            self.node(lf as usize, &children)
        };
        memo.insert(f, r);
        r
    }

    /// Sum `f` over all values of `level`: `Σ_v f[level := v]` — the
    /// expectation building block of the SPUDD Bellman backup.
    pub fn marginalize(&mut self, f: NodeId, level: usize) -> NodeId {
        let mut acc = self.restrict(f, level, 0);
        for v in 1..self.domains[level] {
            let r = self.restrict(f, level, v);
            acc = self.apply(acc, r, Op::Add);
        }
        acc
    }

    /// Move every node of `f` from level `l` to level `map[l]`. The map
    /// must preserve the relative order of the levels that actually occur
    /// in `f` (this is how the solver renames current-state variables to
    /// their primed next-state levels in one O(|f|) pass).
    pub fn relabel(&mut self, f: NodeId, map: &[u32]) -> NodeId {
        assert_eq!(map.len(), self.domains.len());
        let mut memo = HashMap::new();
        self.relabel_rec(f, map, &mut memo)
    }

    fn relabel_rec(
        &mut self,
        f: NodeId,
        map: &[u32],
        memo: &mut HashMap<NodeId, NodeId>,
    ) -> NodeId {
        if self.terminal_value(f).is_some() {
            return f;
        }
        if let Some(&r) = memo.get(&f) {
            return r;
        }
        let lf = self.level_of(f) as usize;
        let new_level = map[lf] as usize;
        assert_eq!(
            self.domains[new_level], self.domains[lf],
            "relabel must preserve arity"
        );
        let k = self.domains[lf];
        let mut children = Vec::with_capacity(k);
        for v in 0..k {
            let c = self.cofactor(f, lf as u32, v);
            children.push(self.relabel_rec(c, map, memo));
        }
        let r = self.node(new_level, &children);
        memo.insert(f, r);
        r
    }

    /// Build the ADD of an arbitrary function over a strictly increasing
    /// set of levels by full enumeration of their joint domain. `f`
    /// receives the assignment values aligned with `levels`; reduction and
    /// consing compress the result on the way up. Cost is the product of
    /// the level arities — intended for *local* functions (CPTs, cost
    /// terms) whose scopes are small.
    pub fn build_over(
        &mut self,
        levels: &[usize],
        f: &mut dyn FnMut(&[usize]) -> f64,
    ) -> NodeId {
        assert!(
            levels.windows(2).all(|w| w[0] < w[1]),
            "build_over levels must be strictly increasing"
        );
        let mut asg = Vec::with_capacity(levels.len());
        self.build_rec(levels, 0, &mut asg, f)
    }

    fn build_rec(
        &mut self,
        levels: &[usize],
        depth: usize,
        asg: &mut Vec<usize>,
        f: &mut dyn FnMut(&[usize]) -> f64,
    ) -> NodeId {
        if depth == levels.len() {
            let v = f(asg);
            return self.terminal(v);
        }
        let l = levels[depth];
        let k = self.domains[l];
        let mut children = Vec::with_capacity(k);
        for v in 0..k {
            asg.push(v);
            children.push(self.build_rec(levels, depth + 1, asg, f));
            asg.pop();
        }
        self.node(l, &children)
    }

    /// Evaluate `f` at a full assignment (`assignment[l]` is the value of
    /// level `l`; levels the diagram does not test are ignored).
    pub fn eval(&self, f: NodeId, assignment: &[usize]) -> f64 {
        let mut id = f;
        loop {
            match &self.nodes[id.0 as usize] {
                NodeData::Terminal(v) => return *v,
                NodeData::Internal { level, children } => {
                    id = children[assignment[*level as usize]];
                }
            }
        }
    }

    /// `max |f|` over all states: in a reduced ordered ADD every terminal
    /// is reached by some assignment, so the sup-norm of the represented
    /// function is the max over reachable terminal values.
    pub fn sup_abs(&self, f: NodeId) -> f64 {
        let mut best: f64 = 0.0;
        let mut seen = std::collections::HashSet::new();
        let mut stack = vec![f];
        while let Some(id) = stack.pop() {
            if !seen.insert(id) {
                continue;
            }
            match &self.nodes[id.0 as usize] {
                NodeData::Terminal(v) => best = best.max(v.abs()),
                NodeData::Internal { children, .. } => stack.extend(children.iter().copied()),
            }
        }
        best
    }

    /// Number of distinct nodes (terminals included) reachable from any of
    /// `roots` — the compression metric reported by `bench_factored`.
    pub fn reachable(&self, roots: &[NodeId]) -> usize {
        let mut seen = std::collections::HashSet::new();
        let mut stack: Vec<NodeId> = roots.to_vec();
        while let Some(id) = stack.pop() {
            if !seen.insert(id) {
                continue;
            }
            if let NodeData::Internal { children, .. } = &self.nodes[id.0 as usize] {
                stack.extend(children.iter().copied());
            }
        }
        seen.len()
    }

    /// Rebuild a fresh store containing only the nodes reachable from
    /// `roots`; returns the new store and the translated root ids (same
    /// order). Used by the structured solver to bound memory across
    /// iterations: hash-consing never frees, so dead iterates accumulate
    /// until compaction.
    pub fn compact(&self, roots: &[NodeId]) -> (AddStore, Vec<NodeId>) {
        let mut fresh = AddStore::new(self.domains.clone());
        let mut memo: HashMap<NodeId, NodeId> = HashMap::new();
        let new_roots = roots
            .iter()
            .map(|&r| self.migrate(r, &mut fresh, &mut memo))
            .collect();
        (fresh, new_roots)
    }

    fn migrate(
        &self,
        id: NodeId,
        fresh: &mut AddStore,
        memo: &mut HashMap<NodeId, NodeId>,
    ) -> NodeId {
        if let Some(&r) = memo.get(&id) {
            return r;
        }
        let r = match &self.nodes[id.0 as usize] {
            NodeData::Terminal(v) => fresh.terminal(*v),
            NodeData::Internal { level, children } => {
                let kids: Vec<NodeId> = children
                    .iter()
                    .map(|&c| self.migrate(c, fresh, memo))
                    .collect();
                fresh.node(*level as usize, &kids)
            }
        };
        memo.insert(id, r);
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Brute-force evaluation over every assignment of `levels`.
    fn for_all_assignments(domains: &[usize], mut f: impl FnMut(&[usize])) {
        let n = domains.len();
        let mut asg = vec![0usize; n];
        loop {
            f(&asg);
            let mut i = n;
            loop {
                if i == 0 {
                    return;
                }
                i -= 1;
                asg[i] += 1;
                if asg[i] < domains[i] {
                    break;
                }
                asg[i] = 0;
            }
        }
    }

    #[test]
    fn terminals_are_hash_consed() {
        let mut s = AddStore::new(vec![2, 2]);
        assert_eq!(s.terminal(1.5), s.terminal(1.5));
        assert_ne!(s.terminal(1.5), s.terminal(2.5));
        // -0.0 and 0.0 collapse
        assert_eq!(s.terminal(0.0), s.terminal(-0.0));
    }

    #[test]
    fn constant_children_reduce() {
        let mut s = AddStore::new(vec![3]);
        let t = s.terminal(7.0);
        assert_eq!(s.node(0, &[t, t, t]), t);
    }

    #[test]
    fn structural_equality_is_pointer_equality() {
        let mut s = AddStore::new(vec![2, 2]);
        // f(x0, x1) = x0 + 2*x1 built two different ways
        let a = s.build_over(&[0, 1], &mut |asg| (asg[0] + 2 * asg[1]) as f64);
        // manual bottom-up construction
        let t = [s.terminal(0.0), s.terminal(2.0), s.terminal(1.0), s.terminal(3.0)];
        let lo = s.node(1, &[t[0], t[1]]);
        let hi = s.node(1, &[t[2], t[3]]);
        let b = s.node(0, &[lo, hi]);
        assert_eq!(a, b);
    }

    #[test]
    fn apply_matches_brute_force() {
        let domains = vec![2, 3, 2];
        let mut s = AddStore::new(domains.clone());
        let f = s.build_over(&[0, 1], &mut |a| (a[0] * 3 + a[1]) as f64);
        let g = s.build_over(&[1, 2], &mut |a| (a[0] as f64) * 0.5 - a[1] as f64);
        for op in [Op::Add, Op::Sub, Op::Mul, Op::Min, Op::Max, Op::Lt, Op::Gt] {
            let h = s.apply(f, g, op);
            for_all_assignments(&domains, |asg| {
                let fa = s.eval(f, asg);
                let ga = s.eval(g, asg);
                assert_eq!(s.eval(h, asg), op.eval(fa, ga), "{op:?} at {asg:?}");
            });
        }
    }

    #[test]
    fn restrict_and_marginalize_match_brute_force() {
        let domains = vec![2, 3, 2];
        let mut s = AddStore::new(domains.clone());
        let f = s.build_over(&[0, 1, 2], &mut |a| {
            (a[0] * 6 + a[1] * 2 + a[2]) as f64 * 0.25
        });
        for v in 0..3 {
            let r = s.restrict(f, 1, v);
            for_all_assignments(&domains, |asg| {
                let mut fixed = asg.to_vec();
                fixed[1] = v;
                assert_eq!(s.eval(r, asg), s.eval(f, &fixed));
            });
        }
        let m = s.marginalize(f, 1);
        for_all_assignments(&domains, |asg| {
            let mut sum = 0.0;
            for v in 0..3 {
                let mut fixed = asg.to_vec();
                fixed[1] = v;
                sum += s.eval(f, &fixed);
            }
            assert!((s.eval(m, asg) - sum).abs() < 1e-12);
        });
    }

    #[test]
    fn relabel_moves_levels() {
        let mut s = AddStore::new(vec![2, 2, 2, 2]);
        let f = s.build_over(&[0, 2], &mut |a| (a[0] * 2 + a[1]) as f64);
        // move levels 0→1, 2→3
        let g = s.relabel(f, &[1, 1, 3, 3]);
        for_all_assignments(&[2, 2, 2, 2], |asg| {
            let shifted = [asg[1], 0, asg[3], 0];
            assert_eq!(s.eval(g, asg), s.eval(f, &shifted));
        });
    }

    #[test]
    fn compact_preserves_function_and_drops_garbage() {
        let mut s = AddStore::new(vec![2, 2]);
        for i in 0..100 {
            let _ = s.terminal(i as f64); // garbage
        }
        let f = s.build_over(&[0, 1], &mut |a| (a[0] + a[1]) as f64);
        let before = s.len();
        let (fresh, roots) = s.compact(&[f]);
        assert!(fresh.len() < before);
        assert_eq!(fresh.len(), s.reachable(&[f]));
        for_all_assignments(&[2, 2], |asg| {
            assert_eq!(fresh.eval(roots[0], asg), s.eval(f, asg));
        });
    }

    #[test]
    fn sup_abs_is_max_over_terminals() {
        let mut s = AddStore::new(vec![2, 2]);
        let f = s.build_over(&[0, 1], &mut |a| match (a[0], a[1]) {
            (0, 0) => -3.5,
            (0, 1) => 2.0,
            (1, 0) => 0.0,
            _ => 1.0,
        });
        assert_eq!(s.sup_abs(f), 3.5);
    }
}
