//! madupite-serve — answer policy queries from a persisted policy store.
//!
//! A thin shell over [`madupite::serve`]: it opens the on-disk store named
//! by `-serve_store` and speaks the line-delimited JSON protocol over
//! stdin/stdout (one request line in, one response line out — see
//! `madupite::serve::protocol`). Typical loop:
//!
//! ```text
//! madupite solve -model maze -rows 20 -cols 20 -serve_store store/
//! echo '{"op": "list"}' | madupite-serve -serve_store store/
//! echo '{"op": "action", "fingerprint": "<fp>", "states": [0, 1]}' \
//!     | madupite-serve -serve_store store/
//! ```
//!
//! Options come from the same database as the `madupite` CLI (same keys,
//! same did-you-mean on typos): `-serve_store <dir>` (required),
//! `-serve_cache_entries <n>`, `-serve_threads <n>`. Pass a model source
//! (`-model`/`-file`, plus its parameters) to enable `q_values` queries —
//! without one the server answers `action`/`value`/`meta`/`list` only.

use madupite::api::{options, MdpBuilder};
use madupite::serve::{PolicyStore, ServeSession};
use madupite::util::args::Options;
use std::io::{BufRead, Write};
use std::sync::Arc;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<(), String> {
    let db = Options::from_env();
    if let Some(first) = db.positional().first() {
        return Err(format!(
            "stray token '{first}': madupite-serve takes only '-key value' options"
        ));
    }
    options::validate_keys(&db).map_err(|e| e.to_string())?;
    let dir = db
        .get("serve_store")
        .ok_or("madupite-serve requires -serve_store <dir>")?;
    let cache = options::resolve_serve_cache_entries(&db).map_err(|e| e.to_string())?;
    let threads = options::resolve_serve_threads(&db).map_err(|e| e.to_string())?;
    let store = PolicyStore::on_disk(dir, cache).map_err(|e| e.to_string())?;
    let mut session = ServeSession::new(store, threads);

    // A model source is optional: it only gates q_values. Note the
    // explicit has() checks — MdpBuilder::from_options defaults to the
    // maze model, and a default model nobody asked for must not be
    // silently attached to arbitrary artifacts.
    if db.has("file") || db.has("model") {
        let builder = MdpBuilder::from_options(&db).map_err(|e| e.to_string())?;
        let builder = if db.has("file") {
            builder // gamma/objective come from the .mdpb header
        } else {
            let gamma =
                options::resolve_gamma(&db, builder.gamma_value()).map_err(|e| e.to_string())?;
            let objective = options::resolve_objective(&db, builder.objective_value())
                .map_err(|e| e.to_string())?;
            builder.gamma(gamma).objective(objective)
        };
        let model = builder.build_serial().map_err(|e| e.to_string())?;
        session = session.with_model(Arc::new(model));
    }

    let keys = session.store().keys().map_err(|e| e.to_string())?;
    eprintln!(
        "madupite-serve {}: store {dir} ({} artifacts, cache {}, {} threads); \
         one JSON request per stdin line",
        madupite::VERSION,
        keys.len(),
        cache,
        threads
    );

    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    for line in stdin.lock().lines() {
        let line = line.map_err(|e| format!("reading stdin: {e}"))?;
        if line.trim().is_empty() {
            continue;
        }
        let response = session.handle_line(&line);
        writeln!(out, "{response}").map_err(|e| format!("writing stdout: {e}"))?;
        out.flush().map_err(|e| format!("flushing stdout: {e}"))?;
    }
    Ok(())
}
