//! The typed JSON request/response protocol the `madupite-serve` binary
//! speaks over stdin/stdout.
//!
//! One request per line, one response line per request (batched
//! line-delimited framing — a client pipelines by writing N lines and
//! reading N lines back). Requests:
//!
//! ```json
//! {"id": 7, "op": "action",   "fingerprint": "<16 hex>", "states": [0, 3, 5]}
//! {"id": 8, "op": "value",    "fingerprint": "<16 hex>", "states": [1]}
//! {"id": 9, "op": "q_values", "fingerprint": "<16 hex>", "states": [2]}
//! {"id": 10, "op": "meta",    "fingerprint": "<16 hex>"}
//! {"id": 11, "op": "list"}
//! ```
//!
//! Responses mirror the `id` back (`null` if the request had none):
//!
//! ```json
//! {"id": 7, "ok": true, "op": "action", "results": [2, 0, 1]}
//! {"id": 7, "ok": false, "error": "bad request: ..."}
//! ```
//!
//! Every malformed input — unparseable JSON, unknown op (answered with a
//! did-you-mean, reusing the options-database suggester), missing
//! fingerprint, fractional or negative state index — is an `ok:false`
//! response, never a panic and never a dropped line. Numeric results
//! round-trip exactly: values serialize via the shortest-representation
//! `f64` formatter and re-parse to the same bits.

use std::sync::Arc;

use crate::api::options;
use crate::mdp::Mdp;
use crate::util::json::Json;

use super::engine::QueryEngine;
use super::store::PolicyStore;
use super::ServeError;

/// Operations the protocol understands, for did-you-mean suggestions.
pub const OPS: &[&str] = &["action", "value", "q_values", "meta", "list"];

/// A serve session: one store, an optional transition model (enables
/// `q_values`), and the worker thread count for batched lookups. Shared
/// across client threads by reference — `handle_line` takes `&self`.
pub struct ServeSession {
    store: PolicyStore,
    model: Option<Arc<Mdp>>,
    threads: usize,
}

impl ServeSession {
    /// Session over `store` answering with `threads` lookup workers.
    pub fn new(store: PolicyStore, threads: usize) -> ServeSession {
        ServeSession {
            store,
            model: None,
            threads: threads.max(1),
        }
    }

    /// Attach a transition model, enabling `q_values` queries.
    pub fn with_model(mut self, model: Arc<Mdp>) -> ServeSession {
        self.model = Some(model);
        self
    }

    /// The underlying store (benchmarks read cache stats through this).
    pub fn store(&self) -> &PolicyStore {
        &self.store
    }

    /// Answer one request line with one response line (no trailing
    /// newline). Never panics on client input.
    pub fn handle_line(&self, line: &str) -> String {
        let (id, outcome) = match Json::parse(line) {
            Ok(req) => {
                let id = req.get("id").cloned().unwrap_or(Json::Null);
                (id, self.dispatch(&req))
            }
            Err(e) => (
                Json::Null,
                Err(ServeError::BadRequest(format!("unparseable request: {e}"))),
            ),
        };
        let response = match outcome {
            Ok((op, results)) => Json::obj(vec![
                ("id", id),
                ("ok", Json::Bool(true)),
                ("op", Json::str(op)),
                ("results", results),
            ]),
            Err(e) => Json::obj(vec![
                ("id", id),
                ("ok", Json::Bool(false)),
                ("error", Json::str(e.to_string())),
            ]),
        };
        response.to_string()
    }

    fn dispatch(&self, req: &Json) -> Result<(&'static str, Json), ServeError> {
        let op = req
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| ServeError::BadRequest("missing string field 'op'".to_string()))?;
        match op {
            "list" => {
                let keys = self.store.keys()?;
                Ok(("list", Json::Arr(keys.into_iter().map(Json::str).collect())))
            }
            "meta" => {
                let engine = self.engine_for(req)?;
                let meta = engine.artifact().meta_json()?;
                Ok(("meta", meta))
            }
            "action" => {
                let engine = self.engine_for(req)?;
                let states = parse_states(req)?;
                let actions = engine.actions_batch(&states, self.threads)?;
                Ok((
                    "action",
                    Json::Arr(actions.into_iter().map(|a| Json::int(a as i64)).collect()),
                ))
            }
            "value" => {
                let engine = self.engine_for(req)?;
                let states = parse_states(req)?;
                let values = engine.values_batch(&states, self.threads)?;
                Ok(("value", Json::nums(&values)))
            }
            "q_values" => {
                let engine = self.engine_for(req)?;
                let states = parse_states(req)?;
                let qs = engine.q_values_batch(&states, self.threads)?;
                Ok(("q_values", Json::Arr(qs.iter().map(|q| Json::nums(q)).collect())))
            }
            unknown => {
                let hint = match options::suggest(unknown, OPS) {
                    Some(s) => format!(" (did you mean '{s}'?)"),
                    None => String::new(),
                };
                Err(ServeError::BadRequest(format!(
                    "unknown op '{unknown}'{hint}; ops: {}",
                    OPS.join(", ")
                )))
            }
        }
    }

    fn engine_for(&self, req: &Json) -> Result<QueryEngine, ServeError> {
        let fp = req
            .get("fingerprint")
            .and_then(Json::as_str)
            .ok_or_else(|| {
                ServeError::BadRequest("missing string field 'fingerprint'".to_string())
            })?;
        let artifact = self.store.get(fp)?;
        Ok(match &self.model {
            Some(model) => QueryEngine::with_model(artifact, Arc::clone(model)),
            None => QueryEngine::new(artifact),
        })
    }
}

/// Extract the `states` array: every element must be a non-negative
/// integer-valued number.
fn parse_states(req: &Json) -> Result<Vec<usize>, ServeError> {
    let arr = req
        .get("states")
        .and_then(Json::as_arr)
        .ok_or_else(|| ServeError::BadRequest("missing array field 'states'".to_string()))?;
    arr.iter()
        .map(|x| {
            let f = x.as_f64().ok_or_else(|| {
                ServeError::BadRequest("'states' entries must be numbers".to_string())
            })?;
            if f < 0.0 || f.fract() != 0.0 || f > u32::MAX as f64 {
                return Err(ServeError::BadRequest(format!(
                    "state index {f} is not a non-negative integer"
                )));
            }
            Ok(f as usize)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{MdpBuilder, Solver};

    fn session() -> (ServeSession, String, crate::api::SolveOutcome) {
        let builder = MdpBuilder::from_fillers(
            5,
            2,
            |s, a| vec![((s + a) % 5, 1.0)],
            |s, a| (s + 2 * a) as f64 * 0.5,
        )
        .gamma(0.5);
        let mdp = builder.build_serial().unwrap();
        let outcome = Solver::new(builder).solve().unwrap();
        let store = PolicyStore::in_memory(8);
        let fp = store.put_outcome(&outcome).unwrap();
        let session = ServeSession::new(store, 2).with_model(Arc::new(mdp));
        (session, fp, outcome)
    }

    fn ok_results(resp: &str) -> Json {
        let json = Json::parse(resp).unwrap();
        assert_eq!(json.get("ok").and_then(Json::as_bool), Some(true), "{resp}");
        json.get("results").cloned().unwrap()
    }

    #[test]
    fn action_roundtrip() {
        let (session, fp, outcome) = session();
        let resp = session.handle_line(&format!(
            r#"{{"id": 1, "op": "action", "fingerprint": "{fp}", "states": [0, 1, 2, 3, 4]}}"#
        ));
        let results = ok_results(&resp);
        let got: Vec<usize> = results
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as usize)
            .collect();
        assert_eq!(got, outcome.policy());
        assert_eq!(Json::parse(&resp).unwrap().get("id").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn value_roundtrip_is_bitwise() {
        let (session, fp, outcome) = session();
        let resp = session.handle_line(&format!(
            r#"{{"op": "value", "fingerprint": "{fp}", "states": [4, 0]}}"#
        ));
        let results = ok_results(&resp);
        let got: Vec<f64> = results
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap())
            .collect();
        assert_eq!(got[0].to_bits(), outcome.value()[4].to_bits());
        assert_eq!(got[1].to_bits(), outcome.value()[0].to_bits());
    }

    #[test]
    fn q_values_shape() {
        let (session, fp, _) = session();
        let resp = session.handle_line(&format!(
            r#"{{"op": "q_values", "fingerprint": "{fp}", "states": [0, 3]}}"#
        ));
        let results = ok_results(&resp);
        let rows = results.as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].as_arr().unwrap().len(), 2); // n_actions
    }

    #[test]
    fn list_and_meta() {
        let (session, fp, _) = session();
        let resp = session.handle_line(r#"{"op": "list"}"#);
        let results = ok_results(&resp);
        assert_eq!(results.as_arr().unwrap()[0].as_str(), Some(fp.as_str()));
        let resp = session.handle_line(&format!(r#"{{"op": "meta", "fingerprint": "{fp}"}}"#));
        let meta = ok_results(&resp);
        assert!(meta.get("model").is_some());
    }

    #[test]
    fn unknown_op_gets_did_you_mean() {
        let (session, fp, _) = session();
        let resp = session.handle_line(&format!(
            r#"{{"op": "actoin", "fingerprint": "{fp}", "states": [0]}}"#
        ));
        let json = Json::parse(&resp).unwrap();
        assert_eq!(json.get("ok").and_then(Json::as_bool), Some(false));
        let err = json.get("error").and_then(Json::as_str).unwrap();
        assert!(err.contains("did you mean 'action'"), "{err}");
    }

    #[test]
    fn malformed_inputs_are_typed_not_panics() {
        let (session, fp, _) = session();
        for bad in [
            "not json at all",
            "{}",
            r#"{"op": "action"}"#,
            r#"{"op": "action", "fingerprint": "0000000000000000", "states": [0]}"#,
            &format!(r#"{{"op": "action", "fingerprint": "{fp}", "states": [1.5]}}"#),
            &format!(r#"{{"op": "action", "fingerprint": "{fp}", "states": [-1]}}"#),
            &format!(r#"{{"op": "action", "fingerprint": "{fp}", "states": [999]}}"#),
            &format!(r#"{{"op": "action", "fingerprint": "{fp}", "states": "zero"}}"#),
        ] {
            let json = Json::parse(&session.handle_line(bad)).unwrap();
            assert_eq!(json.get("ok").and_then(Json::as_bool), Some(false), "{bad}");
            assert!(json.get("error").is_some(), "{bad}");
        }
    }

    #[test]
    fn q_values_without_model_is_error_response() {
        let (session_with_model, fp, outcome) = session();
        drop(session_with_model);
        let store = PolicyStore::in_memory(8);
        store.put_outcome(&outcome).unwrap();
        let bare = ServeSession::new(store, 1);
        let resp = bare.handle_line(&format!(
            r#"{{"op": "q_values", "fingerprint": "{fp}", "states": [0]}}"#
        ));
        let json = Json::parse(&resp).unwrap();
        assert_eq!(json.get("ok").and_then(Json::as_bool), Some(false));
    }
}
