//! [`QueryEngine`] — answer `(state) → action / value / q-values` lookups
//! from a decoded [`PolicyArtifact`].
//!
//! The engine is read-only and shares the artifact by `Arc`, so one decoded
//! artifact serves arbitrarily many concurrent client threads without
//! copies. Batch queries split the requested states into contiguous chunks
//! — one per worker thread — and concatenate the chunk results in order, so
//! the response is byte-identical regardless of `-serve_threads` (the same
//! thread-count-independence discipline the solver's reductions follow).
//!
//! Q-value queries need the transition model (the artifact stores only the
//! optimal value and policy); attach one with [`QueryEngine::with_model`],
//! otherwise `q_values` is a typed [`ServeError::BadRequest`].

use std::sync::Arc;

use crate::mdp::Mdp;

use super::codec::PolicyArtifact;
use super::ServeError;

/// Read-only query engine over one decoded policy artifact.
#[derive(Clone)]
pub struct QueryEngine {
    artifact: Arc<PolicyArtifact>,
    model: Option<Arc<Mdp>>,
}

impl QueryEngine {
    /// Engine over an artifact alone (`action` and `value` queries).
    pub fn new(artifact: Arc<PolicyArtifact>) -> QueryEngine {
        QueryEngine {
            artifact,
            model: None,
        }
    }

    /// Engine with a transition model attached, enabling `q_values`.
    pub fn with_model(artifact: Arc<PolicyArtifact>, model: Arc<Mdp>) -> QueryEngine {
        QueryEngine {
            artifact,
            model: Some(model),
        }
    }

    /// The artifact this engine serves.
    pub fn artifact(&self) -> &Arc<PolicyArtifact> {
        &self.artifact
    }

    fn check_state(&self, state: usize) -> Result<(), ServeError> {
        if state >= self.artifact.n_states {
            return Err(ServeError::BadRequest(format!(
                "state {state} out of range (artifact has {} states)",
                self.artifact.n_states
            )));
        }
        Ok(())
    }

    /// Optimal action at `state`.
    pub fn action(&self, state: usize) -> Result<usize, ServeError> {
        self.check_state(state)?;
        Ok(self.artifact.policy[state])
    }

    /// Optimal value at `state` (bitwise the solver's value).
    pub fn value(&self, state: usize) -> Result<f64, ServeError> {
        self.check_state(state)?;
        Ok(self.artifact.value[state])
    }

    /// Q-values of every action at `state`, computed against the attached
    /// model with the artifact's value function: `q(s,a) = c(s,a) +
    /// γ(s,a) · Σ_j P(s,a,j) v(j)`.
    pub fn q_values(&self, state: usize) -> Result<Vec<f64>, ServeError> {
        self.check_state(state)?;
        let model = self.model.as_ref().ok_or_else(|| {
            ServeError::BadRequest(
                "q_values needs a transition model: start the server with a -model/-file source"
                    .to_string(),
            )
        })?;
        if model.n_states() != self.artifact.n_states
            || model.n_actions() != self.artifact.n_actions
        {
            return Err(ServeError::BadRequest(format!(
                "attached model shape {}x{} does not match artifact {}x{}",
                model.n_states(),
                model.n_actions(),
                self.artifact.n_states,
                self.artifact.n_actions
            )));
        }
        Ok((0..self.artifact.n_actions)
            .map(|a| model.q_value(state, a, &self.artifact.value))
            .collect())
    }

    /// Batched [`Self::action`] over `states`, split across `threads`
    /// workers. Results are in request order and independent of `threads`.
    pub fn actions_batch(
        &self,
        states: &[usize],
        threads: usize,
    ) -> Result<Vec<usize>, ServeError> {
        self.batch(states, threads, |eng, s| eng.action(s))
    }

    /// Batched [`Self::value`] over `states`, split across `threads`
    /// workers. Results are in request order and independent of `threads`.
    pub fn values_batch(&self, states: &[usize], threads: usize) -> Result<Vec<f64>, ServeError> {
        self.batch(states, threads, |eng, s| eng.value(s))
    }

    /// Batched [`Self::q_values`] over `states`, split across `threads`
    /// workers. Results are in request order and independent of `threads`.
    pub fn q_values_batch(
        &self,
        states: &[usize],
        threads: usize,
    ) -> Result<Vec<Vec<f64>>, ServeError> {
        self.batch(states, threads, |eng, s| eng.q_values(s))
    }

    /// Generic ordered fan-out: contiguous chunks, one worker per chunk,
    /// results concatenated in chunk order. The first error (lowest request
    /// index) wins, matching single-threaded behaviour.
    fn batch<T: Send>(
        &self,
        states: &[usize],
        threads: usize,
        op: impl Fn(&QueryEngine, usize) -> Result<T, ServeError> + Sync,
    ) -> Result<Vec<T>, ServeError> {
        let threads = threads.clamp(1, states.len().max(1));
        if threads <= 1 {
            return states.iter().map(|&s| op(self, s)).collect();
        }
        let chunk = states.len().div_ceil(threads);
        let results: Vec<Result<Vec<T>, ServeError>> = std::thread::scope(|scope| {
            let handles: Vec<_> = states
                .chunks(chunk)
                .map(|part| scope.spawn(|| part.iter().map(|&s| op(self, s)).collect()))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut out = Vec::with_capacity(states.len());
        for chunk_result in results {
            out.extend(chunk_result?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{MdpBuilder, Solver};

    fn engine_with_model() -> (QueryEngine, crate::api::SolveOutcome) {
        let builder = MdpBuilder::from_fillers(
            6,
            3,
            |s, a| vec![((s + a) % 6, 1.0)],
            |s, a| (s * 3 + a) as f64 * 0.125,
        )
        .gamma(0.5);
        let mdp = builder.build_serial().unwrap();
        let outcome = Solver::new(builder).solve().unwrap();
        let artifact = Arc::new(PolicyArtifact::from_outcome(&outcome));
        (QueryEngine::with_model(artifact, Arc::new(mdp)), outcome)
    }

    #[test]
    fn point_queries_match_outcome() {
        let (engine, outcome) = engine_with_model();
        for s in 0..6 {
            assert_eq!(engine.action(s).unwrap(), outcome.policy()[s]);
            assert_eq!(engine.value(s).unwrap().to_bits(), outcome.value()[s].to_bits());
        }
    }

    #[test]
    fn out_of_range_state_is_bad_request() {
        let (engine, _) = engine_with_model();
        assert!(matches!(engine.action(6), Err(ServeError::BadRequest(_))));
        assert!(matches!(engine.value(99), Err(ServeError::BadRequest(_))));
        assert!(matches!(engine.q_values(6), Err(ServeError::BadRequest(_))));
    }

    #[test]
    fn q_values_consistent_with_value() {
        // min objective: v(s) == min_a q(s,a), and argmin matches policy.
        let (engine, outcome) = engine_with_model();
        for s in 0..6 {
            let q = engine.q_values(s).unwrap();
            let best = q.iter().enumerate().min_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap();
            assert_eq!(best.0, outcome.policy()[s]);
            assert!((best.1 - outcome.value()[s]).abs() < 1e-9);
        }
    }

    #[test]
    fn q_values_without_model_is_bad_request() {
        let (engine, _) = engine_with_model();
        let bare = QueryEngine::new(Arc::clone(engine.artifact()));
        assert!(matches!(bare.q_values(0), Err(ServeError::BadRequest(_))));
    }

    #[test]
    fn batches_are_thread_count_independent() {
        let (engine, _) = engine_with_model();
        let states: Vec<usize> = (0..6).cycle().take(50).collect();
        let oracle_a = engine.actions_batch(&states, 1).unwrap();
        let oracle_v = engine.values_batch(&states, 1).unwrap();
        let oracle_q = engine.q_values_batch(&states, 1).unwrap();
        for threads in [2, 3, 4, 8, 64] {
            assert_eq!(engine.actions_batch(&states, threads).unwrap(), oracle_a);
            let v = engine.values_batch(&states, threads).unwrap();
            assert_eq!(v.len(), oracle_v.len());
            for (x, y) in v.iter().zip(&oracle_v) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            assert_eq!(engine.q_values_batch(&states, threads).unwrap(), oracle_q);
        }
    }

    #[test]
    fn batch_error_matches_single_threaded() {
        let (engine, _) = engine_with_model();
        let states = vec![0, 1, 99, 2];
        let single = engine.actions_batch(&states, 1).unwrap_err();
        let multi = engine.actions_batch(&states, 4).unwrap_err();
        assert_eq!(single, multi);
    }

    #[test]
    fn empty_batch_is_empty() {
        let (engine, _) = engine_with_model();
        assert!(engine.actions_batch(&[], 4).unwrap().is_empty());
    }
}
