//! Policy serving: persist solved policies, answer decision queries.
//!
//! The solver half of the crate ends a run with a [`crate::api::SolveOutcome`]
//! — this module is the consumption half (ROADMAP item 1): the solve →
//! persist → query loop that turns an offline solve into an online decision
//! service.
//!
//! - [`fingerprint`] keys an outcome by a deterministic model+options
//!   fingerprint (FNV-1a over canonical sorted-key JSON, excluding the
//!   execution shape — ranks/threads/overlap never change results).
//! - [`codec`] is the one serde path: a versioned `.mdpa` binary artifact
//!   following the `.mdpb` header discipline (magic, version, exact
//!   expected-length validation, typed errors on corruption), self-verified
//!   by payload digests on every decode.
//! - [`store`] is the sink/cache split: [`ArtifactSink`] backends (an
//!   in-memory map and an on-disk directory today; an S3-style object sink
//!   slots in behind the same trait) both move *encoded* bytes, so every
//!   backend exercises the same codec; a [`crate::util::lru::ShardedLru`]
//!   holds decoded artifacts in front.
//! - [`engine`] answers `(state) → action / value / q-values` lookups,
//!   batched across client threads with thread-count-independent results.
//! - [`protocol`] is the typed JSON request/response surface the
//!   `madupite-serve` binary speaks over stdin/stdout.
//!
//! Everything user-triggerable fails with a typed [`ServeError`] — a
//! truncated artifact, a flipped version byte, or a stale fingerprint is an
//! error response, never a panic and never a silently served wrong policy.
//!
//! ```
//! use madupite::api::{MdpBuilder, Solver};
//! use madupite::serve::{PolicyStore, QueryEngine};
//!
//! let builder = MdpBuilder::from_fillers(
//!     2,
//!     2,
//!     |s, a| match (s, a) {
//!         (0, 0) => vec![(0, 1.0)],
//!         (0, 1) => vec![(1, 1.0)],
//!         _ => vec![(1, 1.0)],
//!     },
//!     |s, a| match (s, a) {
//!         (0, 0) => 1.0,
//!         (0, 1) => 1.5,
//!         _ => 0.0,
//!     },
//! )
//! .gamma(0.5);
//! let outcome = Solver::new(builder).solve().unwrap();
//!
//! // Persist, then serve from the store (cache up to 64 decoded artifacts).
//! let store = PolicyStore::in_memory(64);
//! let fp = store.put_outcome(&outcome).unwrap();
//! let artifact = store.get(&fp).unwrap();
//! let engine = QueryEngine::new(artifact);
//! assert_eq!(engine.action(0).unwrap(), outcome.policy()[0]);
//! assert_eq!(engine.value(0).unwrap(), outcome.value()[0]);
//! ```

pub mod codec;
pub mod engine;
pub mod fingerprint;
pub mod protocol;
pub mod store;

pub use codec::PolicyArtifact;
pub use engine::QueryEngine;
pub use protocol::ServeSession;
pub use store::{ArtifactSink, DirSink, MemorySink, PolicyStore};

use std::fmt;

/// Error type of the serving layer. Every failure mode a client or a
/// corrupted store can trigger is a distinct typed variant — the
/// corruption-fault suite in `tests/serve.rs` pins each one.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Underlying I/O failure of a sink (filesystem errors, permissions).
    Io(String),
    /// Structurally invalid artifact bytes: bad magic, truncation, length
    /// mismatch, payload digest mismatch, out-of-range policy actions.
    Corrupt(String),
    /// The artifact was written by a different `.mdpa` format version.
    BadVersion {
        /// Version found in the artifact header.
        found: u32,
        /// Version this build reads.
        expected: u32,
    },
    /// The artifact's self-declared fingerprint does not match the key it
    /// was requested under — a renamed or stale artifact must not be
    /// silently served.
    FingerprintMismatch {
        /// The fingerprint the client asked for.
        requested: String,
        /// The fingerprint the artifact actually carries.
        found: String,
    },
    /// No artifact stored under the requested fingerprint.
    NotFound(String),
    /// Malformed query: out-of-range state, unknown operation, missing
    /// field, non-integer state index.
    BadRequest(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Io(msg) => write!(f, "io error: {msg}"),
            ServeError::Corrupt(msg) => write!(f, "corrupt artifact: {msg}"),
            ServeError::BadVersion { found, expected } => write!(
                f,
                "unsupported artifact version {found} (this build reads v{expected})"
            ),
            ServeError::FingerprintMismatch { requested, found } => write!(
                f,
                "fingerprint mismatch: requested {requested}, artifact carries {found}"
            ),
            ServeError::NotFound(fp) => write!(f, "no artifact stored under fingerprint {fp}"),
            ServeError::BadRequest(msg) => write!(f, "bad request: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}
