//! The one serde path for policy artifacts: the `.mdpa` v1 binary format.
//!
//! Every sink backend ([`crate::serve::store`]) moves the bytes produced
//! here — the in-memory map and the on-disk directory (and any future
//! S3-style object sink) share this single codec, so a round-trip bug
//! cannot hide in one backend.
//!
//! The format follows the `.mdpb` v1/v2/v3 header discipline
//! (`crate::mdp::io`): little-endian fixed-width fields, a magic + version
//! prefix, and an *exact* expected-file-length check (computed in `u128` so
//! a corrupted count cannot overflow the check itself). All failures are
//! typed [`ServeError`]s.
//!
//! ```text
//! offset  size  field
//! ------  ----  -----------------------------------------------
//!      0     4  magic "MDPA"
//!      4     4  version (u32, = 1)
//!      8     8  fingerprint (u64, FNV-1a of the meta document)
//!     16     8  n_states (u64)
//!     24     8  n_actions (u64)
//!     32     8  gamma (f64)
//!     40     8  objective code (u64: 0 = min, 1 = max)
//!     48     8  discount mode code (u64: 0/1/2, as .mdpb v3)
//!     56     8  meta_len (u64, bytes)
//!     64    8n  value vector V* (n_states × f64)
//!   +8n     8n  policy π* (n_states × u64)
//!  +16n     meta_len  canonical fingerprint JSON (UTF-8)
//! ```
//!
//! Decoding is self-verifying beyond the structural checks: the trailing
//! meta document embeds FNV-1a digests of the value and policy payloads,
//! the header fingerprint is the FNV-1a of the meta bytes, and the header's
//! model fields must agree with the meta's. A flipped byte anywhere —
//! header, payload, or metadata — therefore surfaces as a typed error, not
//! a silently wrong decision served to a client.

use crate::api::SolveOutcome;
use crate::comm::codec::{decode_f64s, decode_usizes, encode_f64s, encode_usizes};
use crate::mdp::{DiscountMode, Objective};
use crate::util::json::Json;

use super::fingerprint::{fnv1a64, fnv1a64_f64s, fnv1a64_usizes, hex16};
use super::ServeError;

/// Artifact magic bytes.
pub const MAGIC: &[u8; 4] = b"MDPA";
/// Current artifact format version.
pub const VERSION: u32 = 1;
/// Fixed header length in bytes.
pub const HEADER_LEN: usize = 64;

/// A decoded policy artifact: everything a query engine needs to answer
/// `(state) → action / value` without the solver or the model in memory.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyArtifact {
    /// FNV-1a fingerprint of [`Self::meta`] — the artifact's store key.
    pub fingerprint: u64,
    /// Global state count of the solved MDP.
    pub n_states: usize,
    /// Action count of the solved MDP.
    pub n_actions: usize,
    /// Uniform discount bound the solve ran with.
    pub gamma: f64,
    /// Optimization sense the solve ran with.
    pub objective: Objective,
    /// Discount representation the solve ran with.
    pub discount_mode: DiscountMode,
    /// Optimal value vector V* (one entry per state).
    pub value: Vec<f64>,
    /// Optimal policy π* (one action index per state).
    pub policy: Vec<usize>,
    /// Canonical fingerprint JSON (compact, sorted keys) — the document
    /// whose FNV-1a hash is [`Self::fingerprint`].
    pub meta: String,
}

fn objective_code(o: Objective) -> u64 {
    match o {
        Objective::Min => 0,
        Objective::Max => 1,
    }
}

fn objective_from_code(code: u64) -> Result<Objective, ServeError> {
    match code {
        0 => Ok(Objective::Min),
        1 => Ok(Objective::Max),
        other => Err(ServeError::Corrupt(format!(
            "objective code {other} is not 0 (min) or 1 (max)"
        ))),
    }
}

impl PolicyArtifact {
    /// Build the artifact for a solve outcome. The meta document is the
    /// outcome's canonical fingerprint JSON, so the artifact key equals
    /// [`SolveOutcome::fingerprint`].
    pub fn from_outcome(outcome: &SolveOutcome) -> PolicyArtifact {
        let meta = outcome.fingerprint_json().to_string();
        let fingerprint = fnv1a64(meta.as_bytes());
        PolicyArtifact {
            fingerprint,
            n_states: outcome.n_states,
            n_actions: outcome.n_actions,
            gamma: outcome.gamma,
            objective: outcome.objective,
            discount_mode: outcome.discount_mode,
            value: outcome.result.value.clone(),
            policy: outcome.result.policy.clone(),
            meta,
        }
    }

    /// Canonical 16-hex-digit spelling of [`Self::fingerprint`].
    pub fn fingerprint_hex(&self) -> String {
        hex16(self.fingerprint)
    }

    /// The parsed meta document (model shape, solver configuration,
    /// payload digests).
    pub fn meta_json(&self) -> Result<Json, ServeError> {
        Json::parse(&self.meta)
            .map_err(|e| ServeError::Corrupt(format!("artifact metadata is not valid JSON: {e}")))
    }

    /// Encode to `.mdpa` v1 bytes (the inverse of [`decode`]).
    pub fn encode(&self) -> Vec<u8> {
        let meta = self.meta.as_bytes();
        let mut out = Vec::with_capacity(HEADER_LEN + 16 * self.n_states + meta.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&self.fingerprint.to_le_bytes());
        out.extend_from_slice(&(self.n_states as u64).to_le_bytes());
        out.extend_from_slice(&(self.n_actions as u64).to_le_bytes());
        out.extend_from_slice(&self.gamma.to_le_bytes());
        out.extend_from_slice(&objective_code(self.objective).to_le_bytes());
        out.extend_from_slice(&self.discount_mode.code().to_le_bytes());
        out.extend_from_slice(&(meta.len() as u64).to_le_bytes());
        out.extend_from_slice(&encode_f64s(&self.value));
        out.extend_from_slice(&encode_usizes(&self.policy));
        out.extend_from_slice(meta);
        out
    }
}

fn read_u64(bytes: &[u8], offset: usize) -> u64 {
    u64::from_le_bytes(bytes[offset..offset + 8].try_into().expect("bounds checked"))
}

/// Decode and fully validate `.mdpa` v1 bytes. Structural checks (magic,
/// version, exact length) come first; then the payload digests and the
/// header/meta cross-checks, so any single flipped byte is caught.
pub fn decode(bytes: &[u8]) -> Result<PolicyArtifact, ServeError> {
    if bytes.len() < HEADER_LEN {
        return Err(ServeError::Corrupt(format!(
            "truncated artifact: {} bytes, header alone is {HEADER_LEN}",
            bytes.len()
        )));
    }
    if &bytes[0..4] != MAGIC {
        return Err(ServeError::Corrupt(format!(
            "bad magic {:?} (expected {MAGIC:?})",
            &bytes[0..4]
        )));
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().expect("bounds checked"));
    if version != VERSION {
        return Err(ServeError::BadVersion {
            found: version,
            expected: VERSION,
        });
    }
    let fingerprint = read_u64(bytes, 8);
    let n_states_u64 = read_u64(bytes, 16);
    let n_actions_u64 = read_u64(bytes, 24);
    let gamma = f64::from_le_bytes(bytes[32..40].try_into().expect("bounds checked"));
    let objective = objective_from_code(read_u64(bytes, 40))?;
    let discount_mode = DiscountMode::from_code(read_u64(bytes, 48))
        .map_err(ServeError::Corrupt)?;
    let meta_len = read_u64(bytes, 56);

    // Exact expected-length check, computed in u128 so corrupted counts
    // cannot overflow the check itself (the .mdpb discipline).
    let expected = HEADER_LEN as u128 + 16 * n_states_u64 as u128 + meta_len as u128;
    if bytes.len() as u128 != expected {
        return Err(ServeError::Corrupt(format!(
            "length mismatch: file is {} bytes, header implies {expected} \
             (n_states={n_states_u64}, meta_len={meta_len}) — truncated or corrupted",
            bytes.len()
        )));
    }
    let n_states = n_states_u64 as usize;
    let n_actions = n_actions_u64 as usize;
    if n_actions == 0 {
        return Err(ServeError::Corrupt("n_actions is 0".into()));
    }

    let value_end = HEADER_LEN + 8 * n_states;
    let policy_end = value_end + 8 * n_states;
    let value = decode_f64s(&bytes[HEADER_LEN..value_end]);
    let policy = decode_usizes(&bytes[value_end..policy_end]);
    for (s, &a) in policy.iter().enumerate() {
        if a >= n_actions {
            return Err(ServeError::Corrupt(format!(
                "policy action {a} at state {s} is out of range (n_actions={n_actions})"
            )));
        }
    }
    let meta_bytes = &bytes[policy_end..];
    let meta = std::str::from_utf8(meta_bytes)
        .map_err(|e| ServeError::Corrupt(format!("artifact metadata is not UTF-8: {e}")))?
        .to_string();

    // Self-verification: the header fingerprint is the hash of the meta
    // document, and the meta embeds digests of the payload vectors.
    if fnv1a64(meta_bytes) != fingerprint {
        return Err(ServeError::Corrupt(format!(
            "header fingerprint {} does not hash the artifact metadata ({})",
            hex16(fingerprint),
            hex16(fnv1a64(meta_bytes))
        )));
    }
    let meta_doc = Json::parse(&meta)
        .map_err(|e| ServeError::Corrupt(format!("artifact metadata is not valid JSON: {e}")))?;
    let digest_field = |key: &str| -> Result<String, ServeError> {
        meta_doc
            .get(key)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| ServeError::Corrupt(format!("metadata is missing '{key}'")))
    };
    if digest_field("value_digest")? != hex16(fnv1a64_f64s(&value)) {
        return Err(ServeError::Corrupt(
            "value payload digest mismatch — the value vector was modified".into(),
        ));
    }
    if digest_field("policy_digest")? != hex16(fnv1a64_usizes(&policy)) {
        return Err(ServeError::Corrupt(
            "policy payload digest mismatch — the policy vector was modified".into(),
        ));
    }
    // Header/meta cross-checks: the fixed header fields must agree with the
    // (digest-protected) meta document, so header flips cannot slip by.
    let model = meta_doc
        .get("model")
        .ok_or_else(|| ServeError::Corrupt("metadata is missing 'model'".into()))?;
    let model_u64 = |key: &str| -> Result<u64, ServeError> {
        model
            .get(key)
            .and_then(Json::as_f64)
            .map(|x| x as u64)
            .ok_or_else(|| ServeError::Corrupt(format!("metadata model is missing '{key}'")))
    };
    if model_u64("n_states")? != n_states_u64 || model_u64("n_actions")? != n_actions_u64 {
        return Err(ServeError::Corrupt(
            "header model shape disagrees with artifact metadata".into(),
        ));
    }
    let meta_gamma = model
        .get("gamma")
        .and_then(Json::as_f64)
        .ok_or_else(|| ServeError::Corrupt("metadata model is missing 'gamma'".into()))?;
    if meta_gamma.to_bits() != gamma.to_bits() {
        return Err(ServeError::Corrupt(
            "header gamma disagrees with artifact metadata".into(),
        ));
    }
    let model_str = |key: &str| -> Result<String, ServeError> {
        model
            .get(key)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| ServeError::Corrupt(format!("metadata model is missing '{key}'")))
    };
    if model_str("objective")? != objective.name()
        || model_str("discount_mode")? != discount_mode.name()
    {
        return Err(ServeError::Corrupt(
            "header objective/discount mode disagrees with artifact metadata".into(),
        ));
    }

    Ok(PolicyArtifact {
        fingerprint,
        n_states,
        n_actions,
        gamma,
        objective,
        discount_mode,
        value,
        policy,
        meta,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{MdpBuilder, Solver};

    fn solved() -> SolveOutcome {
        let builder = MdpBuilder::from_fillers(
            3,
            2,
            |s, a| match a {
                0 => vec![(s, 1.0)],
                _ => vec![(0, 1.0)],
            },
            |s, a| if a == 0 { s as f64 } else { 0.5 },
        )
        .gamma(0.5);
        Solver::new(builder).solve().unwrap()
    }

    #[test]
    fn roundtrip_bitwise() {
        let outcome = solved();
        let art = PolicyArtifact::from_outcome(&outcome);
        let bytes = art.encode();
        let back = decode(&bytes).unwrap();
        assert_eq!(back, art);
        // payload bitwise equality against the outcome itself
        for (a, b) in back.value.iter().zip(outcome.result.value.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(back.policy, outcome.result.policy);
        assert_eq!(back.fingerprint_hex(), outcome.fingerprint());
    }

    #[test]
    fn truncation_is_typed() {
        let bytes = PolicyArtifact::from_outcome(&solved()).encode();
        for cut in [0, 10, HEADER_LEN - 1, HEADER_LEN + 5, bytes.len() - 1] {
            match decode(&bytes[..cut]) {
                Err(ServeError::Corrupt(msg)) => {
                    assert!(
                        msg.contains("truncated") || msg.contains("length mismatch"),
                        "cut={cut}: {msg}"
                    );
                }
                other => panic!("cut={cut}: expected Corrupt, got {other:?}"),
            }
        }
    }

    #[test]
    fn flipped_version_byte_is_typed() {
        let mut bytes = PolicyArtifact::from_outcome(&solved()).encode();
        bytes[4] ^= 0xFF;
        match decode(&bytes) {
            Err(ServeError::BadVersion { found, expected }) => {
                assert_eq!(expected, VERSION);
                assert_ne!(found, VERSION);
            }
            other => panic!("expected BadVersion, got {other:?}"),
        }
    }

    #[test]
    fn bad_magic_is_typed() {
        let mut bytes = PolicyArtifact::from_outcome(&solved()).encode();
        bytes[0] = b'X';
        assert!(matches!(decode(&bytes), Err(ServeError::Corrupt(_))));
    }

    #[test]
    fn payload_flip_is_caught_by_digest() {
        let mut bytes = PolicyArtifact::from_outcome(&solved()).encode();
        bytes[HEADER_LEN + 3] ^= 0x40; // inside the value vector
        match decode(&bytes) {
            Err(ServeError::Corrupt(msg)) => assert!(msg.contains("digest"), "{msg}"),
            other => panic!("expected digest Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn header_gamma_flip_is_caught_by_cross_check() {
        let mut bytes = PolicyArtifact::from_outcome(&solved()).encode();
        bytes[33] ^= 0x01; // inside the header gamma field
        match decode(&bytes) {
            Err(ServeError::Corrupt(msg)) => assert!(msg.contains("gamma"), "{msg}"),
            other => panic!("expected gamma Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn out_of_range_policy_is_typed() {
        // Hand-build an outcome whose policy is internally inconsistent:
        // digests then match the bad payload, so the range check must fire.
        let mut outcome = solved();
        outcome.result.policy[0] = 7; // n_actions is 2
        let bytes = PolicyArtifact::from_outcome(&outcome).encode();
        match decode(&bytes) {
            Err(ServeError::Corrupt(msg)) => assert!(msg.contains("out of range"), "{msg}"),
            other => panic!("expected range Corrupt, got {other:?}"),
        }
    }
}
