//! Deterministic solve fingerprints (FNV-1a over canonical JSON).
//!
//! A policy artifact is keyed by a fingerprint of *what was solved*: the
//! model shape, the resolved solver configuration, and digests of the
//! result payload itself. Two requirements drive the construction:
//!
//! 1. **Byte stability.** The fingerprint hashes the compact serialization
//!    of a canonical JSON document. [`crate::util::json::Json`] objects are
//!    `BTreeMap`s, so keys serialize in sorted (lexicographic) order at
//!    every nesting level and the bytes cannot drift between runs — the
//!    same property that makes `write_json_metadata` golden-testable.
//! 2. **Execution-shape independence.** `ranks`, `threads` and the
//!    communication-overlap mode are deliberately *excluded*: the solver's
//!    determinism suite (`tests/par_determinism.rs`) pins results bitwise
//!    identical across all of them, so a policy solved on 4 ranks must be
//!    served under the same key as the single-rank solve.
//!
//! The hash is 64-bit FNV-1a — self-contained (no crates), stable across
//! platforms, and collision-resistant enough for a cache key that is *also*
//! verified: the store re-derives payload digests on every decode, so a
//! colliding-but-different artifact is rejected as corrupt rather than
//! silently served.

/// 64-bit FNV-1a over a byte slice.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut hash: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x100000001b3);
    }
    hash
}

/// 64-bit FNV-1a over the little-endian bytes of an `f64` slice (bitwise:
/// `-0.0` and `0.0` hash differently, NaN payloads are preserved).
pub fn fnv1a64_f64s(xs: &[f64]) -> u64 {
    let mut hash: u64 = 0xcbf29ce484222325;
    for &x in xs {
        for b in x.to_le_bytes() {
            hash ^= b as u64;
            hash = hash.wrapping_mul(0x100000001b3);
        }
    }
    hash
}

/// 64-bit FNV-1a over a usize slice, encoded as little-endian u64.
pub fn fnv1a64_usizes(xs: &[usize]) -> u64 {
    let mut hash: u64 = 0xcbf29ce484222325;
    for &x in xs {
        for b in (x as u64).to_le_bytes() {
            hash ^= b as u64;
            hash = hash.wrapping_mul(0x100000001b3);
        }
    }
    hash
}

/// Canonical 16-hex-digit spelling of a fingerprint hash — the artifact
/// key used by sinks, caches, and the serve protocol.
pub fn hex16(hash: u64) -> String {
    format!("{hash:016x}")
}

/// Parse the canonical 16-hex-digit fingerprint spelling back to the hash.
pub fn parse_hex16(s: &str) -> Option<u64> {
    if s.len() != 16 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_reference_vectors() {
        // Published FNV-1a test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn f64_hash_is_bitwise() {
        assert_ne!(fnv1a64_f64s(&[0.0]), fnv1a64_f64s(&[-0.0]));
        assert_eq!(fnv1a64_f64s(&[1.5, 2.5]), fnv1a64_f64s(&[1.5, 2.5]));
        assert_ne!(fnv1a64_f64s(&[1.5, 2.5]), fnv1a64_f64s(&[2.5, 1.5]));
        // matches the byte-level hash of the same encoding
        let xs = [3.141592653589793, -7.25];
        let mut bytes = Vec::new();
        for x in xs {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        assert_eq!(fnv1a64_f64s(&xs), fnv1a64(&bytes));
    }

    #[test]
    fn usize_hash_matches_u64_le_bytes() {
        let xs = [0usize, 1, 42, 1 << 40];
        let mut bytes = Vec::new();
        for &x in &xs {
            bytes.extend_from_slice(&(x as u64).to_le_bytes());
        }
        assert_eq!(fnv1a64_usizes(&xs), fnv1a64(&bytes));
    }

    #[test]
    fn hex16_roundtrip() {
        for h in [0u64, 1, 0xdeadbeef, u64::MAX, 0x0123456789abcdef] {
            let s = hex16(h);
            assert_eq!(s.len(), 16);
            assert_eq!(parse_hex16(&s), Some(h));
        }
        assert_eq!(parse_hex16("xyz"), None);
        assert_eq!(parse_hex16("0123456789abcde"), None); // 15 chars
        assert_eq!(parse_hex16("0123456789abcdeg"), None);
    }
}
