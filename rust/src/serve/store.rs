//! [`PolicyStore`] — pluggable artifact persistence with a sharded LRU of
//! decoded artifacts in front.
//!
//! The store is a three-part split (the shape object stores converge on):
//!
//! - **Sink** ([`ArtifactSink`]): a key → bytes map. Backends move
//!   *encoded* artifact bytes only, so every backend exercises the one
//!   codec (`crate::serve::codec`) — an S3-style object sink later is a
//!   third impl of this trait, nothing more.
//! - **Codec**: encode on `put`, decode + full validation on every cache
//!   miss. Corruption in a sink therefore surfaces as a typed
//!   [`ServeError`] at read time, never as a silently served stale policy.
//! - **Cache**: a [`ShardedLru`] of decoded [`PolicyArtifact`]s keyed by
//!   fingerprint, so hot policies skip both the sink and the decode. The
//!   capacity is the `-serve_cache_entries` knob (0 disables caching
//!   entirely; the cache never exceeds its bound — pinned by the serving
//!   soak test).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::api::SolveOutcome;
use crate::util::lru::ShardedLru;

use super::codec::{self, PolicyArtifact};
use super::ServeError;

/// File extension of on-disk artifacts.
pub const ARTIFACT_EXT: &str = "mdpa";

/// Number of LRU shards the store puts in front of a sink. Sized for
/// single-digit client thread counts; contention only occurs on same-shard
/// keys.
const CACHE_SHARDS: usize = 8;

/// A key → encoded-artifact-bytes backend. Implementations must be cheap
/// to share across client threads (`Send + Sync`); all validation lives
/// above the sink, in the codec.
pub trait ArtifactSink: Send + Sync {
    /// Store `bytes` under `key`, replacing any previous artifact.
    fn put(&self, key: &str, bytes: &[u8]) -> Result<(), ServeError>;
    /// The bytes under `key`, or `None` if nothing is stored there.
    fn get(&self, key: &str) -> Result<Option<Vec<u8>>, ServeError>;
    /// Every key currently stored, sorted.
    fn keys(&self) -> Result<Vec<String>, ServeError>;
    /// Short backend name for logs and bench labels (`"memory"`, `"dir"`).
    fn kind(&self) -> &'static str;
}

/// In-memory sink: a mutex-guarded map of encoded bytes. Holding *encoded*
/// bytes (rather than decoded artifacts) is deliberate — the memory
/// backend round-trips through the same codec as the disk backend, so the
/// acceptance tests exercise one serde path under both.
#[derive(Default)]
pub struct MemorySink {
    map: Mutex<BTreeMap<String, Vec<u8>>>,
}

impl MemorySink {
    /// Empty in-memory sink.
    pub fn new() -> MemorySink {
        MemorySink::default()
    }
}

impl ArtifactSink for MemorySink {
    fn put(&self, key: &str, bytes: &[u8]) -> Result<(), ServeError> {
        validate_key(key)?;
        self.map
            .lock()
            .expect("memory sink poisoned")
            .insert(key.to_string(), bytes.to_vec());
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Option<Vec<u8>>, ServeError> {
        validate_key(key)?;
        Ok(self
            .map
            .lock()
            .expect("memory sink poisoned")
            .get(key)
            .cloned())
    }

    fn keys(&self) -> Result<Vec<String>, ServeError> {
        Ok(self
            .map
            .lock()
            .expect("memory sink poisoned")
            .keys()
            .cloned()
            .collect())
    }

    fn kind(&self) -> &'static str {
        "memory"
    }
}

/// On-disk sink: one `<fingerprint>.mdpa` file per artifact in a flat
/// directory. Writes go through a unique temp file + rename, so a reader
/// never observes a half-written artifact on POSIX filesystems.
pub struct DirSink {
    dir: PathBuf,
}

impl DirSink {
    /// Sink over `dir`, creating the directory if needed.
    pub fn new(dir: impl AsRef<Path>) -> Result<DirSink, ServeError> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .map_err(|e| ServeError::Io(format!("creating {}: {e}", dir.display())))?;
        Ok(DirSink { dir })
    }

    fn path_of(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.{ARTIFACT_EXT}"))
    }
}

impl ArtifactSink for DirSink {
    fn put(&self, key: &str, bytes: &[u8]) -> Result<(), ServeError> {
        validate_key(key)?;
        let path = self.path_of(key);
        let tmp = self.dir.join(format!(".{key}.{}.tmp", std::process::id()));
        std::fs::write(&tmp, bytes)
            .map_err(|e| ServeError::Io(format!("writing {}: {e}", tmp.display())))?;
        std::fs::rename(&tmp, &path)
            .map_err(|e| ServeError::Io(format!("renaming into {}: {e}", path.display())))?;
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Option<Vec<u8>>, ServeError> {
        validate_key(key)?;
        let path = self.path_of(key);
        match std::fs::read(&path) {
            Ok(bytes) => Ok(Some(bytes)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(ServeError::Io(format!("reading {}: {e}", path.display()))),
        }
    }

    fn keys(&self) -> Result<Vec<String>, ServeError> {
        let mut keys = Vec::new();
        let entries = std::fs::read_dir(&self.dir)
            .map_err(|e| ServeError::Io(format!("listing {}: {e}", self.dir.display())))?;
        for entry in entries {
            let entry =
                entry.map_err(|e| ServeError::Io(format!("listing {}: {e}", self.dir.display())))?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(stem) = name.strip_suffix(&format!(".{ARTIFACT_EXT}")) {
                if validate_key(stem).is_ok() {
                    keys.push(stem.to_string());
                }
            }
        }
        keys.sort_unstable();
        Ok(keys)
    }

    fn kind(&self) -> &'static str {
        "dir"
    }
}

/// Keys are fingerprints: non-empty ASCII alphanumerics only. Anything
/// else is rejected before it can touch a path.
fn validate_key(key: &str) -> Result<(), ServeError> {
    if key.is_empty() || !key.bytes().all(|b| b.is_ascii_alphanumeric()) {
        return Err(ServeError::BadRequest(format!(
            "invalid artifact key '{key}' (fingerprints are ASCII alphanumeric)"
        )));
    }
    Ok(())
}

/// The policy store: a sink backend behind a sharded LRU of decoded
/// artifacts. Shared across client threads by reference (all methods take
/// `&self`).
pub struct PolicyStore {
    sink: Box<dyn ArtifactSink>,
    cache: ShardedLru<String, Arc<PolicyArtifact>>,
}

impl PolicyStore {
    /// Store over any sink with an LRU holding up to `cache_entries`
    /// decoded artifacts (0 disables caching; `usize::MAX` is effectively
    /// unbounded).
    pub fn with_sink(sink: Box<dyn ArtifactSink>, cache_entries: usize) -> PolicyStore {
        PolicyStore {
            sink,
            cache: ShardedLru::new(cache_entries, CACHE_SHARDS),
        }
    }

    /// Store over an in-memory sink.
    pub fn in_memory(cache_entries: usize) -> PolicyStore {
        PolicyStore::with_sink(Box::new(MemorySink::new()), cache_entries)
    }

    /// Store over an on-disk directory sink (created if needed).
    pub fn on_disk(dir: impl AsRef<Path>, cache_entries: usize) -> Result<PolicyStore, ServeError> {
        Ok(PolicyStore::with_sink(
            Box::new(DirSink::new(dir)?),
            cache_entries,
        ))
    }

    /// Persist a solve outcome; returns its fingerprint key. The encoded
    /// bytes go to the sink and the decoded artifact is installed in the
    /// cache (a solve-then-serve process answers its first queries
    /// without re-reading the sink).
    pub fn put_outcome(&self, outcome: &SolveOutcome) -> Result<String, ServeError> {
        let artifact = PolicyArtifact::from_outcome(outcome);
        self.put_artifact(artifact)
    }

    /// Persist an already-built artifact; returns its fingerprint key.
    pub fn put_artifact(&self, artifact: PolicyArtifact) -> Result<String, ServeError> {
        let key = artifact.fingerprint_hex();
        self.sink.put(&key, &artifact.encode())?;
        self.cache.put(key.clone(), Arc::new(artifact));
        Ok(key)
    }

    /// Fetch the artifact stored under `fingerprint`: cache hit, or sink
    /// read + decode + validation (including that the artifact actually
    /// carries the requested fingerprint — a renamed file is a typed
    /// [`ServeError::FingerprintMismatch`], not a silent stale serve).
    pub fn get(&self, fingerprint: &str) -> Result<Arc<PolicyArtifact>, ServeError> {
        let key = fingerprint.to_string();
        if let Some(hit) = self.cache.get(&key) {
            return Ok(hit);
        }
        let bytes = self
            .sink
            .get(fingerprint)?
            .ok_or_else(|| ServeError::NotFound(fingerprint.to_string()))?;
        let artifact = codec::decode(&bytes)?;
        if artifact.fingerprint_hex() != fingerprint {
            return Err(ServeError::FingerprintMismatch {
                requested: fingerprint.to_string(),
                found: artifact.fingerprint_hex(),
            });
        }
        let artifact = Arc::new(artifact);
        self.cache.put(key, Arc::clone(&artifact));
        Ok(artifact)
    }

    /// Every fingerprint the sink currently holds, sorted.
    pub fn keys(&self) -> Result<Vec<String>, ServeError> {
        self.sink.keys()
    }

    /// Backend name of the underlying sink (`"memory"`, `"dir"`).
    pub fn kind(&self) -> &'static str {
        self.sink.kind()
    }

    /// Decoded artifacts currently cached (always `<=` [`Self::cache_capacity`]).
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Configured cache bound (`-serve_cache_entries`).
    pub fn cache_capacity(&self) -> usize {
        self.cache.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{MdpBuilder, Solver};

    fn solved(gamma: f64) -> SolveOutcome {
        let builder = MdpBuilder::from_fillers(
            4,
            2,
            |s, a| if a == 0 { vec![(s, 1.0)] } else { vec![(0, 1.0)] },
            |s, a| if a == 0 { s as f64 * 0.25 } else { 1.0 },
        )
        .gamma(gamma);
        Solver::new(builder).solve().unwrap()
    }

    #[test]
    fn memory_roundtrip_and_keys() {
        let store = PolicyStore::in_memory(4);
        let a = store.put_outcome(&solved(0.5)).unwrap();
        let b = store.put_outcome(&solved(0.75)).unwrap();
        assert_ne!(a, b);
        assert_eq!(store.keys().unwrap(), {
            let mut ks = vec![a.clone(), b.clone()];
            ks.sort();
            ks
        });
        assert_eq!(store.get(&a).unwrap().fingerprint_hex(), a);
        assert_eq!(store.kind(), "memory");
    }

    #[test]
    fn missing_key_is_not_found() {
        let store = PolicyStore::in_memory(4);
        match store.get("0123456789abcdef") {
            Err(ServeError::NotFound(fp)) => assert_eq!(fp, "0123456789abcdef"),
            other => panic!("expected NotFound, got {other:?}"),
        }
    }

    #[test]
    fn invalid_keys_rejected() {
        let store = PolicyStore::in_memory(4);
        for bad in ["", "../etc/passwd", "a/b", "key with space"] {
            assert!(
                matches!(store.get(bad), Err(ServeError::BadRequest(_))),
                "{bad}"
            );
        }
    }

    #[test]
    fn zero_cache_still_serves() {
        let store = PolicyStore::in_memory(0);
        let fp = store.put_outcome(&solved(0.5)).unwrap();
        assert_eq!(store.cache_len(), 0);
        let art = store.get(&fp).unwrap(); // pure sink+decode path
        assert_eq!(art.fingerprint_hex(), fp);
        assert_eq!(store.cache_len(), 0);
        assert_eq!(store.cache_capacity(), 0);
    }

    #[test]
    fn renamed_artifact_is_fingerprint_mismatch() {
        // store valid bytes under the *wrong* key via the raw sink
        let sink = MemorySink::new();
        let outcome = solved(0.5);
        let artifact = super::PolicyArtifact::from_outcome(&outcome);
        let real = artifact.fingerprint_hex();
        let wrong = "00000000000000aa";
        assert_ne!(real, wrong);
        sink.put(wrong, &artifact.encode()).unwrap();
        let store = PolicyStore::with_sink(Box::new(sink), 4);
        match store.get(wrong) {
            Err(ServeError::FingerprintMismatch { requested, found }) => {
                assert_eq!(requested, wrong);
                assert_eq!(found, real);
            }
            other => panic!("expected FingerprintMismatch, got {other:?}"),
        }
    }

    #[test]
    fn cache_hit_skips_sink_corruption() {
        // A cached artifact keeps serving even if the sink is later
        // corrupted; evicting (cache size 0 here by using a fresh store)
        // surfaces the corruption as a typed error.
        let outcome = solved(0.5);
        let artifact = super::PolicyArtifact::from_outcome(&outcome);
        let fp = artifact.fingerprint_hex();
        let store = PolicyStore::in_memory(4);
        store.put_artifact(artifact.clone()).unwrap();
        assert!(store.get(&fp).is_ok());
        // corrupt the sink copy underneath the cache
        let mut bytes = artifact.encode();
        bytes[70] ^= 0xFF;
        // same store: cache still hits
        store.sink.put(&fp, &bytes).unwrap();
        assert!(store.get(&fp).is_ok(), "cache hit serves");
        // fresh store over the same (corrupt) bytes: typed error
        let sink = MemorySink::new();
        sink.put(&fp, &bytes).unwrap();
        let fresh = PolicyStore::with_sink(Box::new(sink), 4);
        assert!(matches!(fresh.get(&fp), Err(ServeError::Corrupt(_))));
    }
}
