//! Inexact policy iteration — the outer solver family (the paper's core).
//!
//! madupite's central algorithm is iPI (Gargiani et al. 2024, Alg. 3):
//! alternate a greedy policy improvement with an *inexact* policy
//! evaluation whose accuracy is tied to the current Bellman residual
//! through a forcing term α. The classical methods fall out as presets
//! (paper claim C1):
//!
//! | preset        | evaluation step                                  |
//! |---------------|--------------------------------------------------|
//! | [`Method::Vi`]        | none — `V ← TV`                          |
//! | [`Method::Mpi`]       | `k` fixed Richardson sweeps of `T_π`     |
//! | [`Method::ExactPi`]   | direct dense solve of `(I−γP_π)V = g_π`  |
//! | [`Method::Ipi`]       | Krylov solve to `‖res‖ ≤ α·‖TV − V‖∞`   |
//!
//! The solver is fully distributed: every step works on the rank-local
//! blocks and communicates only through [`crate::comm`] collectives and the
//! ghost plans baked into the matrices.

use crate::comm::{Comm, World};
use crate::ksp::precond::PcType;
use crate::ksp::{self, Apply, KspType, LinOp, Precond, Tolerance};
use crate::mdp::{BsrPolicyOp, DistMdp, F32PolicyOp, MatFreePolicyOp, Mdp};
use crate::util::json::Json;
use std::sync::Arc;
use std::time::Instant;

/// Outer solution method (madupite's `-mode` / `-ksp_type` combination).
#[derive(Clone, Debug, PartialEq)]
pub enum Method {
    /// Value iteration.
    Vi,
    /// Modified policy iteration with a fixed number of `T_π` sweeps.
    Mpi { sweeps: usize },
    /// Exact policy iteration (gathered dense LU — small MDPs only).
    ExactPi,
    /// Inexact policy iteration with the given inner solver.
    Ipi { ksp: KspType, pc: PcType },
}

impl Method {
    /// iPI with GMRES(30), no preconditioner — madupite's workhorse setup.
    pub fn ipi_gmres() -> Method {
        Method::Ipi {
            ksp: KspType::Gmres { restart: 30 },
            pc: PcType::None,
        }
    }

    /// iPI with BiCGStab, no preconditioner.
    pub fn ipi_bicgstab() -> Method {
        Method::Ipi {
            ksp: KspType::BiCgStab,
            pc: PcType::None,
        }
    }

    /// iPI with TFQMR, no preconditioner.
    pub fn ipi_tfqmr() -> Method {
        Method::Ipi {
            ksp: KspType::Tfqmr,
            pc: PcType::None,
        }
    }

    /// Canonical display name (`vi`, `mpi(k)`, `pi-exact`, `ipi(gmres)`, ...).
    pub fn name(&self) -> String {
        match self {
            Method::Vi => "vi".to_string(),
            Method::Mpi { sweeps } => format!("mpi({sweeps})"),
            Method::ExactPi => "pi-exact".to_string(),
            Method::Ipi { ksp, pc } => {
                if *pc == PcType::None {
                    format!("ipi({})", ksp.name())
                } else {
                    format!("ipi({}+{})", ksp.name(), pc.name())
                }
            }
        }
    }
}

/// How the policy-evaluation operator `I − γ P_π` is realized
/// (`-eval_backend`, DESIGN.md §4).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum EvalBackend {
    /// Apply the operator straight off the stacked `(n·m)×n` transition
    /// kernel by indexing rows `s·m + π(s)` — no `P_π` copy in memory, no
    /// per-policy-change assembly ([`MatFreePolicyOp`]). The default.
    #[default]
    MatFree,
    /// Materialize `P_π` as a distributed CSR (with its own tighter ghost
    /// plan) and cache it across outer iterations while the greedy policy
    /// is unchanged ([`LinOp`] over [`DistMdp::policy_system`]).
    Assembled,
    /// Repack the selected policy rows into 1×LANES column blocks for
    /// lane-parallel applies ([`BsrPolicyOp`]); falls back to the gather
    /// kernel per-matrix when the block fill ratio is too low
    /// (DESIGN.md §13).
    Bsr,
}

impl EvalBackend {
    /// Parse the `-eval_backend` option string.
    pub fn parse(name: &str) -> Result<EvalBackend, String> {
        Ok(match name {
            "matfree" | "matrix-free" | "mat_free" => EvalBackend::MatFree,
            "assembled" | "explicit" => EvalBackend::Assembled,
            "bsr" | "blocked" => EvalBackend::Bsr,
            other => return Err(format!("unknown eval_backend '{other}'")),
        })
    }

    /// Canonical option-string form (inverse of [`Self::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            EvalBackend::MatFree => "matfree",
            EvalBackend::Assembled => "assembled",
            EvalBackend::Bsr => "bsr",
        }
    }
}

/// Arithmetic precision of the inner KSP iterations (`-inner_precision`,
/// DESIGN.md §13). Only the iPI evaluation step is affected; Bellman
/// backups, the outer residual, and the convergence certificate always
/// run in f64.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum InnerPrecision {
    /// Full double precision everywhere (the default).
    #[default]
    F64,
    /// Inner Krylov iterations on an f32/u32 copy of the policy operator
    /// ([`F32PolicyOp`]) inside an f64 iterative-refinement loop
    /// ([`ksp::mixed`]) — half the memory traffic on the dominant kernel,
    /// same f64 outer tolerance.
    F32,
}

impl InnerPrecision {
    /// Parse the `-inner_precision` option string.
    pub fn parse(name: &str) -> Result<InnerPrecision, String> {
        Ok(match name {
            "f64" | "double" => InnerPrecision::F64,
            "f32" | "single" | "mixed" => InnerPrecision::F32,
            other => return Err(format!("unknown inner_precision '{other}'")),
        })
    }

    /// Canonical option-string form (inverse of [`Self::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            InnerPrecision::F64 => "f64",
            InnerPrecision::F32 => "f32",
        }
    }
}

/// Solver options (madupite's options database, DESIGN §4).
#[derive(Clone, Debug)]
pub struct SolveOptions {
    /// Outer solution method (`-method` + inner-solver options).
    pub method: Method,
    /// Operator realization for the evaluation step (`-eval_backend`).
    pub eval_backend: EvalBackend,
    /// Precision of the inner KSP iterations (`-inner_precision`): `F32`
    /// runs them on a compressed operator copy inside an f64 refinement
    /// loop. iPI only; other methods always evaluate in f64.
    pub inner_precision: InnerPrecision,
    /// Outer stop: ‖TV − V‖∞ < `atol`.
    pub atol: f64,
    /// Outer iteration cap (`-max_iter_pi`).
    pub max_outer: usize,
    /// Forcing term α: inner solve targets `α · ‖TV − V‖∞` (`-alpha`).
    pub alpha: f64,
    /// Eisenstat–Walker-style adaptive forcing: α_k scales with the square
    /// of the outer residual contraction, clamped to [α, 0.1]. Spends inner
    /// iterations only when the outer iteration is actually converging —
    /// the "adaptive inexactness" extension of the iPI paper.
    pub adaptive_forcing: bool,
    /// Inner iteration cap (`-max_iter_ksp`).
    pub max_inner: usize,
    /// Initial value vector (defaults to zeros).
    pub v0: Option<Vec<f64>>,
    /// Per-iteration residual logging on the root rank (`-verbose`).
    pub verbose: bool,
    /// Bounded-staleness asynchronous value iteration (`-async_vi`,
    /// DESIGN.md §14): between synchronized Bellman backups every rank runs
    /// [`DistMdp::bellman_backup_local`] sweeps against the ghost values of
    /// the last synchronization. Convergence is still decided only on the
    /// collectively reduced residual of the synchronized backup, so the
    /// certificate is rank-identical. Only meaningful with [`Method::Vi`]
    /// (the options layer rejects other methods); ignored by evaluation
    /// methods here.
    pub async_vi: bool,
    /// Staleness bound `k` for `-async_vi`: ghosts are refreshed every `k`
    /// Bellman sweeps (1 synchronized + `k−1` local). `k = 1` degenerates
    /// to synchronous VI with identical iterates.
    pub async_vi_staleness: usize,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            method: Method::ipi_gmres(),
            eval_backend: EvalBackend::MatFree,
            inner_precision: InnerPrecision::F64,
            atol: 1e-8,
            max_outer: 1_000,
            alpha: 1e-4,
            adaptive_forcing: false,
            max_inner: 10_000,
            v0: None,
            verbose: false,
            async_vi: false,
            async_vi_staleness: 4,
        }
    }
}

/// Per-outer-iteration record (the convergence trace the experiments plot).
#[derive(Clone, Debug)]
pub struct IterRecord {
    /// Outer iteration index.
    pub outer: usize,
    /// ‖TV − V‖∞ *before* this iteration's evaluation step.
    pub residual: f64,
    /// Inner (KSP) iterations spent in this outer iteration.
    pub inner_iterations: usize,
    /// Operator applications in this outer iteration (incl. the backup).
    pub spmvs: usize,
    /// Wall time since solve start, seconds.
    pub elapsed_s: f64,
}

/// Result of a solve (global quantities gathered on every rank).
#[derive(Clone, Debug)]
pub struct SolveResult {
    /// Optimal value vector V* (global).
    pub value: Vec<f64>,
    /// Greedy/optimal policy π* (global, one action index per state).
    pub policy: Vec<usize>,
    /// Outer iterations executed.
    pub outer_iterations: usize,
    /// Total operator applications across outer + inner work.
    pub total_spmvs: usize,
    /// Total inner (KSP) iterations across all outer iterations.
    pub total_inner_iterations: usize,
    /// Final ∞-norm Bellman residual ‖TV − V‖∞.
    pub residual: f64,
    /// Whether the residual dropped below `atol`.
    pub converged: bool,
    /// Wall time of the solve, seconds.
    pub wall_time_s: f64,
    /// Per-outer-iteration convergence trace.
    pub trace: Vec<IterRecord>,
    /// Total communication volume (bytes, summed over ranks) during the
    /// solve itself — model distribution/assembly and result gathering are
    /// excluded (counters are snapshotted at `solve_dist` entry and exit).
    pub comm_bytes: u64,
    /// Time spent inside communication calls during the solve (µs, summed
    /// over ranks): barrier waits, collective rendezvous epochs, and
    /// blocking receives. Like [`Self::wall_time_s`] this is a timing
    /// diagnostic — approximate at the µs scale and not bitwise
    /// rank-identical, so it is excluded from determinism fingerprints.
    pub comm_time_us: u64,
    /// Uniform discount bound γ̄ = max γ(s,a) of the solved MDP — equal to
    /// the discount factor for classic scalar-discount MDPs; for semi-MDPs
    /// it is the contraction modulus used by the certificate below.
    pub gamma: f64,
    /// World size (SPMD ranks) the solve ran on.
    pub ranks: usize,
    /// Intra-rank worker threads per rank during the solve (`-threads`) —
    /// together with [`Self::ranks`] this is the hybrid `ranks × threads`
    /// execution shape (DESIGN.md §11). Thread count never changes the
    /// numbers, only the wall time.
    pub threads: usize,
}

impl SolveResult {
    /// Certified sup-norm suboptimality bound from the contraction
    /// argument: `‖V − V*‖∞ ≤ ‖TV − V‖∞ / (1 − γ̄)` (the returned iterate
    /// is the *pre-backup* V, so the bound uses 1/(1−γ̄), not γ̄/(1−γ̄)).
    /// `γ̄ = max γ(s,a)` is the contraction modulus of the generalized
    /// Bellman operator, so the certificate holds for semi-MDPs too.
    pub fn error_bound(&self) -> f64 {
        self.residual / (1.0 - self.gamma)
    }

    /// JSON report (EXPERIMENTS.md tables are generated from these).
    pub fn to_json(&self, label: &str) -> Json {
        Json::obj(vec![
            ("label", Json::str(label)),
            ("outer_iterations", Json::int(self.outer_iterations as i64)),
            ("total_spmvs", Json::int(self.total_spmvs as i64)),
            (
                "total_inner_iterations",
                Json::int(self.total_inner_iterations as i64),
            ),
            ("residual", Json::num(self.residual)),
            ("converged", Json::Bool(self.converged)),
            ("wall_time_s", Json::num(self.wall_time_s)),
            ("comm_bytes", Json::int(self.comm_bytes as i64)),
            ("comm_time_us", Json::int(self.comm_time_us as i64)),
            ("ranks", Json::int(self.ranks as i64)),
            ("threads", Json::int(self.threads as i64)),
            ("error_bound", Json::num(self.error_bound())),
            (
                "residual_trace",
                Json::nums(&self.trace.iter().map(|r| r.residual).collect::<Vec<_>>()),
            ),
        ])
    }
}

/// Rank-local result (before gathering).
pub struct LocalSolveResult {
    /// Rank-local block of the value vector.
    pub value: Vec<f64>,
    /// Rank-local block of the greedy policy.
    pub policy: Vec<usize>,
    /// Uniform discount bound γ̄ of the solved MDP (scalar γ for classic
    /// MDPs).
    pub gamma: f64,
    /// Outer iterations executed.
    pub outer_iterations: usize,
    /// Total operator applications across outer + inner work.
    pub total_spmvs: usize,
    /// Total inner (KSP) iterations.
    pub total_inner_iterations: usize,
    /// Final ∞-norm Bellman residual (global).
    pub residual: f64,
    /// Whether the residual dropped below `atol`.
    pub converged: bool,
    /// Wall time of the solve, seconds.
    pub wall_time_s: f64,
    /// Per-outer-iteration convergence trace.
    pub trace: Vec<IterRecord>,
    /// Global communication bytes counted between solve entry and exit.
    pub comm_bytes: u64,
    /// Time inside communication calls between solve entry and exit (µs,
    /// summed over ranks; approximate — see [`SolveResult::comm_time_us`]).
    pub comm_time_us: u64,
}

/// Solve a distributed MDP in-world. Collective; every rank receives its
/// local blocks of V* and π*.
pub fn solve_dist(comm: &Comm, mdp: &DistMdp, opts: &SolveOptions) -> LocalSolveResult {
    // Snapshot the (world-shared) comm counters so the result reports the
    // bytes of *this solve*, not everything since world start (model
    // distribution, assembly, earlier solves). The leading barrier makes
    // the snapshot complete: every rank counts an op before entering the
    // next collective, so once all ranks reach it, no pre-solve bytes are
    // missing. The *trailing* barrier makes it rank-identical: split-phase
    // ghost sends are point-to-point and count on the sender immediately
    // (no rendezvous), so without it a fast rank could start the first
    // exchange before a slow rank has read the counters.
    comm.barrier();
    let start_stats = comm.stats().snapshot();
    comm.barrier();
    let start = Instant::now();
    let nl = mdp.local_states();
    let part = mdp.partition();
    let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));

    let mut v: Vec<f64> = match &opts.v0 {
        Some(v0) => {
            assert_eq!(v0.len(), mdp.n_states(), "v0 must be the global vector");
            v0[lo..hi].to_vec()
        }
        None => vec![0.0; nl],
    };
    let mut tv = vec![0.0; nl];
    let mut policy = vec![0usize; nl];
    let mut buf = mdp.make_buffer();
    let mut q_scratch = Vec::new();

    let mut trace: Vec<IterRecord> = Vec::new();
    let mut total_spmvs = 0usize;
    let mut total_inner = 0usize;
    let mut residual = f64::INFINITY;
    let mut converged = false;
    // Policy-system cache: rebuilding P_π (ghost plan + CSR assembly) is a
    // large fixed cost per outer iteration; when the greedy policy did not
    // change we reuse the previous system (common near convergence and in
    // wavefront-style problems like mazes). For semi-MDPs the per-state
    // policy discounts γ_π ride along (None under scalar discounting).
    let mut prev_policy: Vec<usize> = Vec::new();
    #[allow(clippy::type_complexity)]
    let mut cached_system: Option<(crate::linalg::dist::DistCsr, Vec<f64>, Option<Vec<f64>>)> =
        None;
    let mut prev_residual = f64::INFINITY;

    for outer in 0..opts.max_outer {
        // -- policy improvement + residual ---------------------------------
        residual = mdp.bellman_backup(comm, &v, &mut tv, &mut policy, &mut buf, &mut q_scratch);
        total_spmvs += 1;
        if opts.verbose && comm.is_root() {
            eprintln!(
                "[{}] outer {:4}  residual {:.3e}",
                opts.method.name(),
                outer,
                residual
            );
        }
        if residual < opts.atol {
            converged = true;
            trace.push(IterRecord {
                outer,
                residual,
                inner_iterations: 0,
                spmvs: 1,
                elapsed_s: start.elapsed().as_secs_f64(),
            });
            break;
        }

        // -- (inexact) policy evaluation ------------------------------------
        // The Assembled backend materializes + caches P_π; refresh it when
        // the greedy policy changed on any rank (collective decision so
        // every rank rebuilds together). MatFree needs no assembly at all.
        let needs_eval = !matches!(opts.method, Method::Vi);
        if needs_eval && opts.eval_backend == EvalBackend::Assembled {
            let changed_local = prev_policy != policy;
            let changed = comm.max(if changed_local { 1.0 } else { 0.0 }) > 0.0;
            if changed || cached_system.is_none() {
                let (p_pi, g) = mdp.policy_system(comm, &policy);
                cached_system = Some((p_pi, g, mdp.policy_discounts(&policy)));
                prev_policy.clear();
                prev_policy.extend_from_slice(&policy);
            }
        }
        let (inner_iters, inner_spmvs) = if !needs_eval {
            v.copy_from_slice(&tv);
            if opts.async_vi {
                // Bounded-staleness sweeps (DESIGN.md §14): `buf` still
                // holds the ghosts exchanged by the synchronized backup
                // above, so each rank advances its own block k−1 more times
                // against that frozen boundary data — no communication at
                // all between synchronizations. Every rank runs the same
                // agreed sweep count, so traces and counters stay
                // rank-identical even though the iterates are not the
                // synchronous ones.
                let sweeps = opts.async_vi_staleness.max(1) - 1;
                for _ in 0..sweeps {
                    mdp.bellman_backup_local(&v, &mut tv, &mut policy, &mut buf, &mut q_scratch);
                    v.copy_from_slice(&tv);
                }
                (sweeps, sweeps)
            } else {
                (0, 0)
            }
        } else {
            // Realize the evaluation operator + RHS for the configured
            // backend; every method below sees only `&dyn Apply`.
            let mf_op: MatFreePolicyOp<'_>;
            let bsr_op: BsrPolicyOp<'_>;
            let mf_g: Vec<f64>;
            let asm_op: LinOp<'_>;
            let (a, g_pi): (&dyn Apply, &[f64]) = match opts.eval_backend {
                EvalBackend::MatFree => {
                    mf_g = mdp.policy_costs(&policy);
                    mf_op = MatFreePolicyOp::new(mdp, &policy);
                    (&mf_op, &mf_g)
                }
                EvalBackend::Bsr => {
                    mf_g = mdp.policy_costs(&policy);
                    bsr_op = BsrPolicyOp::new(mdp, &policy);
                    (&bsr_op, &mf_g)
                }
                EvalBackend::Assembled => {
                    let (p_pi, g, gammas) = cached_system.as_ref().unwrap();
                    asm_op = match gammas {
                        // Semi-MDP: the assembled system is I − diag(γ_π) P_π.
                        Some(gp) => LinOp::with_row_discounts(p_pi, gp),
                        None => LinOp::new(p_pi, mdp.gamma()),
                    };
                    (&asm_op, g.as_slice())
                }
            };
            match &opts.method {
                Method::Vi => unreachable!("handled by needs_eval"),
                Method::Mpi { sweeps } => {
                    // start the sweeps from TV (the Puterman mPI definition)
                    v.copy_from_slice(&tv);
                    let stats = ksp::richardson::fixed_sweeps(comm, a, g_pi, &mut v, *sweeps);
                    (stats.iterations, stats.spmvs)
                }
                Method::ExactPi => {
                    let stats = ksp::direct::solve(comm, a, g_pi, &mut v);
                    (stats.iterations, stats.spmvs)
                }
                Method::Ipi { ksp: ktype, pc } => {
                    let precond = Precond::build(*pc, a);
                    // Eisenstat–Walker choice 2 (safeguarded): contraction-
                    // driven forcing, capped at 0.1 and floored by the
                    // configured α. Written as min→max because
                    // `f64::clamp(lo, hi)` panics whenever α > 0.1.
                    let alpha_k = if opts.adaptive_forcing && prev_residual.is_finite() {
                        let ratio = (residual / prev_residual).powi(2);
                        ratio.min(0.1).max(opts.alpha)
                    } else {
                        opts.alpha
                    };
                    let tol = Tolerance {
                        atol: alpha_k * residual,
                        rtol: 0.0,
                        max_iters: opts.max_inner,
                    };
                    // warm start from TV (one backup ahead of V)
                    v.copy_from_slice(&tv);
                    let stats = match opts.inner_precision {
                        InnerPrecision::F64 => {
                            ksp::solve(ktype, &precond, comm, a, g_pi, &mut v, &tol)
                        }
                        InnerPrecision::F32 => {
                            // Inner iterations on the compressed copy, f64
                            // refinement certified against `a`. The copy is
                            // independent of the eval backend (it compresses
                            // the selected policy rows directly).
                            let a32 = F32PolicyOp::new(mdp, &policy);
                            ksp::solve_mixed(ktype, &precond, comm, a, &a32, g_pi, &mut v, &tol)
                        }
                    };
                    (stats.iterations, stats.spmvs)
                }
            }
        };
        total_spmvs += inner_spmvs;
        total_inner += inner_iters;
        prev_residual = residual;
        trace.push(IterRecord {
            outer,
            residual,
            inner_iterations: inner_iters,
            spmvs: inner_spmvs + 1,
            elapsed_s: start.elapsed().as_secs_f64(),
        });
    }

    // Outer-iteration count = loop iterations only; the post-loop re-check
    // below appends a trace record but is not an outer iteration.
    let outer_iterations = trace.len();

    // final residual check if we ran out of iterations without breaking
    if !converged {
        residual =
            mdp.bellman_backup(comm, &v, &mut tv, &mut policy, &mut buf, &mut q_scratch);
        total_spmvs += 1;
        converged = residual < opts.atol;
        // The re-check is a real Bellman backup: record it so the trace's
        // residual/spmv accounting matches `total_spmvs` in metadata JSON
        // (previously this backup's work was silently dropped).
        trace.push(IterRecord {
            outer: outer_iterations,
            residual,
            inner_iterations: 0,
            spmvs: 1,
            elapsed_s: start.elapsed().as_secs_f64(),
        });
    }

    // Closing barrier: every rank has counted all solve collectives once
    // all ranks arrive, so the byte delta is exact and rank-identical.
    // (The time delta inherits µs-scale per-rank jitter from the barriers
    // themselves — it is a diagnostic, like wall time.)
    comm.barrier();
    let end_stats = comm.stats().snapshot();
    let comm_bytes = end_stats.total_bytes() - start_stats.total_bytes();
    let comm_time_us = end_stats
        .total_time_us()
        .saturating_sub(start_stats.total_time_us());

    LocalSolveResult {
        value: v,
        policy,
        gamma: mdp.gamma(),
        outer_iterations,
        total_spmvs,
        total_inner_iterations: total_inner,
        residual,
        converged,
        wall_time_s: start.elapsed().as_secs_f64(),
        trace,
        comm_bytes,
        comm_time_us,
    }
}

/// Gather a [`LocalSolveResult`] into the global [`SolveResult`] (every rank
/// returns the same global object). Collective.
pub fn gather_result(comm: &Comm, local: LocalSolveResult) -> SolveResult {
    let value = comm.allgather_f64s(&local.value);
    let policy_f: Vec<f64> = local.policy.iter().map(|&a| a as f64).collect();
    let policy: Vec<usize> = comm
        .allgather_f64s(&policy_f)
        .into_iter()
        .map(|a| a as usize)
        .collect();
    SolveResult {
        value,
        policy,
        outer_iterations: local.outer_iterations,
        total_spmvs: local.total_spmvs,
        total_inner_iterations: local.total_inner_iterations,
        residual: local.residual,
        converged: local.converged,
        wall_time_s: local.wall_time_s,
        trace: local.trace,
        comm_bytes: local.comm_bytes,
        comm_time_us: local.comm_time_us,
        gamma: local.gamma,
        ranks: comm.size(),
        threads: crate::util::par::configured_threads(),
    }
}

/// Solve a serial [`Mdp`] on a world of `ranks` threads and return the
/// gathered global result (convenience driver used by examples/benches).
pub fn solve_world(mdp: Arc<Mdp>, ranks: usize, opts: &SolveOptions) -> SolveResult {
    let opts = opts.clone();
    let mut results = World::run(ranks, move |comm| {
        let d = DistMdp::from_serial(&comm, &mdp);
        let local = solve_dist(&comm, &d, &opts);
        gather_result(&comm, local)
    });
    results.swap_remove(0)
}

/// Fully serial convenience wrapper (world of one rank).
pub fn solve_serial(mdp: &Mdp, opts: &SolveOptions) -> SolveResult {
    solve_world(Arc::new(mdp.clone()), 1, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mdp::fixtures::{random_mdp, two_state};
    use crate::util::prop;

    fn methods_under_test() -> Vec<Method> {
        vec![
            Method::Vi,
            Method::Mpi { sweeps: 10 },
            Method::ExactPi,
            Method::ipi_gmres(),
            Method::ipi_bicgstab(),
            Method::ipi_tfqmr(),
            Method::Ipi {
                ksp: KspType::Richardson { omega: 1.0 },
                pc: PcType::Jacobi,
            },
            // regression: the dispatcher used to drop the pc for TFQMR
            Method::Ipi {
                ksp: KspType::Tfqmr,
                pc: PcType::Jacobi,
            },
        ]
    }

    #[test]
    fn all_methods_solve_two_state() {
        // analytic: γ=0.5, c=1.5 → V* = [1.5, 0], π* = [1, ·]
        for method in methods_under_test() {
            let mdp = two_state(0.5, 1.5);
            let opts = SolveOptions {
                method: method.clone(),
                atol: 1e-10,
                ..Default::default()
            };
            let r = solve_serial(&mdp, &opts);
            assert!(r.converged, "{} did not converge", method.name());
            prop::close_slices(&r.value, &[1.5, 0.0], 1e-8)
                .unwrap_or_else(|e| panic!("{}: {e}", method.name()));
            assert_eq!(r.policy[0], 1, "{}", method.name());
        }
    }

    #[test]
    fn all_methods_agree_on_random_mdp() {
        let mdp = random_mdp(21, 40, 3, 0.95);
        let mut reference: Option<Vec<f64>> = None;
        for method in methods_under_test() {
            let opts = SolveOptions {
                method: method.clone(),
                atol: 1e-9,
                ..Default::default()
            };
            let r = solve_serial(&mdp, &opts);
            assert!(r.converged, "{} did not converge", method.name());
            match &reference {
                None => reference = Some(r.value),
                Some(v) => prop::close_slices(v, &r.value, 1e-6)
                    .unwrap_or_else(|e| panic!("{} disagrees: {e}", method.name())),
            }
        }
    }

    #[test]
    fn distributed_equals_serial() {
        let mdp = Arc::new(random_mdp(33, 50, 4, 0.97));
        let opts = SolveOptions {
            method: Method::ipi_gmres(),
            atol: 1e-9,
            ..Default::default()
        };
        let serial = solve_world(Arc::clone(&mdp), 1, &opts);
        for ranks in [2usize, 3, 4] {
            let dist = solve_world(Arc::clone(&mdp), ranks, &opts);
            prop::close_slices(&serial.value, &dist.value, 1e-7)
                .unwrap_or_else(|e| panic!("ranks={ranks}: {e}"));
            assert!(dist.converged);
        }
    }

    #[test]
    fn solution_is_bellman_fixed_point() {
        let mdp = random_mdp(9, 30, 3, 0.9);
        let r = solve_serial(
            &mdp,
            &SolveOptions {
                atol: 1e-10,
                ..Default::default()
            },
        );
        assert!(mdp.bellman_residual(&r.value) < 1e-9);
        // greedy policy of V* must reproduce the returned policy
        let (_, pol) = mdp.bellman(&r.value);
        assert_eq!(pol, r.policy);
    }

    #[test]
    fn residual_trace_decreases_overall() {
        let mdp = random_mdp(41, 60, 3, 0.99);
        let r = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::ipi_gmres(),
                atol: 1e-9,
                ..Default::default()
            },
        );
        assert!(r.trace.len() >= 2);
        let first = r.trace.first().unwrap().residual;
        let last = r.trace.last().unwrap().residual;
        assert!(last < first * 1e-3, "first={first} last={last}");
    }

    #[test]
    fn vi_needs_more_iterations_than_ipi_at_high_gamma() {
        let mdp = random_mdp(55, 50, 3, 0.999);
        let vi = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::Vi,
                atol: 1e-6,
                max_outer: 100_000,
                ..Default::default()
            },
        );
        let ipi = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::ipi_gmres(),
                atol: 1e-6,
                ..Default::default()
            },
        );
        assert!(vi.converged && ipi.converged);
        assert!(
            ipi.outer_iterations * 10 < vi.outer_iterations,
            "vi={} ipi={}",
            vi.outer_iterations,
            ipi.outer_iterations
        );
    }

    #[test]
    fn max_outer_respected_when_tolerance_unreachable() {
        let mdp = random_mdp(3, 20, 2, 0.99);
        let r = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::Vi,
                atol: 1e-300,
                max_outer: 5,
                ..Default::default()
            },
        );
        assert_eq!(r.outer_iterations, 5);
        assert!(!r.converged);
    }

    #[test]
    fn warm_start_v0_accelerates() {
        let mdp = random_mdp(15, 30, 2, 0.95);
        let opts = SolveOptions {
            method: Method::Vi,
            atol: 1e-8,
            ..Default::default()
        };
        let cold = solve_serial(&mdp, &opts);
        let warm = solve_serial(
            &mdp,
            &SolveOptions {
                v0: Some(cold.value.clone()),
                ..opts
            },
        );
        assert!(warm.outer_iterations <= 1);
    }

    #[test]
    fn adaptive_forcing_converges_and_saves_inner_work() {
        // wavefront-style workload where fixed tight forcing wastes inner
        // iterations: adaptive must converge to the same V* with fewer spmvs
        let mdp = crate::models::gridworld::GridSpec::maze(40, 40, 3);
        use crate::models::ModelGenerator;
        let mdp = mdp.build_serial(0.99);
        let fixed = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::ipi_gmres(),
                atol: 1e-8,
                alpha: 1e-6,
                max_outer: 100_000,
                ..Default::default()
            },
        );
        let adaptive = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::ipi_gmres(),
                atol: 1e-8,
                alpha: 1e-6,
                adaptive_forcing: true,
                max_outer: 100_000,
                ..Default::default()
            },
        );
        assert!(fixed.converged && adaptive.converged);
        prop::close_slices(&fixed.value, &adaptive.value, 1e-6).unwrap();
        assert!(
            adaptive.total_spmvs < fixed.total_spmvs,
            "adaptive {} vs fixed {}",
            adaptive.total_spmvs,
            fixed.total_spmvs
        );
    }

    #[test]
    fn adaptive_forcing_alpha_above_cap_does_not_panic() {
        // Regression: `ratio.clamp(alpha, 0.1)` panicked whenever the user
        // set alpha > 0.1 (clamp requires lo <= hi). The safeguard must
        // instead floor at alpha and still converge.
        let mdp = random_mdp(19, 40, 3, 0.97);
        for alpha in [0.11, 0.5, 0.9] {
            let r = solve_serial(
                &mdp,
                &SolveOptions {
                    method: Method::ipi_gmres(),
                    atol: 1e-8,
                    alpha,
                    adaptive_forcing: true,
                    max_outer: 100_000,
                    ..Default::default()
                },
            );
            assert!(r.converged, "alpha={alpha} did not converge");
        }
    }

    #[test]
    fn eval_backends_agree_all_methods() {
        let mdp = random_mdp(23, 35, 3, 0.95);
        for method in methods_under_test() {
            let mut values: Vec<Vec<f64>> = Vec::new();
            for backend in [
                EvalBackend::MatFree,
                EvalBackend::Assembled,
                EvalBackend::Bsr,
            ] {
                let r = solve_serial(
                    &mdp,
                    &SolveOptions {
                        method: method.clone(),
                        eval_backend: backend,
                        atol: 1e-9,
                        ..Default::default()
                    },
                );
                assert!(
                    r.converged,
                    "{}/{} did not converge",
                    method.name(),
                    backend.name()
                );
                values.push(r.value);
            }
            for v in &values[1..] {
                prop::close_slices(&values[0], v, 1e-7)
                    .unwrap_or_else(|e| panic!("{} backends disagree: {e}", method.name()));
            }
        }
    }

    #[test]
    fn eval_backend_parse() {
        assert_eq!(EvalBackend::parse("matfree").unwrap(), EvalBackend::MatFree);
        assert_eq!(
            EvalBackend::parse("assembled").unwrap(),
            EvalBackend::Assembled
        );
        assert_eq!(EvalBackend::parse("bsr").unwrap(), EvalBackend::Bsr);
        assert_eq!(EvalBackend::parse("blocked").unwrap(), EvalBackend::Bsr);
        assert!(EvalBackend::parse("gpu").is_err());
        assert_eq!(EvalBackend::default().name(), "matfree");
    }

    #[test]
    fn inner_precision_parse() {
        assert_eq!(InnerPrecision::parse("f64").unwrap(), InnerPrecision::F64);
        assert_eq!(InnerPrecision::parse("f32").unwrap(), InnerPrecision::F32);
        assert_eq!(InnerPrecision::parse("mixed").unwrap(), InnerPrecision::F32);
        assert!(InnerPrecision::parse("f16").is_err());
        assert_eq!(InnerPrecision::default().name(), "f64");
    }

    #[test]
    fn f32_inner_reaches_f64_outer_tolerance() {
        // The mixed-precision evaluation must converge to the *same* f64
        // outer certificate, on every eval backend, and agree with the
        // all-f64 solution well below the f32 representation floor.
        let mdp = random_mdp(67, 45, 3, 0.97);
        let f64_ref = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::ipi_gmres(),
                atol: 1e-9,
                ..Default::default()
            },
        );
        assert!(f64_ref.converged);
        for backend in [
            EvalBackend::MatFree,
            EvalBackend::Assembled,
            EvalBackend::Bsr,
        ] {
            let r = solve_serial(
                &mdp,
                &SolveOptions {
                    method: Method::ipi_gmres(),
                    eval_backend: backend,
                    inner_precision: InnerPrecision::F32,
                    atol: 1e-9,
                    ..Default::default()
                },
            );
            assert!(r.converged, "{} f32-inner did not converge", backend.name());
            // The certificate is the f64 Bellman residual — verify it
            // independently of the solver's own bookkeeping.
            assert!(
                mdp.bellman_residual(&r.value) < 1e-8,
                "{} certificate violated",
                backend.name()
            );
            prop::close_slices(&f64_ref.value, &r.value, 1e-7)
                .unwrap_or_else(|e| panic!("{} f32-inner disagrees: {e}", backend.name()));
        }
    }

    #[test]
    fn error_bound_certificate_holds() {
        // compare the certified bound against the true distance to V*
        let mdp = random_mdp(3, 25, 3, 0.9);
        let exact = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::ExactPi,
                atol: 1e-12,
                ..Default::default()
            },
        );
        let coarse = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::Vi,
                atol: 1e-3,
                ..Default::default()
            },
        );
        let true_err = coarse
            .value
            .iter()
            .zip(&exact.value)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(
            true_err <= coarse.error_bound() + 1e-12,
            "true {} > bound {}",
            true_err,
            coarse.error_bound()
        );
    }

    #[test]
    fn comm_bytes_is_per_solve_delta_not_cumulative() {
        // Regression: gather_result used to report the world-cumulative
        // counter, so a solve's comm_bytes included model distribution and
        // every earlier solve. Two identical solves on the same world must
        // now report identical volumes, both strictly below the cumulative
        // total (which also contains assembly + gather traffic).
        let mdp = Arc::new(random_mdp(13, 30, 3, 0.95));
        let opts = SolveOptions {
            method: Method::ipi_gmres(),
            atol: 1e-8,
            ..Default::default()
        };
        let out = World::run(3, move |comm| {
            let d = DistMdp::from_serial(&comm, &mdp);
            let r1 = gather_result(&comm, solve_dist(&comm, &d, &opts));
            let r2 = gather_result(&comm, solve_dist(&comm, &d, &opts));
            comm.barrier();
            (r1.comm_bytes, r2.comm_bytes, comm.stats().total_bytes())
        });
        for (b1, b2, cumulative) in out {
            assert!(b1 > 0, "distributed solve must communicate");
            assert_eq!(b1, b2, "identical solves must report identical volume");
            assert!(
                b1 < cumulative,
                "solve delta {b1} not below cumulative {cumulative}"
            );
        }
    }

    #[test]
    fn json_report_shape() {
        let mdp = two_state(0.5, 1.5);
        let r = solve_serial(&mdp, &SolveOptions::default());
        let j = r.to_json("test");
        assert_eq!(j.get("label").unwrap().as_str(), Some("test"));
        assert!(j.get("residual_trace").unwrap().as_arr().unwrap().len() >= 1);
        assert_eq!(j.get("converged").unwrap().as_bool(), Some(true));
        // comm accounting keys the perf-smoke CI gate greps for
        assert!(j.get("comm_bytes").is_some());
        assert!(j.get("comm_time_us").is_some());
    }

    #[test]
    fn async_vi_reaches_sync_solution_and_certificate() {
        let mdp = Arc::new(random_mdp(29, 40, 3, 0.95));
        let sync_opts = SolveOptions {
            method: Method::Vi,
            atol: 1e-9,
            max_outer: 100_000,
            ..Default::default()
        };
        for ranks in [1usize, 3] {
            // Sync reference at the same rank count: k = 1 must match it
            // bitwise (distribution itself is not bitwise vs serial — ghost
            // column remapping changes gather order within rows).
            let sync = solve_world(Arc::clone(&mdp), ranks, &sync_opts);
            assert!(sync.converged);
            for staleness in [1usize, 4, 8] {
                let r = solve_world(
                    Arc::clone(&mdp),
                    ranks,
                    &SolveOptions {
                        async_vi: true,
                        async_vi_staleness: staleness,
                        ..sync_opts.clone()
                    },
                );
                assert!(r.converged, "ranks={ranks} k={staleness} did not converge");
                // The certificate is the collectively reduced residual of a
                // synchronized backup — verify it independently of the
                // solver's bookkeeping.
                assert!(
                    mdp.bellman_residual(&r.value) < 1e-8,
                    "ranks={ranks} k={staleness} certificate violated"
                );
                prop::close_slices(&sync.value, &r.value, 1e-7)
                    .unwrap_or_else(|e| panic!("ranks={ranks} k={staleness}: {e}"));
                assert_eq!(r.policy, sync.policy, "ranks={ranks} k={staleness}");
                // k = 1 runs zero stale sweeps: the path degenerates to
                // synchronous VI and the iterates are bitwise identical.
                if staleness == 1 {
                    assert_eq!(r.value, sync.value, "ranks={ranks}");
                    assert_eq!(r.outer_iterations, sync.outer_iterations);
                }
                // On one rank the "stale" sweeps are exact Bellman sweeps,
                // so k > 1 must cut the certified outer-iteration count.
                if ranks == 1 && staleness > 1 {
                    assert!(
                        r.outer_iterations < sync.outer_iterations,
                        "k={staleness}: {} !< {}",
                        r.outer_iterations,
                        sync.outer_iterations
                    );
                }
            }
        }
    }

    #[test]
    fn alpha_tradeoff_more_outer_fewer_inner() {
        // loose forcing term → more outer iterations, fewer inner per outer
        let mdp = random_mdp(61, 50, 3, 0.99);
        let tight = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::ipi_gmres(),
                alpha: 1e-8,
                atol: 1e-8,
                ..Default::default()
            },
        );
        let loose = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::ipi_gmres(),
                alpha: 0.5,
                atol: 1e-8,
                ..Default::default()
            },
        );
        assert!(tight.converged && loose.converged);
        assert!(loose.outer_iterations >= tight.outer_iterations);
    }
}
