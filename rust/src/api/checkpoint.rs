//! Warm-start checkpoints: re-using a solved value/policy as the seed of
//! the next solve.
//!
//! Production MDPs drift — costs, demand and failure rates change a little
//! between solves — so the previous optimal value vector is an excellent
//! initial guess for the next one. This module turns the `.mdpa` policy
//! artifact ([`crate::serve::codec`]) into that seed: `-warm_start` accepts
//! either a checkpoint *file path* (written by `-write_checkpoint` or
//! [`crate::api::SolveOutcome::write_checkpoint`]) or a 16-hex artifact
//! *fingerprint* resolved against the `-serve_store` directory, closing the
//! drift loop `solve → serve → patch → warm re-solve` end to end.
//!
//! A seed is only usable when it describes the same decision problem:
//! [`WarmStart::check_compat`] verifies state/action shape, the discount
//! bound (bitwise — two solves of "the same" model must agree exactly) and
//! the optimization sense, and every mismatch is a typed [`ApiError`]
//! naming both sides. The seed itself is the *global* value vector; the
//! solver scatters it by row range, so the seed is independent of the rank
//! partition it was produced under (`SolveOptions::v0` slices `[lo, hi)`
//! per rank).

use std::sync::Arc;

use crate::mdp::Objective;
use crate::serve::{codec, fingerprint::parse_hex16, PolicyArtifact, PolicyStore};
use crate::util::args::Options;

use super::{ApiError, SolveOutcome};

/// A resolved warm-start seed: the previous solve's global value vector
/// plus the model identity it was produced under, so compatibility can be
/// checked before any iteration runs.
#[derive(Clone, Debug)]
pub struct WarmStart {
    /// Global value vector of the source solve (one entry per state).
    pub(crate) value: Arc<Vec<f64>>,
    /// State count of the source model.
    pub(crate) n_states: usize,
    /// Action count of the source model.
    pub(crate) n_actions: usize,
    /// Discount bound of the source solve.
    pub(crate) gamma: f64,
    /// Optimization sense of the source solve.
    pub(crate) objective: Objective,
    /// 16-hex artifact fingerprint of the source — recorded as warm-start
    /// provenance in the metadata JSON (and nowhere near the artifact
    /// fingerprint, which stays warm-start-neutral).
    pub(crate) fingerprint: String,
}

impl WarmStart {
    /// Build a seed from a decoded `.mdpa` artifact.
    pub fn from_artifact(artifact: &PolicyArtifact) -> WarmStart {
        WarmStart {
            value: Arc::new(artifact.value.clone()),
            n_states: artifact.n_states,
            n_actions: artifact.n_actions,
            gamma: artifact.gamma,
            objective: artifact.objective,
            fingerprint: artifact.fingerprint_hex(),
        }
    }

    /// Build a seed from an in-process [`SolveOutcome`] — no checkpoint
    /// file involved (the [`crate::api::MdpBuilder::warm_start`] path).
    pub fn from_outcome(outcome: &SolveOutcome) -> WarmStart {
        WarmStart {
            value: Arc::new(outcome.result.value.clone()),
            n_states: outcome.n_states,
            n_actions: outcome.n_actions,
            gamma: outcome.gamma,
            objective: outcome.objective,
            fingerprint: outcome.fingerprint(),
        }
    }

    /// The 16-hex fingerprint of the source artifact/outcome.
    pub fn fingerprint(&self) -> &str {
        &self.fingerprint
    }

    /// Check that this seed may initialize a solve of the given model.
    /// Shape must match exactly, gamma bitwise, and the objective — a max-
    /// reward value vector is not a valid seed for a min-cost solve (and
    /// vice versa). Every mismatch is a typed error naming both sides.
    pub fn check_compat(
        &self,
        n_states: usize,
        n_actions: usize,
        gamma: f64,
        objective: Objective,
    ) -> Result<(), ApiError> {
        let fp = &self.fingerprint;
        if self.n_states != n_states {
            return Err(ApiError(format!(
                "warm start {fp} is incompatible: it solved {} states, this model has {n_states}",
                self.n_states
            )));
        }
        if self.n_actions != n_actions {
            return Err(ApiError(format!(
                "warm start {fp} is incompatible: it solved {} actions, this model has {n_actions}",
                self.n_actions
            )));
        }
        if self.gamma.to_bits() != gamma.to_bits() {
            return Err(ApiError(format!(
                "warm start {fp} is incompatible: it solved with gamma {}, this model uses {gamma}",
                self.gamma
            )));
        }
        if self.objective != objective {
            return Err(ApiError(format!(
                "warm start {fp} is incompatible: it solved objective {}, this solve is {}",
                self.objective.name(),
                objective.name()
            )));
        }
        Ok(())
    }
}

/// Resolve a `-warm_start` argument to a seed. A 16-hex string is treated
/// as a store fingerprint and looked up in the `-serve_store` directory
/// (a typed error when no store is configured); anything else is read as a
/// `.mdpa` checkpoint file path. Decode failures — truncation, flipped
/// bytes, digest mismatches — surface as the codec's typed errors wrapped
/// into [`ApiError`]s.
pub fn load_warm_start(spec: &str, db: &Options) -> Result<WarmStart, ApiError> {
    if parse_hex16(spec).is_some() {
        let Some(dir) = db.get("serve_store") else {
            return Err(ApiError(format!(
                "-warm_start {spec} looks like a store fingerprint, but no \
                 -serve_store directory is set to look it up in — pass a \
                 checkpoint file path instead, or add -serve_store <dir>"
            )));
        };
        let store = PolicyStore::on_disk(dir, 0)
            .map_err(|e| ApiError(format!("-warm_start store '{dir}': {e}")))?;
        let artifact = store
            .get(spec)
            .map_err(|e| ApiError(format!("-warm_start {spec}: {e}")))?;
        Ok(WarmStart::from_artifact(&artifact))
    } else {
        let bytes = std::fs::read(spec)
            .map_err(|e| ApiError(format!("reading -warm_start '{spec}': {e}")))?;
        let artifact = codec::decode(&bytes)
            .map_err(|e| ApiError(format!("-warm_start '{spec}': {e}")))?;
        Ok(WarmStart::from_artifact(&artifact))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::args::Options;

    fn db(toks: &[&str]) -> Options {
        Options::parse(toks.iter().map(|s| s.to_string()))
    }

    fn seed() -> WarmStart {
        WarmStart {
            value: Arc::new(vec![1.0, 2.0, 3.0]),
            n_states: 3,
            n_actions: 2,
            gamma: 0.5,
            objective: Objective::Min,
            fingerprint: "00000000deadbeef".into(),
        }
    }

    #[test]
    fn compat_accepts_matching_model() {
        assert!(seed().check_compat(3, 2, 0.5, Objective::Min).is_ok());
    }

    #[test]
    fn compat_mismatches_are_typed_and_name_both_sides() {
        let err = seed().check_compat(4, 2, 0.5, Objective::Min).unwrap_err();
        assert!(err.0.contains("3 states") && err.0.contains('4'), "{err}");
        let err = seed().check_compat(3, 5, 0.5, Objective::Min).unwrap_err();
        assert!(err.0.contains("2 actions") && err.0.contains('5'), "{err}");
        let err = seed().check_compat(3, 2, 0.9, Objective::Min).unwrap_err();
        assert!(err.0.contains("gamma"), "{err}");
        let err = seed().check_compat(3, 2, 0.5, Objective::Max).unwrap_err();
        assert!(err.0.contains("min") && err.0.contains("max"), "{err}");
        // every message carries the provenance fingerprint
        for e in [
            seed().check_compat(4, 2, 0.5, Objective::Min).unwrap_err(),
            seed().check_compat(3, 2, 0.9, Objective::Min).unwrap_err(),
        ] {
            assert!(e.0.contains("00000000deadbeef"), "{e}");
        }
    }

    #[test]
    fn fingerprint_form_requires_store() {
        let err = load_warm_start("0123456789abcdef", &db(&[])).unwrap_err();
        assert!(err.0.contains("-serve_store"), "{err}");
    }

    #[test]
    fn missing_checkpoint_file_is_typed() {
        let err = load_warm_start("/no/such/checkpoint.mdpa", &db(&[])).unwrap_err();
        assert!(err.0.contains("reading -warm_start"), "{err}");
    }
}
