//! [`MdpBuilder`] — construct serial or distributed MDPs from three
//! interchangeable sources, plus the named benchmark-model catalog.
//!
//! madupite's `MDP` object is created either from user *filler* functions
//! (`createTransitionProbabilityTensor` / `createStageCostMatrix` closures),
//! from an offline binary file, or from one of the benchmark generators.
//! The builder mirrors that surface: exactly one source must be set
//! ([`MdpBuilder::file`], [`MdpBuilder::model`], [`MdpBuilder::fillers`]),
//! conflicting or missing sources are validation *errors* (never panics),
//! and closure-defined models are checked row-by-row for stochasticity
//! before any solve starts.
//!
//! **Source selection is one surface with one precedence.** The
//! constructor family (`from_file`/`from_model`/`from_fillers`) is pure
//! sugar for `MdpBuilder::new()` plus the matching chainer — there is no
//! second code path and no implicit override: every `file`/`model`/
//! `fillers` call *adds* a source, and the moment a second one is added
//! the conflict is recorded **at set time** (naming every kind involved,
//! in the same typed-error style as the options table's did-you-mean).
//! The error surfaces at the first fallible call — `build_serial`, a
//! solve, or `Solver::build` — because the chainers themselves are
//! infallible by design. The CLI keys `-file`/`-model` feed the exact
//! same rule through [`MdpBuilder::from_options`].
//!
//! For *drifting* models the builder carries two delta surfaces that skip
//! full re-validation: [`MdpBuilder::patch_costs`] /
//! [`MdpBuilder::patch_transitions`] re-check only the touched rows, and
//! [`MdpBuilder::warm_start`] seeds the next solve from a previous
//! [`crate::api::SolveOutcome`] without a checkpoint file.

use crate::factored::FactoredMdp;
use crate::mdp::{self, Mdp, Objective};
use crate::models::{
    factory::FactorySpec, garnet::GarnetSpec, gridworld::GridSpec, inventory::InventorySpec,
    maintenance::MaintenanceSpec, queueing::QueueSpec, replacement::ReplacementSpec, sis::SisSpec,
    sis_factored::SisFactoredSpec, traffic::TrafficSpec, ModelGenerator,
};
use crate::util::args::Options;
use std::sync::Arc;

use super::{checkpoint::WarmStart, options, ApiError, SolveOutcome};

/// Shared sparse-transition closure: `(s, a) → [(s', p), ...]`.
pub type ProbFn = Arc<dyn Fn(usize, usize) -> Vec<(usize, f64)> + Send + Sync>;

/// Shared stage-cost closure: `(s, a) → g(s, a)`.
pub type CostFn = Arc<dyn Fn(usize, usize) -> f64 + Send + Sync>;

/// Shared per-transition discount closure: `(s, a) → γ(s,a)` (the semi-MDP
/// filler alongside [`ProbFn`] / [`CostFn`]).
pub type DiscountFn = Arc<dyn Fn(usize, usize) -> f64 + Send + Sync>;

/// One of the model sources the builder accepts.
#[derive(Clone)]
pub(crate) enum Source {
    /// Offline `.mdpb` file (gamma/objective/discounts come from it).
    File(String),
    /// A benchmark model generator.
    Model(Arc<dyn ModelGenerator + Send + Sync>),
    /// User closures in the spirit of madupite's
    /// `createTransitionProbabilityTensor`.
    Fillers {
        n_states: usize,
        n_actions: usize,
        prob: ProbFn,
        cost: CostFn,
    },
    /// A factored model description (DESIGN.md §17): solved either by
    /// flattening through the existing builders or by structured value
    /// iteration (`-factored_mode`).
    Factored(Arc<FactoredMdp>),
}

impl Source {
    fn kind(&self) -> &'static str {
        match self {
            Source::File(_) => "file",
            Source::Model(_) => "model",
            Source::Fillers { .. } => "fillers",
            Source::Factored(_) => "factored",
        }
    }
}

/// Builder for serial or distributed MDPs (madupite's `MDP` creation
/// surface). Construct with one source, optionally set `gamma`/`objective`,
/// then either [`build_serial`](Self::build_serial) or hand it to a
/// [`crate::api::Solver`] for a (possibly multi-rank) solve.
///
/// ```
/// use madupite::api::MdpBuilder;
///
/// // Two-state chain: action 1 jumps to the absorbing state 1 at cost 1.5.
/// let builder = MdpBuilder::from_fillers(
///     2,
///     2,
///     |s, a| match (s, a) {
///         (0, 0) => vec![(0, 1.0)],
///         (0, 1) => vec![(1, 1.0)],
///         _ => vec![(1, 1.0)],
///     },
///     |s, a| match (s, a) {
///         (0, 0) => 1.0,
///         (0, 1) => 1.5,
///         _ => 0.0,
///     },
/// )
/// .gamma(0.5);
/// let mdp = builder.build_serial().unwrap();
/// assert_eq!(mdp.n_states(), 2);
/// ```
#[derive(Clone, Default)]
pub struct MdpBuilder {
    sources: Vec<Source>,
    /// Conflict recorded the moment a second source is set (the chainers
    /// are infallible, so the typed error is raised at the first fallible
    /// call instead — `build_serial`, a solve, or `Solver::build`).
    source_conflict: Option<String>,
    gamma: Option<f64>,
    objective: Option<Objective>,
    /// Semi-MDP filler: per-transition discounts `(s, a) → γ(s,a)`,
    /// applicable to closure sources only.
    discount_filler: Option<DiscountFn>,
    /// In-process warm-start seed ([`Self::warm_start`]).
    warm: Option<WarmStart>,
    /// Pending cost deltas `(s, a, new_cost)` applied after the source
    /// builds, validating only the touched entries.
    cost_patches: Vec<(usize, usize, f64)>,
    /// Pending transition-row deltas `(s, a, new_row)` applied after the
    /// source builds, re-validating only the touched rows.
    transition_patches: Vec<(usize, usize, Vec<(usize, f64)>)>,
}

impl MdpBuilder {
    /// Empty builder: add exactly one source before building/solving.
    pub fn new() -> MdpBuilder {
        MdpBuilder::default()
    }

    /// Builder with an offline `.mdpb` file source.
    pub fn from_file(path: impl Into<String>) -> MdpBuilder {
        MdpBuilder::new().file(path)
    }

    /// Builder over an explicit benchmark generator.
    pub fn from_model(generator: Arc<dyn ModelGenerator + Send + Sync>) -> MdpBuilder {
        MdpBuilder::new().model(generator)
    }

    /// Builder over a named catalog model with `-key value` parameters
    /// (see [`MODEL_CATALOG`]).
    pub fn from_model_name(name: &str, params: &Options) -> Result<MdpBuilder, ApiError> {
        Ok(MdpBuilder::new().model(model_from_options(name, params)?))
    }

    /// Builder from user closures `(s, a) → row` / `(s, a) → cost`. Rows
    /// are validated (stochastic, in-range, finite) when the MDP is built.
    pub fn from_fillers(
        n_states: usize,
        n_actions: usize,
        prob: impl Fn(usize, usize) -> Vec<(usize, f64)> + Send + Sync + 'static,
        cost: impl Fn(usize, usize) -> f64 + Send + Sync + 'static,
    ) -> MdpBuilder {
        MdpBuilder::new().fillers(n_states, n_actions, prob, cost)
    }

    /// Builder over a validated factored model description (DESIGN.md
    /// §17). The solve path is chosen by `-factored_mode`: `compile`
    /// (default) flattens through the existing distributed builders;
    /// `svi` runs structured value iteration on ADDs.
    pub fn from_factored(fmdp: FactoredMdp) -> MdpBuilder {
        MdpBuilder::new().factored(fmdp)
    }

    /// Add a `.mdpb` file source (chainable; at most one source may be set
    /// — a second source records a conflict at set time).
    pub fn file(mut self, path: impl Into<String>) -> MdpBuilder {
        self.sources.push(Source::File(path.into()));
        self.note_source_conflict();
        self
    }

    /// Add a generator source (chainable; at most one source may be set
    /// — a second source records a conflict at set time).
    pub fn model(mut self, generator: Arc<dyn ModelGenerator + Send + Sync>) -> MdpBuilder {
        self.sources.push(Source::Model(generator));
        self.note_source_conflict();
        self
    }

    /// Add a closure source (chainable; at most one source may be set
    /// — a second source records a conflict at set time).
    pub fn fillers(
        mut self,
        n_states: usize,
        n_actions: usize,
        prob: impl Fn(usize, usize) -> Vec<(usize, f64)> + Send + Sync + 'static,
        cost: impl Fn(usize, usize) -> f64 + Send + Sync + 'static,
    ) -> MdpBuilder {
        self.sources.push(Source::Fillers {
            n_states,
            n_actions,
            prob: Arc::new(prob),
            cost: Arc::new(cost),
        });
        self.note_source_conflict();
        self
    }

    /// Add a factored-model source (chainable; at most one source may be
    /// set — a second source records a conflict at set time).
    pub fn factored(mut self, fmdp: FactoredMdp) -> MdpBuilder {
        self.sources.push(Source::Factored(Arc::new(fmdp)));
        self.note_source_conflict();
        self
    }

    /// Record the conflicting-sources error the moment it happens, naming
    /// every kind set so far (the chainers stay infallible; the first
    /// fallible call raises it).
    fn note_source_conflict(&mut self) {
        if self.sources.len() > 1 {
            let kinds: Vec<&str> = self.sources.iter().map(|s| s.kind()).collect();
            self.source_conflict = Some(format!(
                "conflicting model sources: {} are all set — choose exactly one",
                kinds.join(" and ")
            ));
        }
    }

    /// Set the discount factor (validated to [0, 1) at build/solve time).
    /// A `-gamma` entry in the solver's options database overrides this.
    pub fn gamma(mut self, gamma: f64) -> MdpBuilder {
        self.gamma = Some(gamma);
        self
    }

    /// Set a **per-transition discount filler** `(s, a) → γ(s,a)` — the
    /// semi-MDP companion of the transition/cost fillers (madupite's
    /// generalized-discount surface). Applies to closure sources only;
    /// every produced value is validated through the shared gamma check
    /// (rank-locally, with collective agreement, on distributed solves).
    /// Mutually exclusive with a scalar [`Self::gamma`] / `-gamma`.
    pub fn discount_filler(
        mut self,
        disc: impl Fn(usize, usize) -> f64 + Send + Sync + 'static,
    ) -> MdpBuilder {
        self.discount_filler = Some(Arc::new(disc));
        self
    }

    /// Set the optimization sense (min-cost by default). A `-objective`
    /// entry in the solver's options database overrides this.
    pub fn objective(mut self, objective: Objective) -> MdpBuilder {
        self.objective = Some(objective);
        self
    }

    /// Seed the next solve from a previous [`SolveOutcome`] — the
    /// in-process warm-start path (no checkpoint file involved; for the
    /// file/store form use `-warm_start <path|fingerprint>`, and setting
    /// both is a typed conflict error at solve time). Compatibility
    /// (shape, gamma, objective) is checked against the realized model
    /// before any iteration runs.
    pub fn warm_start(mut self, outcome: &SolveOutcome) -> MdpBuilder {
        self.warm = Some(WarmStart::from_outcome(outcome));
        self
    }

    /// The in-process warm-start seed, if set.
    pub(crate) fn warm_start_value(&self) -> Option<&WarmStart> {
        self.warm.as_ref()
    }

    /// Queue stage-cost deltas `(s, a, new_cost)` — the incremental
    /// update path for drifting models. Applied after the source builds;
    /// only the touched entries are validated
    /// ([`crate::mdp::Mdp::patch_costs`]).
    pub fn patch_costs(
        mut self,
        rows: impl IntoIterator<Item = (usize, usize, f64)>,
    ) -> MdpBuilder {
        self.cost_patches.extend(rows);
        self
    }

    /// Queue transition-row deltas `(s, a, new_row)`. Applied after the
    /// source builds; only the touched rows are re-validated —
    /// stochasticity at the construction-time 1e-8 bar, sorted-unique
    /// columns, bounds ([`crate::mdp::Mdp::patch_transitions`]).
    pub fn patch_transitions(
        mut self,
        blocks: impl IntoIterator<Item = (usize, usize, Vec<(usize, f64)>)>,
    ) -> MdpBuilder {
        self.transition_patches.extend(blocks);
        self
    }

    /// Whether any cost/transition deltas are queued.
    pub(crate) fn has_patches(&self) -> bool {
        !self.cost_patches.is_empty() || !self.transition_patches.is_empty()
    }

    /// Apply the queued deltas to a built model — transitions first, then
    /// costs, each batch atomic and touched-rows-only.
    pub(crate) fn apply_patches(&self, mdp: &mut Mdp) -> Result<(), ApiError> {
        mdp.patch_transitions(&self.transition_patches)
            .map_err(ApiError)?;
        mdp.patch_costs(&self.cost_patches).map_err(ApiError)?;
        Ok(())
    }

    /// Builder-level gamma, if explicitly set.
    pub fn gamma_value(&self) -> Option<f64> {
        self.gamma
    }

    /// Builder-level objective, if explicitly set.
    pub fn objective_value(&self) -> Option<Objective> {
        self.objective
    }

    /// The per-transition discount filler, if set.
    pub(crate) fn discount_filler_value(&self) -> Option<&DiscountFn> {
        self.discount_filler.as_ref()
    }

    /// The one discount-filler conflict check, shared by
    /// [`Self::build_serial`] and `api::run_solve`: the filler belongs to
    /// closure sources, and it is mutually exclusive with any scalar gamma
    /// (`db_gamma` covers the options database's `-gamma`).
    pub(crate) fn validate_discount_filler(
        &self,
        source: &Source,
        db_gamma: bool,
    ) -> Result<(), ApiError> {
        if self.discount_filler.is_none() {
            return Ok(());
        }
        if !matches!(source, Source::Fillers { .. }) {
            return Err(ApiError(
                "discount_filler applies to closure (filler) sources only; \
                 files carry their discounts in the header and models define their own"
                    .into(),
            ));
        }
        if db_gamma || self.gamma.is_some() {
            return Err(ApiError(
                "discount_filler supplies γ(s,a) directly; a scalar gamma conflicts with it"
                    .into(),
            ));
        }
        Ok(())
    }

    /// The single configured source — errors on zero or conflicting
    /// sources (the conflict text was recorded at set time by the
    /// chainers, so it names every kind involved).
    pub(crate) fn resolved_source(&self) -> Result<&Source, ApiError> {
        if let Some(msg) = &self.source_conflict {
            return Err(ApiError(msg.clone()));
        }
        match self.sources.as_slice() {
            [] => Err(ApiError(
                "no model source set: use one of file/model/fillers/factored (or -file / -model)"
                    .into(),
            )),
            [one] => Ok(one),
            many => {
                // unreachable in practice (the chainers record conflicts),
                // kept as a defensive fallback with the same message
                let kinds: Vec<&str> = many.iter().map(|s| s.kind()).collect();
                Err(ApiError(format!(
                    "conflicting model sources: {} are all set — choose exactly one",
                    kinds.join(" and ")
                )))
            }
        }
    }

    /// Build the model from the CLI options database: `-file` selects the
    /// offline source, otherwise `-model` (default `maze`) selects a
    /// catalog model. Setting both is a conflicting-sources error.
    pub fn from_options(db: &Options) -> Result<MdpBuilder, ApiError> {
        match (db.get("file").map(str::to_string), db.get("model")) {
            (Some(_), Some(_)) => Err(ApiError(
                "conflicting model sources: -file and -model are both set — choose one".into(),
            )),
            (Some(path), None) => Ok(MdpBuilder::from_file(path)),
            (None, model) => {
                let name = model.unwrap_or("maze").to_string();
                MdpBuilder::from_model_name(&name, db)
            }
        }
    }

    /// Build the full in-memory serial [`Mdp`] (single rank; for the
    /// distributed path hand the builder to a [`crate::api::Solver`]).
    /// Queued [`Self::patch_costs`] / [`Self::patch_transitions`] deltas
    /// are applied on top, re-validating only the touched rows.
    pub fn build_serial(&self) -> Result<Mdp, ApiError> {
        let mut mdp = self.build_serial_unpatched()?;
        if self.has_patches() {
            self.apply_patches(&mut mdp)?;
        }
        Ok(mdp)
    }

    /// [`Self::build_serial`] without the queued deltas.
    fn build_serial_unpatched(&self) -> Result<Mdp, ApiError> {
        let source = self.resolved_source()?;
        self.validate_discount_filler(source, false)?;
        match source {
            Source::File(path) => {
                if self.gamma.is_some() || self.objective.is_some() {
                    return Err(ApiError(format!(
                        "gamma/objective come from the .mdpb header of '{path}'; \
                         do not set them on the builder"
                    )));
                }
                mdp::io::load(path).map_err(|e| ApiError(format!("loading {path}: {e}")))
            }
            Source::Model(generator) => {
                let gamma = validate_gamma(self.gamma.unwrap_or(0.99))?;
                generator
                    .try_build_serial(gamma)
                    .map(|m| m.with_objective(self.objective.unwrap_or_default()))
                    .map_err(ApiError)
            }
            Source::Factored(fmdp) => {
                let gamma = validate_gamma(self.gamma.unwrap_or(0.99))?;
                fmdp.try_build_serial(gamma)
                    .map(|m| m.with_objective(self.objective.unwrap_or_default()))
                    .map_err(ApiError)
            }
            Source::Fillers {
                n_states,
                n_actions,
                prob,
                cost,
            } => {
                if let Some(disc) = &self.discount_filler {
                    // gamma conflicts were rejected by validate_discount_filler
                    return Mdp::try_from_fillers_semi(
                        *n_states,
                        *n_actions,
                        |s, a| disc(s, a),
                        |s, a| prob(s, a),
                        |s, a| cost(s, a),
                    )
                    .map(|m| m.with_objective(self.objective.unwrap_or_default()))
                    .map_err(ApiError);
                }
                let gamma = validate_gamma(self.gamma.unwrap_or(0.99))?;
                Mdp::try_from_fillers(
                    *n_states,
                    *n_actions,
                    gamma,
                    |s, a| prob(s, a),
                    |s, a| cost(s, a),
                )
                .map(|m| m.with_objective(self.objective.unwrap_or_default()))
                .map_err(ApiError)
            }
        }
    }
}

fn validate_gamma(gamma: f64) -> Result<f64, ApiError> {
    mdp::validate_gamma(gamma).map_err(ApiError)
}

/// One catalog entry: a named benchmark model plus the `-key value`
/// parameters it accepts (with their defaults). The CLI help prints this
/// table, so it cannot drift from [`model_from_options`].
pub struct ModelInfo {
    /// Catalog name (the `-model` value).
    pub name: &'static str,
    /// Accepted parameters with defaults, in CLI spelling.
    pub params: &'static str,
    /// One-line description.
    pub about: &'static str,
}

/// The benchmark models `-model` accepts — one entry per arm of
/// [`model_from_options`] (a unit test enforces the correspondence).
pub const MODEL_CATALOG: &[ModelInfo] = &[
    ModelInfo {
        name: "maze",
        params: "-rows 64 -cols 64 -seed 42",
        about: "random-maze navigation gridworld (walls, 4 moves, slip)",
    },
    ModelInfo {
        name: "grid",
        params: "-rows 64 -cols 64",
        about: "open gridworld navigation (no walls)",
    },
    ModelInfo {
        name: "sis",
        params: "-population 1000 -num_actions 4",
        about: "SIS epidemic intervention control",
    },
    ModelInfo {
        name: "traffic",
        params: "-capacity 12",
        about: "two-queue traffic signal control",
    },
    ModelInfo {
        name: "garnet",
        params: "-num_states 1000 -num_actions 4 -branching 5 -seed 42",
        about: "random Garnet MDP family",
    },
    ModelInfo {
        name: "inventory",
        params: "-capacity 50",
        about: "inventory control with order/holding/stockout costs",
    },
    ModelInfo {
        name: "queueing",
        params: "-capacity 50",
        about: "queueing admission control",
    },
    ModelInfo {
        name: "replacement",
        params: "-num_states 50",
        about: "machine replacement (aging cost vs replacement)",
    },
    ModelInfo {
        name: "maintenance",
        params: "-num_states 50",
        about: "semi-MDP machine maintenance (exponential sojourns, per-(s,a) discounts)",
    },
    ModelInfo {
        name: "sis_factored",
        params: "-population 8",
        about: "factored ring-network SIS epidemic control (2^N states, CPT scope 3)",
    },
    ModelInfo {
        name: "factory",
        params: "-machines 4",
        about: "factored machine-line maintenance (3^K states, upstream-coupled wear)",
    },
];

/// Require a model-parameter condition, as a typed error (the spec
/// constructors `assert!` the same invariants — this keeps user input on
/// the error path, never the panic path).
fn require(cond: bool, msg: impl Into<String>) -> Result<(), ApiError> {
    if cond {
        Ok(())
    } else {
        Err(ApiError(msg.into()))
    }
}

/// Instantiate a catalog model from its name and `-key value` parameters
/// (the one model registry behind the CLI, the embedded API and `generate`).
/// Out-of-range parameters are typed errors, not panics.
pub fn model_from_options(
    name: &str,
    db: &Options,
) -> Result<Arc<dyn ModelGenerator + Send + Sync>, ApiError> {
    let seed = db.get_u64("seed", 42)?;
    Ok(match name {
        "maze" | "grid" => {
            let rows = db.get_usize("rows", 64)?;
            let cols = db.get_usize("cols", 64)?;
            require(
                rows >= 2 && cols >= 2,
                format!("{name} needs -rows >= 2 and -cols >= 2, got {rows}x{cols}"),
            )?;
            if name == "maze" {
                Arc::new(GridSpec::maze(rows, cols, seed))
            } else {
                Arc::new(GridSpec::open(rows, cols))
            }
        }
        "sis" => {
            let population = db.get_usize("population", 1000)?;
            let num_actions = db.get_usize("num_actions", 4)?;
            require(
                population >= 1 && num_actions >= 1,
                "sis needs -population >= 1 and -num_actions >= 1",
            )?;
            Arc::new(SisSpec::standard(population, num_actions))
        }
        "traffic" => {
            let capacity = db.get_usize("capacity", 12)?;
            require(capacity >= 1, "traffic needs -capacity >= 1")?;
            Arc::new(TrafficSpec::standard(capacity))
        }
        "garnet" => {
            let num_states = db.get_usize("num_states", 1000)?;
            let num_actions = db.get_usize("num_actions", 4)?;
            let branching = db.get_usize("branching", 5)?;
            require(
                num_states >= 1 && num_actions >= 1,
                "garnet needs -num_states >= 1 and -num_actions >= 1",
            )?;
            require(
                branching >= 1 && branching <= num_states,
                format!(
                    "garnet needs 1 <= -branching <= -num_states, \
                     got branching {branching} with {num_states} states"
                ),
            )?;
            Arc::new(GarnetSpec::new(num_states, num_actions, branching, seed))
        }
        "inventory" => {
            let capacity = db.get_usize("capacity", 50)?;
            require(capacity >= 1, "inventory needs -capacity >= 1")?;
            Arc::new(InventorySpec::standard(capacity))
        }
        "queueing" => {
            let capacity = db.get_usize("capacity", 50)?;
            require(capacity >= 1, "queueing needs -capacity >= 1")?;
            Arc::new(QueueSpec::standard(capacity))
        }
        "replacement" => {
            let num_states = db.get_usize("num_states", 50)?;
            require(num_states >= 3, "replacement needs -num_states >= 3")?;
            Arc::new(ReplacementSpec::standard(num_states))
        }
        "maintenance" => {
            let num_states = db.get_usize("num_states", 50)?;
            require(num_states >= 3, "maintenance needs -num_states >= 3")?;
            Arc::new(MaintenanceSpec::standard(num_states))
        }
        "sis_factored" => {
            let population = db.get_usize("population", 8)?;
            require(
                (3..=24).contains(&population),
                format!("sis_factored needs 3 <= -population <= 24 (2^N flat states), got {population}"),
            )?;
            Arc::new(SisFactoredSpec::new(population).map_err(ApiError)?)
        }
        "factory" => {
            let machines = db.get_usize("machines", 4)?;
            require(
                (2..=12).contains(&machines),
                format!("factory needs 2 <= -machines <= 12 (3^K flat states), got {machines}"),
            )?;
            Arc::new(FactorySpec::new(machines).map_err(ApiError)?)
        }
        other => {
            let names: Vec<&str> = MODEL_CATALOG.iter().map(|m| m.name).collect();
            return Err(match options::suggest(other, &names) {
                Some(near) => ApiError(format!(
                    "unknown model '{other}' (did you mean '{near}'?)"
                )),
                None => ApiError(format!("unknown model '{other}'")),
            });
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn db(toks: &[&str]) -> Options {
        Options::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn catalog_matches_registry() {
        // every catalog name instantiates; an off-catalog name errors
        for info in MODEL_CATALOG {
            let g = model_from_options(info.name, &db(&[])).unwrap();
            assert!(g.n_states() > 0, "{}", info.name);
        }
        assert!(model_from_options("not_a_model", &db(&[])).is_err());
    }

    #[test]
    fn bad_model_params_are_errors_not_panics() {
        // these all hit assert!s in the spec constructors if not caught
        assert!(model_from_options("garnet", &db(&["-branching", "0"])).is_err());
        assert!(model_from_options("garnet", &db(&["-branching", "2000"])).is_err());
        assert!(model_from_options("replacement", &db(&["-num_states", "2"])).is_err());
        assert!(model_from_options("maze", &db(&["-rows", "1"])).is_err());
        assert!(model_from_options("sis", &db(&["-num_actions", "0"])).is_err());
        assert!(model_from_options("sis_factored", &db(&["-population", "2"])).is_err());
        assert!(model_from_options("sis_factored", &db(&["-population", "30"])).is_err());
        assert!(model_from_options("factory", &db(&["-machines", "1"])).is_err());
    }

    #[test]
    fn unknown_model_suggests() {
        let err = model_from_options("mazee", &db(&[])).unwrap_err();
        assert!(err.0.contains("unknown model"), "{err}");
        assert!(err.0.contains("maze"), "{err}");
    }

    #[test]
    fn conflicting_and_missing_sources() {
        let none = MdpBuilder::new();
        assert!(none.resolved_source().unwrap_err().0.contains("no model source"));
        let both = MdpBuilder::from_file("x.mdpb").fillers(
            1,
            1,
            |_, _| vec![(0, 1.0)],
            |_, _| 0.0,
        );
        let err = both.resolved_source().unwrap_err();
        assert!(err.0.contains("conflicting"), "{err}");
        assert!(err.0.contains("file and fillers"), "{err}");
    }

    #[test]
    fn source_conflict_is_recorded_at_set_time() {
        // the conflict text is frozen when the second source is added...
        let both = MdpBuilder::from_file("x.mdpb").model(
            model_from_options("maze", &db(&["-rows", "2", "-cols", "2"])).unwrap(),
        );
        assert!(both.source_conflict.is_some());
        assert!(both
            .source_conflict
            .as_deref()
            .unwrap()
            .contains("file and model"));
        // ...and every fallible call reports it, including build_serial
        let err = both.build_serial().unwrap_err();
        assert!(err.0.contains("conflicting"), "{err}");
        // three sources name all three kinds
        let three = both.fillers(1, 1, |_, _| vec![(0, 1.0)], |_, _| 0.0);
        let err = three.resolved_source().unwrap_err();
        assert!(err.0.contains("file and model and fillers"), "{err}");
    }

    #[test]
    fn builder_patches_apply_through_build_serial() {
        let base = MdpBuilder::from_fillers(
            2,
            2,
            |s, a| match (s, a) {
                (0, 0) => vec![(0, 1.0)],
                (0, 1) => vec![(1, 1.0)],
                _ => vec![(1, 1.0)],
            },
            |s, a| match (s, a) {
                (0, 0) => 1.0,
                (0, 1) => 1.5,
                _ => 0.0,
            },
        )
        .gamma(0.5);
        let patched = base
            .clone()
            .patch_costs([(0, 1, 9.0)])
            .patch_transitions([(0, 0, vec![(0, 0.5), (1, 0.5)])]);
        assert!(patched.has_patches() && !base.has_patches());
        let mdp = patched.build_serial().unwrap();
        assert_eq!(mdp.cost(0, 1), 9.0);
        assert_eq!(mdp.transitions().row(0).1, &[0.5, 0.5]);
        // bad deltas are typed errors from the touched-row validators
        let err = base
            .clone()
            .patch_transitions([(0, 0, vec![(0, 0.2)])])
            .build_serial()
            .unwrap_err();
        assert!(err.0.contains("sums to"), "{err}");
        let err = base
            .patch_costs([(5, 0, 1.0)])
            .build_serial()
            .unwrap_err();
        assert!(err.0.contains("out of range"), "{err}");
    }

    #[test]
    fn from_options_source_selection() {
        assert!(MdpBuilder::from_options(&db(&["-file", "a.mdpb", "-model", "maze"])).is_err());
        let file = MdpBuilder::from_options(&db(&["-file", "a.mdpb"])).unwrap();
        assert!(matches!(file.resolved_source().unwrap(), Source::File(_)));
        let default = MdpBuilder::from_options(&db(&[])).unwrap();
        assert!(matches!(default.resolved_source().unwrap(), Source::Model(_)));
    }

    #[test]
    fn build_serial_validates_gamma_and_rows() {
        let bad_gamma = MdpBuilder::from_fillers(1, 1, |_, _| vec![(0, 1.0)], |_, _| 0.0)
            .gamma(1.5);
        assert!(bad_gamma.build_serial().unwrap_err().0.contains("gamma"));

        let substochastic =
            MdpBuilder::from_fillers(2, 1, |_, _| vec![(0, 0.5)], |_, _| 0.0).gamma(0.9);
        let err = substochastic.build_serial().unwrap_err();
        assert!(err.0.contains("sums to"), "{err}");

        let ok = MdpBuilder::from_fillers(2, 1, |s, _| vec![(s, 1.0)], |_, _| 1.0)
            .gamma(0.9)
            .build_serial()
            .unwrap();
        assert_eq!(ok.n_states(), 2);
    }

    #[test]
    fn factored_source_builds_and_conflicts_like_any_other() {
        let f = crate::models::sis_factored::SisFactoredSpec::new(3)
            .unwrap()
            .factored_mdp()
            .clone();
        let mdp = MdpBuilder::from_factored(f.clone())
            .gamma(0.9)
            .build_serial()
            .unwrap();
        assert_eq!(mdp.n_states(), 8);
        assert_eq!(mdp.n_actions(), 2);
        let both = MdpBuilder::from_file("x.mdpb").factored(f);
        let err = both.resolved_source().unwrap_err();
        assert!(err.0.contains("file and factored"), "{err}");
    }

    #[test]
    fn file_source_rejects_builder_gamma() {
        let b = MdpBuilder::from_file("whatever.mdpb").gamma(0.9);
        let err = b.build_serial().unwrap_err();
        assert!(err.0.contains("header"), "{err}");
    }
}
