//! [`Solver`] — the embedded solve handle carrying a madupite/PETSc-style
//! options database, plus the [`SolveOutcome`] output surface
//! (`write_policy` / `write_cost` / `write_json_metadata`).
//!
//! The CLI `solve` command and the embedded API both funnel through
//! [`run_solve`]: one code path resolves the options database, realizes the
//! model source on every rank, runs the distributed solver and gathers the
//! result — the parity test in `tests/api.rs` checks the two entry points
//! produce byte-identical metadata JSON for the same option set.

use crate::comm::World;
use crate::factored::{solve_svi, FactoredMdp, FactoredMode, FactoredOrder, SviOptions};
use crate::mdp::{io, Discount, DiscountMode, DistMdp, Mdp, Objective};
use crate::solver::{gather_result, solve_dist, IterRecord, SolveOptions, SolveResult};
use crate::util::args::Options;
use crate::util::json::Json;
use std::fmt::Write as _;
use std::path::Path;
use std::sync::Arc;

use super::builder::{DiscountFn, MdpBuilder, Source};
use super::checkpoint::{self, WarmStart};
use super::{options, ApiError};

/// An embedded solve handle: a model (from an [`MdpBuilder`]) plus a
/// PETSc-style options database. Every knob of the CLI is available through
/// [`set_option`](Self::set_option) under the same `-key` spelling, and is
/// resolved through the same table — unknown keys are hard errors with a
/// nearest-key suggestion.
///
/// ```
/// use madupite::api::{MdpBuilder, Solver};
///
/// let builder = MdpBuilder::from_fillers(
///     2,
///     2,
///     |s, a| match (s, a) {
///         (0, 0) => vec![(0, 1.0)],
///         (0, 1) => vec![(1, 1.0)],
///         _ => vec![(1, 1.0)],
///     },
///     |s, a| match (s, a) {
///         (0, 0) => 1.0,
///         (0, 1) => 1.5,
///         _ => 0.0,
///     },
/// )
/// .gamma(0.5);
///
/// let mut solver = Solver::new(builder);
/// solver.set_option("-method", "ipi").unwrap();
/// solver.set_option("-ksp_type", "gmres").unwrap();
/// solver.set_option("-atol", "1e-10").unwrap();
/// let outcome = solver.solve().unwrap();
/// assert!(outcome.result.converged);
/// assert!((outcome.result.value[0] - 1.5).abs() < 1e-8);
/// assert_eq!(outcome.result.policy[0], 1);
/// ```
pub struct Solver {
    builder: MdpBuilder,
    db: Options,
}

impl Solver {
    /// Solver over `builder` with an empty options database (all defaults).
    pub fn new(builder: MdpBuilder) -> Solver {
        Solver {
            builder,
            db: Options::default(),
        }
    }

    /// Solver over `builder` with a pre-populated database (the CLI hands
    /// its parsed argv straight in here).
    pub fn with_database(builder: MdpBuilder, db: Options) -> Solver {
        Solver { builder, db }
    }

    /// Read access to the options database.
    pub fn database(&self) -> &Options {
        &self.db
    }

    /// Set one option, PETSc style: `set_option("-ksp_type", "gmres")`.
    /// The leading dash is optional; unknown keys are rejected immediately
    /// with a nearest-key suggestion. Pass `""` as the value for boolean
    /// flags (`set_option("-verbose", "")`).
    pub fn set_option(&mut self, key: &str, value: &str) -> Result<&mut Solver, ApiError> {
        let key = key.trim_start_matches('-');
        options::check_key(key)?;
        self.db.set(key, value);
        Ok(self)
    }

    /// Ingest a whitespace-separated option string:
    /// `set_options_from_str("-method ipi -ksp_type gmres -alpha 1e-4")`.
    pub fn set_options_from_str(&mut self, text: &str) -> Result<&mut Solver, ApiError> {
        self.set_options_from_args(text.split_whitespace().map(str::to_string))
    }

    /// Ingest argv-style options (e.g. `std::env::args().skip(1)`).
    /// Every token must belong to a `-key value` pair or flag — a stray
    /// bare token (e.g. `method vi` without the dash) is an error, so a
    /// malformed option string can never silently solve with defaults.
    pub fn set_options_from_args<I>(&mut self, args: I) -> Result<&mut Solver, ApiError>
    where
        I: IntoIterator<Item = String>,
    {
        let parsed = Options::parse(args);
        if let Some(first) = parsed.positional().first() {
            return Err(ApiError(format!(
                "stray token '{first}': options must be '-key value' pairs or '-flag's"
            )));
        }
        options::validate_keys(&parsed)?;
        self.db = std::mem::take(&mut self.db).merge(parsed);
        Ok(self)
    }

    /// Ingest the `MADUPITE_OPTIONS` environment variable (PETSc's
    /// `PETSC_OPTIONS` idiom), if set — with the same semantics as the CLI
    /// front end: the env layer is the *lowest* priority (options already
    /// in the database keep winning over it, whenever this is called), a
    /// `-options_file` in it is read and layered just above the env
    /// options, and env-supplied `-gamma`/`-objective`/`-model`/`-file`
    /// defaults silently yield when the builder's source already carries
    /// them (a `.mdpb` header, or any programmatically fixed source).
    pub fn set_options_from_env(&mut self) -> Result<&mut Solver, ApiError> {
        let Ok(text) = std::env::var("MADUPITE_OPTIONS") else {
            return Ok(self);
        };
        let mut parsed = Options::parse(text.split_whitespace().map(str::to_string));
        if let Some(first) = parsed.positional().first() {
            return Err(ApiError(format!(
                "MADUPITE_OPTIONS may only contain -key value options, \
                 found stray token '{first}'"
            )));
        }
        // The builder's source is fixed at construction; env-layer source
        // selection keys are CLI defaults and do not apply here.
        parsed.take("model");
        parsed.take("file");
        // Env-layer gamma/objective/discount_mode are *defaults*: they
        // yield silently whenever the builder already carries a value — a
        // .mdpb header (file source), a programmatic .gamma()/.objective()
        // call, or a discount_filler (which fixes the representation).
        let source_is_file = matches!(self.builder.resolved_source(), Ok(Source::File(_)));
        let has_filler = self.builder.discount_filler_value().is_some();
        if source_is_file || has_filler || self.builder.gamma_value().is_some() {
            parsed.take("gamma");
        }
        if source_is_file || self.builder.objective_value().is_some() {
            parsed.take("objective");
        }
        if source_is_file || has_filler {
            parsed.take("discount_mode");
        }
        // Mirror the CLI: -options_file is consumed here, layered between
        // the env options and everything already set.
        if let Some(path) = parsed.take("options_file") {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| ApiError(format!("reading -options_file {path}: {e}")))?;
            let file_opts = Options::parse_file(&text);
            if let Some(first) = file_opts.positional().first() {
                return Err(ApiError(format!(
                    "-options_file may only contain -key value options, \
                     found stray token '{first}'"
                )));
            }
            parsed = parsed.merge(file_opts);
        }
        options::validate_keys(&parsed)?;
        self.db = parsed.merge(std::mem::take(&mut self.db));
        Ok(self)
    }

    /// Solve the configured model on `-ranks` SPMD ranks (default 1) and
    /// return the gathered outcome. Collective under the hood; the returned
    /// outcome lives on the calling thread (the "root gather" of the
    /// original `writePolicy`/`writeCost` path).
    pub fn solve(&self) -> Result<SolveOutcome, ApiError> {
        run_solve(&self.builder, &self.db)
    }

    /// Split validation from iteration for re-solve loops: resolve the
    /// options database, realize and fully validate the model *once*
    /// (applying any queued builder deltas with touched-row-only
    /// re-validation), and return a [`PreparedModel`] that
    /// [`Self::solve_prepared`] can iterate on. Goes through the exact same
    /// resolution path as [`Self::solve`], so precedence rules, conflict
    /// checks and error text are identical — only the per-solve
    /// re-validation cost is gone.
    pub fn build(&self) -> Result<PreparedModel, ApiError> {
        let resolved = resolve_inputs(&self.builder, &self.db)?;
        if resolved.factored_mode == FactoredMode::Svi {
            return Err(ApiError(
                "-factored_mode svi solves on decision diagrams, not a flat \
                 prepared model — call Solver::solve directly (or use \
                 -factored_mode compile to prepare the flattened model)"
                    .into(),
            ));
        }
        let mdp = build_patched_serial(
            &self.builder,
            &resolved.source,
            &resolved.discount_filler,
            resolved.dmode,
            resolved.gamma,
            resolved.objective,
        )?;
        if let Some(ws) = &resolved.warm {
            ws.check_compat(mdp.n_states(), mdp.n_actions(), mdp.gamma(), mdp.objective())?;
        }
        Ok(PreparedModel {
            mdp: Arc::new(mdp),
            options: resolved.solve_opts,
            ranks: resolved.ranks,
            threads: resolved.threads,
            warm: resolved.warm,
        })
    }

    /// Solve a [`PreparedModel`] produced by [`Self::build`] — the
    /// iteration half of a re-solve loop. The model is already validated:
    /// every rank slices its row block from the prepared model (the slicing
    /// is partition-independent), the solve is seeded from the prepared
    /// warm start if one is attached, and the configured `-write_*`
    /// outputs run exactly as in [`Self::solve`]. The prepared model is
    /// reusable: repeated calls give bitwise-identical outcomes.
    pub fn solve_prepared(&self, prepared: &PreparedModel) -> Result<SolveOutcome, ApiError> {
        crate::util::par::set_threads(prepared.threads);
        if let Some(mode) = options::resolve_comm_overlap(&self.db)? {
            crate::comm::overlap::set_mode(mode);
        }
        let overlap_mode = crate::comm::overlap::current();
        let model = Arc::clone(&prepared.mdp);
        let mut so = prepared.options.clone();
        if let Some(ws) = &prepared.warm {
            // Compatibility was checked when the seed was attached; patches
            // cannot change the model shape afterwards, so it stays valid.
            so.v0 = Some(ws.value.as_ref().clone());
        }
        let ranks = prepared.ranks;
        let results: Vec<SolveResult> = World::run(ranks, move |comm| {
            let mdp = DistMdp::from_serial(&comm, &model);
            let local = solve_dist(&comm, &mdp, &so);
            gather_result(&comm, local)
        });
        let result = results
            .into_iter()
            .next()
            .expect("world returns at least one rank");
        let outcome = SolveOutcome {
            n_states: result.value.len(),
            n_actions: prepared.mdp.n_actions(),
            gamma: prepared.mdp.gamma(),
            objective: prepared.mdp.objective(),
            discount_mode: prepared.mdp.discount().mode(),
            options: prepared.options.clone(),
            ranks,
            threads: prepared.threads,
            comm_overlap: overlap_mode,
            warm_start: prepared.warm.as_ref().map(|ws| ws.fingerprint().to_string()),
            result,
        };
        write_outputs(&outcome, &self.db)?;
        Ok(outcome)
    }
}

/// A validated, ready-to-iterate model: the output of [`Solver::build`].
///
/// Separates the fallible, expensive half of a solve (option resolution,
/// model realization, full stochasticity validation) from the iteration
/// itself, so a drifting-model loop pays validation once:
/// patch → warm-start → [`Solver::solve_prepared`] → repeat. Deltas applied
/// through [`Self::patch_costs`] / [`Self::patch_transitions`] re-validate
/// only the touched rows — untouched rows are never re-scanned.
pub struct PreparedModel {
    mdp: Arc<Mdp>,
    options: SolveOptions,
    ranks: usize,
    threads: usize,
    warm: Option<WarmStart>,
}

impl PreparedModel {
    /// Global state count of the prepared model.
    pub fn n_states(&self) -> usize {
        self.mdp.n_states()
    }

    /// Action count of the prepared model.
    pub fn n_actions(&self) -> usize {
        self.mdp.n_actions()
    }

    /// Uniform discount bound of the prepared model (the scalar γ for
    /// classic MDPs, `max γ(s,a)` for semi-MDPs).
    pub fn gamma(&self) -> f64 {
        self.mdp.gamma()
    }

    /// Overwrite individual `(state, action, cost)` entries in place. Only
    /// the patched entries are validated (in range, finite); all-or-nothing
    /// — on error the model is unchanged.
    pub fn patch_costs(&mut self, rows: &[(usize, usize, f64)]) -> Result<(), ApiError> {
        Arc::make_mut(&mut self.mdp)
            .patch_costs(rows)
            .map_err(ApiError)
    }

    /// Replace individual `(state, action)` transition rows in place. Each
    /// replacement row is validated exactly like a filler row (targets in
    /// range, probabilities summing to 1 within `1e-8`); rows not named in
    /// `blocks` are not re-scanned.
    pub fn patch_transitions(
        &mut self,
        blocks: &[(usize, usize, Vec<(usize, f64)>)],
    ) -> Result<(), ApiError> {
        Arc::make_mut(&mut self.mdp)
            .patch_transitions(blocks)
            .map_err(ApiError)
    }

    /// Seed the next [`Solver::solve_prepared`] call from a previous
    /// outcome — typically the pre-drift solve of the same model. Shape,
    /// gamma and objective compatibility are checked immediately against
    /// the prepared model: a mismatch is a typed error here, not at solve
    /// time.
    pub fn warm_start(&mut self, outcome: &SolveOutcome) -> Result<(), ApiError> {
        let ws = WarmStart::from_outcome(outcome);
        ws.check_compat(
            self.mdp.n_states(),
            self.mdp.n_actions(),
            self.mdp.gamma(),
            self.mdp.objective(),
        )?;
        self.warm = Some(ws);
        Ok(())
    }

    /// Drop the warm-start seed: the next [`Solver::solve_prepared`] call
    /// runs cold.
    pub fn clear_warm_start(&mut self) {
        self.warm = None;
    }
}

/// Everything the pre-model half of a solve derives from a builder plus an
/// options database — the shared front end of [`run_solve`] and
/// [`Solver::build`], so the two can never drift in validation, precedence
/// or error text.
struct Resolved {
    solve_opts: SolveOptions,
    ranks: usize,
    threads: usize,
    overlap: Option<crate::comm::OverlapMode>,
    source: Source,
    discount_filler: Option<DiscountFn>,
    dmode: Option<DiscountMode>,
    gamma: f64,
    objective: Objective,
    warm: Option<WarmStart>,
    /// The factored description behind the source, when there is one
    /// (a [`Source::Factored`], or a catalog model exposing
    /// `ModelGenerator::factored`).
    factored: Option<Arc<FactoredMdp>>,
    /// Effective consumption path for a factored source
    /// (`-factored_mode`, default compile). Meaningless when `factored`
    /// is `None`.
    factored_mode: FactoredMode,
    /// ADD elimination order for the structured solver
    /// (`-factored_order`).
    factored_order: FactoredOrder,
}

/// Validate the database and resolve every pre-model input of a solve:
/// solver options, ranks/threads, overlap mode, the model source, discount
/// semantics, gamma/objective precedence, and the warm-start seed. Pure —
/// no process-global state is installed here, so [`Solver::build`] can call
/// it without side effects.
fn resolve_inputs(builder: &MdpBuilder, db: &Options) -> Result<Resolved, ApiError> {
    options::validate_keys(db)?;
    if db.has("options_file") {
        return Err(ApiError(
            "-options_file is consumed by the CLI front end; in the embedded API read the \
             file and pass its contents to Solver::set_options_from_str"
                .into(),
        ));
    }
    let solve_opts = options::resolve_solve_options(db)?;
    let ranks = db.get_usize("ranks", 1)?;
    if ranks == 0 {
        return Err(ApiError("-ranks must be >= 1".into()));
    }
    let threads = options::resolve_threads(db)?;
    let overlap = options::resolve_comm_overlap(db)?;
    let source = builder.resolved_source()?.clone();
    let discount_filler = builder.discount_filler_value().cloned();
    let dmode = options::resolve_discount_mode(db)?;

    // Factored sources (DESIGN.md §17): a Source::Factored, or a catalog
    // model that exposes its factored description. `-factored_mode`
    // selects the consumption path; `svi` is the serial structured solver,
    // so everything that only makes sense for the flat distributed path
    // is a typed conflict up front.
    let factored: Option<Arc<FactoredMdp>> = match &source {
        Source::Factored(f) => Some(Arc::clone(f)),
        Source::Model(g) => g.factored().map(|f| Arc::new(f.clone())),
        _ => None,
    };
    let factored_mode = options::resolve_factored_mode(db)?;
    if factored_mode.is_some() && factored.is_none() {
        return Err(ApiError(
            "-factored_mode requires a factored source: MdpBuilder::from_factored, \
             or a factored catalog model (sis_factored, factory)"
                .into(),
        ));
    }
    let factored_mode = factored_mode.unwrap_or_default();
    if db.has("factored_order") && factored_mode != FactoredMode::Svi {
        return Err(ApiError(
            "-factored_order is the ADD elimination order of the structured \
             solver; it requires -factored_mode svi"
                .into(),
        ));
    }
    let factored_order = options::resolve_factored_order(db)?;
    if factored_mode == FactoredMode::Svi {
        if ranks != 1 {
            return Err(ApiError(format!(
                "-factored_mode svi runs serially on ADDs (got -ranks {ranks}); \
                 use -factored_mode compile for the distributed path"
            )));
        }
        if db.has("warm_start") || builder.warm_start_value().is_some() {
            return Err(ApiError(
                "-factored_mode svi computes on decision diagrams and cannot \
                 seed from a flat value vector; drop the warm start or use \
                 -factored_mode compile"
                    .into(),
            ));
        }
        if builder.has_patches() {
            return Err(ApiError(
                "queued cost/transition patches apply to the flat model; \
                 -factored_mode svi cannot honor them — use -factored_mode \
                 compile or rebuild the factored spec"
                    .into(),
            ));
        }
        if dmode.is_some() && dmode != Some(DiscountMode::Scalar) {
            return Err(ApiError(format!(
                "-factored_mode svi solves with the scalar discount; \
                 -discount_mode {} does not apply",
                dmode.unwrap().name()
            )));
        }
    }

    // Discount-source conflicts (all typed errors, checked before the
    // world spawns): the filler closure belongs to closure sources and
    // excludes any scalar gamma (one shared check with the builder), a
    // .mdpb carries its own representation, and a semi-MDP's per-(s,a)
    // factors cannot be narrowed to scalar/per-state without solving a
    // different model.
    builder.validate_discount_filler(&source, db.has("gamma"))?;
    match &source {
        Source::File(path) => {
            if dmode.is_some() {
                return Err(ApiError(format!(
                    "the discount representation comes from the .mdpb header of \
                     '{path}'; drop -discount_mode"
                )));
            }
        }
        Source::Model(generator) => {
            options::check_discount_narrowing(dmode, generator.has_discounts(), "solve")?;
        }
        _ => {}
    }
    if discount_filler.is_some()
        && matches!(dmode, Some(DiscountMode::Scalar) | Some(DiscountMode::PerState))
    {
        return Err(ApiError(format!(
            "discount_filler produces per-state-action discounts; \
             -discount_mode {} conflicts with it",
            dmode.unwrap().name()
        )));
    }

    // gamma/objective: for model/closure sources they resolve from the
    // database (falling back to the builder, then defaults); a .mdpb file
    // carries its own in the header, so overriding is a conflict error.
    let (gamma, objective) = match &source {
        Source::File(path) => {
            if db.has("gamma") || builder.gamma_value().is_some() {
                return Err(ApiError(format!(
                    "gamma comes from the .mdpb header of '{path}'; drop -gamma"
                )));
            }
            if db.has("objective") || builder.objective_value().is_some() {
                return Err(ApiError(format!(
                    "objective comes from the .mdpb header of '{path}'; drop -objective"
                )));
            }
            (0.0, Objective::Min) // placeholders; the header supplies both
        }
        _ if discount_filler.is_some() => (
            // the filler supplies γ(s,a); no scalar gamma participates
            0.0,
            options::resolve_objective(db, builder.objective_value())?,
        ),
        _ => (
            options::resolve_gamma(db, builder.gamma_value())?,
            options::resolve_objective(db, builder.objective_value())?,
        ),
    };

    // Warm start: `-warm_start <path|fingerprint>` and the in-process
    // builder seed (`MdpBuilder::warm_start`) are one surface — setting
    // both is a typed conflict, mirroring the model-source rule.
    let warm: Option<WarmStart> = match (db.get("warm_start"), builder.warm_start_value()) {
        (Some(spec), Some(_)) => {
            return Err(ApiError(format!(
                "conflicting warm-start sources: -warm_start {spec} and \
                 MdpBuilder::warm_start are both set — choose exactly one"
            )))
        }
        (Some(spec), None) => Some(checkpoint::load_warm_start(spec, db)?),
        (None, Some(ws)) => Some(ws.clone()),
        (None, None) => None,
    };

    Ok(Resolved {
        solve_opts,
        ranks,
        threads,
        overlap,
        source,
        discount_filler,
        dmode,
        gamma,
        objective,
        warm,
        factored,
        factored_mode,
        factored_order,
    })
}

/// The one shared solve path behind the CLI `solve` command and
/// [`Solver::solve`]: validate the database, resolve options, realize the
/// model source on every rank, solve, gather.
pub fn run_solve(builder: &MdpBuilder, db: &Options) -> Result<SolveOutcome, ApiError> {
    let resolved = resolve_inputs(builder, db)?;
    // Hybrid ranks × threads: install the intra-rank worker-thread count
    // before the world spawns, so every rank's lazily created pool (see
    // `util::par`) picks it up. Results are thread-count independent.
    crate::util::par::set_threads(resolved.threads);
    // Communication overlap: an explicit -comm_overlap installs the
    // process-global mode before the world spawns; otherwise any earlier
    // set_mode / MADUPITE_COMM_OVERLAP / auto stays in effect. Either way
    // the schedule is a pure scheduling knob — results are bitwise
    // identical (tests/par_determinism.rs).
    if let Some(mode) = resolved.overlap {
        crate::comm::overlap::set_mode(mode);
    }
    let overlap_mode = crate::comm::overlap::current();

    // Structured value iteration (DESIGN.md §17): the factored source
    // solves entirely on ADDs — serial, no world, no flat model ever
    // materialized. Every flat-only knob (ranks, warm starts, patches,
    // vector discount modes) was rejected in resolve_inputs, so from here
    // the path is straight: solve, adapt the report, share write_outputs.
    if resolved.factored_mode == FactoredMode::Svi {
        let fmdp = resolved
            .factored
            .as_ref()
            .expect("resolve_inputs pins svi to factored sources");
        let svi_opts = SviOptions {
            atol: resolved.solve_opts.atol,
            max_iter: resolved.solve_opts.max_outer,
            order: resolved.factored_order,
        };
        let started = std::time::Instant::now();
        let svi = solve_svi(fmdp, resolved.gamma, resolved.objective, &svi_opts)
            .map_err(|e| ApiError(format!("structured value iteration: {e}")))?;
        let wall = started.elapsed().as_secs_f64();
        let trace: Vec<IterRecord> = svi
            .residual_trace
            .iter()
            .enumerate()
            .map(|(k, &residual)| IterRecord {
                outer: k + 1,
                residual,
                inner_iterations: 0,
                spmvs: 0,
                elapsed_s: 0.0,
            })
            .collect();
        let n_states = svi.value.len();
        let result = SolveResult {
            value: svi.value,
            policy: svi.policy,
            outer_iterations: svi.iterations,
            total_spmvs: 0,
            total_inner_iterations: 0,
            residual: svi.residual,
            converged: svi.converged,
            wall_time_s: wall,
            trace,
            comm_bytes: 0,
            comm_time_us: 0,
            gamma: resolved.gamma,
            ranks: 1,
            threads: resolved.threads,
        };
        let outcome = SolveOutcome {
            n_states,
            n_actions: fmdp.n_actions(),
            gamma: resolved.gamma,
            objective: resolved.objective,
            discount_mode: DiscountMode::Scalar,
            options: resolved.solve_opts,
            ranks: 1,
            threads: resolved.threads,
            comm_overlap: overlap_mode,
            warm_start: None,
            result,
        };
        write_outputs(&outcome, db)?;
        return Ok(outcome);
    }

    let Resolved {
        solve_opts,
        ranks,
        threads,
        source,
        discount_filler,
        dmode,
        gamma,
        objective,
        warm,
        ..
    } = resolved;

    // Incremental deltas: realize the patched model once on the calling
    // thread (touched-row re-validation only) and let every rank slice its
    // block from it. Cold solves (no patches) keep the direct distributed
    // build paths below untouched — bitwise identical to before the patch
    // surface existed.
    let prebuilt: Option<Arc<Mdp>> = if builder.has_patches() {
        Some(Arc::new(build_patched_serial(
            builder,
            &source,
            &discount_filler,
            dmode,
            gamma,
            objective,
        )?))
    } else {
        None
    };

    let so = solve_opts.clone();
    let warm_in_world = warm.clone();
    type RankOut = Result<(SolveResult, usize, f64, Objective, DiscountMode), String>;
    let results: Vec<RankOut> = World::run(ranks, move |comm| {
        let mdp: DistMdp = if let Some(model) = &prebuilt {
            DistMdp::from_serial(&comm, model)
        } else {
            match &source {
            Source::File(path) => io::load_dist(&comm, path.as_str())
                .map_err(|e| format!("loading {path}: {e}"))?,
            Source::Model(generator) => {
                match dmode {
                    // Force a vector representation of a scalar-discount
                    // model: a rank-local constant expansion, bitwise
                    // equivalent by the Discount invariant (the CLI-visible
                    // ablation knob) and O(local rows) in memory.
                    Some(mode) if mode != DiscountMode::Scalar && !generator.has_discounts() => {
                        DistMdp::try_from_fillers_constant(
                            &comm,
                            generator.n_states(),
                            generator.n_actions(),
                            mode,
                            gamma,
                            |s, a| generator.prob_row(s, a),
                            |s, a| generator.cost(s, a),
                        )?
                        .with_objective(objective)
                    }
                    // fallible build: a semi-MDP generator can reject
                    // extreme gammas (effective factor rounding to 1.0) —
                    // typed error on every rank, not a world panic
                    _ => generator
                        .try_build_dist(&comm, gamma)?
                        .with_objective(objective),
                }
            }
            Source::Factored(fmdp) => {
                match dmode {
                    // Same forced-vector expansion as the Model arm:
                    // factored sources carry a scalar discount, so a
                    // vector mode is a constant expansion, bitwise
                    // equivalent by the Discount invariant.
                    Some(mode) if mode != DiscountMode::Scalar => {
                        DistMdp::try_from_fillers_constant(
                            &comm,
                            fmdp.n_states(),
                            fmdp.n_actions(),
                            mode,
                            gamma,
                            |s, a| fmdp.flat_prob_row(s, a),
                            |s, a| fmdp.flat_cost(s, a),
                        )?
                        .with_objective(objective)
                    }
                    _ => fmdp
                        .try_build_dist(&comm, gamma)?
                        .with_objective(objective),
                }
            }
            Source::Fillers {
                n_states,
                n_actions,
                prob,
                cost,
            } => {
                if let Some(disc) = &discount_filler {
                    DistMdp::try_from_fillers_semi(
                        &comm,
                        *n_states,
                        *n_actions,
                        |s, a| disc(s, a),
                        |s, a| prob(s, a),
                        |s, a| cost(s, a),
                    )?
                    .with_objective(objective)
                } else if let Some(mode) = dmode.filter(|&m| m != DiscountMode::Scalar) {
                    DistMdp::try_from_fillers_constant(
                        &comm,
                        *n_states,
                        *n_actions,
                        mode,
                        gamma,
                        |s, a| prob(s, a),
                        |s, a| cost(s, a),
                    )?
                    .with_objective(objective)
                } else {
                    DistMdp::try_from_fillers(
                        &comm,
                        *n_states,
                        *n_actions,
                        gamma,
                        |s, a| prob(s, a),
                        |s, a| cost(s, a),
                    )?
                    .with_objective(objective)
                }
            }
            }
        };
        // Warm-start compatibility is checked against the *realized* model
        // (the only place a .mdpb's shape is known), from global quantities
        // only — every rank reaches the same verdict, so a mismatch is a
        // typed error on all ranks, never a deadlock. The seed is the
        // global value vector; solve_dist scatters it by row range, making
        // the seeding independent of the rank partition.
        let so = match &warm_in_world {
            Some(ws) => {
                ws.check_compat(mdp.n_states(), mdp.n_actions(), mdp.gamma(), mdp.objective())
                    .map_err(|e| e.0)?;
                let mut seeded = so.clone();
                seeded.v0 = Some(ws.value.as_ref().clone());
                seeded
            }
            None => so.clone(),
        };
        let local = solve_dist(&comm, &mdp, &so);
        let shape = (mdp.n_actions(), mdp.gamma(), mdp.objective(), mdp.discount().mode());
        let global = gather_result(&comm, local);
        Ok((global, shape.0, shape.1, shape.2, shape.3))
    });

    // Per-rank results agree (collective error agreement inside the world):
    // surface the first error, otherwise take rank 0's gathered copy.
    let mut gathered = None;
    for r in results {
        match r {
            Err(e) => return Err(ApiError(e)),
            Ok(v) => {
                if gathered.is_none() {
                    gathered = Some(v);
                }
            }
        }
    }
    let (result, n_actions, gamma, objective, discount_mode) =
        gathered.expect("world returns at least one rank");
    let outcome = SolveOutcome {
        n_states: result.value.len(),
        n_actions,
        gamma,
        objective,
        discount_mode,
        options: solve_opts,
        ranks,
        threads,
        comm_overlap: overlap_mode,
        warm_start: warm.map(|ws| ws.fingerprint),
        result,
    };
    write_outputs(&outcome, db)?;
    Ok(outcome)
}

/// The one output path shared by [`run_solve`] and
/// [`Solver::solve_prepared`]: whichever front end put the output keys in
/// the database, the writes happen here (the CLI only reports the paths
/// afterwards).
fn write_outputs(outcome: &SolveOutcome, db: &Options) -> Result<(), ApiError> {
    if let Some(path) = db.get("json") {
        let text = outcome
            .result
            .to_json(&outcome.options.method.name())
            .to_string_pretty();
        std::fs::write(path, text).map_err(|e| ApiError(format!("writing {path}: {e}")))?;
    }
    if let Some(path) = db.get("write_policy") {
        outcome.write_policy(path)?;
    }
    if let Some(path) = db.get("write_cost") {
        outcome.write_cost(path)?;
    }
    if let Some(path) = db.get("write_json_metadata") {
        outcome.write_json_metadata(path)?;
    }
    if let Some(path) = db.get("write_checkpoint") {
        outcome.write_checkpoint(path)?;
    }
    if let Some(dir) = db.get("serve_store") {
        let cache = options::resolve_serve_cache_entries(db)?;
        let store = crate::serve::PolicyStore::on_disk(dir, cache)
            .map_err(|e| ApiError(format!("serve store {dir}: {e}")))?;
        store
            .put_outcome(outcome)
            .map_err(|e| ApiError(format!("serve store {dir}: {e}")))?;
    }
    Ok(())
}

/// Serial twin of the distributed source-realization arms inside
/// [`run_solve`]'s world closure, used by the patch and
/// [`Solver::build`] paths: same gamma/objective/discount-mode semantics,
/// same typed errors, then the queued builder deltas applied on top with
/// touched-row-only re-validation.
fn build_patched_serial(
    builder: &MdpBuilder,
    source: &Source,
    discount_filler: &Option<DiscountFn>,
    dmode: Option<DiscountMode>,
    gamma: f64,
    objective: Objective,
) -> Result<Mdp, ApiError> {
    let mut mdp = match source {
        Source::File(path) => {
            io::load(path).map_err(|e| ApiError(format!("loading {path}: {e}")))?
        }
        Source::Model(generator) => match dmode {
            Some(mode) if mode != DiscountMode::Scalar && !generator.has_discounts() => {
                Mdp::try_from_fillers_discounted(
                    generator.n_states(),
                    generator.n_actions(),
                    Discount::constant(mode, gamma, generator.n_states(), generator.n_actions()),
                    |s, a| generator.prob_row(s, a),
                    |s, a| generator.cost(s, a),
                )
                .map_err(ApiError)?
                .with_objective(objective)
            }
            _ => generator
                .try_build_serial(gamma)
                .map_err(ApiError)?
                .with_objective(objective),
        },
        Source::Factored(fmdp) => match dmode {
            Some(mode) if mode != DiscountMode::Scalar => {
                Mdp::try_from_fillers_discounted(
                    fmdp.n_states(),
                    fmdp.n_actions(),
                    Discount::constant(mode, gamma, fmdp.n_states(), fmdp.n_actions()),
                    |s, a| fmdp.flat_prob_row(s, a),
                    |s, a| fmdp.flat_cost(s, a),
                )
                .map_err(ApiError)?
                .with_objective(objective)
            }
            _ => fmdp
                .try_build_serial(gamma)
                .map_err(ApiError)?
                .with_objective(objective),
        },
        Source::Fillers {
            n_states,
            n_actions,
            prob,
            cost,
        } => {
            if let Some(disc) = discount_filler {
                Mdp::try_from_fillers_semi(
                    *n_states,
                    *n_actions,
                    |s, a| disc(s, a),
                    |s, a| prob(s, a),
                    |s, a| cost(s, a),
                )
                .map_err(ApiError)?
                .with_objective(objective)
            } else if let Some(mode) = dmode.filter(|&m| m != DiscountMode::Scalar) {
                Mdp::try_from_fillers_discounted(
                    *n_states,
                    *n_actions,
                    Discount::constant(mode, gamma, *n_states, *n_actions),
                    |s, a| prob(s, a),
                    |s, a| cost(s, a),
                )
                .map_err(ApiError)?
                .with_objective(objective)
            } else {
                Mdp::try_from_fillers(
                    *n_states,
                    *n_actions,
                    gamma,
                    |s, a| prob(s, a),
                    |s, a| cost(s, a),
                )
                .map_err(ApiError)?
                .with_objective(objective)
            }
        }
    };
    builder.apply_patches(&mut mdp)?;
    Ok(mdp)
}

/// Gathered result of an embedded solve plus everything needed to report
/// it: the resolved solver configuration and the model shape. Produced on
/// the calling thread (root-gathered), so the `write_*` methods are
/// distributed-safe — they run once, never once-per-rank.
pub struct SolveOutcome {
    /// Global state count of the solved MDP.
    pub n_states: usize,
    /// Action count of the solved MDP.
    pub n_actions: usize,
    /// Uniform discount bound actually solved with — the scalar γ for
    /// classic MDPs, `max γ(s,a)` for semi-MDPs (from the options
    /// database, the builder, the model, or the `.mdpb` header).
    pub gamma: f64,
    /// Discount representation actually solved with
    /// (scalar / per-state / per-state-action).
    pub discount_mode: DiscountMode,
    /// Optimization sense actually solved with.
    pub objective: Objective,
    /// The resolved solver options (method, backend, tolerances).
    pub options: SolveOptions,
    /// World size the solve ran on.
    pub ranks: usize,
    /// Intra-rank worker threads per rank (`-threads`) — the second
    /// dimension of the hybrid `ranks × threads` execution.
    pub threads: usize,
    /// Effective communication-overlap mode the solve ran under
    /// (`-comm_overlap` / `MADUPITE_COMM_OVERLAP` / auto).
    pub comm_overlap: crate::comm::OverlapMode,
    /// Warm-start provenance: the 16-hex fingerprint of the seed artifact
    /// or outcome when the solve was warm-started, `None` for cold solves.
    /// Reported in [`Self::metadata_json`] (only when present, so cold
    /// metadata bytes are unchanged) and deliberately **excluded** from
    /// [`Self::fingerprint_json`] — the artifact key is warm-start-neutral.
    pub warm_start: Option<String>,
    /// The gathered global solve result (value, policy, trace).
    pub result: SolveResult,
}

impl SolveOutcome {
    /// The optimal value vector V* (global, gathered).
    pub fn value(&self) -> &[f64] {
        &self.result.value
    }

    /// The optimal policy π* (global, gathered; one action index per state).
    pub fn policy(&self) -> &[usize] {
        &self.result.policy
    }

    /// Solve metadata as JSON: model shape, resolved solver configuration,
    /// and the full result report (madupite's `writeJSONmetadata`).
    ///
    /// Key order is fixed and documented: [`Json`] objects are `BTreeMap`s,
    /// so keys serialize in sorted (lexicographic) order at every nesting
    /// level — top level `madupite_version`, `model`, `result`, `solver`.
    /// The serialization is therefore byte-deterministic for a given
    /// outcome; `tests/serve.rs` pins the exact bytes with a golden test.
    pub fn metadata_json(&self) -> Json {
        let mut solver_keys = vec![
            ("method", Json::str(self.options.method.name())),
            ("eval_backend", Json::str(self.options.eval_backend.name())),
            (
                "inner_precision",
                Json::str(self.options.inner_precision.name()),
            ),
            ("ranks", Json::int(self.ranks as i64)),
            ("threads", Json::int(self.threads as i64)),
            ("atol", Json::num(self.options.atol)),
            ("alpha", Json::num(self.options.alpha)),
            ("adaptive_forcing", Json::Bool(self.options.adaptive_forcing)),
            ("max_iter_pi", Json::int(self.options.max_outer as i64)),
            ("max_iter_ksp", Json::int(self.options.max_inner as i64)),
            ("comm_overlap", Json::str(self.comm_overlap.name())),
            ("async_vi", Json::Bool(self.options.async_vi)),
            (
                "async_vi_staleness",
                Json::int(self.options.async_vi_staleness as i64),
            ),
        ];
        // Warm-start provenance is emitted only when present: cold solves
        // keep the exact metadata bytes pinned by the golden test in
        // tests/serve.rs.
        if let Some(fp) = &self.warm_start {
            solver_keys.push(("warm_start", Json::str(fp)));
        }
        Json::obj(vec![
            ("madupite_version", Json::str(crate::VERSION)),
            (
                "model",
                Json::obj(vec![
                    ("n_states", Json::int(self.n_states as i64)),
                    ("n_actions", Json::int(self.n_actions as i64)),
                    ("gamma", Json::num(self.gamma)),
                    ("discount_mode", Json::str(self.discount_mode.name())),
                    ("objective", Json::str(self.objective.name())),
                ]),
            ),
            ("solver", Json::obj(solver_keys)),
            ("result", self.result.to_json(&self.options.method.name())),
        ])
    }

    /// Write the optimal policy as text: a `#` header line, then one action
    /// index per line in state order (madupite's `writePolicy`).
    pub fn write_policy(&self, path: impl AsRef<Path>) -> Result<(), ApiError> {
        let mut out = String::with_capacity(self.result.policy.len() * 2 + 80);
        let _ = writeln!(
            out,
            "# madupite optimal policy: n_states={} n_actions={} method={}",
            self.n_states,
            self.n_actions,
            self.options.method.name()
        );
        for &a in &self.result.policy {
            let _ = writeln!(out, "{a}");
        }
        write_text(path.as_ref(), &out)
    }

    /// Write the optimal value/cost vector as text: a `#` header line, then
    /// one value per line in state order (madupite's `writeCost`).
    pub fn write_cost(&self, path: impl AsRef<Path>) -> Result<(), ApiError> {
        let mut out = String::with_capacity(self.result.value.len() * 20 + 80);
        let _ = writeln!(
            out,
            "# madupite optimal cost: n_states={} gamma={} objective={}",
            self.n_states,
            self.gamma,
            self.objective.name()
        );
        for &v in &self.result.value {
            let _ = writeln!(out, "{v}");
        }
        write_text(path.as_ref(), &out)
    }

    /// Write [`Self::metadata_json`] pretty-printed (madupite's
    /// `writeJSONmetadata`). Emitted keys are in the fixed sorted order
    /// documented on [`Self::metadata_json`], 2-space indented, with a
    /// trailing newline — the bytes are stable across runs and platforms.
    pub fn write_json_metadata(&self, path: impl AsRef<Path>) -> Result<(), ApiError> {
        let mut text = self.metadata_json().to_string_pretty();
        text.push('\n');
        write_text(path.as_ref(), &text)
    }

    /// Write this outcome as a digest-verified `.mdpa` checkpoint — the
    /// same self-verifying codec the serve store uses — re-loadable as a
    /// warm-start seed via `-warm_start <path>` on the CLI, or via
    /// [`crate::serve::codec::decode`] plus
    /// [`super::WarmStart::from_artifact`] in the embedded API. The CLI
    /// reaches this through `-write_checkpoint <path.mdpa>`.
    pub fn write_checkpoint(&self, path: impl AsRef<Path>) -> Result<(), ApiError> {
        let bytes = crate::serve::PolicyArtifact::from_outcome(self).encode();
        std::fs::write(path.as_ref(), &bytes)
            .map_err(|e| ApiError(format!("writing {}: {e}", path.as_ref().display())))
    }

    /// The canonical fingerprint document this outcome is keyed by in a
    /// [`crate::serve::PolicyStore`]: model shape, the solver configuration
    /// that determines the result, and FNV-1a digests of the value and
    /// policy payloads. Serialized compact with sorted keys (top level
    /// `format`, `model`, `policy_digest`, `solver`, `value_digest`), so
    /// the bytes — and hence [`Self::fingerprint`] — cannot drift.
    ///
    /// The execution shape (`ranks`, `threads`, `comm_overlap`, async-VI
    /// staleness) is deliberately *excluded*: `tests/par_determinism.rs`
    /// pins results bitwise identical across all of it, so a policy solved
    /// on 4 ranks is served under the same key as the single-rank solve.
    pub fn fingerprint_json(&self) -> Json {
        use crate::serve::fingerprint::{fnv1a64_f64s, fnv1a64_usizes, hex16};
        Json::obj(vec![
            ("format", Json::str("madupite-artifact-fp/v1")),
            (
                "model",
                Json::obj(vec![
                    ("n_states", Json::int(self.n_states as i64)),
                    ("n_actions", Json::int(self.n_actions as i64)),
                    ("gamma", Json::num(self.gamma)),
                    ("discount_mode", Json::str(self.discount_mode.name())),
                    ("objective", Json::str(self.objective.name())),
                ]),
            ),
            (
                "solver",
                Json::obj(vec![
                    ("method", Json::str(self.options.method.name())),
                    ("eval_backend", Json::str(self.options.eval_backend.name())),
                    (
                        "inner_precision",
                        Json::str(self.options.inner_precision.name()),
                    ),
                    ("atol", Json::num(self.options.atol)),
                    ("alpha", Json::num(self.options.alpha)),
                    ("adaptive_forcing", Json::Bool(self.options.adaptive_forcing)),
                    ("max_iter_pi", Json::int(self.options.max_outer as i64)),
                    ("max_iter_ksp", Json::int(self.options.max_inner as i64)),
                ]),
            ),
            (
                "value_digest",
                Json::str(hex16(fnv1a64_f64s(&self.result.value))),
            ),
            (
                "policy_digest",
                Json::str(hex16(fnv1a64_usizes(&self.result.policy))),
            ),
        ])
    }

    /// The 16-hex-digit serving fingerprint of this outcome: FNV-1a over
    /// the compact serialization of [`Self::fingerprint_json`]. This is the
    /// artifact key under `-serve_store` and in the serve protocol.
    pub fn fingerprint(&self) -> String {
        use crate::serve::fingerprint::{fnv1a64, hex16};
        hex16(fnv1a64(self.fingerprint_json().to_string().as_bytes()))
    }
}

fn write_text(path: &Path, text: &str) -> Result<(), ApiError> {
    std::fs::write(path, text)
        .map_err(|e| ApiError(format!("writing {}: {e}", path.display())))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn two_state_builder() -> MdpBuilder {
        MdpBuilder::from_fillers(
            2,
            2,
            |s, a| match (s, a) {
                (0, 0) => vec![(0, 1.0)],
                (0, 1) => vec![(1, 1.0)],
                _ => vec![(1, 1.0)],
            },
            |s, a| match (s, a) {
                (0, 0) => 1.0,
                (0, 1) => 1.5,
                _ => 0.0,
            },
        )
        .gamma(0.5)
    }

    #[test]
    fn embedded_solve_happy_path() {
        let mut solver = Solver::new(two_state_builder());
        solver
            .set_option("-method", "ipi")
            .unwrap()
            .set_option("-ksp_type", "gmres")
            .unwrap()
            .set_option("-atol", "1e-10")
            .unwrap();
        let outcome = solver.solve().unwrap();
        assert!(outcome.result.converged);
        prop::close_slices(outcome.value(), &[1.5, 0.0], 1e-8).unwrap();
        assert_eq!(outcome.policy()[0], 1);
        assert_eq!(outcome.n_states, 2);
        assert_eq!(outcome.n_actions, 2);
        assert_eq!(outcome.gamma, 0.5);
    }

    #[test]
    fn unknown_key_rejected_with_suggestion() {
        let mut solver = Solver::new(two_state_builder());
        let err = solver.set_option("-ksp_tpye", "gmres").unwrap_err();
        assert!(err.0.contains("ksp_type"), "{err}");
        let err = solver.set_options_from_str("-methdo vi").unwrap_err();
        assert!(err.0.contains("method"), "{err}");
    }

    #[test]
    fn options_from_str_merges_and_resolves() {
        let mut solver = Solver::new(two_state_builder());
        solver
            .set_options_from_str("-method mpi -sweeps 5 -atol 1e-9")
            .unwrap();
        let outcome = solver.solve().unwrap();
        assert!(outcome.result.converged);
        assert_eq!(outcome.options.method.name(), "mpi(5)");
    }

    #[test]
    fn multi_rank_solve_matches_serial() {
        let serial = Solver::new(two_state_builder()).solve().unwrap();
        let mut dist = Solver::new(two_state_builder());
        dist.set_option("-ranks", "2").unwrap();
        let dist = dist.solve().unwrap();
        prop::close_slices(serial.value(), dist.value(), 1e-9).unwrap();
        assert_eq!(serial.policy(), dist.policy());
        assert_eq!(dist.ranks, 2);
    }

    #[test]
    fn substochastic_fillers_error_not_panic() {
        // the bad row lives on the *last* state so with 3 ranks only the
        // last rank sees it locally — the collective agreement must turn
        // that into an error on every rank, not a deadlock or panic
        let builder = MdpBuilder::from_fillers(
            30,
            2,
            |s, _| {
                if s == 29 {
                    vec![(0, 0.4)]
                } else {
                    vec![(s, 1.0)]
                }
            },
            |_, _| 1.0,
        )
        .gamma(0.9);
        for ranks in ["1", "3"] {
            let mut solver = Solver::new(builder.clone());
            solver.set_option("-ranks", ranks).unwrap();
            let err = solver.solve().unwrap_err();
            assert!(err.0.contains("sums to"), "ranks={ranks}: {err}");
        }
    }

    #[test]
    fn file_source_gamma_conflict() {
        let mut solver = Solver::new(MdpBuilder::from_file("x.mdpb"));
        solver.set_option("-gamma", "0.9").unwrap();
        let err = solver.solve().unwrap_err();
        assert!(err.0.contains("header"), "{err}");
    }

    #[test]
    fn metadata_json_shape() {
        let outcome = Solver::new(two_state_builder()).solve().unwrap();
        let j = outcome.metadata_json();
        assert_eq!(
            j.get("model").unwrap().get("n_states").unwrap().as_f64(),
            Some(2.0)
        );
        assert_eq!(
            j.get("solver").unwrap().get("ranks").unwrap().as_f64(),
            Some(1.0)
        );
        assert_eq!(
            j.get("result").unwrap().get("converged").unwrap().as_bool(),
            Some(true)
        );
        // comm/async knobs are part of the reported configuration
        let s = j.get("solver").unwrap();
        assert!(s.get("comm_overlap").unwrap().as_str().is_some());
        assert_eq!(s.get("async_vi").unwrap().as_bool(), Some(false));
        assert_eq!(s.get("async_vi_staleness").unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn build_and_solve_prepared_matches_solve() {
        let mut solver = Solver::new(two_state_builder());
        solver
            .set_options_from_str("-method ipi -atol 1e-10")
            .unwrap();
        let cold = solver.solve().unwrap();
        let prepared = solver.build().unwrap();
        assert_eq!(prepared.n_states(), 2);
        assert_eq!(prepared.n_actions(), 2);
        assert_eq!(prepared.gamma(), 0.5);
        let a = solver.solve_prepared(&prepared).unwrap();
        assert!(a.result.converged);
        prop::close_slices(a.value(), cold.value(), 1e-12).unwrap();
        assert_eq!(a.policy(), cold.policy());
        // the prepared model is reusable: a second solve is bitwise equal
        let b = solver.solve_prepared(&prepared).unwrap();
        assert_eq!(a.value(), b.value());
        assert_eq!(a.policy(), b.policy());
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn prepared_patch_and_warm_start_loop() {
        let mut solver = Solver::new(two_state_builder());
        solver
            .set_options_from_str("-method ipi -atol 1e-10")
            .unwrap();
        let cold = solver.solve().unwrap();
        let mut prepared = solver.build().unwrap();
        prepared.warm_start(&cold).unwrap();
        let warm = solver.solve_prepared(&prepared).unwrap();
        // seeded from the converged value: bitwise-identical result,
        // provenance recorded, serving fingerprint unchanged (neutrality)
        assert_eq!(warm.value(), cold.value());
        assert_eq!(warm.policy(), cold.policy());
        assert_eq!(warm.warm_start.as_deref(), Some(cold.fingerprint().as_str()));
        assert_eq!(warm.fingerprint(), cold.fingerprint());
        // drift the model: action 0 in state 0 becomes the cheap one
        prepared.patch_costs(&[(0, 0, 0.1)]).unwrap();
        let resolved = solver.solve_prepared(&prepared).unwrap();
        assert!(resolved.result.converged);
        assert!((resolved.value()[0] - 0.2).abs() < 1e-8, "{:?}", resolved.value());
        assert_eq!(resolved.policy()[0], 0);
        // a bad patch is typed and leaves the model usable
        let err = prepared.patch_costs(&[(9, 0, 1.0)]).unwrap_err();
        assert!(err.0.contains("out of range"), "{err}");
        let again = solver.solve_prepared(&prepared).unwrap();
        assert_eq!(again.value(), resolved.value());
    }

    #[test]
    fn prepared_warm_start_mismatch_is_typed() {
        let mut solver = Solver::new(two_state_builder());
        solver.set_option("-atol", "1e-10").unwrap();
        let outcome = solver.solve().unwrap();
        let other =
            MdpBuilder::from_fillers(3, 2, |s, _| vec![(s, 1.0)], |_, _| 1.0).gamma(0.5);
        let mut prepared = Solver::new(other).build().unwrap();
        let err = prepared.warm_start(&outcome).unwrap_err();
        assert!(err.0.contains("states"), "{err}");
        // a rejected seed leaves the prepared model cold and usable
        prepared.clear_warm_start();
        let out = solver.solve_prepared(&solver.build().unwrap()).unwrap();
        assert!(out.result.converged);
    }

    #[test]
    fn factored_svi_through_api_matches_compile() {
        let f = crate::models::sis_factored::SisFactoredSpec::new(4)
            .unwrap()
            .factored_mdp()
            .clone();
        let mut svi = Solver::new(MdpBuilder::from_factored(f.clone()).gamma(0.9));
        svi.set_options_from_str("-factored_mode svi -atol 1e-12 -max_iter_pi 100000")
            .unwrap();
        let svi = svi.solve().unwrap();
        assert!(svi.result.converged);
        let mut flat = Solver::new(MdpBuilder::from_factored(f).gamma(0.9));
        flat.set_options_from_str("-factored_mode compile -atol 1e-12")
            .unwrap();
        let flat = flat.solve().unwrap();
        assert!(flat.result.converged);
        prop::close_slices(svi.value(), flat.value(), 1e-9).unwrap();
        assert_eq!(svi.policy(), flat.policy());
        assert_eq!(svi.discount_mode, DiscountMode::Scalar);
    }

    #[test]
    fn factored_knobs_are_validated() {
        // -factored_mode needs a factored source
        let mut solver = Solver::new(two_state_builder());
        solver.set_option("-factored_mode", "svi").unwrap();
        let err = solver.solve().unwrap_err();
        assert!(err.0.contains("factored source"), "{err}");
        // svi is serial; multi-rank is a typed conflict
        let f = crate::models::sis_factored::SisFactoredSpec::new(3)
            .unwrap()
            .factored_mdp()
            .clone();
        let mut solver = Solver::new(MdpBuilder::from_factored(f.clone()).gamma(0.9));
        solver
            .set_options_from_str("-factored_mode svi -ranks 3")
            .unwrap();
        let err = solver.solve().unwrap_err();
        assert!(err.0.contains("serially"), "{err}");
        // -factored_order without svi
        let mut solver = Solver::new(MdpBuilder::from_factored(f.clone()).gamma(0.9));
        solver.set_option("-factored_order", "auto").unwrap();
        let err = solver.solve().unwrap_err();
        assert!(err.0.contains("factored_mode svi"), "{err}");
        // svi cannot feed a flat PreparedModel, compile can
        let mut solver = Solver::new(MdpBuilder::from_factored(f.clone()).gamma(0.9));
        solver.set_option("-factored_mode", "svi").unwrap();
        assert!(solver.build().unwrap_err().0.contains("prepared"));
        let prepared = Solver::new(MdpBuilder::from_factored(f).gamma(0.9))
            .build()
            .unwrap();
        assert_eq!(prepared.n_states(), 8);
    }

    #[test]
    fn async_vi_through_api() {
        let mut solver = Solver::new(two_state_builder());
        solver
            .set_options_from_str(
                "-method vi -async_vi -async_vi_staleness 3 -ranks 2 -atol 1e-10",
            )
            .unwrap();
        let outcome = solver.solve().unwrap();
        assert!(outcome.result.converged);
        prop::close_slices(outcome.value(), &[1.5, 0.0], 1e-8).unwrap();
        assert_eq!(outcome.policy()[0], 1);
        let s = outcome.metadata_json();
        let s = s.get("solver").unwrap();
        assert_eq!(s.get("async_vi").unwrap().as_bool(), Some(true));
        assert_eq!(s.get("async_vi_staleness").unwrap().as_f64(), Some(3.0));
        // the orphaned-staleness error surfaces through the shared path too
        let mut bad = Solver::new(two_state_builder());
        bad.set_options_from_str("-async_vi").unwrap();
        let err = bad.solve().unwrap_err();
        assert!(err.0.contains("-method vi"), "{err}");
    }
}
