//! The shared options table: every `-key` the CLI and the embedded API
//! accept, with one resolution path from strings to typed solver options.
//!
//! madupite inherits PETSc's options-database UX: solver configuration is a
//! flat set of `-key value` pairs ingested from the command line, an options
//! file, the environment or programmatic `set_option` calls. This module is
//! the single source of truth for that database — [`OPTION_TABLE`] lists
//! every known key (the CLI help is generated from it, so it cannot drift),
//! [`validate_keys`] rejects unknown keys *before* anything runs (with a
//! nearest-key suggestion, so `-ksp_tpye gmres` can no longer silently solve
//! with the default method), and the `resolve_*` functions turn the database
//! into [`Method`]/[`EvalBackend`]/[`SolveOptions`] for **both** the CLI and
//! [`crate::api::Solver`] — proven identical by the parity test in
//! `tests/api.rs`.

use crate::comm::OverlapMode;
use crate::factored::{FactoredMode, FactoredOrder};
use crate::ksp::precond::PcType;
use crate::ksp::KspType;
use crate::mdp::{DiscountMode, Objective};
use crate::solver::{EvalBackend, InnerPrecision, Method, SolveOptions};
use crate::util::args::Options;

use super::ApiError;

/// Which part of the surface an option belongs to (used to group the
/// generated CLI help; resolution itself is scope-blind).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptionScope {
    /// Model/source selection and per-model parameters.
    Model,
    /// Options shared by several commands (`-gamma`, `-ranks`, ...).
    Common,
    /// Outer/inner solver configuration (`solve`).
    Solve,
    /// Result output files (`solve`).
    Output,
    /// Offline generation (`generate`).
    Generate,
    /// Tooling commands (`info`, `artifacts`).
    Tools,
    /// Policy serving (`-serve_store` on `solve`; the `madupite-serve`
    /// binary).
    Serve,
}

/// One entry of the options database schema.
pub struct OptionSpec {
    /// Key as typed after the dash (`ksp_type` for `-ksp_type`).
    pub key: &'static str,
    /// Value placeholder or choice list shown in help (`"<float>"`,
    /// `"gmres|bicgstab|..."`); empty for boolean flags.
    pub value: &'static str,
    /// One-line description shown in the generated help.
    pub help: &'static str,
    /// Help grouping.
    pub scope: OptionScope,
}

/// Every option key the CLI and the embedded API accept. The CLI help and
/// [`validate_keys`] are both driven by this table, so adding a knob here is
/// all it takes to plumb it end to end.
pub const OPTION_TABLE: &[OptionSpec] = &[
    // -- model / source -----------------------------------------------------
    OptionSpec {
        key: "model",
        value: "<name>",
        help: "benchmark model to generate (see the model catalog)",
        scope: OptionScope::Model,
    },
    OptionSpec {
        key: "file",
        value: "<path.mdpb>",
        help: ".mdpb input (solve/info) or output (generate)",
        scope: OptionScope::Model,
    },
    OptionSpec {
        key: "rows",
        value: "<n>",
        help: "grid rows (maze, grid)",
        scope: OptionScope::Model,
    },
    OptionSpec {
        key: "cols",
        value: "<n>",
        help: "grid columns (maze, grid)",
        scope: OptionScope::Model,
    },
    OptionSpec {
        key: "seed",
        value: "<u64>",
        help: "generator seed (maze, garnet)",
        scope: OptionScope::Model,
    },
    OptionSpec {
        key: "population",
        value: "<n>",
        help: "population size (sis) / ring nodes (sis_factored)",
        scope: OptionScope::Model,
    },
    OptionSpec {
        key: "capacity",
        value: "<n>",
        help: "capacity (traffic, inventory, queueing)",
        scope: OptionScope::Model,
    },
    OptionSpec {
        key: "num_states",
        value: "<n>",
        help: "state count (garnet, replacement)",
        scope: OptionScope::Model,
    },
    OptionSpec {
        key: "num_actions",
        value: "<n>",
        help: "action count (garnet, sis)",
        scope: OptionScope::Model,
    },
    OptionSpec {
        key: "branching",
        value: "<n>",
        help: "successors per (s,a) row (garnet)",
        scope: OptionScope::Model,
    },
    OptionSpec {
        key: "machines",
        value: "<n>",
        help: "machine count in the production line (factory)",
        scope: OptionScope::Model,
    },
    // -- common -------------------------------------------------------------
    OptionSpec {
        key: "gamma",
        value: "<float>",
        help: "discount factor in [0, 1) (model sources only; .mdpb carries its own)",
        scope: OptionScope::Common,
    },
    OptionSpec {
        key: "objective",
        value: "min|mincost|max|maxreward",
        help: "optimization sense (model sources only; .mdpb carries its own)",
        scope: OptionScope::Common,
    },
    OptionSpec {
        key: "discount_mode",
        value: "auto|scalar|per_state|per_state_action",
        help: "discount representation: auto follows the source (semi-MDP models \
                use per-(s,a) factors); vector modes expand a scalar model or \
                closure source to a constant vector (.mdpb carries its own)",
        scope: OptionScope::Common,
    },
    OptionSpec {
        key: "ranks",
        value: "<n>",
        help: "world size (SPMD rank-threads)",
        scope: OptionScope::Common,
    },
    OptionSpec {
        key: "threads",
        value: "<n>",
        help: "intra-rank worker threads per rank (hybrid ranks x threads; \
                env MADUPITE_THREADS, default 1; results are thread-count independent)",
        scope: OptionScope::Common,
    },
    OptionSpec {
        key: "verbose",
        value: "",
        help: "per-iteration residual logging on the root rank",
        scope: OptionScope::Common,
    },
    OptionSpec {
        key: "options_file",
        value: "<path>",
        help: "read additional '-key value' lines from a file (CLI overrides it)",
        scope: OptionScope::Common,
    },
    // -- solve --------------------------------------------------------------
    OptionSpec {
        key: "method",
        value: "vi|mpi|pi|ipi",
        help: "outer solution method (default ipi)",
        scope: OptionScope::Solve,
    },
    OptionSpec {
        key: "sweeps",
        value: "<n>",
        help: "T_pi sweeps per outer iteration (mpi)",
        scope: OptionScope::Solve,
    },
    OptionSpec {
        key: "ksp_type",
        value: "richardson|gmres|bicgstab|tfqmr|direct",
        help: "inner Krylov solver (ipi)",
        scope: OptionScope::Solve,
    },
    OptionSpec {
        key: "ksp_gmres_restart",
        value: "<n>",
        help: "GMRES restart length (default 30)",
        scope: OptionScope::Solve,
    },
    OptionSpec {
        key: "ksp_richardson_scale",
        value: "<float>",
        help: "Richardson relaxation omega (default 1.0)",
        scope: OptionScope::Solve,
    },
    OptionSpec {
        key: "pc_type",
        value: "none|jacobi|sor",
        help: "inner-solver preconditioner",
        scope: OptionScope::Solve,
    },
    OptionSpec {
        key: "eval_backend",
        value: "matfree|assembled|bsr",
        help: "policy-evaluation operator: fused matrix-free, cached P_pi CSR, \
                or lane-blocked rows (falls back to matfree on sparse fill)",
        scope: OptionScope::Solve,
    },
    OptionSpec {
        key: "inner_precision",
        value: "f64|f32",
        help: "inner KSP precision (ipi): f32 runs the Krylov iterations on a \
                compressed copy inside an f64 refinement loop",
        scope: OptionScope::Solve,
    },
    OptionSpec {
        key: "atol",
        value: "<float>",
        help: "outer stop: ||TV - V||_inf < atol (default 1e-8)",
        scope: OptionScope::Solve,
    },
    OptionSpec {
        key: "alpha",
        value: "<float>",
        help: "forcing term: inner solve targets alpha * residual (default 1e-4)",
        scope: OptionScope::Solve,
    },
    OptionSpec {
        key: "adaptive_forcing",
        value: "",
        help: "Eisenstat-Walker-style adaptive forcing term",
        scope: OptionScope::Solve,
    },
    OptionSpec {
        key: "max_iter_pi",
        value: "<n>",
        help: "outer iteration cap (default 1000)",
        scope: OptionScope::Solve,
    },
    OptionSpec {
        key: "max_iter_ksp",
        value: "<n>",
        help: "inner iteration cap (default 10000)",
        scope: OptionScope::Solve,
    },
    OptionSpec {
        key: "comm_overlap",
        value: "on|off|auto",
        help: "split-phase ghost exchange overlapping interior-row compute \
                (bitwise identical to off; auto = on for multi-rank worlds; \
                env MADUPITE_COMM_OVERLAP)",
        scope: OptionScope::Solve,
    },
    OptionSpec {
        key: "async_vi",
        value: "",
        help: "bounded-staleness asynchronous value iteration (requires -method vi): \
                local Bellman sweeps between synchronized certified backups",
        scope: OptionScope::Solve,
    },
    OptionSpec {
        key: "async_vi_staleness",
        value: "<n>",
        help: "ghost refresh period k for -async_vi: 1 synchronized + k-1 local \
                sweeps (default 4; k=1 degenerates to synchronous vi)",
        scope: OptionScope::Solve,
    },
    OptionSpec {
        key: "warm_start",
        value: "<path|fingerprint>",
        help: "seed the solve from a checkpoint: a .mdpa file path, or a 16-hex \
                artifact fingerprint looked up in -serve_store (shape/gamma/\
                objective compatibility is checked before solving)",
        scope: OptionScope::Solve,
    },
    OptionSpec {
        key: "factored_mode",
        value: "compile|svi",
        help: "consumption path for factored sources: compile flattens through \
                the distributed builders (default), svi runs SPUDD-style \
                structured value iteration on ADDs (serial)",
        scope: OptionScope::Solve,
    },
    OptionSpec {
        key: "factored_order",
        value: "given|reverse|auto",
        help: "ADD variable elimination order for -factored_mode svi \
                (auto sorts by CPT scope size; results are order-independent)",
        scope: OptionScope::Solve,
    },
    // -- output -------------------------------------------------------------
    OptionSpec {
        key: "json",
        value: "<path>",
        help: "write the raw solve report JSON",
        scope: OptionScope::Output,
    },
    OptionSpec {
        key: "write_policy",
        value: "<path>",
        help: "write the optimal policy (one action index per line)",
        scope: OptionScope::Output,
    },
    OptionSpec {
        key: "write_cost",
        value: "<path>",
        help: "write the optimal value/cost vector (one value per line)",
        scope: OptionScope::Output,
    },
    OptionSpec {
        key: "write_json_metadata",
        value: "<path>",
        help: "write solve metadata JSON (model + solver + result)",
        scope: OptionScope::Output,
    },
    OptionSpec {
        key: "write_checkpoint",
        value: "<path.mdpa>",
        help: "write the solved value/policy as a digest-verified .mdpa checkpoint \
                (re-loadable via -warm_start)",
        scope: OptionScope::Output,
    },
    // -- generate -----------------------------------------------------------
    OptionSpec {
        key: "chunk_rows",
        value: "<n>",
        help: "streaming writer chunk size (generate)",
        scope: OptionScope::Generate,
    },
    // -- tools --------------------------------------------------------------
    OptionSpec {
        key: "dir",
        value: "<path>",
        help: "artifact directory (artifacts)",
        scope: OptionScope::Tools,
    },
    // -- serve --------------------------------------------------------------
    OptionSpec {
        key: "serve_store",
        value: "<path>",
        help: "policy store directory: solve persists there, madupite-serve serves from it",
        scope: OptionScope::Serve,
    },
    OptionSpec {
        key: "serve_cache_entries",
        value: "<n>",
        help: "decoded artifacts the serving LRU may hold (0 disables; default 64)",
        scope: OptionScope::Serve,
    },
    OptionSpec {
        key: "serve_threads",
        value: "<n>",
        help: "worker threads for batched serve lookups (default 1)",
        scope: OptionScope::Serve,
    },
];

/// Look up a key in [`OPTION_TABLE`].
pub fn spec_for(key: &str) -> Option<&'static OptionSpec> {
    OPTION_TABLE.iter().find(|s| s.key == key)
}

/// Reject a single unknown key with a nearest-key suggestion.
pub fn check_key(key: &str) -> Result<(), ApiError> {
    if spec_for(key).is_some() {
        return Ok(());
    }
    let known: Vec<&str> = OPTION_TABLE.iter().map(|s| s.key).collect();
    match suggest(key, &known) {
        Some(near) => Err(ApiError(format!(
            "unknown option '-{key}' (did you mean '-{near}'?)"
        ))),
        None => Err(ApiError(format!(
            "unknown option '-{key}' (run `madupite help` for the full list)"
        ))),
    }
}

/// Hard-error on any key in `db` that is not in [`OPTION_TABLE`]. Run this
/// *before* solving: a typo'd key must fail fast, not silently fall back to
/// a default and solve with the wrong configuration.
pub fn validate_keys(db: &Options) -> Result<(), ApiError> {
    for key in db.keys() {
        check_key(key)?;
    }
    Ok(())
}

/// Nearest candidate by edit distance, if any is close enough to be a
/// plausible typo (distance <= 2 and strictly closer than a full rewrite).
pub fn suggest<'a>(input: &str, candidates: &[&'a str]) -> Option<&'a str> {
    let mut best: Option<(&str, usize)> = None;
    for &cand in candidates {
        let d = edit_distance(input, cand);
        if best.map(|(_, bd)| d < bd).unwrap_or(true) {
            best = Some((cand, d));
        }
    }
    match best {
        Some((cand, d)) if d <= 2 && d < cand.len() => Some(cand),
        _ => None,
    }
}

/// Classic Levenshtein distance (small inputs only — option keys).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Attach a did-you-mean hint for a bad enumerated *value* (e.g.
/// `-ksp_type gmers`).
fn with_value_suggestion(err: String, value: &str, choices: &[&str]) -> ApiError {
    match suggest(value, choices) {
        Some(near) => ApiError(format!("{err} (did you mean '{near}'?)")),
        None => ApiError(err),
    }
}

/// Resolve `-method` (+ its sub-options `-sweeps`, `-ksp_type`,
/// `-ksp_gmres_restart`, `-ksp_richardson_scale`, `-pc_type`) to a
/// [`Method`]. Shared by the CLI and [`crate::api::Solver`].
pub fn resolve_method(db: &Options) -> Result<Method, ApiError> {
    let method = db.get_choice("method", &["vi", "mpi", "pi", "ipi"], "ipi")?;
    Ok(match method.as_str() {
        "vi" => Method::Vi,
        "mpi" => {
            let sweeps = db.get_usize("sweeps", 20)?;
            if sweeps == 0 {
                return Err(ApiError("-sweeps must be >= 1".into()));
            }
            Method::Mpi { sweeps }
        }
        "pi" => Method::ExactPi,
        _ => {
            let ksp_name = db.get_str("ksp_type", "gmres");
            let mut ksp = KspType::parse(&ksp_name).map_err(|e| {
                with_value_suggestion(
                    e,
                    &ksp_name,
                    &["richardson", "gmres", "bicgstab", "tfqmr", "direct"],
                )
            })?;
            if let KspType::Gmres { restart } = &mut ksp {
                *restart = db.get_usize("ksp_gmres_restart", 30)?;
                if *restart == 0 {
                    return Err(ApiError("-ksp_gmres_restart must be >= 1".into()));
                }
            }
            if let KspType::Richardson { omega } = &mut ksp {
                *omega = db.get_f64("ksp_richardson_scale", 1.0)?;
                if !(omega.is_finite() && *omega > 0.0) {
                    return Err(ApiError(format!(
                        "-ksp_richardson_scale must be a positive finite float, got {omega}"
                    )));
                }
            }
            let pc_name = db.get_str("pc_type", "none");
            let pc = PcType::parse(&pc_name)
                .map_err(|e| with_value_suggestion(e, &pc_name, &["none", "jacobi", "sor"]))?;
            Method::Ipi { ksp, pc }
        }
    })
}

/// Resolve the full [`SolveOptions`] from the database — the one shared
/// string→typed path behind both the CLI `solve` command and
/// [`crate::api::Solver::solve`].
pub fn resolve_solve_options(db: &Options) -> Result<SolveOptions, ApiError> {
    let method = resolve_method(db)?;
    let backend_name = db.get_str("eval_backend", "matfree");
    let eval_backend = EvalBackend::parse(&backend_name)
        .map_err(|e| with_value_suggestion(e, &backend_name, &["matfree", "assembled", "bsr"]))?;
    let precision_name = db.get_str("inner_precision", "f64");
    let inner_precision = InnerPrecision::parse(&precision_name)
        .map_err(|e| with_value_suggestion(e, &precision_name, &["f64", "f32"]))?;
    let atol = db.get_f64("atol", 1e-8)?;
    if !(atol.is_finite() && atol > 0.0) {
        return Err(ApiError(format!(
            "-atol must be a positive finite float, got {atol}"
        )));
    }
    let alpha = db.get_f64("alpha", 1e-4)?;
    if !(alpha.is_finite() && alpha > 0.0) {
        return Err(ApiError(format!(
            "-alpha must be a positive finite float, got {alpha}"
        )));
    }
    let max_outer = db.get_usize("max_iter_pi", 1_000)?;
    if max_outer == 0 {
        return Err(ApiError("-max_iter_pi must be >= 1".into()));
    }
    let max_inner = db.get_usize("max_iter_ksp", 10_000)?;
    if max_inner == 0 {
        return Err(ApiError("-max_iter_ksp must be >= 1".into()));
    }
    let async_vi = db.get_bool("async_vi", false)?;
    if async_vi && !matches!(method, Method::Vi) {
        return Err(ApiError(format!(
            "-async_vi requires -method vi (got '{}'); the evaluation methods \
             synchronize inside the inner solve, so stale sweeps do not apply",
            method.name()
        )));
    }
    if db.has("async_vi_staleness") && !async_vi {
        return Err(ApiError(
            "-async_vi_staleness requires -async_vi (it is the ghost refresh \
             period of the asynchronous sweeps)"
            .into(),
        ));
    }
    let async_vi_staleness = db.get_usize("async_vi_staleness", 4)?;
    if async_vi_staleness == 0 {
        return Err(ApiError(
            "-async_vi_staleness must be >= 1 (1 = synchronous vi)".into(),
        ));
    }
    Ok(SolveOptions {
        method,
        eval_backend,
        inner_precision,
        atol,
        max_outer,
        alpha,
        adaptive_forcing: db.get_bool("adaptive_forcing", false)?,
        max_inner,
        v0: None,
        verbose: db.get_bool("verbose", false)?,
        async_vi,
        async_vi_staleness,
    })
}

/// Resolve `-comm_overlap`: `Some(mode)` when the option was given (the
/// caller applies it process-globally via [`crate::comm::overlap::set_mode`]
/// before the world starts), `None` when absent — the effective mode then
/// falls back to any earlier `set_mode` call, the `MADUPITE_COMM_OVERLAP`
/// environment variable, or `auto` (see [`crate::comm::overlap::current`]).
pub fn resolve_comm_overlap(db: &Options) -> Result<Option<OverlapMode>, ApiError> {
    match db.get("comm_overlap") {
        Some(name) => OverlapMode::parse(name)
            .map(Some)
            .map_err(|e| with_value_suggestion(e, name, &["on", "off", "auto"])),
        None => Ok(None),
    }
}

/// Resolve `-threads`, the intra-rank worker thread count of the hybrid
/// `ranks × threads` execution (DESIGN.md §11): the database wins, then a
/// positive-integer `MADUPITE_THREADS` environment variable, then 1
/// (fully serial execution). Zero and negative/non-integer values are
/// typed errors: the thread count can only change speed, never results
/// (`util::par`'s fixed chunk grid), but silently falling back would hide
/// a misconfigured run.
pub fn resolve_threads(db: &Options) -> Result<usize, ApiError> {
    if db.has("threads") {
        let t = db.get_usize("threads", 1)?;
        if t == 0 {
            return Err(ApiError(
                "-threads must be >= 1 (a rank cannot run on 0 threads)".into(),
            ));
        }
        return Ok(t);
    }
    match std::env::var("MADUPITE_THREADS") {
        Ok(s) => match s.trim().parse::<usize>() {
            Ok(t) if t >= 1 => Ok(t),
            _ => Err(ApiError(format!(
                "MADUPITE_THREADS: expected a positive integer, got '{s}'"
            ))),
        },
        Err(_) => Ok(1),
    }
}

/// Resolve `-serve_cache_entries`: how many decoded artifacts the serving
/// LRU may hold. 0 disables caching entirely; default 64.
pub fn resolve_serve_cache_entries(db: &Options) -> Result<usize, ApiError> {
    db.get_usize("serve_cache_entries", 64).map_err(ApiError::from)
}

/// Resolve `-serve_threads`: worker threads for batched serve lookups.
/// Must be >= 1; default 1.
pub fn resolve_serve_threads(db: &Options) -> Result<usize, ApiError> {
    let t = db.get_usize("serve_threads", 1)?;
    if t == 0 {
        return Err(ApiError(
            "-serve_threads must be >= 1 (queries cannot run on 0 threads)".into(),
        ));
    }
    Ok(t)
}

/// Resolve the discount factor: `-gamma` in the database wins, then the
/// builder-level `fallback`, then the crate default 0.99. Validated to
/// [0, 1) — a "bad gamma" is an error here, never a panic downstream.
pub fn resolve_gamma(db: &Options, fallback: Option<f64>) -> Result<f64, ApiError> {
    let gamma = match db.get("gamma") {
        Some(_) => db.get_f64("gamma", 0.0)?,
        None => fallback.unwrap_or(0.99),
    };
    crate::mdp::validate_gamma(gamma).map_err(ApiError)
}

/// Resolve `-discount_mode`: `None` means `auto` (follow the source — a
/// semi-MDP model or a `discount_filler` yields per-state-action factors,
/// everything else the scalar); `Some(mode)` forces the representation.
/// Forcing a vector mode on a scalar source expands it to a constant
/// vector, which solves bitwise identically — the CLI-visible form of the
/// scalar↔vector equivalence invariant (and the overhead-ablation knob in
/// `bench_kernels`). Unknown values are typed errors with a did-you-mean
/// suggestion.
pub fn resolve_discount_mode(db: &Options) -> Result<Option<DiscountMode>, ApiError> {
    match db.get("discount_mode") {
        None | Some("auto") => Ok(None),
        Some(name) => DiscountMode::parse(name).map(Some).map_err(|e| {
            with_value_suggestion(e, name, &["auto", "scalar", "per_state", "per_state_action"])
        }),
    }
}

/// Reject a `-discount_mode` that would narrow a semi-MDP source: a model
/// with per-state-action factors cannot be represented as scalar or
/// per-state without solving/generating a *different* model. One shared
/// rule for `run_solve` and the CLI `generate` command; `verb` names the
/// action for the error text.
pub fn check_discount_narrowing(
    dmode: Option<DiscountMode>,
    has_discounts: bool,
    verb: &str,
) -> Result<(), ApiError> {
    if has_discounts && matches!(dmode, Some(DiscountMode::Scalar) | Some(DiscountMode::PerState)) {
        return Err(ApiError(format!(
            "this model defines per-state-action discounts (a semi-MDP); \
             -discount_mode {} would {verb} a different model — use auto or \
             per_state_action",
            dmode.unwrap().name()
        )));
    }
    Ok(())
}

/// Resolve `-factored_mode`: `Some(mode)` when the option was given (the
/// caller checks the source actually is factored), `None` when absent —
/// factored sources then default to [`FactoredMode::Compile`]. Unknown
/// values are typed errors with a did-you-mean suggestion.
pub fn resolve_factored_mode(db: &Options) -> Result<Option<FactoredMode>, ApiError> {
    match db.get("factored_mode") {
        None => Ok(None),
        Some("compile") => Ok(Some(FactoredMode::Compile)),
        Some("svi") => Ok(Some(FactoredMode::Svi)),
        Some(other) => Err(with_value_suggestion(
            format!("-factored_mode: expected compile|svi, got '{other}'"),
            other,
            &["compile", "svi"],
        )),
    }
}

/// Resolve `-factored_order`, the ADD variable elimination order of the
/// structured solver (default: the declared variable order). The order
/// changes diagram sizes, never results — `tests/factored.rs` pins the
/// invariance.
pub fn resolve_factored_order(db: &Options) -> Result<FactoredOrder, ApiError> {
    match db.get("factored_order") {
        None | Some("given") => Ok(FactoredOrder::Given),
        Some("reverse") => Ok(FactoredOrder::Reverse),
        Some("auto") => Ok(FactoredOrder::Auto),
        Some(other) => Err(with_value_suggestion(
            format!("-factored_order: expected given|reverse|auto, got '{other}'"),
            other,
            &["given", "reverse", "auto"],
        )),
    }
}

/// Resolve the optimization sense: `-objective` wins over the builder-level
/// `fallback`, default min-cost.
pub fn resolve_objective(db: &Options, fallback: Option<Objective>) -> Result<Objective, ApiError> {
    match db.get("objective") {
        Some(name) => Objective::parse(name)
            .map_err(|e| with_value_suggestion(e, name, &["min", "mincost", "max", "maxreward"])),
        None => Ok(fallback.unwrap_or_default()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn db(toks: &[&str]) -> Options {
        Options::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn table_keys_unique() {
        let mut keys: Vec<&str> = OPTION_TABLE.iter().map(|s| s.key).collect();
        let n = keys.len();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), n, "duplicate keys in OPTION_TABLE");
    }

    #[test]
    fn serve_options_resolve() {
        assert_eq!(resolve_serve_cache_entries(&db(&[])).unwrap(), 64);
        assert_eq!(
            resolve_serve_cache_entries(&db(&["-serve_cache_entries", "0"])).unwrap(),
            0
        );
        assert_eq!(resolve_serve_threads(&db(&[])).unwrap(), 1);
        assert_eq!(
            resolve_serve_threads(&db(&["-serve_threads", "8"])).unwrap(),
            8
        );
        assert!(resolve_serve_threads(&db(&["-serve_threads", "0"])).is_err());
        assert!(resolve_serve_cache_entries(&db(&["-serve_cache_entries", "many"])).is_err());
    }

    #[test]
    fn serve_keys_in_table_with_did_you_mean() {
        for key in ["serve_store", "serve_cache_entries", "serve_threads"] {
            assert!(spec_for(key).is_some(), "{key} missing from OPTION_TABLE");
            assert_eq!(spec_for(key).unwrap().scope, OptionScope::Serve);
        }
        let err = check_key("serve_stroe").unwrap_err();
        assert!(err.0.contains("serve_store"), "{err}");
    }

    #[test]
    fn unknown_key_suggests_nearest() {
        let err = check_key("ksp_tpye").unwrap_err();
        assert!(err.0.contains("ksp_tpye"), "{err}");
        assert!(err.0.contains("ksp_type"), "{err}");
        assert!(check_key("ksp_type").is_ok());
        // far-off keys get the generic message, not a wild guess
        let err = check_key("zzzzzzzzzz").unwrap_err();
        assert!(!err.0.contains("did you mean"), "{err}");
    }

    #[test]
    fn validate_keys_rejects_typos() {
        assert!(validate_keys(&db(&["-gamma", "0.9", "-atol", "1e-8"])).is_ok());
        let err = validate_keys(&db(&["-gamma", "0.9", "-methdo", "vi"])).unwrap_err();
        assert!(err.0.contains("method"), "{err}");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("gmres", "gmres"), 0);
        assert_eq!(edit_distance("gmers", "gmres"), 2);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }

    #[test]
    fn method_resolution_all_spellings() {
        assert_eq!(resolve_method(&db(&["-method", "vi"])).unwrap(), Method::Vi);
        assert_eq!(
            resolve_method(&db(&["-method", "mpi", "-sweeps", "7"])).unwrap(),
            Method::Mpi { sweeps: 7 }
        );
        assert_eq!(
            resolve_method(&db(&["-method", "pi"])).unwrap(),
            Method::ExactPi
        );
        assert_eq!(
            resolve_method(&db(&["-method", "ipi", "-ksp_type", "bcgs"])).unwrap(),
            Method::Ipi {
                ksp: KspType::BiCgStab,
                pc: PcType::None
            }
        );
        assert_eq!(
            resolve_method(&db(&[
                "-ksp_type",
                "gmres",
                "-ksp_gmres_restart",
                "11",
                "-pc_type",
                "jacobi"
            ]))
            .unwrap(),
            Method::Ipi {
                ksp: KspType::Gmres { restart: 11 },
                pc: PcType::Jacobi
            }
        );
        assert_eq!(
            resolve_method(&db(&["-ksp_type", "richardson", "-ksp_richardson_scale", "0.8"]))
                .unwrap(),
            Method::Ipi {
                ksp: KspType::Richardson { omega: 0.8 },
                pc: PcType::None
            }
        );
        assert_eq!(
            resolve_method(&db(&["-ksp_type", "preonly"])).unwrap(),
            Method::Ipi {
                ksp: KspType::Direct,
                pc: PcType::None
            }
        );
    }

    #[test]
    fn gamma_resolution_and_validation() {
        assert_eq!(resolve_gamma(&db(&[]), None).unwrap(), 0.99);
        assert_eq!(resolve_gamma(&db(&[]), Some(0.5)).unwrap(), 0.5);
        assert_eq!(resolve_gamma(&db(&["-gamma", "0.7"]), Some(0.5)).unwrap(), 0.7);
        assert!(resolve_gamma(&db(&["-gamma", "1.0"]), None).is_err());
        assert!(resolve_gamma(&db(&["-gamma", "-0.1"]), None).is_err());
        assert!(resolve_gamma(&db(&[]), Some(1.5)).is_err());
    }

    #[test]
    fn objective_resolution() {
        assert_eq!(resolve_objective(&db(&[]), None).unwrap(), Objective::Min);
        assert_eq!(
            resolve_objective(&db(&["-objective", "maxreward"]), None).unwrap(),
            Objective::Max
        );
        assert_eq!(
            resolve_objective(&db(&[]), Some(Objective::Max)).unwrap(),
            Objective::Max
        );
        let err = resolve_objective(&db(&["-objective", "mni"]), None).unwrap_err();
        assert!(err.0.contains("min"), "{err}");
    }

    #[test]
    fn threads_resolution_and_validation() {
        // NOTE: no env manipulation here — tests run in parallel and
        // MADUPITE_THREADS is process-global; the env path is covered by
        // the CI thread-matrix leg.
        assert_eq!(resolve_threads(&db(&["-threads", "4"])).unwrap(), 4);
        assert_eq!(resolve_threads(&db(&["-threads", "1"])).unwrap(), 1);
        let err = resolve_threads(&db(&["-threads", "0"])).unwrap_err();
        assert!(err.0.contains(">= 1"), "{err}");
        let err = resolve_threads(&db(&["-threads", "-2"])).unwrap_err();
        assert!(err.0.contains("expected integer"), "{err}");
        let err = resolve_threads(&db(&["-threads", "two"])).unwrap_err();
        assert!(err.0.contains("expected integer"), "{err}");
        // typo'd key keeps the did-you-mean behavior
        let err = check_key("thread").unwrap_err();
        assert!(err.0.contains("threads"), "{err}");
    }

    #[test]
    fn solve_options_validation() {
        assert!(resolve_solve_options(&db(&["-atol", "0"])).is_err());
        assert!(resolve_solve_options(&db(&["-alpha", "-1"])).is_err());
        assert!(resolve_solve_options(&db(&["-max_iter_pi", "0"])).is_err());
        assert!(resolve_solve_options(&db(&["-max_iter_ksp", "0"])).is_err());
        let so = resolve_solve_options(&db(&["-adaptive_forcing", "-verbose"])).unwrap();
        assert!(so.adaptive_forcing && so.verbose);
    }

    #[test]
    fn kernel_backend_and_precision_resolution() {
        let so = resolve_solve_options(&db(&[])).unwrap();
        assert_eq!(so.eval_backend, EvalBackend::MatFree);
        assert_eq!(so.inner_precision, InnerPrecision::F64);
        let so = resolve_solve_options(&db(&["-eval_backend", "bsr"])).unwrap();
        assert_eq!(so.eval_backend, EvalBackend::Bsr);
        let so = resolve_solve_options(&db(&["-inner_precision", "f32"])).unwrap();
        assert_eq!(so.inner_precision, InnerPrecision::F32);
        // both keys round-trip through validate_keys
        assert!(validate_keys(&db(&["-eval_backend", "bsr", "-inner_precision", "f32"])).is_ok());
    }

    #[test]
    fn discount_mode_resolution() {
        assert_eq!(resolve_discount_mode(&db(&[])).unwrap(), None);
        assert_eq!(
            resolve_discount_mode(&db(&["-discount_mode", "auto"])).unwrap(),
            None
        );
        assert_eq!(
            resolve_discount_mode(&db(&["-discount_mode", "scalar"])).unwrap(),
            Some(DiscountMode::Scalar)
        );
        assert_eq!(
            resolve_discount_mode(&db(&["-discount_mode", "per_state"])).unwrap(),
            Some(DiscountMode::PerState)
        );
        assert_eq!(
            resolve_discount_mode(&db(&["-discount_mode", "per-state-action"])).unwrap(),
            Some(DiscountMode::PerStateAction)
        );
        // bad values are typed errors with a did-you-mean suggestion
        let err = resolve_discount_mode(&db(&["-discount_mode", "scalr"])).unwrap_err();
        assert!(err.0.contains("scalar"), "{err}");
        // ...and the key itself round-trips through validate_keys
        assert!(validate_keys(&db(&["-discount_mode", "auto"])).is_ok());
        let err = check_key("discount_mod").unwrap_err();
        assert!(err.0.contains("discount_mode"), "{err}");
    }

    #[test]
    fn comm_overlap_resolution() {
        assert_eq!(resolve_comm_overlap(&db(&[])).unwrap(), None);
        assert_eq!(
            resolve_comm_overlap(&db(&["-comm_overlap", "on"])).unwrap(),
            Some(OverlapMode::On)
        );
        assert_eq!(
            resolve_comm_overlap(&db(&["-comm_overlap", "off"])).unwrap(),
            Some(OverlapMode::Off)
        );
        assert_eq!(
            resolve_comm_overlap(&db(&["-comm_overlap", "auto"])).unwrap(),
            Some(OverlapMode::Auto)
        );
        let err = resolve_comm_overlap(&db(&["-comm_overlap", "onn"])).unwrap_err();
        assert!(err.0.contains("on"), "{err}");
        assert!(validate_keys(&db(&["-comm_overlap", "on"])).is_ok());
    }

    #[test]
    fn async_vi_resolution_and_validation() {
        let so = resolve_solve_options(&db(&["-method", "vi", "-async_vi"])).unwrap();
        assert!(so.async_vi);
        assert_eq!(so.async_vi_staleness, 4);
        let so = resolve_solve_options(&db(&[
            "-method",
            "vi",
            "-async_vi",
            "-async_vi_staleness",
            "8",
        ]))
        .unwrap();
        assert_eq!(so.async_vi_staleness, 8);
        // default stays off
        let so = resolve_solve_options(&db(&["-method", "vi"])).unwrap();
        assert!(!so.async_vi);
        // typed errors: wrong method, orphaned staleness, zero staleness
        let err = resolve_solve_options(&db(&["-async_vi"])).unwrap_err();
        assert!(err.0.contains("-method vi"), "{err}");
        let err = resolve_solve_options(&db(&["-method", "mpi", "-async_vi"])).unwrap_err();
        assert!(err.0.contains("-method vi"), "{err}");
        let err =
            resolve_solve_options(&db(&["-method", "vi", "-async_vi_staleness", "4"])).unwrap_err();
        assert!(err.0.contains("requires -async_vi"), "{err}");
        let err = resolve_solve_options(&db(&[
            "-method",
            "vi",
            "-async_vi",
            "-async_vi_staleness",
            "0",
        ]))
        .unwrap_err();
        assert!(err.0.contains(">= 1"), "{err}");
        // keys round-trip through validate_keys
        assert!(validate_keys(&db(&["-async_vi", "-async_vi_staleness", "2"])).is_ok());
    }

    #[test]
    fn factored_mode_and_order_resolution() {
        assert_eq!(resolve_factored_mode(&db(&[])).unwrap(), None);
        assert_eq!(
            resolve_factored_mode(&db(&["-factored_mode", "compile"])).unwrap(),
            Some(FactoredMode::Compile)
        );
        assert_eq!(
            resolve_factored_mode(&db(&["-factored_mode", "svi"])).unwrap(),
            Some(FactoredMode::Svi)
        );
        let err = resolve_factored_mode(&db(&["-factored_mode", "sv"])).unwrap_err();
        assert!(err.0.contains("svi"), "{err}");
        assert_eq!(
            resolve_factored_order(&db(&[])).unwrap(),
            FactoredOrder::Given
        );
        assert_eq!(
            resolve_factored_order(&db(&["-factored_order", "reverse"])).unwrap(),
            FactoredOrder::Reverse
        );
        assert_eq!(
            resolve_factored_order(&db(&["-factored_order", "auto"])).unwrap(),
            FactoredOrder::Auto
        );
        let err = resolve_factored_order(&db(&["-factored_order", "revrse"])).unwrap_err();
        assert!(err.0.contains("reverse"), "{err}");
        // keys round-trip through validate_keys
        assert!(validate_keys(&db(&[
            "-factored_mode",
            "svi",
            "-factored_order",
            "auto",
            "-machines",
            "4",
        ]))
        .is_ok());
        let err = check_key("factored_mod").unwrap_err();
        assert!(err.0.contains("factored_mode"), "{err}");
    }

    #[test]
    fn bad_value_gets_suggestion() {
        let err = resolve_method(&db(&["-ksp_type", "gmers"])).unwrap_err();
        assert!(err.0.contains("gmres"), "{err}");
        let err = resolve_solve_options(&db(&["-eval_backend", "matfre"])).unwrap_err();
        assert!(err.0.contains("matfree"), "{err}");
        let err = resolve_solve_options(&db(&["-inner_precision", "f23"])).unwrap_err();
        assert!(err.0.contains("f32"), "{err}");
    }
}
