//! The embedded user API: madupite's user-facing surface as a library.
//!
//! The paper's core pitch is a *user-friendly API* over the distributed
//! core. This module reproduces that layer for Rust callers — the same
//! surface the original exposes to Python users:
//!
//! - [`MdpBuilder`] constructs MDPs from three interchangeable sources: an
//!   offline `.mdpb` file, a named benchmark model ([`MODEL_CATALOG`]), or
//!   user closures `(s, a) → row / cost` in the spirit of madupite's
//!   `createTransitionProbabilityTensor`.
//! - [`Solver`] carries a PETSc-style options database
//!   (`set_option("-ksp_type", "gmres")`, [`Solver::set_options_from_str`],
//!   env/argv ingestion) resolved through [`options::OPTION_TABLE`] — the
//!   exact same table and code path the CLI uses, so the two can never
//!   drift (a parity test compares their JSON output byte for byte).
//! - [`SolveOutcome`] is the output surface: `write_policy`, `write_cost`,
//!   `write_json_metadata`, `write_checkpoint` — gathered once on the
//!   calling thread, so the writes are distributed-safe like the originals'
//!   root-gather.
//! - [`Solver::build`] splits validation from iteration for re-solve
//!   loops: a [`PreparedModel`] holds the validated model + resolved
//!   options, accepts `patch_costs`/`patch_transitions` deltas and
//!   [`WarmStart`] seeds, and solves repeatedly via
//!   [`Solver::solve_prepared`] without re-validating untouched rows.
//!
//! Everything user-triggerable fails with a typed [`ApiError`] (bad gamma,
//! sub-stochastic closure rows, conflicting sources, unknown `-keys` with
//! did-you-mean suggestions) — never a panic.
//!
//! ```
//! use madupite::api::{MdpBuilder, Solver};
//!
//! // A 10-state random walk that can pay to jump home (state 0).
//! let n = 10;
//! let builder = MdpBuilder::from_fillers(
//!     n,
//!     2,
//!     move |s, a| {
//!         if a == 1 {
//!             vec![(0, 1.0)] // jump home
//!         } else if s + 1 < n {
//!             vec![(s, 0.5), (s + 1, 0.5)] // drift away
//!         } else {
//!             vec![(s, 1.0)]
//!         }
//!     },
//!     |s, a| if a == 1 { 2.0 } else { s as f64 * 0.1 },
//! )
//! .gamma(0.9);
//!
//! let mut solver = Solver::new(builder);
//! solver.set_options_from_str("-method ipi -ksp_type gmres -atol 1e-9").unwrap();
//! let outcome = solver.solve().unwrap();
//! assert!(outcome.result.converged);
//! assert_eq!(outcome.n_states, 10);
//! ```

pub mod builder;
pub mod checkpoint;
pub mod options;
pub mod solver;

pub use builder::{model_from_options, MdpBuilder, ModelInfo, MODEL_CATALOG};
pub use checkpoint::WarmStart;
pub use solver::{run_solve, PreparedModel, SolveOutcome, Solver};

use std::fmt;

/// Error type of the embedded API: every user-triggerable failure (bad
/// options, invalid models, IO) is reported through this, never a panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ApiError(pub String);

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for ApiError {}

impl From<crate::util::args::OptError> for ApiError {
    fn from(e: crate::util::args::OptError) -> ApiError {
        ApiError(e.to_string())
    }
}

impl From<String> for ApiError {
    fn from(s: String) -> ApiError {
        ApiError(s)
    }
}
