//! Small dense matrices + LU factorization (partial pivoting).
//!
//! Used for exact policy evaluation on small systems (the `Method::ExactPi`
//! preset and the `ksp::Direct` inner solver), for GMRES's Hessenberg
//! least-squares, and as the reference in linalg tests. Row-major storage.

use std::ops::{Index, IndexMut};

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMat {
    nrows: usize,
    ncols: usize,
    data: Vec<f64>,
}

impl DenseMat {
    /// All-zero matrix of the given shape.
    pub fn zeros(nrows: usize, ncols: usize) -> DenseMat {
        DenseMat {
            nrows,
            ncols,
            data: vec![0.0; nrows * ncols],
        }
    }

    /// The n x n identity.
    pub fn eye(n: usize) -> DenseMat {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from row slices (all must share one length).
    pub fn from_rows(rows: &[&[f64]]) -> DenseMat {
        let nrows = rows.len();
        let ncols = rows.first().map(|r| r.len()).unwrap_or(0);
        let mut m = Self::zeros(nrows, ncols);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), ncols, "ragged rows");
            m.data[i * ncols..(i + 1) * ncols].copy_from_slice(row);
        }
        m
    }

    /// Number of rows.
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    /// Number of columns.
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Row `r` as a slice.
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.ncols..(r + 1) * self.ncols]
    }

    /// Mutable row `r`.
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.ncols..(r + 1) * self.ncols]
    }

    /// y ← A·x
    pub fn mul_vec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.ncols);
        (0..self.nrows)
            .map(|r| super::dot(self.row(r), x))
            .collect()
    }

    /// C ← A·B
    pub fn mul_mat(&self, b: &DenseMat) -> DenseMat {
        assert_eq!(self.ncols, b.nrows);
        let mut c = DenseMat::zeros(self.nrows, b.ncols);
        for i in 0..self.nrows {
            for k in 0..self.ncols {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                for j in 0..b.ncols {
                    c[(i, j)] += aik * b[(k, j)];
                }
            }
        }
        c
    }

    /// In-place LU factorization with partial pivoting.
    /// Returns the pivot permutation, or Err if singular to working precision.
    pub fn lu_factor(&mut self) -> Result<Vec<usize>, String> {
        assert_eq!(self.nrows, self.ncols, "LU requires square matrix");
        let n = self.nrows;
        let mut piv: Vec<usize> = (0..n).collect();
        for k in 0..n {
            // pivot search
            let (mut pmax, mut prow) = (self[(k, k)].abs(), k);
            for r in k + 1..n {
                let v = self[(r, k)].abs();
                if v > pmax {
                    pmax = v;
                    prow = r;
                }
            }
            if pmax < 1e-300 {
                return Err(format!("singular at column {k}"));
            }
            if prow != k {
                piv.swap(k, prow);
                for c in 0..n {
                    let tmp = self[(k, c)];
                    self[(k, c)] = self[(prow, c)];
                    self[(prow, c)] = tmp;
                }
            }
            let pivot = self[(k, k)];
            for r in k + 1..n {
                let l = self[(r, k)] / pivot;
                self[(r, k)] = l;
                if l != 0.0 {
                    for c in k + 1..n {
                        let v = self[(k, c)];
                        self[(r, c)] -= l * v;
                    }
                }
            }
        }
        Ok(piv)
    }

    /// Solve `self·x = b` using a factorization from [`Self::lu_factor`].
    pub fn lu_solve(&self, piv: &[usize], b: &[f64]) -> Vec<f64> {
        let n = self.nrows;
        assert_eq!(b.len(), n);
        // apply permutation
        let mut x: Vec<f64> = piv.iter().map(|&p| b[p]).collect();
        // forward substitution (L has unit diagonal)
        for i in 1..n {
            let mut acc = x[i];
            for j in 0..i {
                acc -= self[(i, j)] * x[j];
            }
            x[i] = acc;
        }
        // back substitution
        for i in (0..n).rev() {
            let mut acc = x[i];
            for j in i + 1..n {
                acc -= self[(i, j)] * x[j];
            }
            x[i] = acc / self[(i, i)];
        }
        x
    }

    /// One-shot dense solve (copies the matrix).
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>, String> {
        let mut lu = self.clone();
        let piv = lu.lu_factor()?;
        Ok(lu.lu_solve(&piv, b))
    }
}

impl Index<(usize, usize)> for DenseMat {
    type Output = f64;
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        debug_assert!(r < self.nrows && c < self.ncols);
        &self.data[r * self.ncols + c]
    }
}

impl IndexMut<(usize, usize)> for DenseMat {
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        debug_assert!(r < self.nrows && c < self.ncols);
        &mut self.data[r * self.ncols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    #[test]
    fn matvec() {
        let a = DenseMat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(a.mul_vec(&[1.0, 1.0]), vec![3.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = DenseMat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let i = DenseMat::eye(2);
        assert_eq!(a.mul_mat(&i), a);
        assert_eq!(i.mul_mat(&a), a);
    }

    #[test]
    fn solve_2x2() {
        let a = DenseMat::from_rows(&[&[2.0, 1.0], &[1.0, 3.0]]);
        let x = a.solve(&[5.0, 10.0]).unwrap();
        // 2x + y = 5, x + 3y = 10 → x = 1, y = 3
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn solve_needs_pivoting() {
        // zero on the diagonal forces a row swap
        let a = DenseMat::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let x = a.solve(&[2.0, 3.0]).unwrap();
        assert_eq!(x, vec![3.0, 2.0]);
    }

    #[test]
    fn singular_detected() {
        let a = DenseMat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(a.solve(&[1.0, 2.0]).is_err());
    }

    #[test]
    fn prop_solve_recovers_known_x() {
        prop::forall("LU solves random diag-dominant systems", |rng| {
            let n = 1 + rng.index(10);
            let mut a = DenseMat::zeros(n, n);
            for r in 0..n {
                let mut offsum = 0.0;
                for c in 0..n {
                    if c != r {
                        let v = rng.range_f64(-1.0, 1.0);
                        a[(r, c)] = v;
                        offsum += v.abs();
                    }
                }
                a[(r, r)] = offsum + 1.0 + rng.next_f64(); // strictly dominant
            }
            let x_true: Vec<f64> = (0..n).map(|_| rng.range_f64(-5.0, 5.0)).collect();
            let b = a.mul_vec(&x_true);
            let x = a.solve(&b).map_err(|e| e.to_string())?;
            prop::close_slices(&x, &x_true, 1e-9)
        });
    }

    #[test]
    fn prop_lu_reusable_for_multiple_rhs() {
        prop::forall("factor once, solve twice", |rng| {
            let n = 2 + rng.index(6);
            let mut a = DenseMat::eye(n);
            for r in 0..n {
                for c in 0..n {
                    if r != c {
                        a[(r, c)] = rng.range_f64(-0.1, 0.1);
                    }
                }
            }
            let orig = a.clone();
            let piv = a.lu_factor().map_err(|e| e)?;
            for _ in 0..2 {
                let xt: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
                let b = orig.mul_vec(&xt);
                let x = a.lu_solve(&piv, &b);
                prop::close_slices(&x, &xt, 1e-9)?;
            }
            prop_assert!(true, "unreachable");
            Ok(())
        });
    }
}
