//! Serial CSR sparse matrix (the PETSc `SeqAIJ` equivalent).
//!
//! Storage: `indptr` (row offsets, len `nrows+1`), `indices` (column ids),
//! `values`. Column indices within a row are kept **sorted and unique** —
//! the builder enforces this, and the property tests in `util::prop` assert
//! it stays true under every constructor. madupite stores the whole MDP as
//! one stacked `(n·m) × n` CSR of this type (plus the distributed variant in
//! [`super::dist`]).

use std::fmt;

/// Compressed sparse row matrix, f64 values.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    nrows: usize,
    ncols: usize,
    indptr: Vec<usize>,
    indices: Vec<usize>,
    values: Vec<f64>,
}

impl Csr {
    /// Empty matrix with no nonzeros.
    pub fn zeros(nrows: usize, ncols: usize) -> Csr {
        Csr {
            nrows,
            ncols,
            indptr: vec![0; nrows + 1],
            indices: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Identity.
    pub fn eye(n: usize) -> Csr {
        Csr {
            nrows: n,
            ncols: n,
            indptr: (0..=n).collect(),
            indices: (0..n).collect(),
            values: vec![1.0; n],
        }
    }

    /// Build from raw parts, validating the CSR invariants.
    pub fn from_parts(
        nrows: usize,
        ncols: usize,
        indptr: Vec<usize>,
        indices: Vec<usize>,
        values: Vec<f64>,
    ) -> Result<Csr, String> {
        if indptr.len() != nrows + 1 {
            return Err(format!("indptr len {} != nrows+1 {}", indptr.len(), nrows + 1));
        }
        if indptr[0] != 0 || *indptr.last().unwrap() != indices.len() {
            return Err("indptr bounds invalid".to_string());
        }
        if indices.len() != values.len() {
            return Err("indices/values length mismatch".to_string());
        }
        for w in indptr.windows(2) {
            if w[0] > w[1] {
                return Err("indptr not monotone".to_string());
            }
        }
        for r in 0..nrows {
            let row = &indices[indptr[r]..indptr[r + 1]];
            for w in row.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("row {r}: columns not sorted-unique"));
                }
            }
            if let Some(&last) = row.last() {
                if last >= ncols {
                    return Err(format!("row {r}: column {last} >= ncols {ncols}"));
                }
            }
        }
        Ok(Csr {
            nrows,
            ncols,
            indptr,
            indices,
            values,
        })
    }

    /// Build from (row, col, value) triplets; duplicate entries are summed.
    pub fn from_triplets(
        nrows: usize,
        ncols: usize,
        triplets: &[(usize, usize, f64)],
    ) -> Csr {
        let mut rows: Vec<Vec<(usize, f64)>> = vec![Vec::new(); nrows];
        for &(r, c, v) in triplets {
            assert!(r < nrows && c < ncols, "triplet ({r},{c}) out of bounds");
            rows[r].push((c, v));
        }
        Self::from_row_lists(ncols, rows)
    }

    /// Build from per-row (col, value) lists; duplicates summed, zeros kept
    /// only if explicitly inserted as the *sum* (exact 0 sums are dropped).
    pub fn from_row_lists(ncols: usize, mut rows: Vec<Vec<(usize, f64)>>) -> Csr {
        let nrows = rows.len();
        let mut indptr = Vec::with_capacity(nrows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for row in rows.iter_mut() {
            normalize_row_entries(row);
            for &(c, v) in row.iter() {
                assert!(c < ncols, "column {c} out of bounds ({ncols})");
                indices.push(c);
                values.push(v);
            }
            indptr.push(indices.len());
        }
        Csr {
            nrows,
            ncols,
            indptr,
            indices,
            values,
        }
    }

    /// Number of rows.
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    /// Normalize one sparse row in place: sort by column, sum duplicate
    /// columns, drop exact-zero sums. This is CSR's canonical row layout —
    /// shared with the streaming `.mdpb` writer ([`crate::mdp::io`]) so
    /// files written row-by-row are byte-identical to files written from
    /// an assembled matrix.
    pub fn normalize_row_entries(row: &mut Vec<(usize, f64)>) {
        normalize_row_entries(row)
    }

    /// Number of columns.
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Row pointer array (`nrows + 1` entries).
    pub fn indptr(&self) -> &[usize] {
        &self.indptr
    }

    /// Column indices, row-major.
    pub fn indices(&self) -> &[usize] {
        &self.indices
    }

    /// Stored values, aligned with [`Self::indices`].
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Mutable view of the stored values (sparsity is fixed).
    pub fn values_mut(&mut self) -> &mut [f64] {
        &mut self.values
    }

    /// (columns, values) of row `r`.
    pub fn row(&self, r: usize) -> (&[usize], &[f64]) {
        let (a, b) = (self.indptr[r], self.indptr[r + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// Replace one row's entries in place, keeping every other row intact.
    ///
    /// The incremental-update primitive behind [`crate::mdp::Mdp`]'s
    /// `patch_transitions`: only the spliced row is validated (columns
    /// sorted-unique and `< ncols` — the same invariants [`Self::from_parts`]
    /// enforces globally), so patching one row of a huge matrix does not
    /// re-scan the others. The row may grow or shrink; the tail of
    /// `indptr` is shifted accordingly.
    pub fn set_row(&mut self, r: usize, entries: &[(usize, f64)]) -> Result<(), String> {
        if r >= self.nrows {
            return Err(format!("row {r} out of range ({} rows)", self.nrows));
        }
        for w in entries.windows(2) {
            if w[0].0 >= w[1].0 {
                return Err(format!("row {r}: columns not sorted-unique"));
            }
        }
        if let Some(&(last, _)) = entries.last() {
            if last >= self.ncols {
                return Err(format!("row {r}: column {last} >= ncols {}", self.ncols));
            }
        }
        let (start, end) = (self.indptr[r], self.indptr[r + 1]);
        self.indices.splice(start..end, entries.iter().map(|&(c, _)| c));
        self.values.splice(start..end, entries.iter().map(|&(_, v)| v));
        let old_len = end - start;
        if entries.len() != old_len {
            if entries.len() >= old_len {
                let grow = entries.len() - old_len;
                for p in self.indptr[r + 1..].iter_mut() {
                    *p += grow;
                }
            } else {
                let shrink = old_len - entries.len();
                for p in self.indptr[r + 1..].iter_mut() {
                    *p -= shrink;
                }
            }
        }
        Ok(())
    }

    /// Entry lookup (binary search within the row).
    pub fn get(&self, r: usize, c: usize) -> f64 {
        let (cols, vals) = self.row(r);
        match cols.binary_search(&c) {
            Ok(k) => vals[k],
            Err(_) => 0.0,
        }
    }

    /// y ← A·x
    ///
    /// Hot path of every solver iteration. Rows are parallelized over the
    /// rank's worker pool ([`crate::util::par`]); each row's gather runs
    /// through [`crate::util::simd::gather_dot_unchecked`], whose lane
    /// fold is fixed per kernel backend, so the result is bitwise
    /// identical for every thread count. The unchecked reads are sound
    /// because column indices are validated `< ncols` by every
    /// constructor (`from_parts` rejects violations, the builders
    /// assert), and `values_mut` cannot alter indices — see
    /// EXPERIMENTS.md §Perf.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.ncols, "spmv: x len");
        assert_eq!(y.len(), self.nrows, "spmv: y len");
        crate::util::par::par_for_rows(y, |offset, chunk| {
            for (i, yr) in chunk.iter_mut().enumerate() {
                let r = offset + i;
                let (a, b) = (self.indptr[r], self.indptr[r + 1]);
                // SAFETY: every index in `indices` is < ncols == x.len(),
                // enforced at construction.
                *yr = unsafe {
                    crate::util::simd::gather_dot_unchecked(
                        &self.indices[a..b],
                        &self.values[a..b],
                        x,
                    )
                };
            }
        });
    }

    /// y ← A·x (allocating convenience).
    pub fn mul_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.nrows];
        self.spmv(x, &mut y);
        y
    }

    /// y ← α·A·x + β·y (row-parallel like [`Self::spmv`], same bitwise
    /// thread-count independence).
    ///
    /// `beta == 0.0` is special-cased as an **overwrite** of `y`, matching
    /// BLAS convention: stale `NaN`/`Inf` in the output buffer must not
    /// leak through `0.0 * y` (which would yield `NaN`).
    pub fn spmv_acc(&self, alpha: f64, x: &[f64], beta: f64, y: &mut [f64]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        crate::util::par::par_for_rows(y, |offset, chunk| {
            for (i, yr) in chunk.iter_mut().enumerate() {
                let r = offset + i;
                let (a, b) = (self.indptr[r], self.indptr[r + 1]);
                // SAFETY: every index in `indices` is < ncols == x.len(),
                // enforced at construction.
                let acc = unsafe {
                    crate::util::simd::gather_dot_unchecked(
                        &self.indices[a..b],
                        &self.values[a..b],
                        x,
                    )
                };
                if beta == 0.0 {
                    *yr = alpha * acc;
                } else {
                    *yr = alpha * acc + beta * *yr;
                }
            }
        });
    }

    /// Extract a sub-matrix of the given rows (keeps all columns).
    pub fn select_rows(&self, rows: &[usize]) -> Csr {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for &r in rows {
            let (cols, vals) = self.row(r);
            indices.extend_from_slice(cols);
            values.extend_from_slice(vals);
            indptr.push(indices.len());
        }
        Csr {
            nrows: rows.len(),
            ncols: self.ncols,
            indptr,
            indices,
            values,
        }
    }

    /// Dense row sums (for stochasticity checks).
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.nrows)
            .map(|r| self.row(r).1.iter().sum())
            .collect()
    }

    /// Check every row sums to 1 within `tol` and all values are in [0,1].
    /// (Transition-matrix validation, madupite does the same on assembly.)
    pub fn is_row_stochastic(&self, tol: f64) -> bool {
        self.values.iter().all(|&v| (-tol..=1.0 + tol).contains(&v))
            && self
                .row_sums()
                .iter()
                .all(|&s| (s - 1.0).abs() <= tol)
    }

    /// Convert to dense (row-major) — tests and exact PI on small systems.
    pub fn to_dense(&self) -> super::DenseMat {
        let mut m = super::DenseMat::zeros(self.nrows, self.ncols);
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                m[(r, c)] = v;
            }
        }
        m
    }

    /// Frobenius-ish sanity: all values finite.
    pub fn all_finite(&self) -> bool {
        self.values.iter().all(|v| v.is_finite())
    }

    /// Bytes of storage (memory accounting for EXPERIMENTS.md).
    pub fn storage_bytes(&self) -> usize {
        self.indptr.len() * 8 + self.indices.len() * 8 + self.values.len() * 8
    }
}

/// Shared implementation of [`Csr::normalize_row_entries`] (free function
/// so the builder loop and the associated wrapper use one copy).
fn normalize_row_entries(row: &mut Vec<(usize, f64)>) {
    row.sort_by_key(|&(c, _)| c);
    let mut out = 0usize;
    let mut i = 0;
    while i < row.len() {
        let c = row[i].0;
        let mut v = row[i].1;
        let mut j = i + 1;
        while j < row.len() && row[j].0 == c {
            v += row[j].1;
            j += 1;
        }
        if v != 0.0 {
            row[out] = (c, v);
            out += 1;
        }
        i = j;
    }
    row.truncate(out);
}

impl fmt::Display for Csr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "Csr {}x{} nnz={} ({:.3} per row)",
            self.nrows,
            self.ncols,
            self.nnz(),
            self.nnz() as f64 / self.nrows.max(1) as f64
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    fn small() -> Csr {
        // [[1, 0, 2], [0, 3, 0]]
        Csr::from_triplets(2, 3, &[(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0)])
    }

    #[test]
    fn build_and_access() {
        let m = small();
        assert_eq!(m.nrows(), 2);
        assert_eq!(m.ncols(), 3);
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(0, 1), 0.0);
        assert_eq!(m.get(0, 2), 2.0);
        assert_eq!(m.get(1, 1), 3.0);
    }

    #[test]
    fn set_row_splices_and_shifts_tail() {
        // grow row 0 from 2 to 3 entries
        let mut m = small();
        m.set_row(0, &[(0, 4.0), (1, 5.0), (2, 6.0)]).unwrap();
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.get(0, 1), 5.0);
        assert_eq!(m.get(1, 1), 3.0, "untouched row must survive the splice");
        // shrink row 0 to a single entry
        m.set_row(0, &[(2, 7.0)]).unwrap();
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.get(0, 0), 0.0);
        assert_eq!(m.get(0, 2), 7.0);
        assert_eq!(m.get(1, 1), 3.0);
        // result still passes the full-structure validator
        let rebuilt = Csr::from_parts(
            m.nrows(),
            m.ncols(),
            m.indptr().to_vec(),
            m.indices().to_vec(),
            m.values().to_vec(),
        );
        assert!(rebuilt.is_ok(), "{rebuilt:?}");
    }

    #[test]
    fn set_row_rejects_bad_rows() {
        let mut m = small();
        assert!(m.set_row(2, &[(0, 1.0)]).unwrap_err().contains("out of range"));
        assert!(m
            .set_row(0, &[(1, 1.0), (1, 2.0)])
            .unwrap_err()
            .contains("sorted-unique"));
        assert!(m.set_row(0, &[(0, 1.0), (3, 2.0)]).unwrap_err().contains("ncols"));
        // failed patches leave the matrix unchanged
        assert_eq!(m, small());
    }

    #[test]
    fn duplicates_summed() {
        let m = Csr::from_triplets(1, 2, &[(0, 1, 1.0), (0, 1, 2.5)]);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.get(0, 1), 3.5);
    }

    #[test]
    fn zero_sums_dropped() {
        let m = Csr::from_triplets(1, 2, &[(0, 1, 1.0), (0, 1, -1.0)]);
        assert_eq!(m.nnz(), 0);
    }

    #[test]
    fn spmv_matches_manual() {
        let m = small();
        let y = m.mul_vec(&[1.0, 10.0, 100.0]);
        assert_eq!(y, vec![201.0, 30.0]);
    }

    #[test]
    fn spmv_acc_alpha_beta() {
        let m = small();
        let mut y = vec![1.0, 1.0];
        m.spmv_acc(2.0, &[1.0, 10.0, 100.0], -1.0, &mut y);
        assert_eq!(y, vec![401.0, 59.0]);
    }

    #[test]
    fn spmv_acc_beta_zero_overwrites_stale_nan() {
        // Regression: beta == 0.0 must overwrite y, not scale it —
        // otherwise 0.0 * NaN = NaN leaks stale garbage into results.
        let m = small();
        let mut y = vec![f64::NAN, f64::INFINITY];
        m.spmv_acc(2.0, &[1.0, 10.0, 100.0], 0.0, &mut y);
        assert_eq!(y, vec![402.0, 60.0]);
    }

    #[test]
    fn eye_spmv_is_identity() {
        let m = Csr::eye(4);
        let x = vec![1.0, -2.0, 3.0, 0.5];
        assert_eq!(m.mul_vec(&x), x);
    }

    #[test]
    fn select_rows_subset() {
        let m = small();
        let s = m.select_rows(&[1]);
        assert_eq!(s.nrows(), 1);
        assert_eq!(s.get(0, 1), 3.0);
    }

    #[test]
    fn from_parts_validates() {
        assert!(Csr::from_parts(1, 2, vec![0, 1], vec![0], vec![1.0]).is_ok());
        // bad: column out of bounds
        assert!(Csr::from_parts(1, 2, vec![0, 1], vec![5], vec![1.0]).is_err());
        // bad: unsorted columns
        assert!(
            Csr::from_parts(1, 3, vec![0, 2], vec![2, 0], vec![1.0, 1.0]).is_err()
        );
        // bad: indptr not monotone
        assert!(
            Csr::from_parts(2, 2, vec![0, 2, 1], vec![0, 1], vec![1.0, 1.0]).is_err()
        );
        // bad: indptr end mismatch
        assert!(Csr::from_parts(1, 2, vec![0, 2], vec![0], vec![1.0]).is_err());
    }

    #[test]
    fn row_stochastic_check() {
        let p = Csr::from_triplets(2, 2, &[(0, 0, 0.5), (0, 1, 0.5), (1, 1, 1.0)]);
        assert!(p.is_row_stochastic(1e-12));
        let q = Csr::from_triplets(1, 2, &[(0, 0, 0.6), (0, 1, 0.6)]);
        assert!(!q.is_row_stochastic(1e-12));
    }

    #[test]
    fn to_dense_matches() {
        let m = small();
        let d = m.to_dense();
        for r in 0..2 {
            for c in 0..3 {
                assert_eq!(d[(r, c)], m.get(r, c));
            }
        }
    }

    #[test]
    fn prop_roundtrip_triplets_spmv() {
        prop::forall("csr spmv == dense matvec", |rng: &mut prop::Gen| {
            let nrows = 1 + rng.index(12);
            let ncols = 1 + rng.index(12);
            let nnz = rng.index(nrows * ncols + 1);
            let trips: Vec<(usize, usize, f64)> = (0..nnz)
                .map(|_| {
                    (
                        rng.index(nrows),
                        rng.index(ncols),
                        rng.range_f64(-2.0, 2.0),
                    )
                })
                .collect();
            let m = Csr::from_triplets(nrows, ncols, &trips);
            // invariant: sorted unique columns per row
            for r in 0..nrows {
                let (cols, _) = m.row(r);
                for w in cols.windows(2) {
                    prop_assert!(w[0] < w[1], "row {r} not sorted-unique");
                }
            }
            // spmv vs dense
            let x: Vec<f64> = (0..ncols).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let y = m.mul_vec(&x);
            let d = m.to_dense();
            let yd = d.mul_vec(&x);
            prop::close_slices(&y, &yd, 1e-12)
        });
    }

    #[test]
    fn prop_from_parts_accepts_builder_output() {
        prop::forall("builder output passes validation", |rng| {
            let nrows = 1 + rng.index(8);
            let ncols = 1 + rng.index(8);
            let nnz = rng.index(nrows * ncols + 1);
            let trips: Vec<(usize, usize, f64)> = (0..nnz)
                .map(|_| (rng.index(nrows), rng.index(ncols), 1.0))
                .collect();
            let m = Csr::from_triplets(nrows, ncols, &trips);
            let ok = Csr::from_parts(
                m.nrows(),
                m.ncols(),
                m.indptr().to_vec(),
                m.indices().to_vec(),
                m.values().to_vec(),
            );
            prop_assert!(ok.is_ok(), "validation rejected builder output");
            Ok(())
        });
    }
}
