//! 1×[`LANES`] column-blocked sparse rows (a "BSR-lite" layout).
//!
//! The stacked `(n·m) × n` transition matrix and the per-policy `n × n`
//! operator it induces are sparse, but their nonzeros often cluster in
//! column index: catalog models with local dynamics (chains, mazes,
//! epidemic lattices) put a row's entries into a handful of *adjacent*
//! columns. A flat CSR gather (`x[col]` per entry) cannot exploit that —
//! each entry costs an indexed load. This layout groups a row's entries
//! into aligned blocks of [`LANES`] consecutive columns, each stored as a
//! dense `[f64; LANES]` mini-row, so the dot against `x` becomes
//! contiguous lane loads with one block-column lookup per [`LANES`]
//! columns.
//!
//! The trade-off is fill: absent columns inside a touched block are stored
//! as explicit zeros. [`Bsr::fill_ratio`] measures `nnz / (blocks·LANES)`;
//! the backend-selection heuristic in [`crate::mdp::blocked`] only uses
//! this layout when the ratio is high enough to win (DESIGN.md §13).
//!
//! Determinism: [`Bsr::row_dot`] accumulates one lane-sum per lane across
//! all blocks of the row and folds them in the fixed order
//! `(s0+s1)+(s2+s3)` — the same shape as [`crate::util::simd`] — so the
//! result depends only on the matrix, never on thread count or chunking.

use crate::util::simd::LANES;

/// Sparse matrix stored as 1×[`LANES`] column blocks per row.
///
/// Block `b` of a row covers global columns `b·LANES .. b·LANES+LANES`
/// (the final block may run past `ncols`; its trailing lanes are stored as
/// zeros and never read from `x`). Block columns within a row are sorted
/// and unique, mirroring the CSR invariant.
#[derive(Clone, Debug, PartialEq)]
pub struct Bsr {
    nrows: usize,
    ncols: usize,
    nnz: usize,
    indptr: Vec<usize>,
    bcols: Vec<usize>,
    blocks: Vec<[f64; LANES]>,
}

impl Bsr {
    /// Empty builder with no rows yet; grow with [`Self::push_row`].
    pub fn new(ncols: usize) -> Bsr {
        Bsr {
            nrows: 0,
            ncols,
            nnz: 0,
            indptr: vec![0],
            bcols: Vec::new(),
            blocks: Vec::new(),
        }
    }

    /// Append one row from sorted-unique `(cols, vals)` (the CSR row
    /// layout; see [`super::Csr::row`]). Consecutive columns landing in
    /// the same [`LANES`]-aligned block share one stored block.
    ///
    /// Panics if `cols` and `vals` differ in length, a column is out of
    /// bounds, or columns are not strictly increasing.
    pub fn push_row(&mut self, cols: &[usize], vals: &[f64]) {
        assert_eq!(cols.len(), vals.len(), "push_row: cols/vals length");
        for w in cols.windows(2) {
            assert!(w[0] < w[1], "push_row: columns not sorted-unique");
        }
        let row_start = *self.indptr.last().unwrap();
        for (&c, &v) in cols.iter().zip(vals) {
            assert!(c < self.ncols, "push_row: column {c} >= ncols {}", self.ncols);
            let b = c / LANES;
            let need_new =
                self.bcols.len() == row_start || *self.bcols.last().unwrap() != b;
            if need_new {
                self.bcols.push(b);
                self.blocks.push([0.0; LANES]);
            }
            self.blocks.last_mut().unwrap()[c % LANES] = v;
            self.nnz += 1;
        }
        self.nrows += 1;
        self.indptr.push(self.bcols.len());
    }

    /// Convert a whole [`super::Csr`] (convenience for tests/benches).
    pub fn from_csr(m: &super::Csr) -> Bsr {
        let mut out = Bsr::new(m.ncols());
        for r in 0..m.nrows() {
            let (cols, vals) = m.row(r);
            out.push_row(cols, vals);
        }
        out
    }

    /// Number of rows.
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    /// Number of columns.
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Number of *logical* nonzeros (as pushed, excluding block padding).
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Number of stored 1×[`LANES`] blocks.
    pub fn nblocks(&self) -> usize {
        self.blocks.len()
    }

    /// Logical nonzeros over stored lane slots: `nnz / (nblocks·LANES)`.
    ///
    /// 1.0 means every stored lane is a real entry (perfectly clustered
    /// columns); small ratios mean the layout mostly stores padding zeros
    /// and a gather-based kernel is the better choice. Returns 1.0 for an
    /// empty matrix so the heuristic treats it as "no penalty".
    pub fn fill_ratio(&self) -> f64 {
        if self.blocks.is_empty() {
            return 1.0;
        }
        self.nnz as f64 / (self.blocks.len() * LANES) as f64
    }

    /// Dot of row `r` with `x` (`x.len()` must be `ncols`).
    ///
    /// Lane `l` accumulates `block[l] · x[base+l]` across the row's
    /// blocks; the four lane sums fold as `(s0+s1)+(s2+s3)`. The final
    /// block of the matrix may extend past `ncols`; its out-of-range lanes
    /// are skipped (they hold explicit zeros and have no `x` entry).
    pub fn row_dot(&self, r: usize, x: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), self.ncols, "row_dot: x len");
        let (a, b) = (self.indptr[r], self.indptr[r + 1]);
        let mut s = [0.0f64; LANES];
        for k in a..b {
            let base = self.bcols[k] * LANES;
            let blk = &self.blocks[k];
            if base + LANES <= x.len() {
                for (l, sl) in s.iter_mut().enumerate() {
                    *sl += blk[l] * x[base + l];
                }
            } else {
                for l in 0..x.len() - base {
                    s[l] += blk[l] * x[base + l];
                }
            }
        }
        (s[0] + s[1]) + (s[2] + s[3])
    }

    /// y ← A·x (serial; tests and small systems).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.ncols, "spmv: x len");
        assert_eq!(y.len(), self.nrows, "spmv: y len");
        for (r, yr) in y.iter_mut().enumerate() {
            *yr = self.row_dot(r, x);
        }
    }

    /// Bytes of storage (memory accounting, cf. [`super::Csr::storage_bytes`]).
    pub fn storage_bytes(&self) -> usize {
        self.indptr.len() * 8 + self.bcols.len() * 8 + self.blocks.len() * LANES * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Csr;
    use crate::prop_assert;
    use crate::util::prop;

    #[test]
    fn blocks_group_adjacent_columns() {
        // Row [_, 1, 2, _, _, _, _, 3]: cols 1,2 share block 0; col 7 is block 1.
        let mut m = Bsr::new(8);
        m.push_row(&[1, 2, 7], &[1.0, 2.0, 3.0]);
        assert_eq!(m.nrows(), 1);
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.nblocks(), 2);
        assert!((m.fill_ratio() - 3.0 / 8.0).abs() < 1e-15);
        let x = [10.0, 1.0, 2.0, 10.0, 10.0, 10.0, 10.0, 4.0];
        assert_eq!(m.row_dot(0, &x), 1.0 + 4.0 + 12.0);
    }

    #[test]
    fn empty_rows_and_empty_matrix() {
        let mut m = Bsr::new(5);
        m.push_row(&[], &[]);
        m.push_row(&[2], &[7.0]);
        m.push_row(&[], &[]);
        let x = [1.0; 5];
        let mut y = [f64::NAN; 3];
        m.spmv(&x, &mut y);
        assert_eq!(y, [0.0, 7.0, 0.0]);
        assert_eq!(Bsr::new(3).fill_ratio(), 1.0);
    }

    #[test]
    fn final_partial_block_is_guarded() {
        // ncols = 6 with LANES = 4: block 1 covers cols 4..8, but x has 6.
        let mut m = Bsr::new(6);
        m.push_row(&[0, 5], &[1.0, 2.0]);
        let x = [3.0, 0.0, 0.0, 0.0, 0.0, 4.0];
        assert_eq!(m.row_dot(0, &x), 3.0 + 8.0);
    }

    #[test]
    fn fill_ratio_dense_rows_is_one() {
        let mut m = Bsr::new(LANES * 2);
        let cols: Vec<usize> = (0..LANES * 2).collect();
        let vals = vec![1.0; LANES * 2];
        m.push_row(&cols, &vals);
        assert_eq!(m.fill_ratio(), 1.0);
    }

    #[test]
    #[should_panic(expected = "not sorted-unique")]
    fn unsorted_columns_rejected() {
        Bsr::new(4).push_row(&[2, 1], &[1.0, 1.0]);
    }

    #[test]
    fn prop_matches_csr_spmv() {
        prop::forall("bsr spmv == csr spmv", |rng: &mut prop::Gen| {
            let nrows = 1 + rng.index(10);
            // Sizes straddle the lane width to exercise the partial block.
            let ncols = 1 + rng.index(3 * LANES + 2);
            let nnz = rng.index(nrows * ncols + 1);
            let trips: Vec<(usize, usize, f64)> = (0..nnz)
                .map(|_| (rng.index(nrows), rng.index(ncols), rng.range_f64(-2.0, 2.0)))
                .collect();
            let c = Csr::from_triplets(nrows, ncols, &trips);
            let b = Bsr::from_csr(&c);
            prop_assert!(b.nnz() == c.nnz(), "nnz mismatch");
            let x: Vec<f64> = (0..ncols).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let yc = c.mul_vec(&x);
            let mut yb = vec![f64::NAN; nrows];
            b.spmv(&x, &mut yb);
            prop::close_slices(&yc, &yb, 1e-12)
        });
    }

    #[test]
    fn prop_extreme_and_denormal_values_track_reference() {
        prop::forall("bsr handles extreme values", |rng: &mut prop::Gen| {
            let ncols = 1 + rng.index(2 * LANES + 1);
            let mut cols = Vec::new();
            let mut vals = Vec::new();
            for c in 0..ncols {
                if rng.index(2) == 0 {
                    cols.push(c);
                    vals.push(match rng.index(3) {
                        0 => f64::MIN_POSITIVE / 4.0,
                        1 => 1e300,
                        _ => rng.range_f64(-1.0, 1.0),
                    });
                }
            }
            let mut b = Bsr::new(ncols);
            b.push_row(&cols, &vals);
            let x = vec![1.0; ncols];
            let reference: f64 = vals.iter().sum();
            let got = b.row_dot(0, &x);
            // Same additions, possibly different association: relative check.
            prop_assert!(
                (got - reference).abs() <= 1e-12 * reference.abs().max(1.0),
                "extreme-value row_dot mismatch: {got} vs {reference}"
            );
            Ok(())
        });
    }
}
