//! Row-partitioned distributed CSR + ghost exchange (PETSc `MPIAIJ`).
//!
//! A [`DistCsr`] lives inside an SPMD world: each rank holds a contiguous
//! block of matrix rows with **global** column indices, while the vector it
//! multiplies is partitioned over columns by a [`Partition`]. At
//! construction the matrix discovers which remote vector entries ("ghosts")
//! its rows touch, exchanges request lists once (`alltoallv`), and compiles
//! a reusable **ghost plan** — exactly PETSc's `VecScatter` built during
//! `MatAssembly`. Each SpMV then moves only the needed entries, and the
//! comm layer counts the bytes, which is what experiment E2 reports.
//!
//! Column indices are remapped at construction: owned columns to
//! `[0, nlocal)`, ghosts to `[nlocal, nlocal + nghost)` — the same
//! diagonal/off-diagonal split PETSc uses, giving branch-free SpMV over a
//! concatenated `[owned | ghost]` buffer.

use super::Csr;
use crate::comm::{codec, Comm};

/// Contiguous block partition of `n` items over `size` ranks
/// (PETSc's `PetscSplitOwnership`: remainder spread over leading ranks).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Partition {
    n: usize,
    size: usize,
}

impl Partition {
    /// `n = 0` is a valid (empty) partition: every rank owns the empty
    /// range and [`Self::owner`] has no valid argument. `n < size` is also
    /// fine — trailing ranks simply own empty ranges.
    pub fn new(n: usize, size: usize) -> Partition {
        assert!(size >= 1);
        Partition { n, size }
    }

    /// Global vector/row count.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of ranks in the partition.
    pub fn size(&self) -> usize {
        self.size
    }

    /// First global index owned by `rank`.
    pub fn lo(&self, rank: usize) -> usize {
        (rank * self.n) / self.size
    }

    /// One past the last global index owned by `rank`.
    pub fn hi(&self, rank: usize) -> usize {
        ((rank + 1) * self.n) / self.size
    }

    /// Number of indices owned by `rank`.
    pub fn local_len(&self, rank: usize) -> usize {
        self.hi(rank) - self.lo(rank)
    }

    /// Which rank owns global index `i`.
    ///
    /// Panics when `i >= n` (including any call on an empty partition,
    /// which owns no indices at all). The check is a real assert, not a
    /// `debug_assert`: release builds previously fell through to the
    /// division below and died with a bare divide-by-zero on `n = 0`.
    pub fn owner(&self, i: usize) -> usize {
        assert!(
            i < self.n,
            "Partition::owner: index {i} out of range (n = {})",
            self.n
        );
        // Initial guess from the inverse of lo(), then local correction.
        let mut r = ((i as u128 * self.size as u128) / self.n as u128) as usize;
        r = r.min(self.size - 1);
        while i < self.lo(r) {
            r -= 1;
        }
        while i >= self.hi(r) {
            r += 1;
        }
        r
    }

    /// All (lo, hi) ranges in rank order.
    pub fn ranges(&self) -> Vec<(usize, usize)> {
        (0..self.size).map(|r| (self.lo(r), self.hi(r))).collect()
    }
}

/// Reusable ghost-value buffer handed to [`DistCsr::spmv`]; holds the
/// concatenated `[owned | ghost]` x-vector so the hot loop never allocates.
#[derive(Debug)]
pub struct GhostBuf {
    xbuf: Vec<f64>,
    nlocal: usize,
}

impl GhostBuf {
    /// Buffer for an operator with `nlocal` owned entries and `nghost`
    /// ghost entries. Operators that own a [`DistCsr`] should prefer
    /// [`DistCsr::make_buffer`]; this constructor serves matrix-free and
    /// dense operators that size their buffers directly.
    pub fn new(nlocal: usize, nghost: usize) -> GhostBuf {
        GhostBuf {
            xbuf: vec![0.0; nlocal + nghost],
            nlocal,
        }
    }

    /// The concatenated `[owned | ghost]` x-vector. Ghost entries are only
    /// valid after [`DistCsr::update_ghosts`] for the matching matrix;
    /// matrix-free kernels index it with the matrix's remapped columns.
    pub fn x(&self) -> &[f64] {
        &self.xbuf
    }

    /// Number of locally owned entries at the front of [`Self::x`].
    pub fn nlocal(&self) -> usize {
        self.nlocal
    }

    /// Overwrite the owned block; ghost entries keep their last exchanged
    /// values. This is the primitive behind the bounded-staleness VI
    /// sweeps (`-async_vi`), which deliberately compute on stale ghosts
    /// between synchronized exchanges.
    pub fn set_owned(&mut self, x: &[f64]) {
        assert_eq!(x.len(), self.nlocal, "set_owned length");
        self.xbuf[..self.nlocal].copy_from_slice(x);
    }
}

/// Message tag of the split-phase ghost exchange. A single tag suffices
/// even when several matrices interleave exchanges: the SPMD program order
/// is identical on every rank, and per-(source, tag) delivery is FIFO, so
/// the k-th receive from a peer always pairs with its k-th send.
const GHOST_TAG: u64 = 0x6768_6f73_74; // "ghost"

/// Distributed CSR matrix: local row block, global columns ghost-remapped.
pub struct DistCsr {
    rank: usize,
    /// Vector (column-space) partition.
    col_part: Partition,
    /// Local rows with remapped columns; ncols = nlocal + nghost.
    local: Csr,
    /// Sorted global ids of ghost columns.
    ghost_ids: Vec<usize>,
    /// For each rank r: slice `ghost_range[r]` of `ghost_ids` owned by r.
    ghost_range: Vec<(usize, usize)>,
    /// For each rank r: local offsets (into the owned x-block) this rank
    /// must send to r on every exchange.
    send_plan: Vec<Vec<usize>>,
    /// boundary\[r\] ⇔ local row r touches at least one ghost column.
    /// Interior rows (`false`) can be computed while an exchange is in
    /// flight; boundary rows must wait for `finish` (DESIGN.md §14).
    boundary: Vec<bool>,
}

/// Ghost plan restricted to the ghost entries referenced by a *subset* of
/// the local rows, built once per subset by [`DistCsr::build_sub_plan`].
///
/// The policy operators select one of the `m` stacked action rows per
/// state, so the full matrix plan over-fetches whenever a ghost column is
/// referenced only by non-selected actions; the sub-plan moves exactly the
/// entries the selected rows read — the fetched values are the same f64s,
/// so results are bitwise identical while bytes strictly shrink.
pub struct GhostSubPlan {
    /// For each rank r: owned x-offsets to send to r.
    send: Vec<Vec<usize>>,
    /// For each rank r: positions in the ghost section (offsets into
    /// `ghost_ids`) filled by r's payload, ascending.
    recv_pos: Vec<Vec<usize>>,
}

impl GhostSubPlan {
    /// Ghost entries this rank receives per exchange under the sub-plan.
    pub fn nghost_needed(&self) -> usize {
        self.recv_pos.iter().map(|p| p.len()).sum()
    }
}

impl DistCsr {
    /// Assemble from local rows with *global* column indices.
    ///
    /// Collective: every rank must call this with its own rows and the same
    /// `col_part`. `local_rows[i]` are the (global_col, value) entries of the
    /// i-th locally owned row.
    pub fn assemble(
        comm: &Comm,
        col_part: Partition,
        local_rows: Vec<Vec<(usize, f64)>>,
    ) -> DistCsr {
        let rank = comm.rank();
        let size = comm.size();
        assert_eq!(col_part.size(), size, "partition/world size mismatch");
        let (clo, chi) = (col_part.lo(rank), col_part.hi(rank));
        let nlocal = chi - clo;

        // 1. Discover ghost columns.
        let mut ghost_ids: Vec<usize> = Vec::new();
        for row in &local_rows {
            for &(c, _) in row {
                assert!(c < col_part.n(), "column {c} out of range");
                if !(clo..chi).contains(&c) {
                    ghost_ids.push(c);
                }
            }
        }
        ghost_ids.sort_unstable();
        ghost_ids.dedup();

        // 2. Group ghosts by owner (contiguous in sorted order).
        let mut ghost_range = vec![(0usize, 0usize); size];
        {
            let mut start = 0;
            for r in 0..size {
                let (rlo, rhi) = (col_part.lo(r), col_part.hi(r));
                let mut end = start;
                while end < ghost_ids.len() && ghost_ids[end] < rhi {
                    debug_assert!(ghost_ids[end] >= rlo || r == rank);
                    end += 1;
                }
                ghost_range[r] = (start, end);
                start = end;
            }
            debug_assert_eq!(start, ghost_ids.len());
        }

        // 3. Exchange request lists: tell each owner which of its entries we
        //    need; receive which of ours others need (the send plan).
        let requests: Vec<Vec<u8>> = (0..size)
            .map(|r| {
                let (a, b) = ghost_range[r];
                codec::encode_usizes(&ghost_ids[a..b])
            })
            .collect();
        let received = comm.alltoallv(requests);
        let send_plan: Vec<Vec<usize>> = received
            .into_iter()
            .map(|bytes| {
                codec::decode_usizes(&bytes)
                    .into_iter()
                    .map(|g| {
                        debug_assert!((clo..chi).contains(&g));
                        g - clo
                    })
                    .collect()
            })
            .collect();

        // 4. Remap column indices: owned → [0, nlocal), ghost → nlocal + pos.
        let remapped: Vec<Vec<(usize, f64)>> = local_rows
            .into_iter()
            .map(|row| {
                row.into_iter()
                    .map(|(c, v)| {
                        let lc = if (clo..chi).contains(&c) {
                            c - clo
                        } else {
                            nlocal + ghost_ids.binary_search(&c).unwrap()
                        };
                        (lc, v)
                    })
                    .collect()
            })
            .collect();
        let local = Csr::from_row_lists(nlocal + ghost_ids.len(), remapped);

        // 5. Interior/boundary classification: a row whose columns are all
        //    owned (< nlocal) never reads ghost values, so it can be
        //    computed while an exchange is still in flight.
        let boundary: Vec<bool> = (0..local.nrows())
            .map(|r| {
                let (cols, _) = local.row(r);
                cols.iter().any(|&c| c >= nlocal)
            })
            .collect();

        DistCsr {
            rank,
            col_part,
            local,
            ghost_ids,
            ghost_range,
            send_plan,
            boundary,
        }
    }

    /// Owning rank of this local block.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Number of locally owned rows.
    pub fn local_nrows(&self) -> usize {
        self.local.nrows()
    }

    /// Number of ghost (off-rank) columns this block references.
    pub fn nghost(&self) -> usize {
        self.ghost_ids.len()
    }

    /// Stored entries in the local block.
    pub fn nnz_local(&self) -> usize {
        self.local.nnz()
    }

    /// The column-space partition (vector layout).
    pub fn col_partition(&self) -> Partition {
        self.col_part
    }

    /// The remapped local block (for kernels that iterate rows directly).
    pub fn local(&self) -> &Csr {
        &self.local
    }

    /// Per-row interior/boundary classification computed at assembly:
    /// `flags[r]` is true iff local row `r` touches a ghost column. The
    /// policy operators use this to schedule their interior rows during
    /// the split-phase exchange.
    pub fn boundary_flags(&self) -> &[bool] {
        &self.boundary
    }

    /// Translate a remapped local column index back to its global id.
    /// (Used by gather-based direct solves and the IO writer.)
    pub fn global_col(&self, local_col: usize) -> usize {
        let nlocal = self.col_part.local_len(self.rank);
        if local_col < nlocal {
            self.col_part.lo(self.rank) + local_col
        } else {
            self.ghost_ids[local_col - nlocal]
        }
    }

    /// Allocate the x-buffer for [`Self::spmv`].
    pub fn make_buffer(&self) -> GhostBuf {
        let nlocal = self.col_part.local_len(self.rank);
        GhostBuf {
            xbuf: vec![0.0; nlocal + self.ghost_ids.len()],
            nlocal,
        }
    }

    /// Refresh ghost values in `buf` from the distributed vector `x_local`.
    /// Collective. Separated from `spmv` so several SpMVs against the same
    /// x (e.g. the m action-blocks of a Bellman backup) pay one exchange.
    pub fn update_ghosts(&self, comm: &Comm, x_local: &[f64], buf: &mut GhostBuf) {
        assert_eq!(x_local.len(), buf.nlocal, "x_local length");
        buf.xbuf[..buf.nlocal].copy_from_slice(x_local);
        if comm.size() == 1 {
            return;
        }
        let send: Vec<Vec<u8>> = self
            .send_plan
            .iter()
            .map(|idxs| {
                let vals: Vec<f64> = idxs.iter().map(|&i| x_local[i]).collect();
                codec::encode_f64s(&vals)
            })
            .collect();
        let recv = comm.alltoallv(send);
        for (r, bytes) in recv.into_iter().enumerate() {
            let (a, b) = self.ghost_range[r];
            codec::decode_f64s_into(&bytes, &mut buf.xbuf[buf.nlocal + a..buf.nlocal + b]);
        }
    }

    /// Start a split-phase ghost exchange: copy the owned block into `buf`
    /// and post the point-to-point sends. Non-blocking (channel sends are
    /// buffered); pair with [`Self::finish_ghost_exchange`]. Between the
    /// two calls, interior rows (see [`Self::boundary_flags`]) may be
    /// computed — they never read the ghost section.
    pub fn start_ghost_exchange(&self, comm: &Comm, x_local: &[f64], buf: &mut GhostBuf) {
        assert_eq!(x_local.len(), buf.nlocal, "x_local length");
        buf.xbuf[..buf.nlocal].copy_from_slice(x_local);
        if comm.size() == 1 {
            return;
        }
        for r in 0..comm.size() {
            if r == self.rank || self.send_plan[r].is_empty() {
                continue;
            }
            let vals: Vec<f64> = self.send_plan[r].iter().map(|&i| x_local[i]).collect();
            comm.send(r, GHOST_TAG, codec::encode_f64s(&vals));
        }
    }

    /// Finish a split-phase ghost exchange: drain the receives posted by
    /// the peers' `start` calls into the ghost section of `buf`. The
    /// send/recv pairing is symmetric by construction: this rank expects a
    /// payload from r exactly when `ghost_range[r]` is non-empty, i.e.
    /// exactly when r's `send_plan[self]` is non-empty.
    pub fn finish_ghost_exchange(&self, comm: &Comm, buf: &mut GhostBuf) {
        if comm.size() == 1 {
            return;
        }
        for r in 0..comm.size() {
            if r == self.rank {
                continue;
            }
            let (a, b) = self.ghost_range[r];
            if a == b {
                continue;
            }
            let bytes = comm.recv(r, GHOST_TAG);
            codec::decode_f64s_into(&bytes, &mut buf.xbuf[buf.nlocal + a..buf.nlocal + b]);
        }
    }

    /// Build a ghost plan restricted to the ghost entries referenced by
    /// the given local rows. Collective (one `alltoallv` of request
    /// lists); the returned plan is reusable across exchanges for as long
    /// as the row subset is fixed.
    pub fn build_sub_plan(
        &self,
        comm: &Comm,
        rows: impl Iterator<Item = usize>,
    ) -> GhostSubPlan {
        let size = comm.size();
        let nlocal = self.col_part.local_len(self.rank);
        // Ghost positions the selected rows actually read.
        let mut needed = vec![false; self.ghost_ids.len()];
        for r in rows {
            let (cols, _) = self.local.row(r);
            for &c in cols {
                if c >= nlocal {
                    needed[c - nlocal] = true;
                }
            }
        }
        // Group by owner using the full plan's ranges (positions within a
        // range stay ascending, so payloads decode in order).
        let mut recv_pos: Vec<Vec<usize>> = vec![Vec::new(); size];
        for (r, &(a, b)) in self.ghost_range.iter().enumerate() {
            recv_pos[r] = (a..b).filter(|&p| needed[p]).collect();
        }
        // Tell each owner which of its entries we need; what we receive
        // back (as global ids) is our send side of the sub-plan.
        let requests: Vec<Vec<u8>> = recv_pos
            .iter()
            .map(|pos| {
                let ids: Vec<usize> = pos.iter().map(|&p| self.ghost_ids[p]).collect();
                codec::encode_usizes(&ids)
            })
            .collect();
        let clo = self.col_part.lo(self.rank);
        let send: Vec<Vec<usize>> = comm
            .alltoallv(requests)
            .into_iter()
            .map(|bytes| {
                codec::decode_usizes(&bytes)
                    .into_iter()
                    .map(|g| g - clo)
                    .collect()
            })
            .collect();
        GhostSubPlan { send, recv_pos }
    }

    /// [`Self::update_ghosts`] restricted to a sub-plan: refresh only the
    /// ghost entries the plan's rows read. Slots outside the plan keep
    /// stale values — callers must only evaluate rows of the subset the
    /// plan was built for. Collective.
    pub fn update_ghosts_subset(
        &self,
        comm: &Comm,
        plan: &GhostSubPlan,
        x_local: &[f64],
        buf: &mut GhostBuf,
    ) {
        assert_eq!(x_local.len(), buf.nlocal, "x_local length");
        buf.xbuf[..buf.nlocal].copy_from_slice(x_local);
        if comm.size() == 1 {
            return;
        }
        let send: Vec<Vec<u8>> = plan
            .send
            .iter()
            .map(|idxs| {
                let vals: Vec<f64> = idxs.iter().map(|&i| x_local[i]).collect();
                codec::encode_f64s(&vals)
            })
            .collect();
        let recv = comm.alltoallv(send);
        for (r, bytes) in recv.into_iter().enumerate() {
            let vals = codec::decode_f64s(&bytes);
            debug_assert_eq!(vals.len(), plan.recv_pos[r].len());
            for (&p, v) in plan.recv_pos[r].iter().zip(vals) {
                buf.xbuf[buf.nlocal + p] = v;
            }
        }
    }

    /// Split-phase `start` under a sub-plan (see
    /// [`Self::start_ghost_exchange`]).
    pub fn start_ghost_exchange_subset(
        &self,
        comm: &Comm,
        plan: &GhostSubPlan,
        x_local: &[f64],
        buf: &mut GhostBuf,
    ) {
        assert_eq!(x_local.len(), buf.nlocal, "x_local length");
        buf.xbuf[..buf.nlocal].copy_from_slice(x_local);
        if comm.size() == 1 {
            return;
        }
        for r in 0..comm.size() {
            if r == self.rank || plan.send[r].is_empty() {
                continue;
            }
            let vals: Vec<f64> = plan.send[r].iter().map(|&i| x_local[i]).collect();
            comm.send(r, GHOST_TAG, codec::encode_f64s(&vals));
        }
    }

    /// Split-phase `finish` under a sub-plan (see
    /// [`Self::finish_ghost_exchange`]).
    pub fn finish_ghost_exchange_subset(
        &self,
        comm: &Comm,
        plan: &GhostSubPlan,
        buf: &mut GhostBuf,
    ) {
        if comm.size() == 1 {
            return;
        }
        for r in 0..comm.size() {
            if r == self.rank || plan.recv_pos[r].is_empty() {
                continue;
            }
            let bytes = comm.recv(r, GHOST_TAG);
            let vals = codec::decode_f64s(&bytes);
            debug_assert_eq!(vals.len(), plan.recv_pos[r].len());
            for (&p, v) in plan.recv_pos[r].iter().zip(vals) {
                buf.xbuf[buf.nlocal + p] = v;
            }
        }
    }

    /// y_local ← A_local · x  (ghosts must be current in `buf`).
    pub fn spmv_local(&self, buf: &GhostBuf, y_local: &mut [f64]) {
        self.local.spmv(&buf.xbuf, y_local);
    }

    /// One pass of the two-pass overlapped SpMV: compute only the rows
    /// whose boundary flag equals `boundary_pass`, leaving the others
    /// untouched. Uses the same chunk grid and the same per-row gather
    /// kernel as [`Csr::spmv`], so across the two passes every output row
    /// is produced bit-for-bit as in the single-pass kernel.
    fn spmv_rows(&self, buf: &GhostBuf, y_local: &mut [f64], boundary_pass: bool) {
        let csr = &self.local;
        assert_eq!(buf.xbuf.len(), csr.ncols(), "spmv: x len");
        assert_eq!(y_local.len(), csr.nrows(), "spmv: y len");
        let (indptr, indices, values) = (csr.indptr(), csr.indices(), csr.values());
        let x = &buf.xbuf;
        crate::util::par::par_for_rows(y_local, |offset, chunk| {
            for (i, yr) in chunk.iter_mut().enumerate() {
                let r = offset + i;
                if self.boundary[r] != boundary_pass {
                    continue;
                }
                let (a, b) = (indptr[r], indptr[r + 1]);
                // SAFETY: every index in `indices` is < ncols == x.len(),
                // enforced at construction (same invariant as `Csr::spmv`).
                *yr = unsafe {
                    crate::util::simd::gather_dot_unchecked(
                        &indices[a..b],
                        &values[a..b],
                        x,
                    )
                };
            }
        });
    }

    /// Full distributed SpMV: ghost exchange + local kernel. Collective.
    ///
    /// When the [`crate::comm::overlap`] capability is enabled, the
    /// exchange runs split-phase: interior rows are computed while the
    /// ghost values are in flight, boundary rows after `finish`. Both
    /// schedules evaluate every row with the identical kernel over the
    /// identical chunk grid — results are bitwise identical (pinned by
    /// `tests/par_determinism.rs`).
    pub fn spmv(&self, comm: &Comm, x_local: &[f64], y_local: &mut [f64], buf: &mut GhostBuf) {
        if self.ghost_ids.is_empty() && comm.size() == 1 {
            // serial fast path: no ghosts → the remapped local block reads
            // x_local directly, skipping the xbuf memcpy (≈8 MB/iteration
            // at 10⁶ states — EXPERIMENTS.md §Perf)
            self.local.spmv(x_local, y_local);
            return;
        }
        if comm.size() > 1 && crate::comm::overlap::enabled(comm.size()) {
            self.start_ghost_exchange(comm, x_local, buf);
            self.spmv_rows(buf, y_local, false);
            self.finish_ghost_exchange(comm, buf);
            self.spmv_rows(buf, y_local, true);
            return;
        }
        self.update_ghosts(comm, x_local, buf);
        self.spmv_local(buf, y_local);
    }
}

/// Distributed dot product over block-partitioned vectors. Collective.
pub fn dist_dot(comm: &Comm, a: &[f64], b: &[f64]) -> f64 {
    comm.sum(super::dot(a, b))
}

/// Distributed 2-norm. Collective.
pub fn dist_norm2(comm: &Comm, a: &[f64]) -> f64 {
    comm.sum(super::dot(a, a)).sqrt()
}

/// Distributed ∞-norm. Collective.
pub fn dist_norm_inf(comm: &Comm, a: &[f64]) -> f64 {
    comm.max(super::norm_inf(a))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;
    use crate::prop_assert;
    use crate::util::prng::Xoshiro256pp;
    use crate::util::prop;
    use std::sync::Arc;

    #[test]
    fn partition_covers_disjoint() {
        for n in [0usize, 1, 7, 100, 101] {
            for size in [1usize, 2, 3, 8] {
                let p = Partition::new(n, size);
                let mut total = 0;
                for r in 0..size {
                    assert!(p.lo(r) <= p.hi(r));
                    total += p.local_len(r);
                    if r > 0 {
                        assert_eq!(p.hi(r - 1), p.lo(r));
                    }
                }
                assert_eq!(total, n);
            }
        }
    }

    #[test]
    fn partition_owner_correct() {
        let p = Partition::new(103, 7);
        for i in 0..103 {
            let r = p.owner(i);
            assert!(p.lo(r) <= i && i < p.hi(r), "i={i} r={r}");
        }
    }

    #[test]
    fn partition_empty_is_well_defined() {
        // n = 0: every rank owns the empty range; nothing divides by zero.
        let p = Partition::new(0, 5);
        for r in 0..5 {
            assert_eq!((p.lo(r), p.hi(r)), (0, 0));
            assert_eq!(p.local_len(r), 0);
        }
        assert_eq!(p.ranges(), vec![(0, 0); 5]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn partition_owner_empty_panics_cleanly() {
        // Regression: release builds used to die with a raw divide-by-zero
        // here; the contract violation must be reported as such instead.
        Partition::new(0, 4).owner(0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn partition_owner_rejects_out_of_range_index() {
        Partition::new(10, 2).owner(10);
    }

    #[test]
    fn partition_fewer_items_than_ranks() {
        // n < size: leading ranks own one item each, the rest own nothing,
        // and owner() agrees with the ranges.
        let p = Partition::new(3, 8);
        let mut total = 0;
        for r in 0..8 {
            total += p.local_len(r);
            assert!(p.local_len(r) <= 1);
        }
        assert_eq!(total, 3);
        for i in 0..3 {
            let r = p.owner(i);
            assert!(p.lo(r) <= i && i < p.hi(r), "i={i} r={r}");
        }
    }

    #[test]
    fn partition_balanced() {
        let p = Partition::new(1_000_003, 8);
        let lens: Vec<usize> = (0..8).map(|r| p.local_len(r)).collect();
        let max = *lens.iter().max().unwrap();
        let min = *lens.iter().min().unwrap();
        assert!(max - min <= 1, "imbalanced: {lens:?}");
    }

    /// Build a random global CSR, distribute it, and compare distributed
    /// SpMV against the serial product for several world sizes.
    fn check_dist_spmv(seed: u64, n: usize, size: usize) {
        let mut rng = Xoshiro256pp::new(seed);
        // global matrix: ~4 entries per row
        let mut rows: Vec<Vec<(usize, f64)>> = Vec::with_capacity(n);
        for _ in 0..n {
            let k = 1 + rng.index(4);
            rows.push(
                (0..k)
                    .map(|_| (rng.index(n), rng.range_f64(-1.0, 1.0)))
                    .collect(),
            );
        }
        let global = Csr::from_row_lists(n, rows.clone());
        let x: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let y_serial = global.mul_vec(&x);

        let rows = Arc::new(rows);
        let x = Arc::new(x);
        let part = Partition::new(n, size);
        let out = World::run(size, move |comm| {
            let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
            let my_rows: Vec<Vec<(usize, f64)>> = rows[lo..hi].to_vec();
            let a = DistCsr::assemble(&comm, part, my_rows);
            let mut buf = a.make_buffer();
            let mut y = vec![0.0; hi - lo];
            a.spmv(&comm, &x[lo..hi], &mut y, &mut buf);
            y
        });
        let y_dist: Vec<f64> = out.into_iter().flatten().collect();
        prop::close_slices(&y_dist, &y_serial, 1e-12).unwrap();
    }

    #[test]
    fn dist_spmv_matches_serial_various_sizes() {
        for size in [1, 2, 3, 5] {
            check_dist_spmv(100 + size as u64, 37, size);
        }
    }

    #[test]
    fn dist_spmv_large() {
        check_dist_spmv(7, 500, 4);
    }

    #[test]
    fn ghost_reuse_multiple_spmv() {
        // Two products against the same x must allow one exchange.
        let n = 20;
        let part = Partition::new(n, 2);
        let out = World::run(2, move |comm| {
            let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
            // A = shift-by-one permutation (wraps): needs ghosts at edges.
            let rows: Vec<Vec<(usize, f64)>> =
                (lo..hi).map(|i| vec![((i + 1) % n, 1.0)]).collect();
            let a = DistCsr::assemble(&comm, part, rows);
            let x: Vec<f64> = (lo..hi).map(|i| i as f64).collect();
            let mut buf = a.make_buffer();
            a.update_ghosts(&comm, &x, &mut buf);
            let mut y1 = vec![0.0; hi - lo];
            let mut y2 = vec![0.0; hi - lo];
            a.spmv_local(&buf, &mut y1);
            a.spmv_local(&buf, &mut y2);
            assert_eq!(y1, y2);
            y1
        });
        let y: Vec<f64> = out.into_iter().flatten().collect();
        let expect: Vec<f64> = (0..n).map(|i| ((i + 1) % n) as f64).collect();
        assert_eq!(y, expect);
    }

    #[test]
    fn dist_reductions() {
        let part = Partition::new(10, 2);
        let out = World::run(2, move |comm| {
            let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
            let a: Vec<f64> = (lo..hi).map(|i| i as f64).collect();
            let b = vec![1.0; hi - lo];
            (
                dist_dot(&comm, &a, &b),
                dist_norm_inf(&comm, &a),
                dist_norm2(&comm, &b),
            )
        });
        for (d, ninf, n2) in out {
            assert_eq!(d, 45.0);
            assert_eq!(ninf, 9.0);
            assert!((n2 - (10.0f64).sqrt()).abs() < 1e-12);
        }
    }

    #[test]
    fn prop_dist_spmv_random() {
        prop::forall("distributed spmv == serial", |rng| {
            let n = 5 + rng.index(40);
            let size = 1 + rng.index(4);
            let seed = rng.next_u64();
            check_dist_spmv(seed, n, size);
            prop_assert!(true, "");
            Ok(())
        });
    }

    #[test]
    fn no_ghosts_when_serial() {
        let part = Partition::new(10, 1);
        World::run(1, move |comm| {
            let rows: Vec<Vec<(usize, f64)>> = (0..10).map(|i| vec![(i, 2.0)]).collect();
            let a = DistCsr::assemble(&comm, part, rows);
            assert_eq!(a.nghost(), 0);
        });
    }

    /// Random rectangular local blocks (rows_per_col rows per owned
    /// column, like the stacked MDP kernel) for the overlap tests.
    fn random_local_rows(
        rng: &mut Xoshiro256pp,
        n: usize,
        lo: usize,
        hi: usize,
        rows_per_col: usize,
    ) -> Vec<Vec<(usize, f64)>> {
        (0..(hi - lo) * rows_per_col)
            .map(|_| {
                let k = 1 + rng.index(4);
                (0..k)
                    .map(|_| (rng.index(n), rng.range_f64(-1.0, 1.0)))
                    .collect()
            })
            .collect()
    }

    #[test]
    fn boundary_flags_classify_ghost_rows() {
        let n = 12;
        let part = Partition::new(n, 3);
        World::run(3, move |comm| {
            let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
            // Row for i: diagonal (owned) plus neighbor (i+1)%n, which is a
            // ghost exactly for the last owned index.
            let rows: Vec<Vec<(usize, f64)>> = (lo..hi)
                .map(|i| vec![(i, 1.0), ((i + 1) % n, 1.0)])
                .collect();
            let a = DistCsr::assemble(&comm, part, rows);
            let flags = a.boundary_flags();
            assert_eq!(flags.len(), hi - lo);
            for (k, &f) in flags.iter().enumerate() {
                assert_eq!(f, k == hi - lo - 1, "row {k}");
            }
        });
    }

    #[test]
    fn split_phase_exchange_matches_bulk_bitwise() {
        // start/finish must land exactly the bytes update_ghosts lands,
        // including back-to-back exchanges (FIFO pairing, no barriers).
        let n = 41;
        for size in [2usize, 3, 5] {
            let part = Partition::new(n, size);
            World::run(size, move |comm| {
                let mut rng = Xoshiro256pp::new(900 + comm.rank() as u64);
                let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
                let rows = random_local_rows(&mut rng, n, lo, hi, 1);
                let a = DistCsr::assemble(&comm, part, rows);
                let mut bulk = a.make_buffer();
                let mut split = a.make_buffer();
                for round in 0..3u64 {
                    let x: Vec<f64> = (lo..hi)
                        .map(|i| (i as f64 + 0.25) * (round as f64 + 1.0))
                        .collect();
                    a.update_ghosts(&comm, &x, &mut bulk);
                    a.start_ghost_exchange(&comm, &x, &mut split);
                    a.finish_ghost_exchange(&comm, &mut split);
                    assert_eq!(bulk.x(), split.x(), "round {round}");
                }
            });
        }
    }

    #[test]
    fn overlapped_spmv_matches_sync_bitwise() {
        // Two-pass interior/boundary evaluation (explicit split-phase
        // calls, independent of the process-global mode) must reproduce
        // the bulk-synchronous product bit for bit.
        let n = 53;
        for size in [2usize, 4] {
            let part = Partition::new(n, size);
            World::run(size, move |comm| {
                let mut rng = Xoshiro256pp::new(77 + comm.rank() as u64);
                let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
                let rows = random_local_rows(&mut rng, n, lo, hi, 1);
                let a = DistCsr::assemble(&comm, part, rows);
                let x: Vec<f64> = (lo..hi).map(|i| (i as f64).sin()).collect();
                let mut buf = a.make_buffer();
                let mut y_sync = vec![0.0; hi - lo];
                a.update_ghosts(&comm, &x, &mut buf);
                a.spmv_local(&buf, &mut y_sync);
                let mut buf2 = a.make_buffer();
                let mut y_ovl = vec![f64::NAN; hi - lo];
                a.start_ghost_exchange(&comm, &x, &mut buf2);
                a.spmv_rows(&buf2, &mut y_ovl, false);
                a.finish_ghost_exchange(&comm, &mut buf2);
                a.spmv_rows(&buf2, &mut y_ovl, true);
                for (s, o) in y_sync.iter().zip(&y_ovl) {
                    assert_eq!(s.to_bits(), o.to_bits());
                }
            });
        }
    }

    #[test]
    fn sub_plan_matches_full_and_reduces_bytes() {
        // Stacked-kernel shape: 2 rows per owned column ("actions"); the
        // subset selects action 0 everywhere. Action-1 rows reference
        // extra ghosts, so the sub-plan must move strictly fewer bytes
        // while producing bitwise-identical values on the selected rows.
        let n = 12;
        let part = Partition::new(n, 3);
        let out = World::run(3, move |comm| {
            let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
            let mut rows: Vec<Vec<(usize, f64)>> = Vec::new();
            for i in lo..hi {
                // action 0: diagonal + near neighbor
                rows.push(vec![(i, 0.5), ((i + 1) % n, 0.5)]);
                // action 1: far neighbors → extra ghost columns
                rows.push(vec![((i + 2) % n, 0.5), ((i + 5) % n, 0.5)]);
            }
            let a = DistCsr::assemble(&comm, part, rows);
            let sel: Vec<usize> = (0..(hi - lo)).map(|s| 2 * s).collect();
            let plan = a.build_sub_plan(&comm, sel.iter().copied());
            assert!(plan.nghost_needed() < a.nghost());

            let x: Vec<f64> = (lo..hi).map(|i| (i as f64 + 1.0).recip()).collect();
            let mut y_full = vec![0.0; 2 * (hi - lo)];
            let mut y_sub = vec![f64::NAN; 2 * (hi - lo)];

            comm.barrier();
            let b0 = comm.stats().total_bytes();
            let mut buf = a.make_buffer();
            a.update_ghosts(&comm, &x, &mut buf);
            comm.barrier();
            let b1 = comm.stats().total_bytes();
            a.spmv_local(&buf, &mut y_full);

            let mut buf2 = a.make_buffer();
            comm.barrier();
            let b2 = comm.stats().total_bytes();
            a.update_ghosts_subset(&comm, &plan, &x, &mut buf2);
            comm.barrier();
            let b3 = comm.stats().total_bytes();
            a.spmv_local(&buf2, &mut y_sub);

            // Selected rows agree bitwise; the split-phase subset variant
            // agrees with the bulk subset variant too.
            for &r in &sel {
                assert_eq!(y_full[r].to_bits(), y_sub[r].to_bits(), "row {r}");
            }
            let mut buf3 = a.make_buffer();
            a.start_ghost_exchange_subset(&comm, &plan, &x, &mut buf3);
            a.finish_ghost_exchange_subset(&comm, &plan, &mut buf3);
            assert_eq!(buf2.x(), buf3.x());
            (b1 - b0, b3 - b2)
        });
        for (full_bytes, sub_bytes) in out {
            assert!(
                sub_bytes < full_bytes,
                "sub-plan exchange {sub_bytes} not below full {full_bytes}"
            );
        }
    }
}
