//! Sparse/dense linear algebra substrate (the PETSc `Mat`/`Vec` equivalent).
//!
//! - [`csr`]: serial CSR matrices + SpMV kernels (PETSc `SeqAIJ`).
//! - [`bsr`]: 1×[`crate::util::simd::LANES`] column-blocked rows for the
//!   dense-ish policy systems (DESIGN.md §13).
//! - [`dense`]: small dense matrices + LU with partial pivoting (exact
//!   policy evaluation, tests).
//! - [`dist`]: row-partitioned distributed CSR with precomputed
//!   ghost-exchange plans (PETSc `MPIAIJ` + `VecScatter`).
//!
//! The vector kernels below (`dot`/`norm2`/`norm_inf`/`axpy`/`aypx`/
//! `scale`) thread through [`crate::util::simd`]: parallel over the fixed
//! chunk grid of [`crate::util::par`], lane-unrolled inside each chunk,
//! with partials folded in chunk order — bitwise identical for every
//! thread count per selected kernel backend.

pub mod bsr;
pub mod csr;
pub mod dense;
pub mod dist;

pub use bsr::Bsr;
pub use csr::Csr;
pub use dense::DenseMat;
pub use dist::{DistCsr, Partition};

use crate::util::par;
use crate::util::simd;

/// ∞-norm of a slice.
///
/// Parallel over the fixed chunk grid for large slices; `max` is exact, so
/// the result is identical to the serial fold for every thread count and
/// kernel backend.
pub fn norm_inf(xs: &[f64]) -> f64 {
    par::par_reduce(xs.len(), |lo, hi| simd::max_abs(&xs[lo..hi]), f64::max).unwrap_or(0.0)
}

/// 2-norm of a slice.
pub fn norm2(xs: &[f64]) -> f64 {
    dot(xs, xs).sqrt()
}

/// Dot product.
///
/// Large slices reduce over the fixed chunk grid of [`crate::util::par`]
/// (per-chunk lane-unrolled sums combined in chunk order), so the value is
/// **bitwise identical for every thread count** — the KSP inner products
/// this feeds stay deterministic under `-threads`. The per-chunk kernel is
/// [`crate::util::simd::dot`].
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    par::par_reduce(
        a.len(),
        |lo, hi| simd::dot(&a[lo..hi], &b[lo..hi]),
        |x, y| x + y,
    )
    .unwrap_or(0.0)
}

/// y ← a·x + y
///
/// Elementwise, so parallel chunks are bitwise identical to the serial
/// loop for every thread count.
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    par::par_for_rows(y, |offset, chunk| {
        simd::axpy(a, &x[offset..offset + chunk.len()], chunk);
    });
}

/// y ← x + b·y  (BLAS `aypx`)
pub fn aypx(b: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    par::par_for_rows(y, |offset, chunk| {
        simd::aypx(b, &x[offset..offset + chunk.len()], chunk);
    });
}

/// x ← a·x
pub fn scale(a: f64, x: &mut [f64]) {
    par::par_for_rows(x, |_offset, chunk| {
        simd::scale(a, chunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms_and_dot() {
        assert_eq!(norm_inf(&[1.0, -3.0, 2.0]), 3.0);
        assert_eq!(norm2(&[3.0, 4.0]), 5.0);
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(norm_inf(&[]), 0.0);
    }

    #[test]
    fn blas1_ops() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[10.0, 20.0], &mut y);
        assert_eq!(y, vec![21.0, 41.0]);
        let mut y2 = vec![1.0, 2.0];
        aypx(3.0, &[10.0, 10.0], &mut y2);
        assert_eq!(y2, vec![13.0, 16.0]);
        let mut x = vec![2.0, -4.0];
        scale(0.5, &mut x);
        assert_eq!(x, vec![1.0, -2.0]);
    }
}
