//! PJRT runtime bridge + the native dense-block backend.
//!
//! In the full three-layer stack, `python/compile/aot.py` lowers the
//! jax/Pallas graphs (Layers 1/2) to HLO **text** under `artifacts/`, and
//! this module compiles and executes them through a PJRT client. This
//! build is **zero-dependency by construction** (offline container, no XLA
//! client to link), so the PJRT entry points are present but report
//! unavailability from [`Engine::load`]; every call site treats that as
//! "dense accelerator not present" and falls back to the native path.
//!
//! The native path is first-class, not a shim: the dense Bellman kernel
//! ([`bellman_dense_native`]) is the reference the artifacts are validated
//! against, and dense policy evaluation flows through the **same KSP
//! stack** as the sparse solver via [`crate::ksp::DenseOp`] over
//! [`dense_policy_matrix`] — the operator-trait seam of DESIGN.md §4 is
//! exactly what makes the two backends interchangeable.

use crate::linalg::DenseMat;
use std::convert::Infallible;
use std::path::Path;

/// A compiled-artifact cache over one PJRT client.
///
/// Uninhabited in zero-dependency builds: [`Engine::load`] always returns
/// `Err`, so no `Engine` value can exist and the methods below are
/// statically unreachable (they compile against the real signatures the
/// PJRT-enabled build exposes).
pub struct Engine {
    void: Infallible,
}

impl Engine {
    /// Create a PJRT client and read the artifact manifest in `dir`.
    ///
    /// Always `Err` in this build; the message tells the caller (CLI,
    /// benches, tests) why, and they skip the PJRT cases.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine, String> {
        Err(format!(
            "PJRT runtime unavailable: this is the zero-dependency build (no XLA \
             client linked). Artifacts under '{}' are not executable from Rust here; \
             use the native dense path (runtime::bellman_dense_native / ksp::DenseOp).",
            dir.as_ref().display()
        ))
    }

    /// The PJRT platform name (or the stub marker when no client is linked).
    pub fn platform(&self) -> String {
        match self.void {}
    }

    /// Artifact file names listed in the manifest.
    pub fn available(&self) -> Vec<String> {
        match self.void {}
    }

    /// Fused sweep count the `vi_*` artifacts were lowered with.
    pub fn sweeps(&self) -> usize {
        match self.void {}
    }

    /// Compile (once) the executable for an artifact file.
    pub fn executable(&mut self, _file: &str) -> Result<(), String> {
        match self.void {}
    }
}

/// Typed driver for the dense Bellman artifacts of one block shape.
///
/// The dense-block accelerator path (DESIGN.md §2): for dense transition
/// blocks (e.g. SIS models, aggregated macro-states) the Bellman backup and
/// fused k-sweep VI run as a single PJRT execution instead of the sparse
/// CSR path. Constructible only from a live [`Engine`], hence unreachable
/// in this build.
pub struct DenseBellman {
    /// Number of states of the dense block.
    pub n_states: usize,
    /// Number of actions of the dense block.
    pub n_actions: usize,
    /// Fused VI sweeps per execution.
    pub sweeps: usize,
}

impl DenseBellman {
    /// Select the artifact set for an `(n, m)` dense block.
    pub fn new(engine: &Engine, _n_states: usize, _n_actions: usize) -> Result<DenseBellman, String> {
        match engine.void {}
    }

    /// One Bellman backup: returns (TV, greedy policy).
    pub fn bellman(
        &self,
        engine: &mut Engine,
        _p: &[f32],
        _g: &[f32],
        _v: &[f32],
        _gamma: f32,
    ) -> Result<(Vec<f32>, Vec<i32>), String> {
        match engine.void {}
    }

    /// `sweeps` fused value-iteration sweeps (one device round-trip).
    pub fn vi_sweeps(
        &self,
        engine: &mut Engine,
        _p: &[f32],
        _g: &[f32],
        _v: &[f32],
        _gamma: f32,
    ) -> Result<Vec<f32>, String> {
        match engine.void {}
    }

    /// Backup + residual in one execution: (TV, policy, ‖TV − V‖∞).
    pub fn residual(
        &self,
        engine: &mut Engine,
        _p: &[f32],
        _g: &[f32],
        _v: &[f32],
        _gamma: f32,
    ) -> Result<(Vec<f32>, Vec<i32>, f32), String> {
        match engine.void {}
    }

    /// Solve the dense block to tolerance by chaining fused VI sweeps;
    /// returns (V, policy, sweep_count).
    pub fn solve_vi(
        &self,
        engine: &mut Engine,
        _p: &[f32],
        _g: &[f32],
        _gamma: f32,
        _atol: f32,
        _max_sweeps: usize,
    ) -> Result<(Vec<f32>, Vec<i32>, usize), String> {
        match engine.void {}
    }
}

/// Reference implementation of the dense Bellman backup in Rust (f32),
/// used to validate artifacts and as the native comparator in bench E6.
pub fn bellman_dense_native(
    n: usize,
    m: usize,
    p: &[f32],
    g: &[f32],
    v: &[f32],
    gamma: f32,
) -> (Vec<f32>, Vec<i32>) {
    assert_eq!(p.len(), m * n * n);
    assert_eq!(g.len(), m * n);
    assert_eq!(v.len(), n);
    let mut tv = vec![f32::INFINITY; n];
    let mut pi = vec![0i32; n];
    for a in 0..m {
        for s in 0..n {
            let row = &p[a * n * n + s * n..a * n * n + (s + 1) * n];
            let mut exp = 0.0f32;
            for (pj, vj) in row.iter().zip(v) {
                exp += pj * vj;
            }
            let q = g[a * n + s] + gamma * exp;
            if q < tv[s] {
                tv[s] = q;
                pi[s] = a as i32;
            }
        }
    }
    (tv, pi)
}

/// Extract the dense `P_π` (n×n, f64) of a fixed policy from an `(A,S,S)`
/// f32 block. Feed the result to [`crate::ksp::DenseOp`] to evaluate the
/// policy through the shared KSP stack — the dense-accelerator analogue of
/// [`crate::mdp::MatFreePolicyOp`] selecting rows `s·m + π(s)`.
pub fn dense_policy_matrix(n: usize, m: usize, p: &[f32], policy: &[usize]) -> DenseMat {
    assert_eq!(p.len(), m * n * n);
    assert_eq!(policy.len(), n);
    let mut out = DenseMat::zeros(n, n);
    for (s, &a) in policy.iter().enumerate() {
        assert!(a < m, "policy action {a} out of range");
        let row = &p[a * n * n + s * n..a * n * n + (s + 1) * n];
        for (c, &v) in row.iter().enumerate() {
            out[(s, c)] = v as f64;
        }
    }
    out
}

/// Random dense row-stochastic block (f32), deterministic in seed. Shared
/// by the runtime tests, the dense-accelerator example and bench E6.
pub fn random_block(seed: u64, n: usize, m: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    use crate::util::prng::Xoshiro256pp;
    let mut rng = Xoshiro256pp::new(seed);
    let mut p = vec![0.0f32; m * n * n];
    for a in 0..m {
        for s in 0..n {
            let row = &mut p[a * n * n + s * n..a * n * n + (s + 1) * n];
            let mut sum = 0.0f32;
            for x in row.iter_mut() {
                *x = (rng.next_f64() as f32) + 1e-3;
                sum += *x;
            }
            for x in row.iter_mut() {
                *x /= sum;
            }
        }
    }
    let g: Vec<f32> = (0..m * n).map(|_| rng.next_f64() as f32).collect();
    let v: Vec<f32> = (0..n).map(|_| (rng.next_f64() as f32) - 0.5).collect();
    (p, g, v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ksp::{self, Apply, DenseOp, Precond, Tolerance};

    #[test]
    fn native_bellman_minimizes() {
        let (p, g, v) = random_block(1, 16, 3);
        let (tv, pi) = bellman_dense_native(16, 3, &p, &g, &v, 0.9);
        for s in 0..16 {
            for a in 0..3 {
                let row = &p[a * 256 + s * 16..a * 256 + (s + 1) * 16];
                let exp: f32 = row.iter().zip(&v).map(|(x, y)| x * y).sum();
                let q = g[a * 16 + s] + 0.9 * exp;
                assert!(q >= tv[s] - 1e-5);
            }
            let a = pi[s] as usize;
            let row = &p[a * 256 + s * 16..a * 256 + (s + 1) * 16];
            let exp: f32 = row.iter().zip(&v).map(|(x, y)| x * y).sum();
            assert!((g[a * 16 + s] + 0.9 * exp - tv[s]).abs() < 1e-5);
        }
    }

    #[test]
    fn random_block_rows_stochastic() {
        let (p, _, _) = random_block(3, 8, 2);
        for a in 0..2 {
            for s in 0..8 {
                let sum: f32 = p[a * 64 + s * 8..a * 64 + (s + 1) * 8].iter().sum();
                assert!((sum - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn engine_unavailable_in_zero_dep_build() {
        let err = Engine::load("artifacts").err().expect("must be Err");
        assert!(err.contains("PJRT"), "{err}");
    }

    #[test]
    fn dense_policy_matrix_selects_rows() {
        let (p, _, _) = random_block(5, 6, 3);
        let policy = vec![0usize, 1, 2, 0, 1, 2];
        let pd = dense_policy_matrix(6, 3, &p, &policy);
        for (s, &a) in policy.iter().enumerate() {
            for c in 0..6 {
                let expect = p[a * 36 + s * 6 + c] as f64;
                assert!((pd[(s, c)] - expect).abs() < 1e-12);
            }
        }
        // rows stay stochastic (within f32 accumulation error)
        for s in 0..6 {
            let sum: f64 = pd.row(s).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    /// Dense policy evaluation through DenseOp + GMRES matches the fixed
    /// point of the native `T_π` recurrence — the dense backend really does
    /// flow through the shared KSP stack.
    #[test]
    fn dense_op_policy_evaluation_matches_fixed_point() {
        let n = 12;
        let m = 2;
        let (p, g, _) = random_block(9, n, m);
        let policy: Vec<usize> = (0..n).map(|s| s % m).collect();
        let gamma = 0.9f64;
        let pd = dense_policy_matrix(n, m, &p, &policy);
        let g_pi: Vec<f64> = policy
            .iter()
            .enumerate()
            .map(|(s, &a)| g[a * n + s] as f64)
            .collect();

        crate::comm::World::run(1, move |comm| {
            let op = DenseOp::new(&pd, gamma);
            let mut x = vec![0.0; n];
            let tol = Tolerance {
                atol: 1e-12,
                rtol: 0.0,
                max_iters: 10_000,
            };
            let stats = ksp::gmres::solve(&comm, &op, &Precond::None, &g_pi, &mut x, &tol, n);
            assert!(stats.converged);
            // fixed point check: x == g_pi + γ P_π x
            let mut buf = op.make_buffer();
            let mut r = vec![0.0; n];
            let res = op.residual(&comm, &g_pi, &x, &mut r, &mut buf);
            assert!(res < 1e-10, "residual {res}");
        });
    }
}
