//! PJRT runtime: load AOT HLO artifacts and execute them from Rust.
//!
//! This is the bridge between Layer 3 (this crate) and the build-time
//! Layers 1/2: `python/compile/aot.py` lowers the jax/Pallas graphs to HLO
//! **text** under `artifacts/`; [`Engine`] compiles each artifact once on
//! the PJRT CPU client and [`DenseBellman`] exposes typed entry points the
//! solver and examples call. Python never runs at solve time.
//!
//! Artifact discovery goes through `artifacts/manifest.json` (written by
//! aot.py), so the Rust side never hard-codes shapes.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled artifact cache over one PJRT client.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Json,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create a CPU PJRT client and read the manifest in `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!("reading {} (run `make artifacts`)", manifest_path.display())
        })?;
        let manifest = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            dir,
            manifest,
            compiled: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifact file names listed in the manifest.
    pub fn available(&self) -> Vec<String> {
        self.manifest
            .get("entries")
            .and_then(|e| e.as_arr())
            .map(|entries| {
                entries
                    .iter()
                    .filter_map(|e| e.get("file").and_then(|f| f.as_str()).map(String::from))
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Fused sweep count the `vi_*` artifacts were lowered with.
    pub fn sweeps(&self) -> usize {
        self.manifest
            .get("sweeps")
            .and_then(|s| s.as_f64())
            .unwrap_or(10.0) as usize
    }

    /// Compile (once) and return the executable for an artifact file.
    pub fn executable(&mut self, file: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(file) {
            let path = self.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("loading HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {file}"))?;
            self.compiled.insert(file.to_string(), exe);
        }
        Ok(&self.compiled[file])
    }

    /// Execute an artifact on literal inputs; returns the flattened tuple
    /// elements (aot.py lowers everything with `return_tuple=True`).
    pub fn run(&mut self, file: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(file)?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}

/// Typed driver for the dense Bellman artifacts of one block shape.
///
/// The dense-block accelerator path (DESIGN.md §2): for dense transition
/// blocks (e.g. SIS models, aggregated macro-states) the Bellman backup and
/// fused k-sweep VI run as a single PJRT execution instead of the sparse
/// CSR path.
pub struct DenseBellman {
    pub n_states: usize,
    pub n_actions: usize,
    pub sweeps: usize,
    bellman_file: String,
    vi_file: String,
    residual_file: String,
}

impl DenseBellman {
    /// Select the artifact set for an `(n, m)` dense block.
    pub fn new(engine: &Engine, n_states: usize, n_actions: usize) -> Result<DenseBellman> {
        let sweeps = engine.sweeps();
        let bellman_file = format!("bellman_{n_states}_{n_actions}.hlo.txt");
        let vi_file = format!("vi_{n_states}_{n_actions}_k{sweeps}.hlo.txt");
        let residual_file = format!("residual_{n_states}_{n_actions}.hlo.txt");
        let avail = engine.available();
        for f in [&bellman_file, &vi_file, &residual_file] {
            if !avail.iter().any(|a| a == f) {
                return Err(anyhow!(
                    "artifact {f} not in manifest; available: {avail:?} \
                     (re-run `make artifacts` with --shapes {n_states}x{n_actions})"
                ));
            }
        }
        Ok(DenseBellman {
            n_states,
            n_actions,
            sweeps,
            bellman_file,
            vi_file,
            residual_file,
        })
    }

    fn literals(&self, p: &[f32], g: &[f32], v: &[f32], gamma: f32) -> Result<Vec<xla::Literal>> {
        let (n, m) = (self.n_states, self.n_actions);
        anyhow::ensure!(p.len() == m * n * n, "P must be (A,S,S) flattened");
        anyhow::ensure!(g.len() == m * n, "G must be (A,S) flattened");
        anyhow::ensure!(v.len() == n, "V must be (S,)");
        Ok(vec![
            xla::Literal::vec1(p).reshape(&[m as i64, n as i64, n as i64])?,
            xla::Literal::vec1(g).reshape(&[m as i64, n as i64])?,
            xla::Literal::vec1(v),
            xla::Literal::scalar(gamma),
        ])
    }

    /// One Bellman backup: returns (TV, greedy policy).
    pub fn bellman(
        &self,
        engine: &mut Engine,
        p: &[f32],
        g: &[f32],
        v: &[f32],
        gamma: f32,
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        let inputs = self.literals(p, g, v, gamma)?;
        let out = engine.run(&self.bellman_file, &inputs)?;
        anyhow::ensure!(out.len() == 2, "bellman artifact must return (tv, pi)");
        Ok((out[0].to_vec::<f32>()?, out[1].to_vec::<i32>()?))
    }

    /// `sweeps` fused value-iteration sweeps (one device round-trip).
    pub fn vi_sweeps(
        &self,
        engine: &mut Engine,
        p: &[f32],
        g: &[f32],
        v: &[f32],
        gamma: f32,
    ) -> Result<Vec<f32>> {
        let inputs = self.literals(p, g, v, gamma)?;
        let out = engine.run(&self.vi_file, &inputs)?;
        anyhow::ensure!(out.len() == 1, "vi artifact must return (v,)");
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Backup + residual in one execution: (TV, policy, ‖TV − V‖∞).
    pub fn residual(
        &self,
        engine: &mut Engine,
        p: &[f32],
        g: &[f32],
        v: &[f32],
        gamma: f32,
    ) -> Result<(Vec<f32>, Vec<i32>, f32)> {
        let inputs = self.literals(p, g, v, gamma)?;
        let out = engine.run(&self.residual_file, &inputs)?;
        anyhow::ensure!(out.len() == 3, "residual artifact must return 3 values");
        let res = out[2].to_vec::<f32>()?;
        Ok((out[0].to_vec::<f32>()?, out[1].to_vec::<i32>()?, res[0]))
    }

    /// Solve the dense block to tolerance by chaining fused VI sweeps;
    /// returns (V, policy, sweep_count).
    pub fn solve_vi(
        &self,
        engine: &mut Engine,
        p: &[f32],
        g: &[f32],
        gamma: f32,
        atol: f32,
        max_sweeps: usize,
    ) -> Result<(Vec<f32>, Vec<i32>, usize)> {
        let mut v = vec![0.0f32; self.n_states];
        let mut done = 0;
        while done < max_sweeps {
            v = self.vi_sweeps(engine, p, g, &v, gamma)?;
            done += self.sweeps;
            let (_, pi, res) = self.residual(engine, p, g, &v, gamma)?;
            if res < atol {
                return Ok((v, pi, done));
            }
        }
        let (_, pi, _) = self.residual(engine, p, g, &v, gamma)?;
        Ok((v, pi, done))
    }
}

/// Reference implementation of the dense Bellman backup in Rust (f32),
/// used to validate artifacts and as the native comparator in bench E6.
pub fn bellman_dense_native(
    n: usize,
    m: usize,
    p: &[f32],
    g: &[f32],
    v: &[f32],
    gamma: f32,
) -> (Vec<f32>, Vec<i32>) {
    assert_eq!(p.len(), m * n * n);
    assert_eq!(g.len(), m * n);
    assert_eq!(v.len(), n);
    let mut tv = vec![f32::INFINITY; n];
    let mut pi = vec![0i32; n];
    for a in 0..m {
        for s in 0..n {
            let row = &p[a * n * n + s * n..a * n * n + (s + 1) * n];
            let mut exp = 0.0f32;
            for (pj, vj) in row.iter().zip(v) {
                exp += pj * vj;
            }
            let q = g[a * n + s] + gamma * exp;
            if q < tv[s] {
                tv[s] = q;
                pi[s] = a as i32;
            }
        }
    }
    (tv, pi)
}

/// Random dense row-stochastic block (f32), deterministic in seed. Shared
/// by the runtime tests, the dense-accelerator example and bench E6.
pub fn random_block(seed: u64, n: usize, m: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    use crate::util::prng::Xoshiro256pp;
    let mut rng = Xoshiro256pp::new(seed);
    let mut p = vec![0.0f32; m * n * n];
    for a in 0..m {
        for s in 0..n {
            let row = &mut p[a * n * n + s * n..a * n * n + (s + 1) * n];
            let mut sum = 0.0f32;
            for x in row.iter_mut() {
                *x = (rng.next_f64() as f32) + 1e-3;
                sum += *x;
            }
            for x in row.iter_mut() {
                *x /= sum;
            }
        }
    }
    let g: Vec<f32> = (0..m * n).map(|_| rng.next_f64() as f32).collect();
    let v: Vec<f32> = (0..n).map(|_| (rng.next_f64() as f32) - 0.5).collect();
    (p, g, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        // Tests are skipped when artifacts have not been built (CI stages
        // that run cargo test before make artifacts).
        Engine::load("artifacts").ok()
    }

    #[test]
    fn native_bellman_minimizes() {
        let (p, g, v) = random_block(1, 16, 3);
        let (tv, pi) = bellman_dense_native(16, 3, &p, &g, &v, 0.9);
        for s in 0..16 {
            for a in 0..3 {
                let row = &p[a * 256 + s * 16..a * 256 + (s + 1) * 16];
                let exp: f32 = row.iter().zip(&v).map(|(x, y)| x * y).sum();
                let q = g[a * 16 + s] + 0.9 * exp;
                assert!(q >= tv[s] - 1e-5);
            }
            let a = pi[s] as usize;
            let row = &p[a * 256 + s * 16..a * 256 + (s + 1) * 16];
            let exp: f32 = row.iter().zip(&v).map(|(x, y)| x * y).sum();
            assert!((g[a * 16 + s] + 0.9 * exp - tv[s]).abs() < 1e-5);
        }
    }

    #[test]
    fn random_block_rows_stochastic() {
        let (p, _, _) = random_block(3, 8, 2);
        for a in 0..2 {
            for s in 0..8 {
                let sum: f32 = p[a * 64 + s * 8..a * 64 + (s + 1) * 8].iter().sum();
                assert!((sum - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn pjrt_bellman_matches_native() {
        let Some(mut eng) = engine() else { return };
        let db = DenseBellman::new(&eng, 64, 4).unwrap();
        let (p, g, v) = random_block(7, 64, 4);
        let (tv, pi) = db.bellman(&mut eng, &p, &g, &v, 0.95).unwrap();
        let (tv_n, pi_n) = bellman_dense_native(64, 4, &p, &g, &v, 0.95);
        for (a, b) in tv.iter().zip(&tv_n) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        assert_eq!(pi, pi_n);
    }

    #[test]
    fn pjrt_vi_sweeps_match_native_iteration() {
        let Some(mut eng) = engine() else { return };
        let db = DenseBellman::new(&eng, 64, 4).unwrap();
        let (p, g, v) = random_block(9, 64, 4);
        let gamma = 0.9f32;
        let v1 = db.vi_sweeps(&mut eng, &p, &g, &v, gamma).unwrap();
        let mut vn = v.clone();
        for _ in 0..db.sweeps {
            let (tv, _) = bellman_dense_native(64, 4, &p, &g, &vn, gamma);
            vn = tv;
        }
        for (a, b) in v1.iter().zip(&vn) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn pjrt_residual_consistent() {
        let Some(mut eng) = engine() else { return };
        let db = DenseBellman::new(&eng, 64, 4).unwrap();
        let (p, g, v) = random_block(11, 64, 4);
        let (tv, _, res) = db.residual(&mut eng, &p, &g, &v, 0.9).unwrap();
        let manual = tv
            .iter()
            .zip(&v)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!((res - manual).abs() < 1e-5);
    }

    #[test]
    fn pjrt_solve_vi_reaches_tolerance() {
        let Some(mut eng) = engine() else { return };
        let db = DenseBellman::new(&eng, 64, 4).unwrap();
        let (p, g, _) = random_block(13, 64, 4);
        let (v, pi, sweeps) = db.solve_vi(&mut eng, &p, &g, 0.8, 1e-4, 1_000).unwrap();
        assert!(sweeps <= 1_000);
        let (tv, pi2) = bellman_dense_native(64, 4, &p, &g, &v, 0.8);
        let res = tv
            .iter()
            .zip(&v)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(res < 2e-4, "residual {res}");
        assert_eq!(pi, pi2);
    }

    #[test]
    fn missing_shape_rejected() {
        let Some(eng) = engine() else { return };
        assert!(DenseBellman::new(&eng, 999, 7).is_err());
    }

    #[test]
    fn engine_lists_artifacts() {
        let Some(eng) = engine() else { return };
        let avail = eng.available();
        assert!(avail.iter().any(|f| f.starts_with("bellman_64_4")));
        assert!(!eng.platform().is_empty());
    }
}
