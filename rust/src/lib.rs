//! # madupite-rs
//!
//! A distributed high-performance solver for large-scale Markov Decision
//! Processes — a from-scratch reproduction of **madupite** (Gargiani,
//! Pawlowsky, Sieber, Hapla, Lygeros; JOSS 2024 / CS.DC 2025) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! - **Layer 3 (this crate)**: the distributed solver — simulated-MPI SPMD
//!   world ([`comm`]), row-partitioned sparse linear algebra ([`linalg`]),
//!   Krylov inner solvers ([`ksp`]), the inexact-policy-iteration outer
//!   solver family ([`solver`]), benchmark model generators ([`models`]),
//!   factored models with ADD-structured value iteration ([`factored`]),
//!   baselines ([`baseline`]), the PJRT dense-block accelerator
//!   ([`runtime`]) and the policy-serving layer ([`serve`]) that persists
//!   and queries solved policies.
//! - **Layer 2**: JAX compute graphs (`python/compile/model.py`) AOT-lowered
//!   to HLO text artifacts loaded by [`runtime`].
//! - **Layer 1**: Pallas Bellman kernels (`python/compile/kernels/`)
//!   embedded in the L2 graphs.
//!
//! See `DESIGN.md` for the architecture and the experiment index, and
//! `EXPERIMENTS.md` for measured results.
//!
//! The user-facing front door is [`api`]: an [`api::MdpBuilder`] for model
//! construction (file / benchmark model / closures) and an [`api::Solver`]
//! carrying the madupite/PETSc-style options database that the CLI shares.

#![warn(missing_docs)]

pub mod api;
pub mod baseline;
pub mod comm;
pub mod factored;
pub mod ksp;
pub mod linalg;
pub mod mdp;
pub mod models;
pub mod runtime;
pub mod serve;
pub mod solver;
pub mod util;

/// Narrative documentation, compiled and executed in CI.
pub mod docs {
    //! The long-form docs live as markdown under `docs/` and are included
    //! here so every Rust code block is a doctest: `cargo test --doc`
    //! runs the guide's examples, and the `docs_guide` integration test
    //! pins its options table against [`crate::api::options::OPTION_TABLE`]
    //! — the documentation cannot rot.

    #[doc = include_str!("../../docs/guide.md")]
    pub mod guide {}
}

/// Crate version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
