//! MPI-style collectives over the rendezvous board.
//!
//! All collectives are implemented against the shared board in
//! [`super::Comm::rendezvous`]: every rank deposits its contribution, a
//! barrier publishes the board, every rank reads what it needs, a second
//! barrier releases the epoch. This matches MPI semantics (all ranks must
//! call the same collective in the same order) and lets [`CommStats`]
//! account bytes exactly as an MPI implementation would transfer them.

use super::codec;
use super::stats::Op;
use super::Comm;

/// Reduction operators for [`Comm::allreduce_f64`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reduce {
    /// Elementwise sum.
    Sum,
    /// Elementwise minimum.
    Min,
    /// Elementwise maximum.
    Max,
}

impl Comm {
    /// Broadcast `root`'s payload to all ranks.
    pub fn broadcast(&self, root: usize, mine: Vec<u8>) -> Vec<u8> {
        let contribution = if self.rank() == root { Some(mine) } else { None };
        let out = self.rendezvous(contribution, |board| {
            board[root].clone().expect("broadcast root deposited")
        });
        if self.rank() != root {
            self.stats().count(self.rank(), Op::Broadcast, out.len());
        }
        out
    }

    /// Broadcast a single f64.
    pub fn broadcast_f64(&self, root: usize, x: f64) -> f64 {
        codec::decode_f64(&self.broadcast(root, codec::encode_f64(x)))
    }

    /// Broadcast a usize list.
    pub fn broadcast_usizes(&self, root: usize, xs: &[usize]) -> Vec<usize> {
        codec::decode_usizes(&self.broadcast(root, codec::encode_usizes(xs)))
    }

    /// All-reduce a scalar with the given operator.
    pub fn allreduce_f64(&self, x: f64, op: Reduce) -> f64 {
        let out = self.rendezvous(Some(codec::encode_f64(x)), |board| {
            let vals = board
                .iter()
                .map(|b| codec::decode_f64(b.as_ref().expect("allreduce deposit")));
            match op {
                Reduce::Sum => vals.sum(),
                Reduce::Min => vals.fold(f64::INFINITY, f64::min),
                Reduce::Max => vals.fold(f64::NEG_INFINITY, f64::max),
            }
        });
        self.stats().count(self.rank(), Op::Allreduce, 8);
        out
    }

    /// Elementwise all-reduce of an f64 vector.
    pub fn allreduce_f64s(&self, xs: &[f64], op: Reduce) -> Vec<f64> {
        let n = xs.len();
        let out = self.rendezvous(Some(codec::encode_f64s(xs)), |board| {
            let mut acc = vec![
                match op {
                    Reduce::Sum => 0.0,
                    Reduce::Min => f64::INFINITY,
                    Reduce::Max => f64::NEG_INFINITY,
                };
                n
            ];
            for b in board {
                let v = codec::decode_f64s(b.as_ref().expect("allreduce deposit"));
                assert_eq!(v.len(), n, "allreduce length mismatch");
                for (a, x) in acc.iter_mut().zip(v) {
                    *a = match op {
                        Reduce::Sum => *a + x,
                        Reduce::Min => a.min(x),
                        Reduce::Max => a.max(x),
                    };
                }
            }
            acc
        });
        self.stats().count(self.rank(), Op::Allreduce, 8 * n);
        out
    }

    /// Dot product of distributed vectors: local partial in, global sum out.
    /// (Convenience wrapper — the inner KSP solvers call this a lot.)
    pub fn sum(&self, partial: f64) -> f64 {
        self.allreduce_f64(partial, Reduce::Sum)
    }

    /// Global max (used for ∞-norms / Bellman residuals).
    pub fn max(&self, partial: f64) -> f64 {
        self.allreduce_f64(partial, Reduce::Max)
    }

    /// All-gather variable-length byte payloads; returns all ranks' payloads
    /// in rank order.
    pub fn allgatherv(&self, mine: Vec<u8>) -> Vec<Vec<u8>> {
        let out = self.rendezvous(Some(mine), |board| {
            board
                .iter()
                .map(|b| b.as_ref().expect("allgather deposit").clone())
                .collect::<Vec<_>>()
        });
        let recv: usize = out
            .iter()
            .enumerate()
            .filter(|(r, _)| *r != self.rank())
            .map(|(_, b)| b.len())
            .sum();
        self.stats().count(self.rank(), Op::Allgather, recv);
        out
    }

    /// All-gather f64 segments and concatenate in rank order (the
    /// VecScatter-to-all used to assemble a full copy of a distributed
    /// vector when a rank needs remote entries).
    pub fn allgather_f64s(&self, mine: &[f64]) -> Vec<f64> {
        let parts = self.allgatherv(codec::encode_f64s(mine));
        let mut out = Vec::with_capacity(parts.iter().map(|p| p.len() / 8).sum());
        for p in parts {
            out.extend(codec::decode_f64s(&p));
        }
        out
    }

    /// Root scatters one payload per rank; each rank receives its own.
    pub fn scatterv(&self, root: usize, parts: Option<Vec<Vec<u8>>>) -> Vec<u8> {
        let contribution = if self.rank() == root {
            let parts = parts.expect("scatterv root must supply parts");
            assert_eq!(parts.len(), self.size(), "scatterv arity");
            // Flatten with a length header: [n][len0][len1]... then bytes.
            let mut buf = Vec::new();
            buf.extend_from_slice(&(parts.len() as u64).to_le_bytes());
            for p in &parts {
                buf.extend_from_slice(&(p.len() as u64).to_le_bytes());
            }
            for p in &parts {
                buf.extend_from_slice(p);
            }
            Some(buf)
        } else {
            None
        };
        let rank = self.rank();
        let out = self.rendezvous(contribution, |board| {
            let buf = board[root].as_ref().expect("scatterv root deposited");
            let n = u64::from_le_bytes(buf[0..8].try_into().unwrap()) as usize;
            let mut lens = Vec::with_capacity(n);
            for i in 0..n {
                let off = 8 + i * 8;
                lens.push(u64::from_le_bytes(buf[off..off + 8].try_into().unwrap()) as usize);
            }
            let mut off = 8 + n * 8;
            for l in lens.iter().take(rank) {
                off += l;
            }
            buf[off..off + lens[rank]].to_vec()
        });
        if self.rank() != root {
            self.stats().count(self.rank(), Op::Scatter, out.len());
        }
        out
    }

    /// All-to-all variable payloads: `send[j]` goes to rank j; returns
    /// `recv[i]` = payload from rank i. Used by the ghost-exchange plan.
    pub fn alltoallv(&self, send: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
        assert_eq!(send.len(), self.size(), "alltoallv arity");
        let rank = self.rank();
        // Flatten: header of size lens, then concatenated payloads.
        let mut buf = Vec::new();
        for p in &send {
            buf.extend_from_slice(&(p.len() as u64).to_le_bytes());
        }
        for p in &send {
            buf.extend_from_slice(p);
        }
        let size = self.size();
        let out = self.rendezvous(Some(buf), |board| {
            let mut recv = Vec::with_capacity(size);
            for src in 0..size {
                let b = board[src].as_ref().expect("alltoallv deposit");
                let mut lens = Vec::with_capacity(size);
                for i in 0..size {
                    lens.push(
                        u64::from_le_bytes(b[i * 8..(i + 1) * 8].try_into().unwrap()) as usize,
                    );
                }
                let mut off = size * 8;
                for l in lens.iter().take(rank) {
                    off += l;
                }
                recv.push(b[off..off + lens[rank]].to_vec());
            }
            recv
        });
        let recv_bytes: usize = out
            .iter()
            .enumerate()
            .filter(|(r, _)| *r != rank)
            .map(|(_, b)| b.len())
            .sum();
        self.stats().count(rank, Op::Alltoall, recv_bytes);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;

    #[test]
    fn broadcast_from_each_root() {
        for root in 0..3 {
            let out = World::run(3, move |comm: Comm| {
                let mine = if comm.rank() == root {
                    vec![9u8, 8, 7]
                } else {
                    vec![]
                };
                comm.broadcast(root, mine)
            });
            assert!(out.iter().all(|v| v == &vec![9u8, 8, 7]), "root={root}");
        }
    }

    #[test]
    fn allreduce_sum_min_max() {
        let out = World::run(4, |comm: Comm| {
            let x = (comm.rank() + 1) as f64;
            (
                comm.allreduce_f64(x, Reduce::Sum),
                comm.allreduce_f64(x, Reduce::Min),
                comm.allreduce_f64(x, Reduce::Max),
            )
        });
        for (s, mn, mx) in out {
            assert_eq!(s, 10.0);
            assert_eq!(mn, 1.0);
            assert_eq!(mx, 4.0);
        }
    }

    #[test]
    fn allreduce_vector_elementwise() {
        let out = World::run(2, |comm: Comm| {
            let xs = vec![comm.rank() as f64, 10.0 * (comm.rank() + 1) as f64];
            comm.allreduce_f64s(&xs, Reduce::Sum)
        });
        for v in out {
            assert_eq!(v, vec![1.0, 30.0]);
        }
    }

    #[test]
    fn allgather_concatenates_in_rank_order() {
        let out = World::run(3, |comm: Comm| {
            let mine = vec![comm.rank() as f64; comm.rank() + 1];
            comm.allgather_f64s(&mine)
        });
        for v in out {
            assert_eq!(v, vec![0.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
        }
    }

    #[test]
    fn scatterv_delivers_per_rank_parts() {
        let out = World::run(3, |comm: Comm| {
            let parts = if comm.rank() == 0 {
                Some(vec![vec![0u8], vec![1u8, 1], vec![2u8, 2, 2]])
            } else {
                None
            };
            comm.scatterv(0, parts)
        });
        assert_eq!(out[0], vec![0u8]);
        assert_eq!(out[1], vec![1u8, 1]);
        assert_eq!(out[2], vec![2u8, 2, 2]);
    }

    #[test]
    fn alltoallv_transposes() {
        let out = World::run(3, |comm: Comm| {
            // send[j] = [rank, j]
            let send: Vec<Vec<u8>> = (0..3).map(|j| vec![comm.rank() as u8, j as u8]).collect();
            comm.alltoallv(send)
        });
        for (me, recv) in out.iter().enumerate() {
            for (src, payload) in recv.iter().enumerate() {
                assert_eq!(payload, &vec![src as u8, me as u8]);
            }
        }
    }

    #[test]
    fn collectives_sequence_consistent() {
        // Mixing collectives back-to-back must not cross epochs.
        let out = World::run(4, |comm: Comm| {
            let a = comm.sum(1.0);
            let b = comm.max(comm.rank() as f64);
            let c = comm.allgather_f64s(&[comm.rank() as f64]);
            (a, b, c)
        });
        for (a, b, c) in out {
            assert_eq!(a, 4.0);
            assert_eq!(b, 3.0);
            assert_eq!(c, vec![0.0, 1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn allgatherv_zero_length_payloads() {
        // Ranks may legitimately contribute nothing (empty partitions).
        let out = World::run(3, |comm: Comm| {
            let mine = if comm.rank() == 1 { vec![5u8] } else { vec![] };
            comm.allgatherv(mine)
        });
        for recv in out {
            assert_eq!(recv, vec![vec![], vec![5u8], vec![]]);
        }
    }

    #[test]
    fn alltoallv_single_rank_world() {
        // Degenerate exchange: one rank sends only to itself.
        let out = World::run(1, |comm: Comm| {
            let recv = comm.alltoallv(vec![vec![1u8, 2, 3]]);
            let empty = comm.alltoallv(vec![vec![]]);
            (recv, empty)
        });
        assert_eq!(out[0].0, vec![vec![1u8, 2, 3]]);
        assert_eq!(out[0].1, vec![Vec::<u8>::new()]);
    }

    #[test]
    fn scatterv_empty_parts() {
        // Root may have nothing for some (or all) ranks.
        let out = World::run(3, |comm: Comm| {
            let parts = if comm.rank() == 0 {
                Some(vec![vec![], vec![7u8], vec![]])
            } else {
                None
            };
            comm.scatterv(0, parts)
        });
        assert_eq!(out[0], Vec::<u8>::new());
        assert_eq!(out[1], vec![7u8]);
        assert_eq!(out[2], Vec::<u8>::new());
    }

    #[test]
    fn allreduce_vector_matches_scalar_bitwise_across_world_sizes() {
        // The batched reductions the KSP loops rely on: fusing k scalar
        // Sum-allreduces into one length-k vector allreduce must be
        // *bitwise* identical per component, for every world size, because
        // both fold the board in ascending rank order from the identity.
        // Values are chosen so fold order matters in f64.
        for size in 1..=4 {
            let out = World::run(size, move |comm: Comm| {
                let r = comm.rank() as f64;
                let xs = [0.1 * (r + 1.0), 1e16 + r, (-1.0f64).powi(comm.rank() as i32) * 0.3];
                let fused = comm.allreduce_f64s(&xs, Reduce::Sum);
                let scalar: Vec<f64> = xs.iter().map(|&x| comm.allreduce_f64(x, Reduce::Sum)).collect();
                (fused, scalar)
            });
            for (fused, scalar) in &out {
                for (a, b) in fused.iter().zip(scalar) {
                    assert_eq!(a.to_bits(), b.to_bits(), "size={size}");
                }
            }
            // All ranks must agree bit-for-bit on the fused result too.
            for (fused, _) in &out[1..] {
                for (a, b) in fused.iter().zip(&out[0].0) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
        }
    }

    #[test]
    fn bytes_accounted_for_allreduce() {
        let out = World::run(2, |comm: Comm| {
            let _ = comm.sum(1.0);
            comm.barrier();
            comm.stats().snapshot().total_bytes()
        });
        // 2 ranks × 8 bytes each
        assert_eq!(out[0], 16);
    }
}
