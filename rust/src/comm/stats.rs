//! Communication statistics (message and byte counters).
//!
//! The scaling experiments (E2 in DESIGN.md §6) report communication volume
//! per rank and per collective class, since wall-clock scaling is not
//! observable on a single-CPU container. Counters are atomics shared by the
//! whole world; `snapshot()` freezes them for reporting.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// Collective classes tracked separately.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Point-to-point send/recv.
    P2p,
    /// One-to-all broadcast.
    Broadcast,
    /// All-reduce (reduce + broadcast).
    Allreduce,
    /// All-gather (variable-length).
    Allgather,
    /// Root-to-ranks scatter.
    Scatter,
    /// Personalized all-to-all exchange.
    Alltoall,
}

const NOPS: usize = 6;

impl Op {
    fn idx(self) -> usize {
        match self {
            Op::P2p => 0,
            Op::Broadcast => 1,
            Op::Allreduce => 2,
            Op::Allgather => 3,
            Op::Scatter => 4,
            Op::Alltoall => 5,
        }
    }

    /// Display name of the operation.
    pub fn name(self) -> &'static str {
        match self {
            Op::P2p => "p2p",
            Op::Broadcast => "broadcast",
            Op::Allreduce => "allreduce",
            Op::Allgather => "allgather",
            Op::Scatter => "scatter",
            Op::Alltoall => "alltoall",
        }
    }

    /// Every tracked operation, in display order.
    pub fn all() -> [Op; NOPS] {
        [
            Op::P2p,
            Op::Broadcast,
            Op::Allreduce,
            Op::Allgather,
            Op::Scatter,
            Op::Alltoall,
        ]
    }
}

/// Shared counters: per rank × per op, messages and bytes, plus per-rank
/// time spent blocked inside communication calls.
pub struct CommStats {
    size: usize,
    /// msgs[rank * NOPS + op]
    msgs: Vec<AtomicU64>,
    bytes: Vec<AtomicU64>,
    /// time_us[rank] — microseconds spent inside collectives, blocking
    /// receives and barriers (includes synchronization wait, which is the
    /// cost communication overlap hides).
    time_us: Vec<AtomicU64>,
}

impl CommStats {
    /// Fresh zeroed counters for a world of `size` ranks.
    pub fn new(size: usize) -> Self {
        let n = size * NOPS;
        CommStats {
            size,
            msgs: (0..n).map(|_| AtomicU64::new(0)).collect(),
            bytes: (0..n).map(|_| AtomicU64::new(0)).collect(),
            time_us: (0..size).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Record `nbytes` for one `op` executed by `rank`.
    pub fn count(&self, rank: usize, op: Op, nbytes: usize) {
        let i = rank * NOPS + op.idx();
        self.msgs[i].fetch_add(1, Ordering::Relaxed);
        self.bytes[i].fetch_add(nbytes as u64, Ordering::Relaxed);
    }

    /// Record a point-to-point send of `nbytes` from `rank`.
    pub fn count_p2p(&self, rank: usize, nbytes: usize) {
        self.count(rank, Op::P2p, nbytes);
    }

    /// Accumulate `us` microseconds of communication time on `rank`.
    pub fn add_time(&self, rank: usize, us: u64) {
        self.time_us[rank].fetch_add(us, Ordering::Relaxed);
    }

    /// Total communication time across all ranks, microseconds. Wall-clock
    /// overlapped across ranks (each rank accrues independently), so this
    /// is a work measure like `total_bytes`, not elapsed time.
    pub fn total_time_us(&self) -> u64 {
        self.time_us.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    /// Total bytes across all ranks and ops.
    pub fn total_bytes(&self) -> u64 {
        self.bytes.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    /// Total messages across all ranks and ops.
    pub fn total_msgs(&self) -> u64 {
        self.msgs.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    /// Freeze current values.
    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            size: self.size,
            msgs: self.msgs.iter().map(|a| a.load(Ordering::Relaxed)).collect(),
            bytes: self
                .bytes
                .iter()
                .map(|a| a.load(Ordering::Relaxed))
                .collect(),
            time_us: self
                .time_us
                .iter()
                .map(|a| a.load(Ordering::Relaxed))
                .collect(),
        }
    }

    /// Reset all counters (between bench phases).
    pub fn reset(&self) {
        for a in &self.msgs {
            a.store(0, Ordering::Relaxed);
        }
        for a in &self.bytes {
            a.store(0, Ordering::Relaxed);
        }
        for a in &self.time_us {
            a.store(0, Ordering::Relaxed);
        }
    }
}

/// Immutable snapshot for reporting.
#[derive(Debug, Clone, Default)]
pub struct StatsSnapshot {
    /// World size the counters were sized for.
    pub size: usize,
    msgs: Vec<u64>,
    bytes: Vec<u64>,
    time_us: Vec<u64>,
}

impl StatsSnapshot {
    /// Message count of `op` on `rank`.
    pub fn msgs(&self, rank: usize, op: Op) -> u64 {
        self.msgs[rank * NOPS + op.idx()]
    }

    /// Byte count of `op` on `rank`.
    pub fn bytes(&self, rank: usize, op: Op) -> u64 {
        self.bytes[rank * NOPS + op.idx()]
    }

    /// Total bytes sent by `rank` across all operations.
    pub fn rank_bytes(&self, rank: usize) -> u64 {
        Op::all().iter().map(|&op| self.bytes(rank, op)).sum()
    }

    /// Total bytes across all ranks and operations.
    pub fn total_bytes(&self) -> u64 {
        self.bytes.iter().sum()
    }

    /// Total messages across all ranks and operations.
    pub fn total_msgs(&self) -> u64 {
        self.msgs.iter().sum()
    }

    /// World-total message count of one operation class (all ranks).
    pub fn op_msgs(&self, op: Op) -> u64 {
        (0..self.size).map(|r| self.msgs(r, op)).sum()
    }

    /// World-total byte count of one operation class (all ranks).
    pub fn op_bytes(&self, op: Op) -> u64 {
        (0..self.size).map(|r| self.bytes(r, op)).sum()
    }

    /// Communication time accrued by `rank`, microseconds.
    pub fn rank_time_us(&self, rank: usize) -> u64 {
        self.time_us[rank]
    }

    /// Total communication time across all ranks, microseconds.
    pub fn total_time_us(&self) -> u64 {
        self.time_us.iter().sum()
    }

    /// Largest/smallest per-rank byte volume ratio (load-balance measure;
    /// 1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let per: Vec<u64> = (0..self.size).map(|r| self.rank_bytes(r)).collect();
        let max = per.iter().copied().max().unwrap_or(0);
        let min = per.iter().copied().min().unwrap_or(0);
        if min == 0 {
            if max == 0 {
                1.0
            } else {
                f64::INFINITY
            }
        } else {
            max as f64 / min as f64
        }
    }

    /// Snapshot as JSON (per-op totals + per-rank bytes).
    pub fn to_json(&self) -> Json {
        let mut ranks = Vec::new();
        for r in 0..self.size {
            let mut ops = Vec::new();
            for op in Op::all() {
                if self.msgs(r, op) > 0 {
                    ops.push((
                        op.name(),
                        Json::obj(vec![
                            ("msgs", Json::int(self.msgs(r, op) as i64)),
                            ("bytes", Json::int(self.bytes(r, op) as i64)),
                        ]),
                    ));
                }
            }
            ranks.push(Json::obj(ops));
        }
        Json::obj(vec![
            ("total_bytes", Json::int(self.total_bytes() as i64)),
            ("total_msgs", Json::int(self.total_msgs() as i64)),
            ("comm_time_us", Json::int(self.total_time_us() as i64)),
            ("per_rank", Json::Arr(ranks)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_accumulate() {
        let s = CommStats::new(2);
        s.count(0, Op::Allreduce, 8);
        s.count(0, Op::Allreduce, 8);
        s.count(1, Op::P2p, 100);
        let snap = s.snapshot();
        assert_eq!(snap.msgs(0, Op::Allreduce), 2);
        assert_eq!(snap.bytes(0, Op::Allreduce), 16);
        assert_eq!(snap.bytes(1, Op::P2p), 100);
        assert_eq!(snap.total_bytes(), 116);
        assert_eq!(snap.total_msgs(), 3);
    }

    #[test]
    fn reset_zeroes() {
        let s = CommStats::new(1);
        s.count(0, Op::Broadcast, 42);
        s.add_time(0, 17);
        s.reset();
        assert_eq!(s.total_bytes(), 0);
        assert_eq!(s.total_msgs(), 0);
        assert_eq!(s.total_time_us(), 0);
    }

    #[test]
    fn time_accumulates_per_rank() {
        let s = CommStats::new(2);
        s.add_time(0, 5);
        s.add_time(0, 7);
        s.add_time(1, 100);
        assert_eq!(s.total_time_us(), 112);
        let snap = s.snapshot();
        assert_eq!(snap.rank_time_us(0), 12);
        assert_eq!(snap.rank_time_us(1), 100);
        assert_eq!(snap.total_time_us(), 112);
    }

    #[test]
    fn op_totals_sum_over_ranks() {
        let s = CommStats::new(3);
        s.count(0, Op::Allreduce, 8);
        s.count(1, Op::Allreduce, 8);
        s.count(2, Op::P2p, 32);
        let snap = s.snapshot();
        assert_eq!(snap.op_msgs(Op::Allreduce), 2);
        assert_eq!(snap.op_bytes(Op::Allreduce), 16);
        assert_eq!(snap.op_msgs(Op::P2p), 1);
        assert_eq!(snap.op_bytes(Op::Alltoall), 0);
    }

    #[test]
    fn imbalance_measure() {
        let s = CommStats::new(2);
        s.count(0, Op::P2p, 100);
        s.count(1, Op::P2p, 50);
        assert_eq!(s.snapshot().imbalance(), 2.0);
    }

    #[test]
    fn imbalance_empty_world_is_one() {
        let s = CommStats::new(3);
        assert_eq!(s.snapshot().imbalance(), 1.0);
    }

    #[test]
    fn json_shape() {
        let s = CommStats::new(1);
        s.count(0, Op::Allgather, 10);
        let j = s.snapshot().to_json();
        assert_eq!(j.get("total_bytes").unwrap().as_f64(), Some(10.0));
        let per = j.get("per_rank").unwrap().as_arr().unwrap();
        assert_eq!(
            per[0]
                .get("allgather")
                .unwrap()
                .get("bytes")
                .unwrap()
                .as_f64(),
            Some(10.0)
        );
    }
}
