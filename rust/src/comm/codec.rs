//! Little-endian byte codecs for message payloads.
//!
//! The comm layer moves `Vec<u8>`; these helpers encode/decode the slice
//! types the solver exchanges (f64 value-vector segments, usize index lists,
//! mixed headers). Manual codec keeps the wire format explicit and
//! dependency-free (no bincode offline).

/// Encode an f64 slice (little-endian, densely packed).
pub fn encode_f64s(xs: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 8);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Decode an f64 slice. Panics on ragged input (internal protocol error).
pub fn decode_f64s(bytes: &[u8]) -> Vec<f64> {
    assert!(bytes.len() % 8 == 0, "ragged f64 payload: {}", bytes.len());
    bytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

/// Decode f64s into an existing buffer (hot-path variant, no allocation).
pub fn decode_f64s_into(bytes: &[u8], out: &mut [f64]) {
    assert_eq!(bytes.len(), out.len() * 8, "payload/buffer size mismatch");
    for (c, o) in bytes.chunks_exact(8).zip(out.iter_mut()) {
        *o = f64::from_le_bytes(c.try_into().unwrap());
    }
}

/// Encode a usize slice as u64 little-endian.
pub fn encode_usizes(xs: &[usize]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 8);
    for &x in xs {
        out.extend_from_slice(&(x as u64).to_le_bytes());
    }
    out
}

/// Decode a usize slice.
pub fn decode_usizes(bytes: &[u8]) -> Vec<usize> {
    assert!(bytes.len() % 8 == 0, "ragged usize payload");
    bytes
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
        .collect()
}

/// Encode one f64 (for scalar reductions).
pub fn encode_f64(x: f64) -> Vec<u8> {
    x.to_le_bytes().to_vec()
}

/// Decode one f64.
pub fn decode_f64(bytes: &[u8]) -> f64 {
    f64::from_le_bytes(bytes[..8].try_into().unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_roundtrip() {
        let xs = vec![0.0, -1.5, f64::MAX, f64::MIN_POSITIVE, 3.141592653589793];
        assert_eq!(decode_f64s(&encode_f64s(&xs)), xs);
    }

    #[test]
    fn f64_roundtrip_preserves_nan_bits() {
        let xs = vec![f64::NAN];
        let back = decode_f64s(&encode_f64s(&xs));
        assert!(back[0].is_nan());
    }

    #[test]
    fn usize_roundtrip() {
        let xs = vec![0usize, 1, 42, usize::MAX >> 1];
        assert_eq!(decode_usizes(&encode_usizes(&xs)), xs);
    }

    #[test]
    fn decode_into_matches() {
        let xs = vec![1.0, 2.0, 3.0];
        let bytes = encode_f64s(&xs);
        let mut out = vec![0.0; 3];
        decode_f64s_into(&bytes, &mut out);
        assert_eq!(out, xs);
    }

    #[test]
    fn scalar_roundtrip() {
        assert_eq!(decode_f64(&encode_f64(2.5)), 2.5);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_payload_panics() {
        decode_f64s(&[0u8; 7]);
    }

    #[test]
    fn empty_slices() {
        assert_eq!(decode_f64s(&encode_f64s(&[])), Vec::<f64>::new());
        assert_eq!(decode_usizes(&encode_usizes(&[])), Vec::<usize>::new());
    }
}
