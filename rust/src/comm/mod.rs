//! Simulated MPI: SPMD world, point-to-point messaging, collectives.
//!
//! madupite distributes memory and compute with MPI through PETSc. This
//! container has a single CPU and no MPI, so the distributed runtime is
//! reproduced as an SPMD **thread world**: `World::run(n_ranks, f)` spawns
//! one OS thread per rank and hands each a [`Comm`] handle with the MPI
//! surface the solver needs — `send`/`recv`, `barrier`, `broadcast`,
//! `allreduce`, `allgather(v)`, `scatterv`, `alltoallv`. The programming
//! model, communication pattern and per-rank message/byte counts are
//! identical to the MPI build; only physical parallel speedup is absent
//! (documented in DESIGN.md §3).
//!
//! Message payloads are `Vec<u8>`; typed helpers encode `f64`/`usize`
//! slices little-endian (see [`codec`]). Every transfer is counted in
//! [`CommStats`] so the scaling experiments (E2) can report communication
//! volume exactly.

pub mod codec;
pub mod collectives;
pub mod overlap;
pub mod stats;

pub use collectives::Reduce;
pub use overlap::OverlapMode;
pub use stats::CommStats;

use std::cell::RefCell;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Barrier, Mutex};
use std::time::Instant;

/// A tagged point-to-point message.
struct Msg {
    from: usize,
    tag: u64,
    bytes: Vec<u8>,
}

/// Shared state of a world of `size` ranks.
struct WorldShared {
    size: usize,
    /// mailbox\[r\] = receiver owned by rank r (wrapped for Sync handoff).
    senders: Vec<Sender<Msg>>,
    barrier: Barrier,
    /// Rendezvous slots for collectives: one `Vec<Option<Vec<u8>>>` board
    /// per collective epoch, guarded by a mutex + the barrier.
    board: Mutex<Vec<Option<Vec<u8>>>>,
    stats: CommStats,
}

/// This rank's receive side: the channel endpoint plus messages parked by
/// `recv` while waiting for a different (source, tag).
struct Mailbox {
    inbox: Receiver<Msg>,
    parked: Vec<Msg>,
}

/// Per-rank communicator handle (the `MPI_Comm` equivalent).
///
/// `recv` takes `&self` (interior mutability over the rank-private
/// [`Mailbox`]) so the split-phase ghost exchange can complete receives
/// through the same shared `&Comm` the compute path holds. The `RefCell`
/// makes `Comm` `!Sync`, which is exactly the contract: each rank-thread
/// owns its communicator exclusively; worker threads of the intra-rank
/// pool never touch it.
pub struct Comm {
    rank: usize,
    shared: Arc<WorldShared>,
    mailbox: RefCell<Mailbox>,
}

impl Comm {
    /// This rank’s index in `[0, size)`.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// World size (number of ranks).
    pub fn size(&self) -> usize {
        self.shared.size
    }

    /// Whether this is rank 0.
    pub fn is_root(&self) -> bool {
        self.rank == 0
    }

    /// Global statistics (shared across ranks).
    pub fn stats(&self) -> &CommStats {
        &self.shared.stats
    }

    /// Non-blocking-ish send (buffered channel; never deadlocks on send).
    pub fn send(&self, to: usize, tag: u64, bytes: Vec<u8>) {
        assert!(to < self.size(), "send to rank {to} of {}", self.size());
        self.shared.stats.count_p2p(self.rank, bytes.len());
        self.shared.senders[to]
            .send(Msg {
                from: self.rank,
                tag,
                bytes,
            })
            .expect("world torn down during send");
    }

    /// Blocking receive of a message with matching `from` and `tag`.
    pub fn recv(&self, from: usize, tag: u64) -> Vec<u8> {
        let mut mb = self.mailbox.borrow_mut();
        // Check parked messages first. `remove` (not `swap_remove`)
        // preserves arrival order so per-(source, tag) delivery stays FIFO
        // like MPI; parked lists are short, O(n) removal is irrelevant.
        if let Some(i) = mb
            .parked
            .iter()
            .position(|m| m.from == from && m.tag == tag)
        {
            return mb.parked.remove(i).bytes;
        }
        let t0 = Instant::now();
        let bytes = loop {
            let msg = mb
                .inbox
                .recv()
                .expect("world torn down during recv");
            if msg.from == from && msg.tag == tag {
                break msg.bytes;
            }
            mb.parked.push(msg);
        };
        self.shared
            .stats
            .add_time(self.rank, t0.elapsed().as_micros() as u64);
        bytes
    }

    /// Synchronize all ranks.
    pub fn barrier(&self) {
        let t0 = Instant::now();
        self.shared.barrier.wait();
        self.shared
            .stats
            .add_time(self.rank, t0.elapsed().as_micros() as u64);
    }

    /// Internal: run one board-based rendezvous. Each rank deposits
    /// `contribution` (every rank deposits every epoch, `None` when it has
    /// nothing — overwriting its slot from the previous epoch); a barrier
    /// publishes the board; every rank reads through `read`; a trailing
    /// barrier prevents a fast rank from starting the next epoch (and
    /// overwriting its slot) before slow ranks finished reading.
    fn rendezvous<R>(
        &self,
        contribution: Option<Vec<u8>>,
        read: impl FnOnce(&[Option<Vec<u8>>]) -> R,
    ) -> R {
        // The whole epoch (deposit, publish barrier, read, release barrier)
        // is attributed to this rank's communication time: barrier waits
        // are exactly the synchronization cost the overlap mode hides.
        let t0 = Instant::now();
        {
            let mut board = self.shared.board.lock().unwrap();
            board[self.rank] = contribution;
        }
        self.shared.barrier.wait();
        let out = {
            let board = self.shared.board.lock().unwrap();
            read(&board)
        };
        self.shared.barrier.wait();
        self.shared
            .stats
            .add_time(self.rank, t0.elapsed().as_micros() as u64);
        out
    }
}

/// SPMD world entry point: run `f(comm)` on `size` rank-threads, return the
/// per-rank results in rank order. Panics in any rank propagate.
pub struct World;

impl World {
    /// Spawn `size` rank-threads, run `f(comm)` on each, and return the
    /// per-rank results in rank order (blocking until all ranks finish).
    pub fn run<T, F>(size: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(Comm) -> T + Send + Sync + 'static,
    {
        assert!(size >= 1, "world size must be >= 1");
        let mut senders = Vec::with_capacity(size);
        let mut receivers = Vec::with_capacity(size);
        for _ in 0..size {
            let (tx, rx) = std::sync::mpsc::channel();
            senders.push(tx);
            receivers.push(rx);
        }
        let shared = Arc::new(WorldShared {
            size,
            senders,
            barrier: Barrier::new(size),
            board: Mutex::new(vec![None; size]),
            stats: CommStats::new(size),
        });
        let f = Arc::new(f);
        let mut handles = Vec::with_capacity(size);
        for (rank, inbox) in receivers.into_iter().enumerate() {
            let shared = Arc::clone(&shared);
            let f = Arc::clone(&f);
            let builder = std::thread::Builder::new()
                .name(format!("rank{rank}"))
                // GMRES restarts on big problems keep modest stacks, but the
                // maze generator recursion wants headroom.
                .stack_size(8 * 1024 * 1024);
            handles.push(
                builder
                    .spawn(move || {
                        let comm = Comm {
                            rank,
                            shared,
                            mailbox: RefCell::new(Mailbox {
                                inbox,
                                parked: Vec::new(),
                            }),
                        };
                        f(comm)
                    })
                    .expect("failed to spawn rank thread"),
            );
        }
        let mut out = Vec::with_capacity(size);
        for (rank, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(v) => out.push(v),
                Err(e) => std::panic::panic_any(format!(
                    "rank {rank} panicked: {:?}",
                    e.downcast_ref::<String>()
                        .cloned()
                        .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                )),
            }
        }
        out
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_world() {
        let out = World::run(1, |comm: Comm| {
            assert_eq!(comm.rank(), 0);
            assert_eq!(comm.size(), 1);
            42usize
        });
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn ranks_get_distinct_ids() {
        let out = World::run(4, |comm: Comm| comm.rank());
        assert_eq!(out, vec![0, 1, 2, 3]);
    }

    #[test]
    fn p2p_ring() {
        // Each rank sends its rank id to the next rank; receives from prev.
        let out = World::run(4, |comm: Comm| {
            let next = (comm.rank() + 1) % comm.size();
            let prev = (comm.rank() + comm.size() - 1) % comm.size();
            comm.send(next, 7, vec![comm.rank() as u8]);
            let got = comm.recv(prev, 7);
            got[0] as usize
        });
        assert_eq!(out, vec![3, 0, 1, 2]);
    }

    #[test]
    fn recv_filters_by_tag() {
        let out = World::run(2, |comm: Comm| {
            if comm.rank() == 0 {
                // Send tag 2 first, then tag 1; receiver asks for 1 first.
                comm.send(1, 2, vec![20]);
                comm.send(1, 1, vec![10]);
                0
            } else {
                let a = comm.recv(0, 1)[0];
                let b = comm.recv(0, 2)[0];
                assert_eq!((a, b), (10, 20));
                1
            }
        });
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn barrier_synchronizes() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static PHASE: AtomicUsize = AtomicUsize::new(0);
        PHASE.store(0, Ordering::SeqCst);
        World::run(4, |comm: Comm| {
            PHASE.fetch_add(1, Ordering::SeqCst);
            comm.barrier();
            // After the barrier every rank must observe all 4 increments.
            assert_eq!(PHASE.load(Ordering::SeqCst), 4);
        });
    }

    #[test]
    fn p2p_bytes_counted() {
        let out = World::run(2, |comm: Comm| {
            if comm.rank() == 0 {
                comm.send(1, 0, vec![0u8; 100]);
            } else {
                let _ = comm.recv(0, 0);
            }
            comm.barrier();
            comm.stats().total_bytes()
        });
        assert_eq!(out[0], 100);
        assert_eq!(out[1], 100);
    }

    #[test]
    #[should_panic(expected = "rank")]
    fn rank_panic_propagates() {
        World::run(2, |comm: Comm| {
            if comm.rank() == 1 {
                panic!("deliberate");
            }
            // rank 0 must not deadlock waiting on a barrier here
        });
    }
}
