//! Communication/compute overlap capability layer (`-comm_overlap`).
//!
//! The distributed kernels ([`crate::linalg::dist::DistCsr::spmv`], the
//! policy operators, the Bellman backup) can run their ghost exchange in
//! two phases: `start` posts the point-to-point sends, interior rows (rows
//! that touch no ghost column) are computed while the exchange is in
//! flight, `finish` drains the receives, and boundary rows run last. Both
//! schedules compute every output row with the identical per-row kernel
//! over the identical [`crate::util::par`] chunk grid, so results are
//! **bitwise identical** — the mode is a pure scheduling knob (pinned by
//! `tests/par_determinism.rs`).
//!
//! The mode is process-global, like the kernel backend in
//! [`crate::util::simd`] and the thread count in [`crate::util::par`]:
//! resolution order is an explicit [`set_mode`] (the options database /
//! `-comm_overlap` flag, applied by `api::run_solve` before the world
//! starts) > the `MADUPITE_COMM_OVERLAP` environment variable > `auto`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Overlap capability mode (`-comm_overlap on|off|auto`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OverlapMode {
    /// Always use the split-phase (overlapped) ghost exchange.
    On,
    /// Always use the bulk-synchronous exchange.
    Off,
    /// Overlap whenever the world has more than one rank (the default;
    /// a single-rank world has no exchange to hide).
    #[default]
    Auto,
}

impl OverlapMode {
    /// Parse the `-comm_overlap` option string.
    pub fn parse(name: &str) -> Result<OverlapMode, String> {
        Ok(match name {
            "on" | "true" | "1" => OverlapMode::On,
            "off" | "false" | "0" => OverlapMode::Off,
            "auto" => OverlapMode::Auto,
            other => return Err(format!("unknown comm_overlap '{other}'")),
        })
    }

    /// Canonical option-string form (inverse of [`Self::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            OverlapMode::On => "on",
            OverlapMode::Off => "off",
            OverlapMode::Auto => "auto",
        }
    }

    /// Whether this mode enables the split-phase exchange for a world of
    /// `size` ranks.
    pub fn enabled_for(self, size: usize) -> bool {
        match self {
            OverlapMode::On => true,
            OverlapMode::Off => false,
            OverlapMode::Auto => size > 1,
        }
    }

    fn to_code(self) -> u8 {
        match self {
            OverlapMode::On => 1,
            OverlapMode::Off => 2,
            OverlapMode::Auto => 3,
        }
    }

    fn from_code(code: u8) -> Option<OverlapMode> {
        match code {
            1 => Some(OverlapMode::On),
            2 => Some(OverlapMode::Off),
            3 => Some(OverlapMode::Auto),
            _ => None,
        }
    }
}

/// 0 = not configured (fall back to env / auto), else `OverlapMode::to_code`.
static CONFIGURED: AtomicU8 = AtomicU8::new(0);
static ENV_DEFAULT: OnceLock<OverlapMode> = OnceLock::new();

fn env_default() -> OverlapMode {
    *ENV_DEFAULT.get_or_init(|| {
        match std::env::var("MADUPITE_COMM_OVERLAP") {
            // A malformed env value falls back to auto rather than erroring:
            // the env var is a deploy-time default, the checked path for
            // typed errors is the `-comm_overlap` option.
            Ok(v) => OverlapMode::parse(v.trim()).unwrap_or(OverlapMode::Auto),
            Err(_) => OverlapMode::Auto,
        }
    })
}

/// Select the process-global overlap mode (the options database calls this
/// with the resolved `-comm_overlap` value before the world starts).
pub fn set_mode(mode: OverlapMode) {
    CONFIGURED.store(mode.to_code(), Ordering::SeqCst);
}

/// Currently effective mode: [`set_mode`] > `MADUPITE_COMM_OVERLAP` > auto.
pub fn current() -> OverlapMode {
    OverlapMode::from_code(CONFIGURED.load(Ordering::SeqCst)).unwrap_or_else(env_default)
}

/// Whether the split-phase exchange is active for a world of `size` ranks
/// under the currently effective mode. The distributed kernels consult
/// this at apply time, so a mode change takes effect on the next apply.
pub fn enabled(size: usize) -> bool {
    current().enabled_for(size)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips() {
        for mode in [OverlapMode::On, OverlapMode::Off, OverlapMode::Auto] {
            assert_eq!(OverlapMode::parse(mode.name()).unwrap(), mode);
        }
        assert_eq!(OverlapMode::parse("true").unwrap(), OverlapMode::On);
        assert_eq!(OverlapMode::parse("0").unwrap(), OverlapMode::Off);
        assert!(OverlapMode::parse("maybe").is_err());
        assert_eq!(OverlapMode::default(), OverlapMode::Auto);
    }

    #[test]
    fn enabled_for_world_sizes() {
        assert!(OverlapMode::On.enabled_for(1));
        assert!(OverlapMode::On.enabled_for(4));
        assert!(!OverlapMode::Off.enabled_for(4));
        assert!(!OverlapMode::Auto.enabled_for(1));
        assert!(OverlapMode::Auto.enabled_for(2));
    }

    #[test]
    fn code_round_trips() {
        // The atomic encoding must be lossless; 0 is reserved for "unset".
        for mode in [OverlapMode::On, OverlapMode::Off, OverlapMode::Auto] {
            assert_eq!(OverlapMode::from_code(mode.to_code()), Some(mode));
            assert_ne!(mode.to_code(), 0);
        }
        assert_eq!(OverlapMode::from_code(0), None);
    }
}
