//! Block-CSR policy-evaluation operator (the `Bsr` eval backend).
//!
//! Same operator as [`super::matfree::MatFreePolicyOp`] — the policy
//! system `A = I − diag(γ_π) P_π` applied off the stacked transition
//! kernel — but the selected rows are repacked into the 1×LANES
//! column-blocked layout of [`crate::linalg::Bsr`] so each apply streams
//! contiguous lane loads instead of per-entry gathers. Construction is
//! rank-local and communication-free like the matrix-free backend, but it
//! is O(local nnz of P_π): the repack happens once per policy change and
//! pays for itself over the inner Krylov iterations that reuse it.
//!
//! Whether blocking wins depends on column clustering:
//! [`crate::linalg::Bsr::fill_ratio`] measures how many stored lane slots
//! are real entries. When the ratio is below [`BSR_FILL_THRESHOLD`] the
//! padding zeros would cost more bandwidth than the gathers they replace,
//! so the operator keeps the packed matrix only when blocking is
//! profitable and otherwise falls back to the gather kernel — same
//! results either way (DESIGN.md §13 has the heuristic's derivation).
//!
//! Determinism: both the blocked and the fallback row kernels use a fixed
//! lane-fold order and rows are computed independently, so results are
//! bitwise identical for every thread count — the same invariant the
//! other backends keep ([`crate::util::par`]).

use super::{DistMdp, MatFreePolicyOp};
use crate::comm::Comm;
use crate::ksp::Apply;
use crate::linalg::dist::{GhostBuf, GhostSubPlan, Partition};
use crate::linalg::{Bsr, Csr};
use std::sync::OnceLock;

/// Minimum [`Bsr::fill_ratio`] at which the blocked layout is kept.
///
/// Below this, more than ~2 of every 3 stored lanes would be padding
/// zeros: the blocked row pass reads `blocks·LANES` values where the
/// gather reads `nnz` values plus `nnz` indices, so blocking stops paying
/// once `LANES/fill > 2` entries move per real nonzero. 0.35 sits just
/// above that break-even with a small margin for the removed index
/// traffic.
pub const BSR_FILL_THRESHOLD: f64 = 0.35;

/// `A = I − diag(γ_π) P_π` over a block-packed copy of the selected
/// policy rows (`-eval_backend bsr`).
///
/// Holds the packed rows only when the fill heuristic accepts them
/// ([`Self::uses_blocks`]); the fallback path is the same fused gather as
/// the matrix-free backend. Non-apply hooks (diagonal, local block,
/// materialization) delegate to [`MatFreePolicyOp`] — they are setup-time
/// paths where the layout does not matter.
pub struct BsrPolicyOp<'a> {
    mdp: &'a DistMdp,
    policy: &'a [usize],
    /// Selected policy rows in blocked layout (buffer-space columns, one
    /// row per local state), or `None` when the fill heuristic rejected
    /// the packing.
    blocks: Option<Bsr>,
    /// Policy-selected ghost sub-plan, built lazily on the first (collective)
    /// apply — like [`MatFreePolicyOp`], construction stays communication-free
    /// because the non-apply hooks run in non-collective contexts.
    plan: OnceLock<GhostSubPlan>,
}

impl<'a> BsrPolicyOp<'a> {
    /// Pack the selected rows of `mdp` under `policy`, keeping the packed
    /// form only if its fill ratio clears [`BSR_FILL_THRESHOLD`].
    pub fn new(mdp: &'a DistMdp, policy: &'a [usize]) -> Self {
        assert_eq!(
            policy.len(),
            mdp.local_states(),
            "policy must cover the rank-local states"
        );
        debug_assert!(policy.iter().all(|&a| a < mdp.n_actions()));
        let local = mdp.transitions().local();
        let m = mdp.n_actions();
        let mut packed = Bsr::new(local.ncols());
        for (s, &a) in policy.iter().enumerate() {
            let (cols, vals) = local.row(s * m + a);
            packed.push_row(cols, vals);
        }
        let blocks = (packed.fill_ratio() >= BSR_FILL_THRESHOLD).then_some(packed);
        BsrPolicyOp {
            mdp,
            policy,
            blocks,
            plan: OnceLock::new(),
        }
    }

    /// Whether the blocked layout passed the fill heuristic (false means
    /// applies run the gather fallback).
    pub fn uses_blocks(&self) -> bool {
        self.blocks.is_some()
    }

    /// The matrix-free twin used for the setup-time hooks.
    fn matfree(&self) -> MatFreePolicyOp<'a> {
        MatFreePolicyOp::new(self.mdp, self.policy)
    }

    /// The stacked-CSR row index backing local state `s` under π.
    #[inline]
    fn row_of(&self, s: usize) -> usize {
        s * self.mdp.n_actions() + self.policy[s]
    }

    /// The lazily built policy-selected ghost sub-plan (collective on
    /// first use — callers must be on the collective apply path).
    fn plan(&self, comm: &Comm) -> &GhostSubPlan {
        self.plan.get_or_init(|| {
            let nl = self.mdp.local_states();
            self.mdp
                .transitions()
                .build_sub_plan(comm, (0..nl).map(|s| self.row_of(s)))
        })
    }

    /// Fused row pass (blocked or gather fallback). `pass = Some(b)` writes
    /// only rows whose boundary flag equals `b` — the two-pass overlapped
    /// schedule; `None` evaluates every row. Bitwise identical either way.
    fn apply_rows(&self, x: &[f64], y: &mut [f64], buf: &GhostBuf, pass: Option<bool>) {
        let trans = self.mdp.transitions();
        let local = trans.local();
        let flags = trans.boundary_flags();
        let xb = buf.x();
        let m = self.mdp.n_actions();
        let disc = self.mdp.discount();
        // Row-parallel; each row's fold order is fixed per kernel →
        // bitwise identical for any thread count.
        crate::util::par::par_for_rows(y, |offset, chunk| {
            for (i, ys) in chunk.iter_mut().enumerate() {
                let s = offset + i;
                let row = self.row_of(s);
                if let Some(want) = pass {
                    if flags[row] != want {
                        continue;
                    }
                }
                let px = match &self.blocks {
                    Some(b) => b.row_dot(s, xb),
                    None => {
                        let (cols, vals) = local.row(row);
                        // SAFETY: DistCsr remaps every stored column into
                        // buffer space [0, nlocal + nghost) == xb.len().
                        unsafe { crate::util::simd::gather_dot_unchecked(cols, vals, xb) }
                    }
                };
                *ys = x[s] - disc.at_row(row, m) * px;
            }
        });
    }
}

impl Apply for BsrPolicyOp<'_> {
    fn local_rows(&self) -> usize {
        self.mdp.local_states()
    }

    fn partition(&self) -> Partition {
        self.mdp.partition()
    }

    fn make_buffer(&self) -> GhostBuf {
        self.mdp.make_buffer()
    }

    fn apply(&self, comm: &Comm, x: &[f64], y: &mut [f64], buf: &mut GhostBuf) {
        let nl = self.local_rows();
        assert_eq!(x.len(), nl);
        assert_eq!(y.len(), nl);
        let trans = self.mdp.transitions();
        let plan = self.plan(comm);
        if comm.size() > 1 && crate::comm::overlap::enabled(comm.size()) {
            trans.start_ghost_exchange_subset(comm, plan, x, buf);
            self.apply_rows(x, y, buf, Some(false));
            trans.finish_ghost_exchange_subset(comm, plan, buf);
            self.apply_rows(x, y, buf, Some(true));
        } else {
            trans.update_ghosts_subset(comm, plan, x, buf);
            self.apply_rows(x, y, buf, None);
        }
    }

    fn diag(&self, out: &mut [f64]) {
        self.matfree().diag(out)
    }

    fn local_block(&self) -> Csr {
        self.matfree().local_block()
    }

    fn materialize_rows(&self) -> Vec<Vec<(usize, f64)>> {
        self.matfree().materialize_rows()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;
    use crate::mdp::fixtures::random_mdp;
    use crate::util::prng::Xoshiro256pp;
    use crate::util::prop;
    use std::sync::Arc;

    fn random_local_policy(lo: usize, hi: usize, m: usize, seed: u64) -> Vec<usize> {
        (lo..hi)
            .map(|s| {
                let mut rng = Xoshiro256pp::new(seed ^ (s as u64).wrapping_mul(0x5851));
                rng.index(m)
            })
            .collect()
    }

    /// The blocked operator and the matrix-free operator are the same
    /// linear map: identical apply/diag/residual for random policies,
    /// whichever side of the fill heuristic the model lands on.
    #[test]
    fn matches_matfree_any_world_size() {
        for (seed, size) in [(41u64, 1usize), (42, 2), (43, 3)] {
            let mdp = Arc::new(random_mdp(seed, 31, 4, 0.92));
            let out = World::run(size, move |comm| {
                let d = DistMdp::from_serial(&comm, &mdp);
                let part = d.partition();
                let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
                let nl = hi - lo;
                let policy = random_local_policy(lo, hi, 4, seed);
                let x: Vec<f64> = (lo..hi).map(|i| (i as f64 * 0.6).sin()).collect();
                let b: Vec<f64> = (lo..hi).map(|i| (i as f64 * 0.4).cos()).collect();

                let mf = MatFreePolicyOp::new(&d, &policy);
                let bs = BsrPolicyOp::new(&d, &policy);
                assert_eq!(bs.local_rows(), nl);
                let mut buf_m = mf.make_buffer();
                let mut buf_b = bs.make_buffer();
                let mut y_m = vec![0.0; nl];
                let mut y_b = vec![0.0; nl];
                mf.apply(&comm, &x, &mut y_m, &mut buf_m);
                bs.apply(&comm, &x, &mut y_b, &mut buf_b);
                let mut d_m = vec![0.0; nl];
                let mut d_b = vec![0.0; nl];
                mf.diag(&mut d_m);
                bs.diag(&mut d_b);
                let mut r = vec![0.0; nl];
                let res_m = mf.residual(&comm, &b, &x, &mut r, &mut buf_m);
                let res_b = bs.residual(&comm, &b, &x, &mut r, &mut buf_b);

                prop::close_slices(&y_m, &y_b, 1e-13).unwrap();
                prop::close_slices(&d_m, &d_b, 1e-13).unwrap();
                assert!((res_m - res_b).abs() < 1e-12, "{res_m} vs {res_b}");
            });
            assert_eq!(out.len(), size);
        }
    }

    /// Property sweep over random shapes — includes single-action models
    /// (dense column clusters → blocked path) and wide random ones
    /// (scattered columns → gather fallback).
    #[test]
    fn prop_apply_equals_matfree() {
        prop::forall("bsr apply == matfree apply", |rng| {
            let n = 3 + rng.index(24);
            let m = 1 + rng.index(4);
            let gamma = rng.range_f64(0.0, 0.99);
            let seed = rng.next_u64();
            let pol_seed = rng.next_u64();
            let mdp = Arc::new(random_mdp(seed, n, m, gamma));
            let out = World::run(1, move |comm| {
                let d = DistMdp::from_serial(&comm, &mdp);
                let policy = random_local_policy(0, n, m, pol_seed);
                let x: Vec<f64> = (0..n).map(|i| ((i * 5 + 2) as f64).sin()).collect();
                let mf = MatFreePolicyOp::new(&d, &policy);
                let bs = BsrPolicyOp::new(&d, &policy);
                let mut y_m = vec![0.0; n];
                let mut y_b = vec![0.0; n];
                let mut buf_m = mf.make_buffer();
                let mut buf_b = bs.make_buffer();
                mf.apply(&comm, &x, &mut y_m, &mut buf_m);
                bs.apply(&comm, &x, &mut y_b, &mut buf_b);
                (y_m, y_b)
            });
            let (y_m, y_b) = &out[0];
            prop::close_slices(y_m, y_b, 1e-12)
        });
    }

    /// Clustered columns (a chain model: each row hits adjacent states)
    /// must pass the fill heuristic and take the blocked path.
    #[test]
    fn chain_model_packs_blocks() {
        let n = 40;
        let mdp = Arc::new(
            crate::mdp::Mdp::from_fillers(
                n,
                1,
                0.9,
                |s, _| {
                    let hi = (s + 3).min(n - 1);
                    let k = hi - s + 1;
                    (s..=hi).map(|t| (t, 1.0 / k as f64)).collect()
                },
                |_, _| 1.0,
            ),
        );
        World::run(1, move |comm| {
            let d = DistMdp::from_serial(&comm, &mdp);
            let policy = vec![0usize; n];
            let bs = BsrPolicyOp::new(&d, &policy);
            assert!(bs.uses_blocks(), "adjacent-column rows must pack well");
        });
    }
}
