//! Single-precision policy-evaluation operator (`-inner_precision f32`).
//!
//! The inner Krylov iterations of iPI are memory-bound: every apply
//! streams the selected policy rows once. Storing that copy in `f32`
//! (values) + `u32` (column ids) halves the bytes per nonzero, which on
//! bandwidth-bound hardware is a direct throughput win — the classic
//! mixed-precision iterative-refinement trade (DESIGN.md §13).
//!
//! Precision contract: the **operator storage** is f32, but every product
//! is widened to f64 before accumulation
//! ([`crate::util::simd::gather_dot_f32_unchecked`]), the subtraction
//! `x − γ·px` is f64, and all Krylov vectors stay f64. A single apply
//! therefore carries only the `f32` *representation* error of the matrix
//! entries (relative ~1e-7·‖row‖); by itself that floors the achievable
//! residual near 1e-7, which is why [`crate::ksp::mixed`] wraps the inner
//! solve in an f64 refinement loop — the outer convergence certificate is
//! computed with the full-precision operator and reaches the same f64
//! tolerance.
//!
//! Setup-time hooks (diagonal, local block, materialization) delegate to
//! the f64 [`MatFreePolicyOp`]: preconditioners are built from exact
//! values, only the hot apply runs on the compressed copy.

use super::{DistMdp, MatFreePolicyOp};
use crate::comm::Comm;
use crate::ksp::Apply;
use crate::linalg::dist::{GhostBuf, GhostSubPlan, Partition};
use crate::linalg::Csr;
use std::sync::OnceLock;

/// `A = I − diag(γ_π) P_π` applied from an f32/u32 copy of the selected
/// policy rows. See the module docs for the precision contract.
pub struct F32PolicyOp<'a> {
    mdp: &'a DistMdp,
    policy: &'a [usize],
    /// Row offsets into `cols`/`vals` (one row per local state).
    indptr: Vec<usize>,
    /// Buffer-space column ids, narrowed to u32.
    cols: Vec<u32>,
    /// Transition probabilities, narrowed to f32.
    vals: Vec<f32>,
    /// Per-local-row discounts `γ_π(s)`, kept in f64.
    gammas: Vec<f64>,
    /// Policy-selected ghost sub-plan, built lazily on the first
    /// (collective) apply; the exchange moves only the entries π reads.
    plan: OnceLock<GhostSubPlan>,
}

impl<'a> F32PolicyOp<'a> {
    /// Compress the selected rows of `mdp` under `policy` to f32/u32.
    pub fn new(mdp: &'a DistMdp, policy: &'a [usize]) -> Self {
        let nl = mdp.local_states();
        assert_eq!(policy.len(), nl, "policy must cover the rank-local states");
        debug_assert!(policy.iter().all(|&a| a < mdp.n_actions()));
        let local = mdp.transitions().local();
        assert!(
            local.ncols() <= u32::MAX as usize,
            "buffer space too large for u32 column ids"
        );
        let m = mdp.n_actions();
        let mut indptr = Vec::with_capacity(nl + 1);
        let mut cols: Vec<u32> = Vec::new();
        let mut vals: Vec<f32> = Vec::new();
        let mut gammas = Vec::with_capacity(nl);
        indptr.push(0);
        for (s, &a) in policy.iter().enumerate() {
            let row = s * m + a;
            let (rc, rv) = local.row(row);
            cols.extend(rc.iter().map(|&c| c as u32));
            vals.extend(rv.iter().map(|&v| v as f32));
            indptr.push(cols.len());
            gammas.push(mdp.discount().at_row(row, m));
        }
        F32PolicyOp {
            mdp,
            policy,
            indptr,
            cols,
            vals,
            gammas,
            plan: OnceLock::new(),
        }
    }

    /// Bytes of the compressed operator copy (4 per value + 4 per column
    /// id, versus 8 + 8 for the f64 paths) — memory accounting.
    pub fn storage_bytes(&self) -> usize {
        self.indptr.len() * 8 + self.cols.len() * 4 + self.vals.len() * 4 + self.gammas.len() * 8
    }

    /// The f64 matrix-free twin used for the setup-time hooks.
    fn matfree(&self) -> MatFreePolicyOp<'a> {
        MatFreePolicyOp::new(self.mdp, self.policy)
    }

    /// The lazily built policy-selected ghost sub-plan (collective on
    /// first use — callers must be on the collective apply path).
    fn plan(&self, comm: &Comm) -> &GhostSubPlan {
        self.plan.get_or_init(|| {
            let m = self.mdp.n_actions();
            self.mdp.transitions().build_sub_plan(
                comm,
                self.policy.iter().enumerate().map(|(s, &a)| s * m + a),
            )
        })
    }

    /// Compressed row pass over the narrowed vector `xf`. `pass = Some(b)`
    /// writes only rows whose boundary flag equals `b` (the overlapped
    /// schedule); `None` evaluates every row.
    fn apply_rows(&self, x: &[f64], y: &mut [f64], xf: &[f32], pass: Option<bool>) {
        let m = self.mdp.n_actions();
        let flags = self.mdp.transitions().boundary_flags();
        crate::util::par::par_for_rows(y, |offset, chunk| {
            for (i, ys) in chunk.iter_mut().enumerate() {
                let s = offset + i;
                if let Some(want) = pass {
                    if flags[s * m + self.policy[s]] != want {
                        continue;
                    }
                }
                let (a, b) = (self.indptr[s], self.indptr[s + 1]);
                // SAFETY: cols are DistCsr buffer-space columns, all
                // < nlocal + nghost == xf.len(), narrowed loss-free
                // (checked against u32::MAX at construction).
                let px = unsafe {
                    crate::util::simd::gather_dot_f32_unchecked(
                        &self.cols[a..b],
                        &self.vals[a..b],
                        xf,
                    )
                };
                *ys = x[s] - self.gammas[s] * px;
            }
        });
    }
}

impl Apply for F32PolicyOp<'_> {
    fn local_rows(&self) -> usize {
        self.mdp.local_states()
    }

    fn partition(&self) -> Partition {
        self.mdp.partition()
    }

    fn make_buffer(&self) -> GhostBuf {
        self.mdp.make_buffer()
    }

    fn apply(&self, comm: &Comm, x: &[f64], y: &mut [f64], buf: &mut GhostBuf) {
        let nl = self.local_rows();
        assert_eq!(x.len(), nl);
        assert_eq!(y.len(), nl);
        let trans = self.mdp.transitions();
        let plan = self.plan(comm);
        // Narrow the exchanged vector once per apply; the row pass then
        // streams f32 end to end. (A fresh Vec keeps the operator Sync —
        // the allocation is one O(n) pass against m·n row work.)
        if comm.size() > 1 && crate::comm::overlap::enabled(comm.size()) {
            trans.start_ghost_exchange_subset(comm, plan, x, buf);
            let mut xf: Vec<f32> = buf.x().iter().map(|&v| v as f32).collect();
            // Interior rows read only owned slots (< nlocal), which are
            // already fresh; the stale ghost tail is never touched here.
            self.apply_rows(x, y, &xf, Some(false));
            trans.finish_ghost_exchange_subset(comm, plan, buf);
            let nlocal = buf.nlocal();
            for (dst, &v) in xf[nlocal..].iter_mut().zip(&buf.x()[nlocal..]) {
                *dst = v as f32;
            }
            self.apply_rows(x, y, &xf, Some(true));
        } else {
            trans.update_ghosts_subset(comm, plan, x, buf);
            let xf: Vec<f32> = buf.x().iter().map(|&v| v as f32).collect();
            self.apply_rows(x, y, &xf, None);
        }
    }

    fn diag(&self, out: &mut [f64]) {
        self.matfree().diag(out)
    }

    fn local_block(&self) -> Csr {
        self.matfree().local_block()
    }

    fn materialize_rows(&self) -> Vec<Vec<(usize, f64)>> {
        self.matfree().materialize_rows()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;
    use crate::mdp::fixtures::random_mdp;
    use crate::util::prng::Xoshiro256pp;
    use crate::util::prop;
    use std::sync::Arc;

    fn random_local_policy(lo: usize, hi: usize, m: usize, seed: u64) -> Vec<usize> {
        (lo..hi)
            .map(|s| {
                let mut rng = Xoshiro256pp::new(seed ^ (s as u64).wrapping_mul(0x5851));
                rng.index(m)
            })
            .collect()
    }

    /// The f32 apply tracks the f64 matrix-free apply within single
    /// precision of the operand scale, for any world size.
    #[test]
    fn tracks_matfree_within_f32_precision() {
        for (seed, size) in [(51u64, 1usize), (52, 2), (53, 3)] {
            let mdp = Arc::new(random_mdp(seed, 27, 3, 0.94));
            World::run(size, move |comm| {
                let d = DistMdp::from_serial(&comm, &mdp);
                let part = d.partition();
                let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
                let nl = hi - lo;
                let policy = random_local_policy(lo, hi, 3, seed);
                let x: Vec<f64> = (lo..hi).map(|i| (i as f64 * 0.8).sin()).collect();
                let mf = MatFreePolicyOp::new(&d, &policy);
                let lp = F32PolicyOp::new(&d, &policy);
                assert_eq!(lp.local_rows(), nl);
                // Compressed copy: 4+4 bytes per nonzero vs 8+8 for f64.
                let f64_bytes =
                    lp.indptr.len() * 8 + (lp.cols.len() + lp.vals.len()) * 8 + lp.gammas.len() * 8;
                assert!(lp.storage_bytes() < f64_bytes);
                let mut buf_m = mf.make_buffer();
                let mut buf_l = lp.make_buffer();
                let mut y_m = vec![0.0; nl];
                let mut y_l = vec![0.0; nl];
                mf.apply(&comm, &x, &mut y_m, &mut buf_m);
                lp.apply(&comm, &x, &mut y_l, &mut buf_l);
                prop::close_slices(&y_m, &y_l, 1e-5).unwrap();
                // Setup hooks stay full precision: diagonals are bitwise equal.
                let mut d_m = vec![0.0; nl];
                let mut d_l = vec![0.0; nl];
                mf.diag(&mut d_m);
                lp.diag(&mut d_l);
                assert_eq!(d_m, d_l);
            });
        }
    }

    /// Property sweep: random shapes/policies, f32 image within a
    /// single-precision relative envelope of the f64 image.
    #[test]
    fn prop_apply_tracks_f64() {
        prop::forall("f32 apply ~= f64 apply", |rng| {
            let n = 3 + rng.index(20);
            let m = 1 + rng.index(4);
            let gamma = rng.range_f64(0.0, 0.99);
            let seed = rng.next_u64();
            let pol_seed = rng.next_u64();
            let mdp = Arc::new(random_mdp(seed, n, m, gamma));
            let out = World::run(1, move |comm| {
                let d = DistMdp::from_serial(&comm, &mdp);
                let policy = random_local_policy(0, n, m, pol_seed);
                let x: Vec<f64> = (0..n).map(|i| ((i * 3 + 1) as f64).cos()).collect();
                let mf = MatFreePolicyOp::new(&d, &policy);
                let lp = F32PolicyOp::new(&d, &policy);
                let mut y_m = vec![0.0; n];
                let mut y_l = vec![0.0; n];
                let mut buf_m = mf.make_buffer();
                let mut buf_l = lp.make_buffer();
                mf.apply(&comm, &x, &mut y_m, &mut buf_m);
                lp.apply(&comm, &x, &mut y_l, &mut buf_l);
                (y_m, y_l)
            });
            let (y_m, y_l) = &out[0];
            prop::close_slices(y_m, y_l, 1e-5)
        });
    }
}
