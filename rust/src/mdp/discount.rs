//! Generalized discounting — the semi-MDP layer (DESIGN.md §12).
//!
//! madupite's companion paper ("Inside madupite") supports state- and
//! state-action-dependent discount factors, which is exactly what makes the
//! solver applicable to **semi-MDPs**: when the sojourn time in state `s`
//! under action `a` is random (e.g. exponential with rate `r(s,a)` in a
//! maintenance or queueing system), discounting at continuous rate `ρ`
//! yields a per-transition *effective* discount
//! `γ(s,a) = E[e^{−ρτ}] = r(s,a) / (r(s,a) + ρ)` — a number in `[0, 1)`
//! that differs per transition. The Bellman operator becomes
//!
//! ```text
//! (TV)(s) = opt_a [ g(s,a) + γ(s,a) · Σ_{s'} P(s'|s,a) V(s') ]
//! ```
//!
//! and policy evaluation solves `(I − diag(γ_π) P_π) V = g_π`. Everything
//! else — contraction (modulus `max γ(s,a)`), the Krylov machinery, the
//! matrix-free fused operator — carries over unchanged.
//!
//! [`Discount`] is the one representation threaded through every layer:
//! [`crate::mdp::Mdp`]/[`crate::mdp::DistMdp`] storage and backups, the
//! policy-evaluation operators ([`crate::mdp::MatFreePolicyOp`],
//! [`crate::ksp::LinOp`]), the `.mdpb` v3 on-disk format and the options
//! database (`-discount_mode`). The load-bearing invariant, pinned by
//! `tests/discount.rs`: `Discount::Scalar(g)` and a constant
//! per-state(-action) vector filled with `g` produce **bitwise identical**
//! values, policies and residual traces — every kernel reads the effective
//! per-row factor through [`Discount::at_row`] and then runs the exact same
//! arithmetic, so the representation can never change the numbers.

use super::validate_gamma;

/// The representation of the discount factor (`-discount_mode`) — how many
/// entries back an MDP's discounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum DiscountMode {
    /// One global scalar γ (the classic discounted MDP).
    #[default]
    Scalar,
    /// One factor per state: γ(s) (`n` entries).
    PerState,
    /// One factor per state-action pair: γ(s,a) (`n·m` entries, row-aligned
    /// with the stacked `(n·m) × n` transition kernel) — the semi-MDP case.
    PerStateAction,
}

impl DiscountMode {
    /// Parse the `-discount_mode` option string.
    pub fn parse(name: &str) -> Result<DiscountMode, String> {
        match name {
            "scalar" => Ok(DiscountMode::Scalar),
            "per_state" | "per-state" => Ok(DiscountMode::PerState),
            "per_state_action" | "per-state-action" => Ok(DiscountMode::PerStateAction),
            other => Err(format!("unknown discount_mode '{other}'")),
        }
    }

    /// Canonical option-string form (inverse of [`Self::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            DiscountMode::Scalar => "scalar",
            DiscountMode::PerState => "per_state",
            DiscountMode::PerStateAction => "per_state_action",
        }
    }

    /// The `.mdpb` v3 header code (0/1/2).
    pub fn code(&self) -> u64 {
        match self {
            DiscountMode::Scalar => 0,
            DiscountMode::PerState => 1,
            DiscountMode::PerStateAction => 2,
        }
    }

    /// Decode a `.mdpb` v3 header code.
    pub fn from_code(code: u64) -> Result<DiscountMode, String> {
        match code {
            0 => Ok(DiscountMode::Scalar),
            1 => Ok(DiscountMode::PerState),
            2 => Ok(DiscountMode::PerStateAction),
            other => Err(format!("invalid discount_mode code {other}")),
        }
    }

    /// Number of f64 entries the discount payload of this mode stores for
    /// an `n × m` MDP (0 for scalar — the header's `gamma` field carries it).
    pub fn payload_len(&self, n_states: usize, n_actions: usize) -> usize {
        match self {
            DiscountMode::Scalar => 0,
            DiscountMode::PerState => n_states,
            DiscountMode::PerStateAction => n_states * n_actions,
        }
    }
}

/// Discount factors of an MDP: one scalar, one per state, or one per
/// state-action pair (semi-MDPs). See the module docs for the semantics;
/// every entry must be finite and in `[0, 1)` ([`Self::validate`]).
///
/// In a [`crate::mdp::DistMdp`] the vector variants hold the **rank-local
/// slice** (states `[lo, hi)` of the partition), aligned with the local
/// cost table; indexing through [`Self::at`]/[`Self::at_row`] therefore
/// works identically for global (serial) and local (distributed) objects.
#[derive(Clone, Debug, PartialEq)]
pub enum Discount {
    /// One global γ ∈ [0, 1).
    Scalar(f64),
    /// γ(s), one entry per (owned) state.
    PerState(Vec<f64>),
    /// γ(s,a), row-aligned with the stacked transition kernel:
    /// entry `s·m + a`.
    PerStateAction(Vec<f64>),
}

impl Discount {
    /// A constant discount in the requested representation — `gamma`
    /// replicated over however many entries `mode` stores for an
    /// `n_states × n_actions` MDP. By the bitwise-equivalence invariant
    /// this solves identically to `Discount::Scalar(gamma)` in every
    /// method, backend and world shape.
    pub fn constant(mode: DiscountMode, gamma: f64, n_states: usize, n_actions: usize) -> Discount {
        match mode {
            DiscountMode::Scalar => Discount::Scalar(gamma),
            DiscountMode::PerState => Discount::PerState(vec![gamma; n_states]),
            DiscountMode::PerStateAction => {
                Discount::PerStateAction(vec![gamma; n_states * n_actions])
            }
        }
    }

    /// The representation this object uses.
    pub fn mode(&self) -> DiscountMode {
        match self {
            Discount::Scalar(_) => DiscountMode::Scalar,
            Discount::PerState(_) => DiscountMode::PerState,
            Discount::PerStateAction(_) => DiscountMode::PerStateAction,
        }
    }

    /// The scalar γ, when this is the scalar representation.
    pub fn as_scalar(&self) -> Option<f64> {
        match self {
            Discount::Scalar(g) => Some(*g),
            _ => None,
        }
    }

    /// The raw vector entries (None for the scalar representation).
    pub fn entries(&self) -> Option<&[f64]> {
        match self {
            Discount::Scalar(_) => None,
            Discount::PerState(v) | Discount::PerStateAction(v) => Some(v),
        }
    }

    /// Validate every entry through the one crate-wide gamma check
    /// (finite, in `[0, 1)`) and the vector length against the MDP shape.
    /// The first offending entry is named — out-of-range, non-finite and
    /// wrong-length inputs are all typed errors here, never downstream
    /// panics.
    pub fn validate(&self, n_states: usize, n_actions: usize) -> Result<(), String> {
        match self {
            Discount::Scalar(g) => validate_gamma(*g).map(|_| ()),
            Discount::PerState(v) => {
                if v.len() != n_states {
                    return Err(format!(
                        "per-state discount vector has {} entries, expected n_states = {}",
                        v.len(),
                        n_states
                    ));
                }
                for (s, &g) in v.iter().enumerate() {
                    validate_gamma(g).map_err(|e| format!("discount at state {s}: {e}"))?;
                }
                Ok(())
            }
            Discount::PerStateAction(v) => {
                if v.len() != n_states * n_actions {
                    return Err(format!(
                        "per-state-action discount vector has {} entries, \
                         expected n_states * n_actions = {}",
                        v.len(),
                        n_states * n_actions
                    ));
                }
                for (row, &g) in v.iter().enumerate() {
                    validate_gamma(g).map_err(|e| {
                        format!("discount at (s={}, a={}): {e}", row / n_actions, row % n_actions)
                    })?;
                }
                Ok(())
            }
        }
    }

    /// Effective discount of the (state, action) pair. `s` is a global
    /// state index on serial objects and a local one on rank-local slices.
    #[inline]
    pub fn at(&self, s: usize, a: usize, n_actions: usize) -> f64 {
        match self {
            Discount::Scalar(g) => *g,
            Discount::PerState(v) => v[s],
            Discount::PerStateAction(v) => v[s * n_actions + a],
        }
    }

    /// Effective discount of stacked transition row `row = s·m + a`
    /// (local row on rank-local slices).
    #[inline]
    pub fn at_row(&self, row: usize, n_actions: usize) -> f64 {
        match self {
            Discount::Scalar(g) => *g,
            Discount::PerState(v) => v[row / n_actions],
            Discount::PerStateAction(v) => v[row],
        }
    }

    /// Uniform upper bound `γ̄ = max γ(s,a)` — the contraction modulus of
    /// the generalized Bellman operator (used by the suboptimality
    /// certificate `‖V − V*‖∞ ≤ residual / (1 − γ̄)`). Equals the scalar
    /// for classic MDPs.
    pub fn max_gamma(&self) -> f64 {
        match self {
            Discount::Scalar(g) => *g,
            Discount::PerState(v) | Discount::PerStateAction(v) => {
                v.iter().copied().fold(0.0, f64::max)
            }
        }
    }

    /// The sub-slice owned by states `[lo, hi)` — how a validated global
    /// discount is distributed across ranks (scalar stays scalar).
    pub fn slice_states(&self, lo: usize, hi: usize, n_actions: usize) -> Discount {
        match self {
            Discount::Scalar(g) => Discount::Scalar(*g),
            Discount::PerState(v) => Discount::PerState(v[lo..hi].to_vec()),
            Discount::PerStateAction(v) => {
                Discount::PerStateAction(v[lo * n_actions..hi * n_actions].to_vec())
            }
        }
    }

    /// Per-state effective discounts under a fixed policy — the diagonal of
    /// `diag(γ_π)` in the policy-evaluation system
    /// `(I − diag(γ_π) P_π) V = g_π`. Returns `None` for the scalar
    /// representation (the operator then uses the plain `I − γ P_π` path,
    /// keeping scalar solves byte-identical to the pre-semi-MDP code).
    pub fn policy_rows(&self, policy: &[usize], n_actions: usize) -> Option<Vec<f64>> {
        match self {
            Discount::Scalar(_) => None,
            // per-state factors do not depend on the chosen action
            Discount::PerState(v) => Some(v[..policy.len()].to_vec()),
            Discount::PerStateAction(v) => Some(
                policy
                    .iter()
                    .enumerate()
                    .map(|(s, &a)| v[s * n_actions + a])
                    .collect(),
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        for mode in [
            DiscountMode::Scalar,
            DiscountMode::PerState,
            DiscountMode::PerStateAction,
        ] {
            assert_eq!(DiscountMode::parse(mode.name()).unwrap(), mode);
            assert_eq!(DiscountMode::from_code(mode.code()).unwrap(), mode);
        }
        assert!(DiscountMode::parse("per_action").is_err());
        assert!(DiscountMode::from_code(9).is_err());
        assert_eq!(DiscountMode::PerState.payload_len(5, 3), 5);
        assert_eq!(DiscountMode::PerStateAction.payload_len(5, 3), 15);
        assert_eq!(DiscountMode::Scalar.payload_len(5, 3), 0);
    }

    #[test]
    fn validate_catches_bad_entries() {
        assert!(Discount::Scalar(0.9).validate(4, 2).is_ok());
        assert!(Discount::Scalar(1.0).validate(4, 2).is_err());
        // wrong length
        let err = Discount::PerState(vec![0.9; 3]).validate(4, 2).unwrap_err();
        assert!(err.contains("3 entries"), "{err}");
        let err = Discount::PerStateAction(vec![0.9; 7])
            .validate(4, 2)
            .unwrap_err();
        assert!(err.contains("7 entries"), "{err}");
        // out of range / non-finite, with the offending index named
        let err = Discount::PerState(vec![0.9, 1.0, 0.5, 0.2])
            .validate(4, 2)
            .unwrap_err();
        assert!(err.contains("state 1"), "{err}");
        let err = Discount::PerStateAction(vec![0.9, 0.9, 0.9, f64::NAN, 0.9, 0.9, 0.9, 0.9])
            .validate(4, 2)
            .unwrap_err();
        assert!(err.contains("s=1, a=1"), "{err}");
    }

    #[test]
    fn indexing_is_row_aligned() {
        let d = Discount::PerStateAction((0..6).map(|i| i as f64 / 10.0).collect());
        assert_eq!(d.at(1, 1, 2), 0.3);
        assert_eq!(d.at_row(3, 2), 0.3);
        let ps = Discount::PerState(vec![0.1, 0.2, 0.3]);
        assert_eq!(ps.at(2, 1, 2), 0.3);
        assert_eq!(ps.at_row(5, 2), 0.3);
        assert_eq!(Discount::Scalar(0.7).at_row(5, 2), 0.7);
    }

    #[test]
    fn slicing_and_policy_rows() {
        let d = Discount::PerStateAction((0..8).map(|i| i as f64 / 10.0).collect());
        let local = d.slice_states(1, 3, 2);
        assert_eq!(local, Discount::PerStateAction(vec![0.2, 0.3, 0.4, 0.5]));
        let rows = d.policy_rows(&[1, 0, 1, 0], 2).unwrap();
        assert_eq!(rows, vec![0.1, 0.2, 0.5, 0.6]);
        assert!(Discount::Scalar(0.9).policy_rows(&[0, 0], 2).is_none());
        let ps = Discount::PerState(vec![0.1, 0.2, 0.3]);
        assert_eq!(ps.policy_rows(&[1, 1, 0], 2).unwrap(), vec![0.1, 0.2, 0.3]);
        assert_eq!(ps.slice_states(1, 3, 2), Discount::PerState(vec![0.2, 0.3]));
    }

    #[test]
    fn constant_and_max() {
        let c = Discount::constant(DiscountMode::PerStateAction, 0.9, 3, 2);
        assert_eq!(c.entries().unwrap(), &[0.9; 6]);
        assert_eq!(c.max_gamma(), 0.9);
        assert_eq!(Discount::Scalar(0.5).max_gamma(), 0.5);
        assert_eq!(Discount::PerState(vec![0.1, 0.7, 0.3]).max_gamma(), 0.7);
        assert_eq!(c.mode().name(), "per_state_action");
        assert_eq!(Discount::constant(DiscountMode::Scalar, 0.4, 3, 2), Discount::Scalar(0.4));
    }
}
