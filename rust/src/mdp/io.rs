//! Binary MDP file format (offline data path, paper claim C5).
//!
//! madupite loads MDPs from PETSc binary files so that transition data
//! collected offline (e.g. from simulations) can be solved later, possibly
//! on a different number of ranks. This module defines the equivalent
//! self-describing little-endian format:
//!
//! ```text
//! offset  field
//! 0       magic  b"MDPB"
//! 4       version u32 (= 1)
//! 8       n_states u64
//! 16      n_actions u64
//! 24      gamma f64
//! 32      nnz u64
//! 40      indptr  (n·m + 1) × u64
//! ...     indices nnz × u64
//! ...     values  nnz × f64
//! ...     costs   (n·m) × f64
//! ```
//!
//! Because `indptr` precedes the payload, a rank can compute exactly the
//! byte range of its row block and read only that slice —
//! [`load_dist`] does a rank-local partial read, which is how the format
//! supports loading a gigantic MDP that no single rank could hold.

use super::{DistMdp, Mdp};
use crate::comm::Comm;
use crate::linalg::dist::{DistCsr, Partition};
use crate::linalg::Csr;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"MDPB";
const VERSION: u32 = 1;
const HEADER_LEN: u64 = 40;

/// Write a serial MDP to `path`.
pub fn save(mdp: &Mdp, path: impl AsRef<Path>) -> std::io::Result<()> {
    let f = File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(mdp.n_states() as u64).to_le_bytes())?;
    w.write_all(&(mdp.n_actions() as u64).to_le_bytes())?;
    w.write_all(&mdp.gamma().to_le_bytes())?;
    let t = mdp.transitions();
    w.write_all(&(t.nnz() as u64).to_le_bytes())?;
    for &p in t.indptr() {
        w.write_all(&(p as u64).to_le_bytes())?;
    }
    for &i in t.indices() {
        w.write_all(&(i as u64).to_le_bytes())?;
    }
    for &v in t.values() {
        w.write_all(&v.to_le_bytes())?;
    }
    for &c in mdp.costs() {
        w.write_all(&c.to_le_bytes())?;
    }
    w.flush()
}

/// Parsed header.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Header {
    pub n_states: usize,
    pub n_actions: usize,
    pub gamma: f64,
    pub nnz: usize,
}

impl Header {
    fn indptr_off(&self) -> u64 {
        HEADER_LEN
    }
    fn indices_off(&self) -> u64 {
        self.indptr_off() + 8 * (self.n_states as u64 * self.n_actions as u64 + 1)
    }
    fn values_off(&self) -> u64 {
        self.indices_off() + 8 * self.nnz as u64
    }
    fn costs_off(&self) -> u64 {
        self.values_off() + 8 * self.nnz as u64
    }
}

/// Read and validate the header.
pub fn read_header(r: &mut impl Read) -> std::io::Result<Header> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(bad("bad magic (not an MDPB file)"));
    }
    let version = read_u32(r)?;
    if version != VERSION {
        return Err(bad(&format!("unsupported version {version}")));
    }
    let n_states = read_u64(r)? as usize;
    let n_actions = read_u64(r)? as usize;
    let gamma = read_f64(r)?;
    let nnz = read_u64(r)? as usize;
    if n_actions == 0 || n_states == 0 {
        return Err(bad("empty MDP"));
    }
    if !(0.0..1.0).contains(&gamma) {
        return Err(bad(&format!("gamma {gamma} out of range")));
    }
    Ok(Header {
        n_states,
        n_actions,
        gamma,
        nnz,
    })
}

/// Load a full (serial) MDP.
pub fn load(path: impl AsRef<Path>) -> std::io::Result<Mdp> {
    let f = File::open(path)?;
    let mut r = BufReader::new(f);
    let h = read_header(&mut r)?;
    let nm = h.n_states * h.n_actions;
    let indptr = read_u64s(&mut r, nm + 1)?;
    let indices = read_u64s(&mut r, h.nnz)?;
    let values = read_f64s(&mut r, h.nnz)?;
    let costs = read_f64s(&mut r, nm)?;
    let t = Csr::from_parts(nm, h.n_states, indptr, indices, values)
        .map_err(|e| bad(&format!("invalid CSR: {e}")))?;
    Mdp::new(h.n_states, h.n_actions, t, costs, h.gamma).map_err(|e| bad(&e))
}

/// Distributed load: each rank reads only its slice of the file.
/// Collective.
pub fn load_dist(comm: &Comm, path: impl AsRef<Path>) -> std::io::Result<DistMdp> {
    let mut f = File::open(path)?;
    let h = read_header(&mut f)?;
    let part = Partition::new(h.n_states, comm.size());
    let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
    let m = h.n_actions;
    let (row_lo, row_hi) = (lo * m, hi * m);

    // indptr slice for local rows (+1 for the end offset)
    f.seek(SeekFrom::Start(h.indptr_off() + 8 * row_lo as u64))?;
    let indptr = read_u64s(&mut f, row_hi - row_lo + 1)?;
    let (nz_lo, nz_hi) = (indptr[0], indptr[row_hi - row_lo]);

    // indices + values slices
    f.seek(SeekFrom::Start(h.indices_off() + 8 * nz_lo as u64))?;
    let indices = read_u64s(&mut f, nz_hi - nz_lo)?;
    f.seek(SeekFrom::Start(h.values_off() + 8 * nz_lo as u64))?;
    let values = read_f64s(&mut f, nz_hi - nz_lo)?;

    // costs slice
    f.seek(SeekFrom::Start(h.costs_off() + 8 * row_lo as u64))?;
    let costs = read_f64s(&mut f, row_hi - row_lo)?;

    // build per-row global-column lists
    let mut rows = Vec::with_capacity(row_hi - row_lo);
    for r in 0..(row_hi - row_lo) {
        let (a, b) = (indptr[r] - nz_lo, indptr[r + 1] - nz_lo);
        rows.push(
            indices[a..b]
                .iter()
                .copied()
                .zip(values[a..b].iter().copied())
                .collect::<Vec<_>>(),
        );
    }
    let trans = DistCsr::assemble(comm, part, rows);
    Ok(DistMdp {
        part,
        n_actions: h.n_actions,
        gamma: h.gamma,
        objective: crate::mdp::Objective::Min,
        trans,
        costs,
    })
}

fn bad(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

fn read_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f64(r: &mut impl Read) -> std::io::Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

fn read_u64s(r: &mut impl Read, n: usize) -> std::io::Result<Vec<usize>> {
    let mut bytes = vec![0u8; n * 8];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
        .collect())
}

fn read_f64s(r: &mut impl Read, n: usize) -> std::io::Result<Vec<f64>> {
    let mut bytes = vec![0u8; n * 8];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;
    use crate::mdp::fixtures::random_mdp;
    use crate::util::prop;
    use std::sync::Arc;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("madupite-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_serial() {
        let mdp = random_mdp(3, 15, 3, 0.92);
        let path = tmpfile("roundtrip.mdpb");
        save(&mdp, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.n_states(), 15);
        assert_eq!(loaded.n_actions(), 3);
        assert_eq!(loaded.gamma(), 0.92);
        assert_eq!(loaded.transitions(), mdp.transitions());
        prop::close_slices(loaded.costs(), mdp.costs(), 0.0).unwrap();
    }

    #[test]
    fn header_offsets_consistent() {
        let h = Header {
            n_states: 10,
            n_actions: 2,
            gamma: 0.9,
            nnz: 33,
        };
        assert_eq!(h.indptr_off(), 40);
        assert_eq!(h.indices_off(), 40 + 8 * 21);
        assert_eq!(h.values_off(), h.indices_off() + 8 * 33);
        assert_eq!(h.costs_off(), h.values_off() + 8 * 33);
    }

    #[test]
    fn rejects_garbage() {
        let path = tmpfile("garbage.mdpb");
        std::fs::write(&path, b"not an mdp file at all........").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        let path = tmpfile("badver.mdpb");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"MDPB");
        bytes.extend_from_slice(&99u32.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 32]);
        std::fs::write(&path, bytes).unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn dist_load_matches_serial_bellman() {
        let mdp = Arc::new(random_mdp(11, 29, 3, 0.9));
        let path = tmpfile("dist.mdpb");
        save(&mdp, &path).unwrap();
        for size in [1usize, 2, 4] {
            let path2 = path.clone();
            let out = World::run(size, move |comm| {
                let d = load_dist(&comm, &path2).unwrap();
                let part = d.partition();
                let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
                let v: Vec<f64> = (lo..hi).map(|i| (i as f64) * 0.1).collect();
                let mut tv = vec![0.0; hi - lo];
                let mut pol = vec![0usize; hi - lo];
                let mut buf = d.make_buffer();
                let mut q = Vec::new();
                d.bellman_backup(&comm, &v, &mut tv, &mut pol, &mut buf, &mut q);
                tv
            });
            let v_full: Vec<f64> = (0..29).map(|i| (i as f64) * 0.1).collect();
            let (tv_serial, _) = mdp.bellman(&v_full);
            let tv_dist: Vec<f64> = out.into_iter().flatten().collect();
            prop::close_slices(&tv_dist, &tv_serial, 1e-12).unwrap();
        }
    }

    #[test]
    fn dist_load_costs_sliced_correctly() {
        let mdp = Arc::new(random_mdp(13, 10, 2, 0.8));
        let path = tmpfile("costs.mdpb");
        save(&mdp, &path).unwrap();
        let mdp2 = Arc::clone(&mdp);
        World::run(3, move |comm| {
            let d = load_dist(&comm, &path).unwrap();
            let part = d.partition();
            let lo = part.lo(comm.rank());
            for (i, &c) in d.local_costs().iter().enumerate() {
                let s = lo + i / 2;
                let a = i % 2;
                assert_eq!(c, mdp2.cost(s, a));
            }
        });
    }
}
