//! Binary MDP file format (offline data path, paper claim C5).
//!
//! madupite loads MDPs from PETSc binary files so that transition data
//! collected offline (e.g. from simulations) can be solved later, possibly
//! on a different number of ranks. This module defines the equivalent
//! self-describing little-endian format, version 3:
//!
//! ```text
//! offset  field
//! 0       magic  b"MDPB"
//! 4       version u32 (= 3)
//! 8       n_states u64
//! 16      n_actions u64
//! 24      gamma f64 (scalar discount; for vector modes: max γ(s,a))
//! 32      nnz u64
//! 40      objective u64 (0 = min-cost, 1 = max-reward)       [v2+]
//! 48      discount_mode u64 (0 = scalar, 1 = per-state,
//!                            2 = per-state-action)            [v3 only]
//! 56      indptr  (n·m + 1) × u64
//! ...     indices nnz × u64
//! ...     values  nnz × f64
//! ...     costs   (n·m) × f64
//! ...     discounts 0 | n | n·m × f64 (per discount_mode)     [v3 only]
//! ```
//!
//! Version 1 (no `objective` field; payload starts at offset 40) and
//! version 2 (no `discount_mode` field; payload at 48, no discount
//! section) are still accepted byte-compatibly by every reader: v1
//! defaults to [`Objective::Min`], both default to scalar discounting.
//! Writers always emit version 3 — the optional trailing discount payload
//! is what makes state(-action)-dependent discounting (semi-MDPs,
//! [`crate::mdp::Discount`]) storable offline; scalar-discount files carry
//! no payload (length 0) beyond the mode field.
//!
//! Because `indptr` precedes the payload, a rank can compute exactly the
//! byte range of its row block and read only that slice —
//! [`load_dist`] does a rank-local partial read, which is how the format
//! supports loading a gigantic MDP that no single rank could hold. The
//! write side mirrors this: [`MdpWriter`] streams a contiguous block of
//! rows into the file with seek-based chunk writes, so N rank-local
//! writers ([`write_streaming`], [`save_dist`]) produce a byte-identical
//! file to one serial writer without any rank ever materializing the full
//! model (O(chunk) memory — the out-of-core generation path).

use super::{validate_gamma, Discount, DiscountMode, DistMdp, Mdp, Objective};
use crate::comm::{codec, Comm};
use crate::linalg::dist::{DistCsr, Partition};
use crate::linalg::Csr;
use std::fs::{File, OpenOptions};
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"MDPB";
/// Format version emitted by all writers.
pub const VERSION: u32 = 3;
const V1_HEADER_LEN: u64 = 40;
const V2_HEADER_LEN: u64 = 48;
const V3_HEADER_LEN: u64 = 56;

/// Default chunk granularity (rows buffered per flush) for the streaming
/// writer: ~8k rows keep writer memory in the hundreds of KiB while the
/// seek-write batches stay large enough to amortize syscall cost.
pub const DEFAULT_CHUNK_ROWS: usize = 8192;

/// Parsed header.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Header {
    /// Format version (1/2 legacy, 3 current).
    pub version: u32,
    /// Number of states `n`.
    pub n_states: usize,
    /// Number of actions `m`.
    pub n_actions: usize,
    /// Discount factor (for vector discount modes: the uniform bound
    /// `max γ(s,a)`; the per-entry factors live in the trailing payload).
    pub gamma: f64,
    /// Total stored transition entries.
    pub nnz: usize,
    /// Optimization sense (v2+; v1 files default to min).
    pub objective: Objective,
    /// Discount representation (v3; v1/v2 files are scalar).
    pub discount_mode: DiscountMode,
}

impl Header {
    /// v3 header for in-memory metadata (the shape every writer emits).
    pub fn v3(
        n_states: usize,
        n_actions: usize,
        gamma: f64,
        nnz: usize,
        objective: Objective,
        discount_mode: DiscountMode,
    ) -> Header {
        Header {
            version: VERSION,
            n_states,
            n_actions,
            gamma,
            nnz,
            objective,
            discount_mode,
        }
    }

    fn header_len(&self) -> u64 {
        match self.version {
            0 | 1 => V1_HEADER_LEN,
            2 => V2_HEADER_LEN,
            _ => V3_HEADER_LEN,
        }
    }

    fn indptr_off(&self) -> u64 {
        self.header_len()
    }

    fn indices_off(&self) -> u64 {
        self.indptr_off() + 8 * (self.n_states as u64 * self.n_actions as u64 + 1)
    }

    fn values_off(&self) -> u64 {
        self.indices_off() + 8 * self.nnz as u64
    }

    fn costs_off(&self) -> u64 {
        self.values_off() + 8 * self.nnz as u64
    }

    fn discount_off(&self) -> u64 {
        self.costs_off() + 8 * (self.n_states as u64 * self.n_actions as u64)
    }

    /// Number of f64 entries in the trailing discount payload (0 for
    /// scalar-discount files and all v1/v2 files). Computed in u128 like
    /// [`Self::expected_file_len`] so corrupt oversized headers cannot
    /// overflow before the file-length check rejects them.
    fn discount_len(&self) -> u128 {
        if self.version < 3 {
            return 0;
        }
        match self.discount_mode {
            DiscountMode::Scalar => 0,
            DiscountMode::PerState => self.n_states as u128,
            DiscountMode::PerStateAction => self.n_states as u128 * self.n_actions as u128,
        }
    }

    /// Exact byte length a file with this header must have. Computed in
    /// u128 so corrupt headers (oversized n/m/nnz) cannot overflow.
    pub fn expected_file_len(&self) -> u128 {
        let nm = self.n_states as u128 * self.n_actions as u128;
        self.header_len() as u128
            + 8 * (nm + 1)
            + 16 * self.nnz as u128
            + 8 * nm
            + 8 * self.discount_len()
    }

    /// Reject headers whose advertised shape disagrees with the actual
    /// file size — catches truncated payloads and oversized `nnz` before
    /// any reader allocates or seeks. All section offsets are guaranteed
    /// to fit in u64 once this passes.
    pub fn validate_file_len(&self, actual: u64) -> std::io::Result<()> {
        let want = self.expected_file_len();
        if want != actual as u128 {
            return Err(bad(&format!(
                "file length {actual} does not match header (expected {want} bytes \
                 for n={}, m={}, nnz={})",
                self.n_states, self.n_actions, self.nnz
            )));
        }
        Ok(())
    }
}

/// Read and validate the header (v1, v2 and v3 accepted).
pub fn read_header(r: &mut impl Read) -> std::io::Result<Header> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(bad("bad magic (not an MDPB file)"));
    }
    let version = read_u32(r)?;
    if !(1..=VERSION).contains(&version) {
        return Err(bad(&format!("unsupported version {version}")));
    }
    let n_states = read_u64(r)? as usize;
    let n_actions = read_u64(r)? as usize;
    let gamma = read_f64(r)?;
    let nnz = read_u64(r)? as usize;
    let objective = if version >= 2 {
        match read_u64(r)? {
            0 => Objective::Min,
            1 => Objective::Max,
            other => return Err(bad(&format!("invalid objective code {other}"))),
        }
    } else {
        Objective::Min
    };
    let discount_mode = if version >= 3 {
        DiscountMode::from_code(read_u64(r)?).map_err(|e| bad(&e))?
    } else {
        DiscountMode::Scalar
    };
    if n_actions == 0 || n_states == 0 {
        return Err(bad("empty MDP"));
    }
    validate_gamma(gamma).map_err(|e| bad(&e))?;
    Ok(Header {
        version,
        n_states,
        n_actions,
        gamma,
        nnz,
        objective,
        discount_mode,
    })
}

// ------------------------------------------------------------- write side

/// Normalize a sparse row into CSR's canonical layout (sort by column,
/// sum duplicates, drop exact-zero sums) — the *same* routine
/// [`Csr::from_row_lists`] uses at MDP assembly, so streamed bytes match
/// a serial [`save`] of the equivalent in-memory [`Mdp`] bit for bit.
fn normalize_row(row: &mut Vec<(usize, f64)>) {
    Csr::normalize_row_entries(row);
}

/// Row-stochasticity tolerance shared by the writer and both readers —
/// the same bound [`Mdp::new`] enforces via `Csr::is_row_stochastic`, so
/// a file the writer accepts is loadable serially and distributed, and
/// vice versa.
const STOCHASTIC_TOL: f64 = 1e-8;

/// Shared row validation: every probability in `[0, 1]` and the row
/// summing to 1, within [`STOCHASTIC_TOL`]. Returns the offending reason.
fn check_row_stochastic(row: &[(usize, f64)]) -> Result<(), String> {
    let mut sum = 0.0f64;
    for &(_, p) in row {
        if !(-STOCHASTIC_TOL..=1.0 + STOCHASTIC_TOL).contains(&p) {
            return Err(format!("probability {p} outside [0, 1]"));
        }
        sum += p;
    }
    if !sum.is_finite() || (sum - 1.0).abs() > STOCHASTIC_TOL {
        return Err(format!("probabilities sum to {sum}, not 1"));
    }
    Ok(())
}

/// Chunked, seek-based writer for one contiguous block of global rows
/// `[row_lo, row_hi)` of a v3 `.mdpb` file.
///
/// Rows are pushed in global row order (`s·m + a`); every `chunk_rows`
/// rows the buffered indptr / indices / values / costs (and, for vector
/// discount modes, discount) slices are written at their exact byte
/// offsets in the (pre-sized) file. Because all offsets are absolute, N
/// block writers covering disjoint row ranges produce a byte-identical
/// file to a single serial writer — this is the rank-parallel generation
/// path. Peak memory is O(chunk), never O(model).
///
/// Protocol: one rank (or the serial caller) runs
/// [`MdpWriter::create_file`] first; then each writer opens its block with
/// [`MdpWriter::open_block`], pushes its rows ([`MdpWriter::push_row`] for
/// scalar-discount files, [`MdpWriter::push_row_discounted`] for vector
/// modes), and calls [`MdpWriter::finish`].
pub struct MdpWriter {
    f: File,
    h: Header,
    row_hi: usize,
    /// Next global row index [`Self::push_row`] will fill.
    next_row: usize,
    /// Global nonzero offset after the last pushed row.
    nz: u64,
    /// Required value of `nz` at [`Self::finish`] (the next block's base).
    nz_hi: u64,
    chunk_rows: usize,
    rows_buffered: usize,
    /// First global row currently buffered, and its global nz offset.
    flush_row: usize,
    flush_nz: u64,
    /// Global discount-entry index of the first buffered discount entry,
    /// and the index after the last pushed one (rows for per-state-action
    /// mode, states for per-state mode; unused for scalar files).
    flush_disc: u64,
    next_disc: u64,
    /// Per-state mode: the current state's factor, to enforce that all
    /// `m` rows of a state agree before one entry is stored.
    state_gamma: f64,
    indptr_buf: Vec<u8>,
    indices_buf: Vec<u8>,
    values_buf: Vec<u8>,
    costs_buf: Vec<u8>,
    disc_buf: Vec<u8>,
}

impl MdpWriter {
    /// Create (truncate) the output file: pre-size it to the exact final
    /// length, write the v3 header and `indptr[0] = 0`. Call once before
    /// any block writer opens the file.
    pub fn create_file(path: impl AsRef<Path>, h: &Header) -> std::io::Result<()> {
        if h.version != VERSION {
            return Err(bad(&format!("writers only emit version {VERSION}")));
        }
        if h.n_states == 0 || h.n_actions == 0 {
            return Err(bad("refusing to write an empty MDP"));
        }
        validate_gamma(h.gamma).map_err(|e| bad(&e))?;
        let total = h.expected_file_len();
        if total > u64::MAX as u128 {
            return Err(bad("MDP too large for the .mdpb format"));
        }
        let f = File::create(path)?;
        f.set_len(total as u64)?;
        let mut w = BufWriter::new(f);
        w.write_all(MAGIC)?;
        w.write_all(&h.version.to_le_bytes())?;
        w.write_all(&(h.n_states as u64).to_le_bytes())?;
        w.write_all(&(h.n_actions as u64).to_le_bytes())?;
        w.write_all(&h.gamma.to_le_bytes())?;
        w.write_all(&(h.nnz as u64).to_le_bytes())?;
        let obj: u64 = match h.objective {
            Objective::Min => 0,
            Objective::Max => 1,
        };
        w.write_all(&obj.to_le_bytes())?;
        w.write_all(&h.discount_mode.code().to_le_bytes())?;
        // indptr[0]: no row owns entry 0, each pushed row records its END
        // offset at entry row+1.
        w.write_all(&0u64.to_le_bytes())?;
        w.flush()
    }

    /// Open a writer for global rows `[row_lo, row_hi)` whose nonzeros
    /// occupy the global range `[nz_lo, nz_hi)`. The file must already
    /// exist with the final size ([`Self::create_file`]).
    pub fn open_block(
        path: impl AsRef<Path>,
        h: Header,
        row_lo: usize,
        row_hi: usize,
        nz_lo: u64,
        nz_hi: u64,
        chunk_rows: usize,
    ) -> std::io::Result<MdpWriter> {
        let nm = h.n_states * h.n_actions;
        if row_lo > row_hi || row_hi > nm {
            return Err(bad(&format!(
                "row block [{row_lo}, {row_hi}) out of range for {nm} rows"
            )));
        }
        if nz_lo > nz_hi || nz_hi > h.nnz as u64 {
            return Err(bad(&format!(
                "nz block [{nz_lo}, {nz_hi}) out of range for nnz {}",
                h.nnz
            )));
        }
        if chunk_rows == 0 {
            return Err(bad("chunk_rows must be >= 1"));
        }
        let m = h.n_actions;
        if h.discount_mode == DiscountMode::PerState && (row_lo % m != 0 || row_hi % m != 0) {
            return Err(bad(&format!(
                "per-state discount blocks must be state-aligned, \
                 got rows [{row_lo}, {row_hi}) with m = {m}"
            )));
        }
        let disc_base = match h.discount_mode {
            DiscountMode::Scalar => 0,
            DiscountMode::PerState => (row_lo / m) as u64,
            DiscountMode::PerStateAction => row_lo as u64,
        };
        let f = OpenOptions::new().write(true).open(path)?;
        Ok(MdpWriter {
            f,
            h,
            row_hi,
            next_row: row_lo,
            nz: nz_lo,
            nz_hi,
            chunk_rows,
            rows_buffered: 0,
            flush_row: row_lo,
            flush_nz: nz_lo,
            flush_disc: disc_base,
            next_disc: disc_base,
            state_gamma: 0.0,
            indptr_buf: Vec::new(),
            indices_buf: Vec::new(),
            values_buf: Vec::new(),
            costs_buf: Vec::new(),
            disc_buf: Vec::new(),
        })
    }

    /// Rows this block still expects before [`Self::finish`].
    pub fn rows_remaining(&self) -> usize {
        self.row_hi - self.next_row
    }

    /// Append the next row of the block: the sparse transition
    /// distribution `(successor, probability)` plus the stage cost. The
    /// row is normalized (sorted, duplicates summed) and validated —
    /// out-of-range columns, non-stochastic rows and non-finite costs are
    /// rejected so a streaming writer can never produce an unloadable
    /// file. Scalar-discount files only; vector discount modes push each
    /// row's effective factor through [`Self::push_row_discounted`].
    pub fn push_row(&mut self, row: Vec<(usize, f64)>, cost: f64) -> std::io::Result<()> {
        if self.h.discount_mode != DiscountMode::Scalar {
            return Err(bad(&format!(
                "this file stores {} discounts; use push_row_discounted",
                self.h.discount_mode.name()
            )));
        }
        self.push_row_impl(row, cost, None)
    }

    /// [`Self::push_row`] for vector discount modes: `gamma` is the
    /// effective discount of this row's `(s, a)` pair, validated through
    /// the shared gamma check. For per-state files all `m` rows of a state
    /// must carry the same factor (one entry is stored per state; a
    /// disagreement is an error, not a silent pick).
    pub fn push_row_discounted(
        &mut self,
        row: Vec<(usize, f64)>,
        cost: f64,
        gamma: f64,
    ) -> std::io::Result<()> {
        if self.h.discount_mode == DiscountMode::Scalar {
            return Err(bad(
                "this file stores a scalar discount (header gamma); use push_row",
            ));
        }
        self.push_row_impl(row, cost, Some(gamma))
    }

    fn push_row_impl(
        &mut self,
        mut row: Vec<(usize, f64)>,
        cost: f64,
        gamma: Option<f64>,
    ) -> std::io::Result<()> {
        if self.next_row >= self.row_hi {
            return Err(bad(&format!(
                "push_row past the end of the block (row_hi = {})",
                self.row_hi
            )));
        }
        normalize_row(&mut row);
        for &(c, _) in &row {
            if c >= self.h.n_states {
                return Err(bad(&format!(
                    "row {}: successor state {c} out of range ({})",
                    self.next_row, self.h.n_states
                )));
            }
        }
        if let Err(e) = check_row_stochastic(&row) {
            return Err(bad(&format!("row {}: {e}", self.next_row)));
        }
        if !cost.is_finite() {
            return Err(bad(&format!("row {}: non-finite cost {cost}", self.next_row)));
        }
        if self.nz + row.len() as u64 > self.nz_hi {
            return Err(bad(&format!(
                "row {}: block nonzeros exceed the declared range (nz_hi = {})",
                self.next_row, self.nz_hi
            )));
        }
        if let Some(g) = gamma {
            if let Err(e) = validate_gamma(g) {
                return Err(bad(&format!("row {}: discount {e}", self.next_row)));
            }
            match self.h.discount_mode {
                DiscountMode::Scalar => unreachable!("checked by the public entry points"),
                DiscountMode::PerStateAction => {
                    self.disc_buf.extend_from_slice(&g.to_le_bytes());
                    self.next_disc += 1;
                }
                DiscountMode::PerState => {
                    if self.next_row % self.h.n_actions == 0 {
                        // first row of the state owns the entry
                        self.disc_buf.extend_from_slice(&g.to_le_bytes());
                        self.next_disc += 1;
                        self.state_gamma = g;
                    } else if g.to_bits() != self.state_gamma.to_bits() {
                        return Err(bad(&format!(
                            "row {}: per-state discount {g} disagrees with this \
                             state's earlier rows ({})",
                            self.next_row, self.state_gamma
                        )));
                    }
                }
            }
        }
        for &(c, v) in &row {
            self.indices_buf.extend_from_slice(&(c as u64).to_le_bytes());
            self.values_buf.extend_from_slice(&v.to_le_bytes());
        }
        self.nz += row.len() as u64;
        self.indptr_buf.extend_from_slice(&self.nz.to_le_bytes());
        self.costs_buf.extend_from_slice(&cost.to_le_bytes());
        self.next_row += 1;
        self.rows_buffered += 1;
        if self.rows_buffered >= self.chunk_rows {
            self.flush_chunk()?;
        }
        Ok(())
    }

    /// Write the buffered chunk into its sections (absolute offsets).
    fn flush_chunk(&mut self) -> std::io::Result<()> {
        if self.rows_buffered == 0 {
            return Ok(());
        }
        self.f.seek(SeekFrom::Start(self.h.indptr_off() + 8 * (self.flush_row as u64 + 1)))?;
        self.f.write_all(&self.indptr_buf)?;
        self.f.seek(SeekFrom::Start(self.h.indices_off() + 8 * self.flush_nz))?;
        self.f.write_all(&self.indices_buf)?;
        self.f.seek(SeekFrom::Start(self.h.values_off() + 8 * self.flush_nz))?;
        self.f.write_all(&self.values_buf)?;
        self.f.seek(SeekFrom::Start(self.h.costs_off() + 8 * self.flush_row as u64))?;
        self.f.write_all(&self.costs_buf)?;
        if !self.disc_buf.is_empty() {
            self.f.seek(SeekFrom::Start(self.h.discount_off() + 8 * self.flush_disc))?;
            self.f.write_all(&self.disc_buf)?;
        }
        self.flush_row = self.next_row;
        self.flush_nz = self.nz;
        self.flush_disc = self.next_disc;
        self.rows_buffered = 0;
        self.indptr_buf.clear();
        self.indices_buf.clear();
        self.values_buf.clear();
        self.costs_buf.clear();
        self.disc_buf.clear();
        Ok(())
    }

    /// Flush the trailing chunk and verify the block is complete: every
    /// row pushed and the nonzero count exactly matching the declared
    /// `[nz_lo, nz_hi)` range (catches impure row sources whose counting
    /// pass disagrees with the writing pass).
    pub fn finish(mut self) -> std::io::Result<()> {
        if self.next_row != self.row_hi {
            return Err(bad(&format!(
                "finish with {} rows missing from the block",
                self.row_hi - self.next_row
            )));
        }
        if self.nz != self.nz_hi {
            return Err(bad(&format!(
                "block ends at nonzero {} but declared {} — row source is \
                 not deterministic between passes",
                self.nz, self.nz_hi
            )));
        }
        self.flush_chunk()?;
        self.f.flush()
    }
}

/// Write a serial MDP to `path` (v3: objective + discount mode, plus the
/// discount payload for semi-MDPs). Streams through [`MdpWriter`] — the
/// same code path as the rank-parallel writers. The on-disk form is
/// canonical: explicitly stored zero probabilities (possible via
/// `Csr::from_parts`) are dropped, exactly as every other producer drops
/// them, so the header `nnz` counts only the entries the writer will
/// actually emit.
pub fn save(mdp: &Mdp, path: impl AsRef<Path>) -> std::io::Result<()> {
    let t = mdp.transitions();
    let m = mdp.n_actions();
    let nm = mdp.n_states() * m;
    let nnz = t.values().iter().filter(|&&v| v != 0.0).count();
    let mode = mdp.discount().mode();
    let h = Header::v3(mdp.n_states(), m, mdp.gamma(), nnz, mdp.objective(), mode);
    MdpWriter::create_file(&path, &h)?;
    let mut w = MdpWriter::open_block(&path, h, 0, nm, 0, nnz as u64, DEFAULT_CHUNK_ROWS)?;
    for r in 0..nm {
        let (cols, vals) = t.row(r);
        let row: Vec<(usize, f64)> = cols.iter().copied().zip(vals.iter().copied()).collect();
        match mode {
            DiscountMode::Scalar => w.push_row(row, mdp.costs()[r])?,
            _ => w.push_row_discounted(row, mdp.costs()[r], mdp.discount().at_row(r, m))?,
        }
    }
    w.finish()
}

/// Stream a generated MDP straight to disk, rank-parallel. Collective.
///
/// `prob`/`cost` must be pure functions of `(s, a)` (the
/// [`crate::models::ModelGenerator`] contract): pass 1 counts each rank's
/// nonzeros (discarding the rows), the per-rank counts are exchanged once
/// to fix the global layout, and pass 2 re-generates the rows into a
/// rank-local [`MdpWriter`] block. No rank ever holds more than one chunk
/// — this is how `generate` scales to models no single node could
/// materialize, and the resulting bytes are identical for every world
/// size.
#[allow(clippy::too_many_arguments)]
pub fn write_streaming<P, C>(
    comm: &Comm,
    path: &Path,
    n_states: usize,
    n_actions: usize,
    gamma: f64,
    objective: Objective,
    chunk_rows: usize,
    prob: P,
    cost: C,
) -> std::io::Result<Header>
where
    P: FnMut(usize, usize) -> Vec<(usize, f64)>,
    C: FnMut(usize, usize) -> f64,
{
    write_streaming_discounted(
        comm,
        path,
        n_states,
        n_actions,
        objective,
        chunk_rows,
        StreamDiscount::Scalar(gamma),
        prob,
        cost,
    )
}

/// How [`write_streaming_discounted`] sources discount factors: one
/// scalar, a per-state closure, or a per-state-action closure (the
/// semi-MDP generation path). Closures must be pure functions of their
/// indices, like the transition/cost fillers.
pub enum StreamDiscount<'a> {
    /// Classic discounting: one γ in the header, no payload.
    Scalar(f64),
    /// γ(s) per state (`n` payload entries).
    PerState(&'a dyn Fn(usize) -> f64),
    /// γ(s,a) per state-action pair (`n·m` payload entries).
    PerStateAction(&'a dyn Fn(usize, usize) -> f64),
}

impl StreamDiscount<'_> {
    fn mode(&self) -> DiscountMode {
        match self {
            StreamDiscount::Scalar(_) => DiscountMode::Scalar,
            StreamDiscount::PerState(_) => DiscountMode::PerState,
            StreamDiscount::PerStateAction(_) => DiscountMode::PerStateAction,
        }
    }

    fn at(&self, s: usize, a: usize) -> f64 {
        match self {
            StreamDiscount::Scalar(g) => *g,
            StreamDiscount::PerState(f) => f(s),
            StreamDiscount::PerStateAction(f) => f(s, a),
        }
    }
}

/// [`write_streaming`] with a **constant** discount in the requested
/// representation — the generate-side counterpart of
/// [`crate::mdp::DistMdp::try_from_fillers_constant`], i.e. a forced
/// vector `-discount_mode` on a scalar source: the payload is `gamma`
/// replicated, which loads and solves bitwise identically to the scalar.
/// Collective.
#[allow(clippy::too_many_arguments)]
pub fn write_streaming_constant<P, C>(
    comm: &Comm,
    path: &Path,
    n_states: usize,
    n_actions: usize,
    mode: DiscountMode,
    gamma: f64,
    objective: Objective,
    chunk_rows: usize,
    prob: P,
    cost: C,
) -> std::io::Result<Header>
where
    P: FnMut(usize, usize) -> Vec<(usize, f64)>,
    C: FnMut(usize, usize) -> f64,
{
    let per_state = move |_s: usize| gamma;
    let per_sa = move |_s: usize, _a: usize| gamma;
    let discount = match mode {
        DiscountMode::Scalar => StreamDiscount::Scalar(gamma),
        DiscountMode::PerState => StreamDiscount::PerState(&per_state),
        DiscountMode::PerStateAction => StreamDiscount::PerStateAction(&per_sa),
    };
    write_streaming_discounted(
        comm,
        path,
        n_states,
        n_actions,
        objective,
        chunk_rows,
        discount,
        prob,
        cost,
    )
}

/// [`write_streaming`] with generalized discounting: streams the v3
/// discount payload chunk-wise alongside the transition rows, still
/// rank-parallel with O(chunk) memory and bytes identical for every world
/// size. The header's `gamma` field records the global bound
/// `max γ(s,a)` (one extra allreduce for the closure modes); invalid
/// closure values fail collectively through the writer's shared per-row
/// validation, not a deadlock. Collective.
#[allow(clippy::too_many_arguments)]
pub fn write_streaming_discounted<P, C>(
    comm: &Comm,
    path: &Path,
    n_states: usize,
    n_actions: usize,
    objective: Objective,
    chunk_rows: usize,
    discount: StreamDiscount<'_>,
    mut prob: P,
    mut cost: C,
) -> std::io::Result<Header>
where
    P: FnMut(usize, usize) -> Vec<(usize, f64)>,
    C: FnMut(usize, usize) -> f64,
{
    let part = Partition::new(n_states, comm.size());
    let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
    let mode = discount.mode();

    // Pass 1: count this rank's nonzeros (post-normalization lengths) and,
    // for the closure modes, its local discount bound. No early returns —
    // validation happens in pass 2's writer so every rank reaches the
    // collectives below.
    let mut local_nnz: u64 = 0;
    let mut local_gmax: f64 = 0.0;
    for s in lo..hi {
        for a in 0..n_actions {
            let mut row = prob(s, a);
            normalize_row(&mut row);
            local_nnz += row.len() as u64;
            if mode != DiscountMode::Scalar {
                local_gmax = local_gmax.max(discount.at(s, a));
            }
        }
    }

    // One exchange fixes the global layout: every rank learns all block
    // sizes, hence its nz base offset and the total nnz.
    let counts: Vec<u64> = comm
        .allgatherv(codec::encode_usizes(&[local_nnz as usize]))
        .iter()
        .map(|b| codec::decode_usizes(b)[0] as u64)
        .collect();
    let nz_lo: u64 = counts[..comm.rank()].iter().sum();
    let nnz: u64 = counts.iter().sum();
    // The header gamma is the global discount bound (mode-uniform across
    // ranks, so either every rank reduces or none does).
    let gamma = match &discount {
        StreamDiscount::Scalar(g) => *g,
        _ => comm.max(local_gmax),
    };
    let header = Header::v3(n_states, n_actions, gamma, nnz as usize, objective, mode);

    // Root creates + sizes the file; everyone learns whether that worked
    // before opening (keeps the collective deadlock-free on IO errors).
    let create_err = if comm.is_root() {
        MdpWriter::create_file(path, &header).err()
    } else {
        None
    };
    let ok = comm.broadcast_f64(0, if create_err.is_none() { 1.0 } else { 0.0 });

    // Pass 2: every rank streams its block.
    let block_res = if ok == 0.0 {
        Err(create_err.unwrap_or_else(|| bad("rank 0 failed to create the output file")))
    } else {
        (|| -> std::io::Result<()> {
            let mut w = MdpWriter::open_block(
                path,
                header,
                lo * n_actions,
                hi * n_actions,
                nz_lo,
                nz_lo + local_nnz,
                chunk_rows,
            )?;
            for s in lo..hi {
                for a in 0..n_actions {
                    match mode {
                        DiscountMode::Scalar => w.push_row(prob(s, a), cost(s, a))?,
                        _ => w.push_row_discounted(
                            prob(s, a),
                            cost(s, a),
                            discount.at(s, a),
                        )?,
                    }
                }
            }
            w.finish()
        })()
    };
    finish_collective_write(comm, block_res, header)
}

/// Exchange the per-rank write verdict: a block failing on *any* rank
/// means the file is incomplete, so every rank must return `Err` (a rank
/// whose own block succeeded would otherwise report success for a corrupt
/// file). The allreduce doubles as the completion barrier — no rank can
/// pass it before every writer has finished its block.
fn finish_collective_write(
    comm: &Comm,
    block_res: std::io::Result<()>,
    header: Header,
) -> std::io::Result<Header> {
    let any_err = comm.max(if block_res.is_err() { 1.0 } else { 0.0 });
    match block_res {
        Err(e) => Err(e),
        Ok(()) if any_err > 0.0 => Err(bad("streaming write failed on another rank")),
        Ok(()) => Ok(header),
    }
}

/// Write a distributed MDP to `path`, each rank streaming its own block
/// (the "collect on M ranks, solve on N" half of claim C5). Collective.
/// Byte-identical to a serial [`save`] of the equivalent gathered MDP.
pub fn save_dist(comm: &Comm, mdp: &DistMdp, path: impl AsRef<Path>) -> std::io::Result<Header> {
    let path = path.as_ref();
    let part = mdp.partition();
    let m = mdp.n_actions();
    let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
    let trans = mdp.transitions();
    let local = trans.local();
    let local_nnz = local.nnz() as u64;
    // Discount mode and the global bound are rank-uniform by construction
    // (`DistMdp::gamma` is the collectively-agreed max), so the headers
    // every rank computes here are identical.
    let mode = mdp.discount().mode();

    let counts: Vec<u64> = comm
        .allgatherv(codec::encode_usizes(&[local_nnz as usize]))
        .iter()
        .map(|b| codec::decode_usizes(b)[0] as u64)
        .collect();
    let nz_lo: u64 = counts[..comm.rank()].iter().sum();
    let nnz: u64 = counts.iter().sum();
    let header = Header::v3(mdp.n_states(), m, mdp.gamma(), nnz as usize, mdp.objective(), mode);

    let create_err = if comm.is_root() {
        MdpWriter::create_file(path, &header).err()
    } else {
        None
    };
    let ok = comm.broadcast_f64(0, if create_err.is_none() { 1.0 } else { 0.0 });

    let block_res = if ok == 0.0 {
        Err(create_err.unwrap_or_else(|| bad("rank 0 failed to create the output file")))
    } else {
        (|| -> std::io::Result<()> {
            let mut w = MdpWriter::open_block(
                path,
                header,
                lo * m,
                hi * m,
                nz_lo,
                nz_lo + local_nnz,
                DEFAULT_CHUNK_ROWS,
            )?;
            for r in 0..local.nrows() {
                let (cols, vals) = local.row(r);
                // translate remapped local columns back to global ids;
                // push_row re-sorts into global column order
                let row: Vec<(usize, f64)> = cols
                    .iter()
                    .map(|&c| trans.global_col(c))
                    .zip(vals.iter().copied())
                    .collect();
                match mode {
                    DiscountMode::Scalar => w.push_row(row, mdp.local_costs()[r])?,
                    _ => w.push_row_discounted(
                        row,
                        mdp.local_costs()[r],
                        mdp.discount().at_row(r, m),
                    )?,
                }
            }
            w.finish()
        })()
    };
    finish_collective_write(comm, block_res, header)
}

// -------------------------------------------------------------- read side

/// Load a full (serial) MDP.
pub fn load(path: impl AsRef<Path>) -> std::io::Result<Mdp> {
    let f = File::open(path)?;
    let file_len = f.metadata()?.len();
    let mut r = BufReader::new(f);
    let h = read_header(&mut r)?;
    h.validate_file_len(file_len)?;
    let nm = h.n_states * h.n_actions;
    let indptr = read_u64s(&mut r, nm + 1)?;
    let indices = read_u64s(&mut r, h.nnz)?;
    let values = read_f64s(&mut r, h.nnz)?;
    let costs = read_f64s(&mut r, nm)?;
    // v3 trailing discount payload (validate_file_len proved the section
    // is present and exactly sized, so the count fits in usize here)
    let discount = match h.discount_mode {
        DiscountMode::Scalar => Discount::Scalar(h.gamma),
        DiscountMode::PerState => Discount::PerState(read_f64s(&mut r, h.n_states)?),
        DiscountMode::PerStateAction => Discount::PerStateAction(read_f64s(&mut r, nm)?),
    };
    let t = Csr::from_parts(nm, h.n_states, indptr, indices, values)
        .map_err(|e| bad(&format!("invalid CSR: {e}")))?;
    // Mdp::new_discounted re-validates every discount entry (finite,
    // [0, 1), length) — a corrupt payload is InvalidData, never a panic.
    Mdp::new_discounted(h.n_states, h.n_actions, t, costs, discount)
        .map(|m| m.with_objective(h.objective))
        .map_err(|e| bad(&e))
}

/// Distributed load: each rank reads only its slice of the file.
/// Collective; a malformed file yields `Err` on every rank (the validation
/// verdict is allreduced before assembly so no rank can hang in a
/// collective another rank never enters).
pub fn load_dist(comm: &Comm, path: impl AsRef<Path>) -> std::io::Result<DistMdp> {
    let path = path.as_ref();
    let local = read_local_block(comm, path);
    // Collective error agreement: assembly is collective, so every rank
    // must agree to proceed before any rank enters it.
    let any_err = comm.max(if local.is_err() { 1.0 } else { 0.0 });
    if any_err > 0.0 {
        return match local {
            Err(e) => Err(e),
            Ok(_) => Err(bad("load_dist failed on another rank")),
        };
    }
    let (h, part, rows, costs, discount) = local.expect("checked above");
    // Contraction bound: recomputed from the payload (not trusted from
    // the header) and agreed collectively, like the filler builds. Every
    // rank reads the same header, so the mode — hence whether the reduce
    // runs — is rank-uniform.
    let gamma_max = match &discount {
        Discount::Scalar(g) => *g,
        d => comm.max(d.entries().unwrap().iter().copied().fold(0.0, f64::max)),
    };
    let trans = DistCsr::assemble(comm, part, rows);
    Ok(DistMdp {
        part,
        n_actions: h.n_actions,
        discount,
        gamma_max,
        objective: h.objective,
        trans,
        costs,
    })
}

/// Rank-local half of [`load_dist`]: read + validate this rank's slice.
#[allow(clippy::type_complexity)]
fn read_local_block(
    comm: &Comm,
    path: &Path,
) -> std::io::Result<(Header, Partition, Vec<Vec<(usize, f64)>>, Vec<f64>, Discount)> {
    let mut f = File::open(path)?;
    let file_len = f.metadata()?.len();
    let h = read_header(&mut f)?;
    h.validate_file_len(file_len)?;
    let part = Partition::new(h.n_states, comm.size());
    let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
    let m = h.n_actions;
    let (row_lo, row_hi) = (lo * m, hi * m);

    // indptr slice for local rows (+1 for the end offset)
    f.seek(SeekFrom::Start(h.indptr_off() + 8 * row_lo as u64))?;
    let indptr = read_u64s(&mut f, row_hi - row_lo + 1)?;
    // A corrupt indptr (non-monotone or out of range) previously panicked
    // on index underflow below; reject it as InvalidData instead.
    for w in indptr.windows(2) {
        if w[0] > w[1] {
            return Err(bad("indptr not monotone"));
        }
    }
    let (nz_lo, nz_hi) = (indptr[0], indptr[row_hi - row_lo]);
    if nz_hi > h.nnz {
        return Err(bad(&format!(
            "indptr entry {nz_hi} exceeds declared nnz {}",
            h.nnz
        )));
    }
    // Global endpoint checks (the ranks owning the first/last rows see
    // them; interior block boundaries agree because adjacent ranks read
    // the same shared indptr entry) — serial `load` enforces these via
    // `Csr::from_parts`, and both readers must accept the same files.
    if row_lo == 0 && nz_lo != 0 {
        return Err(bad(&format!("indptr starts at {nz_lo}, expected 0")));
    }
    if row_hi == h.n_states * m && nz_hi != h.nnz {
        return Err(bad(&format!(
            "indptr ends at {nz_hi}, expected nnz {}",
            h.nnz
        )));
    }

    // indices + values slices
    f.seek(SeekFrom::Start(h.indices_off() + 8 * nz_lo as u64))?;
    let indices = read_u64s(&mut f, nz_hi - nz_lo)?;
    f.seek(SeekFrom::Start(h.values_off() + 8 * nz_lo as u64))?;
    let values = read_f64s(&mut f, nz_hi - nz_lo)?;
    if let Some(&c) = indices.iter().find(|&&c| c >= h.n_states) {
        return Err(bad(&format!(
            "successor state {c} out of range ({})",
            h.n_states
        )));
    }

    // costs slice
    f.seek(SeekFrom::Start(h.costs_off() + 8 * row_lo as u64))?;
    let costs = read_f64s(&mut f, row_hi - row_lo)?;

    // build per-row global-column lists, validating what the serial
    // loader validates through `Csr::from_parts` + `Mdp::new` (sorted
    // unique columns, stochasticity at the same tolerance) — a file must
    // be loadable by both readers or neither
    let mut rows = Vec::with_capacity(row_hi - row_lo);
    for r in 0..(row_hi - row_lo) {
        let (a, b) = (indptr[r] - nz_lo, indptr[r + 1] - nz_lo);
        let cols = &indices[a..b];
        for w in cols.windows(2) {
            if w[0] >= w[1] {
                return Err(bad(&format!(
                    "row {}: columns not sorted-unique",
                    row_lo + r
                )));
            }
        }
        let row: Vec<(usize, f64)> = cols
            .iter()
            .copied()
            .zip(values[a..b].iter().copied())
            .collect();
        check_row_stochastic(&row).map_err(|e| bad(&format!("row {}: {e}", row_lo + r)))?;
        rows.push(row);
    }
    if let Some(&c) = costs.iter().find(|c| !c.is_finite()) {
        return Err(bad(&format!("non-finite stage cost {c}")));
    }

    // v3 discount payload: read only this rank's slice, validating each
    // entry at the same bar as the serial loader (a file must be loadable
    // by both readers or neither).
    let discount = match h.discount_mode {
        DiscountMode::Scalar => Discount::Scalar(h.gamma),
        DiscountMode::PerState => {
            f.seek(SeekFrom::Start(h.discount_off() + 8 * lo as u64))?;
            let g = read_f64s(&mut f, hi - lo)?;
            for (i, &gi) in g.iter().enumerate() {
                validate_gamma(gi)
                    .map_err(|e| bad(&format!("discount at state {}: {e}", lo + i)))?;
            }
            Discount::PerState(g)
        }
        DiscountMode::PerStateAction => {
            f.seek(SeekFrom::Start(h.discount_off() + 8 * row_lo as u64))?;
            let g = read_f64s(&mut f, row_hi - row_lo)?;
            for (i, &gi) in g.iter().enumerate() {
                let row = row_lo + i;
                validate_gamma(gi).map_err(|e| {
                    bad(&format!("discount at (s={}, a={}): {e}", row / m, row % m))
                })?;
            }
            Discount::PerStateAction(g)
        }
    };
    Ok((h, part, rows, costs, discount))
}

fn bad(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

fn read_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f64(r: &mut impl Read) -> std::io::Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

fn read_u64s(r: &mut impl Read, n: usize) -> std::io::Result<Vec<usize>> {
    let mut bytes = vec![0u8; n * 8];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
        .collect())
}

fn read_f64s(r: &mut impl Read, n: usize) -> std::io::Result<Vec<f64>> {
    let mut bytes = vec![0u8; n * 8];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;
    use crate::mdp::fixtures::random_mdp;
    use crate::models::{garnet::GarnetSpec, ModelGenerator};
    use crate::util::prop;
    use std::sync::Arc;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("madupite-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    /// Write the legacy v1 layout (no objective field) — backward-compat
    /// fixture replicating the original serial writer byte for byte.
    fn write_v1(mdp: &Mdp, path: &std::path::Path) {
        let f = std::fs::File::create(path).unwrap();
        let mut w = BufWriter::new(f);
        w.write_all(MAGIC).unwrap();
        w.write_all(&1u32.to_le_bytes()).unwrap();
        w.write_all(&(mdp.n_states() as u64).to_le_bytes()).unwrap();
        w.write_all(&(mdp.n_actions() as u64).to_le_bytes()).unwrap();
        w.write_all(&mdp.gamma().to_le_bytes()).unwrap();
        let t = mdp.transitions();
        w.write_all(&(t.nnz() as u64).to_le_bytes()).unwrap();
        for &p in t.indptr() {
            w.write_all(&(p as u64).to_le_bytes()).unwrap();
        }
        for &i in t.indices() {
            w.write_all(&(i as u64).to_le_bytes()).unwrap();
        }
        for &v in t.values() {
            w.write_all(&v.to_le_bytes()).unwrap();
        }
        for &c in mdp.costs() {
            w.write_all(&c.to_le_bytes()).unwrap();
        }
        w.flush().unwrap();
    }

    #[test]
    fn roundtrip_serial() {
        let mdp = random_mdp(3, 15, 3, 0.92);
        let path = tmpfile("roundtrip.mdpb");
        save(&mdp, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.n_states(), 15);
        assert_eq!(loaded.n_actions(), 3);
        assert_eq!(loaded.gamma(), 0.92);
        assert_eq!(loaded.objective(), Objective::Min);
        assert_eq!(loaded.transitions(), mdp.transitions());
        prop::close_slices(loaded.costs(), mdp.costs(), 0.0).unwrap();
    }

    #[test]
    fn roundtrip_preserves_max_objective() {
        // the v1 bug: Objective::Max silently degraded to Min on reload
        let mdp = random_mdp(5, 12, 2, 0.9).with_objective(Objective::Max);
        let path = tmpfile("objective.mdpb");
        save(&mdp, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.objective(), Objective::Max);
        // and through the distributed reader, at several world sizes
        for size in [1usize, 3] {
            let p = path.clone();
            let objs = World::run(size, move |comm| {
                load_dist(&comm, &p).unwrap().objective()
            });
            assert!(objs.into_iter().all(|o| o == Objective::Max), "size={size}");
        }
    }

    #[test]
    fn v1_files_still_load_as_min() {
        let mdp = random_mdp(7, 10, 2, 0.85);
        let path = tmpfile("legacy_v1.mdpb");
        write_v1(&mdp, &path);
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.objective(), Objective::Min);
        assert_eq!(loaded.transitions(), mdp.transitions());
        prop::close_slices(loaded.costs(), mdp.costs(), 0.0).unwrap();
        // distributed reader handles the 40-byte v1 header offsets too
        let p = path.clone();
        let mdp2 = Arc::new(mdp);
        let mdp3 = Arc::clone(&mdp2);
        World::run(2, move |comm| {
            let d = load_dist(&comm, &p).unwrap();
            assert_eq!(d.objective(), Objective::Min);
            assert_eq!(d.n_states(), mdp3.n_states());
        });
    }

    /// Write the legacy v2 layout (objective, no discount_mode field) —
    /// backward-compat fixture replicating the v2 serial writer byte for
    /// byte.
    fn write_v2(mdp: &Mdp, path: &std::path::Path) {
        let f = std::fs::File::create(path).unwrap();
        let mut w = BufWriter::new(f);
        w.write_all(MAGIC).unwrap();
        w.write_all(&2u32.to_le_bytes()).unwrap();
        w.write_all(&(mdp.n_states() as u64).to_le_bytes()).unwrap();
        w.write_all(&(mdp.n_actions() as u64).to_le_bytes()).unwrap();
        w.write_all(&mdp.gamma().to_le_bytes()).unwrap();
        let t = mdp.transitions();
        w.write_all(&(t.nnz() as u64).to_le_bytes()).unwrap();
        let obj: u64 = match mdp.objective() {
            Objective::Min => 0,
            Objective::Max => 1,
        };
        w.write_all(&obj.to_le_bytes()).unwrap();
        for &p in t.indptr() {
            w.write_all(&(p as u64).to_le_bytes()).unwrap();
        }
        for &i in t.indices() {
            w.write_all(&(i as u64).to_le_bytes()).unwrap();
        }
        for &v in t.values() {
            w.write_all(&v.to_le_bytes()).unwrap();
        }
        for &c in mdp.costs() {
            w.write_all(&c.to_le_bytes()).unwrap();
        }
        w.flush().unwrap();
    }

    #[test]
    fn v2_files_still_load_with_objective() {
        use crate::mdp::Discount;
        let mdp = random_mdp(23, 10, 2, 0.85).with_objective(Objective::Max);
        let path = tmpfile("legacy_v2.mdpb");
        write_v2(&mdp, &path);
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.objective(), Objective::Max);
        assert_eq!(loaded.discount(), &Discount::Scalar(0.85));
        assert_eq!(loaded.transitions(), mdp.transitions());
        prop::close_slices(loaded.costs(), mdp.costs(), 0.0).unwrap();
        // the distributed reader handles the 48-byte v2 header offsets too
        let p = path.clone();
        World::run(2, move |comm| {
            let d = load_dist(&comm, &p).unwrap();
            assert_eq!(d.objective(), Objective::Max);
            assert_eq!(d.gamma(), 0.85);
            assert_eq!(d.discount(), &Discount::Scalar(0.85));
        });
    }

    #[test]
    fn header_offsets_consistent() {
        let h = Header::v3(10, 2, 0.9, 33, Objective::Min, DiscountMode::Scalar);
        assert_eq!(h.indptr_off(), 56);
        assert_eq!(h.indices_off(), 56 + 8 * 21);
        assert_eq!(h.values_off(), h.indices_off() + 8 * 33);
        assert_eq!(h.costs_off(), h.values_off() + 8 * 33);
        assert_eq!(h.discount_off(), h.costs_off() + 8 * 20);
        let v1 = Header { version: 1, ..h };
        assert_eq!(v1.indptr_off(), 40);
        let v2 = Header { version: 2, ..h };
        assert_eq!(v2.indptr_off(), 48);
        assert_eq!(h.expected_file_len(), 56 + 8 * 21 + 16 * 33 + 8 * 20);
        // vector discount modes append their payload after the costs
        let hs = Header {
            discount_mode: DiscountMode::PerState,
            ..h
        };
        assert_eq!(hs.expected_file_len(), h.expected_file_len() + 8 * 10);
        let hsa = Header {
            discount_mode: DiscountMode::PerStateAction,
            ..h
        };
        assert_eq!(hsa.expected_file_len(), h.expected_file_len() + 8 * 20);
        // ...but never for legacy versions, which predate the field
        let v2s = Header {
            version: 2,
            discount_mode: DiscountMode::PerStateAction,
            ..h
        };
        assert_eq!(v2s.expected_file_len(), 48 + 8 * 21 + 16 * 33 + 8 * 20);
    }

    #[test]
    fn rejects_garbage() {
        let path = tmpfile("garbage.mdpb");
        std::fs::write(&path, b"not an mdp file at all........").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        let path = tmpfile("badver.mdpb");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"MDPB");
        bytes.extend_from_slice(&99u32.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 40]);
        std::fs::write(&path, bytes).unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn rejects_bad_objective_code() {
        let path = tmpfile("badobj.mdpb");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"MDPB");
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes()); // n
        bytes.extend_from_slice(&1u64.to_le_bytes()); // m
        bytes.extend_from_slice(&0.9f64.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes()); // nnz
        bytes.extend_from_slice(&7u64.to_le_bytes()); // invalid objective
        std::fs::write(&path, bytes).unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn rejects_truncated_payload() {
        let mdp = random_mdp(11, 12, 2, 0.9);
        let path = tmpfile("truncated.mdpb");
        save(&mdp, &path).unwrap();
        let full = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(full - 16).unwrap();
        drop(f);
        assert!(load(&path).is_err());
        let p = path.clone();
        World::run(2, move |comm| {
            assert!(load_dist(&comm, &p).is_err());
        });
    }

    #[test]
    fn rejects_oversized_nnz() {
        // header advertises an absurd nnz; readers must refuse before
        // attempting any allocation of that size
        let path = tmpfile("bignnz.mdpb");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"MDPB");
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&4u64.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes());
        bytes.extend_from_slice(&0.95f64.to_le_bytes());
        bytes.extend_from_slice(&(u64::MAX / 32).to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 64]);
        std::fs::write(&path, bytes).unwrap();
        assert!(load(&path).is_err());
        let p = path.clone();
        World::run(1, move |comm| {
            assert!(load_dist(&comm, &p).is_err());
        });
    }

    #[test]
    fn rejects_non_monotone_indptr() {
        let mdp = random_mdp(13, 12, 2, 0.9);
        let path = tmpfile("nonmono.mdpb");
        save(&mdp, &path).unwrap();
        // corrupt indptr entry 1 (offset 56 + 8) to a huge in-range value:
        // entry 1 > entry 2 → previously an index underflow panic
        let nnz = mdp.transitions().nnz() as u64;
        let mut f = OpenOptions::new().write(true).open(&path).unwrap();
        f.seek(SeekFrom::Start(V3_HEADER_LEN + 8)).unwrap();
        f.write_all(&nnz.to_le_bytes()).unwrap();
        drop(f);
        assert!(load(&path).is_err(), "serial load must reject");
        for size in [1usize, 3] {
            let p = path.clone();
            World::run(size, move |comm| {
                assert!(load_dist(&comm, &p).is_err(), "dist load must reject");
            });
        }
    }

    #[test]
    fn save_canonicalizes_explicit_zero_entries() {
        // an Mdp built via from_parts may store an explicit 0.0 entry;
        // save must not fail on it (regression: header nnz counted the
        // zero the writer then dropped) — the file is the canonical form
        let t = Csr::from_parts(2, 2, vec![0, 1, 3], vec![0, 0, 1], vec![1.0, 0.0, 1.0]).unwrap();
        let mdp = Mdp::new(2, 1, t, vec![0.5, 0.25], 0.9).unwrap();
        let path = tmpfile("explicit_zero.mdpb");
        save(&mdp, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.transitions().nnz(), 2, "zero entry dropped on disk");
        let (tv0, _) = mdp.bellman(&[1.0, 2.0]);
        let (tv1, _) = loaded.bellman(&[1.0, 2.0]);
        prop::close_slices(&tv0, &tv1, 0.0).unwrap();
    }

    #[test]
    fn rejects_duplicate_columns_in_both_readers() {
        // duplicate columns within a row: Csr::from_parts rejects them in
        // the serial loader; the distributed reader must agree instead of
        // silently summing them in assemble
        let path = tmpfile("dupcols.mdpb");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"MDPB");
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes()); // n_states
        bytes.extend_from_slice(&1u64.to_le_bytes()); // n_actions
        bytes.extend_from_slice(&0.9f64.to_le_bytes());
        bytes.extend_from_slice(&3u64.to_le_bytes()); // nnz
        bytes.extend_from_slice(&0u64.to_le_bytes()); // objective min
        for p in [0u64, 2, 3] {
            bytes.extend_from_slice(&p.to_le_bytes());
        }
        for c in [0u64, 0, 1] {
            bytes.extend_from_slice(&c.to_le_bytes()); // row 0: col 0 twice
        }
        for v in [0.5f64, 0.5, 1.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for c in [1.0f64, 2.0] {
            bytes.extend_from_slice(&c.to_le_bytes());
        }
        std::fs::write(&path, bytes).unwrap();
        assert!(load(&path).is_err());
        let p = path.clone();
        World::run(2, move |comm| {
            assert!(load_dist(&comm, &p).is_err());
        });
    }

    #[test]
    fn rejects_indptr_not_starting_at_zero() {
        let mdp = random_mdp(19, 8, 2, 0.9);
        let path = tmpfile("badstart.mdpb");
        save(&mdp, &path).unwrap();
        let mut f = OpenOptions::new().write(true).open(&path).unwrap();
        f.seek(SeekFrom::Start(V3_HEADER_LEN)).unwrap();
        f.write_all(&1u64.to_le_bytes()).unwrap();
        drop(f);
        assert!(load(&path).is_err());
        let p = path.clone();
        World::run(2, move |comm| {
            assert!(load_dist(&comm, &p).is_err());
        });
    }

    #[test]
    fn writer_rejects_bad_rows() {
        let h = Header::v3(4, 1, 0.9, 8, Objective::Min, DiscountMode::Scalar);
        let path = tmpfile("writer_validation.mdpb");
        MdpWriter::create_file(&path, &h).unwrap();
        let mut w = MdpWriter::open_block(&path, h, 0, 4, 0, 8, 2).unwrap();
        // column out of range
        assert!(w.push_row(vec![(9, 1.0)], 0.0).is_err());
        // non-stochastic
        assert!(w.push_row(vec![(0, 0.4)], 0.0).is_err());
        // non-finite cost
        assert!(w.push_row(vec![(0, 1.0)], f64::NAN).is_err());
        // discounted pushes belong to vector-mode files
        assert!(w.push_row_discounted(vec![(0, 1.0)], 0.0, 0.5).is_err());
        // a good row, then finishing early must fail
        w.push_row(vec![(0, 1.0)], 1.0).unwrap();
        assert!(w.finish().is_err());
    }

    #[test]
    fn writer_validates_discount_entries() {
        let h = Header::v3(3, 2, 0.9, 6, Objective::Min, DiscountMode::PerStateAction);
        let path = tmpfile("writer_disc_validation.mdpb");
        MdpWriter::create_file(&path, &h).unwrap();
        let mut w = MdpWriter::open_block(&path, h, 0, 6, 0, 6, 2).unwrap();
        // scalar pushes belong to scalar files
        assert!(w.push_row(vec![(0, 1.0)], 0.0).is_err());
        // out-of-range / non-finite discounts are typed errors
        assert!(w.push_row_discounted(vec![(0, 1.0)], 0.0, 1.0).is_err());
        assert!(w
            .push_row_discounted(vec![(0, 1.0)], 0.0, f64::NAN)
            .is_err());
        w.push_row_discounted(vec![(0, 1.0)], 0.0, 0.99).unwrap();

        // per-state files require all m rows of a state to agree
        let h = Header::v3(3, 2, 0.9, 6, Objective::Min, DiscountMode::PerState);
        let path = tmpfile("writer_disc_perstate.mdpb");
        MdpWriter::create_file(&path, &h).unwrap();
        // ...and the block must be state-aligned
        assert!(MdpWriter::open_block(&path, h, 1, 6, 0, 6, 2).is_err());
        let mut w = MdpWriter::open_block(&path, h, 0, 6, 0, 6, 2).unwrap();
        w.push_row_discounted(vec![(0, 1.0)], 0.0, 0.5).unwrap();
        let err = w
            .push_row_discounted(vec![(0, 1.0)], 0.0, 0.6)
            .unwrap_err();
        assert!(err.to_string().contains("disagrees"), "{err}");
    }

    #[test]
    fn streaming_write_matches_serial_save_bytes() {
        // the same garnet model through (a) build_serial + save and
        // (b) write_streaming at several world sizes must be byte-identical
        let spec = Arc::new(GarnetSpec::new(151, 3, 4, 11));
        let gamma = 0.95;
        let mdp = spec.build_serial(gamma).with_objective(Objective::Max);
        let ref_path = tmpfile("stream_ref.mdpb");
        save(&mdp, &ref_path).unwrap();
        let want = std::fs::read(&ref_path).unwrap();
        for ranks in [1usize, 2, 3] {
            let out_path = tmpfile(&format!("stream_r{ranks}.mdpb"));
            let spec2 = Arc::clone(&spec);
            let p = out_path.clone();
            World::run(ranks, move |comm| {
                // chunk of 7 rows: deliberately not a divisor of anything
                write_streaming(
                    &comm,
                    &p,
                    spec2.n_states(),
                    spec2.n_actions(),
                    gamma,
                    Objective::Max,
                    7,
                    |s, a| spec2.prob_row(s, a),
                    |s, a| spec2.cost(s, a),
                )
                .unwrap();
            });
            let got = std::fs::read(&out_path).unwrap();
            assert!(got == want, "ranks={ranks}: streamed bytes differ");
        }
    }

    #[test]
    fn save_dist_matches_serial_save_bytes() {
        let mdp = Arc::new(random_mdp(17, 23, 3, 0.9).with_objective(Objective::Max));
        let ref_path = tmpfile("savedist_ref.mdpb");
        save(&mdp, &ref_path).unwrap();
        let want = std::fs::read(&ref_path).unwrap();
        for ranks in [1usize, 2, 4] {
            let out_path = tmpfile(&format!("savedist_r{ranks}.mdpb"));
            let rp = ref_path.clone();
            let op = out_path.clone();
            World::run(ranks, move |comm| {
                let d = load_dist(&comm, &rp).unwrap();
                save_dist(&comm, &d, &op).unwrap();
            });
            let got = std::fs::read(&out_path).unwrap();
            assert!(got == want, "ranks={ranks}: save_dist bytes differ");
        }
    }

    #[test]
    fn dist_load_matches_serial_bellman() {
        let mdp = Arc::new(random_mdp(11, 29, 3, 0.9));
        let path = tmpfile("dist.mdpb");
        save(&mdp, &path).unwrap();
        for size in [1usize, 2, 4] {
            let path2 = path.clone();
            let out = World::run(size, move |comm| {
                let d = load_dist(&comm, &path2).unwrap();
                let part = d.partition();
                let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
                let v: Vec<f64> = (lo..hi).map(|i| (i as f64) * 0.1).collect();
                let mut tv = vec![0.0; hi - lo];
                let mut pol = vec![0usize; hi - lo];
                let mut buf = d.make_buffer();
                let mut q = Vec::new();
                d.bellman_backup(&comm, &v, &mut tv, &mut pol, &mut buf, &mut q);
                tv
            });
            let v_full: Vec<f64> = (0..29).map(|i| (i as f64) * 0.1).collect();
            let (tv_serial, _) = mdp.bellman(&v_full);
            let tv_dist: Vec<f64> = out.into_iter().flatten().collect();
            prop::close_slices(&tv_dist, &tv_serial, 1e-12).unwrap();
        }
    }

    #[test]
    fn dist_load_costs_sliced_correctly() {
        let mdp = Arc::new(random_mdp(13, 10, 2, 0.8));
        let path = tmpfile("costs.mdpb");
        save(&mdp, &path).unwrap();
        let mdp2 = Arc::clone(&mdp);
        World::run(3, move |comm| {
            let d = load_dist(&comm, &path).unwrap();
            let part = d.partition();
            let lo = part.lo(comm.rank());
            for (i, &c) in d.local_costs().iter().enumerate() {
                let s = lo + i / 2;
                let a = i % 2;
                assert_eq!(c, mdp2.cost(s, a));
            }
        });
    }
}
