//! Fused matrix-free policy-evaluation operator (`MatFree` backend).
//!
//! madupite keeps its Krylov layer matrix-type-agnostic through PETSc's
//! shell `Mat`; this module is the payoff of that seam on our side: the
//! policy system `A = I − diag(γ_π) P_π` applied **directly from the
//! stacked `(n·m) × n` transition CSR** by indexing rows `s·m + π(s)`,
//! with no `P_π` materialization at all. With generalized (semi-MDP)
//! discounting the per-state factor `γ_π(s) = γ(s, π(s))` folds into the
//! same fused row pass — `diag(γ_π)` is never assembled either
//! (DESIGN.md §12); for scalar discounts this reduces to `I − γ P_π`.
//!
//! Versus the assembled backend ([`crate::ksp::LinOp`] over
//! [`DistMdp::policy_system`]) this removes, per policy change:
//!
//! - the **memory** for a second copy of the selected transition rows
//!   (≈ `nnz/m` entries — the difference between fitting a model per node
//!   or not at scale), and
//! - the **setup cost** of a fresh ghost plan + CSR assembly (a collective
//!   `alltoallv` plus O(nnz/m) copying on every outer iteration in which
//!   the greedy policy moved).
//!
//! The ghost exchange uses a policy-selected sub-plan of the stacked
//! matrix's plan, built lazily on first apply (one collective `alltoallv`
//! of request lists): only the ghost entries the selected rows `s·m + π(s)`
//! actually read are moved, matching the assembled `P_π`-only plan's
//! volume without the assembly. The `bench_ablation` "eval-backend" cases
//! measure the remaining trade; DESIGN.md §4 has the selection matrix.

use super::DistMdp;
use crate::comm::Comm;
use crate::ksp::Apply;
use crate::linalg::dist::{GhostBuf, GhostSubPlan, Partition};
use crate::linalg::Csr;
use std::sync::OnceLock;

/// `A = I − diag(γ_π) P_π` applied matrix-free off a [`DistMdp`]'s stacked
/// kernel (`γ_π(s) = γ(s, π(s))`; plain `I − γ P_π` for scalar discounts).
///
/// Borrows the MDP and the rank-local greedy policy; construction is O(1)
/// and communication-free. The first `apply` lazily builds (one collective
/// `alltoallv` of request lists) a [`GhostSubPlan`] restricted to the
/// selected rows `s·m + π(s)`, so each exchange moves only the ghost
/// entries π actually reads instead of the stacked matrix's union over all
/// `m` actions — same f64s for the selected rows, strictly fewer bytes
/// whenever other actions reference extra ghosts. Laziness matters: the
/// non-collective hooks (`diag`, `local_block`, `materialize_rows`) are
/// called from transient contexts where a collective would deadlock.
pub struct MatFreePolicyOp<'a> {
    mdp: &'a DistMdp,
    policy: &'a [usize],
    plan: OnceLock<GhostSubPlan>,
}

impl<'a> MatFreePolicyOp<'a> {
    /// Operator view over `mdp` for the (rank-local) greedy `policy`.
    pub fn new(mdp: &'a DistMdp, policy: &'a [usize]) -> Self {
        assert_eq!(
            policy.len(),
            mdp.local_states(),
            "policy must cover the rank-local states"
        );
        debug_assert!(policy.iter().all(|&a| a < mdp.n_actions()));
        MatFreePolicyOp {
            mdp,
            policy,
            plan: OnceLock::new(),
        }
    }

    /// The stacked-CSR row index backing local state `s` under π.
    #[inline]
    fn row_of(&self, s: usize) -> usize {
        s * self.mdp.n_actions() + self.policy[s]
    }

    /// Effective discount of the selected stacked `row = s·m + π(s)`:
    /// `γ(s, π(s))`, the diagonal of `diag(γ_π)` (the scalar γ for classic
    /// MDPs). Takes the row index the caller already computed for its CSR
    /// access, so the fused kernels pay one indexed load per state — no
    /// second `row_of` evaluation, no second pass, no assembled
    /// `diag(γ_π)` matrix.
    #[inline]
    fn gamma_at(&self, row: usize) -> f64 {
        self.mdp.discount().at_row(row, self.mdp.n_actions())
    }

    /// The lazily built policy-selected ghost sub-plan (collective on
    /// first use — callers must be on the collective apply path).
    fn plan(&self, comm: &Comm) -> &GhostSubPlan {
        self.plan.get_or_init(|| {
            let nl = self.mdp.local_states();
            self.mdp
                .transitions()
                .build_sub_plan(comm, (0..nl).map(|s| self.row_of(s)))
        })
    }

    /// Fused row pass: `y[s] = x[s] − γ_π(s)·(P_π x)[s]`. With
    /// `pass = Some(b)` only rows whose boundary flag equals `b` are
    /// written (the two-pass overlapped schedule); `None` evaluates every
    /// row. Same chunk grid + same gather kernel in all cases → the
    /// schedules are bitwise identical.
    fn apply_rows(&self, x: &[f64], y: &mut [f64], buf: &GhostBuf, pass: Option<bool>) {
        let trans = self.mdp.transitions();
        let local = trans.local();
        let flags = trans.boundary_flags();
        let xb = buf.x();
        // Row-parallel over the rank's worker pool; each selected row's
        // gather goes through the lane-unrolled kernel with a fixed fold
        // order → bitwise identical for any thread count per backend.
        crate::util::par::par_for_rows(y, |offset, chunk| {
            for (i, ys) in chunk.iter_mut().enumerate() {
                let s = offset + i;
                let row = self.row_of(s);
                if let Some(want) = pass {
                    if flags[row] != want {
                        continue;
                    }
                }
                let (cols, vals) = local.row(row);
                // SAFETY: DistCsr remaps every stored column into buffer
                // space [0, nlocal + nghost) == xb.len() at assembly.
                let px = unsafe { crate::util::simd::gather_dot_unchecked(cols, vals, xb) };
                *ys = x[s] - self.gamma_at(row) * px;
            }
        });
    }
}

impl Apply for MatFreePolicyOp<'_> {
    fn local_rows(&self) -> usize {
        self.mdp.local_states()
    }

    fn partition(&self) -> Partition {
        self.mdp.partition()
    }

    fn make_buffer(&self) -> GhostBuf {
        // Sized for the stacked matrix's ghost plan (superset of P_π's).
        self.mdp.make_buffer()
    }

    fn apply(&self, comm: &Comm, x: &[f64], y: &mut [f64], buf: &mut GhostBuf) {
        let nl = self.local_rows();
        assert_eq!(x.len(), nl);
        assert_eq!(y.len(), nl);
        let trans = self.mdp.transitions();
        let plan = self.plan(comm);
        if comm.size() > 1 && crate::comm::overlap::enabled(comm.size()) {
            // Split-phase: interior states compute while π's ghost values
            // are in flight; boundary states after `finish`.
            trans.start_ghost_exchange_subset(comm, plan, x, buf);
            self.apply_rows(x, y, buf, Some(false));
            trans.finish_ghost_exchange_subset(comm, plan, buf);
            self.apply_rows(x, y, buf, Some(true));
        } else {
            trans.update_ghosts_subset(comm, plan, x, buf);
            self.apply_rows(x, y, buf, None);
        }
    }

    fn diag(&self, out: &mut [f64]) {
        // Owned columns are remapped to [0, nlocal): the diagonal of local
        // state s sits at local column s of its selected stacked row.
        let local = self.mdp.transitions().local();
        for (s, o) in out.iter_mut().enumerate() {
            let row = self.row_of(s);
            *o = 1.0 - self.gamma_at(row) * local.get(row, s);
        }
    }

    fn local_block(&self) -> Csr {
        let nl = self.local_rows();
        let local = self.mdp.transitions().local();
        let mut rows: Vec<Vec<(usize, f64)>> = Vec::with_capacity(nl);
        for s in 0..nl {
            let row_idx = self.row_of(s);
            let (cols, vals) = local.row(row_idx);
            let gamma = self.gamma_at(row_idx);
            let mut row: Vec<(usize, f64)> = vec![(s, 1.0)];
            for (&c, &v) in cols.iter().zip(vals) {
                if c < nl {
                    row.push((c, -gamma * v));
                }
            }
            rows.push(row);
        }
        Csr::from_row_lists(nl, rows)
    }

    fn materialize_rows(&self) -> Vec<Vec<(usize, f64)>> {
        let nl = self.local_rows();
        let trans = self.mdp.transitions();
        let local = trans.local();
        let lo = self.partition().lo(trans.rank());
        (0..nl)
            .map(|s| {
                let row_idx = self.row_of(s);
                let (cols, vals) = local.row(row_idx);
                let gamma = self.gamma_at(row_idx);
                let mut row: Vec<(usize, f64)> = Vec::with_capacity(cols.len() + 1);
                row.push((lo + s, 1.0));
                for (&c, &v) in cols.iter().zip(vals) {
                    row.push((trans.global_col(c), -gamma * v));
                }
                row
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;
    use crate::ksp::{LinOp, Precond, Tolerance};
    use crate::mdp::fixtures::random_mdp;
    use crate::util::prng::Xoshiro256pp;
    use crate::util::prop;
    use std::sync::Arc;

    /// Deterministic random local policy for the rank's state range.
    fn random_local_policy(lo: usize, hi: usize, m: usize, seed: u64) -> Vec<usize> {
        (lo..hi)
            .map(|s| {
                let mut rng = Xoshiro256pp::new(seed ^ (s as u64).wrapping_mul(0x5851));
                rng.index(m)
            })
            .collect()
    }

    /// MatFreePolicyOp must agree with LinOp over the assembled P_π on
    /// apply, diag and residual — for random policies, any world size.
    #[test]
    fn matches_assembled_linop() {
        for (seed, size) in [(11u64, 1usize), (12, 2), (13, 3)] {
            let mdp = Arc::new(random_mdp(seed, 29, 4, 0.93));
            let out = World::run(size, move |comm| {
                let d = DistMdp::from_serial(&comm, &mdp);
                let part = d.partition();
                let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
                let nl = hi - lo;
                let policy = random_local_policy(lo, hi, 4, seed);
                let x: Vec<f64> = (lo..hi).map(|i| (i as f64 * 0.7).sin()).collect();
                let b: Vec<f64> = (lo..hi).map(|i| (i as f64 * 0.3).cos()).collect();

                // assembled path
                let (p_pi, _) = d.policy_system(&comm, &policy);
                let asm = LinOp::new(&p_pi, d.gamma());
                let mut buf_a = asm.make_buffer();
                let mut y_a = vec![0.0; nl];
                asm.apply(&comm, &x, &mut y_a, &mut buf_a);
                let mut d_a = vec![0.0; nl];
                asm.diag(&mut d_a);
                let mut r = vec![0.0; nl];
                let res_a = asm.residual(&comm, &b, &x, &mut r, &mut buf_a);

                // matrix-free path
                let mf = MatFreePolicyOp::new(&d, &policy);
                assert_eq!(mf.local_rows(), nl);
                let mut buf_m = mf.make_buffer();
                let mut y_m = vec![0.0; nl];
                mf.apply(&comm, &x, &mut y_m, &mut buf_m);
                let mut d_m = vec![0.0; nl];
                mf.diag(&mut d_m);
                let res_m = mf.residual(&comm, &b, &x, &mut r, &mut buf_m);

                prop::close_slices(&y_a, &y_m, 1e-13).unwrap();
                prop::close_slices(&d_a, &d_m, 1e-13).unwrap();
                assert!((res_a - res_m).abs() < 1e-12, "{res_a} vs {res_m}");
            });
            assert_eq!(out.len(), size);
        }
    }

    /// Property: for random MDP shapes and random policies, the two
    /// operators produce identical images.
    #[test]
    fn prop_apply_equals_assembled() {
        prop::forall("matfree apply == assembled apply", |rng| {
            let n = 3 + rng.index(20);
            let m = 1 + rng.index(4);
            let gamma = rng.range_f64(0.0, 0.99);
            let seed = rng.next_u64();
            let pol_seed = rng.next_u64();
            let mdp = Arc::new(random_mdp(seed, n, m, gamma));
            let out = World::run(1, move |comm| {
                let d = DistMdp::from_serial(&comm, &mdp);
                let policy = random_local_policy(0, n, m, pol_seed);
                let x: Vec<f64> = (0..n).map(|i| ((i * 7 + 1) as f64).sin()).collect();
                let (p_pi, _) = d.policy_system(&comm, &policy);
                let asm = LinOp::new(&p_pi, d.gamma());
                let mf = MatFreePolicyOp::new(&d, &policy);
                let mut y_a = vec![0.0; n];
                let mut y_m = vec![0.0; n];
                let mut buf_a = asm.make_buffer();
                let mut buf_m = mf.make_buffer();
                asm.apply(&comm, &x, &mut y_a, &mut buf_a);
                mf.apply(&comm, &x, &mut y_m, &mut buf_m);
                (y_a, y_m)
            });
            let (y_a, y_m) = &out[0];
            prop::close_slices(y_a, y_m, 1e-12)
        });
    }

    /// The matrix-free operator drives every Krylov solver to the same
    /// solution as the assembled one.
    #[test]
    fn krylov_solvers_run_matrix_free() {
        let mdp = Arc::new(random_mdp(31, 24, 3, 0.95));
        let out = World::run(2, move |comm| {
            let d = DistMdp::from_serial(&comm, &mdp);
            let part = d.partition();
            let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
            let nl = hi - lo;
            let policy = random_local_policy(lo, hi, 3, 5);
            let g = d.policy_costs(&policy);
            let (p_pi, g2) = d.policy_system(&comm, &policy);
            prop::close_slices(&g, &g2, 0.0).unwrap();
            let tol = Tolerance {
                atol: 1e-11,
                rtol: 0.0,
                max_iters: 5_000,
            };

            let mf = MatFreePolicyOp::new(&d, &policy);
            let asm = LinOp::new(&p_pi, d.gamma());
            let mut sols: Vec<Vec<f64>> = Vec::new();
            for op in [&mf as &dyn Apply, &asm as &dyn Apply] {
                let mut x = vec![0.0; nl];
                let s = crate::ksp::gmres::solve(&comm, op, &Precond::None, &g, &mut x, &tol, 20);
                assert!(s.converged, "gmres not converged matrix-free");
                sols.push(x.clone());
                let mut xb = vec![0.0; nl];
                let s = crate::ksp::bicgstab::solve(&comm, op, &Precond::None, &g, &mut xb, &tol);
                assert!(s.converged, "bicgstab not converged");
                sols.push(xb);
            }
            sols
        });
        for rank_sols in &out {
            let reference = &rank_sols[0];
            for s in &rank_sols[1..] {
                prop::close_slices(reference, s, 1e-7).unwrap();
            }
        }
    }
}
