//! Core MDP data structures (serial and distributed).
//!
//! Storage follows madupite exactly: the transition kernel is a single
//! stacked CSR of shape `(n·m) × n` — row `s·m + a` holds the distribution
//! `P(·|s,a)` — and stage costs are a dense `n × m` table. The distributed
//! variant partitions **states** contiguously across ranks; a rank owns the
//! `m` transition rows and the cost row of each of its states plus the
//! matching block of every value vector.
//!
//! Construction mirrors madupite's two paths (paper claim C5):
//! - **online/filler**: user functions `(s, a) → [(s', p)...]` and
//!   `(s, a) → cost`, evaluated rank-locally in parallel;
//! - **offline**: binary `.mdpb` v2 files written/loaded by [`io`],
//!   including rank-sliced distributed loading ([`io::load_dist`]), a
//!   chunked streaming writer ([`io::MdpWriter`]) and rank-parallel
//!   generation/saving ([`io::write_streaming`], [`io::save_dist`]) that
//!   never materialize the full model on one rank.

pub mod blocked;
pub mod discount;
pub mod io;
pub mod lowprec;
pub mod matfree;

pub use blocked::BsrPolicyOp;
pub use discount::{Discount, DiscountMode};
pub use lowprec::F32PolicyOp;
pub use matfree::MatFreePolicyOp;

use crate::comm::Comm;
use crate::linalg::dist::{DistCsr, GhostBuf, Partition};
use crate::linalg::Csr;

/// Optimization sense (madupite's `-mode MINCOST|MAXREWARD`).
///
/// With [`Objective::Max`] the `costs` table is interpreted as *rewards*
/// and every greedy step maximizes; the contraction analysis is identical.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Objective {
    /// Minimize expected discounted cost (the default).
    #[default]
    Min,
    /// Maximize expected discounted reward.
    Max,
}

impl Objective {
    /// true when `candidate` improves on `incumbent` for this sense.
    #[inline]
    pub fn better(&self, candidate: f64, incumbent: f64) -> bool {
        match self {
            Objective::Min => candidate < incumbent,
            Objective::Max => candidate > incumbent,
        }
    }

    /// The identity element of the improvement fold.
    #[inline]
    pub fn worst(&self) -> f64 {
        match self {
            Objective::Min => f64::INFINITY,
            Objective::Max => f64::NEG_INFINITY,
        }
    }

    /// Parse the `-objective` option string (`min`/`mincost`,
    /// `max`/`maxreward`).
    pub fn parse(name: &str) -> Result<Objective, String> {
        match name {
            "min" | "mincost" => Ok(Objective::Min),
            "max" | "maxreward" => Ok(Objective::Max),
            other => Err(format!("unknown objective '{other}'")),
        }
    }

    /// Canonical option-string form (inverse of [`Self::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            Objective::Min => "min",
            Objective::Max => "max",
        }
    }
}

/// The one crate-wide discount-factor check: γ must be finite and in
/// [0, 1). Every layer (options database, builder, filler construction)
/// funnels through this so the accepted range can never drift.
pub(crate) fn validate_gamma(gamma: f64) -> Result<f64, String> {
    if gamma.is_finite() && (0.0..1.0).contains(&gamma) {
        Ok(gamma)
    } else {
        Err(format!("gamma {gamma} outside [0, 1)"))
    }
}

/// Validate one filler-produced transition row: non-empty, targets in
/// range, probabilities finite/non-negative, sum 1 within 1e-8 (the same
/// bar [`Csr::is_row_stochastic`] enforces post-assembly, but with the
/// offending `(s, a)` pair named).
fn validate_filler_row(
    n_states: usize,
    s: usize,
    a: usize,
    row: &[(usize, f64)],
) -> Result<(), String> {
    if row.is_empty() {
        return Err(format!("transition row (s={s}, a={a}) is empty"));
    }
    let mut sum = 0.0;
    for &(col, p) in row {
        if col >= n_states {
            return Err(format!(
                "transition row (s={s}, a={a}) targets state {col} >= n_states {n_states}"
            ));
        }
        if !p.is_finite() || p < 0.0 {
            return Err(format!(
                "transition row (s={s}, a={a}) has invalid probability {p}"
            ));
        }
        sum += p;
    }
    if (sum - 1.0).abs() > 1e-8 {
        return Err(format!(
            "transition row (s={s}, a={a}) sums to {sum}, not 1 (not a distribution)"
        ));
    }
    Ok(())
}

/// A complete (serial) infinite-horizon discounted MDP (or semi-MDP, when
/// the discount is state(-action)-dependent — see [`Discount`]).
#[derive(Clone, Debug)]
pub struct Mdp {
    n_states: usize,
    n_actions: usize,
    /// Stacked transition CSR: row `s·m + a` = P(·|s,a).
    transitions: Csr,
    /// Stage costs, `costs[s·m + a]`.
    costs: Vec<f64>,
    /// Discount factors: one scalar, or per-state / per-state-action
    /// vectors (semi-MDPs), every entry in [0, 1).
    discount: Discount,
    /// Optimization sense (min-cost by default).
    objective: Objective,
}

impl Mdp {
    /// Assemble from parts, validating shapes and stochasticity.
    pub fn new(
        n_states: usize,
        n_actions: usize,
        transitions: Csr,
        costs: Vec<f64>,
        gamma: f64,
    ) -> Result<Mdp, String> {
        Mdp::new_discounted(n_states, n_actions, transitions, costs, Discount::Scalar(gamma))
    }

    /// [`Self::new`] with generalized (possibly state-action-dependent)
    /// discounting. The discount is validated element-wise through the one
    /// crate-wide gamma check — finite, in [0, 1), correct length.
    pub fn new_discounted(
        n_states: usize,
        n_actions: usize,
        transitions: Csr,
        costs: Vec<f64>,
        discount: Discount,
    ) -> Result<Mdp, String> {
        if transitions.nrows() != n_states * n_actions {
            return Err(format!(
                "transition rows {} != n·m = {}",
                transitions.nrows(),
                n_states * n_actions
            ));
        }
        if transitions.ncols() != n_states {
            return Err("transition cols != n_states".into());
        }
        if costs.len() != n_states * n_actions {
            return Err("cost table size != n·m".into());
        }
        discount.validate(n_states, n_actions)?;
        if !transitions.is_row_stochastic(1e-8) {
            return Err("transition matrix is not row-stochastic".into());
        }
        if !costs.iter().all(|c| c.is_finite()) {
            return Err("non-finite stage cost".into());
        }
        Ok(Mdp {
            n_states,
            n_actions,
            transitions,
            costs,
            discount,
            objective: Objective::Min,
        })
    }

    /// Switch the optimization sense (builder style).
    pub fn with_objective(mut self, objective: Objective) -> Mdp {
        self.objective = objective;
        self
    }

    /// The optimization sense (min-cost or max-reward).
    pub fn objective(&self) -> Objective {
        self.objective
    }

    /// Build by evaluating filler functions over all (state, action) pairs
    /// (madupite's "online simulation" creation path). Panics on invalid
    /// fillers — use [`Self::try_from_fillers`] for the fallible variant.
    pub fn from_fillers(
        n_states: usize,
        n_actions: usize,
        gamma: f64,
        prob: impl Fn(usize, usize) -> Vec<(usize, f64)>,
        cost: impl Fn(usize, usize) -> f64,
    ) -> Mdp {
        Mdp::try_from_fillers(n_states, n_actions, gamma, prob, cost)
            .unwrap_or_else(|e| panic!("filler produced an invalid MDP: {e}"))
    }

    /// Fallible [`Self::from_fillers`]: every generated row is validated
    /// (targets in range, probabilities finite and non-negative, row sum 1
    /// within 1e-8, costs finite) and the first offending `(s, a)` pair is
    /// named in the error — the validation layer behind
    /// [`crate::api::MdpBuilder`].
    pub fn try_from_fillers(
        n_states: usize,
        n_actions: usize,
        gamma: f64,
        prob: impl Fn(usize, usize) -> Vec<(usize, f64)>,
        cost: impl Fn(usize, usize) -> f64,
    ) -> Result<Mdp, String> {
        Mdp::try_from_fillers_discounted(n_states, n_actions, Discount::Scalar(gamma), prob, cost)
    }

    /// [`Self::try_from_fillers`] with a pre-built (possibly vector)
    /// [`Discount`] — validated element-wise before any row is generated.
    pub fn try_from_fillers_discounted(
        n_states: usize,
        n_actions: usize,
        discount: Discount,
        prob: impl Fn(usize, usize) -> Vec<(usize, f64)>,
        cost: impl Fn(usize, usize) -> f64,
    ) -> Result<Mdp, String> {
        if n_states == 0 || n_actions == 0 {
            return Err(format!("MDP shape {n_states}x{n_actions} must be positive"));
        }
        discount.validate(n_states, n_actions)?;
        let mut rows = Vec::with_capacity(n_states * n_actions);
        let mut costs = Vec::with_capacity(n_states * n_actions);
        for s in 0..n_states {
            for a in 0..n_actions {
                let row = prob(s, a);
                validate_filler_row(n_states, s, a, &row)?;
                let c = cost(s, a);
                if !c.is_finite() {
                    return Err(format!("cost at (s={s}, a={a}) is not finite"));
                }
                rows.push(row);
                costs.push(c);
            }
        }
        let transitions = Csr::from_row_lists(n_states, rows);
        Mdp::new_discounted(n_states, n_actions, transitions, costs, discount)
    }

    /// Semi-MDP filler construction: a third closure supplies the
    /// per-transition effective discount `(s, a) → γ(s,a)`, validated
    /// pair-by-pair through the shared gamma check with the offending
    /// `(s, a)` named (the serial counterpart of
    /// [`DistMdp::try_from_fillers_semi`]).
    pub fn try_from_fillers_semi(
        n_states: usize,
        n_actions: usize,
        disc: impl Fn(usize, usize) -> f64,
        prob: impl Fn(usize, usize) -> Vec<(usize, f64)>,
        cost: impl Fn(usize, usize) -> f64,
    ) -> Result<Mdp, String> {
        if n_states == 0 || n_actions == 0 {
            return Err(format!("MDP shape {n_states}x{n_actions} must be positive"));
        }
        let mut gammas = Vec::with_capacity(n_states * n_actions);
        for s in 0..n_states {
            for a in 0..n_actions {
                gammas.push(disc(s, a));
            }
        }
        // Discount::validate (inside the discounted build) checks every
        // entry through the shared gamma check, naming the offending (s, a).
        Mdp::try_from_fillers_discounted(
            n_states,
            n_actions,
            Discount::PerStateAction(gammas),
            prob,
            cost,
        )
    }

    /// Number of states `n`.
    pub fn n_states(&self) -> usize {
        self.n_states
    }

    /// Number of actions `m`.
    pub fn n_actions(&self) -> usize {
        self.n_actions
    }

    /// Uniform discount bound `γ̄ = max γ(s,a)` ∈ [0, 1) — the contraction
    /// modulus. For classic (scalar-discount) MDPs this *is* the discount
    /// factor; semi-MDPs expose their per-transition factors through
    /// [`Self::discount`].
    pub fn gamma(&self) -> f64 {
        self.discount.max_gamma()
    }

    /// The discount representation (scalar, per-state, per-state-action).
    pub fn discount(&self) -> &Discount {
        &self.discount
    }

    /// The stacked `(n·m) × n` transition CSR.
    pub fn transitions(&self) -> &Csr {
        &self.transitions
    }

    /// The dense stage-cost table, `costs[s·m + a]`.
    pub fn costs(&self) -> &[f64] {
        &self.costs
    }

    /// Stage cost `g(s, a)`.
    pub fn cost(&self, s: usize, a: usize) -> f64 {
        self.costs[s * self.n_actions + a]
    }

    /// Q-factor backup for one (s, a): `g(s,a) + γ(s,a) Σ P(s'|s,a) V(s')`.
    pub fn q_value(&self, s: usize, a: usize, v: &[f64]) -> f64 {
        let row = s * self.n_actions + a;
        let (cols, vals) = self.transitions.row(row);
        let mut exp = 0.0;
        for (&c, &p) in cols.iter().zip(vals) {
            exp += p * v[c];
        }
        self.cost(s, a) + self.discount.at_row(row, self.n_actions) * exp
    }

    /// One Bellman backup: returns (TV, greedy policy).
    ///
    /// States are parallelized over the rank's worker pool
    /// ([`crate::util::par`]); each state's action scan is serial, so the
    /// result is bitwise identical for every thread count.
    pub fn bellman(&self, v: &[f64]) -> (Vec<f64>, Vec<usize>) {
        assert_eq!(v.len(), self.n_states);
        let mut tv = vec![0.0; self.n_states];
        let mut pol = vec![0usize; self.n_states];
        let _ = crate::util::par::par_for_rows2(
            &mut tv,
            &mut pol,
            |offset, tv_chunk, pol_chunk| {
                for (i, (tvs, pols)) in tv_chunk.iter_mut().zip(pol_chunk.iter_mut()).enumerate() {
                    let s = offset + i;
                    let mut best = self.objective.worst();
                    let mut best_a = 0;
                    for a in 0..self.n_actions {
                        let q = self.q_value(s, a, v);
                        if self.objective.better(q, best) {
                            best = q;
                            best_a = a;
                        }
                    }
                    *tvs = best;
                    *pols = best_a;
                }
            },
            |(), ()| (),
        );
        (tv, pol)
    }

    /// Extract `P_π` (n×n CSR) and `g_π` for a fixed policy.
    pub fn policy_system(&self, policy: &[usize]) -> (Csr, Vec<f64>) {
        assert_eq!(policy.len(), self.n_states);
        let rows: Vec<usize> = policy
            .iter()
            .enumerate()
            .map(|(s, &a)| {
                assert!(a < self.n_actions, "policy action out of range");
                s * self.n_actions + a
            })
            .collect();
        let p_pi = self.transitions.select_rows(&rows);
        let g_pi = policy
            .iter()
            .enumerate()
            .map(|(s, &a)| self.cost(s, a))
            .collect();
        (p_pi, g_pi)
    }

    /// Evaluate a fixed policy exactly (dense solve — small MDPs only).
    pub fn evaluate_policy_exact(&self, policy: &[usize]) -> Vec<f64> {
        let (p_pi, g_pi) = self.policy_system(policy);
        let mut a = p_pi.to_dense();
        // A = I - diag(γ_π) P_π (γ_π(s) = γ(s, π(s)); scalar γ for classic MDPs)
        for r in 0..self.n_states {
            let g = self
                .discount
                .at_row(r * self.n_actions + policy[r], self.n_actions);
            for c in 0..self.n_states {
                a[(r, c)] = if r == c { 1.0 } else { 0.0 } - g * a[(r, c)];
            }
        }
        a.solve(&g_pi).expect("policy system singular")
    }

    /// ∞-norm Bellman residual ‖TV − V‖∞.
    pub fn bellman_residual(&self, v: &[f64]) -> f64 {
        let (tv, _) = self.bellman(v);
        tv.iter()
            .zip(v)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Patch individual stage costs in place — the delta-update path for
    /// drifting models. Validates **only the touched entries** (index
    /// bounds and cost finiteness, the same bar construction applies to
    /// every entry) instead of re-scanning the full cost table; all
    /// patches are checked before any is applied, so a bad entry leaves
    /// the model untouched.
    pub fn patch_costs(&mut self, rows: &[(usize, usize, f64)]) -> Result<(), String> {
        for &(s, a, c) in rows {
            if s >= self.n_states || a >= self.n_actions {
                return Err(format!(
                    "cost patch (s={s}, a={a}) is out of range for a {}x{} MDP",
                    self.n_states, self.n_actions
                ));
            }
            if !c.is_finite() {
                return Err(format!("cost patch (s={s}, a={a}) has non-finite cost {c}"));
            }
        }
        for &(s, a, c) in rows {
            self.costs[s * self.n_actions + a] = c;
        }
        Ok(())
    }

    /// Patch transition rows in place — each block replaces the successor
    /// distribution of one `(s, a)` pair. Re-validates **only the touched
    /// rows** (bounds, finite non-negative probabilities, stochasticity at
    /// the same 1e-8 bar as construction, with the offending `(s, a)`
    /// named); untouched rows are not re-scanned. All blocks are checked
    /// before any row is spliced, so a bad block leaves the model
    /// untouched.
    pub fn patch_transitions(
        &mut self,
        blocks: &[(usize, usize, Vec<(usize, f64)>)],
    ) -> Result<(), String> {
        for (s, a, row) in blocks {
            if *s >= self.n_states || *a >= self.n_actions {
                return Err(format!(
                    "transition patch (s={s}, a={a}) is out of range for a {}x{} MDP",
                    self.n_states, self.n_actions
                ));
            }
            validate_filler_row(self.n_states, *s, *a, row)?;
        }
        for (s, a, row) in blocks {
            let mut entries = row.clone();
            Csr::normalize_row_entries(&mut entries);
            self.transitions.set_row(s * self.n_actions + a, &entries)?;
        }
        Ok(())
    }

    /// Total memory of the MDP data (bytes) — reported in E5.
    pub fn storage_bytes(&self) -> usize {
        let disc = self.discount.entries().map_or(0, |v| v.len() * 8);
        self.transitions.storage_bytes() + self.costs.len() * 8 + disc
    }
}

/// The rank-local block of a distributed MDP.
pub struct DistMdp {
    part: Partition,
    n_actions: usize,
    /// Rank-local discount slice (scalar, or the owned states' entries of
    /// the per-state / per-state-action vectors).
    discount: Discount,
    /// Global contraction modulus `max γ(s,a)` — agreed across ranks at
    /// construction so every rank reports the same certificate.
    gamma_max: f64,
    objective: Objective,
    /// Local stacked transition rows (`m · local_states` of them),
    /// ghost-remapped over the state partition.
    trans: DistCsr,
    /// Local stage costs, `costs[(s − lo)·m + a]`.
    costs: Vec<f64>,
}

/// How a distributed filler build sources its discount factors: a
/// rank-uniform pre-built [`Discount`] (sliced locally), a constant
/// expanded to the requested representation (built directly at local
/// size — no rank ever materializes the global vector), or a closure
/// evaluated rank-locally over the owned `(s, a)` pairs.
enum DiscountSource<'a> {
    Global(Discount),
    Constant(DiscountMode, f64),
    Filler(&'a dyn Fn(usize, usize) -> f64),
}

impl DistMdp {
    /// Build rank-locally from filler functions. Collective. Panics on
    /// invalid fillers — use [`Self::try_from_fillers`] for the fallible
    /// variant.
    pub fn from_fillers(
        comm: &Comm,
        n_states: usize,
        n_actions: usize,
        gamma: f64,
        prob: impl Fn(usize, usize) -> Vec<(usize, f64)>,
        cost: impl Fn(usize, usize) -> f64,
    ) -> DistMdp {
        DistMdp::try_from_fillers(comm, n_states, n_actions, gamma, prob, cost)
            .unwrap_or_else(|e| panic!("filler produced an invalid distributed MDP: {e}"))
    }

    /// Fallible [`Self::from_fillers`]: each rank validates its own rows
    /// (targets in range, probabilities finite and non-negative, row sum 1
    /// within 1e-8, costs finite), then the world *agrees collectively* on
    /// the outcome — either every rank proceeds to assembly or every rank
    /// returns `Err`, so a sub-stochastic row on one rank can never
    /// deadlock the others in a later collective. Collective.
    pub fn try_from_fillers(
        comm: &Comm,
        n_states: usize,
        n_actions: usize,
        gamma: f64,
        prob: impl Fn(usize, usize) -> Vec<(usize, f64)>,
        cost: impl Fn(usize, usize) -> f64,
    ) -> Result<DistMdp, String> {
        DistMdp::build_from_fillers(
            comm,
            n_states,
            n_actions,
            DiscountSource::Global(Discount::Scalar(gamma)),
            prob,
            cost,
        )
    }

    /// [`Self::try_from_fillers`] with a pre-built (possibly vector)
    /// [`Discount`]. The discount must be **rank-uniform** (every rank
    /// passes the same global object — e.g. a header-loaded vector or a
    /// constant expansion); it is validated identically on every rank and
    /// each rank keeps only its owned slice. Collective.
    pub fn try_from_fillers_discounted(
        comm: &Comm,
        n_states: usize,
        n_actions: usize,
        discount: Discount,
        prob: impl Fn(usize, usize) -> Vec<(usize, f64)>,
        cost: impl Fn(usize, usize) -> f64,
    ) -> Result<DistMdp, String> {
        DistMdp::build_from_fillers(
            comm,
            n_states,
            n_actions,
            DiscountSource::Global(discount),
            prob,
            cost,
        )
    }

    /// [`Self::try_from_fillers`] with a **constant** discount in the
    /// requested representation — `gamma` replicated over however many
    /// entries `mode` stores. Each rank builds only its local slice
    /// (O(local), never the global vector), and by the representation
    /// invariant the result solves bitwise identically to the scalar.
    /// Collective.
    pub fn try_from_fillers_constant(
        comm: &Comm,
        n_states: usize,
        n_actions: usize,
        mode: DiscountMode,
        gamma: f64,
        prob: impl Fn(usize, usize) -> Vec<(usize, f64)>,
        cost: impl Fn(usize, usize) -> f64,
    ) -> Result<DistMdp, String> {
        DistMdp::build_from_fillers(
            comm,
            n_states,
            n_actions,
            DiscountSource::Constant(mode, gamma),
            prob,
            cost,
        )
    }

    /// Semi-MDP filler construction: a third closure supplies the
    /// per-transition effective discount `(s, a) → γ(s,a)`, evaluated and
    /// validated **rank-locally** over the owned pairs (through the shared
    /// gamma check, with the offending `(s, a)` named) — the verdict then
    /// joins the same collective agreement as the row validation, so a bad
    /// discount on one rank errors every rank instead of deadlocking the
    /// world. Collective.
    pub fn try_from_fillers_semi(
        comm: &Comm,
        n_states: usize,
        n_actions: usize,
        disc: impl Fn(usize, usize) -> f64,
        prob: impl Fn(usize, usize) -> Vec<(usize, f64)>,
        cost: impl Fn(usize, usize) -> f64,
    ) -> Result<DistMdp, String> {
        DistMdp::build_from_fillers(
            comm,
            n_states,
            n_actions,
            DiscountSource::Filler(&disc),
            prob,
            cost,
        )
    }

    /// The shared distributed filler build behind every construction path.
    fn build_from_fillers(
        comm: &Comm,
        n_states: usize,
        n_actions: usize,
        discount: DiscountSource<'_>,
        prob: impl Fn(usize, usize) -> Vec<(usize, f64)>,
        cost: impl Fn(usize, usize) -> f64,
    ) -> Result<DistMdp, String> {
        // Uniform-input checks: identical on every rank, so an early return
        // here cannot desynchronize the world.
        if n_states == 0 || n_actions == 0 {
            return Err(format!("MDP shape {n_states}x{n_actions} must be positive"));
        }
        match &discount {
            DiscountSource::Global(d) => d.validate(n_states, n_actions)?,
            DiscountSource::Constant(_, g) => {
                validate_gamma(*g)?;
            }
            DiscountSource::Filler(_) => {}
        }
        let part = Partition::new(n_states, comm.size());
        let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
        let mut rows = Vec::with_capacity((hi - lo) * n_actions);
        let mut costs = Vec::with_capacity((hi - lo) * n_actions);
        let mut local_gammas: Vec<f64> = Vec::new();
        let mut local_err: Option<String> = None;
        'fill: for s in lo..hi {
            for a in 0..n_actions {
                let row = prob(s, a);
                if let Err(e) = validate_filler_row(n_states, s, a, &row) {
                    local_err = Some(e);
                    break 'fill;
                }
                let c = cost(s, a);
                if !c.is_finite() {
                    local_err = Some(format!("cost at (s={s}, a={a}) is not finite"));
                    break 'fill;
                }
                if let DiscountSource::Filler(f) = &discount {
                    let g = f(s, a);
                    if let Err(e) = validate_gamma(g) {
                        local_err = Some(format!("discount at (s={s}, a={a}): {e}"));
                        break 'fill;
                    }
                    local_gammas.push(g);
                }
                rows.push(row);
                costs.push(c);
            }
        }
        // Collective agreement before the (collective) assembly: gather
        // every rank's verdict so all ranks return the same (first rank's)
        // specific error — or all proceed together.
        let verdicts = comm.allgatherv(local_err.unwrap_or_default().into_bytes());
        if let Some(msg) = verdicts.into_iter().find(|m| !m.is_empty()) {
            return Err(String::from_utf8_lossy(&msg).into_owned());
        }
        // The discount source variant is rank-uniform (every rank runs the
        // same call), so either all ranks enter the `comm.max` or none do.
        let (local_discount, gamma_max) = match discount {
            DiscountSource::Global(d) => {
                let gmax = d.max_gamma();
                (d.slice_states(lo, hi, n_actions), gmax)
            }
            // local-size expansion: bitwise identical to slicing a global
            // constant vector, without ever building one
            DiscountSource::Constant(mode, g) => {
                (Discount::constant(mode, g, hi - lo, n_actions), g)
            }
            DiscountSource::Filler(_) => {
                let local_max = local_gammas.iter().copied().fold(0.0, f64::max);
                (Discount::PerStateAction(local_gammas), comm.max(local_max))
            }
        };
        let trans = DistCsr::assemble(comm, part, rows);
        Ok(DistMdp {
            part,
            n_actions,
            discount: local_discount,
            gamma_max,
            objective: Objective::Min,
            trans,
            costs,
        })
    }

    /// Switch the optimization sense (builder style).
    pub fn with_objective(mut self, objective: Objective) -> DistMdp {
        self.objective = objective;
        self
    }

    /// The optimization sense (min-cost or max-reward).
    pub fn objective(&self) -> Objective {
        self.objective
    }

    /// Distribute a serial MDP (each rank slices its block — including the
    /// discount vector for semi-MDPs). Collective.
    pub fn from_serial(comm: &Comm, mdp: &Mdp) -> DistMdp {
        DistMdp::try_from_fillers_discounted(
            comm,
            mdp.n_states(),
            mdp.n_actions(),
            mdp.discount().clone(),
            |s, a| {
                let (cols, vals) = mdp.transitions().row(s * mdp.n_actions() + a);
                cols.iter().copied().zip(vals.iter().copied()).collect()
            },
            |s, a| mdp.cost(s, a),
        )
        .unwrap_or_else(|e| panic!("serial MDP failed to distribute: {e}"))
        .with_objective(mdp.objective())
    }

    /// The contiguous state partition across ranks.
    pub fn partition(&self) -> Partition {
        self.part
    }

    /// Global number of states `n`.
    pub fn n_states(&self) -> usize {
        self.part.n()
    }

    /// Number of actions `m`.
    pub fn n_actions(&self) -> usize {
        self.n_actions
    }

    /// Uniform discount bound `γ̄ = max γ(s,a)` ∈ [0, 1) over the **global**
    /// MDP (agreed collectively at construction) — the contraction modulus.
    /// For classic scalar-discount MDPs this is the discount factor.
    pub fn gamma(&self) -> f64 {
        self.gamma_max
    }

    /// The rank-local discount slice (scalar, or the owned states'
    /// per-state / per-state-action entries).
    pub fn discount(&self) -> &Discount {
        &self.discount
    }

    /// Number of locally owned states.
    pub fn local_states(&self) -> usize {
        self.costs.len() / self.n_actions.max(1)
    }

    /// The rank-local block of the stacked transition matrix.
    pub fn transitions(&self) -> &DistCsr {
        &self.trans
    }

    /// Rank-local stage costs, `costs[(s − lo)·m + a]`.
    pub fn local_costs(&self) -> &[f64] {
        &self.costs
    }

    /// Buffer for Bellman backups (sized for the stacked transition SpMV).
    pub fn make_buffer(&self) -> GhostBuf {
        self.trans.make_buffer()
    }

    /// One distributed Bellman backup against the local value block:
    /// fills `tv` (local TV) and `policy` (local greedy actions); returns
    /// the **global** ∞-norm residual ‖TV − V‖∞. Collective.
    ///
    /// Cost: one ghost exchange + `m` local SpMV rows per state + one
    /// scalar allreduce — the per-iteration unit the experiments count.
    pub fn bellman_backup(
        &self,
        comm: &Comm,
        v_local: &[f64],
        tv: &mut [f64],
        policy: &mut [usize],
        buf: &mut GhostBuf,
        q_scratch: &mut Vec<f64>,
    ) -> f64 {
        let nl = self.local_states();
        assert_eq!(v_local.len(), nl);
        assert_eq!(tv.len(), nl);
        assert_eq!(policy.len(), nl);
        // q = P_stacked · v  (one exchange, m·nl local rows)
        q_scratch.resize(nl * self.n_actions, 0.0);
        self.trans.spmv(comm, v_local, q_scratch, buf);
        // Greedy improvement + residual, state-parallel over the rank's
        // worker pool: per-state action scans are serial and the chunk
        // maxima fold in fixed chunk order (max is exact anyway), so the
        // result is bitwise identical for every thread count.
        let q: &[f64] = q_scratch.as_slice();
        let m = self.n_actions;
        let disc = &self.discount;
        let local_res = crate::util::par::par_for_rows2(
            tv,
            policy,
            |offset, tv_chunk, pol_chunk| {
                let mut res = 0.0f64;
                for (i, (tvs, pols)) in tv_chunk.iter_mut().zip(pol_chunk.iter_mut()).enumerate() {
                    let s = offset + i;
                    let base = s * m;
                    let mut best = self.objective.worst();
                    let mut best_a = 0usize;
                    for a in 0..m {
                        // Scalar and a constant vector read the same f64
                        // here, so the Q-values (hence TV/policy/residual)
                        // are bitwise identical across representations.
                        let gv = match disc {
                            Discount::Scalar(g) => *g,
                            Discount::PerState(v) => v[s],
                            Discount::PerStateAction(v) => v[base + a],
                        };
                        let qv = self.costs[base + a] + gv * q[base + a];
                        if self.objective.better(qv, best) {
                            best = qv;
                            best_a = a;
                        }
                    }
                    *tvs = best;
                    *pols = best_a;
                    res = res.max((best - v_local[s]).abs());
                }
                res
            },
            f64::max,
        )
        .unwrap_or(0.0);
        comm.max(local_res)
    }

    /// One **rank-local** Bellman backup against whatever ghost values are
    /// already resident in `buf`: same greedy body as
    /// [`Self::bellman_backup`], but no ghost exchange and no residual
    /// allreduce — returns the **local** ∞-norm residual only. This is the
    /// stale sweep of bounded-staleness asynchronous VI (`-async_vi`,
    /// DESIGN.md §14): ranks iterate on their own block between certified
    /// synchronized backups, reading boundary-coupled terms at the ghost
    /// values of the last synchronization.
    ///
    /// Non-collective: safe to call a different number of times per rank,
    /// though the solver keeps the count agreed so traces stay rank-stable.
    pub fn bellman_backup_local(
        &self,
        v_local: &[f64],
        tv: &mut [f64],
        policy: &mut [usize],
        buf: &mut GhostBuf,
        q_scratch: &mut Vec<f64>,
    ) -> f64 {
        let nl = self.local_states();
        assert_eq!(v_local.len(), nl);
        assert_eq!(tv.len(), nl);
        assert_eq!(policy.len(), nl);
        // q = P_stacked · v with the *current* buffer ghosts (stale between
        // synchronizations); only the owned block is refreshed.
        q_scratch.resize(nl * self.n_actions, 0.0);
        buf.set_owned(v_local);
        self.trans.spmv_local(buf, q_scratch);
        let q: &[f64] = q_scratch.as_slice();
        let m = self.n_actions;
        let disc = &self.discount;
        crate::util::par::par_for_rows2(
            tv,
            policy,
            |offset, tv_chunk, pol_chunk| {
                let mut res = 0.0f64;
                for (i, (tvs, pols)) in tv_chunk.iter_mut().zip(pol_chunk.iter_mut()).enumerate() {
                    let s = offset + i;
                    let base = s * m;
                    let mut best = self.objective.worst();
                    let mut best_a = 0usize;
                    for a in 0..m {
                        let gv = match disc {
                            Discount::Scalar(g) => *g,
                            Discount::PerState(v) => v[s],
                            Discount::PerStateAction(v) => v[base + a],
                        };
                        let qv = self.costs[base + a] + gv * q[base + a];
                        if self.objective.better(qv, best) {
                            best = qv;
                            best_a = a;
                        }
                    }
                    *tvs = best;
                    *pols = best_a;
                    res = res.max((best - v_local[s]).abs());
                }
                res
            },
            f64::max,
        )
        .unwrap_or(0.0)
    }

    /// Rank-local policy costs `g_π` (the RHS of the evaluation system) —
    /// the matrix-free counterpart of [`Self::policy_system`]'s second
    /// return: no matrix assembly, no communication.
    pub fn policy_costs(&self, policy: &[usize]) -> Vec<f64> {
        let nl = self.local_states();
        assert_eq!(policy.len(), nl);
        (0..nl)
            .map(|s| {
                let a = policy[s];
                debug_assert!(a < self.n_actions);
                self.costs[s * self.n_actions + a]
            })
            .collect()
    }

    /// Rank-local per-state discounts `γ_π` under a fixed policy — the
    /// diagonal of `diag(γ_π)` in the evaluation system
    /// `(I − diag(γ_π) P_π) V = g_π`. `None` for scalar discounting (the
    /// assembled operator then takes the classic `I − γ P_π` path).
    pub fn policy_discounts(&self, policy: &[usize]) -> Option<Vec<f64>> {
        debug_assert_eq!(policy.len(), self.local_states());
        self.discount.policy_rows(policy, self.n_actions)
    }

    /// Extract the distributed policy system `(P_π, g_π)` for the current
    /// local policy. Collective (builds a fresh ghost plan).
    pub fn policy_system(&self, comm: &Comm, policy: &[usize]) -> (DistCsr, Vec<f64>) {
        let nl = self.local_states();
        assert_eq!(policy.len(), nl);
        let local = self.trans.local();
        let mut rows = Vec::with_capacity(nl);
        let mut g = Vec::with_capacity(nl);
        for s in 0..nl {
            let a = policy[s];
            debug_assert!(a < self.n_actions);
            let (cols, vals) = local.row(s * self.n_actions + a);
            // translate remapped columns back to global ids
            let row: Vec<(usize, f64)> = cols
                .iter()
                .map(|&c| self.trans.global_col(c))
                .zip(vals.iter().copied())
                .collect();
            rows.push(row);
            g.push(self.costs[s * self.n_actions + a]);
        }
        let p_pi = DistCsr::assemble(comm, self.part, rows);
        (p_pi, g)
    }

    /// Local storage bytes (matrix block + costs + discount entries).
    pub fn storage_bytes(&self) -> usize {
        let disc = self.discount.entries().map_or(0, |v| v.len() * 8);
        self.trans.local().storage_bytes() + self.costs.len() * 8 + disc
    }
}

#[cfg(test)]
pub(crate) mod fixtures {
    //! Shared MDP fixtures for tests across modules.
    use super::*;
    use crate::util::prng::Xoshiro256pp;

    /// Two-state analytic MDP (DESIGN §9): from state 0, action 0 stays
    /// (cost 1), action 1 jumps to the absorbing state 1 (cost c); state 1
    /// self-loops with cost 0. V*(1)=0 and V*(0) = min(1/(1−γ), c).
    pub fn two_state(gamma: f64, c: f64) -> Mdp {
        Mdp::from_fillers(
            2,
            2,
            gamma,
            |s, a| match (s, a) {
                (0, 0) => vec![(0, 1.0)],
                (0, 1) => vec![(1, 1.0)],
                (1, _) => vec![(1, 1.0)],
                _ => unreachable!(),
            },
            |s, a| match (s, a) {
                (0, 0) => 1.0,
                (0, 1) => c,
                (1, _) => 0.0,
                _ => unreachable!(),
            },
        )
    }

    /// Random sparse MDP, deterministic in `seed`.
    pub fn random_mdp(seed: u64, n: usize, m: usize, gamma: f64) -> Mdp {
        Mdp::from_fillers(
            n,
            m,
            gamma,
            move |s, a| {
                let mut rng = Xoshiro256pp::new(seed ^ ((s * 131 + a) as u64));
                let k = 1 + rng.index(3.min(n));
                let targets: Vec<usize> = (0..k).map(|_| rng.index(n)).collect();
                let probs = rng.prob_vector(k);
                targets.into_iter().zip(probs).collect()
            },
            move |s, a| {
                let mut rng = Xoshiro256pp::new(seed ^ 0xC0 ^ ((s * 131 + a) as u64));
                rng.next_f64()
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::fixtures::{random_mdp, two_state};
    use super::*;
    use crate::comm::World;
    use crate::util::prop;
    use std::sync::Arc;

    #[test]
    fn validation_rejects_bad_inputs() {
        let t = Csr::from_triplets(2, 2, &[(0, 0, 1.0), (1, 1, 1.0)]);
        // wrong row count for n=2, m=2 (needs 4 rows)
        assert!(Mdp::new(2, 2, t.clone(), vec![0.0; 4], 0.9).is_err());
        // gamma out of range
        let t4 = Csr::from_triplets(
            4,
            2,
            &[(0, 0, 1.0), (1, 1, 1.0), (2, 0, 1.0), (3, 1, 1.0)],
        );
        assert!(Mdp::new(2, 2, t4.clone(), vec![0.0; 4], 1.0).is_err());
        assert!(Mdp::new(2, 2, t4.clone(), vec![0.0; 4], 0.9).is_ok());
        // non-stochastic
        let bad = Csr::from_triplets(
            4,
            2,
            &[(0, 0, 0.7), (1, 1, 1.0), (2, 0, 1.0), (3, 1, 1.0)],
        );
        assert!(Mdp::new(2, 2, bad, vec![0.0; 4], 0.9).is_err());
        // non-finite cost
        assert!(Mdp::new(2, 2, t4, vec![0.0, f64::NAN, 0.0, 0.0], 0.9).is_err());
    }

    #[test]
    fn patch_costs_touched_entries_only() {
        let mut mdp = two_state(0.5, 1.5);
        mdp.patch_costs(&[(0, 1, 3.0)]).unwrap();
        assert_eq!(mdp.cost(0, 1), 3.0);
        assert_eq!(mdp.cost(0, 0), 1.0, "untouched costs must survive");
        // patched model solves like one built with the new cost: with
        // c=3 > 1/(1−γ)=2, staying forever is optimal.
        let (tv, pol) = mdp.bellman(&[2.0, 0.0]);
        prop::close_slices(&tv, &[2.0, 0.0], 1e-12).unwrap();
        assert_eq!(pol[0], 0);
        // bad patches are typed errors naming the pair, applied atomically
        let err = mdp.patch_costs(&[(0, 0, 0.5), (2, 0, 1.0)]).unwrap_err();
        assert!(err.contains("s=2") && err.contains("out of range"), "{err}");
        assert_eq!(mdp.cost(0, 0), 1.0, "failed batch must not half-apply");
        let err = mdp.patch_costs(&[(1, 0, f64::NAN)]).unwrap_err();
        assert!(err.contains("non-finite"), "{err}");
    }

    #[test]
    fn patch_transitions_revalidates_touched_rows() {
        let mut mdp = two_state(0.5, 1.5);
        // re-route (0, 1): jump home becomes a lazy 50/50 jump
        mdp.patch_transitions(&[(0, 1, vec![(0, 0.5), (1, 0.5)])])
            .unwrap();
        let (cols, vals) = mdp.transitions().row(1);
        assert_eq!(cols, &[0, 1]);
        assert_eq!(vals, &[0.5, 0.5]);
        // untouched rows intact
        assert_eq!(mdp.transitions().row(0).0, &[0]);
        // sub-stochastic rows are rejected with the (s, a) pair named
        let err = mdp
            .patch_transitions(&[(1, 0, vec![(0, 0.4)])])
            .unwrap_err();
        assert!(err.contains("s=1") && err.contains("sums to"), "{err}");
        // out-of-range targets too
        let err = mdp
            .patch_transitions(&[(0, 0, vec![(5, 1.0)])])
            .unwrap_err();
        assert!(err.contains("n_states"), "{err}");
        // unsorted duplicate input is normalized like the builders do
        mdp.patch_transitions(&[(1, 1, vec![(1, 0.25), (0, 0.5), (1, 0.25)])])
            .unwrap();
        let (cols, vals) = mdp.transitions().row(3);
        assert_eq!(cols, &[0, 1]);
        assert_eq!(vals, &[0.5, 0.5]);
    }

    #[test]
    fn bellman_two_state_analytic() {
        // γ=0.5 → 1/(1−γ)=2; with c=1.5 the jump is optimal.
        let mdp = two_state(0.5, 1.5);
        let (tv, pol) = mdp.bellman(&[1.5, 0.0]);
        prop::close_slices(&tv, &[1.5, 0.0], 1e-12).unwrap();
        assert_eq!(pol, vec![1, 0]);
        // with c=3 staying forever is optimal: V*(0)=2
        let mdp2 = two_state(0.5, 3.0);
        let (tv2, pol2) = mdp2.bellman(&[2.0, 0.0]);
        prop::close_slices(&tv2, &[2.0, 0.0], 1e-12).unwrap();
        assert_eq!(pol2[0], 0);
    }

    #[test]
    fn q_value_definition() {
        let mdp = two_state(0.9, 2.0);
        let v = vec![10.0, 20.0];
        assert!((mdp.q_value(0, 0, &v) - (1.0 + 0.9 * 10.0)).abs() < 1e-12);
        assert!((mdp.q_value(0, 1, &v) - (2.0 + 0.9 * 20.0)).abs() < 1e-12);
    }

    #[test]
    fn policy_system_extraction() {
        let mdp = two_state(0.9, 2.0);
        let (p, g) = mdp.policy_system(&[1, 0]);
        assert_eq!(p.nrows(), 2);
        assert_eq!(p.get(0, 1), 1.0); // action 1 from state 0 → state 1
        assert_eq!(g, vec![2.0, 0.0]);
    }

    #[test]
    fn exact_policy_evaluation_geometric_series() {
        let mdp = two_state(0.5, 2.0);
        // policy "always stay": V(0) = 1/(1−γ) = 2
        let v = mdp.evaluate_policy_exact(&[0, 0]);
        prop::close_slices(&v, &[2.0, 0.0], 1e-12).unwrap();
    }

    #[test]
    fn bellman_is_contraction() {
        prop::forall("T is a γ-contraction in ∞-norm", |rng| {
            let n = 2 + rng.index(10);
            let m = 1 + rng.index(4);
            let gamma = rng.range_f64(0.1, 0.99);
            let mdp = random_mdp(rng.next_u64(), n, m, gamma);
            let u: Vec<f64> = (0..n).map(|_| rng.range_f64(-5.0, 5.0)).collect();
            let w: Vec<f64> = (0..n).map(|_| rng.range_f64(-5.0, 5.0)).collect();
            let (tu, _) = mdp.bellman(&u);
            let (tw, _) = mdp.bellman(&w);
            let lhs = tu
                .iter()
                .zip(&tw)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            let rhs = u
                .iter()
                .zip(&w)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            crate::prop_assert!(
                lhs <= gamma * rhs + 1e-10,
                "‖Tu−Tw‖={lhs} > γ‖u−w‖={}",
                gamma * rhs
            );
            Ok(())
        });
    }

    #[test]
    fn dist_bellman_matches_serial() {
        for size in [1usize, 2, 3] {
            let mdp = Arc::new(random_mdp(77, 23, 3, 0.9));
            let mdp2 = Arc::clone(&mdp);
            let out = World::run(size, move |comm| {
                let d = DistMdp::from_serial(&comm, &mdp2);
                let part = d.partition();
                let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
                let v: Vec<f64> = (lo..hi).map(|i| (i as f64).sin()).collect();
                let mut tv = vec![0.0; hi - lo];
                let mut pol = vec![0usize; hi - lo];
                let mut buf = d.make_buffer();
                let mut q = Vec::new();
                let res = d.bellman_backup(&comm, &v, &mut tv, &mut pol, &mut buf, &mut q);
                (tv, pol, res)
            });
            let v_full: Vec<f64> = (0..23).map(|i| (i as f64).sin()).collect();
            let (tv_serial, pol_serial) = mdp.bellman(&v_full);
            let res_serial = mdp.bellman_residual(&v_full);
            let tv_dist: Vec<f64> = out.iter().flat_map(|(tv, _, _)| tv.clone()).collect();
            let pol_dist: Vec<usize> = out.iter().flat_map(|(_, p, _)| p.clone()).collect();
            prop::close_slices(&tv_dist, &tv_serial, 1e-12).unwrap();
            assert_eq!(pol_dist, pol_serial, "size={size}");
            for (_, _, r) in &out {
                assert!((r - res_serial).abs() < 1e-12);
            }
        }
    }

    /// With ghosts freshly exchanged, one local sweep is bitwise identical
    /// to the synchronized backup (same kernel, same fold order); its
    /// local residuals max-reduce to the collective residual.
    #[test]
    fn local_backup_matches_sync_when_ghosts_fresh() {
        for size in [1usize, 2, 3] {
            let mdp = Arc::new(random_mdp(78, 23, 3, 0.9));
            World::run(size, move |comm| {
                let d = DistMdp::from_serial(&comm, &mdp);
                let part = d.partition();
                let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
                let v: Vec<f64> = (lo..hi).map(|i| (i as f64).sin()).collect();
                let nl = hi - lo;
                let (mut tv_s, mut pol_s) = (vec![0.0; nl], vec![0usize; nl]);
                let (mut tv_l, mut pol_l) = (vec![0.0; nl], vec![0usize; nl]);
                let mut buf = d.make_buffer();
                let mut q = Vec::new();
                let res_sync = d.bellman_backup(&comm, &v, &mut tv_s, &mut pol_s, &mut buf, &mut q);
                // `buf` now holds fresh ghosts for `v`; the local sweep
                // must reproduce the synchronized backup exactly.
                let res_local =
                    d.bellman_backup_local(&v, &mut tv_l, &mut pol_l, &mut buf, &mut q);
                assert_eq!(tv_s, tv_l, "size={size}");
                assert_eq!(pol_s, pol_l);
                assert_eq!(comm.max(res_local), res_sync);
            });
        }
    }

    #[test]
    fn dist_policy_system_matches_serial() {
        let mdp = Arc::new(random_mdp(5, 17, 2, 0.95));
        let mdp2 = Arc::clone(&mdp);
        let out = World::run(3, move |comm| {
            let d = DistMdp::from_serial(&comm, &mdp2);
            let part = d.partition();
            let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
            let pol: Vec<usize> = (lo..hi).map(|s| s % 2).collect();
            let (p_pi, g_pi) = d.policy_system(&comm, &pol);
            let x: Vec<f64> = (lo..hi).map(|i| i as f64).collect();
            let mut buf = p_pi.make_buffer();
            let mut y = vec![0.0; hi - lo];
            p_pi.spmv(&comm, &x, &mut y, &mut buf);
            (y, g_pi)
        });
        let pol_full: Vec<usize> = (0..17).map(|s| s % 2).collect();
        let (p_serial, g_serial) = mdp.policy_system(&pol_full);
        let x_full: Vec<f64> = (0..17).map(|i| i as f64).collect();
        let y_serial = p_serial.mul_vec(&x_full);
        let y_dist: Vec<f64> = out.iter().flat_map(|(y, _)| y.clone()).collect();
        let g_dist: Vec<f64> = out.iter().flat_map(|(_, g)| g.clone()).collect();
        prop::close_slices(&y_dist, &y_serial, 1e-12).unwrap();
        prop::close_slices(&g_dist, &g_serial, 1e-12).unwrap();
    }

    #[test]
    fn storage_accounting_positive() {
        let mdp = random_mdp(1, 10, 2, 0.9);
        assert!(mdp.storage_bytes() > 10 * 2 * 8);
    }
}
