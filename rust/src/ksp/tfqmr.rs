//! TFQMR — transpose-free quasi-minimal residual (Freund 1993).
//!
//! Like BiCGStab a short-recurrence two-SpMV-per-iteration method, but with
//! a quasi-minimization that smooths the residual history — useful on the
//! badly conditioned `γ → 1` instances where BiCGStab's residual can
//! oscillate. Unpreconditioned (madupite exposes it the same way through
//! PETSc; preconditioned TFQMR adds little for these systems).

use super::{Apply, KspStats, Tolerance};
use crate::comm::Comm;
use crate::linalg::dist::{dist_dot, dist_norm2};

/// Solve `A x = b` with TFQMR. `x` carries the warm start.
///
/// The quasi-residual recurrence can desynchronize from the true residual
/// in finite precision (stagnation around 1e-9 on ill-conditioned γ→1
/// systems); `solve` therefore runs Freund cycles and **restarts** from the
/// current iterate when a cycle ends by breakdown or stagnation, up to the
/// iteration budget. This mirrors how PETSc users wrap `-ksp_type tfqmr`
/// in practice.
pub fn solve(comm: &Comm, a: &dyn Apply, b: &[f64], x: &mut [f64], tol: &Tolerance) -> KspStats {
    let nl = a.local_rows();
    assert_eq!(b.len(), nl);
    assert_eq!(x.len(), nl);
    let mut buf = a.make_buffer();
    let mut stats = KspStats::default();
    let mut r = vec![0.0; nl];

    let r0norm = a.residual(comm, b, x, &mut r, &mut buf);
    stats.spmvs += 1;
    stats.initial_residual = r0norm;
    let target = tol.threshold(r0norm);
    let mut rnorm = r0norm;

    while rnorm > target && stats.iterations < tol.max_iters {
        let before = rnorm;
        rnorm = cycle(comm, a, b, x, target, tol.max_iters, &mut stats, &mut r, &mut buf);
        if rnorm > before * 0.9 {
            break; // stagnated: < 10% improvement over a whole cycle
        }
    }
    stats.final_residual = rnorm;
    stats.converged = rnorm <= target;
    stats
}

/// One Freund TFQMR cycle starting from the current `x`. Returns the true
/// residual norm at exit; mutates `x` and accumulates `stats`.
#[allow(clippy::too_many_arguments)]
fn cycle(
    comm: &Comm,
    a: &dyn Apply,
    b: &[f64],
    x: &mut [f64],
    target: f64,
    max_iters: usize,
    stats: &mut KspStats,
    r: &mut [f64],
    buf: &mut crate::linalg::dist::GhostBuf,
) -> f64 {
    let nl = a.local_rows();
    let r0norm = a.residual(comm, b, x, r, buf);
    stats.spmvs += 1;
    if r0norm <= target {
        return r0norm;
    }

    let rtilde = r.to_vec();
    let mut w = r.to_vec();
    let mut y1 = r.to_vec();
    let mut d = vec![0.0; nl];
    let mut v = vec![0.0; nl];
    a.apply(comm, &y1, &mut v, buf);
    stats.spmvs += 1;
    let mut u1 = v.clone();
    let mut y2 = vec![0.0; nl];
    let mut u2 = vec![0.0; nl];

    let mut tau = r0norm;
    let mut theta = 0.0f64;
    let mut eta = 0.0f64;
    let mut rho = tau * tau;

    while stats.iterations < max_iters {
        stats.iterations += 1;
        let sigma = dist_dot(comm, &rtilde, &v);
        if sigma.abs() < 1e-300 {
            break; // serious breakdown → restart decision in solve()
        }
        let alpha = rho / sigma;
        for i in 0..nl {
            y2[i] = y1[i] - alpha * v[i];
        }
        a.apply(comm, &y2, &mut u2, buf);
        stats.spmvs += 1;

        let mut done = false;
        for half in 0..2 {
            let (yj, uj): (&[f64], &[f64]) = if half == 0 { (&y1, &u1) } else { (&y2, &u2) };
            for i in 0..nl {
                w[i] -= alpha * uj[i];
            }
            let theta_old = theta;
            let eta_old = eta;
            if tau < 1e-300 {
                done = true; // τ-breakdown: at machine zero
                break;
            }
            let wnorm = dist_norm2(comm, &w);
            theta = wnorm / tau;
            let c = 1.0 / (1.0 + theta * theta).sqrt();
            tau *= theta * c;
            eta = c * c * alpha;
            let factor = theta_old * theta_old * eta_old / alpha;
            if !factor.is_finite() || !eta.is_finite() || !tau.is_finite() {
                done = true; // numerical breakdown
                break;
            }
            for i in 0..nl {
                d[i] = yj[i] + factor * d[i];
                x[i] += eta * d[i];
            }
            // cheap quasi-residual bound τ·sqrt(m+1) triggers a true check
            let m_idx = 2 * stats.iterations - 1 + half;
            if tau * ((m_idx + 1) as f64).sqrt() <= target {
                let true_norm = a.residual(comm, b, x, r, buf);
                stats.spmvs += 1;
                if true_norm <= target {
                    return true_norm;
                }
            }
        }
        if done {
            break;
        }

        let rho_new = dist_dot(comm, &rtilde, &w);
        if rho.abs() < 1e-300 || rho_new.abs() < 1e-300 {
            break;
        }
        let beta = rho_new / rho;
        rho = rho_new;
        for i in 0..nl {
            y1[i] = w[i] + beta * y2[i];
        }
        a.apply(comm, &y1, &mut u1, buf);
        stats.spmvs += 1;
        for i in 0..nl {
            v[i] = u1[i] + beta * (u2[i] + beta * v[i]);
        }
    }

    let out = a.residual(comm, b, x, r, buf);
    stats.spmvs += 1;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;
    use crate::ksp::testmat::random_policy_system;
    use crate::ksp::{LinOp, Precond};
    use crate::util::prop;

    fn run(n: usize, size: usize, gamma: f64) -> Vec<f64> {
        let out = World::run(size, move |comm| {
            let (p, b, part) = random_policy_system(&comm, n, 42);
            let a = LinOp::new(&p, gamma);
            let nl = part.local_len(comm.rank());
            let mut x = vec![0.0; nl];
            let tol = Tolerance {
                atol: 1e-10,
                rtol: 0.0,
                max_iters: 5_000,
            };
            let stats = solve(&comm, &a, &b, &mut x, &tol);
            assert!(
                stats.converged,
                "tfqmr not converged: final={}",
                stats.final_residual
            );
            x
        });
        out.into_iter().flatten().collect()
    }

    #[test]
    fn solves_serial() {
        let x = run(30, 1, 0.9);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn distributed_matches_serial() {
        let xs = run(40, 1, 0.95);
        let xd = run(40, 3, 0.95);
        prop::close_slices(&xs, &xd, 1e-6).unwrap();
    }

    #[test]
    fn agrees_with_gmres_solution() {
        let xt = run(35, 1, 0.99);
        let out = World::run(1, |comm| {
            let (p, b, _) = random_policy_system(&comm, 35, 42);
            let a = LinOp::new(&p, 0.99);
            let mut x = vec![0.0; 35];
            let tol = Tolerance {
                atol: 1e-10,
                rtol: 0.0,
                max_iters: 5_000,
            };
            crate::ksp::gmres::solve(&comm, &a, &Precond::None, &b, &mut x, &tol, 30);
            x
        });
        let xg: Vec<f64> = out.into_iter().flatten().collect();
        prop::close_slices(&xt, &xg, 1e-5).unwrap();
    }

    #[test]
    fn high_gamma_converges() {
        let x = run(50, 2, 0.999);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn warm_start_immediate() {
        World::run(1, |comm| {
            let (p, b, _) = random_policy_system(&comm, 15, 5);
            let a = LinOp::new(&p, 0.9);
            let tol = Tolerance {
                atol: 1e-9,
                rtol: 0.0,
                max_iters: 1_000,
            };
            let mut x = vec![0.0; 15];
            solve(&comm, &a, &b, &mut x, &tol);
            let mut x2 = x.clone();
            let s2 = solve(&comm, &a, &b, &mut x2, &tol);
            assert_eq!(s2.iterations, 0);
        });
    }
}
