//! TFQMR — transpose-free quasi-minimal residual (Freund 1993),
//! right-preconditioned.
//!
//! Like BiCGStab a short-recurrence two-SpMV-per-iteration method, but with
//! a quasi-minimization that smooths the residual history — useful on the
//! badly conditioned `γ → 1` instances where BiCGStab's residual can
//! oscillate. The preconditioner is applied on the right (the Krylov
//! recurrences run on `A M⁻¹`), so residual norms remain true residuals of
//! the original system and the stopping tests need no translation; the
//! iterate update applies `M⁻¹` to the direction vector
//! (`x ← x + η M⁻¹ d`), keeping `x` in the unpreconditioned space.

use super::{Apply, KspStats, Precond, Tolerance};
use crate::comm::Comm;
use crate::linalg::dist::{dist_dot, dist_norm2, GhostBuf};

/// y ← A M⁻¹ x: one right-preconditioned operator application.
fn apply_op(
    comm: &Comm,
    a: &dyn Apply,
    pc: &Precond,
    x: &[f64],
    tmp: &mut [f64],
    y: &mut [f64],
    buf: &mut GhostBuf,
) {
    if pc.is_identity() {
        a.apply(comm, x, y, buf);
    } else {
        pc.apply(x, tmp);
        a.apply(comm, tmp, y, buf);
    }
}

/// Solve `A x = b` with preconditioned TFQMR. `x` carries the warm start.
///
/// The quasi-residual recurrence can desynchronize from the true residual
/// in finite precision (stagnation around 1e-9 on ill-conditioned γ→1
/// systems); `solve` therefore runs Freund cycles and **restarts** from the
/// current iterate when a cycle ends by breakdown or stagnation, up to the
/// iteration budget. This mirrors how PETSc users wrap `-ksp_type tfqmr`
/// in practice.
pub fn solve(
    comm: &Comm,
    a: &dyn Apply,
    pc: &Precond,
    b: &[f64],
    x: &mut [f64],
    tol: &Tolerance,
) -> KspStats {
    let nl = a.local_rows();
    assert_eq!(b.len(), nl);
    assert_eq!(x.len(), nl);
    let mut buf = a.make_buffer();
    let mut stats = KspStats::default();
    let mut r = vec![0.0; nl];

    let r0norm = a.residual(comm, b, x, &mut r, &mut buf);
    stats.spmvs += 1;
    stats.initial_residual = r0norm;
    let target = tol.threshold(r0norm);
    let mut rnorm = r0norm;

    while rnorm > target && stats.iterations < tol.max_iters {
        let before = rnorm;
        rnorm = cycle(comm, a, pc, b, x, target, tol.max_iters, &mut stats, &mut r, &mut buf);
        if rnorm > before * 0.9 {
            break; // stagnated: < 10% improvement over a whole cycle
        }
    }
    stats.final_residual = rnorm;
    stats.converged = rnorm <= target;
    stats
}

/// One Freund TFQMR cycle starting from the current `x`. Returns the true
/// residual norm at exit; mutates `x` and accumulates `stats`.
#[allow(clippy::too_many_arguments)]
fn cycle(
    comm: &Comm,
    a: &dyn Apply,
    pc: &Precond,
    b: &[f64],
    x: &mut [f64],
    target: f64,
    max_iters: usize,
    stats: &mut KspStats,
    r: &mut [f64],
    buf: &mut GhostBuf,
) -> f64 {
    let nl = a.local_rows();
    let r0norm = a.residual(comm, b, x, r, buf);
    stats.spmvs += 1;
    if r0norm <= target {
        return r0norm;
    }

    let rtilde = r.to_vec();
    let mut w = r.to_vec();
    let mut y1 = r.to_vec();
    let mut d = vec![0.0; nl];
    let mut v = vec![0.0; nl];
    let mut tmp = vec![0.0; nl];
    apply_op(comm, a, pc, &y1, &mut tmp, &mut v, buf);
    stats.spmvs += 1;
    let mut u1 = v.clone();
    let mut y2 = vec![0.0; nl];
    let mut u2 = vec![0.0; nl];

    let mut tau = r0norm;
    let mut theta = 0.0f64;
    let mut eta = 0.0f64;
    let mut rho = tau * tau;

    while stats.iterations < max_iters {
        stats.iterations += 1;
        let sigma = dist_dot(comm, &rtilde, &v);
        if sigma.abs() < 1e-300 {
            break; // serious breakdown → restart decision in solve()
        }
        let alpha = rho / sigma;
        for i in 0..nl {
            y2[i] = y1[i] - alpha * v[i];
        }
        apply_op(comm, a, pc, &y2, &mut tmp, &mut u2, buf);
        stats.spmvs += 1;

        let mut done = false;
        for half in 0..2 {
            let (yj, uj): (&[f64], &[f64]) = if half == 0 { (&y1, &u1) } else { (&y2, &u2) };
            for i in 0..nl {
                w[i] -= alpha * uj[i];
            }
            let theta_old = theta;
            let eta_old = eta;
            if tau < 1e-300 {
                done = true; // τ-breakdown: at machine zero
                break;
            }
            let wnorm = dist_norm2(comm, &w);
            theta = wnorm / tau;
            let c = 1.0 / (1.0 + theta * theta).sqrt();
            tau *= theta * c;
            eta = c * c * alpha;
            let factor = theta_old * theta_old * eta_old / alpha;
            if !factor.is_finite() || !eta.is_finite() || !tau.is_finite() {
                done = true; // numerical breakdown
                break;
            }
            for i in 0..nl {
                d[i] = yj[i] + factor * d[i];
            }
            // x lives in the unpreconditioned space: x ← x + η M⁻¹ d
            if pc.is_identity() {
                for i in 0..nl {
                    x[i] += eta * d[i];
                }
            } else {
                pc.apply(&d, &mut tmp);
                for i in 0..nl {
                    x[i] += eta * tmp[i];
                }
            }
            // cheap quasi-residual bound τ·sqrt(m+1) triggers a true check
            let m_idx = 2 * stats.iterations - 1 + half;
            if tau * ((m_idx + 1) as f64).sqrt() <= target {
                let true_norm = a.residual(comm, b, x, r, buf);
                stats.spmvs += 1;
                if true_norm <= target {
                    return true_norm;
                }
            }
        }
        if done {
            break;
        }

        let rho_new = dist_dot(comm, &rtilde, &w);
        if rho.abs() < 1e-300 || rho_new.abs() < 1e-300 {
            break;
        }
        let beta = rho_new / rho;
        rho = rho_new;
        for i in 0..nl {
            y1[i] = w[i] + beta * y2[i];
        }
        apply_op(comm, a, pc, &y1, &mut tmp, &mut u1, buf);
        stats.spmvs += 1;
        for i in 0..nl {
            v[i] = u1[i] + beta * (u2[i] + beta * v[i]);
        }
    }

    let out = a.residual(comm, b, x, r, buf);
    stats.spmvs += 1;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;
    use crate::ksp::precond::PcType;
    use crate::ksp::testmat::random_policy_system;
    use crate::ksp::{LinOp, Precond};
    use crate::util::prop;

    fn run_pc(n: usize, size: usize, gamma: f64, pc_type: PcType) -> Vec<f64> {
        let out = World::run(size, move |comm| {
            let (p, b, part) = random_policy_system(&comm, n, 42);
            let a = LinOp::new(&p, gamma);
            let pc = Precond::build(pc_type, &a);
            let nl = part.local_len(comm.rank());
            let mut x = vec![0.0; nl];
            let tol = Tolerance {
                atol: 1e-10,
                rtol: 0.0,
                max_iters: 5_000,
            };
            let stats = solve(&comm, &a, &pc, &b, &mut x, &tol);
            assert!(
                stats.converged,
                "tfqmr not converged: final={}",
                stats.final_residual
            );
            x
        });
        out.into_iter().flatten().collect()
    }

    fn run(n: usize, size: usize, gamma: f64) -> Vec<f64> {
        run_pc(n, size, gamma, PcType::None)
    }

    #[test]
    fn solves_serial() {
        let x = run(30, 1, 0.9);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn distributed_matches_serial() {
        let xs = run(40, 1, 0.95);
        let xd = run(40, 3, 0.95);
        prop::close_slices(&xs, &xd, 1e-6).unwrap();
    }

    #[test]
    fn agrees_with_gmres_solution() {
        let xt = run(35, 1, 0.99);
        let out = World::run(1, |comm| {
            let (p, b, _) = random_policy_system(&comm, 35, 42);
            let a = LinOp::new(&p, 0.99);
            let mut x = vec![0.0; 35];
            let tol = Tolerance {
                atol: 1e-10,
                rtol: 0.0,
                max_iters: 5_000,
            };
            crate::ksp::gmres::solve(&comm, &a, &Precond::None, &b, &mut x, &tol, 30);
            x
        });
        let xg: Vec<f64> = out.into_iter().flatten().collect();
        prop::close_slices(&xt, &xg, 1e-5).unwrap();
    }

    #[test]
    fn high_gamma_converges() {
        let x = run(50, 2, 0.999);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn warm_start_immediate() {
        World::run(1, |comm| {
            let (p, b, _) = random_policy_system(&comm, 15, 5);
            let a = LinOp::new(&p, 0.9);
            let tol = Tolerance {
                atol: 1e-9,
                rtol: 0.0,
                max_iters: 1_000,
            };
            let mut x = vec![0.0; 15];
            solve(&comm, &a, &Precond::None, &b, &mut x, &tol);
            let mut x2 = x.clone();
            let s2 = solve(&comm, &a, &Precond::None, &b, &mut x2, &tol);
            assert_eq!(s2.iterations, 0);
        });
    }

    #[test]
    fn jacobi_preconditioned_matches_unpreconditioned_solution() {
        let xp = run_pc(30, 2, 0.95, PcType::Jacobi);
        let xu = run_pc(30, 2, 0.95, PcType::None);
        prop::close_slices(&xp, &xu, 1e-6).unwrap();
    }

    #[test]
    fn preconditioner_is_wired_through() {
        // Regression: the KSP dispatcher used to call TFQMR without the
        // Precond, so `-ksp_type tfqmr -pc_type jacobi` silently ran
        // unpreconditioned. On a diagonal system A = diag(1 − γ p_i) with
        // well-spread entries, Jacobi makes A·M⁻¹ the exact identity and
        // TFQMR must converge in one iteration; unpreconditioned it needs
        // many. Were the pc dropped again, both counts would be equal.
        World::run(1, |comm| {
            let n = 40;
            let gamma = 0.99;
            let part = crate::linalg::dist::Partition::new(n, 1);
            let diag: Vec<f64> = (0..n)
                .map(|i| 0.05 + 0.9 * (i as f64) / (n as f64 - 1.0))
                .collect();
            let rows: Vec<Vec<(usize, f64)>> =
                diag.iter().enumerate().map(|(i, &p)| vec![(i, p)]).collect();
            let p = crate::linalg::dist::DistCsr::assemble(&comm, part, rows);
            let a = LinOp::new(&p, gamma);
            let b: Vec<f64> = (0..n).map(|i| 1.0 + i as f64).collect();
            let tol = Tolerance {
                atol: 1e-10,
                rtol: 0.0,
                max_iters: 1_000,
            };

            let pc = Precond::build(PcType::Jacobi, &a);
            let mut xp = vec![0.0; n];
            let sp = solve(&comm, &a, &pc, &b, &mut xp, &tol);
            let mut xu = vec![0.0; n];
            let su = solve(&comm, &a, &Precond::None, &b, &mut xu, &tol);
            assert!(sp.converged && su.converged);

            // analytic solution of the diagonal system
            let want: Vec<f64> = (0..n).map(|i| b[i] / (1.0 - gamma * diag[i])).collect();
            prop::close_slices(&xp, &want, 1e-6).unwrap();
            prop::close_slices(&xu, &want, 1e-6).unwrap();

            assert!(
                sp.iterations <= 2,
                "A·M⁻¹ = I must converge immediately, took {}",
                sp.iterations
            );
            assert!(
                sp.iterations < su.iterations,
                "jacobi tfqmr took {} iterations vs {} unpreconditioned — \
                 the preconditioner is not being applied",
                sp.iterations,
                su.iterations
            );
        });
    }
}
