//! Mixed-precision inner solves: f32 Krylov iterations inside an f64
//! iterative-refinement loop (`-inner_precision f32`).
//!
//! Classic refinement, specialized to the policy-evaluation system. The
//! expensive Krylov iterations run on a compressed single-precision copy
//! `A₃₂` of the operator ([`crate::mdp::F32PolicyOp`] — half the bytes
//! per nonzero on the bandwidth-bound apply), while every accepted step
//! is certified against the full-precision operator `A₆₄`:
//!
//! ```text
//! r ← b − A₆₄ x                (f64 residual)
//! repeat: solve A₃₂ d = r      (f32 storage, f64 accumulation)
//!         x ← x + d
//!         r ← b − A₆₄ x        (f64 residual, the convergence measure)
//! ```
//!
//! Error bound: one inner solve leaves a true residual of order
//! `ε₃₂·κ(A)·‖r‖` (the f32 representation error of the matrix acting on
//! the current correction), so each pass contracts the f64 residual by
//! roughly `ε₃₂·κ(A) ≈ 1e-7·κ(A)` until it either meets the target or
//! stalls at the f64 rounding floor. For the diagonally dominant policy
//! systems here (`κ` modest, bounded via `1/(1−γ̄)`), two to three passes
//! reach `atol = 1e-10` comfortably; the loop is capped at
//! [`MAX_REFINE_PASSES`] and exits early on stagnation. The reported
//! [`KspStats::final_residual`] is always the **f64** residual — the
//! outer iPI certificate never sees single precision (DESIGN.md §13).

use super::{Apply, KspStats, KspType, Precond, Tolerance};
use crate::comm::Comm;

/// Refinement-pass cap: each pass contracts the residual by ~`ε₃₂·κ(A)`,
/// so well-conditioned systems need 2–3; hitting the cap means the f32
/// floor sits above the requested tolerance and more passes cannot help.
pub const MAX_REFINE_PASSES: usize = 8;

/// A pass must shrink the f64 residual below this fraction of the
/// previous one to continue; anything slower is stagnation at the f32
/// floor and the loop exits with the best certified iterate.
const STAGNATION_FACTOR: f64 = 0.9;

/// Solve `A₆₄ x = b` to the f64 tolerance `tol`, running the inner
/// Krylov method on `a32`. `x` holds the warm start on entry and the
/// refined solution on exit. Collective across the world.
///
/// `a32` must be (an approximation of) the same linear map as `a64` —
/// the refinement loop converges at a rate governed by how close; see the
/// module docs for the bound. Iteration/spmv counts accumulate across
/// passes, with the f64 residual recomputations counted as spmvs.
pub fn solve_mixed(
    method: &KspType,
    pc: &Precond,
    comm: &Comm,
    a64: &dyn Apply,
    a32: &dyn Apply,
    b: &[f64],
    x: &mut [f64],
    tol: &Tolerance,
) -> KspStats {
    let nl = a64.local_rows();
    assert_eq!(b.len(), nl);
    assert_eq!(x.len(), nl);
    let mut buf = a64.make_buffer();
    let mut r = vec![0.0; nl];
    let mut rnorm = a64.residual(comm, b, x, &mut r, &mut buf);
    let mut stats = KspStats {
        iterations: 0,
        spmvs: 1,
        initial_residual: rnorm,
        final_residual: rnorm,
        converged: false,
    };
    let target = tol.threshold(rnorm);
    if rnorm <= target {
        stats.converged = true;
        return stats;
    }
    let mut d = vec![0.0; nl];
    for _pass in 0..MAX_REFINE_PASSES {
        let remaining = tol.max_iters.saturating_sub(stats.iterations);
        if remaining == 0 {
            break;
        }
        // Inner correction system A₃₂ d = r, from a zero start. The
        // relative target 1e-6 matches the f32 floor — tighter inner
        // tolerances only burn iterations the refinement cannot use.
        d.iter_mut().for_each(|v| *v = 0.0);
        let inner_tol = Tolerance {
            atol: target,
            rtol: 1e-6,
            max_iters: remaining,
        };
        let inner = super::solve(method, pc, comm, a32, &r, &mut d, &inner_tol);
        stats.iterations += inner.iterations;
        stats.spmvs += inner.spmvs;
        crate::linalg::axpy(1.0, &d, x);
        let prev = rnorm;
        rnorm = a64.residual(comm, b, x, &mut r, &mut buf);
        stats.spmvs += 1;
        stats.final_residual = rnorm;
        if rnorm <= target {
            stats.converged = true;
            break;
        }
        if rnorm > STAGNATION_FACTOR * prev {
            // f32 floor reached (or the inner solve made no progress):
            // further passes re-solve the same system to the same floor.
            break;
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;
    use crate::mdp::fixtures::random_mdp;
    use crate::mdp::{DistMdp, F32PolicyOp, MatFreePolicyOp};
    use crate::util::prop;
    use std::sync::Arc;

    fn policy_for(n: usize, m: usize) -> Vec<usize> {
        (0..n).map(|s| (s * 7 + 3) % m).collect()
    }

    /// Refinement reaches the same f64 tolerance as a pure f64 solve,
    /// certified by the f64 operator — while a single f32 inner solve
    /// alone stalls above it.
    #[test]
    fn refinement_reaches_f64_tolerance() {
        for &method in &["gmres", "bicgstab", "richardson"] {
            let mdp = Arc::new(random_mdp(97, 33, 3, 0.9));
            let m = KspType::parse(method).unwrap();
            World::run(2, move |comm| {
                let d = DistMdp::from_serial(&comm, &mdp);
                let part = d.partition();
                let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
                let nl = hi - lo;
                let policy: Vec<usize> = policy_for(33, 3)[lo..hi].to_vec();
                let g = d.policy_costs(&policy);
                let a64 = MatFreePolicyOp::new(&d, &policy);
                let a32 = F32PolicyOp::new(&d, &policy);
                let tol = Tolerance {
                    atol: 1e-10,
                    rtol: 0.0,
                    max_iters: 10_000,
                };

                let mut x_mixed = vec![0.0; nl];
                let s = solve_mixed(
                    &m,
                    &Precond::None,
                    &comm,
                    &a64,
                    &a32,
                    &g,
                    &mut x_mixed,
                    &tol,
                );
                assert!(s.converged, "{method}: final={}", s.final_residual);
                assert!(s.final_residual <= 1e-10, "{method}");

                // Certify with an independent f64 residual evaluation.
                let mut buf = a64.make_buffer();
                let mut r = vec![0.0; nl];
                let true_res = a64.residual(&comm, &g, &x_mixed, &mut r, &mut buf);
                assert!(true_res <= 2e-10, "{method}: true residual {true_res}");

                // Pure f64 solve agrees on the solution.
                let mut x64 = vec![0.0; nl];
                crate::ksp::solve(&m, &Precond::None, &comm, &a64, &g, &mut x64, &tol);
                prop::close_slices(&x_mixed, &x64, 1e-7).unwrap();

                // A lone f32 inner solve cannot certify 1e-10: its *true*
                // f64 residual stalls at the representation floor.
                let mut x32 = vec![0.0; nl];
                crate::ksp::solve(&m, &Precond::None, &comm, &a32, &g, &mut x32, &tol);
                let res32 = a64.residual(&comm, &g, &x32, &mut r, &mut buf);
                assert!(
                    res32 > 1e-12,
                    "{method}: f32-only residual {res32} suspiciously exact"
                );
            });
        }
    }

    /// A warm start already at the solution returns immediately with the
    /// converged certificate and one residual evaluation.
    #[test]
    fn converged_warm_start_short_circuits() {
        let mdp = Arc::new(random_mdp(13, 21, 2, 0.85));
        World::run(1, move |comm| {
            let d = DistMdp::from_serial(&comm, &mdp);
            let policy = policy_for(21, 2);
            let g = d.policy_costs(&policy);
            let a64 = MatFreePolicyOp::new(&d, &policy);
            let a32 = F32PolicyOp::new(&d, &policy);
            let tol = Tolerance {
                atol: 1e-10,
                rtol: 0.0,
                max_iters: 10_000,
            };
            let mut x = vec![0.0; 21];
            crate::ksp::solve(
                &KspType::Gmres { restart: 20 },
                &Precond::None,
                &comm,
                &a64,
                &g,
                &mut x,
                &tol,
            );
            // Looser target than the pre-solve so the warm start is
            // unambiguously inside the threshold.
            let loose = Tolerance {
                atol: 1e-8,
                rtol: 0.0,
                max_iters: 10_000,
            };
            let s = solve_mixed(
                &KspType::Gmres { restart: 20 },
                &Precond::None,
                &comm,
                &a64,
                &a32,
                &g,
                &mut x,
                &loose,
            );
            assert!(s.converged);
            assert_eq!(s.iterations, 0);
            assert_eq!(s.spmvs, 1);
        });
    }

    /// Jacobi preconditioning (built from the f64 diagonal) composes with
    /// the mixed loop.
    #[test]
    fn preconditioned_mixed_converges() {
        let mdp = Arc::new(random_mdp(29, 25, 2, 0.93));
        World::run(1, move |comm| {
            let d = DistMdp::from_serial(&comm, &mdp);
            let policy = policy_for(25, 2);
            let g = d.policy_costs(&policy);
            let a64 = MatFreePolicyOp::new(&d, &policy);
            let a32 = F32PolicyOp::new(&d, &policy);
            let pc = Precond::build(crate::ksp::precond::PcType::Jacobi, &a64);
            let tol = Tolerance {
                atol: 1e-10,
                rtol: 0.0,
                max_iters: 10_000,
            };
            let mut x = vec![0.0; 25];
            let s = solve_mixed(
                &KspType::BiCgStab,
                &pc,
                &comm,
                &a64,
                &a32,
                &g,
                &mut x,
                &tol,
            );
            assert!(s.converged, "final={}", s.final_residual);
        });
    }
}
