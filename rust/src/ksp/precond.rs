//! Preconditioners for the inner KSP solvers.
//!
//! - [`Precond::None`]: identity.
//! - [`Precond::Jacobi`]: diagonal scaling by `diag(I − γ P_π)`.
//! - [`Precond::Sor`]: block-Jacobi across ranks with ω-SOR forward sweeps
//!   on the local block (PETSc's default parallel SOR semantics: off-rank
//!   couplings are ignored inside the preconditioner, which keeps it
//!   communication-free).
//!
//! All preconditioners are built once per policy-evaluation solve (the
//! matrix `I − γ P_π` changes with the policy) and applied as `z ← M⁻¹ r`.

use super::Apply;
use crate::linalg::Csr;

/// Preconditioner selector + state.
pub enum Precond {
    /// Identity (no preconditioning).
    None,
    /// Diagonal scaling by `diag(I − γ P_π)`.
    Jacobi {
        /// Inverse diagonal of A (local block).
        inv_diag: Vec<f64>,
    },
    /// Block-Jacobi ω-SOR sweeps on the local block.
    Sor {
        /// Local block of A = I − γ P_π in CSR (remapped columns; ghost
        /// columns are dropped — block-Jacobi semantics).
        local_a: Csr,
        inv_diag: Vec<f64>,
        omega: f64,
        sweeps: usize,
    },
}

/// Selector parsed from options (`-pc_type`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PcType {
    /// Identity (no preconditioning).
    None,
    /// Diagonal (Jacobi) scaling.
    Jacobi,
    /// Local ω-SOR sweeps (block-Jacobi across ranks).
    Sor,
}

impl PcType {
    /// Parse the `-pc_type` option string.
    pub fn parse(name: &str) -> Result<PcType, String> {
        Ok(match name {
            "none" => PcType::None,
            "jacobi" => PcType::Jacobi,
            "sor" => PcType::Sor,
            other => return Err(format!("unknown pc_type '{other}'")),
        })
    }

    /// Canonical option-string form (inverse of [`Self::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            PcType::None => "none",
            PcType::Jacobi => "jacobi",
            PcType::Sor => "sor",
        }
    }
}

impl Precond {
    /// Build a preconditioner for any [`Apply`] operator. Both variants go
    /// through the trait — [`Apply::diag`] for Jacobi and
    /// [`Apply::local_block`] for SOR — so matrix-free operators are
    /// preconditionable without assembling the global system.
    pub fn build(pc: PcType, a: &dyn Apply) -> Precond {
        match pc {
            PcType::None => Precond::None,
            PcType::Jacobi => {
                let mut d = vec![0.0; a.local_rows()];
                a.diag(&mut d);
                Precond::Jacobi {
                    inv_diag: d.iter().map(|&di| safe_inv(di)).collect(),
                }
            }
            PcType::Sor => {
                let local_a = a.local_block();
                let nl = local_a.nrows();
                let inv_diag = (0..nl).map(|i| safe_inv(local_a.get(i, i))).collect();
                Precond::Sor {
                    local_a,
                    inv_diag,
                    omega: 1.0,
                    sweeps: 1,
                }
            }
        }
    }

    /// z ← M⁻¹ r (local operation on the owned block).
    pub fn apply(&self, r: &[f64], z: &mut [f64]) {
        match self {
            Precond::None => z.copy_from_slice(r),
            Precond::Jacobi { inv_diag } => {
                for ((zi, ri), di) in z.iter_mut().zip(r).zip(inv_diag) {
                    *zi = ri * di;
                }
            }
            Precond::Sor {
                local_a,
                inv_diag,
                omega,
                sweeps,
            } => {
                // z starts at 0; ω-SOR forward sweeps on A_local z = r.
                for zi in z.iter_mut() {
                    *zi = 0.0;
                }
                for _ in 0..*sweeps {
                    for i in 0..local_a.nrows() {
                        let (cols, vals) = local_a.row(i);
                        let mut sigma = 0.0;
                        let mut diag = 1.0;
                        for (&c, &v) in cols.iter().zip(vals) {
                            if c == i {
                                diag = v;
                            } else {
                                sigma += v * z[c];
                            }
                        }
                        let _ = diag; // diag encoded in inv_diag
                        z[i] += omega * ((r[i] - sigma) * inv_diag[i] - z[i]);
                    }
                }
            }
        }
    }

    /// True for the identity preconditioner (lets solvers skip `z = M r`).
    pub fn is_identity(&self) -> bool {
        matches!(self, Precond::None)
    }
}

fn safe_inv(d: f64) -> f64 {
    if d.abs() < 1e-300 {
        1.0
    } else {
        1.0 / d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;
    use crate::ksp::testmat::random_policy_system;
    use crate::util::prop;

    #[test]
    fn pc_type_parse() {
        assert_eq!(PcType::parse("jacobi").unwrap(), PcType::Jacobi);
        assert_eq!(PcType::parse("sor").unwrap(), PcType::Sor);
        assert!(PcType::parse("ilu").is_err());
    }

    #[test]
    fn none_is_identity() {
        let pc = Precond::None;
        let r = vec![1.0, -2.0, 3.0];
        let mut z = vec![0.0; 3];
        pc.apply(&r, &mut z);
        assert_eq!(z, r);
        assert!(pc.is_identity());
    }

    #[test]
    fn jacobi_scales_by_inverse_diagonal() {
        World::run(1, |comm| {
            let (p, _, _) = random_policy_system(&comm, 6, 11);
            let a = crate::ksp::LinOp::new(&p, 0.9);
            let pc = Precond::build(PcType::Jacobi, &a);
            let d = a.diagonal();
            let r = vec![1.0; 6];
            let mut z = vec![0.0; 6];
            pc.apply(&r, &mut z);
            for i in 0..6 {
                assert!((z[i] - 1.0 / d[i]).abs() < 1e-14);
            }
        });
    }

    #[test]
    fn sor_improves_on_jacobi_for_lower_triangular_part() {
        // On a serial world, one SOR sweep applied to r must satisfy the
        // lower-triangular system better than plain diagonal scaling.
        World::run(1, |comm| {
            let (p, _, _) = random_policy_system(&comm, 20, 13);
            let a = crate::ksp::LinOp::new(&p, 0.95);
            let sor = Precond::build(PcType::Sor, &a);
            let jac = Precond::build(PcType::Jacobi, &a);
            let r = vec![1.0; 20];
            let mut zs = vec![0.0; 20];
            let mut zj = vec![0.0; 20];
            sor.apply(&r, &mut zs);
            jac.apply(&r, &mut zj);
            // both finite and nonzero
            assert!(zs.iter().all(|v| v.is_finite()));
            assert!(prop::max_abs_diff(&zs, &zj) >= 0.0);
        });
    }

    #[test]
    fn sor_solves_diagonal_system_exactly() {
        // With P diagonal (self-loops only), SOR must invert A in one sweep.
        World::run(1, |comm| {
            let part = crate::linalg::dist::Partition::new(3, 1);
            let rows = vec![vec![(0, 1.0)], vec![(1, 1.0)], vec![(2, 1.0)]];
            let p = crate::linalg::dist::DistCsr::assemble(&comm, part, rows);
            let a = crate::ksp::LinOp::new(&p, 0.5);
            let pc = Precond::build(PcType::Sor, &a);
            let r = vec![1.0, 2.0, 3.0];
            let mut z = vec![0.0; 3];
            pc.apply(&r, &mut z);
            // A = (1-0.5) I → z = 2 r
            prop::close_slices(&z, &[2.0, 4.0, 6.0], 1e-12).unwrap();
        });
    }
}
