//! (Preconditioned) Richardson iteration.
//!
//! `x ← x + ω M⁻¹ (b − A x)`. With `A = I − γ P_π`, `M = I`, `ω = 1` this
//! is precisely the classical policy-evaluation sweep
//! `x ← g_π + γ P_π x`, which is how VI and modified PI arise as iPI
//! special cases (DESIGN.md §5.2). Converges for any ρ(I − ωM⁻¹A) < 1; for
//! the MDP operator the unpreconditioned rate is γ.

use super::{Apply, KspStats, Precond, Tolerance};
use crate::comm::Comm;

/// Solve `A x = b` by Richardson iteration. `x` carries the warm start.
pub fn solve(
    comm: &Comm,
    a: &dyn Apply,
    pc: &Precond,
    b: &[f64],
    x: &mut [f64],
    tol: &Tolerance,
    omega: f64,
) -> KspStats {
    let nl = a.local_rows();
    assert_eq!(b.len(), nl);
    assert_eq!(x.len(), nl);
    let mut buf = a.make_buffer();
    let mut r = vec![0.0; nl];
    let mut z = vec![0.0; nl];

    let mut stats = KspStats::default();
    let r0 = a.residual(comm, b, x, &mut r, &mut buf);
    stats.spmvs += 1;
    stats.initial_residual = r0;
    let target = tol.threshold(r0);
    let mut rnorm = r0;

    while rnorm > target && stats.iterations < tol.max_iters {
        pc.apply(&r, &mut z);
        for (xi, zi) in x.iter_mut().zip(&z) {
            *xi += omega * zi;
        }
        rnorm = a.residual(comm, b, x, &mut r, &mut buf);
        stats.spmvs += 1;
        stats.iterations += 1;
    }
    stats.final_residual = rnorm;
    stats.converged = rnorm <= target;
    stats
}

/// Run exactly `sweeps` unpreconditioned ω=1 Richardson sweeps with **no**
/// convergence test (the modified-policy-iteration inner step — mdpsolver's
/// only mode). Cheaper than `solve` because it skips residual norms: each
/// sweep is `x ← b + γ P x`, recovered operator-agnostically from
/// `A = I − γP` as `x ← b + (x − A x)`.
pub fn fixed_sweeps(
    comm: &Comm,
    a: &dyn Apply,
    b: &[f64],
    x: &mut [f64],
    sweeps: usize,
) -> KspStats {
    let nl = a.local_rows();
    let mut buf = a.make_buffer();
    let mut ax = vec![0.0; nl];
    for _ in 0..sweeps {
        a.apply(comm, x, &mut ax, &mut buf);
        for i in 0..nl {
            x[i] = b[i] + x[i] - ax[i];
        }
    }
    KspStats {
        iterations: sweeps,
        spmvs: sweeps,
        initial_residual: f64::NAN,
        final_residual: f64::NAN,
        converged: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;
    use crate::ksp::precond::PcType;
    use crate::ksp::testmat::random_policy_system;
    use crate::ksp::LinOp;
    use crate::linalg::dist::dist_norm_inf;
    use crate::util::prop;

    fn run_richardson(n: usize, size: usize, gamma: f64, pc_type: PcType) -> f64 {
        let out = World::run(size, move |comm| {
            let (p, b, part) = random_policy_system(&comm, n, 99);
            let a = LinOp::new(&p, gamma);
            let pc = Precond::build(pc_type, &a);
            let nl = part.local_len(comm.rank());
            let mut x = vec![0.0; nl];
            let tol = Tolerance {
                atol: 1e-10,
                rtol: 0.0,
                max_iters: 100_000,
            };
            let stats = solve(&comm, &a, &pc, &b, &mut x, &tol, 1.0);
            assert!(stats.converged, "not converged: {stats:?}");
            // verify residual independently
            let mut buf = p.make_buffer();
            let mut r = vec![0.0; nl];
            let rn = a.residual(&comm, &b, &x, &mut r, &mut buf);
            let _ = dist_norm_inf(&comm, &r);
            rn
        });
        out.into_iter().fold(0.0, f64::max)
    }

    #[test]
    fn converges_serial() {
        assert!(run_richardson(30, 1, 0.9, PcType::None) < 1e-9);
    }

    #[test]
    fn converges_distributed_matches() {
        assert!(run_richardson(30, 3, 0.9, PcType::None) < 1e-9);
    }

    #[test]
    fn converges_with_jacobi() {
        assert!(run_richardson(30, 2, 0.95, PcType::Jacobi) < 1e-9);
    }

    #[test]
    fn converges_with_sor() {
        assert!(run_richardson(30, 1, 0.95, PcType::Sor) < 1e-9);
    }

    #[test]
    fn fixed_sweeps_equals_manual_iteration() {
        World::run(1, |comm| {
            let (p, b, _) = random_policy_system(&comm, 12, 5);
            let gamma = 0.8;
            let a = LinOp::new(&p, gamma);
            let mut x = vec![0.0; 12];
            fixed_sweeps(&comm, &a, &b, &mut x, 3);
            // manual: x3 = b + γP(b + γP(b + γP·0))
            let mut buf = p.make_buffer();
            let mut manual = vec![0.0; 12];
            for _ in 0..3 {
                let mut px = vec![0.0; 12];
                p.spmv(&comm, &manual, &mut px, &mut buf);
                for i in 0..12 {
                    manual[i] = b[i] + gamma * px[i];
                }
            }
            prop::close_slices(&x, &manual, 1e-14).unwrap();
        });
    }

    #[test]
    fn warm_start_reduces_iterations() {
        World::run(1, |comm| {
            let (p, b, _) = random_policy_system(&comm, 20, 17);
            let a = LinOp::new(&p, 0.9);
            let pc = Precond::None;
            let tol = Tolerance {
                atol: 1e-10,
                rtol: 0.0,
                max_iters: 100_000,
            };
            let mut x_cold = vec![0.0; 20];
            let cold = solve(&comm, &a, &pc, &b, &mut x_cold, &tol, 1.0);
            // warm start at the solution: zero iterations needed
            let mut x_warm = x_cold.clone();
            let warm = solve(&comm, &a, &pc, &b, &mut x_warm, &tol, 1.0);
            assert!(warm.iterations < cold.iterations.max(1));
        });
    }

    #[test]
    fn respects_max_iters() {
        World::run(1, |comm| {
            let (p, b, _) = random_policy_system(&comm, 20, 21);
            let a = LinOp::new(&p, 0.999);
            let tol = Tolerance {
                atol: 1e-14,
                rtol: 0.0,
                max_iters: 3,
            };
            let mut x = vec![0.0; 20];
            let stats = solve(&comm, &a, &Precond::None, &b, &mut x, &tol, 1.0);
            assert_eq!(stats.iterations, 3);
            assert!(!stats.converged);
        });
    }
}
