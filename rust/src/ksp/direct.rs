//! Direct (gathered dense LU) solve — exact policy iteration.
//!
//! Every rank contributes its local rows of `A = I − γ P_π` with global
//! column ids; the dense system is assembled redundantly on all ranks,
//! LU-factored, and each rank keeps its slice of the solution. O(n²) memory
//! per rank — intended for small MDPs (exact PI baselines, tests), mirroring
//! how one would use `-ksp_type preonly -pc_type lu` in madupite/PETSc.

use super::{Apply, KspStats};
use crate::comm::{codec, Comm};
use crate::linalg::DenseMat;

/// Solve `A x = b` exactly. `x` is overwritten with the local solution
/// block. Collective.
pub fn solve(comm: &Comm, a: &dyn Apply, b: &[f64], x: &mut [f64]) -> KspStats {
    let part = a.partition();
    let n = part.n();
    let nl = a.local_rows();
    assert_eq!(b.len(), nl);
    assert_eq!(x.len(), nl);

    // Densify the local rows of A (global columns, duplicates additive).
    // n is small by contract.
    let lo = part.lo(comm.rank());
    let mut dense_rows = vec![0.0; nl * n];
    for (i, row) in a.materialize_rows().into_iter().enumerate() {
        for (gc, v) in row {
            dense_rows[i * n + gc] += v;
        }
    }

    // Gather A and b redundantly.
    let all_rows = comm.allgatherv(codec::encode_f64s(&dense_rows));
    let all_b = comm.allgather_f64s(b);
    let mut mat = DenseMat::zeros(n, n);
    let mut row0 = 0usize;
    for bytes in &all_rows {
        let vals = codec::decode_f64s(bytes);
        let rows_here = vals.len() / n;
        for r in 0..rows_here {
            mat.row_mut(row0 + r).copy_from_slice(&vals[r * n..(r + 1) * n]);
        }
        row0 += rows_here;
    }
    debug_assert_eq!(row0, n);

    let sol = mat
        .solve(&all_b)
        .expect("direct solve: singular policy system (γ < 1 should prevent this)");
    x.copy_from_slice(&sol[lo..lo + nl]);

    KspStats {
        iterations: 1,
        spmvs: 0,
        initial_residual: f64::NAN,
        final_residual: 0.0,
        converged: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;
    use crate::ksp::testmat::random_policy_system;
    use crate::ksp::{LinOp, Precond, Tolerance};
    use crate::util::prop;

    #[test]
    fn exact_solution_zero_residual() {
        World::run(2, |comm| {
            let (p, b, part) = random_policy_system(&comm, 20, 8);
            let a = LinOp::new(&p, 0.95);
            let nl = part.local_len(comm.rank());
            let mut x = vec![0.0; nl];
            let stats = solve(&comm, &a, &b, &mut x);
            assert!(stats.converged);
            let mut buf = p.make_buffer();
            let mut r = vec![0.0; nl];
            let rn = a.residual(&comm, &b, &x, &mut r, &mut buf);
            assert!(rn < 1e-10, "direct residual {rn}");
        });
    }

    #[test]
    fn matches_gmres() {
        let direct: Vec<f64> = World::run(3, |comm| {
            let (p, b, part) = random_policy_system(&comm, 25, 4);
            let a = LinOp::new(&p, 0.9);
            let mut x = vec![0.0; part.local_len(comm.rank())];
            solve(&comm, &a, &b, &mut x);
            x
        })
        .into_iter()
        .flatten()
        .collect();
        let gmres: Vec<f64> = World::run(1, |comm| {
            let (p, b, _) = random_policy_system(&comm, 25, 4);
            let a = LinOp::new(&p, 0.9);
            let mut x = vec![0.0; 25];
            crate::ksp::gmres::solve(
                &comm,
                &a,
                &Precond::None,
                &b,
                &mut x,
                &Tolerance {
                    atol: 1e-12,
                    rtol: 0.0,
                    max_iters: 1000,
                },
                25,
            );
            x
        })
        .into_iter()
        .flatten()
        .collect();
        prop::close_slices(&direct, &gmres, 1e-8).unwrap();
    }
}
