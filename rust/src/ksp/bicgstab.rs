//! BiCGStab (van der Vorst 1992), right-preconditioned.
//!
//! Short-recurrence Krylov method: two SpMVs per iteration, constant memory
//! (vs GMRES's growing basis). The iPI companion paper finds it competitive
//! with GMRES on many MDP instances, occasionally better when the spectrum
//! of `I − γ P_π` is well clustered.
//!
//! Reduction pipelining (DESIGN.md §14): the textbook loop issues six
//! scalar reductions per iteration. Two pairs are *adjacent* — no vector
//! update separates them — so they fuse into single
//! [`Comm::allreduce_f64s`] calls: `[‖r‖², (r̂,r)]` at the loop head (the
//! convergence check and the next iteration's ρ share one rendezvous) and
//! `[(t,t), (t,s)]` for the stabilization step. Four reductions per
//! iteration remain. The fused collective folds each component in the same
//! rank order as the scalar collective, so every iterate, iteration count,
//! and returned residual is bitwise identical to the unfused loop.

use super::{Apply, KspStats, Precond, Tolerance};
use crate::comm::{Comm, Reduce};
use crate::linalg::dist::{dist_dot, dist_norm2};
use crate::linalg::dot;

/// Solve `A x = b` with preconditioned BiCGStab. `x` carries the warm start.
pub fn solve(
    comm: &Comm,
    a: &dyn Apply,
    pc: &Precond,
    b: &[f64],
    x: &mut [f64],
    tol: &Tolerance,
) -> KspStats {
    let nl = a.local_rows();
    assert_eq!(b.len(), nl);
    assert_eq!(x.len(), nl);
    let mut buf = a.make_buffer();
    let mut stats = KspStats::default();

    let mut r = vec![0.0; nl];
    let r0norm = a.residual(comm, b, x, &mut r, &mut buf);
    stats.spmvs += 1;
    stats.initial_residual = r0norm;
    let target = tol.threshold(r0norm);
    if r0norm <= target {
        stats.final_residual = r0norm;
        stats.converged = true;
        return stats;
    }

    // Shadow residual r̂ = r₀ (fixed).
    let rhat = r.clone();
    let mut rho = 1.0f64;
    let mut alpha = 1.0f64;
    let mut omega = 1.0f64;
    let mut v = vec![0.0; nl];
    let mut p = vec![0.0; nl];
    let mut phat = vec![0.0; nl];
    let mut s = vec![0.0; nl];
    let mut shat = vec![0.0; nl];
    let mut t = vec![0.0; nl];
    let mut rnorm;
    let mut omega_breakdown = false;

    loop {
        // Fused head reduction: ‖r‖² for the convergence check and the
        // next ρ = (r̂, r) share one collective. On the exit passes ρ is
        // computed one reduction early and discarded — the fold itself is
        // identical, so nothing observable changes.
        let head = comm.allreduce_f64s(&[dot(&r, &r), dot(&rhat, &r)], Reduce::Sum);
        rnorm = head[0].sqrt();
        if rnorm <= target {
            break;
        }
        if omega_breakdown || stats.iterations >= tol.max_iters {
            break;
        }
        stats.iterations += 1;
        let rho_new = head[1];
        if rho_new.abs() < 1e-300 {
            break; // breakdown — return best so far
        }
        let beta = (rho_new / rho) * (alpha / omega);
        rho = rho_new;
        for i in 0..nl {
            p[i] = r[i] + beta * (p[i] - omega * v[i]);
        }
        pc.apply(&p, &mut phat);
        a.apply(comm, &phat, &mut v, &mut buf);
        stats.spmvs += 1;
        let denom = dist_dot(comm, &rhat, &v);
        if denom.abs() < 1e-300 {
            break;
        }
        alpha = rho / denom;
        for i in 0..nl {
            s[i] = r[i] - alpha * v[i];
        }
        let snorm = dist_norm2(comm, &s);
        if snorm <= target {
            for i in 0..nl {
                x[i] += alpha * phat[i];
            }
            rnorm = snorm;
            break;
        }
        pc.apply(&s, &mut shat);
        a.apply(comm, &shat, &mut t, &mut buf);
        stats.spmvs += 1;
        // Fused stabilization reduction: (t,t) and (t,s) are adjacent.
        let st = comm.allreduce_f64s(&[dot(&t, &t), dot(&t, &s)], Reduce::Sum);
        let tt = st[0];
        if tt.abs() < 1e-300 {
            break;
        }
        omega = st[1] / tt;
        for i in 0..nl {
            x[i] += alpha * phat[i] + omega * shat[i];
            r[i] = s[i] - omega * t[i];
        }
        // ω-breakdown exits *after* next head's convergence check — the
        // same check order as the unfused loop.
        omega_breakdown = omega.abs() < 1e-300;
    }
    stats.final_residual = rnorm;
    stats.converged = rnorm <= target;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;
    use crate::ksp::precond::PcType;
    use crate::ksp::testmat::random_policy_system;
    use crate::ksp::LinOp;
    use crate::util::prop;

    fn run(n: usize, size: usize, gamma: f64, pc_type: PcType) -> Vec<f64> {
        let out = World::run(size, move |comm| {
            let (p, b, part) = random_policy_system(&comm, n, 42);
            let a = LinOp::new(&p, gamma);
            let pc = Precond::build(pc_type, &a);
            let nl = part.local_len(comm.rank());
            let mut x = vec![0.0; nl];
            let tol = Tolerance {
                atol: 1e-11,
                rtol: 0.0,
                max_iters: 5_000,
            };
            let stats = solve(&comm, &a, &pc, &b, &mut x, &tol);
            assert!(
                stats.converged,
                "bicgstab not converged: final={}",
                stats.final_residual
            );
            x
        });
        out.into_iter().flatten().collect()
    }

    #[test]
    fn solves_serial() {
        let x = run(30, 1, 0.9, PcType::None);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn distributed_matches_serial() {
        let xs = run(40, 1, 0.95, PcType::None);
        let xd = run(40, 4, 0.95, PcType::None);
        prop::close_slices(&xs, &xd, 1e-7).unwrap();
    }

    #[test]
    fn agrees_with_gmres() {
        let xb = run(35, 2, 0.99, PcType::None);
        let out = World::run(2, |comm| {
            let (p, b, part) = random_policy_system(&comm, 35, 42);
            let a = LinOp::new(&p, 0.99);
            let nl = part.local_len(comm.rank());
            let mut x = vec![0.0; nl];
            let tol = Tolerance {
                atol: 1e-11,
                rtol: 0.0,
                max_iters: 5_000,
            };
            crate::ksp::gmres::solve(&comm, &a, &Precond::None, &b, &mut x, &tol, 30);
            x
        });
        let xg: Vec<f64> = out.into_iter().flatten().collect();
        prop::close_slices(&xb, &xg, 1e-6).unwrap();
    }

    #[test]
    fn jacobi_preconditioning_works() {
        let x = run(30, 1, 0.95, PcType::Jacobi);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn warm_start_immediate() {
        World::run(1, |comm| {
            let (p, b, _) = random_policy_system(&comm, 20, 9);
            let a = LinOp::new(&p, 0.9);
            let tol = Tolerance {
                atol: 1e-10,
                rtol: 0.0,
                max_iters: 1_000,
            };
            let mut x = vec![0.0; 20];
            solve(&comm, &a, &Precond::None, &b, &mut x, &tol);
            let mut x2 = x.clone();
            let s2 = solve(&comm, &a, &Precond::None, &b, &mut x2, &tol);
            assert_eq!(s2.iterations, 0);
            assert!(s2.converged);
        });
    }
}
