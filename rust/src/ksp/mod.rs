//! Inner linear solvers for inexact policy evaluation (PETSc `KSP`).
//!
//! iPI's policy-evaluation step solves `(I − γ P_π) V = g_π` *inexactly*,
//! to a forcing tolerance proportional to the outer Bellman residual
//! (Gargiani et al. 2023/2024). The choice of inner solver is madupite's
//! central "tailor the method to the problem" knob (`-ksp_type`), so this
//! module reproduces the relevant PETSc KSP family from scratch:
//!
//! - [`richardson`]: (preconditioned) Richardson iteration — with ω = 1 and
//!   no preconditioner this is exactly the classical `T_π` fixed-point sweep,
//!   making VI and modified PI special cases of iPI.
//! - [`gmres`]: restarted GMRES(m) with modified Gram–Schmidt Arnoldi and
//!   Givens-rotation least squares.
//! - [`bicgstab`]: BiCGStab (van der Vorst).
//! - [`tfqmr`]: transpose-free QMR (Freund).
//! - [`direct`]: gathered dense LU (exact policy iteration on small MDPs).
//!
//! All iterative solvers run distributed: vectors are block-partitioned,
//! inner products reduce through [`crate::comm`], and the operator applies
//! through the ghost plan of [`DistCsr`].

pub mod bicgstab;
pub mod direct;
pub mod gmres;
pub mod precond;
pub mod richardson;
pub mod tfqmr;

use crate::comm::Comm;
use crate::linalg::dist::{dist_norm2, DistCsr, GhostBuf};
pub use precond::Precond;

/// The linear operator `A = I − γ P_π` applied matrix-free on top of the
/// distributed policy-transition matrix.
pub struct LinOp<'a> {
    pub p: &'a DistCsr,
    pub gamma: f64,
}

impl<'a> LinOp<'a> {
    pub fn new(p: &'a DistCsr, gamma: f64) -> Self {
        assert_eq!(
            p.local_nrows(),
            p.col_partition().local_len(p_rank(p)),
            "LinOp requires a square (state × state) policy matrix"
        );
        LinOp { p, gamma }
    }

    pub fn local_len(&self) -> usize {
        self.p.local_nrows()
    }

    /// y ← (I − γ P) x. Collective.
    pub fn apply(&self, comm: &Comm, x: &[f64], y: &mut [f64], buf: &mut GhostBuf) {
        self.p.spmv(comm, x, y, buf);
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi = xi - self.gamma * *yi;
        }
    }

    /// Local diagonal of A (for Jacobi preconditioning).
    pub fn diagonal(&self) -> Vec<f64> {
        let local = self.p.local();
        (0..local.nrows())
            .map(|i| 1.0 - self.gamma * local.get(i, i))
            .collect()
    }

    /// r ← b − A·x. Returns global ‖r‖₂. Collective.
    pub fn residual(
        &self,
        comm: &Comm,
        b: &[f64],
        x: &[f64],
        r: &mut [f64],
        buf: &mut GhostBuf,
    ) -> f64 {
        self.apply(comm, x, r, buf);
        for (ri, bi) in r.iter_mut().zip(b) {
            *ri = bi - *ri;
        }
        dist_norm2(comm, r)
    }
}

// Internal: rank of the DistCsr's world via its partition bookkeeping.
// (DistCsr stores rank privately; expose through local row count identity.)
fn p_rank(p: &DistCsr) -> usize {
    // The column partition + local row count identify the rank uniquely for
    // square matrices; but DistCsr::rank is what we want. Provided below.
    p.rank()
}

/// Inner solver selector (madupite's `-ksp_type`).
#[derive(Clone, Debug, PartialEq)]
pub enum KspType {
    /// Richardson iteration with relaxation ω (ω=1 ⇒ T_π sweeps).
    Richardson { omega: f64 },
    /// Restarted GMRES with Krylov dimension `restart`.
    Gmres { restart: usize },
    BiCgStab,
    Tfqmr,
    /// Gathered dense LU — exact solve, small problems only.
    Direct,
}

impl KspType {
    /// Parse the `-ksp_type` option string.
    pub fn parse(name: &str) -> Result<KspType, String> {
        Ok(match name {
            "richardson" => KspType::Richardson { omega: 1.0 },
            "gmres" => KspType::Gmres { restart: 30 },
            "bicgstab" | "bcgs" => KspType::BiCgStab,
            "tfqmr" => KspType::Tfqmr,
            "direct" | "preonly" => KspType::Direct,
            other => return Err(format!("unknown ksp_type '{other}'")),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            KspType::Richardson { .. } => "richardson",
            KspType::Gmres { .. } => "gmres",
            KspType::BiCgStab => "bicgstab",
            KspType::Tfqmr => "tfqmr",
            KspType::Direct => "direct",
        }
    }
}

/// Stopping control for the inner solve.
#[derive(Clone, Copy, Debug)]
pub struct Tolerance {
    /// Absolute ℓ₂ target on the residual.
    pub atol: f64,
    /// Relative (to ‖r₀‖₂) target.
    pub rtol: f64,
    pub max_iters: usize,
}

impl Default for Tolerance {
    fn default() -> Self {
        Tolerance {
            atol: 1e-12,
            rtol: 1e-8,
            max_iters: 10_000,
        }
    }
}

impl Tolerance {
    /// The residual threshold given the initial residual norm.
    pub fn threshold(&self, r0: f64) -> f64 {
        self.atol.max(self.rtol * r0)
    }
}

/// Outcome of an inner solve.
#[derive(Clone, Debug, Default)]
pub struct KspStats {
    pub iterations: usize,
    /// Operator applications (the unit the iPI papers count cost in).
    pub spmvs: usize,
    pub initial_residual: f64,
    pub final_residual: f64,
    pub converged: bool,
}

/// Dispatch an inner solve: `x` holds the warm start on entry, the solution
/// on exit. Collective across the world.
pub fn solve(
    method: &KspType,
    pc: &Precond,
    comm: &Comm,
    a: &LinOp,
    b: &[f64],
    x: &mut [f64],
    tol: &Tolerance,
) -> KspStats {
    match method {
        KspType::Richardson { omega } => richardson::solve(comm, a, pc, b, x, tol, *omega),
        KspType::Gmres { restart } => gmres::solve(comm, a, pc, b, x, tol, *restart),
        KspType::BiCgStab => bicgstab::solve(comm, a, pc, b, x, tol),
        KspType::Tfqmr => tfqmr::solve(comm, a, b, x, tol),
        KspType::Direct => direct::solve(comm, a, b, x),
    }
}

#[cfg(test)]
pub(crate) mod testmat {
    //! Shared test fixtures: random γ-contraction systems.
    use crate::comm::Comm;
    use crate::linalg::dist::{DistCsr, Partition};
    use crate::util::prng::Xoshiro256pp;

    /// Build a random row-stochastic transition matrix distributed over the
    /// world, returning (P, b, partition) on each rank.
    pub fn random_policy_system(
        comm: &Comm,
        n: usize,
        seed: u64,
    ) -> (DistCsr, Vec<f64>, Partition) {
        let part = Partition::new(n, comm.size());
        let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
        let mut rows = Vec::with_capacity(hi - lo);
        for i in lo..hi {
            // deterministic per-row seed → identical matrix for any world size
            let mut rng = Xoshiro256pp::new(seed ^ (i as u64).wrapping_mul(0x9E37));
            let k = 1 + rng.index(4);
            let cols: Vec<usize> = (0..k).map(|_| rng.index(n)).collect();
            let mut row: Vec<(usize, f64)> = Vec::new();
            let probs = rng.prob_vector(cols.len());
            for (c, p) in cols.into_iter().zip(probs) {
                row.push((c, p));
            }
            rows.push(row);
        }
        let p = DistCsr::assemble(comm, part, rows);
        let b: Vec<f64> = (lo..hi)
            .map(|i| {
                let mut rng = Xoshiro256pp::new(seed ^ 0xB0B ^ (i as u64) << 1);
                rng.range_f64(0.0, 1.0)
            })
            .collect();
        (p, b, part)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;

    #[test]
    fn ksp_type_parse() {
        assert_eq!(
            KspType::parse("gmres").unwrap(),
            KspType::Gmres { restart: 30 }
        );
        assert_eq!(KspType::parse("bcgs").unwrap(), KspType::BiCgStab);
        assert!(KspType::parse("nope").is_err());
        assert_eq!(KspType::parse("tfqmr").unwrap().name(), "tfqmr");
    }

    #[test]
    fn tolerance_threshold() {
        let t = Tolerance {
            atol: 1e-10,
            rtol: 1e-2,
            max_iters: 10,
        };
        assert_eq!(t.threshold(1.0), 1e-2);
        assert_eq!(t.threshold(1e-9), 1e-10);
    }

    #[test]
    fn linop_apply_identity_when_gamma_zero() {
        World::run(2, |comm| {
            let (p, b, part) = testmat::random_policy_system(&comm, 10, 3);
            let a = LinOp::new(&p, 0.0);
            let mut buf = p.make_buffer();
            let nl = part.local_len(comm.rank());
            let mut y = vec![0.0; nl];
            a.apply(&comm, &b, &mut y, &mut buf);
            assert_eq!(y, b);
        });
    }

    #[test]
    fn linop_residual_zero_at_solution() {
        // For x solving (I-γP)x = b the residual must be ~0; test with the
        // trivial γ=0 case where x = b.
        World::run(1, |comm| {
            let (p, b, _) = testmat::random_policy_system(&comm, 8, 5);
            let a = LinOp::new(&p, 0.0);
            let mut buf = p.make_buffer();
            let mut r = vec![0.0; 8];
            let nrm = a.residual(&comm, &b, &b, &mut r, &mut buf);
            assert!(nrm < 1e-14);
        });
    }

    #[test]
    fn linop_diagonal() {
        World::run(1, |comm| {
            let part = crate::linalg::dist::Partition::new(2, 1);
            let rows = vec![vec![(0, 0.5), (1, 0.5)], vec![(1, 1.0)]];
            let p = DistCsr::assemble(&comm, part, rows);
            let a = LinOp::new(&p, 0.9);
            let d = a.diagonal();
            assert!((d[0] - (1.0 - 0.45)).abs() < 1e-15);
            assert!((d[1] - (1.0 - 0.9)).abs() < 1e-15);
        });
    }

    use crate::linalg::dist::DistCsr;
}
