//! Inner linear solvers for inexact policy evaluation (PETSc `KSP`).
//!
//! iPI's policy-evaluation step solves `(I − γ P_π) V = g_π` *inexactly*,
//! to a forcing tolerance proportional to the outer Bellman residual
//! (Gargiani et al. 2023/2024). The choice of inner solver is madupite's
//! central "tailor the method to the problem" knob (`-ksp_type`), so this
//! module reproduces the relevant PETSc KSP family from scratch:
//!
//! - [`richardson`]: (preconditioned) Richardson iteration — with ω = 1 and
//!   no preconditioner this is exactly the classical `T_π` fixed-point sweep,
//!   making VI and modified PI special cases of iPI.
//! - [`gmres`]: restarted GMRES(m) with modified Gram–Schmidt Arnoldi and
//!   Givens-rotation least squares.
//! - [`bicgstab`]: BiCGStab (van der Vorst).
//! - [`tfqmr`]: transpose-free QMR (Freund).
//! - [`direct`]: gathered dense LU (exact policy iteration on small MDPs).
//! - [`mixed`]: mixed-precision driver — any of the above run on an f32
//!   operator copy inside an f64 iterative-refinement loop
//!   (`-inner_precision f32`).
//!
//! All solvers are generic over the [`Apply`] operator trait (PETSc's shell
//! `Mat`): they never see a concrete matrix, only `y ← A x`, which is what
//! lets the same Krylov stack run over an assembled `P_π` CSR ([`LinOp`]),
//! the fused matrix-free policy operator
//! ([`crate::mdp::matfree::MatFreePolicyOp`]) and the dense accelerator
//! block ([`DenseOp`]) — the backend-selection matrix is DESIGN.md §4.
//!
//! All iterative solvers run distributed: vectors are block-partitioned,
//! inner products reduce through [`crate::comm`], and the operator applies
//! through its ghost plan (or rank-locally for serial dense blocks).

pub mod bicgstab;
pub mod direct;
pub mod gmres;
pub mod mixed;
pub mod precond;
pub mod richardson;
pub mod tfqmr;

use crate::comm::Comm;
use crate::linalg::dist::{dist_norm2, DistCsr, GhostBuf, Partition};
use crate::linalg::{Csr, DenseMat};
pub use mixed::solve_mixed;
pub use precond::Precond;

/// A distributed square linear operator `A` with the shape of a policy
/// system `I − γ P_π` (PETSc's matrix-free shell `Mat` + the hooks the KSP
/// stack needs). Rows and the vector space share one block [`Partition`].
///
/// Implementations: [`LinOp`] (assembled CSR),
/// [`crate::mdp::matfree::MatFreePolicyOp`] (fused matrix-free policy
/// evaluation straight off the stacked transition kernel), [`DenseOp`]
/// (dense accelerator block).
pub trait Apply {
    /// Number of locally owned rows (= local length of every vector).
    fn local_rows(&self) -> usize;

    /// The global row/column partition (the operator is square).
    fn partition(&self) -> Partition;

    /// Allocate the `[owned | ghost]` buffer [`Self::apply`] needs.
    fn make_buffer(&self) -> GhostBuf;

    /// y ← A x. Collective across the world.
    fn apply(&self, comm: &Comm, x: &[f64], y: &mut [f64], buf: &mut GhostBuf);

    /// Local diagonal of A (Jacobi-style preconditioning). `out` has
    /// [`Self::local_rows`] entries.
    fn diag(&self, out: &mut [f64]);

    /// The rank-local block of A in CSR form — columns restricted to the
    /// owned range `[0, local_rows)`, off-rank couplings dropped. This is
    /// the block-Jacobi view local preconditioners (SOR) sweep over.
    fn local_block(&self) -> Csr;

    /// Local rows of A as `(global_col, value)` lists, duplicates additive
    /// — the gathered direct solver densifies these. O(local nnz); only
    /// sensible for small systems.
    fn materialize_rows(&self) -> Vec<Vec<(usize, f64)>>;

    /// r ← b − A·x. Returns global ‖r‖₂. Collective.
    fn residual(
        &self,
        comm: &Comm,
        b: &[f64],
        x: &[f64],
        r: &mut [f64],
        buf: &mut GhostBuf,
    ) -> f64 {
        self.apply(comm, x, r, buf);
        for (ri, bi) in r.iter_mut().zip(b) {
            *ri = bi - *ri;
        }
        dist_norm2(comm, r)
    }
}

/// The linear operator `A = I − diag(γ) P` over an **assembled**
/// distributed policy-transition matrix (the `Assembled` evaluation
/// backend). `γ` is either one scalar (classic discounting) or a
/// per-local-row factor vector (`γ_π` for semi-MDPs, see
/// [`crate::mdp::Discount`]); a constant vector applies bit-identically
/// to the scalar because both paths multiply the same f64 per row.
pub struct LinOp<'a> {
    p: &'a DistCsr,
    gamma: f64,
    /// Per-local-row discounts `γ_π(s)`; overrides `gamma` when set.
    row_discounts: Option<&'a [f64]>,
}

impl<'a> LinOp<'a> {
    /// Operator `I - gamma P` over the assembled distributed CSR `p`.
    pub fn new(p: &'a DistCsr, gamma: f64) -> Self {
        assert_eq!(
            p.local_nrows(),
            p.col_partition().local_len(p.rank()),
            "LinOp requires a square (state × state) policy matrix"
        );
        LinOp {
            p,
            gamma,
            row_discounts: None,
        }
    }

    /// Operator `I − diag(γ_π) P` with one discount factor per local row
    /// (the assembled policy system of a semi-MDP).
    pub fn with_row_discounts(p: &'a DistCsr, discounts: &'a [f64]) -> Self {
        assert_eq!(
            p.local_nrows(),
            p.col_partition().local_len(p.rank()),
            "LinOp requires a square (state × state) policy matrix"
        );
        assert_eq!(
            discounts.len(),
            p.local_nrows(),
            "row discounts must cover the local rows"
        );
        LinOp {
            p,
            gamma: 0.0,
            row_discounts: Some(discounts),
        }
    }

    /// The discount factor applied to local row `i`.
    #[inline]
    fn gamma_row(&self, i: usize) -> f64 {
        match self.row_discounts {
            Some(g) => g[i],
            None => self.gamma,
        }
    }

    /// Local diagonal of A as a vector (convenience over [`Apply::diag`]).
    pub fn diagonal(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.local_rows()];
        self.diag(&mut d);
        d
    }
}

impl Apply for LinOp<'_> {
    fn local_rows(&self) -> usize {
        self.p.local_nrows()
    }

    fn partition(&self) -> Partition {
        self.p.col_partition()
    }

    fn make_buffer(&self) -> GhostBuf {
        self.p.make_buffer()
    }

    fn apply(&self, comm: &Comm, x: &[f64], y: &mut [f64], buf: &mut GhostBuf) {
        self.p.spmv(comm, x, y, buf);
        match self.row_discounts {
            None => {
                for (yi, xi) in y.iter_mut().zip(x) {
                    *yi = xi - self.gamma * *yi;
                }
            }
            Some(g) => {
                for (i, (yi, xi)) in y.iter_mut().zip(x).enumerate() {
                    *yi = xi - g[i] * *yi;
                }
            }
        }
    }

    fn diag(&self, out: &mut [f64]) {
        let local = self.p.local();
        for (i, o) in out.iter_mut().enumerate() {
            *o = 1.0 - self.gamma_row(i) * local.get(i, i);
        }
    }

    fn local_block(&self) -> Csr {
        let nl = self.local_rows();
        let p_local = self.p.local();
        let mut rows: Vec<Vec<(usize, f64)>> = Vec::with_capacity(nl);
        for i in 0..nl {
            let (cols, vals) = p_local.row(i);
            let gamma = self.gamma_row(i);
            let mut row: Vec<(usize, f64)> = vec![(i, 1.0)];
            for (&c, &v) in cols.iter().zip(vals) {
                if c < nl {
                    row.push((c, -gamma * v));
                }
            }
            rows.push(row);
        }
        Csr::from_row_lists(nl, rows)
    }

    fn materialize_rows(&self) -> Vec<Vec<(usize, f64)>> {
        let nl = self.local_rows();
        let lo = self.p.col_partition().lo(self.p.rank());
        let local = self.p.local();
        (0..nl)
            .map(|i| {
                let (cols, vals) = local.row(i);
                let gamma = self.gamma_row(i);
                let mut row: Vec<(usize, f64)> = Vec::with_capacity(cols.len() + 1);
                row.push((lo + i, 1.0));
                for (&c, &v) in cols.iter().zip(vals) {
                    row.push((self.p.global_col(c), -gamma * v));
                }
                row
            })
            .collect()
    }
}

/// `A = I − γ P` over a **dense** rank-local transition block — the dense
/// accelerator path (`examples/dense_accelerator.rs`, [`crate::runtime`])
/// routed through the same KSP stack as the sparse solvers. Serial by
/// construction: dense blocks are not partitioned across ranks.
pub struct DenseOp<'a> {
    p: &'a DenseMat,
    gamma: f64,
}

impl<'a> DenseOp<'a> {
    /// Operator `I - gamma P` over the dense block `p` (serial).
    pub fn new(p: &'a DenseMat, gamma: f64) -> Self {
        assert_eq!(p.nrows(), p.ncols(), "DenseOp requires a square matrix");
        DenseOp { p, gamma }
    }
}

impl Apply for DenseOp<'_> {
    fn local_rows(&self) -> usize {
        self.p.nrows()
    }

    fn partition(&self) -> Partition {
        Partition::new(self.p.nrows(), 1)
    }

    fn make_buffer(&self) -> GhostBuf {
        GhostBuf::new(self.p.nrows(), 0)
    }

    fn apply(&self, comm: &Comm, x: &[f64], y: &mut [f64], _buf: &mut GhostBuf) {
        assert_eq!(comm.size(), 1, "DenseOp is a rank-local operator");
        let n = self.p.nrows();
        assert_eq!(x.len(), n);
        assert_eq!(y.len(), n);
        // Row-parallel over the rank's worker pool; the per-row dot nests
        // inside the region and therefore runs inline over the same fixed
        // chunk grid — bitwise identical for any thread count.
        crate::util::par::par_for_rows(y, |offset, chunk| {
            for (i, yr) in chunk.iter_mut().enumerate() {
                let r = offset + i;
                *yr = x[r] - self.gamma * crate::linalg::dot(self.p.row(r), x);
            }
        });
    }

    fn diag(&self, out: &mut [f64]) {
        for (i, o) in out.iter_mut().enumerate() {
            *o = 1.0 - self.gamma * self.p[(i, i)];
        }
    }

    fn local_block(&self) -> Csr {
        Csr::from_row_lists(self.p.nrows(), self.materialize_rows())
    }

    fn materialize_rows(&self) -> Vec<Vec<(usize, f64)>> {
        let n = self.p.nrows();
        (0..n)
            .map(|r| {
                let mut row: Vec<(usize, f64)> = Vec::with_capacity(n + 1);
                row.push((r, 1.0));
                for (c, &v) in self.p.row(r).iter().enumerate() {
                    if v != 0.0 {
                        row.push((c, -self.gamma * v));
                    }
                }
                row
            })
            .collect()
    }
}

/// Inner solver selector (madupite's `-ksp_type`).
#[derive(Clone, Debug, PartialEq)]
pub enum KspType {
    /// Richardson iteration with relaxation ω (ω=1 ⇒ T_π sweeps).
    Richardson { omega: f64 },
    /// Restarted GMRES with Krylov dimension `restart`.
    Gmres { restart: usize },
    /// BiCGStab (van der Vorst).
    BiCgStab,
    /// Transpose-free QMR (Freund).
    Tfqmr,
    /// Gathered dense LU — exact solve, small problems only.
    Direct,
}

impl KspType {
    /// Parse the `-ksp_type` option string.
    pub fn parse(name: &str) -> Result<KspType, String> {
        Ok(match name {
            "richardson" => KspType::Richardson { omega: 1.0 },
            "gmres" => KspType::Gmres { restart: 30 },
            "bicgstab" | "bcgs" => KspType::BiCgStab,
            "tfqmr" => KspType::Tfqmr,
            "direct" | "preonly" => KspType::Direct,
            other => return Err(format!("unknown ksp_type '{other}'")),
        })
    }

    /// Canonical option-string form (inverse of [`Self::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            KspType::Richardson { .. } => "richardson",
            KspType::Gmres { .. } => "gmres",
            KspType::BiCgStab => "bicgstab",
            KspType::Tfqmr => "tfqmr",
            KspType::Direct => "direct",
        }
    }
}

/// Stopping control for the inner solve.
#[derive(Clone, Copy, Debug)]
pub struct Tolerance {
    /// Absolute ℓ₂ target on the residual.
    pub atol: f64,
    /// Relative (to ‖r₀‖₂) target.
    pub rtol: f64,
    /// Iteration cap.
    pub max_iters: usize,
}

impl Default for Tolerance {
    fn default() -> Self {
        Tolerance {
            atol: 1e-12,
            rtol: 1e-8,
            max_iters: 10_000,
        }
    }
}

impl Tolerance {
    /// The residual threshold given the initial residual norm.
    pub fn threshold(&self, r0: f64) -> f64 {
        self.atol.max(self.rtol * r0)
    }
}

/// Outcome of an inner solve.
#[derive(Clone, Debug, Default)]
pub struct KspStats {
    /// Iterations executed.
    pub iterations: usize,
    /// Operator applications (the unit the iPI papers count cost in).
    pub spmvs: usize,
    /// ℓ₂ residual before the solve.
    pub initial_residual: f64,
    /// ℓ₂ residual after the solve.
    pub final_residual: f64,
    /// Whether the tolerance was met within the cap.
    pub converged: bool,
}

/// Dispatch an inner solve: `x` holds the warm start on entry, the solution
/// on exit. Collective across the world.
pub fn solve(
    method: &KspType,
    pc: &Precond,
    comm: &Comm,
    a: &dyn Apply,
    b: &[f64],
    x: &mut [f64],
    tol: &Tolerance,
) -> KspStats {
    match method {
        KspType::Richardson { omega } => richardson::solve(comm, a, pc, b, x, tol, *omega),
        KspType::Gmres { restart } => gmres::solve(comm, a, pc, b, x, tol, *restart),
        KspType::BiCgStab => bicgstab::solve(comm, a, pc, b, x, tol),
        KspType::Tfqmr => tfqmr::solve(comm, a, pc, b, x, tol),
        KspType::Direct => direct::solve(comm, a, b, x),
    }
}

#[cfg(test)]
pub(crate) mod testmat {
    //! Shared test fixtures: random γ-contraction systems.
    use crate::comm::Comm;
    use crate::linalg::dist::{DistCsr, Partition};
    use crate::util::prng::Xoshiro256pp;

    /// Build a random row-stochastic transition matrix distributed over the
    /// world, returning (P, b, partition) on each rank.
    pub fn random_policy_system(
        comm: &Comm,
        n: usize,
        seed: u64,
    ) -> (DistCsr, Vec<f64>, Partition) {
        let part = Partition::new(n, comm.size());
        let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
        let mut rows = Vec::with_capacity(hi - lo);
        for i in lo..hi {
            // deterministic per-row seed → identical matrix for any world size
            let mut rng = Xoshiro256pp::new(seed ^ (i as u64).wrapping_mul(0x9E37));
            let k = 1 + rng.index(4);
            let cols: Vec<usize> = (0..k).map(|_| rng.index(n)).collect();
            let mut row: Vec<(usize, f64)> = Vec::new();
            let probs = rng.prob_vector(cols.len());
            for (c, p) in cols.into_iter().zip(probs) {
                row.push((c, p));
            }
            rows.push(row);
        }
        let p = DistCsr::assemble(comm, part, rows);
        let b: Vec<f64> = (lo..hi)
            .map(|i| {
                let mut rng = Xoshiro256pp::new(seed ^ 0xB0B ^ (i as u64) << 1);
                rng.range_f64(0.0, 1.0)
            })
            .collect();
        (p, b, part)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;
    use crate::util::prop;

    #[test]
    fn ksp_type_parse() {
        assert_eq!(
            KspType::parse("gmres").unwrap(),
            KspType::Gmres { restart: 30 }
        );
        assert_eq!(KspType::parse("bcgs").unwrap(), KspType::BiCgStab);
        assert!(KspType::parse("nope").is_err());
        assert_eq!(KspType::parse("tfqmr").unwrap().name(), "tfqmr");
    }

    #[test]
    fn tolerance_threshold() {
        let t = Tolerance {
            atol: 1e-10,
            rtol: 1e-2,
            max_iters: 10,
        };
        assert_eq!(t.threshold(1.0), 1e-2);
        assert_eq!(t.threshold(1e-9), 1e-10);
    }

    #[test]
    fn linop_apply_identity_when_gamma_zero() {
        World::run(2, |comm| {
            let (p, b, part) = testmat::random_policy_system(&comm, 10, 3);
            let a = LinOp::new(&p, 0.0);
            let mut buf = a.make_buffer();
            let nl = part.local_len(comm.rank());
            let mut y = vec![0.0; nl];
            a.apply(&comm, &b, &mut y, &mut buf);
            assert_eq!(y, b);
        });
    }

    #[test]
    fn linop_residual_zero_at_solution() {
        // For x solving (I-γP)x = b the residual must be ~0; test with the
        // trivial γ=0 case where x = b.
        World::run(1, |comm| {
            let (p, b, _) = testmat::random_policy_system(&comm, 8, 5);
            let a = LinOp::new(&p, 0.0);
            let mut buf = a.make_buffer();
            let mut r = vec![0.0; 8];
            let nrm = a.residual(&comm, &b, &b, &mut r, &mut buf);
            assert!(nrm < 1e-14);
        });
    }

    #[test]
    fn linop_diagonal() {
        World::run(1, |comm| {
            let part = Partition::new(2, 1);
            let rows = vec![vec![(0, 0.5), (1, 0.5)], vec![(1, 1.0)]];
            let p = DistCsr::assemble(&comm, part, rows);
            let a = LinOp::new(&p, 0.9);
            let d = a.diagonal();
            assert!((d[0] - (1.0 - 0.45)).abs() < 1e-15);
            assert!((d[1] - (1.0 - 0.9)).abs() < 1e-15);
        });
    }

    #[test]
    fn linop_materialize_rows_densifies_to_a() {
        World::run(2, |comm| {
            let (p, _, part) = testmat::random_policy_system(&comm, 12, 9);
            let gamma = 0.8;
            let a = LinOp::new(&p, gamma);
            let lo = part.lo(comm.rank());
            let rows = a.materialize_rows();
            assert_eq!(rows.len(), a.local_rows());
            // densify and compare against apply on unit vectors (serial
            // reconstruction is overkill; check the diagonal instead)
            let mut d = vec![0.0; a.local_rows()];
            a.diag(&mut d);
            for (i, row) in rows.iter().enumerate() {
                let diag: f64 = row
                    .iter()
                    .filter(|&&(c, _)| c == lo + i)
                    .map(|&(_, v)| v)
                    .sum();
                assert!((diag - d[i]).abs() < 1e-14, "row {i}: {diag} vs {}", d[i]);
            }
        });
    }

    #[test]
    fn dense_op_matches_linop() {
        // The same transition matrix through DenseOp and assembled LinOp
        // must give identical apply / diag / residual results.
        World::run(1, |comm| {
            let (p, b, _) = testmat::random_policy_system(&comm, 10, 21);
            let gamma = 0.9;
            let sparse = LinOp::new(&p, gamma);
            // densify P (serial world → local columns are global columns)
            let mut pd = DenseMat::zeros(10, 10);
            let local = p.local();
            for r in 0..10 {
                let (cols, vals) = local.row(r);
                for (&c, &v) in cols.iter().zip(vals) {
                    pd[(r, p.global_col(c))] = v;
                }
            }
            let dense = DenseOp::new(&pd, gamma);
            assert_eq!(dense.local_rows(), sparse.local_rows());

            let x: Vec<f64> = (0..10).map(|i| (i as f64).cos()).collect();
            let mut ys = vec![0.0; 10];
            let mut yd = vec![0.0; 10];
            let mut bs = sparse.make_buffer();
            let mut bd = dense.make_buffer();
            sparse.apply(&comm, &x, &mut ys, &mut bs);
            dense.apply(&comm, &x, &mut yd, &mut bd);
            prop::close_slices(&ys, &yd, 1e-14).unwrap();

            let mut ds = vec![0.0; 10];
            let mut dd = vec![0.0; 10];
            sparse.diag(&mut ds);
            dense.diag(&mut dd);
            prop::close_slices(&ds, &dd, 1e-14).unwrap();

            let mut r = vec![0.0; 10];
            let rs = sparse.residual(&comm, &b, &x, &mut r, &mut bs);
            let rd = dense.residual(&comm, &b, &x, &mut r, &mut bd);
            assert!((rs - rd).abs() < 1e-12);
        });
    }

    #[test]
    fn dense_op_solves_through_gmres() {
        World::run(1, |comm| {
            let (p, b, _) = testmat::random_policy_system(&comm, 14, 33);
            let gamma = 0.95;
            let mut pd = DenseMat::zeros(14, 14);
            let local = p.local();
            for r in 0..14 {
                let (cols, vals) = local.row(r);
                for (&c, &v) in cols.iter().zip(vals) {
                    pd[(r, p.global_col(c))] = v;
                }
            }
            let dense = DenseOp::new(&pd, gamma);
            let mut x = vec![0.0; 14];
            let tol = Tolerance {
                atol: 1e-11,
                rtol: 0.0,
                max_iters: 1_000,
            };
            let stats = gmres::solve(&comm, &dense, &Precond::None, &b, &mut x, &tol, 14);
            assert!(stats.converged, "final={}", stats.final_residual);
            // verify against the sparse path
            let sparse = LinOp::new(&p, gamma);
            let mut xs = vec![0.0; 14];
            gmres::solve(&comm, &sparse, &Precond::None, &b, &mut xs, &tol, 14);
            prop::close_slices(&x, &xs, 1e-8).unwrap();
        });
    }

    use crate::linalg::dist::DistCsr;
}
