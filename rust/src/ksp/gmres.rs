//! Restarted GMRES(m) — the workhorse inner solver of inexact GMRES policy
//! iteration (Gargiani et al., 2023).
//!
//! Left-preconditioned, modified Gram–Schmidt Arnoldi, Givens-rotation QR of
//! the Hessenberg matrix, residual norm tracked for free from the rotations.
//! All inner products are distributed reductions; each Arnoldi step costs
//! one SpMV + one ghost exchange, matching the cost model the iPI paper
//! counts.
//!
//! Reduction pipelining (DESIGN.md §14): at initialization and at every
//! restart the raw residual norm ‖b − Ax‖ and the preconditioned norm
//! ‖M⁻¹(b − Ax)‖ are needed back to back with only local work between
//! them, so [`residual_pair`] fuses both into a single
//! [`Comm::allreduce_f64s`] — square roots taken *after* the reduction, so
//! each norm is bit-for-bit the value the unfused pair of collectives
//! produced. The modified Gram–Schmidt projections are sequentially
//! dependent (h_{ij} feeds the very next vector update) and cannot fuse.

use super::{Apply, KspStats, Precond, Tolerance};
use crate::comm::{Comm, Reduce};
use crate::linalg::dist::{dist_dot, dist_norm2};
use crate::linalg::dot;

/// Compute `r = b − Ax` and `z = M⁻¹ r`, returning `(‖r‖₂, ‖z‖₂)` with the
/// two norm reductions fused into one collective. Bitwise identical to
/// [`Apply::residual`] followed by a separate `dist_norm2(z)`.
#[allow(clippy::too_many_arguments)]
fn residual_pair(
    comm: &Comm,
    a: &dyn Apply,
    pc: &Precond,
    b: &[f64],
    x: &[f64],
    r: &mut [f64],
    z: &mut [f64],
    buf: &mut crate::linalg::dist::GhostBuf,
) -> (f64, f64) {
    a.apply(comm, x, r, buf);
    for (ri, bi) in r.iter_mut().zip(b) {
        *ri = bi - *ri;
    }
    pc.apply(r, z);
    let sums = comm.allreduce_f64s(&[dot(r, r), dot(z, z)], Reduce::Sum);
    (sums[0].sqrt(), sums[1].sqrt())
}

/// Solve `A x = b` with restarted GMRES(m). `x` carries the warm start.
pub fn solve(
    comm: &Comm,
    a: &dyn Apply,
    pc: &Precond,
    b: &[f64],
    x: &mut [f64],
    tol: &Tolerance,
    restart: usize,
) -> KspStats {
    let nl = a.local_rows();
    assert_eq!(b.len(), nl);
    assert_eq!(x.len(), nl);
    let m = restart.max(1);
    let mut buf = a.make_buffer();

    let mut stats = KspStats::default();
    let mut r = vec![0.0; nl];
    let mut z = vec![0.0; nl];
    let mut w = vec![0.0; nl];

    // Krylov basis (m+1 vectors of local length).
    let mut v: Vec<Vec<f64>> = (0..=m).map(|_| vec![0.0; nl]).collect();
    // Hessenberg (column-major packed: h[j] has j+2 entries).
    let mut h: Vec<Vec<f64>> = (0..m).map(|j| vec![0.0; j + 2]).collect();
    let (mut cs, mut sn) = (vec![0.0; m], vec![0.0; m]);
    let mut g = vec![0.0; m + 1];

    // Initial (preconditioned) residual — both norms in one reduction.
    let (raw0, mut beta) = residual_pair(comm, a, pc, b, x, &mut r, &mut z, &mut buf);
    stats.spmvs += 1;
    stats.initial_residual = raw0;
    // Threshold in the preconditioned norm; for PC=None they coincide.
    let target = tol.threshold(if pc.is_identity() { raw0 } else { beta });

    if beta <= target {
        stats.final_residual = raw0;
        stats.converged = true;
        return stats;
    }

    'outer: loop {
        // v0 = z / beta
        for (vi, zi) in v[0].iter_mut().zip(&z) {
            *vi = zi / beta;
        }
        g.iter_mut().for_each(|gi| *gi = 0.0);
        g[0] = beta;
        let mut k_used = 0;

        for j in 0..m {
            // w = M⁻¹ A v_j
            a.apply(comm, &v[j], &mut w, &mut buf);
            stats.spmvs += 1;
            let mut mw = vec![0.0; nl];
            pc.apply(&w, &mut mw);
            // modified Gram–Schmidt
            for i in 0..=j {
                let hij = dist_dot(comm, &mw, &v[i]);
                h[j][i] = hij;
                for (wk, vk) in mw.iter_mut().zip(&v[i]) {
                    *wk -= hij * vk;
                }
            }
            let hlast = dist_norm2(comm, &mw);
            h[j][j + 1] = hlast;
            if hlast > 1e-300 {
                for (vk, wk) in v[j + 1].iter_mut().zip(&mw) {
                    *vk = wk / hlast;
                }
            }
            // apply accumulated Givens rotations to the new column
            for i in 0..j {
                let t = cs[i] * h[j][i] + sn[i] * h[j][i + 1];
                h[j][i + 1] = -sn[i] * h[j][i] + cs[i] * h[j][i + 1];
                h[j][i] = t;
            }
            // new rotation to annihilate h[j][j+1]
            let (c, s) = givens(h[j][j], h[j][j + 1]);
            cs[j] = c;
            sn[j] = s;
            h[j][j] = c * h[j][j] + s * h[j][j + 1];
            h[j][j + 1] = 0.0;
            let gj = g[j];
            g[j] = c * gj;
            g[j + 1] = -s * gj;

            stats.iterations += 1;
            k_used = j + 1;
            let rnorm_est = g[j + 1].abs();
            if rnorm_est <= target || hlast <= 1e-300 {
                break;
            }
            if stats.iterations >= tol.max_iters {
                break;
            }
        }

        // back-substitute y from the k_used×k_used triangular system
        let mut y = vec![0.0; k_used];
        for i in (0..k_used).rev() {
            let mut acc = g[i];
            for j2 in i + 1..k_used {
                acc -= h[j2][i] * y[j2];
            }
            y[i] = acc / h[i][i];
        }
        // x += V y
        for (j2, yj) in y.iter().enumerate() {
            for (xi, vi) in x.iter_mut().zip(&v[j2]) {
                *xi += yj * vi;
            }
        }

        // true residual for the restart / convergence decision — raw and
        // preconditioned norms fused into one reduction
        let (raw, beta_new) = residual_pair(comm, a, pc, b, x, &mut r, &mut z, &mut buf);
        beta = beta_new;
        stats.spmvs += 1;
        let check = if pc.is_identity() { raw } else { beta };
        stats.final_residual = raw;
        if check <= target {
            stats.converged = true;
            break 'outer;
        }
        if stats.iterations >= tol.max_iters {
            break 'outer;
        }
    }
    stats
}

/// Stable Givens rotation coefficients.
fn givens(a: f64, b: f64) -> (f64, f64) {
    if b == 0.0 {
        (1.0, 0.0)
    } else if a.abs() < b.abs() {
        let t = a / b;
        let s = 1.0 / (1.0 + t * t).sqrt();
        (s * t, s)
    } else {
        let t = b / a;
        let c = 1.0 / (1.0 + t * t).sqrt();
        (c, c * t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;
    use crate::ksp::precond::PcType;
    use crate::ksp::testmat::random_policy_system;
    use crate::ksp::LinOp;
    use crate::util::prop;

    fn run_gmres(n: usize, size: usize, gamma: f64, restart: usize, pc_type: PcType) -> Vec<f64> {
        let out = World::run(size, move |comm| {
            let (p, b, part) = random_policy_system(&comm, n, 42);
            let a = LinOp::new(&p, gamma);
            let pc = Precond::build(pc_type, &a);
            let nl = part.local_len(comm.rank());
            let mut x = vec![0.0; nl];
            let tol = Tolerance {
                atol: 1e-11,
                rtol: 0.0,
                max_iters: 5_000,
            };
            let stats = solve(&comm, &a, &pc, &b, &mut x, &tol, restart);
            assert!(
                stats.converged,
                "gmres not converged: final={}",
                stats.final_residual
            );
            x
        });
        out.into_iter().flatten().collect()
    }

    #[test]
    fn solves_serial() {
        let x = run_gmres(30, 1, 0.9, 30, PcType::None);
        assert_eq!(x.len(), 30);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn distributed_matches_serial() {
        let xs = run_gmres(40, 1, 0.95, 20, PcType::None);
        let xd = run_gmres(40, 3, 0.95, 20, PcType::None);
        prop::close_slices(&xs, &xd, 1e-8).unwrap();
    }

    #[test]
    fn restart_smaller_than_dimension_still_converges() {
        let x = run_gmres(50, 2, 0.99, 5, PcType::None);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn preconditioned_variants_agree() {
        let x0 = run_gmres(35, 1, 0.95, 30, PcType::None);
        let xj = run_gmres(35, 1, 0.95, 30, PcType::Jacobi);
        let xs = run_gmres(35, 1, 0.95, 30, PcType::Sor);
        prop::close_slices(&x0, &xj, 1e-7).unwrap();
        prop::close_slices(&x0, &xs, 1e-7).unwrap();
    }

    #[test]
    fn gmres_exact_in_n_iterations() {
        // Full GMRES (restart >= n) solves exactly within n steps.
        World::run(1, |comm| {
            let (p, b, _) = random_policy_system(&comm, 10, 77);
            let a = LinOp::new(&p, 0.9999);
            // atol leaves headroom for κ(A) ≈ 1/(1−γ) = 1e4 in f64.
            let tol = Tolerance {
                atol: 1e-10,
                rtol: 0.0,
                max_iters: 10,
            };
            let mut x = vec![0.0; 10];
            let stats = solve(&comm, &a, &Precond::None, &b, &mut x, &tol, 10);
            assert!(stats.converged, "final={}", stats.final_residual);
            assert!(stats.iterations <= 10);
        });
    }

    #[test]
    fn gmres_beats_richardson_on_high_gamma() {
        // The iPI headline: Krylov >> fixed-point when γ → 1.
        World::run(1, |comm| {
            let (p, b, _) = random_policy_system(&comm, 60, 31);
            let a = LinOp::new(&p, 0.999);
            let tol = Tolerance {
                atol: 1e-9,
                rtol: 0.0,
                max_iters: 100_000,
            };
            let mut xg = vec![0.0; 60];
            let sg = solve(&comm, &a, &Precond::None, &b, &mut xg, &tol, 30);
            let mut xr = vec![0.0; 60];
            let sr = crate::ksp::richardson::solve(
                &comm,
                &a,
                &Precond::None,
                &b,
                &mut xr,
                &tol,
                1.0,
            );
            assert!(sg.converged && sr.converged);
            assert!(
                sg.spmvs * 5 < sr.spmvs,
                "gmres {} vs richardson {} spmvs",
                sg.spmvs,
                sr.spmvs
            );
        });
    }

    #[test]
    fn zero_rhs_immediate_convergence() {
        World::run(1, |comm| {
            let (p, _, _) = random_policy_system(&comm, 8, 3);
            let a = LinOp::new(&p, 0.9);
            let b = vec![0.0; 8];
            let mut x = vec![0.0; 8];
            let stats = solve(
                &comm,
                &a,
                &Precond::None,
                &b,
                &mut x,
                &Tolerance::default(),
                30,
            );
            assert!(stats.converged);
            assert_eq!(stats.iterations, 0);
        });
    }

    #[test]
    fn givens_annihilates() {
        let (c, s) = givens(3.0, 4.0);
        let r = c * 3.0 + s * 4.0;
        let zero = -s * 3.0 + c * 4.0;
        assert!((r - 5.0).abs() < 1e-12);
        assert!(zero.abs() < 1e-12);
        assert_eq!(givens(1.0, 0.0), (1.0, 0.0));
    }
}
