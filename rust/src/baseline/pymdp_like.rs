//! `pymdptoolbox`-style baseline: dense per-action transition matrices and
//! plain value iteration.
//!
//! pymdptoolbox stores `P` as an `(A, S, S)` dense array (unless the user
//! hands it scipy.sparse, which the toolbox then still traverses row by
//! row in Python). The relevant structural properties reproduced here:
//! O(A·S²) memory regardless of sparsity, full dense matvec per backup,
//! and value iteration as the default algorithm with the span-based
//! stopping rule of Puterman §6.3.2.

use super::BaselineResult;
use crate::linalg::DenseMat;
use crate::mdp::Mdp;

/// Dense-tensor MDP replica.
pub struct DenseMdp {
    /// One dense S×S matrix per action.
    pub p: Vec<DenseMat>,
    /// costs[a][s]
    pub costs: Vec<Vec<f64>>,
    /// Discount factor.
    pub gamma: f64,
}

impl DenseMdp {
    /// Densify a sparse [`Mdp`] into the baseline layout. Scalar-discount
    /// MDPs only: the baseline algorithms model one γ, so a semi-MDP
    /// ([`crate::mdp::Discount`] vector modes) would be silently collapsed
    /// to its bound — refused loudly instead.
    pub fn from_mdp(mdp: &Mdp) -> DenseMdp {
        assert!(
            mdp.discount().as_scalar().is_some(),
            "baseline solvers support scalar discounting only (got {})",
            mdp.discount().mode().name()
        );
        let (n, m) = (mdp.n_states(), mdp.n_actions());
        let mut p = Vec::with_capacity(m);
        let mut costs = Vec::with_capacity(m);
        for a in 0..m {
            let mut mat = DenseMat::zeros(n, n);
            let mut c = Vec::with_capacity(n);
            for s in 0..n {
                let (cols, vals) = mdp.transitions().row(s * m + a);
                for (&col, &v) in cols.iter().zip(vals) {
                    mat[(s, col)] = v;
                }
                c.push(mdp.cost(s, a));
            }
            p.push(mat);
            costs.push(c);
        }
        DenseMdp {
            p,
            costs,
            gamma: mdp.gamma(),
        }
    }

    /// Number of states.
    pub fn n_states(&self) -> usize {
        self.costs.first().map(|c| c.len()).unwrap_or(0)
    }

    /// Total memory of the dense tables (bytes).
    pub fn storage_bytes(&self) -> usize {
        let n = self.n_states();
        self.p.len() * n * n * 8 + self.costs.len() * n * 8
    }

    /// Plain value iteration with the ε(1−γ)/2γ span stopping rule.
    pub fn solve_vi(&self, epsilon: f64, max_iter: usize) -> BaselineResult {
        let n = self.n_states();
        let m = self.p.len();
        let mut v = vec![0.0; n];
        let mut policy = vec![0usize; n];
        let threshold = if self.gamma > 0.0 {
            epsilon * (1.0 - self.gamma) / (2.0 * self.gamma)
        } else {
            epsilon
        };
        let mut iterations = 0;
        let mut converged = false;
        while iterations < max_iter {
            iterations += 1;
            // dense backups: full matvec per action (the structural cost)
            let mut tv = vec![f64::INFINITY; n];
            for a in 0..m {
                let pv = self.p[a].mul_vec(&v);
                for s in 0..n {
                    let q = self.costs[a][s] + self.gamma * pv[s];
                    if q < tv[s] {
                        tv[s] = q;
                        policy[s] = a;
                    }
                }
            }
            // span(TV − V) stopping rule
            let mut mn = f64::INFINITY;
            let mut mx = f64::NEG_INFINITY;
            for s in 0..n {
                let d = tv[s] - v[s];
                mn = mn.min(d);
                mx = mx.max(d);
            }
            v = tv;
            if mx - mn < threshold {
                converged = true;
                break;
            }
        }
        BaselineResult {
            storage_bytes: self.storage_bytes(),
            value: v,
            policy,
            iterations,
            converged,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mdp::fixtures::{random_mdp, two_state};
    use crate::solver::{solve_serial, SolveOptions};
    use crate::util::prop;

    #[test]
    fn dense_conversion_row_stochastic() {
        let mdp = random_mdp(1, 10, 2, 0.9);
        let d = DenseMdp::from_mdp(&mdp);
        for a in 0..2 {
            for s in 0..10 {
                let sum: f64 = d.p[a].row(s).iter().sum();
                assert!((sum - 1.0).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn solves_analytic_mdp() {
        let mdp = two_state(0.5, 1.5);
        let d = DenseMdp::from_mdp(&mdp);
        let r = d.solve_vi(1e-8, 10_000);
        assert!(r.converged);
        prop::close_slices(&r.value, &[1.5, 0.0], 1e-6).unwrap();
        assert_eq!(r.policy[0], 1);
    }

    #[test]
    fn policy_agrees_with_madupite() {
        let mdp = random_mdp(29, 25, 3, 0.9);
        let ours = solve_serial(
            &mdp,
            &SolveOptions {
                atol: 1e-10,
                ..Default::default()
            },
        );
        let d = DenseMdp::from_mdp(&mdp);
        let vi = d.solve_vi(1e-9, 100_000);
        assert!(vi.converged);
        // span-rule VI yields an ε-optimal policy; policies should agree
        let mismatches = ours
            .policy
            .iter()
            .zip(&vi.policy)
            .filter(|(a, b)| a != b)
            .count();
        assert!(mismatches <= 1, "policies differ in {mismatches} states");
    }

    #[test]
    fn dense_storage_quadratic() {
        let mdp = random_mdp(2, 50, 2, 0.9);
        let d = DenseMdp::from_mdp(&mdp);
        // 2 actions × 50×50 × 8 bytes = 40 kB ≫ sparse CSR
        assert_eq!(d.storage_bytes(), 2 * 50 * 50 * 8 + 2 * 50 * 8);
        assert!(d.storage_bytes() > 10 * mdp.transitions().storage_bytes());
    }
}
