//! `mdpsolver`-style baseline: nested-vector storage + modified policy
//! iteration (the only method mdpsolver provides).
//!
//! Deliberately reproduces the design the paper criticizes: transitions are
//! `Vec<Vec<Vec<(u32, f64)>>>` indexed `[state][action][k]` — a pointer
//! chase per state–action pair, no CSR, no reusable SpMV kernel — and the
//! value update walks that structure directly. Used by bench E5 to show the
//! structural gap madupite's PETSc-style storage closes.

use super::BaselineResult;
use crate::mdp::Mdp;

/// Nested-vector MDP replica.
pub struct NestedVecMdp {
    /// transitions[s][a] = list of (successor, probability)
    pub transitions: Vec<Vec<Vec<(u32, f64)>>>,
    /// rewards[s][a] (mdpsolver is reward-maximizing; we keep costs and
    /// minimize to stay comparable)
    pub costs: Vec<Vec<f64>>,
    /// Discount factor.
    pub gamma: f64,
}

impl NestedVecMdp {
    /// Convert from the madupite representation (what a user migrating
    /// between the tools would do). Scalar-discount MDPs only: the
    /// baseline models one γ, so a semi-MDP ([`crate::mdp::Discount`]
    /// vector modes) would be silently collapsed to its bound — refused
    /// loudly instead.
    pub fn from_mdp(mdp: &Mdp) -> NestedVecMdp {
        assert!(
            mdp.discount().as_scalar().is_some(),
            "baseline solvers support scalar discounting only (got {})",
            mdp.discount().mode().name()
        );
        let (n, m) = (mdp.n_states(), mdp.n_actions());
        let mut transitions = Vec::with_capacity(n);
        let mut costs = Vec::with_capacity(n);
        for s in 0..n {
            let mut per_action = Vec::with_capacity(m);
            let mut c_row = Vec::with_capacity(m);
            for a in 0..m {
                let (cols, vals) = mdp.transitions().row(s * m + a);
                per_action.push(
                    cols.iter()
                        .map(|&c| c as u32)
                        .zip(vals.iter().copied())
                        .collect::<Vec<_>>(),
                );
                c_row.push(mdp.cost(s, a));
            }
            transitions.push(per_action);
            costs.push(c_row);
        }
        NestedVecMdp {
            transitions,
            costs,
            gamma: mdp.gamma(),
        }
    }

    /// Number of states.
    pub fn n_states(&self) -> usize {
        self.transitions.len()
    }

    /// Number of actions.
    pub fn n_actions(&self) -> usize {
        self.transitions.first().map(|t| t.len()).unwrap_or(0)
    }

    /// Approximate heap bytes of the nested structure (three levels of Vec
    /// headers + the payload) — the memory-overhead column of E5.
    pub fn storage_bytes(&self) -> usize {
        let vec_hdr = std::mem::size_of::<Vec<u8>>(); // ptr+len+cap
        let mut total = vec_hdr; // outer
        for per_action in &self.transitions {
            total += vec_hdr;
            for row in per_action {
                total += vec_hdr + row.len() * std::mem::size_of::<(u32, f64)>();
            }
        }
        for c_row in &self.costs {
            total += vec_hdr + c_row.len() * 8;
        }
        total
    }

    /// Modified policy iteration (mdpsolver's algorithm): greedy improvement
    /// + `sweeps` fixed-point evaluation sweeps, until the span of the
    /// Bellman update is below `epsilon`.
    pub fn solve_mpi(&self, epsilon: f64, sweeps: usize, max_iter: usize) -> BaselineResult {
        let n = self.n_states();
        let m = self.n_actions();
        let mut v = vec![0.0; n];
        let mut policy = vec![0usize; n];
        let mut iterations = 0;
        let mut converged = false;

        while iterations < max_iter {
            iterations += 1;
            // greedy improvement + residual, walking the nested vectors
            let mut tv = vec![0.0; n];
            let mut residual = 0.0f64;
            for s in 0..n {
                let mut best = f64::INFINITY;
                let mut best_a = 0;
                for a in 0..m {
                    let mut q = self.costs[s][a];
                    let mut exp = 0.0;
                    for &(t, p) in &self.transitions[s][a] {
                        exp += p * v[t as usize];
                    }
                    q += self.gamma * exp;
                    if q < best {
                        best = q;
                        best_a = a;
                    }
                }
                tv[s] = best;
                policy[s] = best_a;
                residual = residual.max((best - v[s]).abs());
            }
            v = tv;
            if residual < epsilon {
                converged = true;
                break;
            }
            // partial evaluation sweeps under the fixed policy
            for _ in 0..sweeps {
                let mut nv = vec![0.0; n];
                for s in 0..n {
                    let a = policy[s];
                    let mut exp = 0.0;
                    for &(t, p) in &self.transitions[s][a] {
                        exp += p * v[t as usize];
                    }
                    nv[s] = self.costs[s][a] + self.gamma * exp;
                }
                v = nv;
            }
        }

        BaselineResult {
            storage_bytes: self.storage_bytes(),
            value: v,
            policy,
            iterations,
            converged,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mdp::fixtures::{random_mdp, two_state};
    use crate::solver::{solve_serial, SolveOptions};
    use crate::util::prop;

    #[test]
    fn conversion_preserves_data() {
        let mdp = random_mdp(3, 12, 3, 0.9);
        let nv = NestedVecMdp::from_mdp(&mdp);
        assert_eq!(nv.n_states(), 12);
        assert_eq!(nv.n_actions(), 3);
        for s in 0..12 {
            for a in 0..3 {
                let (cols, vals) = mdp.transitions().row(s * 3 + a);
                let row = &nv.transitions[s][a];
                assert_eq!(row.len(), cols.len());
                for (k, &(t, p)) in row.iter().enumerate() {
                    assert_eq!(t as usize, cols[k]);
                    assert_eq!(p, vals[k]);
                }
            }
        }
    }

    #[test]
    fn solves_analytic_mdp() {
        let mdp = two_state(0.5, 1.5);
        let nv = NestedVecMdp::from_mdp(&mdp);
        let r = nv.solve_mpi(1e-10, 10, 10_000);
        assert!(r.converged);
        prop::close_slices(&r.value, &[1.5, 0.0], 1e-7).unwrap();
        assert_eq!(r.policy[0], 1);
    }

    #[test]
    fn agrees_with_madupite() {
        let mdp = random_mdp(19, 30, 3, 0.95);
        let ours = solve_serial(
            &mdp,
            &SolveOptions {
                atol: 1e-10,
                ..Default::default()
            },
        );
        let nv = NestedVecMdp::from_mdp(&mdp);
        let theirs = nv.solve_mpi(1e-10, 20, 100_000);
        assert!(theirs.converged);
        prop::close_slices(&ours.value, &theirs.value, 1e-6).unwrap();
    }

    #[test]
    fn storage_overhead_exceeds_csr() {
        // the nested-vec structure must cost strictly more bytes per nnz
        let mdp = random_mdp(7, 100, 4, 0.9);
        let nv = NestedVecMdp::from_mdp(&mdp);
        assert!(
            nv.storage_bytes() > mdp.transitions().storage_bytes(),
            "nested {} vs csr {}",
            nv.storage_bytes(),
            mdp.transitions().storage_bytes()
        );
    }
}
