//! Comparator toolboxes (paper claim C4).
//!
//! The paper positions madupite against two existing solvers; E5 reproduces
//! that comparison, so both are reimplemented here faithfully **including
//! their design flaws**:
//!
//! - [`mdpsolver_like`]: mimics `mdpsolver` (Reenberg Andersen & Fink
//!   Andersen 2024) — C++ with values and indices in nested `std::vector`s
//!   "independently of their sparsity degree ... precluding the use of
//!   available optimized linear algebra routines" (paper, Statement of
//!   need), and *modified policy iteration only*.
//! - [`pymdp_like`]: mimics `pymdptoolbox` (Chadès et al. 2014) — dense
//!   per-action transition matrices and plain value iteration, no
//!   parallelism.
//!
//! Both are serial by construction (neither original distributes), so E5
//! compares them against `madupite-rs` on one rank — structure, not
//! hardware, is what the experiment isolates.

pub mod mdpsolver_like;
pub mod pymdp_like;

/// Common result shape for the baselines.
#[derive(Clone, Debug)]
pub struct BaselineResult {
    /// Final value vector.
    pub value: Vec<f64>,
    /// Final greedy policy.
    pub policy: Vec<usize>,
    /// Outer iterations executed.
    pub iterations: usize,
    /// Whether the tolerance was met.
    pub converged: bool,
    /// Bytes used by the transition storage (for the memory comparison).
    pub storage_bytes: usize,
}
