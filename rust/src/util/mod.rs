//! Self-contained utility substrates.
//!
//! The offline build environment ships only the `xla` crate closure, so the
//! usual ecosystem crates (`rand`, `serde_json`, `clap`, `criterion`,
//! `proptest`) are reimplemented here at the scale this project needs.

pub mod args;
pub mod benchkit;
pub mod json;
pub mod lru;
pub mod par;
pub mod prng;
pub mod prop;
pub mod simd;
