//! Options database + CLI argument parsing (madupite/PETSc style).
//!
//! madupite inherits PETSc's options-database idiom: every solver knob is a
//! `-key value` pair that can come from the command line or an options file
//! (`-ksp_type gmres -alpha 1e-4 -max_iter_pi 200 ...`). With no `clap`
//! available offline, this module implements that database directly — which
//! is in fact closer to the original system's UX than a derive-macro CLI.

use std::collections::BTreeMap;
use std::fmt;

/// Parse/lookup error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OptError(pub String);

impl fmt::Display for OptError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "option error: {}", self.0)
    }
}
impl std::error::Error for OptError {}

/// An ordered options database: `-key value` pairs plus positional args.
///
/// Flags (keys with no value, e.g. `-verbose`) store an empty string.
#[derive(Debug, Clone, Default)]
pub struct Options {
    map: BTreeMap<String, String>,
    positional: Vec<String>,
    /// Keys that were queried at least once — `report_unused` uses this to
    /// flag typos, mirroring PETSc's `-options_left`.
    used: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Options {
    /// Parse from an argv-style iterator (excluding the program name).
    ///
    /// Grammar: tokens starting with `-` followed by a non-numeric char are
    /// keys; a key consumes the next token as its value unless that token is
    /// itself a key (then the key is a boolean flag). Other tokens are
    /// positional. `--` passes everything after it as positional.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Options {
        let mut opts = Options::default();
        let mut it = args.into_iter().peekable();
        let mut raw = false;
        while let Some(tok) = it.next() {
            if raw {
                opts.positional.push(tok);
                continue;
            }
            if tok == "--" {
                raw = true;
            } else if is_key(&tok) {
                let key = tok.trim_start_matches('-').to_string();
                match it.peek() {
                    Some(next) if !is_key(next) => {
                        let v = it.next().unwrap();
                        opts.map.insert(key, v);
                    }
                    _ => {
                        opts.map.insert(key, String::new());
                    }
                }
            } else {
                opts.positional.push(tok);
            }
        }
        opts
    }

    /// Parse from process args (skipping argv[0]).
    pub fn from_env() -> Options {
        Options::parse(std::env::args().skip(1))
    }

    /// Parse an options file: `key value` / `-key value` pairs (or bare
    /// flags) per line, `#` comments. On a line with no `-`-prefixed
    /// token, every even-positioned token is treated as a key and dashed
    /// (so `verbose` alone is a flag, `ksp_type gmres` is a pair); lines
    /// that already use dashes are taken verbatim. The bare-key heuristic
    /// is per line, so a flag on one line cannot shift the key/value
    /// pairing of the next. Later CLI options override file options via
    /// [`Self::merge`].
    pub fn parse_file(text: &str) -> Options {
        let mut tokens = Vec::new();
        for line in text.lines() {
            let line = line.split('#').next().unwrap_or("");
            let line_toks: Vec<&str> = line.split_whitespace().collect();
            let bare = !line_toks.is_empty() && line_toks.iter().all(|t| !t.starts_with('-'));
            for (i, tok) in line_toks.iter().enumerate() {
                if bare && i % 2 == 0 {
                    tokens.push(format!("-{tok}"));
                } else {
                    tokens.push(tok.to_string());
                }
            }
        }
        Options::parse(tokens)
    }

    /// Overlay `other` on top of `self` (other wins).
    pub fn merge(mut self, other: Options) -> Options {
        for (k, v) in other.map {
            self.map.insert(k, v);
        }
        self.positional.extend(other.positional);
        self
    }

    /// Insert programmatically.
    pub fn set(&mut self, key: &str, value: impl Into<String>) {
        self.map.insert(key.to_string(), value.into());
    }

    /// Remove `key` from the database, returning its value if present
    /// (for front-end keys that must not reach later layers).
    pub fn take(&mut self, key: &str) -> Option<String> {
        self.map.remove(key)
    }

    /// Positional (non-`-key`) arguments, in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// All keys present in the database, sorted (does not mark them used —
    /// this is the schema-validation view, not a lookup).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }

    /// Whether `key` is present (marks it used).
    pub fn has(&self, key: &str) -> bool {
        self.touch(key);
        self.map.contains_key(key)
    }

    /// Raw string lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.touch(key);
        self.map.get(key).map(|s| s.as_str())
    }

    /// String lookup with a default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).map(|s| s.to_string()).unwrap_or_else(|| default.to_string())
    }

    /// Float lookup with a default; parse failures are errors.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, OptError> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse::<f64>()
                .map_err(|_| OptError(format!("-{key}: expected float, got '{s}'"))),
        }
    }

    /// Integer lookup with a default; accepts `4k`/`2m`/`1g` suffixes.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, OptError> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => parse_usize_with_suffix(s)
                .ok_or_else(|| OptError(format!("-{key}: expected integer, got '{s}'"))),
        }
    }

    /// `u64` lookup with a default (same grammar as [`Self::get_usize`]).
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, OptError> {
        Ok(self.get_usize(key, default as usize)? as u64)
    }

    /// Bool lookup: bare flags and `true/1/yes/on` are true,
    /// `false/0/no/off` false.
    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool, OptError> {
        match self.get(key) {
            None => Ok(default),
            Some("") | Some("true") | Some("1") | Some("yes") | Some("on") => Ok(true),
            Some("false") | Some("0") | Some("no") | Some("off") => Ok(false),
            Some(s) => Err(OptError(format!("-{key}: expected bool, got '{s}'"))),
        }
    }

    /// Enumerated choice with validation.
    pub fn get_choice(&self, key: &str, choices: &[&str], default: &str) -> Result<String, OptError> {
        let v = self.get_str(key, default);
        if choices.contains(&v.as_str()) {
            Ok(v)
        } else {
            Err(OptError(format!(
                "-{key}: '{v}' is not one of {choices:?}"
            )))
        }
    }

    fn touch(&self, key: &str) {
        self.used.borrow_mut().insert(key.to_string());
    }

    /// Keys present but never queried (PETSc `-options_left` equivalent).
    pub fn unused_keys(&self) -> Vec<String> {
        let used = self.used.borrow();
        self.map
            .keys()
            .filter(|k| !used.contains(*k))
            .cloned()
            .collect()
    }
}

fn is_key(tok: &str) -> bool {
    let mut ch = tok.chars();
    match (ch.next(), ch.next()) {
        (Some('-'), Some(c)) => !(c.is_ascii_digit() || c == '.'),
        _ => false,
    }
}

/// Accept `4k`, `2m`, `1g` suffixes (powers of 10^3) for sizes like state
/// counts: `-num_states 1m`.
fn parse_usize_with_suffix(s: &str) -> Option<usize> {
    if let Ok(v) = s.parse::<usize>() {
        return Some(v);
    }
    let (num, mult) = match s.chars().last()? {
        'k' | 'K' => (&s[..s.len() - 1], 1_000),
        'm' | 'M' => (&s[..s.len() - 1], 1_000_000),
        'g' | 'G' => (&s[..s.len() - 1], 1_000_000_000),
        _ => return None,
    };
    let base: f64 = num.parse().ok()?;
    Some((base * mult as f64) as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Options {
        Options::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_pairs() {
        let o = parse(&["-ksp_type", "gmres", "-alpha", "1e-4"]);
        assert_eq!(o.get("ksp_type"), Some("gmres"));
        assert_eq!(o.get_f64("alpha", 0.0).unwrap(), 1e-4);
    }

    #[test]
    fn flags_without_value() {
        let o = parse(&["-verbose", "-ksp_type", "gmres"]);
        assert!(o.has("verbose"));
        assert!(o.get_bool("verbose", false).unwrap());
        assert_eq!(o.get("ksp_type"), Some("gmres"));
    }

    #[test]
    fn negative_numbers_are_values_not_keys() {
        let o = parse(&["-shift", "-0.5", "-n", "-3"]);
        assert_eq!(o.get_f64("shift", 0.0).unwrap(), -0.5);
        assert_eq!(o.get("n"), Some("-3"));
    }

    #[test]
    fn positional_and_double_dash() {
        let o = parse(&["solve", "-tol", "1e-8", "--", "-raw"]);
        assert_eq!(o.positional(), &["solve".to_string(), "-raw".to_string()]);
    }

    #[test]
    fn defaults_and_errors() {
        let o = parse(&["-x", "abc"]);
        assert_eq!(o.get_f64("missing", 2.5).unwrap(), 2.5);
        assert!(o.get_f64("x", 0.0).is_err());
        assert!(o.get_choice("x", &["a", "b"], "a").is_err());
    }

    #[test]
    fn choice_validation() {
        let o = parse(&["-ksp_type", "tfqmr"]);
        let v = o
            .get_choice("ksp_type", &["richardson", "gmres", "tfqmr"], "gmres")
            .unwrap();
        assert_eq!(v, "tfqmr");
        assert_eq!(
            o.get_choice("missing", &["a", "b"], "b").unwrap(),
            "b".to_string()
        );
    }

    #[test]
    fn size_suffixes() {
        let o = parse(&["-num_states", "2m", "-rows", "4k", "-big", "1g"]);
        assert_eq!(o.get_usize("num_states", 0).unwrap(), 2_000_000);
        assert_eq!(o.get_usize("rows", 0).unwrap(), 4_000);
        assert_eq!(o.get_usize("big", 0).unwrap(), 1_000_000_000);
    }

    #[test]
    fn file_parsing_and_merge() {
        let file = Options::parse_file("ksp_type gmres # comment\n-alpha 1e-3\n");
        assert_eq!(file.get("ksp_type"), Some("gmres"));
        let cli = parse(&["-alpha", "1e-6"]);
        let merged = file.merge(cli);
        assert_eq!(merged.get_f64("alpha", 0.0).unwrap(), 1e-6);
        assert_eq!(merged.get("ksp_type"), Some("gmres"));
    }

    #[test]
    fn file_flag_does_not_shift_pairing() {
        // regression: a bare flag line used to flip the global token
        // parity, making the next line's key consume as a value
        let o = Options::parse_file("verbose\nksp_type gmres\n");
        assert!(o.get_bool("verbose", false).unwrap());
        assert_eq!(o.get("ksp_type"), Some("gmres"));
        // multi-pair bare lines still work
        let o = Options::parse_file("a 1 b 2\n");
        assert_eq!(o.get("a"), Some("1"));
        assert_eq!(o.get("b"), Some("2"));
    }

    #[test]
    fn unused_keys_reported() {
        let o = parse(&["-used", "1", "-typo_key", "2"]);
        let _ = o.get("used");
        assert_eq!(o.unused_keys(), vec!["typo_key".to_string()]);
    }

    #[test]
    fn bool_parsing_variants() {
        let o = parse(&["-a", "true", "-b", "0", "-c", "yes", "-d", "off"]);
        assert!(o.get_bool("a", false).unwrap());
        assert!(!o.get_bool("b", true).unwrap());
        assert!(o.get_bool("c", false).unwrap());
        assert!(!o.get_bool("d", true).unwrap());
        assert!(o.get_bool("missing", true).unwrap());
    }
}
