//! Sharded least-recently-used cache (`util::lru`).
//!
//! The policy-serving layer (`crate::serve`) keeps decoded artifacts behind
//! an LRU so hot fingerprints are answered without touching the sink. The
//! cache is *sharded*: keys hash to one of `S` independently locked shards,
//! so concurrent clients contend only when they hit the same shard — the
//! standard recipe for a read-heavy serving cache without lock-free
//! machinery (the build is dependency-free, so no `dashmap`).
//!
//! Semantics are strict LRU **per shard**: `get` and `put` both refresh
//! recency, and an insert into a full shard evicts that shard's
//! least-recently-used entry. The *total* capacity is distributed across
//! shards at construction (`Σ shard caps == capacity`), so `len() <=
//! capacity()` always holds — the serving soak test pins this bound under
//! 8-thread load. A capacity of 0 disables storage entirely (every `get`
//! misses), which is the `-serve_cache_entries 0` spelling of "no cache".
//!
//! Recency is tracked with a monotone per-shard clock stamp; eviction scans
//! the shard for the minimum stamp. That is O(shard size) per eviction, and
//! shard sizes here are small (a serving cache holds tens of decoded
//! artifacts, not millions of rows) — the property tests below check the
//! *semantics* against a reference model, and `bench_serve` measures the
//! throughput that actually matters.

use std::collections::BTreeMap;
use std::hash::{Hash, Hasher};
use std::sync::Mutex;

/// A sharded LRU cache. `K` must be `Ord` (shards index with a `BTreeMap`
/// so iteration — and therefore eviction tie-breaking — is deterministic)
/// and `Hash` (shard selection); `V` is returned by clone, so callers
/// typically store `Arc<T>`.
pub struct ShardedLru<K, V> {
    shards: Vec<Mutex<Shard<K, V>>>,
    capacity: usize,
}

struct Shard<K, V> {
    cap: usize,
    clock: u64,
    map: BTreeMap<K, Entry<V>>,
}

struct Entry<V> {
    value: V,
    stamp: u64,
}

impl<K: Ord + Hash + Clone, V: Clone> ShardedLru<K, V> {
    /// Cache with `capacity` total entries spread over `shards` locks.
    /// `shards` is clamped to `[1, capacity]` (a shard with nothing to hold
    /// is pointless; zero-capacity caches collapse to one empty shard), and
    /// the per-shard capacities sum exactly to `capacity`.
    pub fn new(capacity: usize, shards: usize) -> ShardedLru<K, V> {
        let shards = shards.clamp(1, capacity.max(1));
        let base = capacity / shards;
        let extra = capacity % shards;
        let shards: Vec<Mutex<Shard<K, V>>> = (0..shards)
            .map(|i| {
                Mutex::new(Shard {
                    cap: base + usize::from(i < extra),
                    clock: 0,
                    map: BTreeMap::new(),
                })
            })
            .collect();
        ShardedLru { shards, capacity }
    }

    /// Total configured capacity (`Σ` shard capacities).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Current number of cached entries across all shards. Always
    /// `<= capacity()`: each shard enforces its own bound under its own
    /// lock, so the sum cannot overshoot even under concurrent inserts.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().expect("lru shard poisoned").map.len())
            .sum()
    }

    /// Whether the cache currently holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn shard_of(&self, key: &K) -> &Mutex<Shard<K, V>> {
        // DefaultHasher::new() uses fixed keys — shard selection is
        // deterministic across runs, like everything else in the crate.
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        let idx = (h.finish() % self.shards.len() as u64) as usize;
        &self.shards[idx]
    }

    /// Look up `key`, refreshing its recency on a hit.
    pub fn get(&self, key: &K) -> Option<V> {
        let mut shard = self.shard_of(key).lock().expect("lru shard poisoned");
        shard.clock += 1;
        let stamp = shard.clock;
        let entry = shard.map.get_mut(key)?;
        entry.stamp = stamp;
        Some(entry.value.clone())
    }

    /// Insert or replace `key`, evicting the shard's least-recently-used
    /// entry if the shard is at capacity. A zero-capacity shard stores
    /// nothing (the value is dropped).
    pub fn put(&self, key: K, value: V) {
        let mut shard = self.shard_of(&key).lock().expect("lru shard poisoned");
        if shard.cap == 0 {
            return;
        }
        shard.clock += 1;
        let stamp = shard.clock;
        if let Some(entry) = shard.map.get_mut(&key) {
            entry.value = value;
            entry.stamp = stamp;
            return;
        }
        if shard.map.len() >= shard.cap {
            // Evict the minimum stamp; BTreeMap iteration order makes the
            // (unreachable-in-practice) tie deterministic.
            if let Some(victim) = shard
                .map
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| k.clone())
            {
                shard.map.remove(&victim);
            }
        }
        shard.map.insert(key, Entry { value, stamp });
    }

    /// Drop every cached entry (capacities are unchanged).
    pub fn clear(&self) {
        for shard in &self.shards {
            shard.lock().expect("lru shard poisoned").map.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256pp;

    /// Reference model: a plain Vec ordered least-recent-first. O(n) per
    /// op, unambiguous semantics — the oracle the sharded implementation
    /// is pinned against (single-shard configs must match it exactly).
    struct RefLru {
        cap: usize,
        entries: Vec<(u64, u64)>, // (key, value), LRU at the front
    }

    impl RefLru {
        fn new(cap: usize) -> RefLru {
            RefLru {
                cap,
                entries: Vec::new(),
            }
        }

        fn get(&mut self, key: u64) -> Option<u64> {
            let idx = self.entries.iter().position(|(k, _)| *k == key)?;
            let e = self.entries.remove(idx);
            let v = e.1;
            self.entries.push(e);
            Some(v)
        }

        fn put(&mut self, key: u64, value: u64) {
            if self.cap == 0 {
                return;
            }
            if let Some(idx) = self.entries.iter().position(|(k, _)| *k == key) {
                self.entries.remove(idx);
            } else if self.entries.len() >= self.cap {
                self.entries.remove(0);
            }
            self.entries.push((key, value));
        }
    }

    #[test]
    fn basic_hit_miss_evict() {
        let lru: ShardedLru<u64, u64> = ShardedLru::new(2, 1);
        lru.put(1, 10);
        lru.put(2, 20);
        assert_eq!(lru.get(&1), Some(10));
        lru.put(3, 30); // evicts 2 (1 was refreshed by the get)
        assert_eq!(lru.get(&2), None);
        assert_eq!(lru.get(&1), Some(10));
        assert_eq!(lru.get(&3), Some(30));
        assert_eq!(lru.len(), 2);
    }

    #[test]
    fn replace_refreshes_recency() {
        let lru: ShardedLru<u64, u64> = ShardedLru::new(2, 1);
        lru.put(1, 10);
        lru.put(2, 20);
        lru.put(1, 11); // replace: 1 becomes most recent
        lru.put(3, 30); // evicts 2
        assert_eq!(lru.get(&1), Some(11));
        assert_eq!(lru.get(&2), None);
    }

    #[test]
    fn zero_capacity_stores_nothing() {
        let lru: ShardedLru<u64, u64> = ShardedLru::new(0, 4);
        for k in 0..32 {
            lru.put(k, k);
            assert_eq!(lru.get(&k), None);
        }
        assert_eq!(lru.len(), 0);
        assert_eq!(lru.capacity(), 0);
        assert!(lru.is_empty());
    }

    #[test]
    fn capacity_one_keeps_only_last_insert() {
        let lru: ShardedLru<u64, u64> = ShardedLru::new(1, 8); // clamps to 1 shard
        lru.put(1, 10);
        lru.put(2, 20);
        assert_eq!(lru.get(&1), None);
        assert_eq!(lru.get(&2), Some(20));
        assert_eq!(lru.len(), 1);
    }

    #[test]
    fn shard_caps_sum_to_capacity() {
        for (cap, shards) in [(7usize, 3usize), (8, 4), (1, 16), (5, 5), (64, 8)] {
            let lru: ShardedLru<u64, u64> = ShardedLru::new(cap, shards);
            // overfill massively; the bound must hold exactly
            for k in 0..10 * cap as u64 + 10 {
                lru.put(k, k);
            }
            assert!(
                lru.len() <= cap,
                "cap={cap} shards={shards} len={}",
                lru.len()
            );
            assert_eq!(lru.capacity(), cap);
        }
    }

    /// Property test: random get/put sequences against the reference model.
    /// Single-shard configs must match the oracle *exactly* (hit/miss and
    /// value per op, length per step) — including the capacity-0 and
    /// capacity-1 edge cases named by the serving issue.
    #[test]
    fn property_single_shard_matches_reference() {
        for cap in [0usize, 1, 2, 3, 8] {
            for seed in 0..6u64 {
                let lru: ShardedLru<u64, u64> = ShardedLru::new(cap, 1);
                let mut oracle = RefLru::new(cap);
                let mut rng = Xoshiro256pp::new(0xC0FFEE + seed * 131 + cap as u64);
                for step in 0..2000 {
                    let key = rng.next_below(12);
                    if rng.next_f64() < 0.5 {
                        let got = lru.get(&key);
                        let want = oracle.get(key);
                        assert_eq!(
                            got, want,
                            "cap={cap} seed={seed} step={step} get({key})"
                        );
                    } else {
                        let value = rng.next_u64();
                        lru.put(key, value);
                        oracle.put(key, value);
                    }
                    assert_eq!(
                        lru.len(),
                        oracle.entries.len(),
                        "cap={cap} seed={seed} step={step} len"
                    );
                }
            }
        }
    }

    /// Property test, sharded: eviction *choice* may differ from the global
    /// oracle (each shard evicts locally), but three invariants cannot: the
    /// total bound, hit values always equal to the last put, and a
    /// capacity's worth of distinct keys never evicting inside one shard's
    /// working set beyond its cap.
    #[test]
    fn property_sharded_bound_and_value_correctness() {
        for (cap, shards) in [(4usize, 2usize), (8, 4), (9, 3)] {
            for seed in 0..4u64 {
                let lru: ShardedLru<u64, u64> = ShardedLru::new(cap, shards);
                let mut last_put: BTreeMap<u64, u64> = BTreeMap::new();
                let mut rng = Xoshiro256pp::new(0xBEEF + seed * 977 + cap as u64);
                for step in 0..3000 {
                    let key = rng.next_below(20);
                    if rng.next_f64() < 0.5 {
                        if let Some(got) = lru.get(&key) {
                            assert_eq!(
                                Some(&got),
                                last_put.get(&key),
                                "cap={cap} shards={shards} seed={seed} step={step}: \
                                 a hit must return the last value put for the key"
                            );
                        }
                    } else {
                        let value = rng.next_u64();
                        lru.put(key, value);
                        last_put.insert(key, value);
                    }
                    assert!(
                        lru.len() <= cap,
                        "cap={cap} shards={shards} seed={seed} step={step}: bound"
                    );
                }
            }
        }
    }

    #[test]
    fn clear_empties_all_shards() {
        let lru: ShardedLru<u64, u64> = ShardedLru::new(8, 4);
        for k in 0..8 {
            lru.put(k, k);
        }
        assert!(!lru.is_empty());
        lru.clear();
        assert_eq!(lru.len(), 0);
        // still usable after clear
        lru.put(1, 1);
        assert_eq!(lru.get(&1), Some(1));
    }

    #[test]
    fn concurrent_access_holds_bound() {
        use std::sync::Arc;
        let lru: Arc<ShardedLru<u64, u64>> = Arc::new(ShardedLru::new(16, 4));
        std::thread::scope(|scope| {
            for t in 0..8u64 {
                let lru = Arc::clone(&lru);
                scope.spawn(move || {
                    let mut rng = Xoshiro256pp::new(t + 1);
                    for _ in 0..5000 {
                        let key = rng.next_below(64);
                        if rng.next_f64() < 0.5 {
                            if let Some(v) = lru.get(&key) {
                                // values are key-derived: hits are never garbage
                                assert_eq!(v, key * 3);
                            }
                        } else {
                            lru.put(key, key * 3);
                        }
                        assert!(lru.len() <= 16);
                    }
                });
            }
        });
        assert!(lru.len() <= 16);
    }
}
