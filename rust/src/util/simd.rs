//! Explicit multi-lane kernels for the hot numeric loops (DESIGN.md §13).
//!
//! The solver's wall-clock is dominated by memory-bound sparse kernels —
//! `Csr::spmv`, the fused policy-operator row pass, the Bellman backup and
//! the KSP vector kernels. `std::simd` is nightly-only and the build is
//! offline/stable, so this module implements the classic manual-lane
//! idiom instead: [`LANES`] independent accumulators walked in a fixed
//! stride-`LANES` pattern with a serial remainder loop, which LLVM lowers
//! to packed vector instructions on every mainstream target.
//!
//! Two invariants the rest of the crate leans on:
//!
//! - **Fixed fold order.** Lane partials always combine as
//!   `(s0 + s1) + (s2 + s3)` and the remainder is always appended last.
//!   Together with the fixed chunk grid of [`crate::util::par`] this keeps
//!   every reduction **bitwise identical for every thread count** per
//!   selected backend (`tests/par_determinism.rs`).
//! - **Scalar fallback.** [`KernelBackend::Scalar`] routes every kernel
//!   through the plain left-to-right reference loop. It is selectable at
//!   runtime ([`set_kernel_backend`]) and from the environment
//!   (`MADUPITE_KERNELS=scalar|simd`), which is how CI's `kernels-matrix`
//!   leg runs the whole suite against both implementations. The two
//!   backends differ only by floating-point reassociation; the property
//!   tests in this module pin them together within accumulation error.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Accumulator lane count of the manual-lane kernels (f64x4-style: one
/// AVX2 register of doubles, two NEON registers).
pub const LANES: usize = 4;

/// Which implementation the numeric kernels use (process-global).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KernelBackend {
    /// Plain left-to-right scalar loops — the reference implementation.
    Scalar,
    /// Manual [`LANES`]-lane unrolled kernels (default).
    #[default]
    Simd,
}

impl KernelBackend {
    /// Parse a `MADUPITE_KERNELS` value.
    pub fn parse(name: &str) -> Result<KernelBackend, String> {
        match name {
            "scalar" => Ok(KernelBackend::Scalar),
            "simd" => Ok(KernelBackend::Simd),
            other => Err(format!("unknown kernel backend '{other}' (scalar|simd)")),
        }
    }

    /// Canonical option-string form (inverse of [`Self::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Simd => "simd",
        }
    }
}

/// Sentinel meaning "not initialized yet — consult the environment".
const UNSET: usize = usize::MAX;

static BACKEND: AtomicUsize = AtomicUsize::new(UNSET);

fn env_backend() -> KernelBackend {
    match std::env::var("MADUPITE_KERNELS") {
        Ok(s) => KernelBackend::parse(s.trim()).unwrap_or_default(),
        Err(_) => KernelBackend::default(),
    }
}

/// The currently selected kernel backend. First call resolves
/// `MADUPITE_KERNELS` (default `simd`); [`set_kernel_backend`] overrides.
#[inline]
pub fn kernel_backend() -> KernelBackend {
    match BACKEND.load(Ordering::Relaxed) {
        0 => KernelBackend::Scalar,
        1 => KernelBackend::Simd,
        _ => {
            let b = env_backend();
            BACKEND.store(b as usize, Ordering::Relaxed);
            b
        }
    }
}

/// Select the kernel backend process-wide (benches and the test matrix
/// flip this; production code leaves the default).
pub fn set_kernel_backend(b: KernelBackend) {
    BACKEND.store(b as usize, Ordering::Relaxed);
}

/// Dot product with [`LANES`] accumulators and fixed fold order
/// `(s0 + s1) + (s2 + s3)` + serial remainder. Falls back to the scalar
/// reference loop under [`KernelBackend::Scalar`].
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    if kernel_backend() == KernelBackend::Scalar {
        return a.iter().zip(b).map(|(x, y)| x * y).sum();
    }
    let mut s = [0.0f64; LANES];
    let whole = a.len() - a.len() % LANES;
    let mut i = 0;
    while i < whole {
        for (l, sl) in s.iter_mut().enumerate() {
            *sl += a[i + l] * b[i + l];
        }
        i += LANES;
    }
    let mut acc = (s[0] + s[1]) + (s[2] + s[3]);
    for k in whole..a.len() {
        acc += a[k] * b[k];
    }
    acc
}

/// Max |x| over a slice, lane-unrolled. `max` is associative and
/// commutative over the values that occur here, so both backends return
/// identical results.
#[inline]
pub fn max_abs(xs: &[f64]) -> f64 {
    if kernel_backend() == KernelBackend::Scalar {
        return xs.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
    }
    let mut s = [0.0f64; LANES];
    let whole = xs.len() - xs.len() % LANES;
    let mut i = 0;
    while i < whole {
        for (l, sl) in s.iter_mut().enumerate() {
            *sl = sl.max(xs[i + l].abs());
        }
        i += LANES;
    }
    let mut m = (s[0].max(s[1])).max(s[2].max(s[3]));
    for k in whole..xs.len() {
        m = m.max(xs[k].abs());
    }
    m
}

/// `y += a·x`. Elementwise, so there is nothing to reassociate: the
/// straight-line loop vectorizes cleanly and is bitwise identical on
/// every backend.
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// `y = x + b·y`. Elementwise — bitwise identical on every backend.
#[inline]
pub fn aypx(b: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = xi + b * *yi;
    }
}

/// `x *= a`. Elementwise — bitwise identical on every backend.
#[inline]
pub fn scale(a: f64, x: &mut [f64]) {
    for xi in x {
        *xi *= a;
    }
}

/// Sparse gather dot `Σ vals[k] · x[cols[k]]` — the inner loop of every
/// CSR row kernel (`spmv`, `spmv_acc`, the fused policy operator). Same
/// lane discipline as [`dot`].
///
/// # Safety
///
/// Every entry of `cols` must be `< x.len()`. CSR construction
/// (`Csr::from_parts`/`from_row_lists`) validates column bounds, so row
/// slices of a CSR paired with an `x` of length `ncols` satisfy this.
#[inline]
pub unsafe fn gather_dot_unchecked(cols: &[usize], vals: &[f64], x: &[f64]) -> f64 {
    debug_assert_eq!(cols.len(), vals.len());
    if kernel_backend() == KernelBackend::Scalar {
        let mut acc = 0.0;
        for (&c, &v) in cols.iter().zip(vals) {
            debug_assert!(c < x.len());
            acc += v * *x.get_unchecked(c);
        }
        return acc;
    }
    let mut s = [0.0f64; LANES];
    let whole = cols.len() - cols.len() % LANES;
    let mut i = 0;
    while i < whole {
        for (l, sl) in s.iter_mut().enumerate() {
            let c = *cols.get_unchecked(i + l);
            debug_assert!(c < x.len());
            *sl += *vals.get_unchecked(i + l) * *x.get_unchecked(c);
        }
        i += LANES;
    }
    let mut acc = (s[0] + s[1]) + (s[2] + s[3]);
    for k in whole..cols.len() {
        let c = *cols.get_unchecked(k);
        debug_assert!(c < x.len());
        acc += *vals.get_unchecked(k) * *x.get_unchecked(c);
    }
    acc
}

/// Single-precision sparse gather dot for the mixed-precision inner
/// operator (`-inner_precision f32`): `f32` storage for values, columns
/// and the gathered vector (half the memory traffic of the f64 kernel),
/// products widened to `f64` before accumulation so only the *inputs*
/// are rounded, not the running sum.
///
/// # Safety
///
/// Every entry of `cols` must be `< x.len()`.
#[inline]
pub unsafe fn gather_dot_f32_unchecked(cols: &[u32], vals: &[f32], x: &[f32]) -> f64 {
    debug_assert_eq!(cols.len(), vals.len());
    if kernel_backend() == KernelBackend::Scalar {
        let mut acc = 0.0f64;
        for (&c, &v) in cols.iter().zip(vals) {
            debug_assert!((c as usize) < x.len());
            acc += v as f64 * *x.get_unchecked(c as usize) as f64;
        }
        return acc;
    }
    let mut s = [0.0f64; LANES];
    let whole = cols.len() - cols.len() % LANES;
    let mut i = 0;
    while i < whole {
        for (l, sl) in s.iter_mut().enumerate() {
            let c = *cols.get_unchecked(i + l) as usize;
            debug_assert!(c < x.len());
            *sl += *vals.get_unchecked(i + l) as f64 * *x.get_unchecked(c) as f64;
        }
        i += LANES;
    }
    let mut acc = (s[0] + s[1]) + (s[2] + s[3]);
    for k in whole..cols.len() {
        let c = *cols.get_unchecked(k) as usize;
        debug_assert!(c < x.len());
        acc += *vals.get_unchecked(k) as f64 * *x.get_unchecked(c) as f64;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prng::Xoshiro256pp;
    use crate::util::prop;

    /// The backend is process-global; tests that flip it serialize here so
    /// concurrent tests never observe a mid-flight switch.
    static FLIP_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    /// Run `f` under both backends, restoring the previous selection.
    fn with_backends(mut f: impl FnMut(KernelBackend)) {
        let _guard = FLIP_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        let prev = kernel_backend();
        for b in [KernelBackend::Scalar, KernelBackend::Simd] {
            set_kernel_backend(b);
            f(b);
        }
        set_kernel_backend(prev);
    }

    fn scalar_dot(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[test]
    fn backend_parse_roundtrip() {
        for b in [KernelBackend::Scalar, KernelBackend::Simd] {
            assert_eq!(KernelBackend::parse(b.name()).unwrap(), b);
        }
        assert!(KernelBackend::parse("avx512").is_err());
    }

    #[test]
    fn dot_small_and_empty_match_scalar_exactly() {
        // below one lane chunk both backends run the identical remainder
        // loop, so even the bits agree
        with_backends(|_| {
            assert_eq!(dot(&[], &[]), 0.0);
            assert_eq!(dot(&[2.0], &[3.0]), 6.0);
            assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        });
    }

    #[test]
    fn prop_dot_matches_scalar_all_lengths() {
        // odd lengths, non-multiple-of-lane remainders, empty — the lane
        // kernel may reassociate, so compare within accumulation error
        prop::forall("simd dot == scalar dot", |rng| {
            let n = rng.index(67); // 0..=66 covers 0, <LANES, odd remainders
            let a: Vec<f64> = (0..n).map(|_| rng.range_f64(-10.0, 10.0)).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.range_f64(-10.0, 10.0)).collect();
            let reference = scalar_dot(&a, &b);
            let mut got = f64::NAN;
            with_backends(|_| got = dot(&a, &b));
            prop_assert!(
                (got - reference).abs() <= 1e-10 * (1.0 + reference.abs()),
                "n={n}: {got} vs {reference}"
            );
            Ok(())
        });
    }

    #[test]
    fn dot_handles_denormal_and_extreme_values() {
        with_backends(|_| {
            let tiny = f64::MIN_POSITIVE / 4.0; // denormal
            let a = [tiny, -tiny, tiny, tiny, tiny];
            let b = [1.0, 1.0, 1.0, 1.0, 1.0];
            assert_eq!(dot(&a, &b), 3.0 * tiny);
            let big = [1e300, -1e300, 1e300, -1e300, 0.0];
            let ones = [1.0; 5];
            assert_eq!(dot(&big, &ones), 0.0);
        });
    }

    #[test]
    fn max_abs_is_backend_independent() {
        prop::forall("max_abs backend equivalence", |rng| {
            let n = rng.index(50);
            let xs: Vec<f64> = (0..n).map(|_| rng.range_f64(-5.0, 5.0)).collect();
            let reference = xs.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
            let mut vals = Vec::new();
            with_backends(|_| vals.push(max_abs(&xs)));
            prop_assert!(
                vals.iter().all(|&v| v.to_bits() == reference.to_bits()),
                "max_abs diverged: {vals:?} vs {reference}"
            );
            Ok(())
        });
    }

    #[test]
    fn elementwise_kernels_match_reference_bitwise() {
        prop::forall("axpy/aypx/scale bitwise", |rng| {
            let n = rng.index(40);
            let x: Vec<f64> = (0..n).map(|_| rng.range_f64(-3.0, 3.0)).collect();
            let y0: Vec<f64> = (0..n).map(|_| rng.range_f64(-3.0, 3.0)).collect();
            let a = rng.range_f64(-2.0, 2.0);

            let mut want = y0.clone();
            for (yi, xi) in want.iter_mut().zip(&x) {
                *yi += a * xi;
            }
            let mut got = y0.clone();
            axpy(a, &x, &mut got);
            prop_assert!(got == want, "axpy diverged");

            let mut want = y0.clone();
            for (yi, xi) in want.iter_mut().zip(&x) {
                *yi = xi + a * *yi;
            }
            let mut got = y0.clone();
            aypx(a, &x, &mut got);
            prop_assert!(got == want, "aypx diverged");

            let want: Vec<f64> = y0.iter().map(|v| v * a).collect();
            let mut got = y0.clone();
            scale(a, &mut got);
            prop_assert!(got == want, "scale diverged");
            Ok(())
        });
    }

    #[test]
    fn prop_gather_dot_matches_dense_reference() {
        prop::forall("gather dot == dense reference", |rng| {
            let ncols = 1 + rng.index(30);
            let len = rng.index(20); // includes empty rows
            let cols: Vec<usize> = (0..len).map(|_| rng.index(ncols)).collect();
            let vals: Vec<f64> = (0..len).map(|_| rng.range_f64(-2.0, 2.0)).collect();
            let x: Vec<f64> = (0..ncols).map(|_| rng.range_f64(-2.0, 2.0)).collect();
            let reference: f64 = cols.iter().zip(&vals).map(|(&c, &v)| v * x[c]).sum();
            let mut results = Vec::new();
            with_backends(|_| results.push(unsafe { gather_dot_unchecked(&cols, &vals, &x) }));
            for got in results {
                prop_assert!(
                    (got - reference).abs() <= 1e-12 * (1.0 + reference.abs()),
                    "len={len}: {got} vs {reference}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_f32_gather_tracks_f64_within_single_precision() {
        prop::forall("f32 gather ~= f64 gather", |rng| {
            let ncols = 1 + rng.index(30);
            let len = rng.index(20);
            let cols: Vec<usize> = (0..len).map(|_| rng.index(ncols)).collect();
            let vals: Vec<f64> = (0..len).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let x: Vec<f64> = (0..ncols).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let cols32: Vec<u32> = cols.iter().map(|&c| c as u32).collect();
            let vals32: Vec<f32> = vals.iter().map(|&v| v as f32).collect();
            let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
            let reference = unsafe { gather_dot_unchecked(&cols, &vals, &x) };
            let mut results = Vec::new();
            with_backends(|_| {
                results.push(unsafe { gather_dot_f32_unchecked(&cols32, &vals32, &x32) })
            });
            for got in results {
                // inputs rounded to f32: error ~ len · eps_f32 · |terms|
                let bound = 1e-6 * (1.0 + len as f64);
                prop_assert!(
                    (got - reference).abs() <= bound,
                    "len={len}: {got} vs {reference}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn gather_dot_seeded_large_row_exercises_lane_path() {
        // one deterministic large case so the lane loop (not just the
        // remainder) is definitely on the line
        let mut rng = Xoshiro256pp::new(42);
        let ncols = 1000;
        let len = 4 * LANES + 3;
        let cols: Vec<usize> = (0..len).map(|_| rng.index(ncols)).collect();
        let vals: Vec<f64> = (0..len).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let x: Vec<f64> = (0..ncols).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let reference: f64 = cols.iter().zip(&vals).map(|(&c, &v)| v * x[c]).sum();
        with_backends(|_| {
            let got = unsafe { gather_dot_unchecked(&cols, &vals, &x) };
            assert!((got - reference).abs() < 1e-12, "{got} vs {reference}");
        });
    }
}
