//! Mini property-based testing kit (proptest substitute).
//!
//! Offline build → no `proptest`/`quickcheck`. This module provides the
//! subset the test suite needs: seeded generators built on
//! [`crate::util::prng::Xoshiro256pp`], a `forall` driver that runs N cases
//! (override the count with `MADUPITE_PROP_CASES`), and helpers for the
//! domain types (probability vectors, sparse rows, random MDP shapes).
//!
//! **Shrinking.** Properties draw their randomness through a [`Gen`]: in
//! record mode it wraps the case RNG and logs every raw `u64` draw onto a
//! tape; when a case fails, the driver greedily shrinks that tape —
//! shorter prefixes (missing draws replay as 0), zeroed, halved and
//! decremented entries — re-running the property on each candidate and
//! keeping it whenever the failure persists. The panic then reports both
//! the original failure and the minimal counterexample tape, alongside the
//! `MADUPITE_PROP_SEED` reproduce line. Because every generator method is
//! a pure function of the `u64` stream, a replayed tape drives the
//! property through exactly the same values.

use crate::util::prng::Xoshiro256pp;

/// Number of cases per property (override with MADUPITE_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("MADUPITE_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("MADUPITE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Replay budget for the shrink loop: candidate tapes re-run per failure.
const SHRINK_BUDGET: usize = 512;

/// The property-test generator: the [`Xoshiro256pp`] surface, recorded.
///
/// In **record** mode every raw `u64` draw comes from the wrapped RNG and
/// is appended to the tape; in **replay** mode draws come from the tape
/// (exhausted positions yield 0, so shrinking may truncate freely). All
/// derived samplers (`next_f64`, `index`, `prob_vector`, ...) are pure
/// functions of the raw stream — identical tape, identical values.
pub struct Gen {
    rng: Option<Xoshiro256pp>,
    tape: Vec<u64>,
    pos: usize,
}

impl Gen {
    /// Recording generator over a fresh case RNG.
    pub fn record(seed: u64) -> Gen {
        Gen {
            rng: Some(Xoshiro256pp::new(seed)),
            tape: Vec::new(),
            pos: 0,
        }
    }

    /// Replaying generator over a fixed tape (draws past the end are 0).
    pub fn replay(tape: Vec<u64>) -> Gen {
        Gen {
            rng: None,
            tape,
            pos: 0,
        }
    }

    /// The recorded (or replayed) raw draws so far.
    pub fn tape(&self) -> &[u64] {
        &self.tape
    }

    /// Next raw 64-bit draw — the one primitive everything else derives
    /// from.
    pub fn next_u64(&mut self) -> u64 {
        match &mut self.rng {
            Some(rng) => {
                let v = rng.next_u64();
                self.tape.push(v);
                v
            }
            None => {
                let v = self.tape.get(self.pos).copied().unwrap_or(0);
                self.pos += 1;
                v
            }
        }
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of entropy.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (widening-multiply, bias-free).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "next_below(0)");
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize index in `[0, bound)`.
    pub fn index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform `f64` in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller. The uniform is clamped away from 0
    /// instead of looping (a shrunk tape replays zeros, which must stay
    /// total) — the clamp moves a ~1e-300 tail, unobservable in tests.
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Random probability vector of length `n` (normalized exponentials —
    /// i.e. a sample from a flat Dirichlet).
    pub fn prob_vector(&mut self, n: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..n).map(|_| -self.next_f64().max(1e-300).ln()).collect();
        let s: f64 = v.iter().sum();
        for x in &mut v {
            *x /= s;
        }
        v
    }
}

/// Run `prop` for `default_cases()` seeded cases. Each case gets its own
/// deterministic recorded generator. On failure the recorded tape is
/// shrunk to a minimal counterexample and the panic reports both, plus
/// the reproducing seed (re-run with `MADUPITE_PROP_SEED=<seed>`).
pub fn forall<F>(name: &str, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let cases = default_cases();
    let seed0 = base_seed();
    for case in 0..cases {
        let seed = seed0 ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::record(seed);
        if let Err(msg) = prop(&mut g) {
            // Shrink the recorded tape: keep any candidate that still
            // fails (an Err *or* a panic — degenerate replays may trip
            // asserts the original draw never reached).
            let (tape, replays) = shrink(std::mem::take(&mut g.tape), |cand| {
                replay_fails(&mut prop, cand).is_some()
            });
            let min_msg = replay_fails(&mut prop, &tape)
                .unwrap_or_else(|| "failure no longer reproduces from the tape".into());
            panic!(
                "property '{name}' failed at case {case}/{cases}: {msg}\n\
                 minimal counterexample after {replays} shrink replays: {min_msg}\n\
                 tape: {}\n\
                 reproduce with MADUPITE_PROP_SEED={seed0} (case seed {seed})",
                format_tape(&tape),
            );
        }
    }
}

/// Re-run the property on a replayed tape, mapping both `Err` and panics
/// to the failure message (`None` = the candidate passes).
fn replay_fails<F>(prop: &mut F, tape: &[u64]) -> Option<String>
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut g = Gen::replay(tape.to_vec());
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g))) {
        Ok(Ok(())) => None,
        Ok(Err(msg)) => Some(msg),
        Err(payload) => Some(match payload.downcast::<String>() {
            Ok(s) => format!("panicked: {s}"),
            Err(payload) => match payload.downcast::<&'static str>() {
                Ok(s) => format!("panicked: {s}"),
                Err(_) => "panicked".into(),
            },
        }),
    }
}

/// Greedy tape shrinking to a local minimum: shorter prefixes first
/// (halve, then drop one), then smaller entries (zero, halve, decrement),
/// repeated to a fixpoint within [`SHRINK_BUDGET`] replays. `fails`
/// returns whether a candidate tape still fails the property; the
/// returned tape is the smallest failing one found plus the replay count.
fn shrink<F>(mut tape: Vec<u64>, mut fails: F) -> (Vec<u64>, usize)
where
    F: FnMut(&[u64]) -> bool,
{
    let mut replays = 0usize;
    loop {
        let mut improved = false;

        // Shorter tapes first: a failing prefix dominates any entry edit.
        while !tape.is_empty() && replays < SHRINK_BUDGET {
            let cand = tape[..tape.len() / 2].to_vec();
            replays += 1;
            if fails(&cand) {
                tape = cand;
                improved = true;
            } else {
                break;
            }
        }
        while !tape.is_empty() && replays < SHRINK_BUDGET {
            let cand = tape[..tape.len() - 1].to_vec();
            replays += 1;
            if fails(&cand) {
                tape = cand;
                improved = true;
            } else {
                break;
            }
        }

        // Then smaller entries, each monotone toward 0.
        let mut i = 0;
        while i < tape.len() && replays < SHRINK_BUDGET {
            if tape[i] != 0 {
                let mut cand = tape.clone();
                cand[i] = 0;
                replays += 1;
                if fails(&cand) {
                    tape = cand;
                    improved = true;
                    i += 1;
                    continue;
                }
                while tape[i] > 1 && replays < SHRINK_BUDGET {
                    let mut cand = tape.clone();
                    cand[i] /= 2;
                    replays += 1;
                    if fails(&cand) {
                        tape = cand;
                        improved = true;
                    } else {
                        break;
                    }
                }
                if tape[i] > 1 && replays < SHRINK_BUDGET {
                    let mut cand = tape.clone();
                    cand[i] -= 1;
                    replays += 1;
                    if fails(&cand) {
                        tape = cand;
                        improved = true;
                    }
                }
            }
            i += 1;
        }

        if !improved || replays >= SHRINK_BUDGET {
            break;
        }
    }
    (tape, replays)
}

/// Compact tape rendering for the failure report (long tapes elided).
fn format_tape(tape: &[u64]) -> String {
    const SHOW: usize = 32;
    let shown: Vec<String> = tape.iter().take(SHOW).map(|v| v.to_string()).collect();
    if tape.len() > SHOW {
        format!(
            "[{}, … {} more] ({} draws)",
            shown.join(", "),
            tape.len() - SHOW,
            tape.len()
        )
    } else {
        format!("[{}] ({} draws)", shown.join(", "), tape.len())
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Check two f64 slices are elementwise close.
pub fn close_slices(a: &[f64], b: &[f64], tol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let scale = 1.0_f64.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Max |a-b| over slices (for diagnostics).
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial() {
        forall("trivial", |rng| {
            let x = rng.next_f64();
            prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'must-fail'")]
    fn forall_reports_failure() {
        forall("must-fail", |rng| {
            let x = rng.next_f64();
            prop_assert!(x < 0.5, "x={x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn forall_reports_minimal_counterexample() {
        forall("shrinks", |rng| {
            let x = rng.next_u64();
            prop_assert!(x < 1000, "x={x}");
            Ok(())
        });
    }

    #[test]
    fn record_and_replay_agree() {
        let mut rec = Gen::record(42);
        let a = (
            rec.next_f64(),
            rec.index(10),
            rec.next_gaussian(),
            rec.prob_vector(4),
        );
        let mut rep = Gen::replay(rec.tape().to_vec());
        let b = (
            rep.next_f64(),
            rep.index(10),
            rep.next_gaussian(),
            rep.prob_vector(4),
        );
        assert_eq!(a, b);
        // draws past the tape end replay as zeros, not panics
        assert_eq!(rep.next_u64(), 0);
        assert_eq!(rep.next_f64(), 0.0);
        assert!(rep.next_gaussian().is_finite());
    }

    #[test]
    fn shrink_finds_the_boundary() {
        // fails iff the first draw exceeds 100: the minimal failing tape
        // is exactly [101]
        let fails = |t: &[u64]| t.first().copied().unwrap_or(0) > 100;
        let (tape, replays) = shrink(vec![500_000, 7, 9], fails);
        assert_eq!(tape, vec![101]);
        assert!(replays <= SHRINK_BUDGET, "replays={replays}");
        // an always-failing property shrinks to the empty tape
        let (tape, _) = shrink(vec![1, 2, 3], |_| true);
        assert!(tape.is_empty());
    }

    #[test]
    fn close_slices_tolerance() {
        assert!(close_slices(&[1.0, 2.0], &[1.0 + 1e-12, 2.0], 1e-9).is_ok());
        assert!(close_slices(&[1.0], &[1.1], 1e-9).is_err());
        assert!(close_slices(&[1.0], &[1.0, 2.0], 1e-9).is_err());
        // relative scaling: big numbers allowed bigger absolute deviation
        assert!(close_slices(&[1e12], &[1e12 + 1.0], 1e-9).is_ok());
    }

    #[test]
    fn max_abs_diff_works() {
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 4.0]), 1.0);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }
}
