//! Mini property-based testing kit (proptest substitute).
//!
//! Offline build → no `proptest`/`quickcheck`. This module provides the
//! subset the test suite needs: seeded generators built on
//! [`crate::util::prng::Xoshiro256pp`], a `forall` driver that runs N cases
//! and reports the failing seed + case index (re-run with
//! `MADUPITE_PROP_SEED=<seed>` to reproduce), and helpers for the domain
//! types (probability vectors, sparse rows, random MDP shapes).
//!
//! No shrinking: cases are kept small by construction instead, which in
//! practice localizes failures well enough for this codebase.

use crate::util::prng::Xoshiro256pp;

/// Number of cases per property (override with MADUPITE_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("MADUPITE_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("MADUPITE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `prop` for `default_cases()` seeded cases. Each case gets its own
/// deterministic RNG. Panics with the reproducing seed on failure.
pub fn forall<F>(name: &str, mut prop: F)
where
    F: FnMut(&mut Xoshiro256pp) -> Result<(), String>,
{
    let cases = default_cases();
    let seed0 = base_seed();
    for case in 0..cases {
        let seed = seed0 ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Xoshiro256pp::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{cases}: {msg}\n\
                 reproduce with MADUPITE_PROP_SEED={seed0} (case seed {seed})"
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Check two f64 slices are elementwise close.
pub fn close_slices(a: &[f64], b: &[f64], tol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let scale = 1.0_f64.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Max |a-b| over slices (for diagnostics).
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial() {
        forall("trivial", |rng| {
            let x = rng.next_f64();
            prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'must-fail'")]
    fn forall_reports_failure() {
        forall("must-fail", |rng| {
            let x = rng.next_f64();
            prop_assert!(x < 0.5, "x={x}");
            Ok(())
        });
    }

    #[test]
    fn close_slices_tolerance() {
        assert!(close_slices(&[1.0, 2.0], &[1.0 + 1e-12, 2.0], 1e-9).is_ok());
        assert!(close_slices(&[1.0], &[1.1], 1e-9).is_err());
        assert!(close_slices(&[1.0], &[1.0, 2.0], 1e-9).is_err());
        // relative scaling: big numbers allowed bigger absolute deviation
        assert!(close_slices(&[1e12], &[1e12 + 1.0], 1e-9).is_ok());
    }

    #[test]
    fn max_abs_diff_works() {
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 4.0]), 1.0);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }
}
