//! Shared-memory parallel execution layer (hybrid rank × thread).
//!
//! madupite runs hybrid-parallel: MPI ranks distribute memory, and inside
//! each rank the PETSc kernels exploit the node's cores. Our reproduction
//! distributes memory across rank-threads ([`crate::comm`], DESIGN.md §3);
//! this module adds the *intra-rank* dimension — a zero-dependency worker
//! pool (`std::thread` only) that parallelizes the hot row loops of every
//! per-rank kernel: Bellman backups, CSR/dense SpMV, the matrix-free policy
//! operator, and the KSP vector kernels (dot, norms, axpy). DESIGN.md §11
//! has the full picture.
//!
//! # Deterministic, thread-count-independent reductions
//!
//! Floating-point addition is not associative, so a naive parallel sum
//! would change with the thread count. Every primitive here therefore works
//! over a **fixed chunk grid** that depends only on the problem size `n`
//! (never on the thread count): ranges below [`MIN_PAR`] items are a single
//! chunk evaluated inline, larger ranges are cut into [`GRID_CHUNK`]-sized
//! chunks. Threads only decide *who* computes a chunk; per-chunk partials
//! are always combined **in ascending chunk order** on the calling thread.
//! The result is bitwise identical for `threads = 1..N` — proven by
//! `tests/par_determinism.rs` across the full method × backend matrix.
//!
//! Inside each chunk, the arithmetic itself runs through the
//! [`crate::util::simd`] lane kernels (DESIGN.md §13), which fold their
//! `LANES` partial sums in a fixed order too — so the two layers compose:
//! the chunk grid fixes the outer association, the lane fold fixes the
//! inner one, and neither depends on the thread count.
//!
//! # Pool lifecycle
//!
//! Each rank-thread lazily owns one persistent [`ThreadPool`], created on
//! the first sufficiently large kernel call and sized by
//! [`configured_threads`] (the `-threads` option / `MADUPITE_THREADS`
//! environment variable, default 1 — fully serial execution). Note that
//! the chunked reduction *order* applies at **every** thread count,
//! including 1: a reduction over ≥ [`MIN_PAR`] items folds per-chunk
//! partials rather than one long left-to-right sum, so large-problem
//! results can differ bitwise from pre-hybrid releases (by design — the
//! invariant is thread-count independence, not cross-release bit
//! stability). The pool lives in a thread-local, so it is dropped (workers
//! joined) when the rank-thread exits at the end of `World::run`. Nested
//! parallel regions — a kernel invoked from inside a chunk body, on either
//! the caller lane or a worker — detect the situation and run inline over
//! the same grid, so determinism survives composition and the thread count
//! can never multiply.

use std::cell::{Cell, RefCell};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// Row count of one grid chunk for ranges of at least [`MIN_PAR`] items.
pub const GRID_CHUNK: usize = 2048;

/// Ranges smaller than this are a single chunk evaluated inline on the
/// caller — parallel dispatch would cost more than it saves, and the
/// cutoff depends only on the problem size, preserving determinism.
pub const MIN_PAR: usize = 4096;

/// Process-wide thread-count configuration (`0` = unset, fall back to the
/// `MADUPITE_THREADS` environment variable, then 1).
static CONFIGURED: AtomicUsize = AtomicUsize::new(0);
/// Cached `MADUPITE_THREADS` resolution (`0` = not read yet).
static ENV_DEFAULT: AtomicUsize = AtomicUsize::new(0);

/// Set the intra-rank thread count for subsequent parallel regions (the
/// `-threads` option lands here via `api::options::resolve_threads`).
/// Values are clamped to at least 1. Each rank's pool is rebuilt lazily on
/// its next parallel region if the size changed.
pub fn set_threads(n: usize) {
    CONFIGURED.store(n.max(1), Ordering::Relaxed);
}

/// The thread count parallel regions currently run with: the value set by
/// [`set_threads`], else a positive-integer `MADUPITE_THREADS` environment
/// variable, else 1.
pub fn configured_threads() -> usize {
    let t = CONFIGURED.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let cached = ENV_DEFAULT.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let resolved = std::env::var("MADUPITE_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&v| v >= 1)
        .unwrap_or(1);
    ENV_DEFAULT.store(resolved, Ordering::Relaxed);
    resolved
}

thread_local! {
    /// The rank-thread's persistent pool (created lazily, joined on exit).
    static RANK_POOL: RefCell<Option<ThreadPool>> = const { RefCell::new(None) };
    /// True while this thread is the caller lane of an active region.
    static IN_REGION: Cell<bool> = const { Cell::new(false) };
    /// True on pool worker threads (set once at spawn).
    static IS_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// A lane body dispatched to the pool: called once per lane with the lane
/// index in `[0, lanes)`. Type- and lifetime-erased to a raw data pointer
/// plus a monomorphized invoke shim; soundness is the pool's completion
/// wait (see [`ThreadPool::run`]) — the pointee outlives every call.
#[derive(Clone, Copy)]
struct Job {
    data: *const (),
    invoke: unsafe fn(*const (), usize),
}

// SAFETY: `Job` is only built by `ThreadPool::run` from an `&F` where
// `F: Fn(usize) + Sync`, so sharing the pointee across worker threads is
// sound, and `run` blocks until no worker can still call it.
unsafe impl Send for Job {}

/// State shared between a pool's caller and its workers.
struct Shared {
    state: Mutex<State>,
    /// Workers wait here for a new epoch.
    work: Condvar,
    /// The caller waits here for `active == 0`.
    done: Condvar,
}

struct State {
    epoch: u64,
    job: Option<Job>,
    /// Workers still running the current epoch's job.
    active: usize,
    /// A worker's lane body panicked this epoch.
    panicked: bool,
    shutdown: bool,
}

/// A small persistent worker pool owned by one rank-thread.
///
/// `lanes` counts the caller too: a pool of `lanes = T` has `T − 1` parked
/// worker threads, and [`ThreadPool::run`] executes the lane body on all
/// `T` lanes (lane 0 on the caller). `lanes = 1` spawns nothing and runs
/// inline. Workers park on a condvar between regions, so a region costs
/// one mutex/condvar round-trip rather than `T` thread spawns.
pub struct ThreadPool {
    lanes: usize,
    shared: Option<Arc<Shared>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Pool with `lanes` total lanes (clamped to at least 1); spawns
    /// `lanes − 1` parked worker threads.
    pub fn new(lanes: usize) -> ThreadPool {
        let lanes = lanes.max(1);
        if lanes == 1 {
            return ThreadPool {
                lanes,
                shared: None,
                workers: Vec::new(),
            };
        }
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let mut workers = Vec::with_capacity(lanes - 1);
        for lane in 1..lanes {
            let shared = Arc::clone(&shared);
            let handle = thread::Builder::new()
                .name(format!("madupite-par{lane}"))
                .spawn(move || worker_loop(lane, shared))
                .expect("failed to spawn pool worker");
            workers.push(handle);
        }
        ThreadPool {
            lanes,
            shared: Some(shared),
            workers,
        }
    }

    /// Total lanes (caller + workers).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Run `body(lane)` once on every lane; lane 0 executes on the calling
    /// thread. Blocks until all lanes finished. A panic in any lane body is
    /// re-raised on the caller *after* every lane completed, so borrowed
    /// data never outlives a running worker.
    pub fn run<F: Fn(usize) + Sync>(&self, body: &F) {
        let Some(shared) = &self.shared else {
            body(0);
            return;
        };
        /// Monomorphized shim recovering the erased closure type.
        ///
        /// # Safety
        /// `ptr` must point at a live `F`; guaranteed because `run` does
        /// not return (or unwind) until `active == 0`, i.e. until no
        /// worker can still invoke the job.
        unsafe fn invoke<F: Fn(usize)>(ptr: *const (), lane: usize) {
            // SAFETY: see the function contract above.
            unsafe { (*ptr.cast::<F>())(lane) }
        }
        let job = Job {
            data: (body as *const F).cast::<()>(),
            invoke: invoke::<F>,
        };
        {
            let mut st = shared.state.lock().unwrap();
            st.job = Some(job);
            st.active = self.workers.len();
            st.epoch = st.epoch.wrapping_add(1);
            shared.work.notify_all();
        }
        // Caller is lane 0. Catch a caller-lane panic so we still wait for
        // the workers before unwinding frees the borrowed data.
        let caller = catch_unwind(AssertUnwindSafe(|| body(0)));
        let worker_panicked = {
            let mut st = shared.state.lock().unwrap();
            while st.active > 0 {
                st = shared.done.wait(st).unwrap();
            }
            st.job = None;
            std::mem::replace(&mut st.panicked, false)
        };
        if let Err(payload) = caller {
            resume_unwind(payload);
        }
        if worker_panicked {
            panic!("a worker thread panicked inside a parallel region");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        if let Some(shared) = &self.shared {
            shared.state.lock().unwrap().shutdown = true;
            shared.work.notify_all();
        }
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(lane: usize, shared: Arc<Shared>) {
    IS_WORKER.with(|f| f.set(true));
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break st.job.expect("epoch bumped without a job");
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        // SAFETY: the caller of `run` blocks until this epoch completes,
        // so the erased closure behind `job.data` is still alive.
        let outcome = catch_unwind(AssertUnwindSafe(|| unsafe { (job.invoke)(job.data, lane) }));
        let mut st = shared.state.lock().unwrap();
        if outcome.is_err() {
            st.panicked = true;
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done.notify_all();
        }
    }
}

/// Number of grid chunks for a range of `n` items (a pure function of `n`,
/// which is what makes every reduction thread-count-independent).
fn chunk_count(n: usize) -> usize {
    if n < MIN_PAR {
        1
    } else {
        n.div_ceil(GRID_CHUNK)
    }
}

/// Bounds of chunk `c` in the grid of `n` items.
fn chunk_bounds(n: usize, nchunks: usize, c: usize) -> (usize, usize) {
    if nchunks == 1 {
        (0, n)
    } else {
        (c * GRID_CHUNK, ((c + 1) * GRID_CHUNK).min(n))
    }
}

/// Contiguous chunk-index span `[lo, hi)` owned by `lane` of `lanes`.
fn lane_span(nchunks: usize, lanes: usize, lane: usize) -> (usize, usize) {
    let per = nchunks / lanes;
    let rem = nchunks % lanes;
    let lo = lane * per + lane.min(rem);
    (lo, lo + per + usize::from(lane < rem))
}

/// Clears the in-region flag even if the region body unwinds.
struct RegionGuard;

impl RegionGuard {
    fn enter() -> RegionGuard {
        IN_REGION.with(|f| f.set(true));
        RegionGuard
    }
}

impl Drop for RegionGuard {
    fn drop(&mut self) {
        IN_REGION.with(|f| f.set(false));
    }
}

/// Core dispatcher: invoke `body(c, lo, hi)` for every chunk of the fixed
/// grid over `[0, n)`, spreading contiguous chunk spans over the rank
/// pool's lanes (or inline when small, serial, nested, or on a worker).
fn run_chunks(n: usize, body: &(dyn Fn(usize, usize, usize) + Sync)) {
    if n == 0 {
        return;
    }
    let nchunks = chunk_count(n);
    let serial = || {
        for c in 0..nchunks {
            let (lo, hi) = chunk_bounds(n, nchunks, c);
            body(c, lo, hi);
        }
    };
    if nchunks == 1
        || IS_WORKER.with(|f| f.get())
        || IN_REGION.with(|f| f.get())
        || configured_threads() == 1
    {
        serial();
        return;
    }
    RANK_POOL.with(|cell| {
        let mut slot = cell.borrow_mut();
        let want = configured_threads();
        if slot.as_ref().map(|p| p.lanes()) != Some(want) {
            *slot = Some(ThreadPool::new(want));
        }
        let pool = slot.as_ref().expect("pool installed above");
        if pool.lanes() == 1 {
            serial();
            return;
        }
        let lanes = pool.lanes();
        let _region = RegionGuard::enter();
        pool.run(&|lane| {
            let (clo, chi) = lane_span(nchunks, lanes, lane);
            for c in clo..chi {
                let (lo, hi) = chunk_bounds(n, nchunks, c);
                body(c, lo, hi);
            }
        });
    });
}

/// Raw-pointer wrapper making disjoint chunk writes shareable across
/// lanes. Soundness: every chunk of the grid is visited by exactly one
/// lane, and chunk ranges are disjoint by construction.
struct SendPtr<T>(*mut T);

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    fn get(&self) -> *mut T {
        self.0
    }
}

/// Chunked parallel-for over row ranges: `body(offset, chunk)` receives
/// each grid chunk of `out` as a disjoint mutable sub-slice starting at
/// global row `offset`. Rows are independent, so results are bitwise
/// identical for every thread count.
pub fn par_for_rows<T, F>(out: &mut [T], body: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = out.len();
    if n == 0 {
        return;
    }
    if chunk_count(n) == 1 {
        // Single-chunk grid (n < MIN_PAR): identical at every thread
        // count; skip the dispatch machinery on this hot path.
        body(0, out);
        return;
    }
    let ptr = SendPtr(out.as_mut_ptr());
    run_chunks(n, &|_c, lo, hi| {
        // SAFETY: chunks are disjoint and each is visited exactly once, so
        // the sub-slices never alias; `out` is untouched until return.
        let chunk = unsafe { std::slice::from_raw_parts_mut(ptr.get().add(lo), hi - lo) };
        body(lo, chunk);
    });
}

/// Two-output variant of [`par_for_rows`] with a deterministic reduction:
/// `body(offset, a_chunk, b_chunk) -> R` runs per grid chunk; the per-chunk
/// partials are folded **in ascending chunk order** on the caller, so the
/// result is bitwise identical for every thread count. Returns `None` for
/// empty inputs. This is the Bellman-backup shape (values + greedy actions
/// + residual max).
pub fn par_for_rows2<A, B, R, F, G>(a: &mut [A], b: &mut [B], body: F, fold: G) -> Option<R>
where
    A: Send,
    B: Send,
    R: Send,
    F: Fn(usize, &mut [A], &mut [B]) -> R + Sync,
    G: FnMut(R, R) -> R,
{
    let n = a.len();
    assert_eq!(n, b.len(), "par_for_rows2: slice lengths differ");
    if n == 0 {
        return None;
    }
    let nchunks = chunk_count(n);
    if nchunks == 1 {
        // Single-chunk grid: same value at every thread count; skip the
        // partials allocation on this hot path.
        return Some(body(0, a, b));
    }
    let mut partials: Vec<Option<R>> = (0..nchunks).map(|_| None).collect();
    let pa = SendPtr(a.as_mut_ptr());
    let pb = SendPtr(b.as_mut_ptr());
    let pp = SendPtr(partials.as_mut_ptr());
    run_chunks(n, &|c, lo, hi| {
        // SAFETY: disjoint chunks, one visit per chunk (see par_for_rows);
        // partial slot `c` is likewise written by exactly one lane.
        let ca = unsafe { std::slice::from_raw_parts_mut(pa.get().add(lo), hi - lo) };
        let cb = unsafe { std::slice::from_raw_parts_mut(pb.get().add(lo), hi - lo) };
        let r = body(lo, ca, cb);
        unsafe { *pp.get().add(c) = Some(r) };
    });
    partials
        .into_iter()
        .map(|p| p.expect("every chunk produced a partial"))
        .reduce(fold)
}

/// Deterministic parallel reduction: `body(lo, hi) -> R` runs once per grid
/// chunk of `[0, n)`; partials are folded **in ascending chunk order** on
/// the caller. Bitwise identical for every thread count (the grid depends
/// only on `n`). Returns `None` when `n == 0`.
pub fn par_reduce<R, F, G>(n: usize, body: F, fold: G) -> Option<R>
where
    R: Send,
    F: Fn(usize, usize) -> R + Sync,
    G: FnMut(R, R) -> R,
{
    if n == 0 {
        return None;
    }
    let nchunks = chunk_count(n);
    if nchunks == 1 {
        // Single-chunk grid (every dot/norm below MIN_PAR, e.g. the
        // per-row dots of DenseOp): same value at every thread count, and
        // no partials allocation on this hot path.
        return Some(body(0, n));
    }
    let mut partials: Vec<Option<R>> = (0..nchunks).map(|_| None).collect();
    let pp = SendPtr(partials.as_mut_ptr());
    run_chunks(n, &|c, lo, hi| {
        let r = body(lo, hi);
        // SAFETY: slot `c` is written by exactly one lane.
        unsafe { *pp.get().add(c) = Some(r) };
    });
    partials
        .into_iter()
        .map(|p| p.expect("every chunk produced a partial"))
        .reduce(fold)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256pp;

    fn noisy(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Xoshiro256pp::new(seed);
        (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect()
    }

    #[test]
    fn grid_depends_only_on_n() {
        assert_eq!(chunk_count(0), 1);
        assert_eq!(chunk_count(MIN_PAR - 1), 1);
        assert_eq!(chunk_count(MIN_PAR), MIN_PAR / GRID_CHUNK);
        let n = 10 * GRID_CHUNK + 7;
        let nchunks = chunk_count(n);
        let mut covered = 0;
        for c in 0..nchunks {
            let (lo, hi) = chunk_bounds(n, nchunks, c);
            assert_eq!(lo, covered);
            covered = hi;
        }
        assert_eq!(covered, n);
    }

    #[test]
    fn lane_span_partitions_chunks() {
        for (nchunks, lanes) in [(1usize, 4usize), (7, 3), (16, 4), (5, 8)] {
            let mut covered = 0;
            for lane in 0..lanes {
                let (lo, hi) = lane_span(nchunks, lanes, lane);
                assert_eq!(lo, covered);
                covered = hi;
            }
            assert_eq!(covered, nchunks);
        }
    }

    #[test]
    fn par_for_rows_matches_serial() {
        let n = 3 * MIN_PAR + 17;
        let x = noisy(n, 1);
        for t in [1usize, 2, 5] {
            set_threads(t);
            let mut y = vec![0.0; n];
            par_for_rows(&mut y, |offset, chunk| {
                for (i, yi) in chunk.iter_mut().enumerate() {
                    *yi = 2.0 * x[offset + i] + 1.0;
                }
            });
            for (yi, xi) in y.iter().zip(&x) {
                assert_eq!(*yi, 2.0 * xi + 1.0);
            }
        }
        set_threads(1);
    }

    #[test]
    fn par_reduce_bitwise_identical_across_thread_counts() {
        let n = 5 * MIN_PAR + 123;
        let x = noisy(n, 2);
        let y = noisy(n, 3);
        let mut reference: Option<u64> = None;
        for t in [1usize, 2, 3, 8] {
            set_threads(t);
            let dot = par_reduce(
                n,
                |lo, hi| crate::linalg::dot(&x[lo..hi], &y[lo..hi]),
                |a, b| a + b,
            )
            .unwrap();
            match reference {
                None => reference = Some(dot.to_bits()),
                Some(bits) => assert_eq!(bits, dot.to_bits(), "threads={t} diverged"),
            }
        }
        set_threads(1);
    }

    #[test]
    fn par_for_rows2_reduction_in_chunk_order() {
        let n = 2 * MIN_PAR;
        set_threads(4);
        let mut a = vec![0.0f64; n];
        let mut b = vec![0usize; n];
        let max = par_for_rows2(
            &mut a,
            &mut b,
            |offset, ca, cb| {
                let mut m = 0.0f64;
                for (i, (ai, bi)) in ca.iter_mut().zip(cb.iter_mut()).enumerate() {
                    *ai = (offset + i) as f64;
                    *bi = offset + i;
                    m = m.max(*ai);
                }
                m
            },
            f64::max,
        )
        .unwrap();
        assert_eq!(max, (n - 1) as f64);
        assert_eq!(a[n - 1], (n - 1) as f64);
        assert_eq!(b[7], 7);
        set_threads(1);
    }

    #[test]
    fn nested_regions_run_inline_and_stay_deterministic() {
        let n = 2 * MIN_PAR;
        let x = noisy(n, 9);
        set_threads(4);
        let mut y = vec![0.0; n];
        // The chunk body calls another parallel primitive; it must inline.
        par_for_rows(&mut y, |offset, chunk| {
            let inner = par_reduce(chunk.len(), |lo, hi| (hi - lo) as f64, |a, b| a + b).unwrap();
            assert_eq!(inner, chunk.len() as f64);
            for (i, yi) in chunk.iter_mut().enumerate() {
                *yi = x[offset + i];
            }
        });
        assert_eq!(y, x);
        set_threads(1);
    }

    #[test]
    fn panic_in_chunk_body_propagates_and_pool_survives() {
        let n = 2 * MIN_PAR;
        set_threads(3);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut y = vec![0.0f64; n];
            par_for_rows(&mut y, |offset, _chunk| {
                if offset == 0 {
                    panic!("deliberate chunk panic");
                }
            });
        }));
        assert!(result.is_err(), "panic must propagate to the caller");
        // The pool must still be usable afterwards.
        let mut y = vec![0.0f64; n];
        par_for_rows(&mut y, |_, chunk| chunk.fill(1.0));
        assert!(y.iter().all(|&v| v == 1.0));
        set_threads(1);
    }

    #[test]
    fn pool_resizes_when_configuration_changes() {
        let n = 2 * MIN_PAR;
        for t in [2usize, 4, 1, 3] {
            set_threads(t);
            let total = par_reduce(n, |lo, hi| (hi - lo) as f64, |a, b| a + b).unwrap();
            assert_eq!(total, n as f64);
        }
        set_threads(1);
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert!(par_reduce(0, |_, _| 1.0f64, |a, b| a + b).is_none());
        let mut empty: Vec<f64> = Vec::new();
        par_for_rows(&mut empty, |_, _| panic!("must not be called"));
        let mut one = vec![0.0f64];
        par_for_rows(&mut one, |offset, c| {
            assert_eq!((offset, c.len()), (0, 1));
            c[0] = 5.0;
        });
        assert_eq!(one[0], 5.0);
    }
}
