//! Deterministic pseudo-random number generation.
//!
//! The build environment has no `rand` crate, so madupite-rs ships its own
//! small PRNG substrate: [`SplitMix64`] for seeding and [`Xoshiro256pp`]
//! (xoshiro256++, Blackman & Vigna) as the workhorse generator used by the
//! model generators, property tests and benches. Both are fully
//! deterministic from a `u64` seed, which keeps every experiment in
//! EXPERIMENTS.md reproducible bit-for-bit.

/// SplitMix64: a tiny, high-quality 64-bit mixer.
///
/// Used to expand a single `u64` seed into the 256-bit state of
/// [`Xoshiro256pp`], and directly wherever a throwaway stream is enough.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, 256-bit state, passes BigCrush.
///
/// This is the default generator for all stochastic model builders
/// (`models::garnet`, maze slip noise, ...).
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 expansion (the construction recommended by the
    /// xoshiro authors — avoids the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of entropy.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire's multiply-shift rejection-free
    /// variant is overkill here; modulo bias is negligible for bound << 2^64
    /// but we use the widening-multiply trick anyway for exactness).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "next_below(0)");
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize index in `[0, bound)`.
    pub fn index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform `f64` in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (one value; the pair's twin is dropped
    /// for simplicity — generators here are not perf-critical).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Random probability vector of length `n` (normalized exponentials —
    /// i.e. a sample from a flat Dirichlet).
    pub fn prob_vector(&mut self, n: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..n).map(|_| -self.next_f64().max(1e-300).ln()).collect();
        let s: f64 = v.iter().sum();
        for x in &mut v {
            *x /= s;
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference sequence for seed 1234567 (computed from the published
        // algorithm; stability of this test pins our implementation).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic() {
        let mut a = Xoshiro256pp::new(42);
        let mut b = Xoshiro256pp::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xoshiro_different_seeds_differ() {
        let mut a = Xoshiro256pp::new(1);
        let mut b = Xoshiro256pp::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256pp::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Xoshiro256pp::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_bounds() {
        let mut r = Xoshiro256pp::new(11);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..100 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn next_below_covers_small_range() {
        let mut r = Xoshiro256pp::new(13);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            seen[r.next_below(5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn prob_vector_sums_to_one() {
        let mut r = Xoshiro256pp::new(17);
        for n in [1usize, 2, 5, 100] {
            let p = r.prob_vector(n);
            assert_eq!(p.len(), n);
            let s: f64 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "sum={s}");
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::new(19);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Xoshiro256pp::new(23);
        let s = r.sample_indices(50, 10);
        assert_eq!(s.len(), 10);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 10);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256pp::new(29);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
