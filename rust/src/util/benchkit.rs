//! Micro/macro benchmark harness (criterion substitute).
//!
//! The offline build environment has no `criterion`, so the bench targets in
//! `rust/benches/` use this small harness instead: warmup, fixed-count or
//! time-budgeted repetition, median/mean/stddev/min, aligned-table printing,
//! and JSON export so EXPERIMENTS.md tables can be regenerated verbatim.
//!
//! ## Baseline capture protocol (`BENCH_0.json`)
//!
//! The repo-root `BENCH_0.json` pins the kernel-performance baseline the
//! §13 backend work is measured against. To (re)capture it, run the two
//! kernel-adjacent suites in quick mode with a pinned shape, then merge
//! their JSON exports:
//!
//! ```text
//! cd rust
//! MADUPITE_BENCH_SAMPLES=5 MADUPITE_BENCH_BUDGET_MS=1000 \
//!   MADUPITE_BENCH_THREADS=1,4 MADUPITE_BENCH_MAX_N=100000 \
//!   cargo bench --bench bench_kernels
//! MADUPITE_BENCH_SAMPLES=5 MADUPITE_BENCH_BUDGET_MS=1000 \
//!   cargo bench --bench bench_solvers
//! jq -s '{schema: "madupite-bench-baseline/v1",
//!         captured: (now | todate),
//!         pinned_config: {samples: 5, budget_ms: 1000,
//!                         threads: "1,4", max_n: 100000},
//!         suites: .}' \
//!   target/bench-json/e6-kernels.json \
//!   target/bench-json/e1-method-comparison.json > ../BENCH_0.json
//! ```
//!
//! (The slug of each suite's JSON file is printed by [`Suite::finish`];
//! adjust the paths if suite titles change.) Workloads are deterministic
//! in their seeds, so a recapture on the same machine measures the same
//! work; compare `median_s` per case name. The committed file records
//! `status: "pending-capture"` when it was produced on a machine without
//! a usable toolchain — treat the first real capture as the baseline.

use crate::util::json::Json;
use std::time::{Duration, Instant};

/// Statistics for one measured case.
#[derive(Debug, Clone)]
pub struct Stats {
    /// Case label (row name in the report table).
    pub name: String,
    /// Per-iteration wall times, seconds.
    pub samples: Vec<f64>,
    /// Optional scalar metrics attached by the workload (e.g. iterations,
    /// SpMV count, comm bytes) — reported alongside the timing columns.
    pub metrics: Vec<(String, f64)>,
}

impl Stats {
    /// Median of the recorded samples.
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if s.is_empty() {
            return f64::NAN;
        }
        let n = s.len();
        if n % 2 == 1 {
            s[n / 2]
        } else {
            0.5 * (s[n / 2 - 1] + s[n / 2])
        }
    }

    /// Arithmetic mean of the recorded samples.
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation of the recorded samples.
    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    /// Minimum recorded sample.
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Stats as a JSON object (name, n, median, mean, stddev, min).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::str(self.name.clone())),
            ("median_s", Json::num(self.median())),
            ("mean_s", Json::num(self.mean())),
            ("stddev_s", Json::num(self.stddev())),
            ("min_s", Json::num(self.min())),
            ("samples", Json::int(self.samples.len() as i64)),
        ];
        for (k, v) in &self.metrics {
            pairs.push((k.as_str(), Json::num(*v)));
        }
        // keys borrowed from metrics — rebuild with owned keys
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }
}

/// A benchmark suite: collects cases, prints a table, writes JSON.
pub struct Suite {
    /// Suite title (report heading).
    pub title: String,
    /// Collected per-case statistics, in run order.
    pub results: Vec<Stats>,
    /// Max samples per case.
    pub max_samples: usize,
    /// Time budget per case (stop sampling when exceeded).
    pub budget: Duration,
    /// Warmup runs per case.
    pub warmup: usize,
}

impl Suite {
    /// New empty suite titled `title`.
    pub fn new(title: &str) -> Self {
        // Environment knobs let CI shrink the suites:
        // MADUPITE_BENCH_SAMPLES / MADUPITE_BENCH_BUDGET_MS.
        let max_samples = std::env::var("MADUPITE_BENCH_SAMPLES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(5);
        let budget_ms = std::env::var("MADUPITE_BENCH_BUDGET_MS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(10_000u64);
        Suite {
            title: title.to_string(),
            results: Vec::new(),
            max_samples,
            budget: Duration::from_millis(budget_ms),
            warmup: 1,
        }
    }

    /// Measure `f` repeatedly. `f` returns optional metrics recorded with the
    /// case (the metrics of the last run win).
    pub fn case<F>(&mut self, name: &str, mut f: F) -> &Stats
    where
        F: FnMut() -> Vec<(String, f64)>,
    {
        for _ in 0..self.warmup {
            let _ = f();
        }
        let mut samples = Vec::new();
        let mut metrics = Vec::new();
        let start = Instant::now();
        for _ in 0..self.max_samples {
            let t0 = Instant::now();
            metrics = f();
            samples.push(t0.elapsed().as_secs_f64());
            if start.elapsed() > self.budget {
                break;
            }
        }
        self.results.push(Stats {
            name: name.to_string(),
            samples,
            metrics,
        });
        self.results.last().unwrap()
    }

    /// Render an aligned text table of all cases.
    pub fn table(&self) -> String {
        let mut metric_keys: Vec<String> = Vec::new();
        for r in &self.results {
            for (k, _) in &r.metrics {
                if !metric_keys.contains(k) {
                    metric_keys.push(k.clone());
                }
            }
        }
        let mut header = vec![
            "case".to_string(),
            "median".to_string(),
            "mean".to_string(),
            "stddev".to_string(),
            "min".to_string(),
            "n".to_string(),
        ];
        header.extend(metric_keys.iter().cloned());
        let mut rows = vec![header];
        for r in &self.results {
            let mut row = vec![
                r.name.clone(),
                fmt_time(r.median()),
                fmt_time(r.mean()),
                fmt_time(r.stddev()),
                fmt_time(r.min()),
                format!("{}", r.samples.len()),
            ];
            for k in &metric_keys {
                let v = r.metrics.iter().find(|(mk, _)| mk == k).map(|(_, v)| *v);
                row.push(v.map(fmt_metric).unwrap_or_default());
            }
            rows.push(row);
        }
        render_table(&self.title, &rows)
    }

    /// Full suite report as JSON (title + per-benchmark stats).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            (
                "cases",
                Json::Arr(self.results.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }

    /// Print the table and write `target/bench-json/<slug>.json`.
    ///
    /// Write failures are *reported on stderr*, never swallowed: CI's
    /// perf-smoke job merges these files into the `BENCH_CI.json` artifact,
    /// and a silently missing suite would read as "no data" instead of
    /// "broken writer".
    pub fn finish(&self) {
        println!("{}", self.table());
        let slug: String = self
            .title
            .chars()
            .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '-' })
            .collect();
        let dir = std::path::Path::new("target/bench-json");
        if let Err(e) = std::fs::create_dir_all(dir) {
            eprintln!(
                "[benchkit] WARNING: cannot create {}: {e} — suite '{}' not exported",
                dir.display(),
                self.title
            );
            return;
        }
        let path = dir.join(format!("{slug}.json"));
        match std::fs::write(&path, self.to_json().to_string_pretty()) {
            Ok(()) => println!("[benchkit] wrote {}", path.display()),
            Err(e) => eprintln!(
                "[benchkit] WARNING: failed to write {}: {e} — suite '{}' not exported",
                path.display(),
                self.title
            ),
        }
    }
}

/// Thread counts for bench sweeps: the comma-separated
/// `MADUPITE_BENCH_THREADS` environment variable, else `default`.
/// Non-positive or unparsable entries are dropped; if nothing valid
/// remains, `default` wins. Shared by `bench_kernels`/`bench_scaling` so
/// the grammar cannot drift between them.
pub fn thread_counts(default: &[usize]) -> Vec<usize> {
    std::env::var("MADUPITE_BENCH_THREADS")
        .ok()
        .map(|s| {
            s.split(',')
                .filter_map(|t| t.trim().parse().ok())
                .filter(|&t| t >= 1)
                .collect::<Vec<usize>>()
        })
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| default.to_vec())
}

/// Human-scaled time formatting.
pub fn fmt_time(s: f64) -> String {
    if !s.is_finite() {
        return "-".to_string();
    }
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

fn fmt_metric(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        let i = v as i64;
        if i.abs() >= 10_000 {
            // thousands separators for big counters
            let mut s = String::new();
            let digits = i.abs().to_string();
            for (idx, c) in digits.chars().enumerate() {
                if idx > 0 && (digits.len() - idx) % 3 == 0 {
                    s.push('_');
                }
                s.push(c);
            }
            if i < 0 {
                format!("-{s}")
            } else {
                s
            }
        } else {
            format!("{i}")
        }
    } else {
        format!("{v:.4}")
    }
}

/// Render rows as an aligned table with a title rule.
pub fn render_table(title: &str, rows: &[Vec<String>]) -> String {
    let cols = rows.iter().map(|r| r.len()).max().unwrap_or(0);
    let mut widths = vec![0usize; cols];
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.chars().count());
        }
    }
    let total: usize = widths.iter().sum::<usize>() + 3 * cols.saturating_sub(1);
    let mut out = String::new();
    out.push_str(&format!("\n== {title} ==\n"));
    for (ri, row) in rows.iter().enumerate() {
        for (i, cell) in row.iter().enumerate() {
            let pad = widths[i] - cell.chars().count();
            out.push_str(cell);
            for _ in 0..pad {
                out.push(' ');
            }
            if i + 1 < cols {
                out.push_str(" | ");
            }
        }
        out.push('\n');
        if ri == 0 {
            for _ in 0..total {
                out.push('-');
            }
            out.push('\n');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_median_mean() {
        let s = Stats {
            name: "x".into(),
            samples: vec![3.0, 1.0, 2.0],
            metrics: vec![],
        };
        assert_eq!(s.median(), 2.0);
        assert!((s.mean() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
    }

    #[test]
    fn stats_median_even() {
        let s = Stats {
            name: "x".into(),
            samples: vec![1.0, 2.0, 3.0, 10.0],
            metrics: vec![],
        };
        assert_eq!(s.median(), 2.5);
    }

    #[test]
    fn stddev_zero_for_single() {
        let s = Stats {
            name: "x".into(),
            samples: vec![5.0],
            metrics: vec![],
        };
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn suite_runs_cases() {
        std::env::set_var("MADUPITE_BENCH_SAMPLES", "3");
        let mut suite = Suite::new("test suite");
        suite.case("noop", || vec![("iters".to_string(), 7.0)]);
        assert_eq!(suite.results.len(), 1);
        assert!(suite.results[0].samples.len() >= 1);
        assert_eq!(suite.results[0].metrics[0].1, 7.0);
        let table = suite.table();
        assert!(table.contains("noop"));
        assert!(table.contains("iters"));
        std::env::remove_var("MADUPITE_BENCH_SAMPLES");
    }

    #[test]
    fn fmt_time_scales() {
        assert_eq!(fmt_time(2.5), "2.500 s");
        assert_eq!(fmt_time(0.0025), "2.500 ms");
        assert_eq!(fmt_time(2.5e-6), "2.500 µs");
        assert_eq!(fmt_time(5e-9), "5 ns");
    }

    #[test]
    fn metric_thousands_separator() {
        assert_eq!(fmt_metric(1234567.0), "1_234_567");
        assert_eq!(fmt_metric(123.0), "123");
        assert_eq!(fmt_metric(0.5), "0.5000");
    }

    #[test]
    fn json_export_shape() {
        let s = Stats {
            name: "case".into(),
            samples: vec![1.0, 2.0],
            metrics: vec![("spmvs".to_string(), 10.0)],
        };
        let j = s.to_json();
        assert_eq!(j.get("name").unwrap().as_str(), Some("case"));
        assert_eq!(j.get("spmvs").unwrap().as_f64(), Some(10.0));
    }

    #[test]
    fn table_alignment_no_panic_ragged() {
        let rows = vec![
            vec!["a".to_string(), "b".to_string()],
            vec!["long-cell".to_string()],
        ];
        let t = render_table("t", &rows);
        assert!(t.contains("long-cell"));
    }
}
