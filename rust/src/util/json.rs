//! Minimal JSON implementation (value type, serializer, parser).
//!
//! The build environment has no `serde`/`serde_json`, so madupite-rs ships a
//! small self-contained JSON substrate used for:
//! - structured metrics / convergence-trace output (`solver::metrics`),
//! - machine-readable bench reports (EXPERIMENTS.md tables are generated
//!   from these),
//! - solver option files.
//!
//! It supports the full JSON data model; numbers are kept as `f64` (plus an
//! integer fast path on output). The parser is a straightforward
//! recursive-descent over bytes with proper string-escape handling.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic — important for golden tests and diffable experiment logs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (f64, like JavaScript).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Json>),
    /// JSON object (sorted keys — serialization is deterministic).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array.
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    /// String value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Float value.
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// Integer value (stored as f64, like JavaScript).
    pub fn int(x: i64) -> Json {
        Json::Num(x as f64)
    }

    /// Array of f64.
    pub fn nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// The number, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The string, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The bool, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The items, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object field lookup, if this is an `Obj`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_nan() || x.is_infinite() {
        // JSON has no NaN/Inf; emit null (documented behaviour for metrics).
        out.push_str("null");
    } else if x == x.trunc() && x.abs() < 9.0e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        // Ryu-style shortest printing is what format! gives us for f64.
        out.push_str(&format!("{x}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Human-readable parse failure.
    pub msg: String,
    /// Byte offset of the failure in the input.
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: handle the high surrogate by
                            // peeking a following \uXXXX low surrogate.
                            if (0xD800..0xDC00).contains(&cp) {
                                self.pos += 5;
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    let hex2 = std::str::from_utf8(
                                        &self.bytes[self.pos + 2..self.pos + 6],
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    s.push(
                                        char::from_u32(c).ok_or_else(|| self.err("bad cp"))?,
                                    );
                                    self.pos += 5; // loop tail adds 1
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                                continue;
                            }
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (we validated input is &str).
                    let start = self.pos;
                    let rest = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.5", "1e3"] {
            let v = Json::parse(src).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "src={src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "line1\nline2\t\"quoted\" \\back\\ unicode: ψ✓";
        let v = Json::Str(s.to_string());
        let parsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(parsed.as_str(), Some(s));
    }

    #[test]
    fn unicode_escape_parse() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn surrogate_pair_parse() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn numbers_preserved() {
        let v = Json::parse("[0.1, -2.5e-3, 123456789, -0.0]").unwrap();
        let a = v.as_arr().unwrap();
        assert!((a[0].as_f64().unwrap() - 0.1).abs() < 1e-15);
        assert!((a[1].as_f64().unwrap() + 0.0025).abs() < 1e-15);
        assert_eq!(a[2].as_f64().unwrap(), 123456789.0);
    }

    #[test]
    fn integer_output_has_no_decimal_point() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(-3.0).to_string(), "-3");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }

    #[test]
    fn nan_inf_serialize_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn object_keys_sorted_deterministic() {
        let v = Json::obj(vec![("zeta", Json::int(1)), ("alpha", Json::int(2))]);
        assert_eq!(v.to_string(), r#"{"alpha":2,"zeta":1}"#);
    }

    #[test]
    fn pretty_print_parses_back() {
        let v = Json::obj(vec![
            ("trace", Json::nums(&[1.0, 0.5, 0.25])),
            ("solver", Json::str("gmres")),
        ]);
        let pretty = v.to_string_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn errors_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::Arr(vec![]).to_string_pretty(), "[]");
    }
}
