//! madupite CLI — solve, generate and inspect large-scale MDPs.
//!
//! Usage (PETSc/madupite-style options database):
//!
//! ```text
//! madupite solve    -model maze -rows 200 -cols 200 -gamma 0.99
//!                   -method ipi -ksp_type gmres -alpha 1e-4 -atol 1e-8
//!                   -ranks 4 [-json out.json] [-verbose]
//! madupite solve    -file model.mdpb -method mpi -sweeps 20
//! madupite generate -model sis -population 10000 -gamma 0.95 -file out.mdpb
//! madupite info     -file model.mdpb
//! madupite artifacts [-dir artifacts]
//! ```
//!
//! `-model` ∈ {maze, grid, sis, traffic, garnet, inventory, queueing}.
//! `-method` ∈ {vi, mpi, pi, ipi}; `-ksp_type` ∈ {richardson, gmres,
//! bicgstab, tfqmr}; `-pc_type` ∈ {none, jacobi, sor}.

use madupite::comm::World;
use madupite::ksp::precond::PcType;
use madupite::ksp::KspType;
use madupite::mdp::io;
use madupite::models::{
    garnet::GarnetSpec, gridworld::GridSpec, inventory::InventorySpec, queueing::QueueSpec,
    replacement::ReplacementSpec, sis::SisSpec, traffic::TrafficSpec, ModelGenerator,
};
use madupite::solver::{gather_result, solve_dist, EvalBackend, Method, SolveOptions};
use madupite::util::args::Options;
use std::sync::Arc;

fn main() {
    let opts = Options::from_env();
    let cmd = opts.positional().first().cloned().unwrap_or_default();
    let code = match cmd.as_str() {
        "solve" => cmd_solve(&opts),
        "generate" => cmd_generate(&opts),
        "info" => cmd_info(&opts),
        "artifacts" => cmd_artifacts(&opts),
        "" | "help" | "-h" | "--help" => {
            print_help();
            Ok(())
        }
        other => Err(format!("unknown command '{other}' (try `madupite help`)")),
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e}");
        1
    });
    let unused = opts.unused_keys();
    if !unused.is_empty() {
        eprintln!("warning: unused options: {unused:?}");
    }
    std::process::exit(code);
}

fn print_help() {
    println!(
        "madupite-rs {} — distributed solver for large-scale MDPs\n\n\
         commands:\n\
         \x20 solve     -model <name> | -file <path>, -method vi|mpi|pi|ipi, -ranks N\n\
         \x20 generate  -model <name> -file <out.mdpb> [-ranks N] [-objective min|max]\n\
         \x20           [-chunk_rows K]  (streaming v2 writer: O(chunk) memory,\n\
         \x20           rank-parallel, bytes identical for every N)\n\
         \x20 info      -file <path.mdpb>\n\
         \x20 artifacts [-dir artifacts]  (list + smoke-compile PJRT artifacts)\n\n\
         common options: -gamma G -atol T -alpha A -adaptive_forcing\n\
         \x20               -ksp_type K -pc_type P -objective min|max\n\
         \x20               -eval_backend matfree|assembled  (policy-evaluation\n\
         \x20               operator: fused matrix-free vs cached P_pi CSR)\n\
         model options:  -rows/-cols/-seed (maze, grid), -population (sis),\n\
         \x20               -capacity (traffic, inventory, queueing),\n\
         \x20               -num_states (replacement, garnet),\n\
         \x20               -num_actions/-branching (garnet)",
        madupite::VERSION
    );
}

fn err_str<E: std::fmt::Display>(e: E) -> String {
    e.to_string()
}

/// Build the generator named by `-model` from its options.
fn make_generator(opts: &Options) -> Result<Arc<dyn ModelGenerator + Send + Sync>, String> {
    let model = opts.get_str("model", "maze");
    let seed = opts.get_u64("seed", 42).map_err(err_str)?;
    Ok(match model.as_str() {
        "maze" => Arc::new(GridSpec::maze(
            opts.get_usize("rows", 64).map_err(err_str)?,
            opts.get_usize("cols", 64).map_err(err_str)?,
            seed,
        )),
        "grid" => Arc::new(GridSpec::open(
            opts.get_usize("rows", 64).map_err(err_str)?,
            opts.get_usize("cols", 64).map_err(err_str)?,
        )),
        "sis" => Arc::new(SisSpec::standard(
            opts.get_usize("population", 1000).map_err(err_str)?,
            opts.get_usize("num_actions", 4).map_err(err_str)?,
        )),
        "traffic" => Arc::new(TrafficSpec::standard(
            opts.get_usize("capacity", 12).map_err(err_str)?,
        )),
        "garnet" => Arc::new(GarnetSpec::new(
            opts.get_usize("num_states", 1000).map_err(err_str)?,
            opts.get_usize("num_actions", 4).map_err(err_str)?,
            opts.get_usize("branching", 5).map_err(err_str)?,
            seed,
        )),
        "inventory" => Arc::new(InventorySpec::standard(
            opts.get_usize("capacity", 50).map_err(err_str)?,
        )),
        "queueing" => Arc::new(QueueSpec::standard(
            opts.get_usize("capacity", 50).map_err(err_str)?,
        )),
        "replacement" => Arc::new(ReplacementSpec::standard(
            opts.get_usize("num_states", 50).map_err(err_str)?,
        )),
        other => return Err(format!("unknown model '{other}'")),
    })
}

fn parse_method(opts: &Options) -> Result<Method, String> {
    let method = opts
        .get_choice("method", &["vi", "mpi", "pi", "ipi"], "ipi")
        .map_err(err_str)?;
    Ok(match method.as_str() {
        "vi" => Method::Vi,
        "mpi" => Method::Mpi {
            sweeps: opts.get_usize("sweeps", 20).map_err(err_str)?,
        },
        "pi" => Method::ExactPi,
        _ => {
            let ksp = KspType::parse(&opts.get_str("ksp_type", "gmres"))?;
            let pc = PcType::parse(&opts.get_str("pc_type", "none"))?;
            Method::Ipi { ksp, pc }
        }
    })
}

fn parse_solve_options(opts: &Options) -> Result<SolveOptions, String> {
    Ok(SolveOptions {
        method: parse_method(opts)?,
        eval_backend: EvalBackend::parse(&opts.get_str("eval_backend", "matfree"))?,
        atol: opts.get_f64("atol", 1e-8).map_err(err_str)?,
        max_outer: opts.get_usize("max_iter_pi", 1000).map_err(err_str)?,
        alpha: opts.get_f64("alpha", 1e-4).map_err(err_str)?,
        adaptive_forcing: opts.get_bool("adaptive_forcing", false).map_err(err_str)?,
        max_inner: opts.get_usize("max_iter_ksp", 10_000).map_err(err_str)?,
        v0: None,
        verbose: opts.get_bool("verbose", false).map_err(err_str)?,
    })
}

fn cmd_solve(opts: &Options) -> Result<(), String> {
    let ranks = opts.get_usize("ranks", 1).map_err(err_str)?;
    let solve_opts = parse_solve_options(opts)?;
    let gamma = opts.get_f64("gamma", 0.99).map_err(err_str)?;
    let file = opts.get("file").map(|s| s.to_string());
    let t0 = std::time::Instant::now();

    let result = if let Some(path) = file {
        let path = Arc::new(path);
        let so = solve_opts.clone();
        let mut results = World::run(ranks, move |comm| {
            let mdp = io::load_dist(&comm, path.as_str())
                .unwrap_or_else(|e| panic!("loading {path}: {e}"));
            let local = solve_dist(&comm, &mdp, &so);
            gather_result(&comm, local)
        });
        results.swap_remove(0)
    } else {
        let generator = make_generator(opts)?;
        let objective = madupite::mdp::Objective::parse(&opts.get_str("objective", "min"))?;
        let so = solve_opts.clone();
        let mut results = World::run(ranks, move |comm| {
            let mdp = generator.build_dist(&comm, gamma).with_objective(objective);
            let local = solve_dist(&comm, &mdp, &so);
            gather_result(&comm, local)
        });
        results.swap_remove(0)
    };

    println!(
        "method={} backend={} states={} converged={} outer={} spmvs={} residual={:.3e} \
         err_bound={:.3e} time={:.3}s comm={}B",
        solve_opts.method.name(),
        solve_opts.eval_backend.name(),
        result.value.len(),
        result.converged,
        result.outer_iterations,
        result.total_spmvs,
        result.residual,
        result.error_bound(),
        t0.elapsed().as_secs_f64(),
        result.comm_bytes,
    );
    if let Some(json_path) = opts.get("json") {
        let j = result.to_json(&solve_opts.method.name());
        std::fs::write(json_path, j.to_string_pretty()).map_err(err_str)?;
        println!("wrote {json_path}");
    }
    Ok(())
}

fn cmd_generate(opts: &Options) -> Result<(), String> {
    let generator = make_generator(opts)?;
    let gamma = opts.get_f64("gamma", 0.99).map_err(err_str)?;
    let objective = madupite::mdp::Objective::parse(&opts.get_str("objective", "min"))?;
    let ranks = opts.get_usize("ranks", 1).map_err(err_str)?;
    let chunk_rows = opts
        .get_usize("chunk_rows", io::DEFAULT_CHUNK_ROWS)
        .map_err(err_str)?;
    let file = opts
        .get("file")
        .ok_or("generate requires -file <out.mdpb>")?
        .to_string();
    // Streaming v2 pipeline: rank-local blocks go straight from the
    // generator to disk, O(chunk) memory — never a full in-memory Mdp.
    let t0 = std::time::Instant::now();
    let path = Arc::new(file.clone());
    let results = World::run(ranks, move |comm| {
        generator.write_mdpb(
            &comm,
            gamma,
            objective,
            std::path::Path::new(path.as_str()),
            chunk_rows,
        )
    });
    // every rank writes its own block — any rank failing means the file
    // is incomplete, so surface the first per-rank error
    let mut header = None;
    for (rank, r) in results.into_iter().enumerate() {
        header = Some(r.map_err(|e| format!("rank {rank}: {e}"))?);
    }
    let h = header.expect("world has at least one rank");
    println!(
        "wrote {file}: {} states × {} actions, nnz={}, gamma={}, objective={} \
         (v{}, {} ranks, {:.3}s)",
        h.n_states,
        h.n_actions,
        h.nnz,
        h.gamma,
        h.objective.name(),
        h.version,
        ranks,
        t0.elapsed().as_secs_f64(),
    );
    Ok(())
}

fn cmd_info(opts: &Options) -> Result<(), String> {
    let file = opts.get("file").ok_or("info requires -file <path>")?;
    let mut f = std::fs::File::open(file).map_err(err_str)?;
    let file_len = f.metadata().map_err(err_str)?.len();
    let h = io::read_header(&mut f).map_err(err_str)?;
    h.validate_file_len(file_len).map_err(err_str)?;
    println!(
        "{file}: v{} n_states={} n_actions={} gamma={} objective={} nnz={} \
         ({:.2} per row, {} bytes)",
        h.version,
        h.n_states,
        h.n_actions,
        h.gamma,
        h.objective.name(),
        h.nnz,
        h.nnz as f64 / (h.n_states * h.n_actions) as f64,
        file_len,
    );
    Ok(())
}

fn cmd_artifacts(opts: &Options) -> Result<(), String> {
    let dir = opts.get_str("dir", "artifacts");
    let mut engine = madupite::runtime::Engine::load(&dir).map_err(err_str)?;
    println!("platform: {}", engine.platform());
    for file in engine.available() {
        print!("  {file} ... ");
        match engine.executable(&file) {
            Ok(_) => println!("compiles"),
            Err(e) => println!("FAILED: {e}"),
        }
    }
    Ok(())
}
