//! madupite CLI — a thin shell over the embedded API (`madupite::api`).
//!
//! Usage (PETSc/madupite-style options database):
//!
//! ```text
//! madupite solve    -model maze -rows 200 -cols 200 -gamma 0.99
//!                   -method ipi -ksp_type gmres -alpha 1e-4 -atol 1e-8
//!                   -ranks 4 [-json out.json] [-write_policy pi.txt]
//!                   [-write_cost v.txt] [-write_json_metadata meta.json]
//! madupite solve    -file model.mdpb -method mpi -sweeps 20
//! madupite generate -model sis -population 10000 -gamma 0.95 -file out.mdpb
//! madupite info     -file model.mdpb
//! madupite artifacts [-dir artifacts]
//! ```
//!
//! Solves can additionally persist to a policy store (`-serve_store <dir>`)
//! which the companion `madupite-serve` binary answers queries from — see
//! the "Serving solved policies" guide chapter.
//!
//! Options are ingested lowest-priority-first from the `MADUPITE_OPTIONS`
//! environment variable, then `-options_file <path>`, then the command
//! line. Unknown `-keys` are hard errors with a nearest-key suggestion.
//! The full key table and the model catalog live in `madupite::api` — the
//! help below is generated from them, so it cannot drift.

use madupite::api::options::{OptionScope, OPTION_TABLE};
use madupite::api::{self, MdpBuilder};
use madupite::mdp::{io, DiscountMode};
use madupite::util::args::Options;
use std::sync::Arc;

fn main() {
    let opts = match assemble_options() {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    };
    let cmd = opts.positional().first().cloned().unwrap_or_default();
    let code = match cmd.as_str() {
        "solve" => cmd_solve(&opts),
        "generate" => cmd_generate(&opts),
        "info" => cmd_info(&opts),
        "artifacts" => cmd_artifacts(&opts),
        "" | "help" | "-h" | "--help" => {
            print_help();
            Ok(())
        }
        other => Err(format!("unknown command '{other}' (try `madupite help`)")),
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e}");
        1
    });
    // Known keys that this command never consulted (e.g. -population with
    // -model maze) are reported as a warning; unknown keys were already
    // rejected up front by `validate_keys`. Only meaningful when the
    // command actually ran to completion.
    if code == 0 && matches!(cmd.as_str(), "solve" | "generate" | "info" | "artifacts") {
        let unused = opts.unused_keys();
        if !unused.is_empty() {
            eprintln!("warning: unused options: {unused:?}");
        }
    }
    std::process::exit(code);
}

/// Layer the options database PETSc style: `MADUPITE_OPTIONS` environment
/// variable first, then `-options_file <path>`, then the command line
/// (highest priority).
fn assemble_options() -> Result<Options, String> {
    let mut cli = Options::from_env();
    let mut env_opts = Options::default();
    if let Ok(text) = std::env::var("MADUPITE_OPTIONS") {
        env_opts = Options::parse(text.split_whitespace().map(str::to_string));
        reject_positionals(&env_opts, "MADUPITE_OPTIONS")?;
    }
    // -options_file is a front-end key: honored from the CLI or the env
    // layer, consumed here (taken out of *both* layers, unconditionally,
    // so no copy of it ever reaches the solve path) with the CLI winning.
    let cli_options_file = cli.take("options_file");
    let env_options_file = env_opts.take("options_file");
    let options_file = cli_options_file.or(env_options_file);
    // Track whether gamma/objective/discount_mode/model were given
    // *explicitly* (CLI or options file) before the layers are flattened —
    // see below.
    let mut explicit_gamma = cli.keys().any(|k| k == "gamma");
    let mut explicit_objective = cli.keys().any(|k| k == "objective");
    let mut explicit_discount_mode = cli.keys().any(|k| k == "discount_mode");
    let mut explicit_model = cli.keys().any(|k| k == "model");
    let mut layers = env_opts;
    if let Some(path) = options_file {
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("reading -options_file {path}: {e}"))?;
        let file_opts = Options::parse_file(&text);
        reject_positionals(&file_opts, "-options_file")?;
        if file_opts.keys().any(|k| k == "options_file") {
            return Err("-options_file cannot be nested inside an options file".into());
        }
        explicit_gamma |= file_opts.keys().any(|k| k == "gamma");
        explicit_objective |= file_opts.keys().any(|k| k == "objective");
        explicit_discount_mode |= file_opts.keys().any(|k| k == "discount_mode");
        explicit_model |= file_opts.keys().any(|k| k == "model");
        layers = layers.merge(file_opts);
    }
    let mut opts = layers.merge(cli);
    // A .mdpb source carries gamma/objective/discount mode in its header
    // and *is* the model. Env-layer defaults for
    // -gamma/-objective/-discount_mode/-model are meant for model-source
    // runs, so for -file solves they silently yield; only *explicit*
    // values (CLI or options file) stay in the database and conflict
    // loudly downstream. (generate's -file is an output path — env
    // defaults stay meaningful there.)
    let file_solve = opts.positional().first().map(String::as_str) == Some("solve")
        && opts.keys().any(|k| k == "file");
    if file_solve {
        if !explicit_gamma {
            opts.take("gamma");
        }
        if !explicit_objective {
            opts.take("objective");
        }
        if !explicit_discount_mode {
            opts.take("discount_mode");
        }
        if !explicit_model {
            opts.take("model");
        }
    }
    Ok(opts)
}

/// The low-priority option layers may only carry `-key value` pairs — a
/// stray bare token there would displace the CLI subcommand.
fn reject_positionals(opts: &Options, origin: &str) -> Result<(), String> {
    match opts.positional() {
        [] => Ok(()),
        [first, ..] => Err(format!(
            "{origin} may only contain -key value options, found stray token '{first}'"
        )),
    }
}

fn print_help() {
    println!(
        "madupite-rs {} — distributed solver for large-scale MDPs\n\n\
         commands:\n\
         \x20 solve     solve an MDP from -model <name> or -file <path.mdpb>\n\
         \x20 generate  stream a model to a .mdpb v2 file (-model, -file; rank-parallel,\n\
         \x20           O(chunk) memory, bytes identical for every -ranks)\n\
         \x20 info      print the header of a .mdpb file (-file)\n\
         \x20 artifacts list + smoke-compile PJRT artifacts (-dir)\n\
         \x20 help      this text",
        madupite::VERSION
    );
    let sections: &[(OptionScope, &str)] = &[
        (OptionScope::Model, "model selection"),
        (OptionScope::Common, "common"),
        (OptionScope::Solve, "solver"),
        (OptionScope::Output, "outputs (solve)"),
        (OptionScope::Generate, "generate"),
        (OptionScope::Tools, "tools"),
        (OptionScope::Serve, "serving (solve -serve_store; madupite-serve)"),
    ];
    for (scope, title) in sections {
        println!("\n{title} options:");
        for spec in OPTION_TABLE.iter().filter(|s| s.scope == *scope) {
            let lhs = if spec.value.is_empty() {
                format!("-{}", spec.key)
            } else {
                format!("-{} {}", spec.key, spec.value)
            };
            println!("  {lhs:<42} {}", spec.help);
        }
    }
    println!("\nmodels (-model <name>, with per-model parameters and defaults):");
    for m in api::MODEL_CATALOG {
        println!("  {:<12} {:<52} {}", m.name, m.params, m.about);
    }
}

fn err_str<E: std::fmt::Display>(e: E) -> String {
    e.to_string()
}

fn cmd_solve(opts: &Options) -> Result<(), String> {
    // Key validation happens inside run_solve — the one shared path.
    let builder = MdpBuilder::from_options(opts).map_err(err_str)?;
    let t0 = std::time::Instant::now();
    // The CLI is a thin shell: the database is handed to the embedded API
    // unchanged (`run_solve` is also what `api::Solver::solve` calls), so
    // both front ends resolve options through one code path.
    let outcome = api::run_solve(&builder, opts).map_err(err_str)?;

    println!(
        "method={} backend={} ranks={} threads={} states={} converged={} outer={} spmvs={} \
         residual={:.3e} err_bound={:.3e} time={:.3}s comm={}B",
        outcome.options.method.name(),
        outcome.options.eval_backend.name(),
        outcome.ranks,
        outcome.threads,
        outcome.n_states,
        outcome.result.converged,
        outcome.result.outer_iterations,
        outcome.result.total_spmvs,
        outcome.result.residual,
        outcome.result.error_bound(),
        t0.elapsed().as_secs_f64(),
        outcome.result.comm_bytes,
    );
    // run_solve already wrote any requested output files; report them.
    for key in [
        "json",
        "write_policy",
        "write_cost",
        "write_json_metadata",
        "write_checkpoint",
    ] {
        if let Some(path) = opts.get(key) {
            println!("wrote {path}");
        }
    }
    if let Some(dir) = opts.get("serve_store") {
        println!("persisted {} to {dir}", outcome.fingerprint());
    }
    Ok(())
}

fn cmd_generate(opts: &Options) -> Result<(), String> {
    api::options::validate_keys(opts).map_err(err_str)?;
    let model = opts.get_str("model", "maze");
    let generator = api::model_from_options(&model, opts).map_err(err_str)?;
    let gamma = api::options::resolve_gamma(opts, None).map_err(err_str)?;
    let objective = api::options::resolve_objective(opts, None).map_err(err_str)?;
    let dmode = api::options::resolve_discount_mode(opts).map_err(err_str)?;
    api::options::check_discount_narrowing(dmode, generator.has_discounts(), "generate")
        .map_err(err_str)?;
    let ranks = opts.get_usize("ranks", 1).map_err(err_str)?;
    if ranks == 0 {
        return Err("-ranks must be >= 1".into());
    }
    let chunk_rows = opts
        .get_usize("chunk_rows", io::DEFAULT_CHUNK_ROWS)
        .map_err(err_str)?;
    if chunk_rows == 0 {
        return Err("-chunk_rows must be >= 1".into());
    }
    let file = opts
        .get("file")
        .ok_or("generate requires -file <out.mdpb>")?
        .to_string();
    // Streaming v3 pipeline: rank-local blocks go straight from the
    // generator to disk, O(chunk) memory — never a full in-memory Mdp.
    // A forced vector -discount_mode on a scalar model streams a constant
    // payload (bitwise-equivalent to the scalar on solve).
    let t0 = std::time::Instant::now();
    let path = Arc::new(file.clone());
    let results = madupite::comm::World::run(ranks, move |comm| {
        let p = std::path::Path::new(path.as_str());
        match dmode {
            Some(mode) if mode != DiscountMode::Scalar && !generator.has_discounts() => {
                io::write_streaming_constant(
                    &comm,
                    p,
                    generator.n_states(),
                    generator.n_actions(),
                    mode,
                    gamma,
                    objective,
                    chunk_rows,
                    |s, a| generator.prob_row(s, a),
                    |s, a| generator.cost(s, a),
                )
            }
            _ => generator.write_mdpb(&comm, gamma, objective, p, chunk_rows),
        }
    });
    // every rank writes its own block — any rank failing means the file
    // is incomplete, so surface the first per-rank error
    let mut header = None;
    for (rank, r) in results.into_iter().enumerate() {
        header = Some(r.map_err(|e| format!("rank {rank}: {e}"))?);
    }
    let h = header.expect("world has at least one rank");
    println!(
        "wrote {file}: {} states × {} actions, nnz={}, gamma={}, discount={}, \
         objective={} (v{}, {} ranks, {:.3}s)",
        h.n_states,
        h.n_actions,
        h.nnz,
        h.gamma,
        h.discount_mode.name(),
        h.objective.name(),
        h.version,
        ranks,
        t0.elapsed().as_secs_f64(),
    );
    Ok(())
}

fn cmd_info(opts: &Options) -> Result<(), String> {
    api::options::validate_keys(opts).map_err(err_str)?;
    let file = opts.get("file").ok_or("info requires -file <path>")?;
    let mut f = std::fs::File::open(file).map_err(err_str)?;
    let file_len = f.metadata().map_err(err_str)?.len();
    let h = io::read_header(&mut f).map_err(err_str)?;
    h.validate_file_len(file_len).map_err(err_str)?;
    println!(
        "{file}: v{} n_states={} n_actions={} gamma={} discount={} objective={} \
         nnz={} ({:.2} per row, {} bytes)",
        h.version,
        h.n_states,
        h.n_actions,
        h.gamma,
        h.discount_mode.name(),
        h.objective.name(),
        h.nnz,
        h.nnz as f64 / (h.n_states * h.n_actions) as f64,
        file_len,
    );
    Ok(())
}

fn cmd_artifacts(opts: &Options) -> Result<(), String> {
    api::options::validate_keys(opts).map_err(err_str)?;
    let dir = opts.get_str("dir", "artifacts");
    let mut engine = madupite::runtime::Engine::load(&dir).map_err(err_str)?;
    println!("platform: {}", engine.platform());
    for file in engine.available() {
        print!("  {file} ... ");
        match engine.executable(&file) {
            Ok(_) => println!("compiles"),
            Err(e) => println!("FAILED: {e}"),
        }
    }
    Ok(())
}
