//! Gridworld / maze navigation MDPs.
//!
//! The navigation benchmark of the iPI companion paper: an agent moves on a
//! `rows × cols` grid with walls, four actions (N/E/S/W), a slip
//! probability (perpendicular drift), unit step cost and an absorbing
//! zero-cost goal. Mazes are carved deterministically from a seed with
//! recursive division, so a 1M-state maze can be generated rank-locally
//! without communication — this is the E2 strong-scaling workload.

use super::ModelGenerator;
use crate::util::prng::Xoshiro256pp;

/// Actions: 0=N, 1=E, 2=S, 3=W.
const DR: [isize; 4] = [-1, 0, 1, 0];
const DC: [isize; 4] = [0, 1, 0, -1];

/// Grid specification. Build with [`GridSpec::open`] or [`GridSpec::maze`].
#[derive(Clone, Debug)]
pub struct GridSpec {
    /// Grid rows.
    pub rows: usize,
    /// Grid columns.
    pub cols: usize,
    /// `walls[r*cols + c]` — wall cells are self-looping high-cost states.
    pub walls: Vec<bool>,
    /// Goal cell (absorbing, zero cost).
    pub goal: (usize, usize),
    /// Probability mass that drifts to each perpendicular direction.
    pub slip: f64,
}

impl GridSpec {
    /// Open room without interior walls; goal in the far corner.
    pub fn open(rows: usize, cols: usize) -> GridSpec {
        assert!(rows >= 2 && cols >= 2);
        GridSpec {
            rows,
            cols,
            walls: vec![false; rows * cols],
            goal: (rows - 1, cols - 1),
            slip: 0.1,
        }
    }

    /// Recursive-division maze, deterministic in `seed`.
    pub fn maze(rows: usize, cols: usize, seed: u64) -> GridSpec {
        let mut spec = GridSpec::open(rows, cols);
        let mut rng = Xoshiro256pp::new(seed);
        divide(&mut spec.walls, cols, 0, 0, rows, cols, &mut rng, 0);
        // goal must be free: carve it and its neighborhood
        let (gr, gc) = (rows - 1, cols - 1);
        spec.walls[gr * cols + gc] = false;
        if gr > 0 {
            spec.walls[(gr - 1) * cols + gc] = false;
        }
        if gc > 0 {
            spec.walls[gr * cols + gc - 1] = false;
        }
        // start corner free as well
        spec.walls[0] = false;
        spec.goal = (gr, gc);
        spec
    }

    /// Total number of grid cells (`rows * cols`).
    pub fn n_cells(&self) -> usize {
        self.rows * self.cols
    }

    fn is_wall(&self, r: isize, c: isize) -> bool {
        if r < 0 || c < 0 || r as usize >= self.rows || c as usize >= self.cols {
            return true; // out of bounds behaves like a wall
        }
        self.walls[r as usize * self.cols + c as usize]
    }

    fn goal_state(&self) -> usize {
        self.goal.0 * self.cols + self.goal.1
    }

    /// Successor cell when moving from (r,c) in direction d (stay on wall).
    fn step(&self, r: usize, c: usize, d: usize) -> usize {
        let (nr, nc) = (r as isize + DR[d], c as isize + DC[d]);
        if self.is_wall(nr, nc) {
            r * self.cols + c
        } else {
            nr as usize * self.cols + nc as usize
        }
    }
}

/// Iterative recursive-division (explicit stack to bound memory on big
/// mazes): splits a chamber with a wall + door, recurses on both halves.
///
/// Connectivity invariant: walls live on **even global** coordinates and
/// doors on **odd global** coordinates, so a perpendicular wall added later
/// (even coordinate) can never cover a door cell (odd coordinate) — the
/// maze stays fully connected regardless of subdivision order.
#[allow(clippy::too_many_arguments)]
fn divide(
    walls: &mut [bool],
    stride: usize,
    top: usize,
    left: usize,
    height: usize,
    width: usize,
    rng: &mut Xoshiro256pp,
    _depth: usize,
) {
    /// Pick a random value of the given parity in [lo, hi] (inclusive).
    fn pick(rng: &mut Xoshiro256pp, lo: usize, hi: usize, odd: bool) -> Option<usize> {
        if hi < lo {
            return None;
        }
        let first = if (lo % 2 == 1) == odd { lo } else { lo + 1 };
        if first > hi {
            return None;
        }
        let count = (hi - first) / 2 + 1;
        Some(first + 2 * rng.index(count))
    }

    let mut stack = vec![(top, left, height, width)];
    while let Some((top, left, height, width)) = stack.pop() {
        if height < 3 || width < 3 {
            continue;
        }
        let prefer_horizontal = if width < height {
            true
        } else if height < width {
            false
        } else {
            rng.next_below(2) == 0
        };
        // wall on an even global coordinate strictly inside the chamber,
        // door on an odd global coordinate spanning the chamber
        let try_h = |rng: &mut Xoshiro256pp| {
            let wy = pick(rng, top + 1, top + height - 2, false)?;
            let door = pick(rng, left, left + width - 1, true)?;
            Some((wy, door))
        };
        let try_v = |rng: &mut Xoshiro256pp| {
            let wx = pick(rng, left + 1, left + width - 2, false)?;
            let door = pick(rng, top, top + height - 1, true)?;
            Some((wx, door))
        };
        let (horizontal, cut) = if prefer_horizontal {
            match try_h(rng) {
                Some(c) => (true, Some(c)),
                None => (false, try_v(rng)),
            }
        } else {
            match try_v(rng) {
                Some(c) => (false, Some(c)),
                None => (true, try_h(rng)),
            }
        };
        let Some((w_coord, door)) = cut else { continue };
        if horizontal {
            for x in left..left + width {
                if x != door {
                    walls[w_coord * stride + x] = true;
                }
            }
            stack.push((top, left, w_coord - top, width));
            stack.push((w_coord + 1, left, top + height - w_coord - 1, width));
        } else {
            for y in top..top + height {
                if y != door {
                    walls[y * stride + w_coord] = true;
                }
            }
            stack.push((top, left, height, w_coord - left));
            stack.push((top, w_coord + 1, height, left + width - w_coord - 1));
        }
    }
}

impl ModelGenerator for GridSpec {
    fn n_states(&self) -> usize {
        self.n_cells()
    }

    fn n_actions(&self) -> usize {
        4
    }

    fn prob_row(&self, s: usize, a: usize) -> Vec<(usize, f64)> {
        let (r, c) = (s / self.cols, s % self.cols);
        if s == self.goal_state() || self.walls[s] {
            return vec![(s, 1.0)]; // absorbing (goal or unreachable wall)
        }
        let main = self.step(r, c, a);
        let perp1 = self.step(r, c, (a + 1) % 4);
        let perp2 = self.step(r, c, (a + 3) % 4);
        let mut row: Vec<(usize, f64)> = vec![
            (main, 1.0 - self.slip),
            (perp1, self.slip / 2.0),
            (perp2, self.slip / 2.0),
        ];
        // merge duplicates (e.g. bounced off walls to the same cell)
        row.sort_by_key(|&(t, _)| t);
        let mut merged: Vec<(usize, f64)> = Vec::with_capacity(3);
        for (t, p) in row {
            if p == 0.0 {
                continue;
            }
            match merged.last_mut() {
                Some((lt, lp)) if *lt == t => *lp += p,
                _ => merged.push((t, p)),
            }
        }
        merged
    }

    fn cost(&self, s: usize, _a: usize) -> f64 {
        if s == self.goal_state() {
            0.0
        } else if self.walls[s] {
            0.0 // unreachable filler states
        } else {
            1.0
        }
    }
}

/// Convenience: build a maze MDP in one call (used by docs and examples).
pub fn build_gridworld(spec: &GridSpec, gamma: f64) -> crate::mdp::Mdp {
    spec.build_serial(gamma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::check_generator;
    use crate::solver::{solve_serial, Method, SolveOptions};

    #[test]
    fn open_grid_valid() {
        check_generator(&GridSpec::open(5, 7));
    }

    #[test]
    fn maze_valid() {
        check_generator(&GridSpec::maze(15, 15, 42));
    }

    #[test]
    fn maze_deterministic_in_seed() {
        let a = GridSpec::maze(21, 21, 7);
        let b = GridSpec::maze(21, 21, 7);
        let c = GridSpec::maze(21, 21, 8);
        assert_eq!(a.walls, b.walls);
        assert_ne!(a.walls, c.walls);
    }

    #[test]
    fn maze_has_walls_and_free_space() {
        let m = GridSpec::maze(31, 31, 3);
        let wall_count = m.walls.iter().filter(|&&w| w).count();
        assert!(wall_count > 10, "no walls carved");
        assert!(wall_count < m.n_cells() / 2, "too many walls");
    }

    #[test]
    fn goal_is_absorbing_and_free() {
        let m = GridSpec::maze(15, 15, 1);
        let g = m.goal_state();
        assert!(!m.walls[g]);
        assert_eq!(m.prob_row(g, 2), vec![(g, 1.0)]);
        assert_eq!(m.cost(g, 0), 0.0);
    }

    #[test]
    fn slip_mass_distributed() {
        let m = GridSpec::open(5, 5);
        // interior cell, no walls around
        let s = 2 * 5 + 2;
        let row = m.prob_row(s, 0);
        let main: f64 = row
            .iter()
            .filter(|&&(t, _)| t == 1 * 5 + 2)
            .map(|&(_, p)| p)
            .sum();
        assert!((main - 0.9).abs() < 1e-12);
        assert_eq!(row.len(), 3);
    }

    #[test]
    fn bounce_off_boundary_stays() {
        let m = GridSpec::open(4, 4);
        // top-left corner, move north → bounce to stay
        let row = m.prob_row(0, 0);
        let stay: f64 = row
            .iter()
            .filter(|&&(t, _)| t == 0)
            .map(|&(_, p)| p)
            .sum();
        // main (north, bounced) + west slip (bounced) = 0.9 + 0.05
        assert!((stay - 0.95).abs() < 1e-12);
    }

    #[test]
    fn optimal_value_increases_with_distance() {
        // On an open grid, V* at the goal is 0 and grows with distance.
        let m = GridSpec::open(6, 6);
        let mdp = m.build_serial(0.95);
        let r = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::ipi_gmres(),
                atol: 1e-9,
                ..Default::default()
            },
        );
        let g = m.goal_state();
        assert!(r.value[g].abs() < 1e-8);
        // the start corner (0,0) is farthest → largest value
        let vmax = r.value.iter().cloned().fold(f64::MIN, f64::max);
        assert!((r.value[0] - vmax).abs() < 1e-6, "corner not the worst");
        // neighbor of goal cheaper than corner
        assert!(r.value[g - 1] < r.value[0]);
    }

    /// BFS over free cells from (0,0).
    fn reachable(m: &GridSpec) -> Vec<bool> {
        let mut seen = vec![false; m.n_cells()];
        let mut queue = std::collections::VecDeque::new();
        seen[0] = true;
        queue.push_back(0usize);
        while let Some(s) = queue.pop_front() {
            let (r, c) = (s / m.cols, s % m.cols);
            for d in 0..4 {
                let t = m.step(r, c, d);
                if !seen[t] && !m.walls[t] {
                    seen[t] = true;
                    queue.push_back(t);
                }
            }
        }
        seen
    }

    #[test]
    fn maze_fully_connected_all_seeds() {
        // The even-wall/odd-door invariant must make every free cell
        // reachable from the start, for many seeds and odd/even sizes.
        for seed in 0..10u64 {
            for (rows, cols) in [(15, 15), (16, 16), (21, 33), (32, 32)] {
                let m = GridSpec::maze(rows, cols, seed);
                let seen = reachable(&m);
                for s in 0..m.n_cells() {
                    if !m.walls[s] {
                        assert!(
                            seen[s],
                            "free cell {s} unreachable (seed={seed}, {rows}x{cols})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn maze_solvable_start_reaches_goal() {
        let m = GridSpec::maze(15, 15, 9);
        let mdp = m.build_serial(0.99);
        let r = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::ipi_gmres(),
                atol: 1e-8,
                ..Default::default()
            },
        );
        assert!(r.converged);
        // start value finite and below the "never reach goal" plateau 1/(1−γ)
        let plateau = 1.0 / (1.0 - 0.99);
        assert!(
            r.value[0] < plateau * 0.9,
            "start unreachable: V[0]={} plateau={plateau}",
            r.value[0]
        );
    }
}
