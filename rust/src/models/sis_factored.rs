//! Factored network-SIS epidemic control (DESIGN.md §17): the
//! combinatorial cousin of the birth–death [`super::sis`] chain.
//!
//! `N` individuals sit on a ring contact network; each is susceptible (0)
//! or infected (1), so the flat state space is `2^N` — out of reach for
//! any flat catalog generator at modest `N`, but compactly factored: each
//! node's next state depends only on itself and its two ring neighbors
//! (CPT scope 3), and the stage cost is a sum of per-node infection
//! burdens plus a global treatment cost. Two actions: do nothing, or
//! treat (population-wide: lower contact transmission, faster recovery,
//! at a fixed cost per period).
//!
//! Per-node weights carry a tiny index-dependent tilt (`1 + 0.001·i`) so
//! optimal Q-values never tie exactly — the cross-representation
//! conformance suite compares *policies* exactly, which demands tie-free
//! instances.

use super::ModelGenerator;
use crate::factored::{CostTerm, Cpt, FactoredMdp, VarSpec};

/// Infection probability per infected ring neighbor, by action.
const BETA: [f64; 2] = [0.35, 0.12];
/// Recovery probability of an infected node, by action.
const RECOVER: [f64; 2] = [0.20, 0.55];
/// Per-period cost of the treat action (empty-scope cost term).
const TREAT_COST: f64 = 0.38;

/// Factored ring-SIS specification.
#[derive(Clone, Debug)]
pub struct SisFactoredSpec {
    nodes: usize,
    fmdp: FactoredMdp,
}

impl SisFactoredSpec {
    /// Build the factored model for a ring of `nodes` individuals
    /// (`nodes >= 3` so the three-variable neighbor scopes are distinct).
    pub fn new(nodes: usize) -> Result<SisFactoredSpec, String> {
        if nodes < 3 {
            return Err(format!(
                "sis_factored needs at least 3 nodes for a ring, got {nodes}"
            ));
        }
        let vars = (0..nodes)
            .map(|i| VarSpec::new(&format!("n{i}"), 2))
            .collect();
        let mut cpts = Vec::with_capacity(nodes);
        for i in 0..nodes {
            let scope = vec![(i + nodes - 1) % nodes, i, (i + 1) % nodes];
            // scope assignment u = x_prev*4 + x_self*2 + x_next
            let mut rows = Vec::with_capacity(2 * 8 * 2);
            for (&beta, &recover) in BETA.iter().zip(RECOVER.iter()) {
                for u in 0..8usize {
                    let (x_prev, x_self, x_next) = ((u >> 2) & 1, (u >> 1) & 1, u & 1);
                    let p_infected = if x_self == 1 {
                        1.0 - recover
                    } else {
                        let k = (x_prev + x_next) as i32;
                        1.0 - (1.0 - beta).powi(k)
                    };
                    rows.push(1.0 - p_infected);
                    rows.push(p_infected);
                }
            }
            cpts.push(Cpt {
                var: i,
                scope,
                rows,
            });
        }
        let mut costs: Vec<CostTerm> = (0..nodes)
            .map(|i| {
                let burden = 1.0 + 0.001 * i as f64;
                CostTerm {
                    scope: vec![i],
                    values: vec![0.0, burden, 0.0, burden],
                }
            })
            .collect();
        costs.push(CostTerm {
            scope: vec![],
            values: vec![0.0, TREAT_COST],
        });
        let fmdp = FactoredMdp::new(vars, 2, cpts, costs).map_err(|e| e.to_string())?;
        Ok(SisFactoredSpec { nodes, fmdp })
    }

    /// Number of ring nodes (`2^nodes` flat states).
    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// The underlying factored description.
    pub fn factored_mdp(&self) -> &FactoredMdp {
        &self.fmdp
    }
}

impl ModelGenerator for SisFactoredSpec {
    fn n_states(&self) -> usize {
        self.fmdp.n_states()
    }

    fn n_actions(&self) -> usize {
        self.fmdp.n_actions()
    }

    fn prob_row(&self, s: usize, a: usize) -> Vec<(usize, f64)> {
        self.fmdp.flat_prob_row(s, a)
    }

    fn cost(&self, s: usize, a: usize) -> f64 {
        self.fmdp.flat_cost(s, a)
    }

    fn factored(&self) -> Option<&FactoredMdp> {
        Some(&self.fmdp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::check_generator;

    #[test]
    fn generator_valid() {
        check_generator(&SisFactoredSpec::new(6).unwrap());
    }

    #[test]
    fn ring_too_small_is_an_error() {
        assert!(SisFactoredSpec::new(2).is_err());
    }

    #[test]
    fn healthy_state_is_absorbing_and_free_without_treatment() {
        let s = SisFactoredSpec::new(5).unwrap();
        // state 0 = all susceptible; no neighbors infected → no infection
        assert_eq!(s.prob_row(0, 0), vec![(0, 1.0)]);
        assert_eq!(s.cost(0, 0), 0.0);
        assert!((s.cost(0, 1) - TREAT_COST).abs() < 1e-15);
    }

    #[test]
    fn treatment_reduces_infection_pressure() {
        let s = SisFactoredSpec::new(5).unwrap();
        let all_infected = s.n_states() - 1;
        // expected next-period infections drop under treatment
        let expect = |a: usize| -> f64 {
            s.prob_row(all_infected, a)
                .iter()
                .map(|&(t, p)| p * (t.count_ones() as f64))
                .sum()
        };
        assert!(expect(1) < expect(0));
    }

    #[test]
    fn cost_counts_infected_nodes() {
        let s = SisFactoredSpec::new(4).unwrap();
        let one_infected = 1usize; // node 3 infected (least significant)
        let c = s.cost(one_infected, 0);
        assert!((c - 1.003).abs() < 1e-12, "c={c}");
    }
}
