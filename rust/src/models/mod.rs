//! Benchmark MDP generators.
//!
//! These are the workloads the paper's motivation cites (White 1985;
//! Steimle & Denton 2017; Xu et al. 2016) and the companion iPI paper
//! benchmarks on: grid/maze navigation, SIS epidemic control, traffic
//! signal control, plus the standard synthetic families (Garnet, inventory
//! control, queueing admission). Every generator is a deterministic
//! function of its spec (+ seed), exposes madupite-style *filler* functions
//! `(s, a) → row / cost`, and can build either a serial [`Mdp`] or a
//! rank-local [`DistMdp`] without ever materializing the global model on
//! one rank.

pub mod garnet;
pub mod gridworld;
pub mod inventory;
pub mod queueing;
pub mod replacement;
pub mod sis;
pub mod traffic;

use crate::comm::Comm;
use crate::mdp::{io, DistMdp, Mdp, Objective};
use std::path::Path;

/// Anything that can generate MDP rows state-by-state.
///
/// `prob_row(s, a)` returns the sparse distribution over successor states;
/// `cost(s, a)` the stage cost. Implementations must be pure functions of
/// `(spec, s, a)` so that distributed construction is reproducible and
/// rank-independent.
pub trait ModelGenerator: Sync {
    /// Number of states of the generated MDP.
    fn n_states(&self) -> usize;
    /// Number of actions of the generated MDP.
    fn n_actions(&self) -> usize;
    /// The sparse successor distribution of `(s, a)`.
    fn prob_row(&self, s: usize, a: usize) -> Vec<(usize, f64)>;
    /// The stage cost of `(s, a)`.
    fn cost(&self, s: usize, a: usize) -> f64;

    /// Build the full serial MDP.
    fn build_serial(&self, gamma: f64) -> Mdp {
        Mdp::from_fillers(
            self.n_states(),
            self.n_actions(),
            gamma,
            |s, a| self.prob_row(s, a),
            |s, a| self.cost(s, a),
        )
    }

    /// Build the rank-local block of the distributed MDP. Collective.
    fn build_dist(&self, comm: &Comm, gamma: f64) -> DistMdp {
        DistMdp::from_fillers(
            comm,
            self.n_states(),
            self.n_actions(),
            gamma,
            |s, a| self.prob_row(s, a),
            |s, a| self.cost(s, a),
        )
    }

    /// Stream the generated MDP straight to a `.mdpb` v2 file without
    /// materializing it: rank-parallel, O(chunk) memory per rank, bytes
    /// identical for every world size (the offline pipeline behind
    /// `madupite generate`). Collective; see [`io::write_streaming`].
    fn write_mdpb(
        &self,
        comm: &Comm,
        gamma: f64,
        objective: Objective,
        path: &Path,
        chunk_rows: usize,
    ) -> std::io::Result<io::Header> {
        io::write_streaming(
            comm,
            path,
            self.n_states(),
            self.n_actions(),
            gamma,
            objective,
            chunk_rows,
            |s, a| self.prob_row(s, a),
            |s, a| self.cost(s, a),
        )
    }
}

/// Shared validation helper used by the per-model tests: every row of every
/// action must be a probability distribution.
#[cfg(test)]
pub(crate) fn check_generator(g: &dyn ModelGenerator) {
    assert!(g.n_states() > 0 && g.n_actions() > 0);
    for s in 0..g.n_states() {
        for a in 0..g.n_actions() {
            let row = g.prob_row(s, a);
            assert!(!row.is_empty(), "empty row at (s={s}, a={a})");
            let mut sum = 0.0;
            for &(c, p) in &row {
                assert!(c < g.n_states(), "target {c} out of range at ({s},{a})");
                assert!(
                    (0.0..=1.0 + 1e-12).contains(&p),
                    "bad probability {p} at ({s},{a})"
                );
                sum += p;
            }
            assert!(
                (sum - 1.0).abs() < 1e-9,
                "row ({s},{a}) sums to {sum}, not 1"
            );
            assert!(g.cost(s, a).is_finite(), "non-finite cost at ({s},{a})");
        }
    }
}
