//! Benchmark MDP generators.
//!
//! These are the workloads the paper's motivation cites (White 1985;
//! Steimle & Denton 2017; Xu et al. 2016) and the companion iPI paper
//! benchmarks on: grid/maze navigation, SIS epidemic control, traffic
//! signal control, plus the standard synthetic families (Garnet, inventory
//! control, queueing admission). Every generator is a deterministic
//! function of its spec (+ seed), exposes madupite-style *filler* functions
//! `(s, a) → row / cost`, and can build either a serial [`Mdp`] or a
//! rank-local [`DistMdp`] without ever materializing the global model on
//! one rank.

pub mod factory;
pub mod garnet;
pub mod gridworld;
pub mod inventory;
pub mod maintenance;
pub mod queueing;
pub mod replacement;
pub mod sis;
pub mod sis_factored;
pub mod traffic;

use crate::comm::Comm;
use crate::mdp::{io, DistMdp, Mdp, Objective};
use std::path::Path;

/// Anything that can generate MDP rows state-by-state.
///
/// `prob_row(s, a)` returns the sparse distribution over successor states;
/// `cost(s, a)` the stage cost. Implementations must be pure functions of
/// `(spec, s, a)` so that distributed construction is reproducible and
/// rank-independent.
///
/// Semi-MDP generators additionally override [`Self::discount`] (and set
/// [`Self::has_discounts`]): given the base per-unit-time discount
/// `gamma`, they return the *effective* per-transition factor `γ(s,a)` —
/// e.g. `r/(r+ρ)` for exponential sojourn times with rate `r` under
/// continuous discount rate `ρ = −ln γ` ([`maintenance`]).
pub trait ModelGenerator: Sync {
    /// Number of states of the generated MDP.
    fn n_states(&self) -> usize;
    /// Number of actions of the generated MDP.
    fn n_actions(&self) -> usize;
    /// The sparse successor distribution of `(s, a)`.
    fn prob_row(&self, s: usize, a: usize) -> Vec<(usize, f64)>;
    /// The stage cost of `(s, a)`.
    fn cost(&self, s: usize, a: usize) -> f64;

    /// The effective discount of `(s, a)` given the base discount `gamma`.
    /// Classic discounted models keep the default (the scalar itself);
    /// semi-MDP generators override it with their per-transition factor
    /// (a pure function of `(spec, s, a, gamma)`).
    fn discount(&self, s: usize, a: usize, gamma: f64) -> f64 {
        let _ = (s, a);
        gamma
    }

    /// Whether [`Self::discount`] is non-uniform — i.e. the generated
    /// model is a semi-MDP with a per-state-action discount vector.
    fn has_discounts(&self) -> bool {
        false
    }

    /// The factored description behind this generator, when there is one
    /// (DESIGN.md §17). Factored catalog models override this so the
    /// structured solver (`-factored_mode svi`) can reach their CPT/cost
    /// decomposition; flat generators keep the default.
    fn factored(&self) -> Option<&crate::factored::FactoredMdp> {
        None
    }

    /// Fallible [`Self::build_serial`]. Well-formed generators only fail
    /// for extreme inputs — e.g. a semi-MDP with a base gamma so close to
    /// 1 that an effective `r/(r+ρ)` rounds to exactly 1.0 — and those
    /// surface as typed errors here (the infallible wrapper panics).
    fn try_build_serial(&self, gamma: f64) -> Result<Mdp, String> {
        if self.has_discounts() {
            Mdp::try_from_fillers_semi(
                self.n_states(),
                self.n_actions(),
                |s, a| self.discount(s, a, gamma),
                |s, a| self.prob_row(s, a),
                |s, a| self.cost(s, a),
            )
        } else {
            Mdp::try_from_fillers(
                self.n_states(),
                self.n_actions(),
                gamma,
                |s, a| self.prob_row(s, a),
                |s, a| self.cost(s, a),
            )
        }
    }

    /// Build the full serial MDP (a semi-MDP when
    /// [`Self::has_discounts`]). Panics on invalid generator output — use
    /// [`Self::try_build_serial`] for the fallible variant.
    fn build_serial(&self, gamma: f64) -> Mdp {
        self.try_build_serial(gamma)
            .unwrap_or_else(|e| panic!("generator produced an invalid MDP: {e}"))
    }

    /// Fallible [`Self::build_dist`] — see [`Self::try_build_serial`] for
    /// when generators fail. Collective (errors agree across ranks).
    fn try_build_dist(&self, comm: &Comm, gamma: f64) -> Result<DistMdp, String> {
        if self.has_discounts() {
            DistMdp::try_from_fillers_semi(
                comm,
                self.n_states(),
                self.n_actions(),
                |s, a| self.discount(s, a, gamma),
                |s, a| self.prob_row(s, a),
                |s, a| self.cost(s, a),
            )
        } else {
            DistMdp::try_from_fillers(
                comm,
                self.n_states(),
                self.n_actions(),
                gamma,
                |s, a| self.prob_row(s, a),
                |s, a| self.cost(s, a),
            )
        }
    }

    /// Build the rank-local block of the distributed MDP. Collective.
    /// Panics on invalid generator output — use [`Self::try_build_dist`]
    /// for the fallible variant.
    fn build_dist(&self, comm: &Comm, gamma: f64) -> DistMdp {
        self.try_build_dist(comm, gamma)
            .unwrap_or_else(|e| panic!("generator produced an invalid distributed MDP: {e}"))
    }

    /// Stream the generated MDP straight to a `.mdpb` v3 file without
    /// materializing it: rank-parallel, O(chunk) memory per rank, bytes
    /// identical for every world size (the offline pipeline behind
    /// `madupite generate`). Semi-MDP generators stream their discount
    /// payload chunk-wise alongside the rows. Collective; see
    /// [`io::write_streaming`] / [`io::write_streaming_discounted`].
    fn write_mdpb(
        &self,
        comm: &Comm,
        gamma: f64,
        objective: Objective,
        path: &Path,
        chunk_rows: usize,
    ) -> std::io::Result<io::Header> {
        if self.has_discounts() {
            let disc = |s: usize, a: usize| self.discount(s, a, gamma);
            io::write_streaming_discounted(
                comm,
                path,
                self.n_states(),
                self.n_actions(),
                objective,
                chunk_rows,
                io::StreamDiscount::PerStateAction(&disc),
                |s, a| self.prob_row(s, a),
                |s, a| self.cost(s, a),
            )
        } else {
            io::write_streaming(
                comm,
                path,
                self.n_states(),
                self.n_actions(),
                gamma,
                objective,
                chunk_rows,
                |s, a| self.prob_row(s, a),
                |s, a| self.cost(s, a),
            )
        }
    }
}

/// Shared validation helper used by the per-model tests: every row of every
/// action must be a probability distribution.
#[cfg(test)]
pub(crate) fn check_generator(g: &dyn ModelGenerator) {
    assert!(g.n_states() > 0 && g.n_actions() > 0);
    for s in 0..g.n_states() {
        for a in 0..g.n_actions() {
            let row = g.prob_row(s, a);
            assert!(!row.is_empty(), "empty row at (s={s}, a={a})");
            let mut sum = 0.0;
            for &(c, p) in &row {
                assert!(c < g.n_states(), "target {c} out of range at ({s},{a})");
                assert!(
                    (0.0..=1.0 + 1e-12).contains(&p),
                    "bad probability {p} at ({s},{a})"
                );
                sum += p;
            }
            assert!(
                (sum - 1.0).abs() < 1e-9,
                "row ({s},{a}) sums to {sum}, not 1"
            );
            assert!(g.cost(s, a).is_finite(), "non-finite cost at ({s},{a})");
            for gamma in [0.5, 0.99] {
                crate::mdp::validate_gamma(g.discount(s, a, gamma)).unwrap_or_else(|e| {
                    panic!("bad discount at ({s},{a}) for gamma {gamma}: {e}")
                });
            }
        }
    }
}
