//! Traffic-signal control MDP (Xu et al. 2016 motivation).
//!
//! A two-approach intersection: state = (queue₁, queue₂, active phase),
//! queues saturate at capacity `K`. Each period the controller either keeps
//! the current green phase or switches (losing the period to amber).
//! Arrivals are independent Bernoulli per approach; the green approach
//! discharges up to `saturation` vehicles per period. Cost = total queue
//! (+ a small switching penalty), so the optimal controller trades cycle
//! losses against queue balance.

use super::ModelGenerator;

/// Intersection specification.
#[derive(Clone, Debug)]
pub struct TrafficSpec {
    /// Queue capacity per approach (queues live in 0..=K).
    pub capacity: usize,
    /// Bernoulli arrival probability, approach 1 / approach 2.
    pub arrival1: f64,
    /// Bernoulli arrival probability, approach 2.
    pub arrival2: f64,
    /// Vehicles discharged per green period.
    pub saturation: usize,
    /// Extra cost charged on a phase switch.
    pub switch_penalty: f64,
}

impl TrafficSpec {
    /// The standard benchmark parameterization for a given queue capacity.
    pub fn standard(capacity: usize) -> TrafficSpec {
        TrafficSpec {
            capacity,
            arrival1: 0.45,
            arrival2: 0.30,
            saturation: 1,
            switch_penalty: 0.5,
        }
    }

    fn qdim(&self) -> usize {
        self.capacity + 1
    }

    /// state = ((q1 · qdim) + q2) · 2 + phase
    pub fn encode(&self, q1: usize, q2: usize, phase: usize) -> usize {
        ((q1 * self.qdim()) + q2) * 2 + phase
    }

    /// Decode a state index into `(queue1, queue2, phase)`.
    pub fn decode(&self, s: usize) -> (usize, usize, usize) {
        let phase = s % 2;
        let q = s / 2;
        (q / self.qdim(), q % self.qdim(), phase)
    }
}

/// Actions: 0 = keep current phase, 1 = switch.
impl ModelGenerator for TrafficSpec {
    fn n_states(&self) -> usize {
        self.qdim() * self.qdim() * 2
    }

    fn n_actions(&self) -> usize {
        2
    }

    fn prob_row(&self, s: usize, a: usize) -> Vec<(usize, f64)> {
        let (q1, q2, phase) = self.decode(s);
        let new_phase = if a == 1 { 1 - phase } else { phase };
        // a switch period is amber: nothing discharges
        let (dep1, dep2) = if a == 1 {
            (0usize, 0usize)
        } else if new_phase == 0 {
            (self.saturation, 0)
        } else {
            (0, self.saturation)
        };
        let base1 = q1.saturating_sub(dep1);
        let base2 = q2.saturating_sub(dep2);
        let mut row: Vec<(usize, f64)> = Vec::with_capacity(4);
        for (a1, p1) in [(0usize, 1.0 - self.arrival1), (1, self.arrival1)] {
            for (a2, p2) in [(0usize, 1.0 - self.arrival2), (1, self.arrival2)] {
                let n1 = (base1 + a1).min(self.capacity);
                let n2 = (base2 + a2).min(self.capacity);
                let t = self.encode(n1, n2, new_phase);
                let p = p1 * p2;
                match row.iter_mut().find(|(tt, _)| *tt == t) {
                    Some((_, pp)) => *pp += p,
                    None => row.push((t, p)),
                }
            }
        }
        row.sort_by_key(|&(t, _)| t);
        row
    }

    fn cost(&self, s: usize, a: usize) -> f64 {
        let (q1, q2, _) = self.decode(s);
        (q1 + q2) as f64 + if a == 1 { self.switch_penalty } else { 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::check_generator;
    use crate::models::ModelGenerator;
    use crate::solver::{solve_serial, Method, SolveOptions};

    #[test]
    fn generator_valid() {
        check_generator(&TrafficSpec::standard(6));
    }

    #[test]
    fn encode_decode_roundtrip() {
        let t = TrafficSpec::standard(5);
        for q1 in 0..=5 {
            for q2 in 0..=5 {
                for ph in 0..2 {
                    assert_eq!(t.decode(t.encode(q1, q2, ph)), (q1, q2, ph));
                }
            }
        }
    }

    #[test]
    fn green_discharges_queue() {
        let t = TrafficSpec::standard(5);
        // q1=3, phase 0 green, keep → base1 = 2 (before arrivals)
        let s = t.encode(3, 0, 0);
        let row = t.prob_row(s, 0);
        // no-arrival outcome: (2, 0, 0)
        let target = t.encode(2, 0, 0);
        let p: f64 = row.iter().filter(|&&(x, _)| x == target).map(|&(_, p)| p).sum();
        assert!((p - (1.0 - 0.45) * (1.0 - 0.30)).abs() < 1e-12);
    }

    #[test]
    fn switch_period_is_amber() {
        let t = TrafficSpec::standard(5);
        let s = t.encode(3, 3, 0);
        let row = t.prob_row(s, 1);
        // nothing discharged: all targets have q1 >= 3 and phase flipped
        for &(tgt, _) in &row {
            let (q1, _, ph) = t.decode(tgt);
            assert!(q1 >= 3);
            assert_eq!(ph, 1);
        }
    }

    #[test]
    fn queues_saturate_at_capacity() {
        let t = TrafficSpec::standard(3);
        let s = t.encode(3, 3, 0);
        for a in 0..2 {
            for &(tgt, _) in &t.prob_row(s, a) {
                let (q1, q2, _) = t.decode(tgt);
                assert!(q1 <= 3 && q2 <= 3);
            }
        }
    }

    #[test]
    fn controller_eventually_serves_both_queues() {
        let spec = TrafficSpec::standard(8);
        let mdp = spec.build_serial(0.95);
        let r = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::ipi_gmres(),
                atol: 1e-8,
                ..Default::default()
            },
        );
        assert!(r.converged);
        // if approach 2 is congested and 1 empty while 1 is green, switch
        let s = spec.encode(0, 8, 0);
        assert_eq!(r.policy[s], 1, "should switch to serve congested queue");
        // if the green queue is congested and the red empty, keep
        let s2 = spec.encode(8, 0, 0);
        assert_eq!(r.policy[s2], 0, "should keep serving congested queue");
        // empty intersection has lower value than fully congested
        assert!(
            r.value[spec.encode(0, 0, 0)] < r.value[spec.encode(8, 8, 0)]
        );
    }
}
