//! SIS epidemic-control MDP (Steimle & Denton 2017 motivation; the
//! epidemiology benchmark family of the iPI companion paper).
//!
//! Stochastic SIS (susceptible–infected–susceptible) birth–death chain on a
//! population of `N` individuals: the state is the number of infected
//! `i ∈ {0..N}`, and the decision maker picks one of `m` intervention
//! levels each period. Level `a` scales the contact rate by `1/(1+a)` at a
//! quadratic economic cost. Infections and recoveries happen one at a time
//! (birth–death), giving a tridiagonal transition matrix — sparse,
//! diagonally structured, and with strongly state-dependent mixing: a good
//! stress test for inner-solver choice (claim C2).

use super::ModelGenerator;

/// SIS model specification.
#[derive(Clone, Debug)]
pub struct SisSpec {
    /// Population size (states = 0..=N infected).
    pub population: usize,
    /// Number of intervention levels (actions).
    pub n_interventions: usize,
    /// Base infection pressure β₀.
    pub beta: f64,
    /// Recovery rate μ.
    pub mu: f64,
    /// Weight of the infection burden in the stage cost.
    pub infection_weight: f64,
    /// Weight of the intervention cost.
    pub intervention_weight: f64,
}

impl SisSpec {
    /// Canonical benchmark configuration for a given population.
    pub fn standard(population: usize, n_interventions: usize) -> SisSpec {
        SisSpec {
            population,
            n_interventions,
            beta: 0.6,
            mu: 0.25,
            infection_weight: 1.0,
            intervention_weight: 0.3,
        }
    }

    /// Contact-rate multiplier for intervention level `a`.
    fn contact_scale(&self, a: usize) -> f64 {
        1.0 / (1.0 + a as f64)
    }

    /// Birth (new-infection) probability from state `i` under action `a`.
    fn p_up(&self, i: usize, a: usize) -> f64 {
        let n = self.population as f64;
        let i = i as f64;
        (self.beta * self.contact_scale(a) * i * (n - i) / (n * n)).min(0.49)
    }

    /// Death (recovery) probability from state `i`.
    fn p_down(&self, i: usize) -> f64 {
        let n = self.population as f64;
        (self.mu * i as f64 / n).min(0.49)
    }
}

impl ModelGenerator for SisSpec {
    fn n_states(&self) -> usize {
        self.population + 1
    }

    fn n_actions(&self) -> usize {
        self.n_interventions
    }

    fn prob_row(&self, i: usize, a: usize) -> Vec<(usize, f64)> {
        if i == 0 {
            return vec![(0, 1.0)]; // disease-free absorbing state
        }
        let up = if i < self.population { self.p_up(i, a) } else { 0.0 };
        let down = self.p_down(i);
        let stay = 1.0 - up - down;
        let mut row = Vec::with_capacity(3);
        if down > 0.0 {
            row.push((i - 1, down));
        }
        row.push((i, stay));
        if up > 0.0 {
            row.push((i + 1, up));
        }
        row
    }

    fn cost(&self, i: usize, a: usize) -> f64 {
        if i == 0 {
            return 0.0; // no infection, no intervention needed
        }
        let frac = i as f64 / self.population as f64;
        let act = if self.n_interventions > 1 {
            a as f64 / (self.n_interventions - 1) as f64
        } else {
            0.0
        };
        self.infection_weight * frac + self.intervention_weight * act * act
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::check_generator;
    use crate::models::ModelGenerator;
    use crate::solver::{solve_serial, Method, SolveOptions};

    #[test]
    fn generator_valid() {
        check_generator(&SisSpec::standard(50, 4));
    }

    #[test]
    fn tridiagonal_structure() {
        let s = SisSpec::standard(30, 3);
        for i in 1..30 {
            for a in 0..3 {
                let row = s.prob_row(i, a);
                assert!(row.len() <= 3);
                for &(t, _) in &row {
                    assert!((t as isize - i as isize).abs() <= 1);
                }
            }
        }
    }

    #[test]
    fn disease_free_absorbing() {
        let s = SisSpec::standard(20, 3);
        assert_eq!(s.prob_row(0, 0), vec![(0, 1.0)]);
        assert_eq!(s.cost(0, 2), 0.0);
    }

    #[test]
    fn intervention_reduces_infection_pressure() {
        let s = SisSpec::standard(100, 5);
        // stronger intervention → lower up-probability at mid-epidemic
        let p0 = s.p_up(50, 0);
        let p4 = s.p_up(50, 4);
        assert!(p4 < p0 / 3.0, "p0={p0} p4={p4}");
    }

    #[test]
    fn cost_monotone_in_infections() {
        let s = SisSpec::standard(40, 3);
        assert!(s.cost(10, 0) < s.cost(30, 0));
        // same infections, intervention costs extra
        assert!(s.cost(10, 0) < s.cost(10, 2));
    }

    #[test]
    fn optimal_policy_intervenes_during_epidemic() {
        let spec = SisSpec::standard(60, 4);
        let mdp = spec.build_serial(0.97);
        let r = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::ipi_gmres(),
                atol: 1e-9,
                ..Default::default()
            },
        );
        assert!(r.converged);
        // value is 0 at the disease-free state and increasing in infections
        assert!(r.value[0].abs() < 1e-8);
        assert!(r.value[30] > r.value[5]);
        // at significant prevalence the policy should use some intervention
        let active: usize = (20..50).map(|i| r.policy[i]).max().unwrap();
        assert!(active > 0, "policy never intervenes");
    }
}
