//! Factored machine-line maintenance MDP (DESIGN.md §17) — the factory
//! process-control family of the SPUDD line of work.
//!
//! `K` machines form a production line; each is good (0), worn (1) or
//! failed (2), giving `3^K` flat states. Wear is *directionally coupled*:
//! a failed upstream machine stresses its successor (higher wear/failure
//! probability), so machine `i`'s CPT scope is `[i-1, i]` (just `[i]`
//! for the line head). Actions: `0` runs the line as-is; action `a ≥ 1`
//! services machine `a-1` (mostly restoring it to good) while the rest of
//! the line keeps running degraded.
//!
//! Costs decompose per machine (production loss by condition, tilted by a
//! small per-machine factor so Q-values never tie exactly) plus a
//! per-action service charge — distinct per machine, again to keep the
//! conformance suite's exact-policy comparison well-posed.

use super::ModelGenerator;
use crate::factored::{CostTerm, Cpt, FactoredMdp, VarSpec};

/// Wear probability good→worn while running (base / upstream-failed).
const WEAR: (f64, f64) = (0.20, 0.45);
/// Failure probability worn→failed while running (base / upstream-failed).
const FAIL: (f64, f64) = (0.15, 0.35);
/// Probability a service visit restores the machine to good.
const SERVICE_OK: f64 = 0.85;
/// Production loss per period by condition (good, worn, failed).
const LOSS: [f64; 3] = [0.0, 0.45, 2.2];

/// Factored machine-line specification.
#[derive(Clone, Debug)]
pub struct FactorySpec {
    machines: usize,
    fmdp: FactoredMdp,
}

impl FactorySpec {
    /// Build the factored model for a line of `machines` (`>= 2` so the
    /// upstream coupling exists). Actions: `machines + 1`.
    pub fn new(machines: usize) -> Result<FactorySpec, String> {
        if machines < 2 {
            return Err(format!(
                "factory needs at least 2 machines in the line, got {machines}"
            ));
        }
        let m = machines + 1;
        let vars = (0..machines)
            .map(|i| VarSpec::new(&format!("m{i}"), 3))
            .collect();
        let mut cpts = Vec::with_capacity(machines);
        for i in 0..machines {
            let scope: Vec<usize> = if i == 0 { vec![0] } else { vec![i - 1, i] };
            let card = if i == 0 { 3 } else { 9 };
            let mut rows = Vec::with_capacity(m * card * 3);
            for a in 0..m {
                for u in 0..card {
                    let (upstream, x) = if i == 0 { (0, u) } else { (u / 3, u % 3) };
                    let mut dist = [0.0f64; 3];
                    if a == i + 1 {
                        // service this machine
                        dist[0] += SERVICE_OK;
                        dist[x] += 1.0 - SERVICE_OK;
                    } else {
                        // line runs (possibly while another machine is serviced)
                        let stressed = i > 0 && upstream == 2;
                        match x {
                            0 => {
                                let w = if stressed { WEAR.1 } else { WEAR.0 };
                                dist[0] = 1.0 - w;
                                dist[1] = w;
                            }
                            1 => {
                                let f = if stressed { FAIL.1 } else { FAIL.0 };
                                dist[1] = 1.0 - f;
                                dist[2] = f;
                            }
                            _ => dist[2] = 1.0,
                        }
                    }
                    rows.extend_from_slice(&dist);
                }
            }
            cpts.push(Cpt {
                var: i,
                scope,
                rows,
            });
        }
        let mut costs: Vec<CostTerm> = (0..machines)
            .map(|i| {
                let tilt = 1.0 + 0.01 * i as f64;
                let mut values = Vec::with_capacity(m * 3);
                for _a in 0..m {
                    for x in 0..3 {
                        values.push(tilt * LOSS[x]);
                    }
                }
                CostTerm {
                    scope: vec![i],
                    values,
                }
            })
            .collect();
        costs.push(CostTerm {
            scope: vec![],
            values: (0..m)
                .map(|a| if a == 0 { 0.0 } else { 1.05 + 0.013 * (a - 1) as f64 })
                .collect(),
        });
        let fmdp = FactoredMdp::new(vars, m, cpts, costs).map_err(|e| e.to_string())?;
        Ok(FactorySpec { machines, fmdp })
    }

    /// Number of machines in the line (`3^machines` flat states).
    pub fn machines(&self) -> usize {
        self.machines
    }

    /// The underlying factored description.
    pub fn factored_mdp(&self) -> &FactoredMdp {
        &self.fmdp
    }
}

impl ModelGenerator for FactorySpec {
    fn n_states(&self) -> usize {
        self.fmdp.n_states()
    }

    fn n_actions(&self) -> usize {
        self.fmdp.n_actions()
    }

    fn prob_row(&self, s: usize, a: usize) -> Vec<(usize, f64)> {
        self.fmdp.flat_prob_row(s, a)
    }

    fn cost(&self, s: usize, a: usize) -> f64 {
        self.fmdp.flat_cost(s, a)
    }

    fn factored(&self) -> Option<&FactoredMdp> {
        Some(&self.fmdp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::check_generator;

    #[test]
    fn generator_valid() {
        check_generator(&FactorySpec::new(3).unwrap());
    }

    #[test]
    fn line_too_short_is_an_error() {
        assert!(FactorySpec::new(1).is_err());
    }

    #[test]
    fn all_good_line_is_cheap_and_wears_slowly() {
        let f = FactorySpec::new(3).unwrap();
        assert_eq!(f.cost(0, 0), 0.0);
        // from all-good under run, staying all-good has the largest mass
        let row = f.prob_row(0, 0);
        let stay = row.iter().find(|&&(t, _)| t == 0).unwrap().1;
        assert!(stay > 0.5, "stay={stay}");
    }

    #[test]
    fn upstream_failure_stresses_downstream() {
        let f = FactorySpec::new(2).unwrap();
        // machine 1 good; machine 0 failed (state 2*3+0=6) vs good (0)
        let p_wear = |s: usize| -> f64 {
            f.prob_row(s, 0)
                .iter()
                .filter(|&&(t, _)| t % 3 == 1)
                .map(|&(_, p)| p)
                .sum()
        };
        assert!(p_wear(6) > p_wear(0));
    }

    #[test]
    fn service_mostly_restores() {
        let f = FactorySpec::new(2).unwrap();
        // machine 0 failed, machine 1 good; action 1 services machine 0
        let row = f.prob_row(6, 1);
        let back_to_good: f64 = row
            .iter()
            .filter(|&&(t, _)| t / 3 == 0)
            .map(|&(_, p)| p)
            .sum();
        assert!(back_to_good >= SERVICE_OK - 1e-12, "p={back_to_good}");
    }
}
