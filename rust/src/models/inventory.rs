//! Inventory-control MDP (the classical (s, S) problem — Bäuerle & Rieder
//! 2011 motivation, finance/operations family).
//!
//! State = stock on hand `0..=capacity`; action = order quantity
//! `0..=max_order` (deliveries clipped at capacity). Demand is truncated
//! Poisson. Stage cost = holding + per-unit ordering + fixed ordering +
//! expected stockout penalty. The optimal policy is known to be of (s, S)
//! threshold form, which the tests exploit.

use super::ModelGenerator;

/// Inventory specification.
#[derive(Clone, Debug)]
pub struct InventorySpec {
    /// Maximum stock level.
    pub capacity: usize,
    /// Largest order per period (action count − 1).
    pub max_order: usize,
    /// Poisson demand rate.
    pub demand_rate: f64,
    /// Demand support truncation (0..=demand_max, renormalized).
    pub demand_max: usize,
    /// Cost per unit held per period.
    pub holding_cost: f64,
    /// Cost per unit ordered.
    pub unit_order_cost: f64,
    /// Fixed cost per non-empty order.
    pub fixed_order_cost: f64,
    /// Penalty per unit of unmet demand.
    pub stockout_penalty: f64,
}

impl InventorySpec {
    /// The standard benchmark parameterization for a given capacity.
    pub fn standard(capacity: usize) -> InventorySpec {
        InventorySpec {
            capacity,
            max_order: capacity,
            demand_rate: 2.0,
            demand_max: 8,
            holding_cost: 0.1,
            unit_order_cost: 0.5,
            fixed_order_cost: 0.8,
            stockout_penalty: 4.0,
        }
    }

    /// Truncated, renormalized Poisson pmf over 0..=demand_max.
    pub fn demand_pmf(&self) -> Vec<f64> {
        let mut pmf = Vec::with_capacity(self.demand_max + 1);
        let lambda = self.demand_rate;
        let mut p = (-lambda).exp(); // P(d = 0)
        let mut total = 0.0;
        for d in 0..=self.demand_max {
            if d > 0 {
                p *= lambda / d as f64;
            }
            pmf.push(p);
            total += p;
        }
        for q in &mut pmf {
            *q /= total;
        }
        pmf
    }
}

impl ModelGenerator for InventorySpec {
    fn n_states(&self) -> usize {
        self.capacity + 1
    }

    fn n_actions(&self) -> usize {
        self.max_order + 1
    }

    fn prob_row(&self, s: usize, a: usize) -> Vec<(usize, f64)> {
        let after_order = (s + a).min(self.capacity);
        let pmf = self.demand_pmf();
        let mut row: Vec<(usize, f64)> = Vec::new();
        for (d, &p) in pmf.iter().enumerate() {
            let next = after_order.saturating_sub(d);
            match row.iter_mut().find(|(t, _)| *t == next) {
                Some((_, pp)) => *pp += p,
                None => row.push((next, p)),
            }
        }
        row.sort_by_key(|&(t, _)| t);
        row
    }

    fn cost(&self, s: usize, a: usize) -> f64 {
        let after_order = (s + a).min(self.capacity);
        let effective_order = after_order - s;
        let pmf = self.demand_pmf();
        // expected stockout = Σ_d p(d) · max(d − stock, 0)
        let mut exp_stockout = 0.0;
        for (d, &p) in pmf.iter().enumerate() {
            if d > after_order {
                exp_stockout += p * (d - after_order) as f64;
            }
        }
        self.holding_cost * s as f64
            + self.unit_order_cost * effective_order as f64
            + if effective_order > 0 { self.fixed_order_cost } else { 0.0 }
            + self.stockout_penalty * exp_stockout
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::check_generator;
    use crate::models::ModelGenerator;
    use crate::solver::{solve_serial, SolveOptions};

    #[test]
    fn generator_valid() {
        check_generator(&InventorySpec::standard(12));
    }

    #[test]
    fn pmf_sums_to_one_and_decreases_in_tail() {
        let spec = InventorySpec::standard(10);
        let pmf = spec.demand_pmf();
        let sum: f64 = pmf.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
        // mode of Poisson(2) at d = 1, 2; tail decreasing
        assert!(pmf[7] < pmf[3]);
    }

    #[test]
    fn order_clipped_at_capacity() {
        let spec = InventorySpec::standard(5);
        // s=4, a=5 → after_order = 5 (not 9)
        let row = spec.prob_row(4, 5);
        // zero-demand outcome lands on 5
        assert!(row.iter().any(|&(t, _)| t == 5));
        assert!(row.iter().all(|&(t, _)| t <= 5));
        // effective order = 1 unit, not 5
        let c_over = spec.cost(4, 5);
        let c_exact = spec.cost(4, 1);
        assert!((c_over - c_exact).abs() < 1e-12);
    }

    #[test]
    fn stockout_priced_into_cost() {
        let spec = InventorySpec::standard(10);
        // empty stock, no order → guaranteed expected stockout cost
        let c = spec.cost(0, 0);
        let exp_demand: f64 = spec
            .demand_pmf()
            .iter()
            .enumerate()
            .map(|(d, p)| d as f64 * p)
            .sum();
        assert!((c - spec.stockout_penalty * exp_demand).abs() < 1e-12);
    }

    #[test]
    fn optimal_policy_is_threshold_like() {
        let spec = InventorySpec::standard(15);
        let mdp = spec.build_serial(0.95);
        let r = solve_serial(
            &mdp,
            &SolveOptions {
                atol: 1e-9,
                ..Default::default()
            },
        );
        assert!(r.converged);
        // at full stock ordering is pointless
        assert_eq!(r.policy[15], 0);
        // with empty stock the optimizer orders something
        assert!(r.policy[0] > 0);
        // order-up-to level S = s + a(s) is non-increasing-ish in s for
        // (s,S) policies; check weak monotonicity of the target level
        let target: Vec<usize> = (0..=15).map(|s| s + r.policy[s]).collect();
        let t0 = target[0];
        for s in 0..=15 {
            if r.policy[s] > 0 {
                assert!(
                    (target[s] as isize - t0 as isize).abs() <= 2,
                    "order-up-to level varies wildly: {target:?}"
                );
            }
        }
    }
}
